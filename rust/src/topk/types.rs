//! Shared types for row-wise top-k.

/// Search mode — the paper's two algorithms.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Mode {
    /// Algorithm 1: iterate until the bracket closes below
    /// `eps_rel * max(row)` (the paper's line 3) or the count hits k
    /// exactly. For rows whose max is non-positive — where the paper's
    /// formula would be negative/zero and the width exit could never
    /// fire — the scale falls back to `max(|max(row)|, |min(row)|)`;
    /// see `topk::binary_search`.
    /// `eps_rel = 1e-16` is the paper's "no early stopping" setting
    /// (below f32 resolution, so effectively exact).
    Exact { eps_rel: f32 },
    /// Algorithm 2: hard iteration budget, one-pass selection at the
    /// final lower bracket. Approximate; paper sweeps max_iter in 2..8.
    EarlyStop { max_iter: u32 },
}

impl Mode {
    /// The paper's default exact setting (eps = 1e-16).
    pub const EXACT: Mode = Mode::Exact { eps_rel: 1e-16 };

    pub fn tag(&self) -> String {
        match self {
            Mode::Exact { eps_rel } if *eps_rel <= 1e-15 => "exact".into(),
            Mode::Exact { eps_rel } => format!("exact_eps{eps_rel:.0e}"),
            Mode::EarlyStop { max_iter } => format!("es{max_iter}"),
        }
    }
}

/// Dense row-major result of a batched top-k: row r's selection lives in
/// `values[r*k..(r+1)*k]` / `indices[r*k..(r+1)*k]`.
///
/// Values are **unsorted** (selection order: threshold survivors by
/// index, then borderline supplements by index) exactly as the paper
/// specifies — neural-network consumers never need sorted output.
#[derive(Clone, Debug, PartialEq)]
pub struct TopKResult {
    pub rows: usize,
    pub k: usize,
    pub values: Vec<f32>,
    pub indices: Vec<u32>,
}

impl TopKResult {
    pub fn zeros(rows: usize, k: usize) -> Self {
        TopKResult {
            rows,
            k,
            values: vec![0.0; rows * k],
            indices: vec![0; rows * k],
        }
    }

    #[inline]
    pub fn row_values(&self, r: usize) -> &[f32] {
        &self.values[r * self.k..(r + 1) * self.k]
    }

    #[inline]
    pub fn row_indices(&self, r: usize) -> &[u32] {
        &self.indices[r * self.k..(r + 1) * self.k]
    }

    /// Mutable (values, indices) slices for one row — handed to row
    /// selectors by the batched driver.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> (&mut [f32], &mut [u32]) {
        let k = self.k;
        (
            &mut self.values[r * k..(r + 1) * k],
            &mut self.indices[r * k..(r + 1) * k],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_tags() {
        assert_eq!(Mode::EXACT.tag(), "exact");
        assert_eq!(Mode::EarlyStop { max_iter: 4 }.tag(), "es4");
        assert_eq!(Mode::Exact { eps_rel: 1e-4 }.tag(), "exact_eps1e-4");
    }

    #[test]
    fn result_row_access() {
        let mut r = TopKResult::zeros(3, 2);
        {
            let (v, i) = r.row_mut(1);
            v.copy_from_slice(&[5.0, 6.0]);
            i.copy_from_slice(&[7, 8]);
        }
        assert_eq!(r.row_values(1), &[5.0, 6.0]);
        assert_eq!(r.row_indices(1), &[7, 8]);
        assert_eq!(r.row_values(0), &[0.0, 0.0]);
    }
}
