//! Ablation (beyond the paper's tables): the full top-k algorithm zoo —
//! RTop-K vs RadixSelect, QuickSelect, heap, bucket, bitonic and full
//! sort — across the paper's row-wise regime. Validates the paper's
//! §2.1 qualitative ranking on this substrate and documents where each
//! baseline sits.

use rtopk::bench::{time_algo, workload, Table};
use rtopk::topk::rowwise::RowAlgo;
use rtopk::topk::types::Mode;

fn main() {
    let quick = std::env::var("RTOPK_QUICK").is_ok();
    let n = if quick { 1 << 12 } else { 1 << 14 };
    let cases = [(256usize, 32usize), (256, 128), (1024, 64), (4096, 64)];

    let mut algos: Vec<RowAlgo> = vec![
        RowAlgo::RTopK(Mode::EarlyStop { max_iter: 4 }),
        RowAlgo::RTopK(Mode::EXACT),
    ];
    algos.extend(RowAlgo::all_baselines());

    let mut t = Table::new(
        &format!("Ablation: row-wise top-k algorithms, median ms (N={n})"),
        &["algorithm", "M=256 k=32", "M=256 k=128", "M=1024 k=64", "M=4096 k=64"],
    );
    for algo in algos {
        let mut row = vec![algo.name()];
        for &(m, k) in &cases {
            // bitonic at M=4096 pads to 4096 and runs the full network —
            // expensive; keep it but note the cost is the point.
            let x = workload(n, m, 0xAB1A + (m + k) as u64);
            let v = time_algo(&x, k, algo).median_ms();
            row.push(format!("{v:.2}"));
        }
        t.row(row);
    }
    t.print();
    println!("\nexpected ranking (paper §2.1): rtopk fastest in this regime; bucket\n\
              competitive; radix/quickselect mid; heap ok at small k; bitonic and\n\
              full sort slowest.");
}
