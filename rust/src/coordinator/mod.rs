//! The L3 coordinator: a multi-tenant row-wise top-k *service* and the
//! MaxK-GNN training orchestrator, built on the PJRT runtime and the
//! execution backends.
//!
//! Serving path (quickstart -> production):
//!
//! ```text
//!   client threads ──submit(SubmitRequest)─▶ admission control (tenant)
//!                                           │ quota check: reject or
//!                                           │ reserve (never queue shed
//!                                           ▼            load)
//!                                        Batcher (deadline + WDRR +
//!                                           │     backpressure)
//!                                           │ single-tenant tiles of
//!                                           │ R rows, same (M, k, mode)
//!                                           ▼
//!                                        Scheduler workers
//!                                           │ backend: the planner's
//!                                           │ measured per-shape choice
//!                                           │ (crate::plan)
//!                                           ▼
//!                                        ExecBackend (crate::backend)
//!                                           │ cpu:  in-crate engine
//!                                           │ pjrt: Executor thread
//!                                           │       (owns PJRT)
//! ```
//!
//! The adaptive execution planner (`crate::plan`) owns dispatch end to
//! end: for each batch shape it picks the execution *backend* (a PJRT
//! tile artifact when one is compiled **and measures faster**, the CPU
//! engine otherwise) plus the CPU algorithm and work-unit grain —
//! decided once per shape (cost-model prior + microbenchmark
//! calibration, accelerator probes included) and cached. Backends that
//! cannot execute here skip their probes cleanly, so the service always
//! answers.
//!
//! Requests enter through the typed API (`request`): a
//! [`SubmitRequest`] builder carrying matrix + k plus per-request
//! policy (mode, tenant, end-to-end deadline, WDRR priority,
//! validation and over-quota overrides), answered by a [`TopKTicket`]
//! (`wait` / `wait_timeout` / `try_wait` / `cancel`). The same request
//! type has a versioned binary wire form (`wire`) — the frame format
//! the future network-ingestion and sharding layers speak.
//!
//! Multi-tenancy (`tenant`): every request runs as a tenant; admission
//! control rejects over-quota submissions before they queue (or parks
//! cooperative `Block`-policy submitters FIFO until quota frees), the
//! batcher drains budget-full tiles across tenants proportionally to
//! configured weights (weighted-deficit round-robin scaled by request
//! priority, with deadline flushes exempt so no tenant starves past
//! its latency budget), and metrics keep per-tenant counters and
//! latency reservoirs next to the aggregates. The trainer drives the
//! AOT train/eval step artifacts with device-resident parameter
//! round-trips.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod service;
pub mod tenant;
pub mod trainer;
pub mod wire;

pub use metrics::{
    LoadSnapshot, Metrics, NetGauges, NetProbe, QueueGauges, QueueProbe,
    TelemetryHub,
};
pub use request::{
    CancelToken, OverQuotaPolicy, Priority, SubmitRequest, TopKTicket,
    ValidationPolicy,
};
pub use service::{ServiceStats, TopKService};
#[allow(deprecated)]
pub use service::TopKRequest;
pub use tenant::{TenantDirectory, TenantId, DEFAULT_TENANT};
pub use trainer::{TrainOutcome, Trainer};
