//! Occupancy model: from per-warp cycle estimates to kernel time.
//!
//! The paper's occupancy rule (Appendix B): one warp per row, and
//! `floor(8192 / M)` warps per block so each block's rows fit shared
//! memory. Kernel time = waves * per-warp cycles / clock, where a wave
//! is `SMs * warps_per_sm` concurrent warps.

use crate::simt::cost::CostModel;
use crate::simt::kernels::KernelEstimate;

/// Concurrent warps the device sustains for a given per-warp smem need.
pub fn concurrent_warps(smem_f32_per_warp: usize, sms: usize) -> usize {
    // warps per block limited by the paper's 8192-f32 shared budget
    let per_block = (CostModel::SMEM_F32_PER_BLOCK / smem_f32_per_warp.max(1))
        .clamp(1, 32);
    // Ampere SM sustains up to 48 warps; assume 4 resident blocks/SM max
    let per_sm = (per_block * 4).min(48);
    sms * per_sm
}

/// Estimated kernel wall time in milliseconds for N rows.
pub fn kernel_time_ms(n_rows: usize, est: &KernelEstimate, sms: usize,
                      clock_ghz: f64) -> f64 {
    let conc = concurrent_warps(est.smem_f32, sms) as f64;
    let waves = (n_rows as f64 / conc).ceil();
    let cycles = waves * est.stages.total();
    cycles / (clock_ghz * 1e9) * 1e3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simt::kernels::simulate_rtopk_row;

    #[test]
    fn occupancy_shrinks_with_m() {
        assert!(concurrent_warps(256, 84) > concurrent_warps(2048, 84));
        assert_eq!(concurrent_warps(16_384, 84), 84 * 4);
    }

    #[test]
    fn time_scales_with_rows() {
        let est = simulate_rtopk_row(256, 32, 9.0, &CostModel::A6000);
        let t1 = kernel_time_ms(1 << 14, &est, 84, 1.8);
        let t2 = kernel_time_ms(1 << 20, &est, 84, 1.8);
        assert!(t2 > 30.0 * t1, "t1={t1} t2={t2}");
        assert!(t1 > 0.0);
    }

    #[test]
    fn fig4_magnitude_sanity() {
        // paper Fig 4: N=2^20, M=256 RTop-K kernel runs in ~0.1-1 ms.
        let est = simulate_rtopk_row(256, 32, 9.6, &CostModel::A6000);
        let t = kernel_time_ms(1 << 20, &est,
                               CostModel::A6000_SMS,
                               CostModel::A6000_CLOCK_GHZ);
        assert!((0.02..20.0).contains(&t), "estimated {t} ms");
    }
}
