//! Shape-keyed plan cache with schema-versioned, host-fingerprinted,
//! TTL-stamped JSON persistence.
//!
//! Keys are `(rows-bucket, cols, k, mode-tag)` — the batcher's shape
//! key plus the [`RowBucket`] batch-geometry dimension — so one
//! calibration serves every batch of that keyed shape for the process
//! lifetime, and (when a `cache_path` is configured) across restarts.
//! Each entry additionally records the *backend id* the shape was
//! calibrated to, the raw probe timings behind the decision, and the
//! race's runner-up (the shadow re-probe comparator), so a persisted
//! decision is a complete, auditable execution plan.
//!
//! Persisted plans are measurements of a particular machine at a
//! particular time, so the document carries a schema version, a host
//! fingerprint (`available_parallelism` + the CPU model string), and a
//! creation timestamp checked against a TTL at load. A cache written by
//! another schema or another host — or one older than the TTL — is
//! **rejected wholesale** at load: the planner logs it and
//! re-calibrates instead of trusting timings measured elsewhere (or
//! elsewhen). v2 documents (no rows bucket, no raw timings, no
//! timestamp) are rejected by the version check and re-calibrated. The
//! on-disk format (written with the in-tree `util::json`):
//!
//! ```json
//! {"version": 4,
//!  "host": {"parallelism": 8, "cpu_model": "..."},
//!  "created_unix": 1753660800,
//!  "bucket_bounds": [64, 1024],
//!  "plans": [
//!    {"rows_bucket": "le64", "cols": 256, "k": 32, "mode": "exact",
//!     "backend": "cpu", "algo": "rtopk_exact", "grain": 64,
//!     "probes": [{"kind": "algo", "name": "rtopk_exact",
//!                 "secs": 1.2e-5, "rows": 64}],
//!     "runner_up": {"backend": "cpu", "algo": "heap", "grain": 64},
//!     "shadow": {"ewma": -0.4, "samples": 5, "demotions": 1}}
//! ]}
//! ```
//!
//! Schema v4 adds the document-level `bucket_bounds` pair — the
//! (possibly learned) row-bucket boundaries every `rows_bucket` label
//! in the document is keyed under. v3 documents (fixed 64/1024
//! boundaries, no `bucket_bounds` key) are still accepted and
//! **migrated**: each entry is re-keyed by its calibration probe's row
//! count under the loading cache's current boundaries, so existing
//! calibration survives the schema bump instead of being discarded.
//!
//! The optional `shadow` object is the online-demotion evidence
//! (`plan::ShadowHistory`): present iff the entry's winner was
//! installed by a shadow re-probe demotion. Documents without it load
//! unchanged.
//!
//! Rejection rules, in the order the loader applies them (each is
//! all-or-nothing — a document failing any rule merges zero entries):
//!
//! 1. `version` not 4 (current) or 3 (migrated) — stale or foreign
//!    schema; re-calibrate.
//! 2. Missing or mismatched `host` fingerprint — timings from another
//!    machine are not evidence about this one.
//! 3. Missing `created_unix`, or `now - created_unix > ttl` (with
//!    `ttl > 0`) — measurements expire; hosts drift.
//! 4. A v4 document missing `bucket_bounds`, or carrying a degenerate
//!    pair (`b0 = 0` or `b1 < 2*b0`).
//! 5. Any entry missing a required field (`rows_bucket`, `cols`, `k`,
//!    `mode`, `backend`, `algo`) or naming an unknown bucket /
//!    algorithm / mode tag.
//! 6. Any entry (or its runner-up) pairing an approximate mode key
//!    (`es<N>`, `apx<N>`, loose-eps exact) with a non-rtopk algorithm —
//!    that would change the output contract, not just the speed.
//!
//! Recall-contracted entries (`apx<N>` keys) additionally carry an
//! optional `recall` number — the winner's achieved recall on the
//! qualification probe — following the `shadow` optional-field
//! precedent (an entry-payload addition, not a schema bump; documents
//! without it load unchanged).

use crate::plan::{
    Plan, PlanSource, ProbeKind, RawProbe, RowBucket, RunnerUp, ShadowHistory,
};
use crate::topk::rowwise::RowAlgo;
use crate::topk::types::Mode;
use crate::util::json::{self, Value};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Mutex, RwLock};
use std::time::{SystemTime, UNIX_EPOCH};

/// Version of the persisted document. Bump whenever the schema or the
/// meaning of a field changes; old caches are then re-calibrated, never
/// reinterpreted — except v3, which is migrated (see
/// [`MIGRATABLE_VERSION`]). (v1 had no host fingerprint and no backend
/// field; v2 had no rows bucket, no raw probe timings, and no TTL
/// timestamp; v3 had no `bucket_bounds`.)
pub const SCHEMA_VERSION: usize = 4;

/// The one prior version the loader migrates instead of rejecting:
/// v3 entries carry their calibration probes, which is enough to
/// re-key them under the current bucket boundaries.
pub const MIGRATABLE_VERSION: usize = 3;

/// Default persisted-cache TTL: one week. Calibration is cheap and
/// hosts drift (thermal paste, firmware, co-tenants), so a stale cache
/// is quietly re-measured rather than trusted forever. `0` disables
/// expiry.
pub const DEFAULT_TTL_SECS: u64 = 7 * 24 * 3600;

/// What makes one host's calibration untrustworthy on another.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HostFingerprint {
    /// `std::thread::available_parallelism` at calibration time
    pub parallelism: usize,
    /// CPU model string (`/proc/cpuinfo` on Linux; "unknown" elsewhere)
    pub cpu_model: String,
}

impl HostFingerprint {
    /// Fingerprint of the machine we are running on.
    pub fn current() -> HostFingerprint {
        let parallelism = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        HostFingerprint { parallelism, cpu_model: read_cpu_model() }
    }
}

fn read_cpu_model() -> String {
    if let Ok(text) = std::fs::read_to_string("/proc/cpuinfo") {
        for line in text.lines() {
            if let Some((key, val)) = line.split_once(':') {
                if key.trim() == "model name" {
                    return val.trim().to_string();
                }
            }
        }
    }
    "unknown".into()
}

fn now_unix() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

type Key = (RowBucket, usize, usize, String);

/// Concurrent plan cache (read-mostly; one write per new keyed shape).
#[derive(Debug)]
pub struct PlanCache {
    inner: RwLock<BTreeMap<Key, Plan>>,
    /// `created_unix` of the oldest document merged into this cache.
    /// Preserved across load → save cycles so the TTL measures time
    /// since *calibration*, not time since the last service restart —
    /// re-stamping on every save would let a frequently-restarted
    /// service keep stale measurements alive forever. `None` until a
    /// document is loaded; a never-loaded cache saves with "now".
    created: Mutex<Option<u64>>,
    /// Row-bucket boundaries `(b0, b1)` every key's [`RowBucket`] label
    /// is interpreted under. Seeded with
    /// [`RowBucket::DEFAULT_BOUNDS`]; re-derived from observed traffic
    /// by [`crate::plan::Planner::relearn_buckets`] via
    /// [`PlanCache::set_bounds`], and persisted as the v4 document's
    /// `bucket_bounds`. Lock order: `bounds` before `inner` (only
    /// `set_bounds` holds both).
    bounds: RwLock<(usize, usize)>,
}

impl Default for PlanCache {
    fn default() -> PlanCache {
        PlanCache {
            inner: RwLock::new(BTreeMap::new()),
            created: Mutex::new(None),
            bounds: RwLock::new(RowBucket::DEFAULT_BOUNDS),
        }
    }
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// The current row-bucket boundaries `(b0, b1)`.
    pub fn bounds(&self) -> (usize, usize) {
        *self.bounds.read().unwrap()
    }

    /// The bucket `rows` falls in under the current boundaries.
    pub fn bucket_of(&self, rows: usize) -> RowBucket {
        RowBucket::of_with(rows, self.bounds())
    }

    /// Install new (learned) bucket boundaries, re-keying every cached
    /// entry under them. An entry is re-bucketed by its calibration
    /// probe's row count when it carries one (that is the geometry the
    /// measurement was actually taken at), else by the top edge of its
    /// old bucket. On a key collision the entry from the smaller old
    /// bucket wins (deterministic; the displaced shape lazily
    /// re-calibrates if its geometry recurs). Boundaries are
    /// sanitized to `b0 >= 1`, `b1 >= 2*b0`.
    pub fn set_bounds(&self, b0: usize, b1: usize) {
        let b0 = b0.max(1);
        let b1 = b1.max(b0.saturating_mul(2));
        let mut bounds = self.bounds.write().unwrap();
        if *bounds == (b0, b1) {
            return;
        }
        let (ob0, ob1) = *bounds;
        let mut inner = self.inner.write().unwrap();
        let old: BTreeMap<Key, Plan> = std::mem::take(&mut *inner);
        for ((bucket, cols, k, mode), plan) in old {
            let rows = plan
                .probes
                .iter()
                .find(|p| p.kind == ProbeKind::Algo)
                .map(|p| p.rows)
                .unwrap_or(match bucket {
                    RowBucket::Le64 => ob0,
                    RowBucket::Le1024 => ob1,
                    RowBucket::Gt1024 => ob1.saturating_add(1),
                });
            let rebucketed = RowBucket::of_with(rows, (b0, b1));
            inner.entry((rebucketed, cols, k, mode)).or_insert(plan);
        }
        *bounds = (b0, b1);
    }

    pub fn get(
        &self,
        bucket: RowBucket,
        cols: usize,
        k: usize,
        mode_tag: &str,
    ) -> Option<Plan> {
        self.inner
            .read()
            .unwrap()
            .get(&(bucket, cols, k, mode_tag.to_string()))
            .cloned()
    }

    pub fn insert(
        &self,
        bucket: RowBucket,
        cols: usize,
        k: usize,
        mode_tag: &str,
        plan: Plan,
    ) {
        self.inner
            .write()
            .unwrap()
            .insert((bucket, cols, k, mode_tag.to_string()), plan);
    }

    pub fn len(&self) -> usize {
        self.inner.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of every cached entry (for reporting / persistence).
    pub fn snapshot(&self) -> Vec<(RowBucket, usize, usize, String, Plan)> {
        self.inner
            .read()
            .unwrap()
            .iter()
            .map(|((b, c, k, m), p)| (*b, *c, *k, m.clone(), p.clone()))
            .collect()
    }

    /// Serialize to the JSON document format, stamped with a host
    /// fingerprint and a creation time. Forced plans are deliberately
    /// dropped: they record an operator pin, not a measurement, and
    /// persisting them would keep the pinned choice alive after the pin
    /// is removed from the config.
    pub fn to_json_for_host_at(&self, host: &HostFingerprint, created_unix: u64) -> String {
        let plans: Vec<Value> = self
            .snapshot()
            .into_iter()
            .filter(|(_, _, _, _, plan)| plan.source != PlanSource::Forced)
            .map(|(bucket, cols, k, mode, plan)| {
                let probes: Vec<Value> = plan
                    .probes
                    .iter()
                    .map(|p| {
                        json::obj(vec![
                            ("kind", json::s(p.kind.name())),
                            ("name", json::s(&p.name)),
                            ("secs", json::num(p.secs)),
                            ("rows", json::num(p.rows as f64)),
                        ])
                    })
                    .collect();
                let runner_up = match &plan.runner_up {
                    Some(ru) => json::obj(vec![
                        ("backend", json::s(&ru.backend)),
                        ("algo", json::s(&ru.algo.name())),
                        ("grain", json::num(ru.grain as f64)),
                    ]),
                    None => Value::Null,
                };
                // entry-payload addition (still schema v3): demotion
                // evidence rides with a shadow-demoted plan so a
                // restart cannot resurrect the demoted winner blind
                let shadow = match &plan.shadow {
                    Some(h) => json::obj(vec![
                        ("ewma", json::num(h.ewma)),
                        ("samples", json::num(h.samples as f64)),
                        ("demotions", json::num(h.demotions as f64)),
                    ]),
                    None => Value::Null,
                };
                // achieved recall travels with recall-contracted plans
                // so a recalled decision stays auditable after restart
                let recall = match plan.recall {
                    Some(r) => json::num(r),
                    None => Value::Null,
                };
                json::obj(vec![
                    ("rows_bucket", json::s(bucket.name())),
                    ("cols", json::num(cols as f64)),
                    ("k", json::num(k as f64)),
                    ("mode", json::s(&mode)),
                    ("backend", json::s(&plan.backend)),
                    ("algo", json::s(&plan.algo.name())),
                    ("grain", json::num(plan.grain as f64)),
                    ("probes", json::arr(probes)),
                    ("runner_up", runner_up),
                    ("shadow", shadow),
                    ("recall", recall),
                ])
            })
            .collect();
        let (b0, b1) = self.bounds();
        json::obj(vec![
            ("version", json::num(SCHEMA_VERSION as f64)),
            (
                "host",
                json::obj(vec![
                    ("parallelism", json::num(host.parallelism as f64)),
                    ("cpu_model", json::s(&host.cpu_model)),
                ]),
            ),
            ("created_unix", json::num(created_unix as f64)),
            (
                "bucket_bounds",
                json::arr(vec![json::num(b0 as f64), json::num(b1 as f64)]),
            ),
            ("plans", json::arr(plans)),
        ])
        .to_string()
    }

    /// The stamp a save should carry: the oldest merged document's
    /// `created_unix` when entries were loaded from disk, else now.
    fn persist_stamp(&self) -> u64 {
        self.created.lock().unwrap().unwrap_or_else(now_unix)
    }

    /// Serialize stamped with a host fingerprint, preserving the
    /// original calibration time of loaded entries (see `created`).
    pub fn to_json_for_host(&self, host: &HostFingerprint) -> String {
        self.to_json_for_host_at(host, self.persist_stamp())
    }

    /// Serialize stamped with the current machine's fingerprint.
    pub fn to_json(&self) -> String {
        self.to_json_for_host(&HostFingerprint::current())
    }

    /// Persist to a file (best-effort caller decides how to surface).
    pub fn save(&self, path: &Path) -> Result<(), String> {
        std::fs::write(path, self.to_json())
            .map_err(|e| format!("write plan cache {path:?}: {e}"))
    }

    /// Merge entries from a JSON document into this cache, trusting it
    /// only if its schema version matches, its host fingerprint matches
    /// `host`, and its creation stamp is within `ttl_secs` of
    /// `now_unix` (`ttl_secs = 0` disables expiry). All-or-nothing: a
    /// document that fails anywhere leaves the cache untouched (a
    /// caller that logs "re-calibrating" must actually have ignored all
    /// of it).
    pub fn load_json_for_host_at(
        &self,
        text: &str,
        host: &HostFingerprint,
        now_unix: u64,
        ttl_secs: u64,
    ) -> Result<usize, String> {
        let v = json::parse(text)?;
        let version = v.get("version").and_then(Value::as_usize).unwrap_or(0);
        if version != SCHEMA_VERSION && version != MIGRATABLE_VERSION {
            return Err(format!(
                "plan-cache schema version {version} is neither \
                 {SCHEMA_VERSION} nor the migratable {MIGRATABLE_VERSION} \
                 (stale or foreign cache)"
            ));
        }
        let h = v.get("host").ok_or("plan cache missing host fingerprint")?;
        let parallelism = h
            .get("parallelism")
            .and_then(Value::as_usize)
            .ok_or("bad host.parallelism")?;
        let cpu_model = h
            .get("cpu_model")
            .and_then(Value::as_str)
            .ok_or("bad host.cpu_model")?;
        if parallelism != host.parallelism || cpu_model != host.cpu_model {
            return Err(format!(
                "plan cache was calibrated on another host \
                 ({parallelism} threads, {cpu_model:?}) — this host is \
                 ({} threads, {:?})",
                host.parallelism, host.cpu_model
            ));
        }
        let created = v
            .get("created_unix")
            .and_then(Value::as_usize)
            .ok_or("plan cache missing created_unix stamp")?
            as u64;
        if ttl_secs > 0 {
            let age = now_unix.saturating_sub(created);
            if age > ttl_secs {
                return Err(format!(
                    "plan cache expired (age {age}s > ttl {ttl_secs}s)"
                ));
            }
        }
        // v4 carries the boundaries its bucket labels are keyed under;
        // a v3 document has none (fixed 64/1024) and its entries are
        // migrated below by probe geometry instead
        let doc_bounds = if version == SCHEMA_VERSION {
            let b = v
                .get("bucket_bounds")
                .and_then(Value::as_array)
                .ok_or("plan cache missing bucket_bounds")?;
            let (b0, b1) = match b {
                [b0, b1] => (
                    b0.as_usize().ok_or("bad bucket_bounds[0]")?,
                    b1.as_usize().ok_or("bad bucket_bounds[1]")?,
                ),
                _ => return Err("bucket_bounds must be a [b0, b1] pair".into()),
            };
            if b0 == 0 || b1 < b0.saturating_mul(2) {
                return Err(format!(
                    "degenerate bucket_bounds [{b0}, {b1}] \
                     (need b0 >= 1 and b1 >= 2*b0)"
                ));
            }
            Some((b0, b1))
        } else {
            None
        };
        let plans = v
            .get("plans")
            .and_then(Value::as_array)
            .ok_or("plan cache missing plans array")?;
        let mut parsed: Vec<(RowBucket, usize, usize, String, Plan)> = Vec::new();
        for p in plans {
            let bucket = RowBucket::parse(
                p.get("rows_bucket")
                    .and_then(Value::as_str)
                    .ok_or("bad rows_bucket")?,
            )?;
            let cols = p.get("cols").and_then(Value::as_usize).ok_or("bad cols")?;
            let k = p.get("k").and_then(Value::as_usize).ok_or("bad k")?;
            let mode = p.get("mode").and_then(Value::as_str).ok_or("bad mode")?;
            let backend = p
                .get("backend")
                .and_then(Value::as_str)
                .ok_or("bad backend")?;
            let algo_name =
                p.get("algo").and_then(Value::as_str).ok_or("bad algo")?;
            let grain =
                p.get("grain").and_then(Value::as_usize).unwrap_or(0).max(1);
            let algo = parse_algo(algo_name)?;
            // an approximate mode key (early-stop / loose eps) must map
            // to the paper's kernel — any other algorithm would change
            // the output contract, not just the speed
            let key_mode = parse_mode_tag(mode)?;
            let exact = crate::plan::is_exact_semantics(key_mode);
            if !exact && !matches!(algo, RowAlgo::RTopK(_)) {
                return Err(format!(
                    "plan for approximate mode {mode:?} must use the rtopk \
                     kernel, got {algo_name:?}"
                ));
            }
            let mut probes = Vec::new();
            if let Some(arr) = p.get("probes").and_then(Value::as_array) {
                for pr in arr {
                    probes.push(RawProbe {
                        kind: ProbeKind::parse(
                            pr.get("kind")
                                .and_then(Value::as_str)
                                .ok_or("bad probe kind")?,
                        )?,
                        name: pr
                            .get("name")
                            .and_then(Value::as_str)
                            .ok_or("bad probe name")?
                            .to_string(),
                        secs: pr
                            .get("secs")
                            .and_then(Value::as_f64)
                            .ok_or("bad probe secs")?,
                        rows: pr
                            .get("rows")
                            .and_then(Value::as_usize)
                            .unwrap_or(0)
                            .max(1),
                    });
                }
            }
            let runner_up = match p.get("runner_up") {
                None | Some(Value::Null) => None,
                Some(ru) => {
                    let ru_algo = parse_algo(
                        ru.get("algo")
                            .and_then(Value::as_str)
                            .ok_or("bad runner_up.algo")?,
                    )?;
                    if !exact && !matches!(ru_algo, RowAlgo::RTopK(_)) {
                        return Err(format!(
                            "runner-up for approximate mode {mode:?} must \
                             use the rtopk kernel"
                        ));
                    }
                    Some(RunnerUp {
                        backend: ru
                            .get("backend")
                            .and_then(Value::as_str)
                            .ok_or("bad runner_up.backend")?
                            .to_string(),
                        algo: ru_algo,
                        grain: ru
                            .get("grain")
                            .and_then(Value::as_usize)
                            .unwrap_or(0)
                            .max(1),
                    })
                }
            };
            // optional demotion evidence (entry-payload addition, not
            // a schema bump: older v3 documents simply carry none)
            let shadow = match p.get("shadow") {
                None | Some(Value::Null) => None,
                Some(sh) => Some(ShadowHistory {
                    ewma: sh
                        .get("ewma")
                        .and_then(Value::as_f64)
                        .ok_or("bad shadow.ewma")?,
                    samples: sh
                        .get("samples")
                        .and_then(Value::as_usize)
                        .ok_or("bad shadow.samples")?
                        as u64,
                    demotions: sh
                        .get("demotions")
                        .and_then(Value::as_usize)
                        .ok_or("bad shadow.demotions")?
                        as u32,
                }),
            };
            // optional achieved-recall figure (entry-payload addition,
            // like `shadow`); a present-but-unparseable or out-of-range
            // value rejects the document — it claims evidence it cannot
            // carry
            let recall = match p.get("recall") {
                None | Some(Value::Null) => None,
                Some(r) => {
                    let r = r.as_f64().ok_or("bad recall")?;
                    if !(0.0..=1.0).contains(&r) {
                        return Err(format!("recall {r} outside [0, 1]"));
                    }
                    Some(r)
                }
            };
            parsed.push((
                bucket,
                cols,
                k,
                mode.to_string(),
                Plan {
                    backend: backend.to_string(),
                    algo,
                    grain,
                    source: PlanSource::Cached,
                    probes,
                    runner_up,
                    shadow,
                    recall,
                },
            ));
        }
        let n = parsed.len();
        // v4: adopt the document's boundaries first (set_bounds re-keys
        // anything already cached), then insert under the parsed labels
        // — they were written under exactly these boundaries. v3: keep
        // the current boundaries and migrate each entry by the geometry
        // its calibration probe actually ran at (entries without probes
        // keep their label: under the seed boundaries that is the same
        // partition a v3 writer used).
        if let Some((b0, b1)) = doc_bounds {
            self.set_bounds(b0, b1);
        }
        for (bucket, cols, k, mode, plan) in parsed {
            let bucket = if doc_bounds.is_some() {
                bucket
            } else {
                plan.probes
                    .iter()
                    .find(|p| p.kind == ProbeKind::Algo)
                    .map(|p| self.bucket_of(p.rows))
                    .unwrap_or(bucket)
            };
            self.insert(bucket, cols, k, &mode, plan);
        }
        // remember the oldest merged stamp so a later save carries the
        // calibration time forward instead of refreshing the TTL
        {
            let mut c = self.created.lock().unwrap();
            *c = Some(c.map_or(created, |prev| prev.min(created)));
        }
        Ok(n)
    }

    /// Merge a document checked against `host` at the current time with
    /// the default TTL.
    pub fn load_json_for_host(
        &self,
        text: &str,
        host: &HostFingerprint,
    ) -> Result<usize, String> {
        self.load_json_for_host_at(text, host, now_unix(), DEFAULT_TTL_SECS)
    }

    /// Merge a document checked against the current machine.
    pub fn load_json(&self, text: &str) -> Result<usize, String> {
        self.load_json_for_host(text, &HostFingerprint::current())
    }

    /// Load from a file path with an explicit TTL (`0` = no expiry).
    pub fn load_with_ttl(&self, path: &Path, ttl_secs: u64) -> Result<usize, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read plan cache {path:?}: {e}"))?;
        self.load_json_for_host_at(
            &text,
            &HostFingerprint::current(),
            now_unix(),
            ttl_secs,
        )
    }

    /// Load from a file path with the default TTL.
    pub fn load(&self, path: &Path) -> Result<usize, String> {
        self.load_with_ttl(path, DEFAULT_TTL_SECS)
    }
}

/// Parse a serialized [`RowAlgo`] name (the inverse of
/// `RowAlgo::name()`): `rtopk_<mode-tag>` or a fixed-algorithm name.
pub fn parse_algo(name: &str) -> Result<RowAlgo, String> {
    match name {
        "radix" => Ok(RowAlgo::Radix),
        "quickselect" => Ok(RowAlgo::QuickSelect),
        "heap" => Ok(RowAlgo::Heap),
        "bucket" => Ok(RowAlgo::Bucket),
        "bitonic" => Ok(RowAlgo::Bitonic),
        "sort" => Ok(RowAlgo::Sort),
        _ => {
            let tag = name
                .strip_prefix("rtopk_")
                .ok_or_else(|| format!("unknown algorithm {name:?}"))?;
            Ok(RowAlgo::RTopK(parse_mode_tag(tag)?))
        }
    }
}

/// Parse a `Mode::tag()` string back into a [`Mode`].
pub fn parse_mode_tag(tag: &str) -> Result<Mode, String> {
    if tag == "exact" {
        return Ok(Mode::EXACT);
    }
    if let Some(eps) = tag.strip_prefix("exact_eps") {
        let eps_rel: f32 =
            eps.parse().map_err(|_| format!("bad mode tag {tag:?}"))?;
        return Ok(Mode::Exact { eps_rel });
    }
    if let Some(it) = tag.strip_prefix("es") {
        let max_iter: u32 =
            it.parse().map_err(|_| format!("bad mode tag {tag:?}"))?;
        return Ok(Mode::EarlyStop { max_iter });
    }
    if let Some(rm) = tag.strip_prefix("apx") {
        let recall_milli: u16 =
            rm.parse().map_err(|_| format!("bad mode tag {tag:?}"))?;
        if recall_milli == 0 || recall_milli > 1000 {
            return Err(format!(
                "mode tag {tag:?}: recall target must be in 1..=1000 thousandths"
            ));
        }
        return Ok(Mode::Approx { recall_milli });
    }
    Err(format!("unknown mode tag {tag:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(algo: RowAlgo, grain: usize) -> Plan {
        Plan {
            backend: "cpu".into(),
            algo,
            grain,
            source: PlanSource::Calibrated,
            probes: Vec::new(),
            runner_up: None,
            shadow: None,
            recall: None,
        }
    }

    fn rich_plan() -> Plan {
        Plan {
            backend: "cpu".into(),
            algo: RowAlgo::RTopK(Mode::EXACT),
            grain: 64,
            source: PlanSource::Calibrated,
            probes: vec![
                RawProbe {
                    kind: ProbeKind::Algo,
                    name: "rtopk_exact".into(),
                    secs: 1.25e-5,
                    rows: 64,
                },
                RawProbe {
                    kind: ProbeKind::Backend,
                    name: "pjrt".into(),
                    secs: 3.5e-4,
                    rows: 1024,
                },
            ],
            runner_up: Some(RunnerUp {
                backend: "cpu".into(),
                algo: RowAlgo::Heap,
                grain: 32,
            }),
            shadow: Some(ShadowHistory {
                ewma: -0.375,
                samples: 6,
                demotions: 2,
            }),
            recall: None,
        }
    }

    #[test]
    fn insert_get_snapshot() {
        let c = PlanCache::new();
        assert!(c.is_empty());
        c.insert(RowBucket::Le1024, 256, 32, "exact", plan(RowAlgo::Radix, 64));
        assert_eq!(c.len(), 1);
        let p = c.get(RowBucket::Le1024, 256, 32, "exact").unwrap();
        assert_eq!(p.algo, RowAlgo::Radix);
        assert_eq!(p.grain, 64);
        assert_eq!(p.backend, "cpu");
        assert!(c.get(RowBucket::Le1024, 256, 32, "es4").is_none());
        assert!(
            c.get(RowBucket::Le64, 256, 32, "exact").is_none(),
            "buckets are distinct key dimensions"
        );
        assert_eq!(c.snapshot().len(), 1);
    }

    #[test]
    fn json_roundtrip_preserves_backend_probes_and_runner_up() {
        let c = PlanCache::new();
        c.insert(RowBucket::Le64, 256, 32, "exact", rich_plan());
        c.insert(
            RowBucket::Le1024,
            512,
            16,
            "es4",
            plan(RowAlgo::RTopK(Mode::EarlyStop { max_iter: 4 }), 32),
        );
        c.insert(
            RowBucket::Gt1024,
            768,
            128,
            "exact",
            Plan {
                backend: "pjrt".into(),
                algo: RowAlgo::Bucket,
                grain: 21,
                source: PlanSource::Calibrated,
                probes: Vec::new(),
                runner_up: None,
                shadow: None,
                recall: None,
            },
        );
        // a recall-contracted entry with its achieved-recall figure
        c.insert(
            RowBucket::Le64,
            1024,
            32,
            "apx950",
            Plan {
                recall: Some(0.9625),
                ..plan(RowAlgo::RTopK(Mode::Approx { recall_milli: 950 }), 16)
            },
        );
        let text = c.to_json();
        let d = PlanCache::new();
        assert_eq!(d.load_json(&text).unwrap(), 4);
        for (bucket, cols, k, mode, p) in c.snapshot() {
            let q = d.get(bucket, cols, k, &mode).unwrap();
            assert_eq!(q.algo, p.algo);
            assert_eq!(q.grain, p.grain);
            assert_eq!(q.backend, p.backend);
            assert_eq!(q.probes, p.probes);
            assert_eq!(q.runner_up, p.runner_up);
            assert_eq!(q.shadow, p.shadow, "demotion history roundtrips");
            assert_eq!(q.recall, p.recall, "achieved recall roundtrips");
            assert_eq!(q.source, PlanSource::Cached);
        }
    }

    #[test]
    fn file_roundtrip() {
        let c = PlanCache::new();
        c.insert(RowBucket::Le64, 100, 10, "exact", plan(RowAlgo::QuickSelect, 8));
        let path = std::env::temp_dir().join("rtopk_plan_cache_test.json");
        c.save(&path).unwrap();
        let d = PlanCache::new();
        assert_eq!(d.load(&path).unwrap(), 1);
        assert_eq!(
            d.get(RowBucket::Le64, 100, 10, "exact").unwrap().algo,
            RowAlgo::QuickSelect
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn ttl_expires_old_documents_wholesale() {
        let host = HostFingerprint::current();
        let c = PlanCache::new();
        c.insert(RowBucket::Le64, 256, 32, "exact", plan(RowAlgo::Radix, 64));
        let written_at = 1_000_000u64;
        let text = c.to_json_for_host_at(&host, written_at);
        let d = PlanCache::new();
        // within the ttl: loads
        assert_eq!(
            d.load_json_for_host_at(&text, &host, written_at + 100, 3600)
                .unwrap(),
            1
        );
        // past the ttl: rejected wholesale
        let e = PlanCache::new();
        let err = e
            .load_json_for_host_at(&text, &host, written_at + 7200, 3600)
            .unwrap_err();
        assert!(err.contains("expired"), "got: {err}");
        assert!(e.is_empty());
        // ttl = 0 disables expiry
        assert_eq!(
            e.load_json_for_host_at(&text, &host, written_at + 1_000_000_000, 0)
                .unwrap(),
            1
        );
    }

    #[test]
    fn save_preserves_the_original_calibration_stamp() {
        // Regression: re-stamping created_unix at every save let a
        // load→save cycle (any service restart) refresh the TTL
        // forever; the stamp must keep recording calibration time.
        let host = HostFingerprint::current();
        let src = PlanCache::new();
        src.insert(RowBucket::Le64, 256, 32, "exact", plan(RowAlgo::Radix, 64));
        let t0 = 1_000_000u64;
        let text = src.to_json_for_host_at(&host, t0);
        let d = PlanCache::new();
        assert_eq!(
            d.load_json_for_host_at(&text, &host, t0 + 10, 3600).unwrap(),
            1
        );
        // d re-saves much later: the document must still carry t0...
        let resaved = d.to_json_for_host(&host);
        let e = PlanCache::new();
        let err = e
            .load_json_for_host_at(&resaved, &host, t0 + 7200, 3600)
            .unwrap_err();
        assert!(err.contains("expired"), "ttl was refreshed by save: {err}");
        // ...while a never-loaded cache stamps its own (fresh) time
        let fresh = PlanCache::new();
        fresh.insert(RowBucket::Le64, 64, 8, "exact", plan(RowAlgo::Heap, 8));
        let f = PlanCache::new();
        assert_eq!(f.load_json(&fresh.to_json()).unwrap(), 1);
    }

    #[test]
    fn parse_algo_names() {
        assert_eq!(parse_algo("radix").unwrap(), RowAlgo::Radix);
        assert_eq!(
            parse_algo("rtopk_exact").unwrap(),
            RowAlgo::RTopK(Mode::EXACT)
        );
        assert_eq!(
            parse_algo("rtopk_es4").unwrap(),
            RowAlgo::RTopK(Mode::EarlyStop { max_iter: 4 })
        );
        assert_eq!(
            parse_algo("rtopk_apx950").unwrap(),
            RowAlgo::RTopK(Mode::Approx { recall_milli: 950 })
        );
        assert!(matches!(
            parse_algo("rtopk_exact_eps1e-4").unwrap(),
            RowAlgo::RTopK(Mode::Exact { .. })
        ));
        assert!(parse_algo("nope").is_err());
        assert!(parse_algo("rtopk_wat").is_err());
    }

    #[test]
    fn approx_mode_tags_roundtrip_and_reject_out_of_range_targets() {
        assert_eq!(
            parse_mode_tag("apx950").unwrap(),
            Mode::Approx { recall_milli: 950 }
        );
        assert_eq!(
            parse_mode_tag("apx1000").unwrap(),
            Mode::Approx { recall_milli: 1000 }
        );
        // the tag is lossless: parse(tag(m)) == m for every target
        for rm in [1u16, 500, 950, 999, 1000] {
            let m = Mode::Approx { recall_milli: rm };
            assert_eq!(parse_mode_tag(&m.tag()).unwrap(), m);
        }
        assert!(parse_mode_tag("apx0").is_err(), "recall 0 is meaningless");
        assert!(parse_mode_tag("apx1001").is_err(), "recall > 1 impossible");
        assert!(parse_mode_tag("apx").is_err());
        assert!(parse_mode_tag("apx9.5").is_err());
    }

    #[test]
    fn recall_field_out_of_range_rejects_the_document() {
        let doc = format!(
            r#"{{"version": 3, {}, "plans": [
              {{"rows_bucket": "le64", "cols": 256, "k": 32, "mode": "apx950",
                "backend": "cpu", "algo": "rtopk_apx950", "grain": 8,
                "recall": 1.5}}
            ]}}"#,
            host_json()
        );
        let c = PlanCache::new();
        let err = c.load_json(&doc).unwrap_err();
        assert!(err.contains("recall"), "got: {err}");
        assert!(c.is_empty());
    }

    /// `"host": {...}, "created_unix": N` fragment for hand-built docs.
    fn host_json() -> String {
        let host = HostFingerprint::current();
        format!(
            r#""host": {{"parallelism": {}, "cpu_model": {}}}, "created_unix": {}"#,
            host.parallelism,
            json::s(&host.cpu_model).to_string(),
            super::now_unix()
        )
    }

    #[test]
    fn rejects_bad_documents() {
        let c = PlanCache::new();
        assert!(c.load_json("{}").is_err());
        // v1/v2 documents are stale by definition — recalibrate rather
        // than reinterpret (v2 lacked buckets, probes, and the stamp);
        // a future schema is just as untrustworthy
        assert!(c.load_json(r#"{"version": 1, "plans": []}"#).is_err());
        assert!(c.load_json(r#"{"version": 2, "plans": []}"#).is_err());
        assert!(c.load_json(r#"{"version": 5, "plans": []}"#).is_err());
        // v3 (migratable) and v4 still need a host stamp
        assert!(c.load_json(r#"{"version": 3, "plans": []}"#).is_err());
        assert!(c.load_json(r#"{"version": 4, "plans": []}"#).is_err());
        // v3 without a creation stamp
        let host = HostFingerprint::current();
        let no_stamp = format!(
            r#"{{"version": 3,
                "host": {{"parallelism": {}, "cpu_model": {}}},
                "plans": []}}"#,
            host.parallelism,
            json::s(&host.cpu_model).to_string()
        );
        assert!(c.load_json(&no_stamp).unwrap_err().contains("created_unix"));
        // v4 without bucket_bounds, or with a degenerate pair
        let v4_no_bounds = format!(r#"{{"version": 4, {}, "plans": []}}"#, host_json());
        assert!(c
            .load_json(&v4_no_bounds)
            .unwrap_err()
            .contains("bucket_bounds"));
        let v4_degenerate = format!(
            r#"{{"version": 4, {}, "bucket_bounds": [512, 600], "plans": []}}"#,
            host_json()
        );
        assert!(c
            .load_json(&v4_degenerate)
            .unwrap_err()
            .contains("degenerate"));
        // entry missing required fields
        let doc = format!(
            r#"{{"version": 3, {}, "plans": [{{"cols": 1}}]}}"#,
            host_json()
        );
        assert!(c.load_json(&doc).is_err());
        // entry without a rows bucket (the v3 key dimension)
        let doc = format!(
            r#"{{"version": 3, {}, "plans": [
              {{"cols": 256, "k": 32, "mode": "exact", "backend": "cpu",
                "algo": "radix", "grain": 8}}
            ]}}"#,
            host_json()
        );
        let err = c.load_json(&doc).unwrap_err();
        assert!(err.contains("rows_bucket"), "got: {err}");
        assert!(c.is_empty());
    }

    #[test]
    fn cache_from_another_host_is_recalibrated_not_trusted() {
        let c = PlanCache::new();
        c.insert(RowBucket::Le64, 256, 32, "exact", plan(RowAlgo::Radix, 64));
        let foreign = HostFingerprint {
            parallelism: 31_337,
            cpu_model: "Martian Quantum Core".into(),
        };
        let text = c.to_json_for_host(&foreign);
        let d = PlanCache::new();
        let err = d.load_json(&text).unwrap_err();
        assert!(err.contains("another host"), "got: {err}");
        assert!(d.is_empty(), "foreign cache must not merge");
        // the same document checked against its own fingerprint loads
        assert_eq!(d.load_json_for_host(&text, &foreign).unwrap(), 1);
    }

    #[test]
    fn entries_without_a_backend_id_are_rejected() {
        let doc = format!(
            r#"{{"version": 3, {}, "plans": [
              {{"rows_bucket": "le64", "cols": 256, "k": 32,
                "mode": "exact", "algo": "radix", "grain": 8}}
            ]}}"#,
            host_json()
        );
        let c = PlanCache::new();
        let err = c.load_json(&doc).unwrap_err();
        assert!(err.contains("backend"), "got: {err}");
        assert!(c.is_empty());
    }

    #[test]
    fn forced_plans_are_not_persisted() {
        let c = PlanCache::new();
        c.insert(
            RowBucket::Le64,
            256,
            32,
            "exact",
            plan(RowAlgo::RTopK(Mode::EXACT), 64),
        );
        c.insert(
            RowBucket::Le64,
            512,
            32,
            "exact",
            Plan {
                backend: "pjrt".into(),
                algo: RowAlgo::Sort,
                grain: 64,
                source: PlanSource::Forced,
                probes: Vec::new(),
                runner_up: None,
                shadow: None,
                recall: None,
            },
        );
        let d = PlanCache::new();
        assert_eq!(d.load_json(&c.to_json()).unwrap(), 1);
        assert!(
            d.get(RowBucket::Le64, 512, 32, "exact").is_none(),
            "pin leaked to disk"
        );
    }

    #[test]
    fn approximate_mode_keys_require_the_rtopk_kernel() {
        let c = PlanCache::new();
        let doc = format!(
            r#"{{"version": 3, {}, "plans": [
              {{"rows_bucket": "le64", "cols": 256, "k": 32, "mode": "es4",
                "backend": "cpu", "algo": "heap", "grain": 8}}
            ]}}"#,
            host_json()
        );
        let err = c.load_json(&doc).unwrap_err();
        assert!(err.contains("rtopk"), "got: {err}");
        assert!(c.is_empty());
        // a non-rtopk runner-up under an approximate key is just as
        // wrong: a shadow demotion would then change semantics
        let doc = format!(
            r#"{{"version": 3, {}, "plans": [
              {{"rows_bucket": "le64", "cols": 256, "k": 32, "mode": "es4",
                "backend": "cpu", "algo": "rtopk_es4", "grain": 8,
                "runner_up": {{"backend": "cpu", "algo": "heap",
                               "grain": 8}}}}
            ]}}"#,
            host_json()
        );
        let err = c.load_json(&doc).unwrap_err();
        assert!(err.contains("runner-up"), "got: {err}");
        assert!(c.is_empty());
        // the same algo under an exact key is fine
        let ok = format!(
            r#"{{"version": 3, {}, "plans": [
              {{"rows_bucket": "le64", "cols": 256, "k": 32, "mode": "exact",
                "backend": "cpu", "algo": "heap", "grain": 8}}
            ]}}"#,
            host_json()
        );
        assert_eq!(c.load_json(&ok).unwrap(), 1);
    }

    #[test]
    fn bucket_bounds_roundtrip_in_v4_documents() {
        let c = PlanCache::new();
        assert_eq!(c.bounds(), RowBucket::DEFAULT_BOUNDS);
        c.set_bounds(128, 2048);
        c.insert(RowBucket::Le64, 256, 32, "exact", plan(RowAlgo::Radix, 64));
        let text = c.to_json();
        assert!(text.contains(r#""version":4"#), "got: {text}");
        assert!(text.contains(r#""bucket_bounds":[128,2048]"#), "got: {text}");
        let d = PlanCache::new();
        assert_eq!(d.load_json(&text).unwrap(), 1);
        assert_eq!(d.bounds(), (128, 2048), "learned bounds survive the roundtrip");
        assert!(d.get(RowBucket::Le64, 256, 32, "exact").is_some());
    }

    #[test]
    fn set_bounds_rebuckets_entries_by_probe_geometry() {
        let c = PlanCache::new();
        // calibrated at 500 probe rows -> Le1024 under the seed bounds
        let mut probed = plan(RowAlgo::Radix, 64);
        probed.probes.push(RawProbe {
            kind: ProbeKind::Algo,
            name: "radix".into(),
            secs: 1.0e-5,
            rows: 500,
        });
        c.insert(RowBucket::Le1024, 256, 32, "exact", probed);
        // probe-less entry: falls back to its old bucket's top edge
        c.insert(RowBucket::Le64, 512, 16, "exact", plan(RowAlgo::Heap, 8));
        c.set_bounds(500, 1000);
        assert_eq!(c.len(), 2, "re-keying must not lose calibration");
        // 500 <= the new b0: the small bucket now owns that plan
        assert_eq!(
            c.get(RowBucket::Le64, 256, 32, "exact").unwrap().algo,
            RowAlgo::Radix
        );
        assert!(c.get(RowBucket::Le1024, 256, 32, "exact").is_none());
        // the old small bucket's top edge (64) is still <= 500
        assert!(c.get(RowBucket::Le64, 512, 16, "exact").is_some());
        // setting the same bounds again is a no-op
        c.set_bounds(500, 1000);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn v3_documents_migrate_with_rebucketed_entries() {
        // A v3 document (pre-learned-bounds schema, no bucket_bounds):
        // accepted, entries re-keyed by the geometry their calibration
        // probe ran at under the loading cache's current boundaries.
        let doc = format!(
            r#"{{"version": 3, {}, "plans": [
              {{"rows_bucket": "le1024", "cols": 256, "k": 32, "mode": "exact",
                "backend": "cpu", "algo": "radix", "grain": 8,
                "probes": [{{"kind": "algo", "name": "radix",
                             "secs": 1e-5, "rows": 500}}]}},
              {{"rows_bucket": "le64", "cols": 128, "k": 8, "mode": "exact",
                "backend": "cpu", "algo": "heap", "grain": 8}}
            ]}}"#,
            host_json()
        );
        // under the seed bounds the migration is the identity mapping
        let c = PlanCache::new();
        assert_eq!(c.load_json(&doc).unwrap(), 2);
        assert!(c.get(RowBucket::Le1024, 256, 32, "exact").is_some());
        assert!(c.get(RowBucket::Le64, 128, 8, "exact").is_some());
        // under learned bounds the probed entry re-keys; the probe-less
        // one keeps its label
        let d = PlanCache::new();
        d.set_bounds(500, 1000);
        assert_eq!(d.load_json(&doc).unwrap(), 2);
        assert_eq!(
            d.get(RowBucket::Le64, 256, 32, "exact").unwrap().algo,
            RowAlgo::Radix
        );
        assert!(d.get(RowBucket::Le1024, 256, 32, "exact").is_none());
        assert!(d.get(RowBucket::Le64, 128, 8, "exact").is_some());
        // a migrated cache reserializes as v4 with its own boundaries
        let text = d.to_json();
        assert!(text.contains(r#""version":4"#));
        assert!(text.contains(r#""bucket_bounds":[500,1000]"#));
    }

    #[test]
    fn bad_document_is_all_or_nothing() {
        // a valid entry followed by a broken one must not leave the
        // valid prefix merged in
        let doc = format!(
            r#"{{"version": 3, {}, "plans": [
              {{"rows_bucket": "le64", "cols": 256, "k": 32, "mode": "exact",
                "backend": "cpu", "algo": "radix", "grain": 8}},
              {{"rows_bucket": "le64", "cols": 512, "k": 16, "mode": "exact",
                "backend": "cpu", "algo": "not_an_algo"}}
            ]}}"#,
            host_json()
        );
        let c = PlanCache::new();
        assert!(c.load_json(&doc).is_err());
        assert!(c.is_empty(), "partial merge from a rejected document");
    }
}
