//! Oracle comparison utilities: exact-set checks and the paper's
//! approximation metrics (Table 2's E1 / E2 / Hit).

use crate::topk::types::TopKResult;
use crate::util::matrix::RowMatrix;

/// Per-row approximation metrics of a (possibly approximate) selection
/// against the exact top-k of the same row.
#[derive(Clone, Copy, Debug, Default)]
pub struct ApproxMetrics {
    /// |max(sel) - max(opt)| / |max(opt)|   (paper's E1)
    pub e1: f64,
    /// |min(sel) - min(opt)| / |min(opt)|   (paper's E2)
    pub e2: f64,
    /// |sel ∩ opt| / k                      (paper's Hit)
    pub hit: f64,
}

/// Exact top-k values of one row, sorted descending (the oracle).
pub fn exact_topk_desc(row: &[f32], k: usize) -> Vec<(f32, u32)> {
    let mut pairs: Vec<(f32, u32)> =
        row.iter().enumerate().map(|(j, &v)| (v, j as u32)).collect();
    pairs.sort_by(|a, b| {
        b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1))
    });
    pairs.truncate(k);
    pairs
}

/// True iff the selection's value multiset equals the exact top-k
/// multiset for every row.
pub fn is_exact(x: &RowMatrix, res: &TopKResult) -> bool {
    for r in 0..x.rows {
        let mut got: Vec<f32> = res.row_values(r).to_vec();
        got.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let want: Vec<f32> =
            exact_topk_desc(x.row(r), res.k).iter().map(|p| p.0).collect();
        if got != want {
            return false;
        }
    }
    true
}

/// Table-2 metrics for one row's selection.
pub fn approx_metrics_row(row: &[f32], values: &[f32], indices: &[u32])
    -> ApproxMetrics {
    let k = values.len();
    let opt = exact_topk_desc(row, k);
    let opt_max = opt[0].0 as f64;
    let opt_min = opt[k - 1].0 as f64;
    let sel_max = values.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let sel_min = values.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
    let e1 = ((sel_max - opt_max).abs()) / opt_max.abs().max(f64::MIN_POSITIVE);
    let e2 = ((sel_min - opt_min).abs()) / opt_min.abs().max(f64::MIN_POSITIVE);
    // hit rate by index-set overlap
    let mut opt_idx: Vec<u32> = opt.iter().map(|p| p.1).collect();
    opt_idx.sort_unstable();
    let mut hits = 0usize;
    for &i in indices {
        if opt_idx.binary_search(&i).is_ok() {
            hits += 1;
        }
    }
    ApproxMetrics { e1, e2, hit: hits as f64 / k as f64 }
}

/// Average Table-2 metrics over all rows of a batched result.
pub fn approx_metrics(x: &RowMatrix, res: &TopKResult) -> ApproxMetrics {
    let mut acc = ApproxMetrics::default();
    for r in 0..x.rows {
        let m = approx_metrics_row(x.row(r), res.row_values(r), res.row_indices(r));
        acc.e1 += m.e1;
        acc.e2 += m.e2;
        acc.hit += m.hit;
    }
    let n = x.rows as f64;
    ApproxMetrics { e1: acc.e1 / n, e2: acc.e2 / n, hit: acc.hit / n }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topk::{rowwise_topk, Mode};
    use crate::util::rng::Rng;

    #[test]
    fn exact_mode_is_exact() {
        let mut rng = Rng::seed_from(8);
        let x = RowMatrix::random_normal(64, 128, &mut rng);
        let res = rowwise_topk(&x, 16, Mode::EXACT);
        assert!(is_exact(&x, &res));
        let m = approx_metrics(&x, &res);
        assert!(m.e1 < 1e-12 && m.e2 < 1e-12);
        assert!((m.hit - 1.0).abs() < 1e-12);
    }

    #[test]
    fn early_stop_metrics_in_paper_ballpark() {
        // Table 2, k=32, M=256: paper reports hit = 83.19% at max_iter=5
        // and 90.19% at 8; our implementation measures ~87.8% and ~98.3%
        // (same shape, tighter tail — after i iterations the residual
        // bracket holds ~M*D*phi/2^i ≈ 1.4 borderline candidates at i=8,
        // bounding misses well below the paper's 10%; see EXPERIMENTS.md
        // §Table2 for the discrepancy note). The run is derandomized
        // (fixed seed 9), and the interval bounds carry slack beyond the
        // measured point values: at n = 2000 rows x k = 32 slots the
        // binomial 3-sigma band on a hit rate is ~+-0.6%, but the mean
        // itself shifts by a few percent across RNG streams, so the
        // bounds bracket the *regime* (hit@2 poor, hit@5 good, hit@8
        // near-exact) rather than a specific stream's decimal. The
        // strict orderings below are the paper's structural claims and
        // stay exact.
        let mut rng = Rng::seed_from(9);
        let x = RowMatrix::random_normal(2000, 256, &mut rng);
        let m2 = approx_metrics(&x, &rowwise_topk(&x, 32, Mode::EarlyStop { max_iter: 2 }));
        let m5 = approx_metrics(&x, &rowwise_topk(&x, 32, Mode::EarlyStop { max_iter: 5 }));
        let m8 = approx_metrics(&x, &rowwise_topk(&x, 32, Mode::EarlyStop { max_iter: 8 }));
        assert!(m2.hit < 0.7, "hit@2 = {}", m2.hit);
        assert!((0.75..0.97).contains(&m5.hit), "hit@5 = {}", m5.hit);
        assert!((0.90..=1.0).contains(&m8.hit), "hit@8 = {}", m8.hit);
        assert!(m2.hit < m5.hit && m5.hit < m8.hit);
        assert!(m5.e1 < 0.05 && m8.e1 < m5.e1 + 1e-9);
    }

    #[test]
    fn hit_rate_counts_overlap() {
        let row = [4.0f32, 3.0, 2.0, 1.0];
        // pretend selection picked indices 0 and 2 for k=2 (true top-2 is 0,1)
        let m = approx_metrics_row(&row, &[4.0, 2.0], &[0, 2]);
        assert!((m.hit - 0.5).abs() < 1e-12);
        assert!(m.e1 < 1e-12); // max matches
        assert!((m.e2 - (3.0 - 2.0) / 3.0).abs() < 1e-9);
    }
}

#[cfg(test)]
mod curve_probe {
    use super::*;
    use crate::topk::{rowwise_topk, Mode};
    use crate::util::rng::Rng;

    #[test]
    #[ignore] // probe: run with --ignored to print the Table-2 curve
    fn print_hit_curve() {
        let mut rng = Rng::seed_from(10);
        let x = RowMatrix::random_normal(5000, 256, &mut rng);
        for k in [16usize, 32, 64, 128] {
            for it in [2u32, 3, 4, 5, 6, 7, 8] {
                let m = approx_metrics(&x, &rowwise_topk(&x, k, Mode::EarlyStop { max_iter: it }));
                println!("k={k:3} it={it} E1={:.2}% E2={:.2}% hit={:.2}%", m.e1*100.0, m.e2*100.0, m.hit*100.0);
            }
        }
    }
}
