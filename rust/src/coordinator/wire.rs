//! Versioned wire codec: the framed little-endian binary encoding of
//! [`SubmitRequest`] and [`TopKResult`] — the on-disk / on-socket
//! contract the future network-ingestion and cross-process-sharding
//! layers plug into unchanged.
//!
//! ## Frame layout (schema v1)
//!
//! ```text
//! offset  size  field
//! 0       4     magic  "RTKF"
//! 4       2     schema version (u16 LE) — strict: unknown versions are
//!               rejected with a positioned error, never reinterpreted
//! 6       1     frame kind (1 = submit request, 2 = top-k result,
//!               3 = error, 4 = ping, 5 = pong)
//! 7       1     reserved (must be 0)
//! 8       8     payload length (u64 LE) — must equal the bytes that
//!               actually follow the header, exactly
//! 16      4     payload CRC32 (u32 LE)
//! 20      4     header CRC32 over bytes 0..20 (u32 LE)
//! 24      ...   payload
//! ```
//!
//! Both checksums are standard CRC-32 (IEEE 802.3 reflected polynomial
//! `0xEDB88320`, the same function zlib's `crc32` computes), so any
//! language can produce and verify frames. Every decode failure carries
//! the byte offset it was detected at; decode never panics on arbitrary
//! input — truncations, bit flips, bad enums, and length mismatches are
//! all positioned [`WireError`]s.
//!
//! ## Payloads (all little-endian)
//!
//! Submit request (kind 1):
//!
//! ```text
//! u16 tenant_len, tenant bytes (UTF-8)
//! u32 k
//! u8  mode tag: 0 = unset (tenant/service default),
//!               1 = exact (f32 eps_rel follows),
//!               2 = early-stop (u32 max_iter follows),
//!               3 = approx (u16 recall target in thousandths follows;
//!                   must be 1..=1000 — 0 and impossible targets are
//!                   rejected at both encode and decode)
//! u64 deadline_ns (0 = none; a zero deadline is unrepresentable and
//!                  rejected at encode — the service refuses it anyway)
//! u8  priority: 0 low, 1 normal, 2 high
//! u8  validation: 0 inherit, 1 strict, 2 skip
//! u8  over-quota: 0 service default, 1 reject, 2 block
//! u32 rows, u32 cols
//! rows*cols f32 matrix data (row-major)
//! ```
//!
//! Top-k result (kind 2):
//!
//! ```text
//! u32 rows, u32 k
//! rows*k f32 values
//! rows*k u32 indices
//! ```
//!
//! Error (kind 3) — the server's negative answer to one submit frame
//! (admission rejection, timeout, cancellation, shard failure):
//!
//! ```text
//! u32 code (see ERR_* constants)
//! u32 message length, message bytes (UTF-8)
//! ```
//!
//! Ping (kind 4) / pong (kind 5) — liveness probes, echoed verbatim:
//!
//! ```text
//! u64 nonce
//! ```
//!
//! Golden fixture frames for schema v1 are committed under
//! `rust/tests/fixtures/` and byte-pinned by `tests/wire.rs`, so an
//! accidental encoding change breaks the build instead of silently
//! breaking every peer.

use crate::coordinator::request::{
    OverQuotaPolicy, Priority, SubmitRequest, ValidationPolicy,
};
use crate::coordinator::tenant::TenantId;
use crate::topk::types::{Mode, TopKResult};
use crate::util::matrix::RowMatrix;
use std::time::Duration;

/// Frame magic: "RTKF" (RTop-K Frame).
pub const MAGIC: [u8; 4] = *b"RTKF";
/// The schema version this build speaks. Decoding any other version is
/// a strict, positioned rejection.
pub const VERSION: u16 = 1;
/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 24;
/// Decode guard: frames claiming a payload larger than this are
/// rejected before any allocation happens.
pub const MAX_PAYLOAD: u64 = 1 << 32;

const KIND_SUBMIT: u8 = 1;
const KIND_RESULT: u8 = 2;
const KIND_ERROR: u8 = 3;
const KIND_PING: u8 = 4;
const KIND_PONG: u8 = 5;

/// Error-frame code: the service refused or failed the request
/// (admission, validation, execution, deadline, cancellation — the
/// message says which, in the service's own words).
pub const ERR_REQUEST: u32 = 1;
/// Error-frame code: the peer violated the framing protocol (for
/// example a client sent a result frame); the connection closes after
/// this frame is flushed.
pub const ERR_PROTOCOL: u32 = 2;
/// Error-frame code: the shard holding this in-flight request died;
/// the message names the shard address.
pub const ERR_SHARD_DOWN: u32 = 3;
/// Error-frame code: the server is at its connection cap.
pub const ERR_OVERLOAD: u32 = 4;

/// A positioned decode/encode failure: `offset` is the byte at which
/// the problem was detected.
#[derive(Debug, PartialEq, Eq)]
pub struct WireError {
    pub offset: usize,
    pub msg: String,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "wire frame error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for WireError {}

fn fail<T>(offset: usize, msg: impl Into<String>) -> Result<T, WireError> {
    Err(WireError { offset, msg: msg.into() })
}

/// Byte-at-a-time lookup table for [`crc32`], built at compile time.
/// Frames carry whole matrices, so the checksum runs over megabytes on
/// the request path — the table form is ~10x the bitwise loop.
const CRC32_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 {
                (crc >> 1) ^ 0xEDB8_8320
            } else {
                crc >> 1
            };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
};

/// Standard CRC-32 (IEEE, reflected, init/xorout `0xFFFFFFFF`) — the
/// same checksum zlib's `crc32` computes, so non-Rust peers need no
/// custom code.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc: u32 = 0xFFFF_FFFF;
    for &b in bytes {
        crc = (crc >> 8) ^ CRC32_TABLE[((crc ^ u32::from(b)) & 0xFF) as usize];
    }
    !crc
}

/// A per-request failure carried on the wire in place of a result
/// frame: a stable numeric code (the `ERR_*` constants) plus the
/// server's positioned human-readable message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ErrorFrame {
    pub code: u32,
    pub msg: String,
}

/// A decoded frame.
#[derive(Debug, PartialEq)]
pub enum Frame {
    Submit(SubmitRequest),
    Result(TopKResult),
    Error(ErrorFrame),
    Ping(u64),
    Pong(u64),
}

/// Encode any frame kind. See [`encode_request`] / [`encode_result`] /
/// [`encode_error`] / [`encode_ping`] / [`encode_pong`] for the
/// kind-specific entry points.
pub fn encode(frame: &Frame) -> Result<Vec<u8>, WireError> {
    match frame {
        Frame::Submit(req) => encode_request(req),
        Frame::Result(res) => encode_result(res),
        Frame::Error(err) => encode_error(err),
        Frame::Ping(nonce) => Ok(encode_ping(*nonce)),
        Frame::Pong(nonce) => Ok(encode_pong(*nonce)),
    }
}

fn frame_with_payload(kind: u8, payload: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(kind);
    out.push(0); // reserved
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&crc32(&payload).to_le_bytes());
    let header_crc = crc32(&out[..20]);
    out.extend_from_slice(&header_crc.to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Encode a [`SubmitRequest`] as a v1 frame. Fails (never panics) on
/// shapes the format cannot carry: tenant names past `u16::MAX` bytes
/// or matrix dimensions past `u32::MAX`.
pub fn encode_request(req: &SubmitRequest) -> Result<Vec<u8>, WireError> {
    let tenant = req.tenant.as_str().as_bytes();
    if tenant.len() > u16::MAX as usize {
        return fail(0, format!("tenant name too long ({} bytes)", tenant.len()));
    }
    if req.matrix.rows > u32::MAX as usize
        || req.matrix.cols > u32::MAX as usize
        || req.k > u32::MAX as usize
    {
        return fail(
            0,
            format!(
                "matrix shape ({} x {}, k={}) exceeds the u32 wire fields",
                req.matrix.rows, req.matrix.cols, req.k
            ),
        );
    }
    // exact payload size up front: frames carry whole matrices, and
    // growing a multi-megabyte Vec by doubling would re-copy the data
    // several times before the CRC pass even starts
    let mode_bytes = match req.mode {
        None => 0,
        Some(Mode::Approx { .. }) => 2,
        Some(_) => 4,
    };
    let mut p = Vec::with_capacity(
        2 + tenant.len()
            + 4
            + 1
            + mode_bytes
            + 8
            + 3
            + 8
            + 4 * req.matrix.data.len(),
    );
    p.extend_from_slice(&(tenant.len() as u16).to_le_bytes());
    p.extend_from_slice(tenant);
    p.extend_from_slice(&(req.k as u32).to_le_bytes());
    match req.mode {
        None => p.push(0),
        Some(Mode::Exact { eps_rel }) => {
            p.push(1);
            p.extend_from_slice(&eps_rel.to_bits().to_le_bytes());
        }
        Some(Mode::EarlyStop { max_iter }) => {
            p.push(2);
            p.extend_from_slice(&max_iter.to_le_bytes());
        }
        Some(Mode::Approx { recall_milli }) => {
            // mirror the zero-deadline rule: an out-of-range target is
            // rejected at encode so encode(decode(x)) can never produce
            // a frame this build's own decoder refuses
            if recall_milli == 0 || recall_milli > 1000 {
                return fail(
                    0,
                    format!(
                        "approx recall target {recall_milli} out of range \
                         (1..=1000 thousandths)"
                    ),
                );
            }
            p.push(3);
            p.extend_from_slice(&recall_milli.to_le_bytes());
        }
    }
    // 0 on the wire means "no deadline", so a zero deadline cannot be
    // represented — reject it instead of silently aliasing it to None
    // (the service refuses zero deadlines anyway; a peer must too).
    // Likewise a deadline past the u64 nanosecond field (> ~584 years)
    // is rejected rather than silently truncated: encode(decode(x))
    // must round-trip exactly or fail loudly.
    let deadline_ns = match req.deadline {
        None => 0u64,
        Some(d) if d.is_zero() => {
            return fail(0, "a zero deadline is not representable on the wire \
                            (0 encodes \"no deadline\")")
        }
        Some(d) => match u64::try_from(d.as_nanos()) {
            Ok(ns) => ns,
            Err(_) => {
                return fail(
                    0,
                    format!(
                        "deadline {d:?} exceeds the u64 nanosecond wire field"
                    ),
                )
            }
        },
    };
    p.extend_from_slice(&deadline_ns.to_le_bytes());
    p.push(match req.priority {
        Priority::Low => 0,
        Priority::Normal => 1,
        Priority::High => 2,
    });
    p.push(match req.validation {
        ValidationPolicy::Inherit => 0,
        ValidationPolicy::Strict => 1,
        ValidationPolicy::Skip => 2,
    });
    p.push(match req.over_quota {
        None => 0,
        Some(OverQuotaPolicy::Reject) => 1,
        Some(OverQuotaPolicy::Block) => 2,
    });
    p.extend_from_slice(&(req.matrix.rows as u32).to_le_bytes());
    p.extend_from_slice(&(req.matrix.cols as u32).to_le_bytes());
    for v in &req.matrix.data {
        p.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    Ok(frame_with_payload(KIND_SUBMIT, p))
}

/// Encode a [`TopKResult`] as a v1 frame.
pub fn encode_result(res: &TopKResult) -> Result<Vec<u8>, WireError> {
    if res.rows > u32::MAX as usize || res.k > u32::MAX as usize {
        return fail(
            0,
            format!("result shape ({} rows, k={}) exceeds the u32 wire fields",
                    res.rows, res.k),
        );
    }
    let mut p =
        Vec::with_capacity(8 + 4 * res.values.len() + 4 * res.indices.len());
    p.extend_from_slice(&(res.rows as u32).to_le_bytes());
    p.extend_from_slice(&(res.k as u32).to_le_bytes());
    for v in &res.values {
        p.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    for i in &res.indices {
        p.extend_from_slice(&i.to_le_bytes());
    }
    Ok(frame_with_payload(KIND_RESULT, p))
}

/// Encode an [`ErrorFrame`] as a v1 frame. Fails (never panics) on
/// messages past the u32 length field — in practice unreachable, since
/// server error strings are short.
pub fn encode_error(err: &ErrorFrame) -> Result<Vec<u8>, WireError> {
    let msg = err.msg.as_bytes();
    if msg.len() > u32::MAX as usize {
        return fail(0, format!("error message too long ({} bytes)", msg.len()));
    }
    let mut p = Vec::with_capacity(8 + msg.len());
    p.extend_from_slice(&err.code.to_le_bytes());
    p.extend_from_slice(&(msg.len() as u32).to_le_bytes());
    p.extend_from_slice(msg);
    Ok(frame_with_payload(KIND_ERROR, p))
}

/// Encode a ping frame carrying `nonce` (echoed back in the pong).
pub fn encode_ping(nonce: u64) -> Vec<u8> {
    frame_with_payload(KIND_PING, nonce.to_le_bytes().to_vec())
}

/// Encode a pong frame echoing `nonce`.
pub fn encode_pong(nonce: u64) -> Vec<u8> {
    frame_with_payload(KIND_PONG, nonce.to_le_bytes().to_vec())
}

/// Bounds-checked little-endian reader tracking the absolute byte
/// offset for positioned errors.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Bytes left unread — the allocation bound for shape-sized reads.
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], WireError> {
        if self.buf.len() - self.pos < n {
            return fail(
                self.pos,
                format!(
                    "truncated payload: {what} needs {n} bytes, {} remain",
                    self.buf.len() - self.pos
                ),
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8, WireError> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &str) -> Result<u16, WireError> {
        let s = self.take(2, what)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self, what: &str) -> Result<u32, WireError> {
        let s = self.take(4, what)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64, WireError> {
        let s = self.take(8, what)?;
        let mut b = [0u8; 8];
        b.copy_from_slice(s);
        Ok(u64::from_le_bytes(b))
    }

    fn f32(&mut self, what: &str) -> Result<f32, WireError> {
        Ok(f32::from_bits(self.u32(what)?))
    }
}

/// Validate everything a 24-byte header can prove on its own — magic,
/// header checksum, schema version, reserved byte, payload-length cap —
/// and return the declared payload length. Shared by the one-shot
/// [`decode`] and the incremental [`FrameDecoder`], which must reject a
/// corrupt header the moment 24 bytes arrive instead of buffering
/// toward a garbage length field.
fn check_header(bytes: &[u8]) -> Result<u64, WireError> {
    if bytes[0..4] != MAGIC {
        return fail(0, format!("bad magic {:02x?} (expected {MAGIC:02x?})",
                               &bytes[0..4]));
    }
    let stored_header_crc = u32::from_le_bytes([
        bytes[20], bytes[21], bytes[22], bytes[23],
    ]);
    let actual_header_crc = crc32(&bytes[..20]);
    if stored_header_crc != actual_header_crc {
        return fail(
            20,
            format!(
                "header checksum mismatch: stored {stored_header_crc:#010x}, \
                 computed {actual_header_crc:#010x}"
            ),
        );
    }
    let version = u16::from_le_bytes([bytes[4], bytes[5]]);
    if version != VERSION {
        return fail(
            4,
            format!(
                "unsupported schema version {version} (this build speaks \
                 {VERSION}); refusing to reinterpret a foreign schema"
            ),
        );
    }
    if bytes[7] != 0 {
        return fail(7, format!("reserved byte must be 0, got {}", bytes[7]));
    }
    let payload_len = u64::from_le_bytes([
        bytes[8], bytes[9], bytes[10], bytes[11], bytes[12], bytes[13],
        bytes[14], bytes[15],
    ]);
    if payload_len > MAX_PAYLOAD {
        return fail(
            8,
            format!("payload length {payload_len} exceeds the {MAX_PAYLOAD} cap"),
        );
    }
    Ok(payload_len)
}

/// Decode one frame, strictly: the magic, both checksums, the schema
/// version, every enum tag, and the exact payload length must all
/// check out, and no trailing bytes may remain. Errors carry the byte
/// offset the problem was detected at.
pub fn decode(bytes: &[u8]) -> Result<Frame, WireError> {
    if bytes.len() < HEADER_LEN {
        return fail(
            bytes.len(),
            format!("truncated frame: {} bytes < {HEADER_LEN}-byte header",
                    bytes.len()),
        );
    }
    let payload_len = check_header(bytes)?;
    let kind = bytes[6];
    let actual_payload = bytes.len() - HEADER_LEN;
    if payload_len != actual_payload as u64 {
        return fail(
            8,
            format!(
                "payload length mismatch: header says {payload_len}, frame \
                 carries {actual_payload} (truncated or trailing bytes)"
            ),
        );
    }
    let payload = &bytes[HEADER_LEN..];
    let stored_payload_crc =
        u32::from_le_bytes([bytes[16], bytes[17], bytes[18], bytes[19]]);
    let actual_payload_crc = crc32(payload);
    if stored_payload_crc != actual_payload_crc {
        return fail(
            16,
            format!(
                "payload checksum mismatch: stored {stored_payload_crc:#010x}, \
                 computed {actual_payload_crc:#010x}"
            ),
        );
    }
    // payload errors report absolute frame offsets
    let mut r = Reader { buf: bytes, pos: HEADER_LEN };
    let frame = match kind {
        KIND_SUBMIT => Frame::Submit(decode_submit(&mut r)?),
        KIND_RESULT => Frame::Result(decode_result(&mut r)?),
        KIND_ERROR => Frame::Error(decode_error(&mut r)?),
        KIND_PING => Frame::Ping(r.u64("ping nonce")?),
        KIND_PONG => Frame::Pong(r.u64("pong nonce")?),
        other => {
            return fail(6, format!("unknown frame kind {other} (expected 1..=5)"))
        }
    };
    if r.pos != bytes.len() {
        return fail(
            r.pos,
            format!("{} trailing payload bytes after the frame body",
                    bytes.len() - r.pos),
        );
    }
    Ok(frame)
}

fn decode_submit(r: &mut Reader<'_>) -> Result<SubmitRequest, WireError> {
    let tenant_len = r.u16("tenant length")? as usize;
    let tenant_pos = r.pos;
    let tenant_bytes = r.take(tenant_len, "tenant name")?;
    let tenant = match std::str::from_utf8(tenant_bytes) {
        Ok(s) => TenantId::new(s),
        Err(e) => {
            return fail(
                tenant_pos + e.valid_up_to(),
                "tenant name is not valid UTF-8",
            )
        }
    };
    let k = r.u32("k")? as usize;
    let mode_pos = r.pos;
    let mode = match r.u8("mode tag")? {
        0 => None,
        1 => {
            let eps_pos = r.pos;
            let eps_rel = r.f32("exact eps")?;
            if !eps_rel.is_finite() {
                return fail(eps_pos, format!("non-finite exact eps {eps_rel}"));
            }
            Some(Mode::Exact { eps_rel })
        }
        2 => Some(Mode::EarlyStop { max_iter: r.u32("early-stop max_iter")? }),
        3 => {
            let rm_pos = r.pos;
            let recall_milli = r.u16("approx recall target")?;
            if recall_milli == 0 || recall_milli > 1000 {
                return fail(
                    rm_pos,
                    format!(
                        "approx recall target {recall_milli} out of range \
                         (1..=1000 thousandths)"
                    ),
                );
            }
            Some(Mode::Approx { recall_milli })
        }
        other => {
            return fail(
                mode_pos,
                format!("unknown mode tag {other} (expected 0 | 1 | 2 | 3)"),
            )
        }
    };
    let deadline_ns = r.u64("deadline")?;
    let deadline = match deadline_ns {
        0 => None,
        ns => Some(Duration::from_nanos(ns)),
    };
    let prio_pos = r.pos;
    let priority = match r.u8("priority")? {
        0 => Priority::Low,
        1 => Priority::Normal,
        2 => Priority::High,
        other => {
            return fail(prio_pos, format!("unknown priority tag {other}"))
        }
    };
    let val_pos = r.pos;
    let validation = match r.u8("validation policy")? {
        0 => ValidationPolicy::Inherit,
        1 => ValidationPolicy::Strict,
        2 => ValidationPolicy::Skip,
        other => {
            return fail(val_pos, format!("unknown validation tag {other}"))
        }
    };
    let oq_pos = r.pos;
    let over_quota = match r.u8("over-quota policy")? {
        0 => None,
        1 => Some(OverQuotaPolicy::Reject),
        2 => Some(OverQuotaPolicy::Block),
        other => {
            return fail(oq_pos, format!("unknown over-quota tag {other}"))
        }
    };
    let rows = r.u32("rows")? as usize;
    let cols = r.u32("cols")? as usize;
    let cells = match rows.checked_mul(cols) {
        Some(c) => c,
        None => return fail(r.pos, format!("rows*cols overflows ({rows} x {cols})")),
    };
    // pre-allocate at most what the payload can actually carry: a tiny
    // frame claiming a huge shape must fail on truncation, not OOM
    let mut data = Vec::with_capacity(cells.min(r.remaining() / 4));
    for _ in 0..cells {
        data.push(r.f32("matrix data")?);
    }
    Ok(SubmitRequest {
        matrix: RowMatrix::from_vec(rows, cols, data),
        k,
        mode,
        tenant,
        deadline,
        priority,
        validation,
        over_quota,
    })
}

fn decode_result(r: &mut Reader<'_>) -> Result<TopKResult, WireError> {
    let rows = r.u32("rows")? as usize;
    let k = r.u32("k")? as usize;
    let cells = match rows.checked_mul(k) {
        Some(c) => c,
        None => return fail(r.pos, format!("rows*k overflows ({rows} x {k})")),
    };
    // same allocation guard as decode_submit: capacity is bounded by
    // the bytes actually present, never by the claimed shape
    let mut values = Vec::with_capacity(cells.min(r.remaining() / 8));
    for _ in 0..cells {
        values.push(r.f32("result values")?);
    }
    let mut indices = Vec::with_capacity(cells.min(r.remaining() / 4));
    for _ in 0..cells {
        indices.push(r.u32("result indices")?);
    }
    Ok(TopKResult { rows, k, values, indices })
}

fn decode_error(r: &mut Reader<'_>) -> Result<ErrorFrame, WireError> {
    let code = r.u32("error code")?;
    let msg_len = r.u32("error message length")? as usize;
    let msg_pos = r.pos;
    let msg_bytes = r.take(msg_len, "error message")?;
    let msg = match std::str::from_utf8(msg_bytes) {
        Ok(s) => s.to_string(),
        Err(e) => {
            return fail(
                msg_pos + e.valid_up_to(),
                "error message is not valid UTF-8",
            )
        }
    };
    Ok(ErrorFrame { code, msg })
}

/// Incremental frame decoder: feed arbitrary byte chunks as they
/// arrive off a socket, pull complete [`Frame`]s out as they become
/// available. The network layer's read path never needs a whole frame
/// in one `read()`.
///
/// Headers are validated eagerly the moment 24 bytes are buffered
/// (magic, header checksum, version, reserved byte, payload cap), so a
/// corrupt or non-RTKF stream fails fast instead of waiting on a
/// garbage length field. Any returned [`WireError`] means framing is
/// lost and the stream is unrecoverable — callers must drop the
/// connection, not call [`FrameDecoder::next`] again.
#[derive(Debug, Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    /// consumed prefix of `buf`, drained lazily so each yielded frame
    /// is O(frame) instead of O(buffer)
    start: usize,
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder::default()
    }

    /// Append bytes read off the stream.
    pub fn feed(&mut self, bytes: &[u8]) {
        // compact before growing: keeps the buffer bounded by the
        // unconsumed suffix plus one read chunk
        if self.start > 0 {
            self.buf.drain(..self.start);
            self.start = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered and not yet consumed by a yielded frame — the
    /// quantity a server bounds to cap per-connection memory.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.start
    }

    /// Pull the next complete frame. `Ok(None)` means "need more
    /// bytes"; errors are terminal (see the type-level doc).
    pub fn next(&mut self) -> Result<Option<Frame>, WireError> {
        let avail = &self.buf[self.start..];
        if avail.len() < HEADER_LEN {
            return Ok(None);
        }
        let payload_len = check_header(avail)?;
        let total = HEADER_LEN + payload_len as usize;
        if avail.len() < total {
            return Ok(None);
        }
        let frame = decode(&avail[..total])?;
        self.start += total;
        Ok(Some(frame))
    }

    /// Like [`FrameDecoder::next`], but also return the frame's exact
    /// encoded bytes — what a router forwards verbatim so the payload
    /// is never re-encoded (and never re-checksummed incorrectly).
    pub fn next_with_bytes(
        &mut self,
    ) -> Result<Option<(Frame, Vec<u8>)>, WireError> {
        let avail = &self.buf[self.start..];
        if avail.len() < HEADER_LEN {
            return Ok(None);
        }
        let payload_len = check_header(avail)?;
        let total = HEADER_LEN + payload_len as usize;
        if avail.len() < total {
            return Ok(None);
        }
        let bytes = avail[..total].to_vec();
        let frame = decode(&bytes)?;
        self.start += total;
        Ok(Some((frame, bytes)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_request() -> SubmitRequest {
        SubmitRequest::new(
            RowMatrix::from_vec(2, 3, vec![1.0, -2.5, 0.0, 3.25, -0.125, 8.0]),
            2,
        )
        .mode(Mode::EarlyStop { max_iter: 4 })
        .tenant("alpha")
        .deadline(Duration::from_micros(1500))
        .priority(Priority::High)
        .validation(ValidationPolicy::Strict)
        .on_over_quota(OverQuotaPolicy::Block)
    }

    #[test]
    fn crc32_matches_the_standard_vectors() {
        // the canonical IEEE CRC-32 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn submit_roundtrip() {
        let req = sample_request();
        let bytes = encode_request(&req).unwrap();
        assert_eq!(&bytes[0..4], &MAGIC);
        match decode(&bytes).unwrap() {
            Frame::Submit(back) => assert_eq!(back, req),
            other => panic!("wrong kind: {other:?}"),
        }
    }

    #[test]
    fn result_roundtrip() {
        let res = TopKResult {
            rows: 2,
            k: 2,
            values: vec![3.25, 1.0, 8.0, 0.5],
            indices: vec![3, 0, 1, 2],
        };
        let bytes = encode_result(&res).unwrap();
        match decode(&bytes).unwrap() {
            Frame::Result(back) => assert_eq!(back, res),
            other => panic!("wrong kind: {other:?}"),
        }
    }

    #[test]
    fn unknown_version_is_rejected_with_position() {
        let mut bytes = encode_request(&sample_request()).unwrap();
        bytes[4..6].copy_from_slice(&2u16.to_le_bytes());
        // keep the header checksum valid so the version check itself is
        // what fires
        let crc = crc32(&bytes[..20]);
        bytes[20..24].copy_from_slice(&crc.to_le_bytes());
        let err = decode(&bytes).unwrap_err();
        assert_eq!(err.offset, 4);
        assert!(err.msg.contains("version 2"), "got: {}", err.msg);
    }

    #[test]
    fn empty_and_tiny_inputs_reject_cleanly() {
        assert!(decode(&[]).is_err());
        assert!(decode(&[0x52]).is_err());
        assert!(decode(&MAGIC).is_err());
    }

    #[test]
    fn approx_mode_roundtrips_and_rejects_out_of_range_targets() {
        let req = sample_request().mode(Mode::Approx { recall_milli: 950 });
        let bytes = encode_request(&req).unwrap();
        match decode(&bytes).unwrap() {
            Frame::Submit(back) => {
                assert_eq!(back.mode, Some(Mode::Approx { recall_milli: 950 }));
                assert_eq!(back, req);
            }
            other => panic!("wrong kind: {other:?}"),
        }
        // encode refuses impossible targets outright
        for bad in [0u16, 1001, u16::MAX] {
            let err = encode_request(
                &sample_request().mode(Mode::Approx { recall_milli: bad }),
            )
            .unwrap_err();
            assert!(err.msg.contains("out of range"), "got: {err}");
        }
        // decode refuses a hand-patched out-of-range target with the
        // positioned error (the u16 sits right after the mode tag byte)
        let good = sample_request().mode(Mode::Approx { recall_milli: 1000 });
        let mut bytes = encode_request(&good).unwrap();
        let rm_pos = HEADER_LEN + 2 + "alpha".len() + 4 + 1;
        bytes[rm_pos..rm_pos + 2].copy_from_slice(&1001u16.to_le_bytes());
        let crc = crc32(&bytes[HEADER_LEN..]);
        bytes[16..20].copy_from_slice(&crc.to_le_bytes());
        let hcrc = crc32(&bytes[..20]);
        bytes[20..24].copy_from_slice(&hcrc.to_le_bytes());
        let err = decode(&bytes).unwrap_err();
        assert_eq!(err.offset, rm_pos);
        assert!(err.msg.contains("out of range"), "got: {err}");
    }

    #[test]
    fn zero_deadline_is_rejected_at_encode_not_aliased_to_none() {
        // 0 ns on the wire means "no deadline"; silently encoding a
        // zero deadline as None would break the roundtrip property
        let req = sample_request().deadline(Duration::ZERO);
        let err = encode_request(&req).unwrap_err();
        assert!(err.msg.contains("zero deadline"), "got: {err}");
    }

    #[test]
    fn huge_claimed_shapes_fail_on_truncation_without_allocating() {
        // a tiny frame claiming rows=2^31 x cols=2 must die on the
        // first missing byte, not pre-allocate gigabytes. An empty
        // matrix puts rows/cols in the last 8 payload bytes.
        let small = SubmitRequest::new(RowMatrix::zeros(0, 0), 1);
        let mut bytes = encode_request(&small).unwrap();
        // patch rows to 2^31 and cols to 2 (last 8 payload bytes),
        // re-stamp both CRCs so only the truncation check can fire
        let n = bytes.len();
        bytes[n - 8..n - 4].copy_from_slice(&(1u32 << 31).to_le_bytes());
        bytes[n - 4..].copy_from_slice(&2u32.to_le_bytes());
        let crc = crc32(&bytes[HEADER_LEN..]);
        bytes[16..20].copy_from_slice(&crc.to_le_bytes());
        let hcrc = crc32(&bytes[..20]);
        bytes[20..24].copy_from_slice(&hcrc.to_le_bytes());
        let err = decode(&bytes).unwrap_err();
        assert!(err.msg.contains("truncated"), "got: {err}");
    }

    #[test]
    fn control_frames_roundtrip() {
        let err = ErrorFrame {
            code: ERR_SHARD_DOWN,
            msg: "shard 127.0.0.1:9000 failed".to_string(),
        };
        match decode(&encode_error(&err).unwrap()).unwrap() {
            Frame::Error(back) => assert_eq!(back, err),
            other => panic!("wrong kind: {other:?}"),
        }
        match decode(&encode_ping(0xDEAD_BEEF_0BAD_CAFE)).unwrap() {
            Frame::Ping(n) => assert_eq!(n, 0xDEAD_BEEF_0BAD_CAFE),
            other => panic!("wrong kind: {other:?}"),
        }
        match decode(&encode_pong(7)).unwrap() {
            Frame::Pong(n) => assert_eq!(n, 7),
            other => panic!("wrong kind: {other:?}"),
        }
        // empty messages are fine; the code still travels
        let bare = ErrorFrame { code: ERR_OVERLOAD, msg: String::new() };
        match decode(&encode_error(&bare).unwrap()).unwrap() {
            Frame::Error(back) => assert_eq!(back, bare),
            other => panic!("wrong kind: {other:?}"),
        }
    }

    #[test]
    fn unknown_kind_names_the_full_range() {
        let mut bytes = encode_ping(1);
        bytes[6] = 9;
        let hcrc = crc32(&bytes[..20]);
        bytes[20..24].copy_from_slice(&hcrc.to_le_bytes());
        let err = decode(&bytes).unwrap_err();
        assert_eq!(err.offset, 6);
        assert!(err.msg.contains("1..=5"), "got: {}", err.msg);
    }

    /// Deterministic xorshift so the split-point property test never
    /// depends on ambient randomness.
    struct XorShift(u64);
    impl XorShift {
        fn next(&mut self) -> u64 {
            let mut x = self.0;
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            self.0 = x;
            x
        }
    }

    fn sample_frames() -> Vec<Vec<u8>> {
        vec![
            encode_request(&sample_request()).unwrap(),
            encode_result(&TopKResult {
                rows: 2,
                k: 2,
                values: vec![3.25, 1.0, 8.0, 0.5],
                indices: vec![3, 0, 1, 2],
            })
            .unwrap(),
            encode_request(
                &sample_request().mode(Mode::Approx { recall_milli: 950 }),
            )
            .unwrap(),
            encode_error(&ErrorFrame {
                code: ERR_REQUEST,
                msg: "deadline exceeded".to_string(),
            })
            .unwrap(),
            encode_ping(42),
            encode_pong(42),
        ]
    }

    #[test]
    fn frame_decoder_yields_one_shot_frames_across_random_splits() {
        // property: for any way of chunking a stream of valid frames,
        // the incremental decoder yields exactly the frames the
        // one-shot decoder sees, in order
        let frames = sample_frames();
        let expected: Vec<Frame> =
            frames.iter().map(|b| decode(b).unwrap()).collect();
        let stream: Vec<u8> = frames.concat();
        let mut rng = XorShift(0x2A65_11B8_D00D_F00D);
        for trial in 0..64 {
            let mut dec = FrameDecoder::new();
            let mut got = Vec::new();
            let mut pos = 0;
            while pos < stream.len() {
                // chunk sizes 1..=max, mixing tiny and large reads
                let max = if trial % 2 == 0 { 7 } else { 4096 };
                let n = (rng.next() as usize % max + 1)
                    .min(stream.len() - pos);
                dec.feed(&stream[pos..pos + n]);
                pos += n;
                while let Some(f) = dec.next().unwrap() {
                    got.push(f);
                }
            }
            assert_eq!(got, expected, "trial {trial} diverged");
            assert_eq!(dec.buffered(), 0, "trial {trial} left bytes behind");
        }
    }

    #[test]
    fn frame_decoder_single_byte_feed_matches_one_shot() {
        let frames = sample_frames();
        let expected: Vec<Frame> =
            frames.iter().map(|b| decode(b).unwrap()).collect();
        let mut dec = FrameDecoder::new();
        let mut got = Vec::new();
        for b in frames.concat() {
            dec.feed(&[b]);
            while let Some(f) = dec.next().unwrap() {
                got.push(f);
            }
        }
        assert_eq!(got, expected);
    }

    #[test]
    fn frame_decoder_rejects_corrupt_headers_before_buffering_payload() {
        // a bad magic fails as soon as 24 bytes are in, even though the
        // (garbage) length field claims a huge payload
        let mut junk = encode_ping(1);
        junk[0] = b'X';
        let mut dec = FrameDecoder::new();
        dec.feed(&junk[..HEADER_LEN]);
        let err = dec.next().unwrap_err();
        assert_eq!(err.offset, 0);
        assert!(err.msg.contains("bad magic"), "got: {}", err.msg);

        // a bit flip anywhere in the header trips the header CRC with
        // only the header buffered
        let mut flipped = encode_ping(2);
        flipped[9] ^= 0x40;
        let mut dec = FrameDecoder::new();
        dec.feed(&flipped[..HEADER_LEN]);
        let err = dec.next().unwrap_err();
        assert!(
            err.msg.contains("checksum mismatch"),
            "got: {}",
            err.msg
        );
    }

    #[test]
    fn frame_decoder_reports_need_more_until_the_frame_completes() {
        let frame = encode_request(&sample_request()).unwrap();
        let mut dec = FrameDecoder::new();
        // header alone: valid, but the payload is still outstanding
        dec.feed(&frame[..HEADER_LEN]);
        assert!(dec.next().unwrap().is_none());
        // all but the last byte: still pending
        dec.feed(&frame[HEADER_LEN..frame.len() - 1]);
        assert!(dec.next().unwrap().is_none());
        dec.feed(&frame[frame.len() - 1..]);
        match dec.next().unwrap() {
            Some(Frame::Submit(back)) => assert_eq!(back, sample_request()),
            other => panic!("wrong frame: {other:?}"),
        }
        assert!(dec.next().unwrap().is_none());
    }
}
