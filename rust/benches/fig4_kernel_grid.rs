//! Figure 4: kernel execution time over the (N, M, k) grid for RTop-K
//! with max_iter in 2..8 and no early stopping, vs the RadixSelect
//! baseline. Two views:
//!
//!  1. measured wall time of the CPU engine (this testbed's ground
//!     truth), and
//!  2. the A6000 warp-simulator estimate (`simt`), which reproduces the
//!     paper's GPU-scale numbers from the algorithms' instruction
//!     streams.
//!
//! RTOPK_FULL=1 extends N to 2^20 (needs ~3 GB for M=768).

use rtopk::bench::{time_algo, workload, Table};
use rtopk::simt::{kernel_time_ms, simulate_radix_row, simulate_rtopk_row, CostModel};
use rtopk::stats::expected_iterations;
use rtopk::topk::rowwise::RowAlgo;
use rtopk::topk::types::Mode;

fn main() {
    let quick = std::env::var("RTOPK_QUICK").is_ok();
    let full = std::env::var("RTOPK_FULL").is_ok();
    let ns: Vec<usize> = if full {
        vec![1 << 14, 1 << 16, 1 << 18, 1 << 20]
    } else if quick {
        vec![1 << 12, 1 << 14]
    } else {
        vec![1 << 14]
    };
    let ms = [256usize, 512, 768];
    let ks = [16usize, 32, 64, 96, 128];
    let iters = [2u32, 4, 8];

    // ---- view 1: measured wall time ----
    for &n in &ns {
        for &m in &ms {
            let mut t = Table::new(
                &format!("Fig 4 (measured, CPU engine): N=2^{} M={m} — time ms",
                         n.trailing_zeros()),
                &["k", "radix", "es2", "es4", "es8", "no-ES", "speedup(no-ES)"],
            );
            for &k in &ks {
                let x = workload(n, m, 0xF16 + (n + m + k) as u64);
                let base = time_algo(&x, k, RowAlgo::Radix).median_ms();
                let mut cells = vec![k.to_string(), format!("{base:.2}")];
                let mut noes = 0.0;
                for &it in &iters {
                    let v = time_algo(&x, k, RowAlgo::RTopK(Mode::EarlyStop { max_iter: it }))
                        .median_ms();
                    cells.push(format!("{v:.2}"));
                }
                let v = time_algo(&x, k, RowAlgo::RTopK(Mode::EXACT)).median_ms();
                noes = v;
                cells.push(format!("{v:.2}"));
                cells.push(format!("{:.2}x", base / noes));
                t.row(cells);
            }
            t.print();
        }
    }

    // ---- view 2: A6000 simulator estimate ----
    let c = CostModel::A6000;
    for &m in &ms {
        let mut t = Table::new(
            &format!("Fig 4 (A6000 simulator): M={m}, N=2^20 — estimated kernel ms"),
            &["k", "torch.topk", "es2", "es4", "es8", "no-ES", "speedup(no-ES)"],
        );
        let n = 1 << 20;
        for &k in &ks {
            let radix = simulate_radix_row(m, k, &c);
            let tr = kernel_time_ms(n, &radix, CostModel::A6000_SMS, CostModel::A6000_CLOCK_GHZ);
            let mut cells = vec![k.to_string(), format!("{tr:.3}")];
            let mut t_noes = 0.0;
            for &it in &[2u32, 4, 8] {
                let est = simulate_rtopk_row(m, k, it as f64, &c);
                cells.push(format!("{:.3}", kernel_time_ms(n, &est, CostModel::A6000_SMS, CostModel::A6000_CLOCK_GHZ)));
            }
            let e_iters = expected_iterations(m, k.min(m - 1));
            let est = simulate_rtopk_row(m, k, e_iters, &c);
            t_noes = kernel_time_ms(n, &est, CostModel::A6000_SMS, CostModel::A6000_CLOCK_GHZ);
            cells.push(format!("{t_noes:.3}"));
            cells.push(format!("{:.2}x", tr / t_noes));
            t.row(cells);
        }
        t.print();
    }
    println!("\npaper (Fig 4): avg no-ES speedup 8.88x at M=256, 7.27x at M=512, 5.72x at M=768");
}
