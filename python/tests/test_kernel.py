"""L1 correctness: Pallas RTop-K kernel vs the pure-jnp oracle.

Three rings of defense:

  1. the reference itself is validated against ``jax.lax.top_k``
     (independent implementation) — exact mode must return the exact
     top-k multiset;
  2. the Pallas kernel must match the reference *bit-for-bit* (same f32
     bracket arithmetic, same selection ranking) in both modes;
  3. hypothesis sweeps shapes/dtypes/k/max_iter/block_rows and
     distributions, checking the structural invariants that must hold
     for any input (exactly k selected, indices valid and strictly
     increasing, values gathered from x, mask consistent).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, rtopk, rtopk_mask, maxk

jax.config.update("jax_platform_name", "cpu")


def normal_rows(seed: int, n: int, m: int, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, m)).astype(dtype)


def check_invariants(x, k, vals, idx, mask):
    """Structural invariants independent of search mode."""
    n, m = x.shape
    vals = np.asarray(vals)
    idx = np.asarray(idx)
    mask = np.asarray(mask)
    # mask has exactly k nonzeros per row
    np.testing.assert_array_equal((mask != 0).sum(axis=1), k)
    # indices valid and unique per row (selection never duplicates).
    # NOTE: indices are *not* globally sorted — the two-pass selection
    # emits threshold survivors first (by index), then borderline
    # supplements (by index), exactly like the paper's selecting stage.
    assert idx.min() >= 0 and idx.max() < m
    for r in range(n):
        assert len(np.unique(idx[r])) == k
    # values are gathered from x at idx
    gathered = np.asarray(x)[np.arange(n)[:, None], idx]
    np.testing.assert_array_equal(vals, gathered.astype(vals.dtype))
    # mask marks exactly the selected indices
    sel_from_idx = np.zeros((n, m), bool)
    sel_from_idx[np.arange(n)[:, None], idx] = True
    np.testing.assert_array_equal(mask != 0, sel_from_idx)


# ---------------------------------------------------------------------------
# Ring 1: reference vs lax.top_k
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,m,k", [(7, 32, 4), (32, 256, 16), (5, 64, 64),
                                   (16, 100, 1), (3, 8, 7)])
def test_ref_exact_matches_lax_topk(n, m, k):
    x = normal_rows(42 + n, n, m)
    vals, idx, mask = ref.rtopk_exact(jnp.asarray(x), k)
    opt_vals, _ = ref.lax_topk(jnp.asarray(x), k)
    # same multiset of values (our order is by index, lax's by value)
    np.testing.assert_allclose(
        np.sort(np.asarray(vals), axis=1),
        np.sort(np.asarray(opt_vals), axis=1),
        rtol=0, atol=0,
    )
    check_invariants(x, k, vals, idx, mask)


def test_ref_exact_with_ties():
    # many duplicates around the borderline — the paper's corner case
    x = np.array(
        [[1.0] * 8 + [2.0] * 8, [3.0] * 16, [0.0] * 15 + [1.0]],
        np.float32,
    )
    for k in (1, 4, 8, 12, 16):
        vals, idx, mask = ref.rtopk_exact(jnp.asarray(x), k)
        opt_vals, _ = ref.lax_topk(jnp.asarray(x), k)
        np.testing.assert_array_equal(
            np.sort(np.asarray(vals), axis=1),
            np.sort(np.asarray(opt_vals), axis=1),
        )
        check_invariants(x, k, vals, idx, mask)


def test_ref_early_stop_invariants_and_hit():
    x = normal_rows(7, 64, 128)
    for it in (2, 3, 5, 8):
        vals, idx, mask = ref.rtopk_early_stop(jnp.asarray(x), 16, it)
        check_invariants(x, 16, vals, idx, mask)
        e1, e2, hit = ref.earlystop_metrics(jnp.asarray(x), 16, it)
        assert float(jnp.mean(hit)) > 0.2


def test_ref_early_stop_hit_rate_improves_with_iters():
    x = normal_rows(11, 256, 256)
    hits = []
    for it in (2, 4, 6, 8):
        _, _, hit = ref.earlystop_metrics(jnp.asarray(x), 32, it)
        hits.append(float(jnp.mean(hit)))
    assert hits == sorted(hits), f"hit rate not monotone: {hits}"
    assert hits[-1] > 0.85  # paper Table 2: 90.19% at max_iter=8, k=32


# ---------------------------------------------------------------------------
# Ring 2: kernel vs reference, bit-for-bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,m,k", [(16, 64, 8), (33, 256, 32), (8, 128, 128),
                                   (100, 96, 1), (5, 512, 96)])
def test_kernel_exact_matches_ref(n, m, k):
    x = normal_rows(1000 + n, n, m)
    rv, ri, rm = ref.rtopk_exact(jnp.asarray(x), k)
    kv, ki, km = rtopk(jnp.asarray(x), k, mode="exact")
    np.testing.assert_array_equal(np.asarray(kv), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(km) != 0, np.asarray(rm))


@pytest.mark.parametrize("max_iter", [1, 2, 4, 8, 13])
def test_kernel_early_stop_matches_ref(max_iter):
    x = normal_rows(max_iter, 24, 192)
    k = 24
    rv, ri, rm = ref.rtopk_early_stop(jnp.asarray(x), k, max_iter)
    kv, ki, km = rtopk(jnp.asarray(x), k, mode="early_stop",
                       max_iter=max_iter)
    np.testing.assert_array_equal(np.asarray(kv), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(km) != 0, np.asarray(rm))


@pytest.mark.parametrize("block_rows", [1, 3, 8, 64])
def test_kernel_tiling_invariance(block_rows):
    """Grid decomposition must not change results (rows are independent)."""
    x = normal_rows(99, 50, 64)
    base = rtopk(jnp.asarray(x), 8, mode="exact", block_rows=50)
    tiled = rtopk(jnp.asarray(x), 8, mode="exact", block_rows=block_rows)
    for a, b in zip(base, tiled):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_kernel_eps_precision_modes():
    """Larger eps_rel exits earlier but still returns exactly k."""
    x = normal_rows(5, 32, 256)
    for eps in (0.0, 1e-16, 1e-8, 1e-4, 1e-2):
        vals, idx, mask = rtopk(jnp.asarray(x), 32, mode="exact",
                                eps_rel=eps)
        check_invariants(x, 32, vals, idx, mask)


def test_mask_kernel_matches_full_kernel():
    x = normal_rows(21, 40, 160)
    for mode, kw in (("exact", {}), ("early_stop", {"max_iter": 3})):
        m1 = rtopk_mask(jnp.asarray(x), 20, mode=mode, **kw)
        _, _, m2 = rtopk(jnp.asarray(x), 20, mode=mode, **kw)
        np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


def test_kernel_bf16_input():
    x = normal_rows(3, 8, 64).astype(jnp.bfloat16)
    vals, idx, mask = rtopk(x, 8, mode="exact")
    assert vals.dtype == jnp.bfloat16
    check_invariants(np.asarray(x, np.float32), 8,
                     np.asarray(vals, np.float32), idx, mask)


def test_kernel_k_equals_m():
    x = normal_rows(4, 6, 32)
    vals, idx, mask = rtopk(jnp.asarray(x), 32, mode="exact")
    np.testing.assert_array_equal(np.asarray(idx),
                                  np.tile(np.arange(32), (6, 1)))
    np.testing.assert_array_equal(np.asarray(vals), x)


def test_kernel_rejects_bad_k():
    x = jnp.zeros((2, 8), jnp.float32)
    with pytest.raises(ValueError):
        rtopk(x, 0)
    with pytest.raises(ValueError):
        rtopk(x, 9)


# ---------------------------------------------------------------------------
# Ring 3: hypothesis sweeps
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 40),
    m=st.sampled_from([8, 32, 100, 256]),
    kfrac=st.floats(0.01, 1.0),
    seed=st.integers(0, 2**31 - 1),
    dist=st.sampled_from(["normal", "uniform", "lognormal", "negated",
                          "quantized"]),
)
def test_prop_exact_equals_lax_topk(n, m, kfrac, seed, dist):
    k = max(1, min(m, int(round(kfrac * m))))
    rng = np.random.default_rng(seed)
    if dist == "normal":
        x = rng.standard_normal((n, m))
    elif dist == "uniform":
        x = rng.random((n, m)) * 10 - 5
    elif dist == "lognormal":
        x = rng.lognormal(size=(n, m))
    elif dist == "negated":
        x = -np.abs(rng.standard_normal((n, m)))
    else:  # heavy ties
        x = np.round(rng.standard_normal((n, m)) * 2) / 2
    x = x.astype(np.float32)
    vals, idx, mask = rtopk(jnp.asarray(x), k, mode="exact")
    check_invariants(x, k, vals, idx, mask)
    opt_vals, _ = ref.lax_topk(jnp.asarray(x), k)
    np.testing.assert_array_equal(
        np.sort(np.asarray(vals), axis=1),
        np.sort(np.asarray(opt_vals), axis=1),
    )


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 24),
    m=st.sampled_from([16, 64, 256]),
    kfrac=st.floats(0.05, 1.0),
    max_iter=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_prop_early_stop_invariants(n, m, kfrac, max_iter, seed):
    k = max(1, min(m, int(round(kfrac * m))))
    x = np.random.default_rng(seed).standard_normal((n, m)).astype(np.float32)
    vals, idx, mask = rtopk(jnp.asarray(x), k, mode="early_stop",
                            max_iter=max_iter)
    check_invariants(x, k, vals, idx, mask)
    # kernel == reference, decision-for-decision
    rv, ri, _ = ref.rtopk_early_stop(jnp.asarray(x), k, max_iter)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ri))


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 16),
    m=st.sampled_from([32, 128]),
    k=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_prop_maxk_gradient_support(n, m, k, seed):
    """grad(maxk) is supported exactly on the selection mask."""
    x = np.random.default_rng(seed).standard_normal((n, m)).astype(np.float32)

    def loss(xx):
        return jnp.sum(maxk(xx, k, mode="exact") ** 2)

    g = np.asarray(jax.grad(loss)(jnp.asarray(x)))
    _, _, mask = rtopk(jnp.asarray(x), k, mode="exact")
    mask = np.asarray(mask) != 0
    # grad is 2*x on selected entries, 0 elsewhere
    np.testing.assert_allclose(g[mask], 2 * x[mask], rtol=1e-6)
    assert (g[~mask] == 0).all()


def test_spmm_ref_padded_edges_are_noops():
    rng = np.random.default_rng(3)
    n, e, f = 10, 24, 5
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    w = rng.random(e).astype(np.float32)
    w[-6:] = 0.0  # padded tail
    x = rng.standard_normal((n, f)).astype(np.float32)
    full = ref.spmm_ref(jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w),
                        jnp.asarray(x), n)
    trimmed = ref.spmm_ref(jnp.asarray(src[:-6]), jnp.asarray(dst[:-6]),
                           jnp.asarray(w[:-6]), jnp.asarray(x), n)
    np.testing.assert_allclose(np.asarray(full), np.asarray(trimmed),
                               rtol=1e-6)
