//! The in-crate CPU engine as an [`ExecBackend`]: every shape is
//! supported, and the planner-calibrated `(algorithm, grain)` from the
//! [`ExecSpec`] decides how each matrix runs.

use crate::backend::{ExecBackend, ExecSpec, CPU_BACKEND_ID};
use crate::topk::rowwise::{rowwise_topk_grained, RowAlgo};
use crate::topk::types::{Mode, TopKResult};
use crate::util::matrix::RowMatrix;
use anyhow::Result;

/// The always-available fallback backend wrapping
/// [`rowwise_topk_grained`] and the algorithm zoo.
#[derive(Clone, Copy, Debug, Default)]
pub struct CpuBackend;

impl ExecBackend for CpuBackend {
    fn id(&self) -> &str {
        CPU_BACKEND_ID
    }

    fn describe(&self) -> String {
        format!(
            "in-crate CPU engine ({} algorithms + the paper's kernel)",
            RowAlgo::all_baselines().len()
        )
    }

    fn supports(&self, _cols: usize, _k: usize, _mode: Mode) -> bool {
        true
    }

    fn execute(
        &self,
        spec: &ExecSpec,
        mats: &[&RowMatrix],
        k: usize,
        _mode: Mode,
    ) -> Result<Vec<TopKResult>> {
        Ok(mats
            .iter()
            .map(|x| rowwise_topk_grained(x, k, spec.algo, spec.grain))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topk::verify::is_exact;
    use crate::util::rng::Rng;

    #[test]
    fn executes_groups_with_the_spec_algorithm() {
        let b = CpuBackend;
        assert_eq!(b.id(), "cpu");
        assert!(b.supports(123, 45, Mode::EXACT));
        let mut rng = Rng::seed_from(77);
        let x = RowMatrix::random_normal(20, 64, &mut rng);
        let y = RowMatrix::random_normal(11, 64, &mut rng);
        let spec = ExecSpec { algo: RowAlgo::Heap, grain: 4 };
        let out = b.execute(&spec, &[&x, &y], 8, Mode::EXACT).unwrap();
        assert_eq!(out.len(), 2);
        assert!(is_exact(&x, &out[0]));
        assert!(is_exact(&y, &out[1]));
        let oracle = rowwise_topk_grained(&x, 8, RowAlgo::Heap, 4);
        assert_eq!(out[0].values, oracle.values);
        assert_eq!(out[0].indices, oracle.indices);
    }
}
