//! Summary statistics for experiment outputs: mean/std/percentiles and
//! a streaming histogram used by the coordinator's latency metrics.

/// Simple summary over a finished sample set.
#[derive(Clone, Debug)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty slice");
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        Summary {
            count: xs.len(),
            mean,
            std: var.sqrt(),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }
}

/// Percentile by linear interpolation on a *sorted* slice; p in [0, 100].
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let f = rank - lo as f64;
        sorted[lo] * (1.0 - f) + sorted[hi] * f
    }
}

/// Cumulative distribution of small integer observations (exit-iteration
/// histograms for Tables 1 and 5).
#[derive(Clone, Debug, Default)]
pub struct IntHistogram {
    counts: Vec<u64>,
    total: u64,
}

impl IntHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, value: usize) {
        if value >= self.counts.len() {
            self.counts.resize(value + 1, 0);
        }
        self.counts[value] += 1;
        self.total += 1;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Cumulative fraction of observations <= value.
    pub fn cdf_at(&self, value: usize) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let c: u64 = self
            .counts
            .iter()
            .take((value + 1).min(self.counts.len()))
            .sum();
        c as f64 / self.total as f64
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let s: f64 = self
            .counts
            .iter()
            .enumerate()
            .map(|(v, &c)| v as f64 * c as f64)
            .sum();
        s / self.total as f64
    }

    pub fn max_value(&self) -> usize {
        self.counts.len().saturating_sub(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_cdf_and_mean() {
        let mut h = IntHistogram::new();
        for v in [1usize, 2, 2, 3, 3, 3] {
            h.record(v);
        }
        assert_eq!(h.total(), 6);
        assert!((h.cdf_at(1) - 1.0 / 6.0).abs() < 1e-12);
        assert!((h.cdf_at(2) - 3.0 / 6.0).abs() < 1e-12);
        assert!((h.cdf_at(3) - 1.0).abs() < 1e-12);
        assert!((h.cdf_at(99) - 1.0).abs() < 1e-12);
        assert!((h.mean() - 14.0 / 6.0).abs() < 1e-12);
    }
}
