//! Oracle comparison utilities: exact-set checks, the paper's
//! approximation metrics (Table 2's E1 / E2 / Hit), and the recall
//! harness behind the `Mode::Approx` contracts — a single recall
//! oracle ([`recall_of`] / [`recall_of_row`]) shared by Table-2
//! metrics, planner qualification, calibration, and the recall test
//! suites, plus seeded workload distributions ([`Dist`]) and a
//! documented statistical acceptance gate ([`recall_gate`]).

use crate::topk::types::TopKResult;
use crate::util::matrix::RowMatrix;
use crate::util::rng::Rng;

/// Per-row approximation metrics of a (possibly approximate) selection
/// against the exact top-k of the same row.
#[derive(Clone, Copy, Debug, Default)]
pub struct ApproxMetrics {
    /// |max(sel) - max(opt)| / |max(opt)|   (paper's E1)
    pub e1: f64,
    /// |min(sel) - min(opt)| / |min(opt)|   (paper's E2)
    pub e2: f64,
    /// |sel ∩ opt| / k                      (paper's Hit)
    pub hit: f64,
}

/// Exact top-k values of one row, sorted descending (the oracle).
pub fn exact_topk_desc(row: &[f32], k: usize) -> Vec<(f32, u32)> {
    let mut pairs: Vec<(f32, u32)> =
        row.iter().enumerate().map(|(j, &v)| (v, j as u32)).collect();
    pairs.sort_by(|a, b| {
        b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1))
    });
    pairs.truncate(k);
    pairs
}

/// True iff the selection's value multiset equals the exact top-k
/// multiset for every row.
pub fn is_exact(x: &RowMatrix, res: &TopKResult) -> bool {
    for r in 0..x.rows {
        let mut got: Vec<f32> = res.row_values(r).to_vec();
        got.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let want: Vec<f32> =
            exact_topk_desc(x.row(r), res.k).iter().map(|p| p.0).collect();
        if got != want {
            return false;
        }
    }
    true
}

/// Recall of one row's selected *values* against the exact top-k value
/// multiset: |multiset(sel) ∩ multiset(opt)| / k. Value-based on
/// purpose — under ties an approximate selector may pick an equal-value
/// element at a different index, which loses nothing, so index-set
/// overlap would under-count; on tie-free data the two definitions
/// coincide. This is the single recall oracle every consumer
/// (Table-2 Hit, `topk::approx` calibration, planner qualification,
/// `tests/recall.rs`) measures through.
pub fn recall_of_row(row: &[f32], values: &[f32]) -> f64 {
    let k = values.len();
    let want: Vec<f32> = exact_topk_desc(row, k).iter().map(|p| p.0).collect();
    let mut got: Vec<f32> = values.to_vec();
    got.sort_by(|a, b| b.partial_cmp(a).unwrap());
    // multiset intersection of two descending-sorted lists
    let (mut i, mut j, mut hits) = (0usize, 0usize, 0usize);
    while i < k && j < k {
        if got[i] == want[j] {
            hits += 1;
            i += 1;
            j += 1;
        } else if got[i] > want[j] {
            i += 1;
        } else {
            j += 1;
        }
    }
    hits as f64 / k as f64
}

/// Row-averaged [`recall_of_row`] over a batched result.
pub fn recall_of(x: &RowMatrix, res: &TopKResult) -> f64 {
    let mut total = 0.0;
    for r in 0..x.rows {
        total += recall_of_row(x.row(r), res.row_values(r));
    }
    total / (x.rows as f64).max(1.0)
}

/// Lower acceptance bound for a measured mean recall against a
/// `target` contract over `rows` independent rows:
/// `target - 3 * sqrt(target * (1 - target) / rows)`.
///
/// Per-row recall lies in [0, 1], so by the Bhatia–Davis inequality a
/// row with mean recall `t` has variance at most `t(1-t)` — *whatever*
/// the correlation between slots inside the row (a bucket overflow in
/// two-stage selection drops several winners at once, so slot-level
/// independence would be a lie). The sample mean over `rows` i.i.d.
/// rows then has sigma at most `sqrt(t(1-t)/rows)`, and 3 sigma keeps
/// the false-failure rate of a true-at-the-bound mode under ~0.2%.
/// Every suite using this gate is also seed-fixed: the gate documents
/// the slack's provenance, it does not absorb nondeterminism.
pub fn recall_gate(target: f64, rows: usize) -> f64 {
    (target - 3.0 * (target * (1.0 - target) / rows.max(1) as f64).sqrt()).max(0.0)
}

/// Seeded workload distributions for the recall harness. Each is a
/// deterministic function of (rows, cols, seed); `Ties` quantizes
/// heavily so duplicate values straddle every selection boundary (the
/// adversarial case for threshold selectors).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Dist {
    Uniform,
    Gaussian,
    HeavyTail,
    Ties,
}

impl Dist {
    pub const ALL: [Dist; 4] =
        [Dist::Uniform, Dist::Gaussian, Dist::HeavyTail, Dist::Ties];

    pub fn name(&self) -> &'static str {
        match self {
            Dist::Uniform => "uniform",
            Dist::Gaussian => "gaussian",
            Dist::HeavyTail => "heavy_tail",
            Dist::Ties => "ties",
        }
    }

    /// A seeded (rows, cols) matrix from this distribution. The seed is
    /// salted per distribution so the same caller seed does not reuse
    /// one underlying stream across distributions.
    pub fn matrix(&self, rows: usize, cols: usize, seed: u64) -> RowMatrix {
        let salt = match self {
            Dist::Uniform => 0x5EED_0001u64,
            Dist::Gaussian => 0x5EED_0002,
            Dist::HeavyTail => 0x5EED_0003,
            Dist::Ties => 0x5EED_0004,
        };
        let mut rng = Rng::seed_from(seed ^ salt);
        match self {
            Dist::Uniform => {
                RowMatrix::from_fn(rows, cols, |_, _| rng.uniform_range(-5.0, 5.0))
            }
            Dist::Gaussian => RowMatrix::random_normal(rows, cols, &mut rng),
            Dist::HeavyTail => RowMatrix::from_fn(rows, cols, |_, _| {
                // signed lognormal: a few enormous magnitudes per row
                let v = rng.normal().exp() as f32;
                if rng.chance(0.5) {
                    v
                } else {
                    -v
                }
            }),
            Dist::Ties => RowMatrix::from_fn(rows, cols, |_, _| {
                // coarse quantization: ~13 distinct levels across +-1.5
                // sigma, so duplicates straddle every top-k boundary
                (rng.normal_f32() * 4.0).round() / 4.0
            }),
        }
    }
}

/// Table-2 metrics for one row's selection. `hit` is measured through
/// the shared recall oracle ([`recall_of_row`]); `indices` stay in the
/// signature for gather-checking callers but the hit rate itself is
/// value-based (identical on tie-free data, fairer under ties).
pub fn approx_metrics_row(row: &[f32], values: &[f32], _indices: &[u32])
    -> ApproxMetrics {
    let k = values.len();
    let opt = exact_topk_desc(row, k);
    let opt_max = opt[0].0 as f64;
    let opt_min = opt[k - 1].0 as f64;
    let sel_max = values.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let sel_min = values.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
    let e1 = ((sel_max - opt_max).abs()) / opt_max.abs().max(f64::MIN_POSITIVE);
    let e2 = ((sel_min - opt_min).abs()) / opt_min.abs().max(f64::MIN_POSITIVE);
    ApproxMetrics { e1, e2, hit: recall_of_row(row, values) }
}

/// Average Table-2 metrics over all rows of a batched result.
pub fn approx_metrics(x: &RowMatrix, res: &TopKResult) -> ApproxMetrics {
    let mut acc = ApproxMetrics::default();
    for r in 0..x.rows {
        let m = approx_metrics_row(x.row(r), res.row_values(r), res.row_indices(r));
        acc.e1 += m.e1;
        acc.e2 += m.e2;
        acc.hit += m.hit;
    }
    let n = x.rows as f64;
    ApproxMetrics { e1: acc.e1 / n, e2: acc.e2 / n, hit: acc.hit / n }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topk::{rowwise_topk, Mode};
    use crate::util::rng::Rng;

    #[test]
    fn exact_mode_is_exact() {
        let mut rng = Rng::seed_from(8);
        let x = RowMatrix::random_normal(64, 128, &mut rng);
        let res = rowwise_topk(&x, 16, Mode::EXACT);
        assert!(is_exact(&x, &res));
        let m = approx_metrics(&x, &res);
        assert!(m.e1 < 1e-12 && m.e2 < 1e-12);
        assert!((m.hit - 1.0).abs() < 1e-12);
    }

    #[test]
    fn early_stop_metrics_in_paper_ballpark() {
        // Table 2, k=32, M=256: paper reports hit = 83.19% at max_iter=5
        // and 90.19% at 8; our implementation measures ~87.8% and ~98.3%
        // (same shape, tighter tail — after i iterations the residual
        // bracket holds ~M*D*phi/2^i ≈ 1.4 borderline candidates at i=8,
        // bounding misses well below the paper's 10%; see EXPERIMENTS.md
        // §Table2 for the discrepancy note). The run is derandomized
        // (fixed seed 9), and the interval bounds carry slack beyond the
        // measured point values: at n = 2000 rows x k = 32 slots the
        // binomial 3-sigma band on a hit rate is ~+-0.6%, but the mean
        // itself shifts by a few percent across RNG streams, so the
        // bounds bracket the *regime* (hit@2 poor, hit@5 good, hit@8
        // near-exact) rather than a specific stream's decimal. The
        // strict orderings below are the paper's structural claims and
        // stay exact.
        let mut rng = Rng::seed_from(9);
        let x = RowMatrix::random_normal(2000, 256, &mut rng);
        // hit rates measured through the shared recall oracle — the
        // same code path Mode::Approx calibration and the planner's
        // qualification gate use
        let res2 = rowwise_topk(&x, 32, Mode::EarlyStop { max_iter: 2 });
        let res5 = rowwise_topk(&x, 32, Mode::EarlyStop { max_iter: 5 });
        let res8 = rowwise_topk(&x, 32, Mode::EarlyStop { max_iter: 8 });
        let h2 = recall_of(&x, &res2);
        let h5 = recall_of(&x, &res5);
        let h8 = recall_of(&x, &res8);
        assert!(h2 < 0.7, "hit@2 = {h2}");
        assert!((0.75..0.97).contains(&h5), "hit@5 = {h5}");
        assert!((0.90..=1.0).contains(&h8), "hit@8 = {h8}");
        assert!(h2 < h5 && h5 < h8);
        let m5 = approx_metrics(&x, &res5);
        let m8 = approx_metrics(&x, &res8);
        assert!(
            (m5.hit - h5).abs() < 1e-12,
            "Table-2 Hit and the recall oracle must be one code path"
        );
        assert!(m5.e1 < 0.05 && m8.e1 < m5.e1 + 1e-9);
    }

    #[test]
    fn hit_rate_counts_overlap() {
        let row = [4.0f32, 3.0, 2.0, 1.0];
        // pretend selection picked indices 0 and 2 for k=2 (true top-2 is 0,1)
        let m = approx_metrics_row(&row, &[4.0, 2.0], &[0, 2]);
        assert!((m.hit - 0.5).abs() < 1e-12);
        assert!(m.e1 < 1e-12); // max matches
        assert!((m.e2 - (3.0 - 2.0) / 3.0).abs() < 1e-9);
    }

    #[test]
    fn recall_oracle_is_value_based_and_tie_robust() {
        // exact hit
        let row = [4.0f32, 3.0, 2.0, 1.0];
        assert!((recall_of_row(&row, &[3.0, 4.0]) - 1.0).abs() < 1e-12);
        assert!((recall_of_row(&row, &[4.0, 2.0]) - 0.5).abs() < 1e-12);
        // ties: picking a different index of an equal value loses
        // nothing (index-set overlap would miscount this as 0.5)
        let tied = [2.0f32, 2.0, 1.0, 0.0];
        assert!((recall_of_row(&tied, &[tied[1], tied[0]]) - 1.0).abs() < 1e-12);
        // duplicates are counted with multiplicity: a selection that
        // repeats one tied value cannot claim both slots
        let dup = [3.0f32, 3.0, 1.0, 0.0];
        assert!((recall_of_row(&dup, &[3.0, 1.0]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn recall_gate_bounds_are_sane() {
        // exact targets have a zero-width band
        assert!((recall_gate(1.0, 100) - 1.0).abs() < 1e-12);
        // 0.95 over 2000 rows: 3*sqrt(.95*.05/2000) ~ 0.0146
        let g = recall_gate(0.95, 2000);
        assert!((0.93..0.95).contains(&g), "gate = {g}");
        // more rows tighten the gate monotonically
        assert!(recall_gate(0.95, 200) < g);
        assert_eq!(recall_gate(0.5, 0), recall_gate(0.5, 1));
    }

    #[test]
    fn distributions_are_seeded_and_cover_their_shapes() {
        for d in Dist::ALL {
            let a = d.matrix(7, 33, 42);
            let b = d.matrix(7, 33, 42);
            assert_eq!(a, b, "{} must be deterministic per seed", d.name());
            assert_ne!(
                a,
                d.matrix(7, 33, 43),
                "{} must vary with the seed",
                d.name()
            );
            assert_eq!(a.rows, 7);
            assert_eq!(a.cols, 33);
            assert!(a.data.iter().all(|v| v.is_finite()), "{}", d.name());
        }
        // the adversarial distribution actually produces duplicates
        let t = Dist::Ties.matrix(4, 64, 7);
        let mut vals = t.row(0).to_vec();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        vals.dedup();
        assert!(vals.len() < 40, "ties distribution produced no duplicates");
        // distinct distributions differ under one seed
        assert_ne!(Dist::Uniform.matrix(4, 16, 9), Dist::Gaussian.matrix(4, 16, 9));
    }
}

#[cfg(test)]
mod curve_probe {
    use super::*;
    use crate::topk::{rowwise_topk, Mode};
    use crate::util::rng::Rng;

    #[test]
    #[ignore] // probe: run with --ignored to print the Table-2 curve
    fn print_hit_curve() {
        let mut rng = Rng::seed_from(10);
        let x = RowMatrix::random_normal(5000, 256, &mut rng);
        for k in [16usize, 32, 64, 128] {
            for it in [2u32, 3, 4, 5, 6, 7, 8] {
                let m = approx_metrics(&x, &rowwise_topk(&x, k, Mode::EarlyStop { max_iter: it }));
                println!("k={k:3} it={it} E1={:.2}% E2={:.2}% hit={:.2}%", m.e1*100.0, m.e2*100.0, m.hit*100.0);
            }
        }
    }
}
