//! Scratch-arena behavior: correctness when one thread's grow-only
//! arena serves interleaved (cols, k) shapes back to back, and the
//! zero-allocation steady state the persistent pool exists to provide.
//!
//! This is deliberately the only test in its binary: the allocation
//! counter (`baselines::scratch_allocs`) is process-global, and a
//! sibling test running topk work concurrently would fault its own
//! arenas mid-window.

use rtopk::topk::baselines::scratch_allocs;
use rtopk::topk::rowwise::{rowwise_topk_grained, RowAlgo};
use rtopk::topk::types::Mode;
use rtopk::util::matrix::RowMatrix;
use rtopk::util::rng::Rng;

#[test]
fn interleaved_shapes_stay_correct_and_steady_state_allocates_zero() {
    let mut rng = Rng::seed_from(0xA7E4A);
    // Interleave shapes so each thread's arena alternates between
    // larger and smaller (M, k) demands — the reuse pattern where a
    // stale capacity or un-cleared buffer would corrupt a selection.
    let shapes: [(usize, usize, usize); 5] = [
        (40, 96, 8),
        (24, 256, 32),
        (64, 33, 5),
        (16, 512, 64),
        (48, 96, 12),
    ];
    let algos = [
        RowAlgo::Heap,
        RowAlgo::Radix,
        RowAlgo::Bucket,
        RowAlgo::RTopK(Mode::EXACT),
    ];
    for round in 0..3 {
        for (i, &(rows, cols, k)) in shapes.iter().enumerate() {
            let x = RowMatrix::random_normal(rows, cols, &mut rng);
            let algo = algos[(round + i) % algos.len()];
            let res = rowwise_topk_grained(&x, k, algo, 2);
            for r in 0..rows {
                let mut got = res.row_values(r).to_vec();
                got.sort_by(|a, b| b.partial_cmp(a).unwrap());
                let mut want = x.row(r).to_vec();
                want.sort_by(|a, b| b.partial_cmp(a).unwrap());
                want.truncate(k);
                assert_eq!(
                    got, want,
                    "{} round {round} shape ({rows},{cols},{k}) row {r}",
                    algo.name()
                );
                for (v, &ix) in res.row_values(r).iter().zip(res.row_indices(r)) {
                    assert_eq!(*v, x.get(r, ix as usize), "{}", algo.name());
                }
            }
        }
    }

    // Steady state: once every participating thread's arena has grown
    // to the recurring shape, a window of repeated batches performs
    // zero allocation events. Dynamic scheduling can leave a slow
    // worker's arena cold for a while, so earlier windows double as
    // warmup; convergence within the attempt budget is required.
    let x = RowMatrix::random_normal(64, 512, &mut rng);
    let mut last = u64::MAX;
    for _ in 0..10 {
        let before = scratch_allocs();
        for _ in 0..20 {
            rowwise_topk_grained(&x, 64, RowAlgo::Radix, 4).recycle();
        }
        last = scratch_allocs() - before;
        if last == 0 {
            break;
        }
    }
    assert_eq!(last, 0, "steady-state batches must not allocate scratch");
}
