//! Multi-tenant identity, quotas, and admission control.
//!
//! The serving north star is many clients sharing one device-backed
//! top-k service; without per-tenant isolation a single heavy client
//! can fill the batcher and starve everyone else's latency budget. This
//! module supplies the two service-side halves of isolation:
//!
//! * **Identity + policy** — a [`TenantId`] threaded through every
//!   request, and a [`TenantDirectory`] holding each tenant's
//!   [`TenantSpec`] (weight, quotas, optional per-tenant execution
//!   overrides) built from the `[tenants.<name>]` config tables.
//! * **Admission control** — [`TenantDirectory::admit`] reserves a
//!   request against the tenant's `max_queue_depth` /
//!   `max_in_flight_rows` quotas *before* the batcher sees it, and
//!   rejects over-quota submissions with a positioned error (tenant,
//!   observed load, limit) instead of letting them queue. Cooperative
//!   tenants can opt into *blocking* admission instead
//!   ([`TenantDirectory::admit_blocking`], selected per request via
//!   `OverQuotaPolicy::Block`): the submitting thread parks in a
//!   per-tenant FIFO — bounded by
//!   [`TenantDirectory::with_max_blocked_waiters`] — until quota
//!   frees, the request's deadline expires, or the service shuts down
//!   ([`TenantDirectory::close`]). Accepted work is released by the
//!   scheduler when the reply is sent ([`TenantDirectory::release`]),
//!   which also wakes blocked waiters; "in flight" spans
//!   submit-to-reply, not just queue residency.
//!
//! The third half — *weighted-fair draining* of admitted work — lives
//! in the batcher's weighted-deficit-round-robin flush policy
//! (`crate::coordinator::batcher`), which consumes the per-tenant
//! weights this directory exposes.
//!
//! Unknown tenants are legal: a request naming a tenant with no
//! `[tenants.<name>]` table is served under the default spec (weight 1,
//! no quotas). Configuration *constrains* tenants; it does not
//! register them. Ad-hoc registration is bounded, though: tenant names
//! are client-chosen, and an attacker minting a fresh name per request
//! would otherwise grow the directory without limit — past
//! [`MAX_AD_HOC_TENANTS`] never-configured tenants, admissions for
//! *new* names are rejected (configured tenants and already-seen names
//! are unaffected). The metrics table has the matching bound
//! (`crate::coordinator::metrics`), folding overflow tenants into one
//! shared entry.

use crate::config::TenantsConfig;
use crate::plan::{is_exact_semantics, parse_force, ForceAlgo};
use crate::topk::rowwise::RowAlgo;
use crate::topk::types::Mode;
// Admission-control protocol state goes through the sync façade so the
// model checker can explore it (`RwLock` is passthrough — its guards
// are never held across a blocking operation here; see util/sync.rs).
use crate::util::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use crate::util::sync::{Arc, Condvar, Mutex, RwLock};
use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

/// The tenant every request without an explicit tenant runs under.
pub const DEFAULT_TENANT: &str = "default";

/// A tenant identity: a cheap-to-clone, hashable name. Two ids are
/// equal iff their names are equal, so a `TenantId` can key the
/// batcher's group map and the metrics table directly.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TenantId(Arc<str>);

impl TenantId {
    pub fn new(name: &str) -> TenantId {
        TenantId(Arc::from(name))
    }

    pub fn as_str(&self) -> &str {
        &self.0
    }
}

impl Default for TenantId {
    fn default() -> Self {
        TenantId::new(DEFAULT_TENANT)
    }
}

impl std::fmt::Display for TenantId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

/// One tenant's validated serving policy (the typed form of a
/// `[tenants.<name>]` config table).
#[derive(Clone, Debug)]
pub struct TenantSpec {
    pub id: TenantId,
    /// weighted-deficit-round-robin drain weight (>= 1); a weight-4
    /// tenant's budget-full batches drain 4x as often as a weight-1
    /// tenant's when both have backlog
    pub weight: u64,
    /// max rows admitted and not yet replied to (0 = unlimited)
    pub max_in_flight_rows: usize,
    /// max requests admitted and not yet replied to (0 = unlimited)
    pub max_queue_depth: usize,
    /// per-tenant algorithm pin, honored only where it cannot change
    /// result semantics (same contract as the global `force_algo`)
    pub force_algo: Option<ForceAlgo>,
    /// mode used when the tenant submits without an explicit mode
    pub default_mode: Option<Mode>,
}

impl TenantSpec {
    /// The spec an unconfigured tenant serves under: weight 1, no
    /// quotas, no overrides.
    pub fn ad_hoc(id: TenantId) -> TenantSpec {
        TenantSpec {
            id,
            weight: 1,
            max_in_flight_rows: 0,
            max_queue_depth: 0,
            force_algo: None,
            default_mode: None,
        }
    }

    /// The algorithm this tenant's pin forces for a request mode, if
    /// any. Mirrors the planner's global-pin rule: a fixed baseline is
    /// honored only for exact-semantics requests; approximate requests
    /// keep the paper's kernel at their own mode (substituting an exact
    /// baseline would change the output contract, not just the speed).
    pub fn pinned_algo(&self, mode: Mode) -> Option<RowAlgo> {
        self.force_algo.map(|force| match force {
            ForceAlgo::RTopK => RowAlgo::RTopK(mode),
            ForceAlgo::Fixed(a) if is_exact_semantics(mode) => a,
            ForceAlgo::Fixed(_) => RowAlgo::RTopK(mode),
        })
    }
}

/// Live per-tenant admission counters next to the tenant's spec.
#[derive(Debug)]
struct TenantState {
    spec: TenantSpec,
    /// rows admitted and not yet replied to
    in_flight_rows: AtomicUsize,
    /// requests admitted and not yet replied to
    in_flight_requests: AtomicUsize,
    /// FIFO of blocked cooperative submitters (ticket numbers, front =
    /// next to admit)
    blocked: Mutex<VecDeque<u64>>,
    /// signaled on release / shutdown so blocked submitters recheck
    freed: Condvar,
    /// ticket counter behind the blocked FIFO
    next_ticket: AtomicU64,
    /// Model-check observer: tickets of parked waiters in the order
    /// they were admitted. The FIFO model suite asserts this is
    /// ascending in every explored schedule (plain std mutex — an
    /// observer, invisible to the scheduler).
    #[cfg(rtopk_model_check)]
    admitted_order: std::sync::Mutex<Vec<u64>>,
}

impl TenantState {
    fn new(spec: TenantSpec) -> TenantState {
        TenantState {
            spec,
            in_flight_rows: AtomicUsize::new(0),
            in_flight_requests: AtomicUsize::new(0),
            blocked: Mutex::new(VecDeque::new()),
            freed: Condvar::new(),
            next_ticket: AtomicU64::new(0),
            #[cfg(rtopk_model_check)]
            admitted_order: std::sync::Mutex::new(Vec::new()),
        }
    }
}

/// How a blocking admission ([`TenantDirectory::admit_blocking`])
/// failed — the service maps each kind to the right metric (a timeout
/// is not a rejection).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdmitBlockError {
    /// the request's deadline expired while waiting for quota
    Timeout(String),
    /// the directory shut down while waiting
    Closed(String),
    /// the per-tenant blocked FIFO is full (bounded cooperation: past
    /// the cap, blocking degrades to rejection)
    WaitersFull(String),
    /// rejected before any waiting was possible (e.g. the ad-hoc
    /// tenant registry is at capacity) — same taxonomy as a
    /// non-blocking rejection
    Rejected(String),
}

impl AdmitBlockError {
    /// The positioned message, whatever the kind.
    pub fn message(&self) -> &str {
        match self {
            AdmitBlockError::Timeout(m)
            | AdmitBlockError::Closed(m)
            | AdmitBlockError::WaitersFull(m)
            | AdmitBlockError::Rejected(m) => m,
        }
    }
}

/// Cap on tenants registered ad hoc (i.e. never configured). Tenant
/// names are client-chosen, so without a bound a caller minting a
/// fresh name per request would grow the directory forever.
pub const MAX_AD_HOC_TENANTS: usize = 1024;

/// Default cap on blocked cooperative submitters per tenant (the
/// `[serve] max_blocked_waiters` knob overrides it). Each blocked
/// waiter is a parked client thread; the bound keeps a stalled tenant
/// from accumulating unbounded parked threads. One value with
/// `ServeConfig`'s default, by construction.
pub const MAX_BLOCKED_WAITERS: usize = crate::config::MAX_BLOCKED_WAITERS;

/// The service's tenant table: specs from config plus ad-hoc tenants
/// registered on first use (bounded by [`MAX_AD_HOC_TENANTS`]), with
/// live admission counters.
#[derive(Debug)]
pub struct TenantDirectory {
    tenants: RwLock<HashMap<TenantId, Arc<TenantState>>>,
    /// total entries allowed: configured tenants + the ad-hoc budget
    capacity: usize,
    /// per-tenant cap on blocked cooperative submitters
    max_blocked_waiters: usize,
    /// set by [`TenantDirectory::close`]; blocked waiters drain with a
    /// shutdown error and new blocking admissions refuse immediately
    closed: AtomicBool,
}

impl Default for TenantDirectory {
    fn default() -> Self {
        TenantDirectory::new()
    }
}

impl TenantDirectory {
    /// An empty directory: every tenant is ad hoc (weight 1, no
    /// quotas).
    pub fn new() -> TenantDirectory {
        TenantDirectory {
            tenants: RwLock::new(HashMap::new()),
            capacity: MAX_AD_HOC_TENANTS,
            max_blocked_waiters: MAX_BLOCKED_WAITERS,
            closed: AtomicBool::new(false),
        }
    }

    /// Override the per-tenant blocked-waiter cap (the `[serve]
    /// max_blocked_waiters` knob; 0 disables blocking admission
    /// entirely — every `Block` submission degrades to rejection).
    pub fn with_max_blocked_waiters(mut self, cap: usize) -> TenantDirectory {
        self.max_blocked_waiters = cap;
        self
    }

    /// Build from the `[tenants]` config tables, validating each
    /// tenant's `force_algo` / `mode` strings (a typo is a startup
    /// error, not a silently-ignored knob).
    pub fn from_config(cfg: &TenantsConfig) -> Result<TenantDirectory, String> {
        if !cfg.unknown_keys.is_empty() {
            return Err(format!(
                "unknown [tenants] config keys {:?} — a misspelled quota \
                 would silently stay unenforced (known fields: {}; tenant \
                 names must not contain dots)",
                cfg.unknown_keys,
                crate::config::TENANT_KEYS.join(", ")
            ));
        }
        let mut dir = TenantDirectory::new();
        dir.capacity = cfg.tenants.len() + MAX_AD_HOC_TENANTS;
        {
            let mut map = dir.tenants.write().unwrap();
            for t in &cfg.tenants {
                let force_algo = match t.force_algo.as_deref() {
                    None | Some("") => None,
                    Some(s) => Some(parse_force(s).map_err(|e| {
                        format!("[tenants.{}] force_algo: {e}", t.name)
                    })?),
                };
                let default_mode = match t.mode.as_deref() {
                    None | Some("") => None,
                    Some(s) => Some(crate::bench::parse_mode(s).map_err(|e| {
                        format!("[tenants.{}] mode: {e}", t.name)
                    })?),
                };
                let id = TenantId::new(&t.name);
                let spec = TenantSpec {
                    id: id.clone(),
                    weight: t.weight.max(1),
                    max_in_flight_rows: t.max_in_flight_rows,
                    max_queue_depth: t.max_queue_depth,
                    force_algo,
                    default_mode,
                };
                map.insert(id, Arc::new(TenantState::new(spec)));
            }
        }
        Ok(dir)
    }

    /// The tenant's live state, registering an ad-hoc spec on first
    /// sight (read-lock fast path; the write lock is only taken once
    /// per new tenant). Errors once the directory is at capacity and
    /// the name is new — client-chosen names must not grow state
    /// without bound.
    fn state(&self, id: &TenantId) -> Result<Arc<TenantState>, String> {
        if let Some(s) = self.tenants.read().unwrap().get(id) {
            return Ok(s.clone());
        }
        let mut map = self.tenants.write().unwrap();
        if map.len() >= self.capacity && !map.contains_key(id) {
            return Err(format!(
                "tenant directory full ({} entries): refusing to register \
                 new ad-hoc tenant {:?} — configure [tenants.{}] for \
                 legitimate tenants or reuse existing names",
                map.len(),
                id.as_str(),
                id.as_str()
            ));
        }
        Ok(map
            .entry(id.clone())
            .or_insert_with(|| {
                Arc::new(TenantState::new(TenantSpec::ad_hoc(id.clone())))
            })
            .clone())
    }

    /// The quota reserve-check-undo on one tenant's atomic counters.
    fn try_reserve(st: &TenantState, id: &TenantId, rows: usize) -> Result<(), String> {
        let spec = &st.spec;
        let depth = st.in_flight_requests.fetch_add(1, Ordering::AcqRel) + 1;
        if spec.max_queue_depth > 0 && depth > spec.max_queue_depth {
            st.in_flight_requests.fetch_sub(1, Ordering::AcqRel);
            return Err(format!(
                "tenant {:?} over quota: {depth} requests in flight > \
                 max_queue_depth {} (shed load or raise \
                 [tenants.{}] max_queue_depth)",
                id.as_str(),
                spec.max_queue_depth,
                id.as_str()
            ));
        }
        let in_rows = st.in_flight_rows.fetch_add(rows, Ordering::AcqRel) + rows;
        if spec.max_in_flight_rows > 0 && in_rows > spec.max_in_flight_rows {
            st.in_flight_rows.fetch_sub(rows, Ordering::AcqRel);
            st.in_flight_requests.fetch_sub(1, Ordering::AcqRel);
            return Err(format!(
                "tenant {:?} over quota: {in_rows} rows in flight \
                 (this request: {rows}) > max_in_flight_rows {} \
                 (shed load or raise [tenants.{}] max_in_flight_rows)",
                id.as_str(),
                spec.max_in_flight_rows,
                id.as_str()
            ));
        }
        Ok(())
    }

    /// Reserve one request of `rows` rows against the tenant's quotas.
    /// On success the tenant's in-flight counters include the request
    /// until [`TenantDirectory::release`] is called; on rejection the
    /// counters are untouched and the error names the tenant, the
    /// observed load, and the violated limit. The reserve-check-undo
    /// sequence can transiently overcount a concurrent submitter by one
    /// request — quotas are admission backstops, not exact semaphores.
    pub fn admit(&self, id: &TenantId, rows: usize) -> Result<(), String> {
        let st = self.state(id)?;
        Self::try_reserve(&st, id, rows)
    }

    /// Blocking admission for cooperative tenants
    /// (`OverQuotaPolicy::Block`): instead of rejecting an over-quota
    /// submission, park the submitting thread in the tenant's FIFO of
    /// blocked waiters until quota frees. *Blocking* waiters admit
    /// strictly in arrival order (a Block newcomer never overtakes a
    /// parked Block waiter, even when quota is momentarily free);
    /// non-blocking `Reject`-policy admissions stay lock-free and may
    /// race a parked waiter for freed quota — quotas are backstops,
    /// not exact semaphores, and the FIFO guarantee is among
    /// cooperators. Gives up — with the matching [`AdmitBlockError`]
    /// kind — when `expire_at` passes, the directory
    /// [closes](TenantDirectory::close), the tenant's blocked FIFO is
    /// already at the waiter cap, or the request could never fit the
    /// quota at any load (waiting would hang forever).
    pub fn admit_blocking(
        &self,
        id: &TenantId,
        rows: usize,
        expire_at: Option<Instant>,
    ) -> Result<(), AdmitBlockError> {
        let st = match self.state(id) {
            Ok(st) => st,
            // registry capacity, not a full waiter FIFO — keep the
            // error kinds truthful
            Err(e) => return Err(AdmitBlockError::Rejected(e)),
        };
        if self.closed.load(Ordering::Acquire) {
            return Err(AdmitBlockError::Closed(format!(
                "tenant {:?}: service is shutting down",
                id.as_str()
            )));
        }
        // an alone-over-quota request can never be admitted however
        // long it waits — parking it would hang the submitter forever
        // AND head-of-line block every later cooperator for the tenant
        let cap = st.spec.max_in_flight_rows;
        if cap > 0 && rows > cap {
            return Err(AdmitBlockError::Rejected(format!(
                "tenant {:?}: request of {rows} rows can never fit \
                 max_in_flight_rows {cap}; refusing to wait for quota that \
                 cannot free",
                id.as_str()
            )));
        }
        let mut q = st.blocked.lock().unwrap();
        // FIFO: only jump the queue when nobody is parked
        if q.is_empty() && Self::try_reserve(&st, id, rows).is_ok() {
            return Ok(());
        }
        if q.len() >= self.max_blocked_waiters {
            return Err(AdmitBlockError::WaitersFull(format!(
                "tenant {:?} over quota with {} submitters already blocked \
                 (max_blocked_waiters {}): rejecting instead of parking \
                 another thread",
                id.as_str(),
                q.len(),
                self.max_blocked_waiters
            )));
        }
        let my = st.next_ticket.fetch_add(1, Ordering::AcqRel);
        q.push_back(my);
        loop {
            if self.closed.load(Ordering::Acquire) {
                q.retain(|&t| t != my);
                st.freed.notify_all();
                return Err(AdmitBlockError::Closed(format!(
                    "tenant {:?}: service shut down while blocked on quota",
                    id.as_str()
                )));
            }
            if let Some(at) = expire_at {
                if Instant::now() >= at {
                    q.retain(|&t| t != my);
                    st.freed.notify_all();
                    return Err(AdmitBlockError::Timeout(format!(
                        "tenant {:?}: request deadline expired while blocked \
                         on admission quota (quota never freed in time)",
                        id.as_str()
                    )));
                }
            }
            // strict arrival order: only the queue's front may take
            // freed quota
            #[cfg(not(rtopk_model_check_mutants))]
            let at_head = q.front() == Some(&my);
            // Seeded waiter-order mutant: LIFO — the newest waiter
            // steals freed quota from the oldest. The FIFO model suite
            // asserts admission follows ticket order and catches this.
            #[cfg(rtopk_model_check_mutants)]
            let at_head = q.back() == Some(&my);
            if at_head && Self::try_reserve(&st, id, rows).is_ok() {
                #[cfg(not(rtopk_model_check_mutants))]
                q.pop_front();
                #[cfg(rtopk_model_check_mutants)]
                q.pop_back();
                #[cfg(rtopk_model_check)]
                st.admitted_order.lock().unwrap().push(my);
                // the next waiter may also fit (e.g. a large release)
                st.freed.notify_all();
                return Ok(());
            }
            // bounded wait: re-check periodically so a release whose
            // notification raced the park (release notifies without
            // holding this lock) can never strand a waiter
            let poll = Duration::from_millis(50);
            let wait = match expire_at {
                Some(at) => at
                    .saturating_duration_since(Instant::now())
                    .min(poll),
                None => poll,
            };
            q = st.freed.wait_timeout(q, wait).unwrap().0;
        }
    }

    /// Live blocked-waiter count for a tenant (0 for tenants never
    /// seen). Reporting / test hook for blocking admission.
    pub fn blocked_waiters(&self, id: &TenantId) -> usize {
        match self.tenants.read().unwrap().get(id) {
            Some(st) => st.blocked.lock().unwrap().len(),
            None => 0,
        }
    }

    /// Shut the directory down: blocked cooperative submitters drain
    /// with a shutdown error and new blocking admissions refuse
    /// immediately. Idempotent; non-blocking admission (`admit`) is
    /// unaffected — the service boundary stops those itself.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
        for st in self.tenants.read().unwrap().values() {
            // acquire the waiter lock so the store above cannot race
            // into the window between a waiter's check and its park
            drop(st.blocked.lock().unwrap());
            st.freed.notify_all();
        }
    }

    /// Return an admitted request's reservation (called by the
    /// scheduler when the reply is delivered, and by the service when a
    /// submission fails after admission). Wakes blocked cooperative
    /// submitters — the freed quota may admit the front of the FIFO.
    pub fn release(&self, id: &TenantId, rows: usize) {
        if let Some(st) = self.tenants.read().unwrap().get(id) {
            st.in_flight_rows.fetch_sub(rows, Ordering::AcqRel);
            st.in_flight_requests.fetch_sub(1, Ordering::AcqRel);
            if !st.blocked.lock().unwrap().is_empty() {
                st.freed.notify_all();
            }
        }
    }

    /// Live (in-flight rows, in-flight requests) for a tenant; (0, 0)
    /// for tenants never seen.
    pub fn in_flight(&self, id: &TenantId) -> (usize, usize) {
        match self.tenants.read().unwrap().get(id) {
            Some(st) => (
                st.in_flight_rows.load(Ordering::Acquire),
                st.in_flight_requests.load(Ordering::Acquire),
            ),
            None => (0, 0),
        }
    }

    /// Live `(tenant, in-flight rows, in-flight requests)` for every
    /// tenant the directory has seen — the telemetry hub's per-tenant
    /// load gauges. Configured-but-never-seen tenants report zeros;
    /// counters are read individually, so a row can be transiently
    /// inconsistent with a concurrent admit/release (gauges, not
    /// ledger).
    pub fn all_in_flight(&self) -> Vec<(TenantId, u64, u64)> {
        self.tenants
            .read()
            .unwrap()
            .iter()
            .map(|(id, st)| {
                (
                    id.clone(),
                    st.in_flight_rows.load(Ordering::Acquire) as u64,
                    st.in_flight_requests.load(Ordering::Acquire) as u64,
                )
            })
            .collect()
    }

    /// The tenant's WDRR weight (1 for unconfigured tenants).
    pub fn weight(&self, id: &TenantId) -> u64 {
        self.tenants
            .read()
            .unwrap()
            .get(id)
            .map(|st| st.spec.weight)
            .unwrap_or(1)
    }

    /// Every configured `(tenant, weight)` pair — the batcher's WDRR
    /// table (ad-hoc tenants default to weight 1 inside the batcher).
    pub fn batch_weights(&self) -> Vec<(TenantId, u64)> {
        self.tenants
            .read()
            .unwrap()
            .iter()
            .map(|(id, st)| (id.clone(), st.spec.weight))
            .collect()
    }

    /// The algorithm the tenant's `force_algo` pin forces for a request
    /// mode, if the tenant has a pin and the mode's semantics allow it.
    pub fn pinned_algo(&self, id: &TenantId, mode: Mode) -> Option<RowAlgo> {
        self.tenants
            .read()
            .unwrap()
            .get(id)
            .and_then(|st| st.spec.pinned_algo(mode))
    }

    /// The tenant's default mode, if one is configured.
    pub fn default_mode(&self, id: &TenantId) -> Option<Mode> {
        self.tenants
            .read()
            .unwrap()
            .get(id)
            .and_then(|st| st.spec.default_mode)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, TenantsConfig};

    fn dir_from(toml: &str) -> Result<TenantDirectory, String> {
        let c = Config::parse(toml).unwrap();
        TenantDirectory::from_config(&TenantsConfig::from_config(&c))
    }

    #[test]
    fn tenant_ids_compare_by_name() {
        let a = TenantId::new("alpha");
        let b = TenantId::new("alpha");
        let c = TenantId::new("beta");
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.to_string(), "alpha");
        assert_eq!(TenantId::default().as_str(), DEFAULT_TENANT);
    }

    #[test]
    fn unconfigured_tenants_are_unlimited_weight_one() {
        let d = TenantDirectory::new();
        let id = TenantId::new("walk-in");
        for _ in 0..1000 {
            d.admit(&id, 10_000).unwrap();
        }
        assert_eq!(d.weight(&id), 1);
        assert_eq!(d.in_flight(&id), (10_000_000, 1000));
        for _ in 0..1000 {
            d.release(&id, 10_000);
        }
        assert_eq!(d.in_flight(&id), (0, 0));
    }

    #[test]
    fn all_in_flight_reports_every_seen_tenant() {
        let d = dir_from("[tenants.vip]\nweight = 2").unwrap();
        let vip = TenantId::new("vip");
        let anon = TenantId::new("walk-in");
        d.admit(&vip, 10).unwrap();
        d.admit(&anon, 3).unwrap();
        d.admit(&anon, 4).unwrap();
        let mut all = d.all_in_flight();
        all.sort();
        assert_eq!(
            all,
            vec![(vip.clone(), 10, 1), (anon.clone(), 7, 2)],
            "rows and request depth per tenant"
        );
        d.release(&anon, 3);
        d.release(&anon, 4);
        let mut all = d.all_in_flight();
        all.sort();
        assert_eq!(
            all,
            vec![(vip, 10, 1), (anon, 0, 0)],
            "released tenants stay listed at zero"
        );
    }

    #[test]
    fn row_quota_rejects_with_a_positioned_error() {
        let d = dir_from(
            "[tenants.alpha]\nweight = 4\nmax_in_flight_rows = 100",
        )
        .unwrap();
        let alpha = TenantId::new("alpha");
        d.admit(&alpha, 60).unwrap();
        d.admit(&alpha, 40).unwrap();
        let err = d.admit(&alpha, 1).unwrap_err();
        assert!(err.contains("alpha"), "names the tenant: {err}");
        assert!(err.contains("101"), "names the observed load: {err}");
        assert!(err.contains("100"), "names the limit: {err}");
        // a rejection must not leak a reservation
        assert_eq!(d.in_flight(&alpha), (100, 2));
        d.release(&alpha, 60);
        d.admit(&alpha, 60).unwrap();
    }

    #[test]
    fn queue_depth_quota_counts_requests_not_rows() {
        let d = dir_from("[tenants.b]\nmax_queue_depth = 2").unwrap();
        let b = TenantId::new("b");
        d.admit(&b, 1).unwrap();
        d.admit(&b, 1).unwrap();
        let err = d.admit(&b, 1).unwrap_err();
        assert!(err.contains("max_queue_depth"), "got: {err}");
        assert_eq!(d.in_flight(&b), (2, 2));
        // other tenants are not affected by b's quota
        d.admit(&TenantId::new("c"), 1).unwrap();
    }

    #[test]
    fn config_overrides_parse_and_validate() {
        let d = dir_from(
            "[tenants.pinned]\nforce_algo = \"heap\"\nweight = 3\n\
             [tenants.approx]\nmode = \"es4\"",
        )
        .unwrap();
        let pinned = TenantId::new("pinned");
        assert_eq!(d.weight(&pinned), 3);
        assert_eq!(
            d.pinned_algo(&pinned, Mode::EXACT),
            Some(RowAlgo::Heap)
        );
        // approximate requests keep the paper's kernel despite the pin
        let es = Mode::EarlyStop { max_iter: 4 };
        assert_eq!(d.pinned_algo(&pinned, es), Some(RowAlgo::RTopK(es)));
        let approx = TenantId::new("approx");
        assert_eq!(d.default_mode(&approx), Some(es));
        assert_eq!(d.default_mode(&pinned), None);
        // bad knob values are startup errors
        assert!(dir_from("[tenants.x]\nforce_algo = \"warp9\"").is_err());
        assert!(dir_from("[tenants.x]\nmode = \"sometimes\"").is_err());
    }

    #[test]
    fn ad_hoc_tenant_registration_is_bounded() {
        // tenant names are client-chosen: minting a fresh name per
        // request must hit a wall instead of growing the directory
        // forever
        let d = dir_from("[tenants.vip]\nweight = 2").unwrap();
        for i in 0..MAX_AD_HOC_TENANTS {
            d.admit(&TenantId::new(&format!("anon-{i}")), 1).unwrap();
        }
        let err = d.admit(&TenantId::new("one-too-many"), 1).unwrap_err();
        assert!(err.contains("full"), "got: {err}");
        assert!(err.contains("one-too-many"), "names the tenant: {err}");
        // known names — ad hoc or configured — keep working
        d.admit(&TenantId::new("anon-0"), 1).unwrap();
        d.admit(&TenantId::new("vip"), 1).unwrap();
    }

    #[test]
    fn dotted_tenant_names_fail_startup() {
        // [tenants.team.alpha] would silently register "team.alpha"
        // while the operator meant to quota "alpha"
        let err =
            dir_from("[tenants.team.alpha]\nmax_in_flight_rows = 64")
                .unwrap_err();
        assert!(err.contains("team.alpha"), "names the key: {err}");
    }

    #[test]
    fn misspelled_tenant_config_keys_fail_startup() {
        let err = dir_from("[tenants.abuser]\nmax_inflight_rows = 4096")
            .unwrap_err();
        assert!(err.contains("max_inflight_rows"), "names the typo: {err}");
        assert!(err.contains("max_in_flight_rows"), "names the fix: {err}");
    }

    #[test]
    fn block_admission_admits_in_fifo_order() {
        // Two waiters park behind a full quota; releases must admit
        // them strictly in arrival order, and a newcomer must not
        // overtake a parked waiter.
        let d = Arc::new(dir_from("[tenants.coop]\nmax_queue_depth = 1").unwrap());
        let coop = TenantId::new("coop");
        d.admit(&coop, 4).unwrap(); // fills the quota
        let spawn_waiter = |tag: u64| {
            let d = d.clone();
            let coop = coop.clone();
            std::thread::spawn(move || {
                d.admit_blocking(&coop, 1, None).unwrap();
                tag
            })
        };
        let w1 = spawn_waiter(1);
        while d.blocked_waiters(&coop) < 1 {
            std::thread::yield_now();
        }
        let w2 = spawn_waiter(2);
        while d.blocked_waiters(&coop) < 2 {
            std::thread::yield_now();
        }
        // free one slot: exactly the first waiter admits
        d.release(&coop, 4);
        assert_eq!(w1.join().unwrap(), 1);
        let deadline = Instant::now() + Duration::from_secs(2);
        while d.blocked_waiters(&coop) > 1 && Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(d.blocked_waiters(&coop), 1, "second waiter still parked");
        assert_eq!(d.in_flight(&coop), (1, 1));
        // free again: the second waiter admits
        d.release(&coop, 1);
        assert_eq!(w2.join().unwrap(), 2);
        assert_eq!(d.blocked_waiters(&coop), 0);
        assert_eq!(d.in_flight(&coop), (1, 1));
    }

    #[test]
    fn block_admission_respects_shutdown() {
        let d = Arc::new(dir_from("[tenants.coop]\nmax_queue_depth = 1").unwrap());
        let coop = TenantId::new("coop");
        d.admit(&coop, 1).unwrap();
        let waiter = {
            let d = d.clone();
            let coop = coop.clone();
            std::thread::spawn(move || d.admit_blocking(&coop, 1, None))
        };
        while d.blocked_waiters(&coop) < 1 {
            std::thread::yield_now();
        }
        d.close();
        match waiter.join().unwrap() {
            Err(AdmitBlockError::Closed(m)) => {
                assert!(m.contains("coop"), "names the tenant: {m}")
            }
            other => panic!("expected Closed, got {other:?}"),
        }
        // reservation count untouched by the refused waiter
        assert_eq!(d.in_flight(&coop), (1, 1));
        // and new blocking admissions refuse immediately once closed
        assert!(matches!(
            d.admit_blocking(&TenantId::new("late"), 1, None),
            Err(AdmitBlockError::Closed(_))
        ));
    }

    #[test]
    fn block_admission_times_out_at_the_deadline() {
        let d = dir_from("[tenants.coop]\nmax_queue_depth = 1").unwrap();
        let coop = TenantId::new("coop");
        d.admit(&coop, 1).unwrap();
        let t0 = Instant::now();
        let err = d
            .admit_blocking(&coop, 1, Some(t0 + Duration::from_millis(60)))
            .unwrap_err();
        assert!(
            t0.elapsed() >= Duration::from_millis(55),
            "gave up early: {:?}",
            t0.elapsed()
        );
        match err {
            AdmitBlockError::Timeout(m) => {
                assert!(m.contains("deadline"), "got: {m}")
            }
            other => panic!("expected Timeout, got {other:?}"),
        }
        assert_eq!(d.blocked_waiters(&coop), 0, "timed-out waiter left the FIFO");
        assert_eq!(d.in_flight(&coop), (1, 1), "no reservation leaked");
    }

    #[test]
    fn infeasible_block_requests_are_rejected_not_parked_forever() {
        // A request larger than the row cap can never fit — blocking
        // on it would hang the submitter and head-of-line block every
        // later cooperator for the tenant.
        let d = dir_from("[tenants.tiny]\nmax_in_flight_rows = 8").unwrap();
        let tiny = TenantId::new("tiny");
        match d.admit_blocking(&tiny, 9, None) {
            Err(AdmitBlockError::Rejected(m)) => {
                assert!(m.contains("never fit"), "got: {m}");
                assert!(m.contains("max_in_flight_rows"), "names the knob: {m}");
            }
            other => panic!("expected Rejected, got {other:?}"),
        }
        assert_eq!(d.blocked_waiters(&tiny), 0, "nothing parked");
        assert_eq!(d.in_flight(&tiny), (0, 0));
        // a feasible request still admits normally
        assert!(d.admit_blocking(&tiny, 8, None).is_ok());
    }

    #[test]
    fn blocked_waiters_are_bounded() {
        let d = Arc::new(
            dir_from("[tenants.coop]\nmax_queue_depth = 1")
                .unwrap()
                .with_max_blocked_waiters(1),
        );
        let coop = TenantId::new("coop");
        d.admit(&coop, 1).unwrap();
        let waiter = {
            let d = d.clone();
            let coop = coop.clone();
            std::thread::spawn(move || d.admit_blocking(&coop, 1, None))
        };
        while d.blocked_waiters(&coop) < 1 {
            std::thread::yield_now();
        }
        // the FIFO is at capacity: the next Block submission degrades
        // to an immediate rejection instead of parking another thread
        match d.admit_blocking(&coop, 1, None) {
            Err(AdmitBlockError::WaitersFull(m)) => {
                assert!(m.contains("max_blocked_waiters"), "got: {m}")
            }
            other => panic!("expected WaitersFull, got {other:?}"),
        }
        d.release(&coop, 1);
        assert!(waiter.join().unwrap().is_ok());
    }

    #[test]
    fn zero_weight_is_clamped_to_one() {
        let d = dir_from("[tenants.z]\nweight = 0").unwrap();
        assert_eq!(d.weight(&TenantId::new("z")), 1);
        assert!(d
            .batch_weights()
            .iter()
            .any(|(id, w)| id.as_str() == "z" && *w == 1));
    }
}

/// Model-check suites: exhaustive/randomized interleaving exploration
/// of the blocking-admission protocol (see `rust/modelcheck`). Compiled
/// only under `RUSTFLAGS="--cfg rtopk_model_check"`; the `mutants`
/// module additionally wants `--cfg rtopk_model_check_mutants`, which
/// swaps seeded bugs into the production code above and asserts the
/// checker catches them.
#[cfg(all(test, rtopk_model_check))]
mod model_tests {
    use super::*;
    use crate::util::sync::thread;

    /// A directory with one tenant ("coop") whose depth quota admits a
    /// single request, so every concurrent cooperator parks. Built by
    /// direct construction — config parsing would add file-shaped noise
    /// to every explored schedule. Single tenant on purpose: `HashMap`
    /// iteration order (e.g. in `close`) is seeded per-map, and a
    /// one-entry map keeps DFS replay deterministic.
    fn quota_dir() -> TenantDirectory {
        let dir = TenantDirectory::new();
        let id = TenantId::new("coop");
        let spec = TenantSpec {
            id: id.clone(),
            weight: 1,
            max_in_flight_rows: 0,
            max_queue_depth: 1,
            force_algo: None,
            default_mode: None,
        };
        dir.tenants
            .write()
            .unwrap()
            .insert(id, Arc::new(TenantState::new(spec)));
        dir
    }

    fn admitted_order(d: &TenantDirectory, id: &TenantId) -> Vec<u64> {
        d.tenants.read().unwrap()[id]
            .admitted_order
            .lock()
            .unwrap()
            .clone()
    }

    /// Shared body: the trunk suite requires it to hold in every
    /// explored schedule; the LIFO mutant must make it fail in some
    /// schedule. Root fills the depth quota, two cooperators block on
    /// admission, root frees the quota; parked waiters must then be
    /// admitted in ticket (arrival) order — `admitted_order` records
    /// only admissions that went through the parked path, so a waiter
    /// that fast-paths before the other arrives never pollutes the
    /// assertion.
    fn fifo_body() {
        let d = Arc::new(quota_dir());
        let coop = TenantId::new("coop");
        d.admit(&coop, 1).unwrap();
        let waiters: Vec<_> = (0..2)
            .map(|_| {
                let d = Arc::clone(&d);
                let coop = coop.clone();
                thread::spawn(move || {
                    d.admit_blocking(&coop, 1, None).unwrap();
                    d.release(&coop, 1);
                })
            })
            .collect();
        d.release(&coop, 1);
        for w in waiters {
            w.join().unwrap();
        }
        let order = admitted_order(&d, &coop);
        assert!(
            order.windows(2).all(|w| w[0] < w[1]),
            "parked waiters admitted out of arrival order: {order:?}"
        );
    }

    /// Trunk (no mutants): the suites must be clean. DFS has no
    /// partial-order reduction, so for 3 threads it only exhausts a
    /// capped prefix of the schedule tree; the randomized pass restores
    /// depth by sampling whole schedules uniformly at random.
    #[cfg(not(rtopk_model_check_mutants))]
    mod trunk {
        use super::*;
        use modelcheck::Checker;

        /// Shutdown path: two cooperators block on a full quota that is
        /// never released; `close` must drain both with `Closed` — no
        /// waiter may hang or sneak an admission.
        fn close_body() {
            let d = Arc::new(quota_dir());
            let coop = TenantId::new("coop");
            d.admit(&coop, 1).unwrap();
            let waiters: Vec<_> = (0..2)
                .map(|_| {
                    let d = Arc::clone(&d);
                    let coop = coop.clone();
                    thread::spawn(move || d.admit_blocking(&coop, 1, None))
                })
                .collect();
            d.close();
            for w in waiters {
                let res = w.join().unwrap();
                assert!(
                    matches!(res, Err(AdmitBlockError::Closed(_))),
                    "close must drain blocked waiters with Closed, got {res:?}"
                );
            }
        }

        #[test]
        fn model_blocking_admission_is_fifo() {
            let r = Checker::dfs()
                .max_executions(4_000)
                .env_caps()
                .check(fifo_body);
            assert!(r.failure.is_none(), "{:#?}", r.failure);
            let r = Checker::random(1_000, 0x746e_6e74)
                .env_caps()
                .check(fifo_body);
            assert!(r.failure.is_none(), "{:#?}", r.failure);
        }

        #[test]
        fn model_close_drains_blocked_waiters() {
            let r = Checker::dfs()
                .max_executions(4_000)
                .env_caps()
                .check(close_body);
            assert!(r.failure.is_none(), "{:#?}", r.failure);
            let r = Checker::random(800, 0x636c_6f73)
                .env_caps()
                .check(close_body);
            assert!(r.failure.is_none(), "{:#?}", r.failure);
        }
    }

    /// Seeded-bug pin: under `--cfg rtopk_model_check_mutants` the
    /// parked-success branch pops the *newest* waiter (LIFO). The
    /// deadline-poll loop self-heals lost wakeups, so the symptom is
    /// not a deadlock — it is the FIFO-order assertion tripping in any
    /// schedule where both cooperators park before quota frees. Random
    /// walks hit that window in a double-digit fraction of iterations,
    /// so 1 200 draws from a fixed seed find it with overwhelming
    /// margin while staying replayable.
    #[cfg(rtopk_model_check_mutants)]
    mod mutants {
        use super::*;
        use modelcheck::Checker;

        #[test]
        fn mutant_lifo_waiter_pop_is_caught() {
            // deliberately no env_caps(): capping the walk budget could
            // starve the buggy schedule and fail this test spuriously
            let r = Checker::random(1_200, 0x6c69_666f).check(fifo_body);
            let failure = r
                .failure
                .expect("LIFO pop must violate arrival order in some schedule");
            assert!(
                failure.message.contains("out of arrival order"),
                "unexpected failure shape: {}",
                failure.message
            );
        }
    }
}
