//! Backend registry: the set of [`ExecBackend`]s a deployment carries.
//!
//! The CPU engine is always registered (it is the guaranteed fallback
//! and the calibration baseline); accelerator backends are added from
//! the manifest subject to the `[backend]` config knobs (`enable`,
//! `deny`). The planner races registered backends per shape; the
//! scheduler resolves a plan's backend id through [`BackendRegistry::get`].

use crate::backend::{CpuBackend, ExecBackend, PjrtBackend, CPU_BACKEND_ID};
use crate::config::BackendConfig;
use crate::runtime::executor::ExecutorHandle;
use anyhow::Result;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

/// Consecutive runtime failures after which a backend is quarantined
/// for the rest of the process (the scheduler stops attempting it and
/// runs its batches on the CPU engine directly). Bounds both the
/// doubled per-batch work of try-then-fall-back and the failure log:
/// at most this many lines per backend between successes.
pub const QUARANTINE_AFTER: u32 = 3;

/// Registered execution backends; the CPU backend is always present.
///
/// The registry also tracks per-backend runtime health (consecutive
/// execute failures, reported by the scheduler): a backend that keeps
/// failing after calibration — dead device, driver wedged — is
/// quarantined instead of being retried and logged on every batch.
/// Quarantine lasts until process restart; calibration-time probe
/// failures are handled separately (the planner just never picks the
/// backend).
pub struct BackendRegistry {
    backends: Vec<Arc<dyn ExecBackend>>,
    /// consecutive-failure counter per backend, parallel to `backends`
    failures: Vec<AtomicU32>,
}

impl Default for BackendRegistry {
    fn default() -> Self {
        BackendRegistry::cpu_only()
    }
}

impl BackendRegistry {
    /// Just the CPU engine (tests, pure-CPU deployments, the global
    /// planner).
    pub fn cpu_only() -> BackendRegistry {
        BackendRegistry {
            backends: vec![Arc::new(CpuBackend)],
            failures: vec![AtomicU32::new(0)],
        }
    }

    /// CPU engine plus the PJRT tile backend built from the executor's
    /// manifest, honoring the `[backend]` knobs (`enable = false` or a
    /// deny-listed id registers nothing extra).
    pub fn with_manifest(cfg: &BackendConfig, handle: ExecutorHandle) -> BackendRegistry {
        let mut r = BackendRegistry::cpu_only();
        if cfg.enable {
            let pjrt = PjrtBackend::from_handle(handle);
            if !pjrt.tiles().is_empty() && !cfg.denies(pjrt.id()) {
                r.register(Arc::new(pjrt));
            }
        }
        r
    }

    /// Register a backend (latest id wins; the CPU backend cannot be
    /// displaced — it is the fallback every layer assumes exists).
    pub fn register(&mut self, backend: Arc<dyn ExecBackend>) {
        if backend.id() == CPU_BACKEND_ID {
            return;
        }
        if let Some(i) = self.backends.iter().position(|b| b.id() == backend.id()) {
            self.backends.remove(i);
            self.failures.remove(i);
        }
        self.backends.push(backend);
        self.failures.push(AtomicU32::new(0));
    }

    pub fn get(&self, id: &str) -> Option<Arc<dyn ExecBackend>> {
        self.backends.iter().find(|b| b.id() == id).cloned()
    }

    pub fn contains(&self, id: &str) -> bool {
        self.backends.iter().any(|b| b.id() == id)
    }

    /// The CPU fallback backend (always registered).
    pub fn cpu(&self) -> Arc<dyn ExecBackend> {
        self.get(CPU_BACKEND_ID).expect("cpu backend is always registered")
    }

    /// Every backend, CPU first.
    pub fn all(&self) -> &[Arc<dyn ExecBackend>] {
        &self.backends
    }

    /// Non-CPU backends (the calibrator's extra candidates).
    pub fn accelerators(&self) -> Vec<Arc<dyn ExecBackend>> {
        self.backends
            .iter()
            .filter(|b| b.id() != CPU_BACKEND_ID)
            .cloned()
            .collect()
    }

    pub fn ids(&self) -> Vec<String> {
        self.backends.iter().map(|b| b.id().to_string()).collect()
    }

    /// Union of compiled variants across accelerator backends.
    pub fn variants(&self) -> Vec<(usize, usize, String)> {
        let mut v: Vec<(usize, usize, String)> = self
            .backends
            .iter()
            .flat_map(|b| b.variants())
            .collect();
        v.sort();
        v.dedup();
        v
    }

    /// Run every backend's startup hook (compile-cache warmup).
    pub fn warmup(&self) -> Result<()> {
        for b in &self.backends {
            b.warmup()?;
        }
        Ok(())
    }

    fn failure_slot(&self, id: &str) -> Option<&AtomicU32> {
        self.backends
            .iter()
            .position(|b| b.id() == id)
            .map(|i| &self.failures[i])
    }

    /// Record one runtime execute failure; returns the consecutive
    /// count (callers log only while it is <= [`QUARANTINE_AFTER`]).
    pub fn note_failure(&self, id: &str) -> u32 {
        self.failure_slot(id)
            .map(|c| c.fetch_add(1, Ordering::Relaxed) + 1)
            .unwrap_or(0)
    }

    /// Record a successful execution (resets the consecutive count).
    pub fn note_success(&self, id: &str) {
        if let Some(c) = self.failure_slot(id) {
            c.store(0, Ordering::Relaxed);
        }
    }

    /// Whether a backend has failed [`QUARANTINE_AFTER`] consecutive
    /// times and should no longer be attempted (CPU never quarantines —
    /// it is the fallback).
    pub fn is_quarantined(&self, id: &str) -> bool {
        id != CPU_BACKEND_ID
            && self
                .failure_slot(id)
                .is_some_and(|c| c.load(Ordering::Relaxed) >= QUARANTINE_AFTER)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::ExecSpec;
    use crate::topk::types::{Mode, TopKResult};
    use crate::util::matrix::RowMatrix;

    struct FakeBackend(&'static str);

    impl ExecBackend for FakeBackend {
        fn id(&self) -> &str {
            self.0
        }
        fn describe(&self) -> String {
            "fake".into()
        }
        fn supports(&self, cols: usize, _k: usize, _mode: Mode) -> bool {
            cols == 256
        }
        fn execute(
            &self,
            _spec: &ExecSpec,
            _mats: &[&RowMatrix],
            _k: usize,
            _mode: Mode,
        ) -> Result<Vec<TopKResult>> {
            Ok(Vec::new())
        }
        fn variants(&self) -> Vec<(usize, usize, String)> {
            vec![(256, 32, "exact".into())]
        }
    }

    #[test]
    fn cpu_is_always_present_and_undisplaceable() {
        let mut r = BackendRegistry::cpu_only();
        assert!(r.contains(CPU_BACKEND_ID));
        assert_eq!(r.all().len(), 1);
        assert!(r.accelerators().is_empty());
        // attempting to replace the cpu backend is a no-op
        r.register(Arc::new(CpuBackend));
        assert_eq!(r.all().len(), 1);
        assert_eq!(r.cpu().id(), "cpu");
    }

    #[test]
    fn register_get_and_latest_wins() {
        let mut r = BackendRegistry::cpu_only();
        r.register(Arc::new(FakeBackend("mock")));
        assert!(r.contains("mock"));
        assert_eq!(r.accelerators().len(), 1);
        assert_eq!(r.ids(), vec!["cpu".to_string(), "mock".to_string()]);
        assert_eq!(r.variants(), vec![(256, 32, "exact".to_string())]);
        // same id re-registers in place
        r.register(Arc::new(FakeBackend("mock")));
        assert_eq!(r.all().len(), 2);
        assert!(r.get("nope").is_none());
    }

    #[test]
    fn quarantine_after_consecutive_failures_resets_on_success() {
        let mut r = BackendRegistry::cpu_only();
        r.register(Arc::new(FakeBackend("mock")));
        assert!(!r.is_quarantined("mock"));
        for i in 1..=QUARANTINE_AFTER {
            assert_eq!(r.note_failure("mock"), i);
        }
        assert!(r.is_quarantined("mock"));
        r.note_success("mock");
        assert!(!r.is_quarantined("mock"), "success lifts the quarantine");
        // the cpu fallback never quarantines, whatever is recorded
        for _ in 0..QUARANTINE_AFTER + 2 {
            r.note_failure(CPU_BACKEND_ID);
        }
        assert!(!r.is_quarantined(CPU_BACKEND_ID));
        // unknown ids are inert
        assert_eq!(r.note_failure("nope"), 0);
        assert!(!r.is_quarantined("nope"));
    }
}
