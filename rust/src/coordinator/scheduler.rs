//! Scheduler: worker threads that pull batches from the batcher,
//! execute them through the planner-chosen execution backend, and
//! scatter per-request results back to reply channels.
//!
//! There is no routing logic here: the planner owns the backend choice
//! (`crate::plan`), the registry resolves the chosen id to a handle
//! (`crate::backend`), and this module only dispatches and delivers.
//! An accelerator backend that fails at execution time degrades to the
//! CPU engine instead of failing the batch.
//!
//! Tenancy: batches are single-tenant by construction (the batcher
//! groups per tenant), so delivery is where per-tenant accounting
//! closes the loop — each reply releases the request's admission
//! reservation (`crate::coordinator::tenant::TenantDirectory::release`)
//! and records the latency into the tenant's own metrics table. A
//! tenant-level `force_algo` pin (honored only where semantics allow,
//! like the global pin) overrides the plan's CPU algorithm at dispatch
//! and routes the batch to the CPU engine; pinned batches are never
//! shadow-sampled — the timing would measure the pin, not the plan's
//! winner.
//!
//! Cancellation and deadlines are enforced here, end to end: before a
//! batch dispatches, every cancelled item is dropped (reservation
//! released, `cancelled` error delivered, counted) and every item
//! whose per-request deadline already expired is answered with a
//! positioned timeout error instead of stale work — neither ever
//! reaches a backend. Both flags are re-checked at delivery: a request
//! cancelled mid-flight completes but its reply is discarded, and a
//! result finished after the deadline is reported as a timeout rather
//! than handed over late. Only the surviving items count as served
//! requests or touch latency reservoirs.
//!
//! Shadow re-probing: when `[plan] shadow_every = N` is set (N > 0),
//! every Nth dispatched batch is timed and then re-executed on the
//! plan's recorded runner-up; the measured edge feeds the planner's
//! per-shape EWMA (`Planner::record_shadow`), which demotes winners
//! whose calibration-time edge has inverted (thermal drift, co-tenant
//! contention, driver updates). The shadow result is discarded — only
//! the winner's results are delivered — and a batch that had to fall
//! back from a failing accelerator is never used as a shadow sample
//! (its timing measures the failure, not the winner). `shadow_every =
//! 0` skips all of this: the dispatch path is then exactly the
//! pre-shadow code.
//!
//! Telemetry feedback: every executed batch feeds the hub's ns-per-row
//! service-rate EWMA (`Metrics::record_batch_timing` — the estimate
//! behind deadline-feasibility admission) and reports the live queue
//! gauges to the planner's shadow-cadence controller
//! (`Planner::note_load` — deep queues or near-deadline traffic
//! stretch the re-probe cadence, idle restores it). Every
//! [`RELEARN_EVERY`] batches the planner re-derives its row-bucket
//! boundaries from the hub's recent-request-rows window
//! (`Planner::relearn_buckets`). The hub's `LoadSnapshot` additionally
//! carries the persistent worker pool's gauges
//! (`crate::util::pool::gauges` — jobs, steals, park/unpark counts,
//! worker utilization), read live at snapshot time, so consumers see
//! execution-substrate saturation next to queue depth; shadow results
//! are recycled into the result-buffer freelist since they never leave
//! this module.

use crate::backend::{
    registry::QUARANTINE_AFTER, BackendRegistry, CPU_BACKEND_ID,
};
use crate::coordinator::batcher::{Batch, Batcher};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::tenant::TenantDirectory;
use crate::plan::{Plan, Planner};
use crate::topk::rowwise::rowwise_topk;
use crate::topk::types::TopKResult;
use crate::util::matrix::RowMatrix;
use anyhow::{anyhow, Result};
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Re-derive the planner's row-bucket boundaries from the telemetry
/// hub's rows window once per this many executed batches. Cheap
/// (sort of a bounded window) but not free, and the boundaries only
/// drift on workload shifts — no reason to pay it per batch.
pub const RELEARN_EVERY: u64 = 64;

/// Reply slot carried through the batcher.
pub type Reply = mpsc::Sender<Result<TopKResult>>;

/// Spawn `workers` scheduler threads; they exit when the batcher closes.
/// Batches execute through the shared adaptive `planner` (plans are
/// cached per keyed shape, so workers agree after the first batch of a
/// shape) against the backends in `backends`.
pub fn spawn_workers(
    workers: usize,
    batcher: Arc<Batcher<Reply>>,
    backends: Arc<BackendRegistry>,
    metrics: Arc<Metrics>,
    planner: Arc<Planner>,
    tenants: Arc<TenantDirectory>,
) -> Vec<JoinHandle<()>> {
    (0..workers.max(1))
        .map(|i| {
            let batcher = batcher.clone();
            let backends = backends.clone();
            let metrics = metrics.clone();
            let planner = planner.clone();
            let tenants = tenants.clone();
            std::thread::Builder::new()
                .name(format!("topk-worker-{i}"))
                .spawn(move || {
                    while let Some(batch) = batcher.next_batch() {
                        run_batch(batch, &backends, &metrics, &planner, &tenants);
                    }
                })
                .expect("spawn worker")
        })
        .collect()
}

/// The shape one dispatched batch executed at (after cancelled /
/// expired items were dropped).
#[derive(Clone, Copy)]
struct BatchShape {
    rows: usize,
    cols: usize,
    k: usize,
    mode: crate::topk::types::Mode,
}

/// Re-execute a shadowed batch on the plan's runner-up and feed the
/// measured edge back to the planner. The shadow result is discarded;
/// a runner-up that cannot execute (quarantined, vanished tile) simply
/// yields no sample.
fn shadow_reprobe(
    shape: BatchShape,
    mats: &[&RowMatrix],
    winner_secs: f64,
    backends: &BackendRegistry,
    planner: &Planner,
    plan: &Plan,
) {
    let Some(ru) = &plan.runner_up else { return };
    let Some(rb) = backends.get(&ru.backend) else { return };
    if backends.is_quarantined(rb.id()) {
        return;
    }
    let spec = crate::backend::ExecSpec { algo: ru.algo, grain: ru.grain };
    let t0 = Instant::now();
    match rb.execute(&spec, mats, shape.k, shape.mode) {
        Ok(res) => {
            let runner_secs = t0.elapsed().as_secs_f64();
            // shadow results never leave the scheduler: return their
            // buffers to the result freelist instead of dropping them
            for r in res {
                std::hint::black_box(&r);
                r.recycle();
            }
            planner.record_shadow(
                shape.rows,
                shape.cols,
                shape.k,
                shape.mode,
                winner_secs,
                runner_secs,
            );
        }
        // an unexecutable runner-up is a skipped probe, not an error —
        // same contract as calibration-time probe failures
        Err(_) => {}
    }
}

/// Drop one cancelled request: release its reservation, count it, and
/// deliver the `cancelled` error to the ticket. Shared with the
/// service's ticket cancel-hook (which evicts cancelled requests from
/// the batcher queue) so both cancellation reply paths stay identical.
pub(crate) fn reply_cancelled(
    item: crate::coordinator::batcher::Pending<Reply>,
    metrics: &Metrics,
    tenants: &TenantDirectory,
    when: &str,
) {
    tenants.release(&item.tenant, item.matrix.rows);
    metrics.record_cancelled_for(&item.tenant);
    let _ = item.reply.send(Err(anyhow!(
        "request cancelled by the client {when} (tenant {:?})",
        item.tenant.as_str()
    )));
}

/// Answer one deadline-expired request with a positioned timeout error
/// — never stale work.
fn reply_timed_out(
    item: crate::coordinator::batcher::Pending<Reply>,
    metrics: &Metrics,
    tenants: &TenantDirectory,
    when: &str,
) {
    tenants.release(&item.tenant, item.matrix.rows);
    metrics.record_timed_out_for(&item.tenant);
    // waited is measured from *submit*, not from batcher enqueue — a
    // Block-policy request spends part of its budget parked in
    // admission, and the positioned error must never claim
    // waited < deadline for a correctly expired request
    let waited_us = item.submitted.elapsed().as_micros();
    let deadline_us =
        item.deadline.map(|d| d.as_micros()).unwrap_or_default();
    let _ = item.reply.send(Err(anyhow!(
        "request deadline exceeded {when}: tenant {:?} waited {waited_us} us \
         against a {deadline_us} us deadline; answering with a timeout \
         instead of stale work",
        item.tenant.as_str()
    )));
}

/// Execute one batch through the plan's backend and deliver per-request
/// results. Cancelled and deadline-expired items are dropped here,
/// before any work is dispatched.
pub fn run_batch(
    batch: Batch<Reply>,
    backends: &BackendRegistry,
    metrics: &Metrics,
    planner: &Planner,
    tenants: &TenantDirectory,
) {
    let Batch { tenant, cols, k, mode, items, .. } = batch;
    // pre-dispatch gate: drop cancelled items, answer expired ones
    let now = Instant::now();
    let mut live: Vec<_> = Vec::with_capacity(items.len());
    for item in items {
        if item.cancel.is_cancelled() {
            reply_cancelled(item, metrics, tenants, "while queued");
        } else if item.expire_at.is_some_and(|at| now >= at) {
            reply_timed_out(item, metrics, tenants, "before dispatch");
        } else {
            live.push(item);
        }
    }
    if live.is_empty() {
        // the whole batch died before dispatch: nothing executes, no
        // batch is recorded
        return;
    }
    let total_rows: usize = live.iter().map(|p| p.matrix.rows).sum();
    let plan = planner.plan(total_rows, cols, k, mode);
    // a plan can only name a registered backend, but resolve
    // defensively; a backend that kept failing at runtime is
    // quarantined — its batches run on the CPU engine directly instead
    // of paying a doomed attempt (and a log line) per batch
    let mut backend = backends
        .get(&plan.backend)
        .unwrap_or_else(|| backends.cpu());
    if backends.is_quarantined(backend.id()) {
        backend = backends.cpu();
    }
    let mut spec = plan.spec();
    // a tenant-level algorithm pin overrides the plan's CPU algorithm
    // and runs on the CPU engine (so what the pin names is what
    // executes); semantics-gated exactly like the global force_algo
    let mut tenant_pinned = false;
    if let Some(algo) = tenants.pinned_algo(&tenant, mode) {
        if algo != spec.algo {
            spec = crate::backend::ExecSpec { algo, grain: plan.grain };
            backend = backends.cpu();
            tenant_pinned = true;
        }
    }
    let mats: Vec<&RowMatrix> = live.iter().map(|item| &item.matrix).collect();
    let mut via_accel = backend.id() != CPU_BACKEND_ID;
    // time the dispatch only when this batch is a shadow sample — and
    // only when what executes really is the plan's winner: a dispatch
    // that silently resolved a quarantined/unregistered backend to the
    // CPU (or a tenant pin) would otherwise feed record_shadow a
    // timing that measures something other than the cached winner
    let is_primary = !tenant_pinned && backend.id() == plan.backend;
    let shadow_t0 =
        if is_primary && planner.shadow_due() && plan.runner_up.is_some() {
            Some(Instant::now())
        } else {
            None
        };
    let exec_t0 = Instant::now();
    let mut outcome = backend.execute(&spec, &mats, k, mode);
    let winner_secs = shadow_t0.map(|t| t.elapsed().as_secs_f64());
    let mut fell_back = false;
    if via_accel && outcome.is_err() {
        // accelerator misbehaved at runtime: degrade to the CPU engine
        // rather than failing every request in the batch. The failure
        // log is bounded — at most QUARANTINE_AFTER lines per backend
        // between successes — and a backend that keeps failing stops
        // being attempted at all.
        let msg = outcome
            .as_ref()
            .err()
            .map(|e| format!("{e:#}"))
            .unwrap_or_default();
        let fails = backends.note_failure(backend.id());
        if fails <= QUARANTINE_AFTER {
            eprintln!(
                "scheduler: backend {:?} failed ({msg}); batch falls back \
                 to cpu{}",
                backend.id(),
                if fails == QUARANTINE_AFTER {
                    " (quarantining backend until restart)"
                } else {
                    ""
                }
            );
        }
        via_accel = false;
        fell_back = true;
        outcome = backends.cpu().execute(&spec, &mats, k, mode);
    } else if via_accel {
        backends.note_success(backend.id());
    }
    // captured before any shadow re-execute: the service-rate estimate
    // must measure what it took to serve the batch (fallback attempts
    // included), not the optional runner-up probe on top
    let exec_elapsed = exec_t0.elapsed();
    // the shadow run needs the live matrices, so it happens before the
    // results scatter consumes the batch; a fallen-back batch is not a
    // valid winner sample
    if let Some(winner_secs) = winner_secs {
        if !fell_back && outcome.is_ok() {
            let shape = BatchShape { rows: total_rows, cols, k, mode };
            shadow_reprobe(shape, &mats, winner_secs, backends, planner, &plan);
        }
    }
    drop(mats);
    metrics.record_batch(via_accel);
    if outcome.is_ok() {
        metrics.record_batch_timing(total_rows, exec_elapsed);
    }
    // close the feedback loop once per batch: feed the live queue
    // gauges to the planner's shadow-cadence controller, and
    // periodically re-derive the row-bucket boundaries from the
    // observed request-size window
    let gauges = metrics.queue_gauges();
    planner.note_load(gauges.queued_rows, gauges.min_slack_us);
    if metrics.batches.load(Ordering::Relaxed) % RELEARN_EVERY == 0 {
        planner.relearn_buckets(&metrics.rows_window());
    }
    match outcome {
        Ok(results) => {
            for (item, res) in live.into_iter().zip(results) {
                // delivery gate: a request cancelled mid-flight
                // completed, but its reply is discarded; a result
                // finished past the deadline is a timeout, not a late
                // answer
                if item.cancel.is_cancelled() {
                    reply_cancelled(item, metrics, tenants, "mid-flight");
                    continue;
                }
                if item.expire_at.is_some_and(|at| Instant::now() >= at) {
                    reply_timed_out(item, metrics, tenants, "at delivery");
                    continue;
                }
                // latency spans submit-to-reply (matching the tenant
                // module's in-flight contract): time parked in blocking
                // admission or backpressure is client-visible wait and
                // must reach the reservoirs
                let latency = item.submitted.elapsed();
                metrics.record_request_for(&tenant, item.matrix.rows, latency);
                tenants.release(&tenant, item.matrix.rows);
                let _ = item.reply.send(Ok(res));
            }
        }
        Err(e) => {
            metrics.record_error_for(&tenant);
            let msg = format!("{e:#}");
            for item in live {
                // the delivery gates apply here too: a caller that
                // cancelled (or whose deadline passed) gets the
                // documented cancelled/timeout error and counter, not
                // a generic execution error it might retry on
                if item.cancel.is_cancelled() {
                    reply_cancelled(item, metrics, tenants, "mid-flight");
                    continue;
                }
                if item.expire_at.is_some_and(|at| Instant::now() >= at) {
                    reply_timed_out(item, metrics, tenants, "at delivery");
                    continue;
                }
                tenants.release(&tenant, item.matrix.rows);
                let _ = item.reply.send(Err(anyhow!("{msg}")));
            }
        }
    }
}

/// Pad-free helper used by tests and the service's synchronous path.
pub fn run_direct_cpu(matrix: &RowMatrix, k: usize,
                      mode: crate::topk::types::Mode) -> TopKResult {
    rowwise_topk(matrix, k, mode)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{ExecBackend, ExecSpec};
    use crate::coordinator::batcher::BatchPolicy;
    use crate::plan::{PlannerConfig, SHADOW_MIN_SAMPLES};
    use crate::topk::rowwise::rowwise_topk_grained;
    use crate::topk::types::Mode;
    use crate::topk::verify::is_exact;
    use crate::util::rng::Rng;
    use std::time::Duration;

    fn one_pending(x: &RowMatrix, k: usize, mode: Mode, tx: Reply)
        -> crate::coordinator::batcher::Pending<Reply> {
        use crate::coordinator::request::{CancelToken, Priority};
        use crate::coordinator::tenant::TenantId;
        let now = std::time::Instant::now();
        crate::coordinator::batcher::Pending {
            tenant: TenantId::default(),
            matrix: x.clone(),
            k,
            mode,
            submitted: now,
            enqueued: now,
            flush_at: now,
            deadline: None,
            expire_at: None,
            priority: Priority::Normal,
            cancel: CancelToken::new(),
            reply: tx,
        }
    }

    fn one_item_batch(x: &RowMatrix, k: usize, mode: Mode, tx: Reply) -> Batch<Reply> {
        use crate::coordinator::tenant::TenantId;
        Batch {
            tenant: TenantId::default(),
            cols: x.cols,
            k,
            mode,
            total_rows: x.rows,
            items: vec![one_pending(x, k, mode, tx)],
        }
    }

    fn no_tenants() -> Arc<TenantDirectory> {
        Arc::new(TenantDirectory::new())
    }

    #[test]
    fn cpu_pipeline_end_to_end() {
        let batcher: Arc<Batcher<Reply>> = Arc::new(Batcher::new(BatchPolicy {
            max_rows: 64,
            max_wait: Duration::from_millis(2),
            queue_limit: 4096,
        }));
        let backends = Arc::new(BackendRegistry::cpu_only());
        let metrics = Arc::new(Metrics::default());
        let planner = Arc::new(Planner::default());
        let workers = spawn_workers(
            2,
            batcher.clone(),
            backends,
            metrics.clone(),
            planner.clone(),
            no_tenants(),
        );

        let mut rng = Rng::seed_from(21);
        let mut rxs = Vec::new();
        let mut mats = Vec::new();
        for _ in 0..6 {
            let x = RowMatrix::random_normal(20, 32, &mut rng);
            let (tx, rx) = mpsc::channel();
            assert!(batcher.submit(
                crate::coordinator::tenant::TenantId::default(),
                x.clone(),
                4,
                Mode::EXACT,
                tx
            ));
            rxs.push(rx);
            mats.push(x);
        }
        for (rx, x) in rxs.into_iter().zip(&mats) {
            let res = rx.recv().unwrap().unwrap();
            assert_eq!(res.rows, 20);
            assert!(is_exact(x, &res));
        }
        batcher.close();
        for w in workers {
            w.join().unwrap();
        }
        let s = metrics.snapshot();
        assert_eq!(s.requests, 6);
        assert_eq!(s.rows, 120);
        assert!(s.batches >= 1);
        assert_eq!(s.errors, 0);
        assert!(
            metrics.ns_per_row() > 0,
            "served batches must feed the service-rate EWMA"
        );
        // default config: shadow_every = 0 — dispatch must never have
        // taken a shadow sample
        assert_eq!(planner.shadow_observations(), 0);
    }

    #[test]
    fn expired_deadline_is_answered_before_work_is_dispatched() {
        // A batch whose only item is already past its deadline must be
        // answered with a positioned timeout error — no backend runs,
        // nothing counts as served.
        let backends = Arc::new(BackendRegistry::cpu_only());
        let metrics = Arc::new(Metrics::default());
        let planner = Arc::new(Planner::default());
        let tenants = no_tenants();
        let mut rng = Rng::seed_from(0x61);
        let x = RowMatrix::random_normal(6, 32, &mut rng);
        let (tx, rx) = mpsc::channel();
        let mut batch = one_item_batch(&x, 4, Mode::EXACT, tx);
        batch.items[0].deadline = Some(Duration::from_micros(10));
        batch.items[0].expire_at =
            Some(std::time::Instant::now() - Duration::from_millis(1));
        run_batch(batch, &backends, &metrics, &planner, &tenants);
        let err = rx.recv().unwrap().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("deadline exceeded"), "got: {msg}");
        assert!(msg.contains("10 us"), "names the deadline: {msg}");
        let s = metrics.snapshot();
        assert_eq!(s.timed_out, 1);
        assert_eq!(s.requests, 0, "never served");
        assert_eq!(s.batches, 0, "no work dispatched");
        assert_eq!(s.errors, 0, "a timeout is not an execution error");
        assert_eq!(planner.cache().len(), 0, "never even planned");
    }

    #[test]
    fn cancelled_item_is_dropped_and_live_items_still_serve() {
        // One cancelled and one live request in the same batch: the
        // cancelled one gets a `cancelled` error and the live one is
        // served normally.
        let backends = Arc::new(BackendRegistry::cpu_only());
        let metrics = Arc::new(Metrics::default());
        let planner = Arc::new(Planner::default());
        let tenants = no_tenants();
        let mut rng = Rng::seed_from(0x62);
        let x = RowMatrix::random_normal(5, 32, &mut rng);
        let y = RowMatrix::random_normal(5, 32, &mut rng);
        let (tx_c, rx_c) = mpsc::channel();
        let (tx_l, rx_l) = mpsc::channel();
        let cancelled = one_pending(&x, 4, Mode::EXACT, tx_c);
        cancelled.cancel.cancel();
        let live = one_pending(&y, 4, Mode::EXACT, tx_l);
        let batch = Batch {
            tenant: crate::coordinator::tenant::TenantId::default(),
            cols: 32,
            k: 4,
            mode: Mode::EXACT,
            total_rows: 10,
            items: vec![cancelled, live],
        };
        run_batch(batch, &backends, &metrics, &planner, &tenants);
        let err = rx_c.recv().unwrap().unwrap_err();
        assert!(format!("{err:#}").contains("cancelled"), "got: {err:#}");
        let res = rx_l.recv().unwrap().unwrap();
        assert!(is_exact(&y, &res), "live request served exactly");
        let s = metrics.snapshot();
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.requests, 1, "only the live request was served");
        assert_eq!(s.rows, 5, "cancelled rows never count as served");
    }

    #[test]
    fn failing_accelerator_degrades_to_cpu_not_to_errors() {
        use std::sync::atomic::{AtomicUsize, Ordering};

        struct Flaky {
            attempts: AtomicUsize,
        }
        impl ExecBackend for Flaky {
            fn id(&self) -> &str {
                "flaky"
            }
            fn describe(&self) -> String {
                "errors at execute".into()
            }
            fn supports(&self, _c: usize, _k: usize, _m: Mode) -> bool {
                true
            }
            fn execute(
                &self,
                _spec: &ExecSpec,
                _mats: &[&RowMatrix],
                _k: usize,
                _mode: Mode,
            ) -> Result<Vec<TopKResult>> {
                self.attempts.fetch_add(1, Ordering::SeqCst);
                Err(anyhow!("device fell off the bus"))
            }
        }

        let flaky = Arc::new(Flaky { attempts: AtomicUsize::new(0) });
        let mut registry = BackendRegistry::cpu_only();
        registry.register(flaky.clone());
        let backends = Arc::new(registry);
        // pin the batch to the flaky backend so the fallback path runs
        let planner = Arc::new(crate::plan::Planner::with_backends(
            PlannerConfig {
                force_backend: Some("flaky".into()),
                calib_rows: 0,
                ..PlannerConfig::default()
            },
            backends.clone(),
        ));
        let metrics = Arc::new(Metrics::default());

        let mut rng = Rng::seed_from(99);
        let x = RowMatrix::random_normal(12, 32, &mut rng);
        // run several batches: the first QUARANTINE_AFTER attempt the
        // backend and fall back; after that the backend is quarantined
        // and never even tried again
        let total_batches = QUARANTINE_AFTER + 2;
        for _ in 0..total_batches {
            let (tx, rx) = mpsc::channel();
            let batch = one_item_batch(&x, 4, Mode::EXACT, tx);
            run_batch(batch, &backends, &metrics, &planner, &no_tenants());
            let res = rx.recv().unwrap().unwrap();
            assert!(is_exact(&x, &res), "fallback result must stay exact");
        }
        assert_eq!(
            flaky.attempts.load(Ordering::SeqCst) as u32,
            QUARANTINE_AFTER,
            "quarantined backend stops being attempted"
        );
        let s = metrics.snapshot();
        assert_eq!(s.errors, 0, "fallback is not a client error");
        assert_eq!(
            s.cpu_batches,
            total_batches as u64,
            "every batch is accounted to the cpu engine"
        );
    }

    #[test]
    fn quarantined_winner_is_not_shadow_sampled() {
        // Regression: dispatch that silently resolves a quarantined
        // winner to the CPU must not take a shadow sample — timing the
        // CPU against its own runner-up measures nothing and pins the
        // stale winner's EWMA at zero.
        struct Dead;
        impl ExecBackend for Dead {
            fn id(&self) -> &str {
                "dead"
            }
            fn describe(&self) -> String {
                "quarantined before the test starts".into()
            }
            fn supports(&self, _c: usize, _k: usize, _m: Mode) -> bool {
                true
            }
            fn execute(
                &self,
                _spec: &ExecSpec,
                _mats: &[&RowMatrix],
                _k: usize,
                _mode: Mode,
            ) -> Result<Vec<TopKResult>> {
                panic!("quarantined backend must not be executed")
            }
        }

        let mut registry = BackendRegistry::cpu_only();
        registry.register(Arc::new(Dead));
        let backends = Arc::new(registry);
        for _ in 0..QUARANTINE_AFTER {
            backends.note_failure("dead");
        }
        assert!(backends.is_quarantined("dead"));
        let planner = Arc::new(Planner::with_backends(
            PlannerConfig {
                calib_rows: 0,
                shadow_every: 1,
                ..PlannerConfig::default()
            },
            backends.clone(),
        ));
        let metrics = Arc::new(Metrics::default());
        let mut rng = Rng::seed_from(0x52);
        let x = RowMatrix::random_normal(10, 32, &mut rng);
        // the model prior still names the (quarantined) accelerator
        assert_eq!(planner.plan(10, 32, 4, Mode::EXACT).backend, "dead");
        let (tx, rx) = mpsc::channel();
        run_batch(
            one_item_batch(&x, 4, Mode::EXACT, tx),
            &backends,
            &metrics,
            &planner,
            &no_tenants(),
        );
        assert!(is_exact(&x, &rx.recv().unwrap().unwrap()));
        assert_eq!(
            planner.shadow_observations(),
            0,
            "cpu-vs-cpu shadow sample must not be recorded"
        );
        assert_eq!(metrics.snapshot().errors, 0);
    }

    #[test]
    fn shadow_reprobing_demotes_a_slow_backend_to_cpu() {
        // A backend that wins calibration but then turns slow (thermal
        // throttle, contended device): shadow re-probing must measure
        // the inversion on live batches and demote it to the CPU
        // runner-up, after which dispatch goes straight to the CPU.
        use std::sync::atomic::{AtomicUsize, Ordering};

        struct Sluggish {
            calls: AtomicUsize,
        }
        impl ExecBackend for Sluggish {
            fn id(&self) -> &str {
                "sluggish"
            }
            fn describe(&self) -> String {
                "correct but 2ms slow per batch".into()
            }
            fn supports(&self, _c: usize, _k: usize, _m: Mode) -> bool {
                true
            }
            fn execute(
                &self,
                spec: &ExecSpec,
                mats: &[&RowMatrix],
                k: usize,
                _mode: Mode,
            ) -> Result<Vec<TopKResult>> {
                self.calls.fetch_add(1, Ordering::SeqCst);
                std::thread::sleep(Duration::from_millis(2));
                Ok(mats
                    .iter()
                    .map(|x| rowwise_topk_grained(x, k, spec.algo, spec.grain))
                    .collect())
            }
        }

        let sluggish = Arc::new(Sluggish { calls: AtomicUsize::new(0) });
        let mut registry = BackendRegistry::cpu_only();
        registry.register(sluggish.clone());
        let backends = Arc::new(registry);
        let planner = Arc::new(Planner::with_backends(
            PlannerConfig {
                // model-only decision: the manifest prior picks the
                // accelerator, with the CPU prior as runner-up — the
                // exact "calibration went stale" shape
                calib_rows: 0,
                shadow_every: 1,
                ..PlannerConfig::default()
            },
            backends.clone(),
        ));
        let metrics = Arc::new(Metrics::default());

        let mut rng = Rng::seed_from(0x51);
        let x = RowMatrix::random_normal(12, 32, &mut rng);
        let first = planner.plan(12, 32, 4, Mode::EXACT);
        assert_eq!(first.backend, "sluggish", "premise: prior picks the accel");
        assert_eq!(first.runner_up.as_ref().unwrap().backend, CPU_BACKEND_ID);

        // a 2ms sleep against a microsecond CPU batch is an edge of
        // ~-1.0, far past the hysteresis margin, deterministically
        for _ in 0..SHADOW_MIN_SAMPLES {
            let (tx, rx) = mpsc::channel();
            run_batch(
                one_item_batch(&x, 4, Mode::EXACT, tx),
                &backends,
                &metrics,
                &planner,
                &no_tenants(),
            );
            assert!(is_exact(&x, &rx.recv().unwrap().unwrap()));
        }
        assert!(
            planner.shadow_observations() >= SHADOW_MIN_SAMPLES,
            "every batch was shadow-sampled"
        );
        let demoted = planner.plan(12, 32, 4, Mode::EXACT);
        assert_eq!(demoted.backend, CPU_BACKEND_ID, "stale winner demoted");
        assert_eq!(
            demoted.runner_up.as_ref().unwrap().backend,
            "sluggish",
            "old winner stays recorded as the comparator"
        );

        // demoted dispatch no longer touches the slow backend as the
        // primary; it may still be shadow-probed, which is the point
        let calls_before = sluggish.calls.load(Ordering::SeqCst);
        let (tx, rx) = mpsc::channel();
        run_batch(
            one_item_batch(&x, 4, Mode::EXACT, tx),
            &backends,
            &metrics,
            &planner,
            &no_tenants(),
        );
        assert!(is_exact(&x, &rx.recv().unwrap().unwrap()));
        let s = metrics.snapshot();
        assert!(s.cpu_batches >= 1, "post-demotion batch ran on the cpu");
        assert_eq!(s.errors, 0);
        // exactly one extra call: the shadow probe of the comparator,
        // not the primary dispatch
        assert_eq!(sluggish.calls.load(Ordering::SeqCst), calls_before + 1);
    }
}
