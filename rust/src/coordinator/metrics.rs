//! Service metrics: lock-free aggregate counters, per-tenant counter
//! tables, and mutex-guarded latency reservoirs with percentile
//! snapshots.
//!
//! Reservoirs use counter-driven uniform sampling (Vitter's
//! Algorithm R): once full, observation number `n` replaces a random
//! slot with probability `cap / n`, so the snapshot is a uniform
//! sample of the whole stream. The previous scheme picked the
//! overwrite slot from the latency value itself
//! (`latency.as_nanos() % cap`), which collapsed identical/quantized
//! latencies into the same few slots — a bimodal stream would keep
//! overwriting two slots while 65k stale entries skewed every
//! percentile.
//!
//! Tenancy: every served request is recorded twice — into the
//! aggregate counters/reservoir (capacity [`RESERVOIR`]) and into its
//! tenant's own table (a smaller [`TENANT_RESERVOIR`] reservoir per
//! tenant; past [`MAX_TENANT_TABLES`] distinct tenants new names fold
//! into the shared [`OVERFLOW_TENANT`] entry, so client-chosen names
//! cannot grow the table forever). Quota rejections, client
//! cancellations, and deadline timeouts are recorded *only* as
//! counters (`rejected` / `cancelled` / `timed_out`): none of them is
//! a served request, so none may touch any latency reservoir — one
//! tenant shedding, cancelling, or timing out cannot perturb another
//! tenant's percentiles. Pinned by the isolation tests in
//! `tests/tenants.rs`.

use crate::coordinator::tenant::TenantId;
use crate::stats::summary::percentile;
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

/// Aggregate latency-reservoir capacity.
pub const RESERVOIR: usize = 1 << 16;

/// Per-tenant latency-reservoir capacity (bounded per tenant so the
/// table scales to many tenants).
pub const TENANT_RESERVOIR: usize = 4096;

/// Cap on distinct per-tenant metric tables. Tenant names are
/// client-chosen, so past this many entries new names fold into the
/// shared [`OVERFLOW_TENANT`] row instead of growing the map forever.
/// Sized above the tenant directory's own bound
/// (`crate::coordinator::tenant::MAX_AD_HOC_TENANTS` plus configured
/// tenants) so well-behaved deployments never hit it.
pub const MAX_TENANT_TABLES: usize = 4096;

/// The synthetic tenant name overflow traffic is accounted under.
pub const OVERFLOW_TENANT: &str = "(overflow)";

/// Shared metrics hub (cheap to clone via Arc by the owner).
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub rows: AtomicU64,
    pub batches: AtomicU64,
    pub pjrt_batches: AtomicU64,
    pub cpu_batches: AtomicU64,
    pub errors: AtomicU64,
    /// requests dropped because the caller cancelled the ticket
    pub cancelled: AtomicU64,
    /// requests answered with a deadline-timeout error
    pub timed_out: AtomicU64,
    /// request latencies in microseconds (bounded uniform reservoir)
    latencies_us: Mutex<Reservoir>,
    /// per-tenant counters and reservoirs, registered on first sight
    tenants: RwLock<HashMap<TenantId, Arc<TenantMetrics>>>,
}

/// One tenant's counters + latency reservoir.
#[derive(Debug)]
struct TenantMetrics {
    requests: AtomicU64,
    rows: AtomicU64,
    errors: AtomicU64,
    /// submissions rejected by admission control (over quota)
    rejected: AtomicU64,
    /// requests dropped because the caller cancelled the ticket
    cancelled: AtomicU64,
    /// requests answered with a deadline-timeout error
    timed_out: AtomicU64,
    latencies_us: Mutex<Reservoir>,
}

impl TenantMetrics {
    fn new() -> TenantMetrics {
        TenantMetrics {
            requests: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            timed_out: AtomicU64::new(0),
            latencies_us: Mutex::new(Reservoir::with_cap(
                TENANT_RESERVOIR,
                0x7E4A,
            )),
        }
    }
}

/// Bounded uniform sample of a latency stream.
#[derive(Debug)]
struct Reservoir {
    samples: Vec<u64>,
    /// observations offered so far (the Algorithm R counter)
    seen: u64,
    rng: Rng,
    cap: usize,
}

impl Reservoir {
    /// Deterministic seed: sampling must be unpredictable *per slot*,
    /// not across runs — reproducible metrics are a feature.
    fn with_cap(cap: usize, seed: u64) -> Reservoir {
        Reservoir {
            samples: Vec::new(),
            seen: 0,
            rng: Rng::seed_from(seed),
            cap,
        }
    }

    /// Offer one observation (Algorithm R: kept with probability
    /// `cap / seen`, in a uniformly chosen slot).
    fn offer(&mut self, us: u64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(us);
        } else {
            let seen = self.seen;
            let j = self.rng.below(seen) as usize;
            if j < self.cap {
                self.samples[j] = us;
            }
        }
    }

    /// Sorted snapshot with (p50, p95, p99, max) in microseconds.
    fn stats(&self) -> (f64, f64, f64, f64) {
        let mut lat: Vec<f64> = self.samples.iter().map(|&v| v as f64).collect();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pick = |p: f64| if lat.is_empty() { 0.0 } else { percentile(&lat, p) };
        (
            pick(50.0),
            pick(95.0),
            pick(99.0),
            lat.last().copied().unwrap_or(0.0),
        )
    }
}

impl Default for Reservoir {
    fn default() -> Self {
        Reservoir::with_cap(RESERVOIR, 0x1A7E)
    }
}

/// Point-in-time view.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub rows: u64,
    pub batches: u64,
    pub pjrt_batches: u64,
    pub cpu_batches: u64,
    pub errors: u64,
    /// requests dropped because the caller cancelled the ticket
    pub cancelled: u64,
    /// requests answered with a deadline-timeout error
    pub timed_out: u64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
    /// per-tenant view, sorted by tenant name
    pub tenants: Vec<TenantSnapshot>,
}

/// Point-in-time view of one tenant.
#[derive(Clone, Debug)]
pub struct TenantSnapshot {
    pub tenant: String,
    pub requests: u64,
    pub rows: u64,
    pub errors: u64,
    /// submissions rejected by admission control (over quota)
    pub rejected: u64,
    /// requests dropped because the caller cancelled the ticket
    pub cancelled: u64,
    /// requests answered with a deadline-timeout error
    pub timed_out: u64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

impl Metrics {
    /// The tenant's table entry, registered on first sight (read-lock
    /// fast path). Past [`MAX_TENANT_TABLES`] distinct tenants, new
    /// names share the [`OVERFLOW_TENANT`] entry — client-chosen names
    /// must not grow the map without bound.
    fn tenant(&self, id: &TenantId) -> Arc<TenantMetrics> {
        if let Some(t) = self.tenants.read().unwrap().get(id) {
            return t.clone();
        }
        let mut map = self.tenants.write().unwrap();
        if map.len() >= MAX_TENANT_TABLES && !map.contains_key(id) {
            return map
                .entry(TenantId::new(OVERFLOW_TENANT))
                .or_insert_with(|| Arc::new(TenantMetrics::new()))
                .clone();
        }
        map.entry(id.clone())
            .or_insert_with(|| Arc::new(TenantMetrics::new()))
            .clone()
    }

    /// Record a served request into the aggregate counters/reservoir
    /// only (trainer path; the service path attributes to a tenant via
    /// [`Metrics::record_request_for`]).
    pub fn record_request(&self, rows: usize, latency: Duration) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(rows as u64, Ordering::Relaxed);
        let us = latency.as_micros() as u64;
        self.latencies_us.lock().unwrap().offer(us);
    }

    /// Record a served request into both the aggregate and the tenant's
    /// own counters/reservoir.
    pub fn record_request_for(
        &self,
        tenant: &TenantId,
        rows: usize,
        latency: Duration,
    ) {
        self.record_request(rows, latency);
        let t = self.tenant(tenant);
        t.requests.fetch_add(1, Ordering::Relaxed);
        t.rows.fetch_add(rows as u64, Ordering::Relaxed);
        let us = latency.as_micros() as u64;
        t.latencies_us.lock().unwrap().offer(us);
    }

    pub fn record_batch(&self, via_pjrt: bool) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        if via_pjrt {
            self.pjrt_batches.fetch_add(1, Ordering::Relaxed);
        } else {
            self.cpu_batches.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a failed batch against the aggregate and the tenant.
    pub fn record_error_for(&self, tenant: &TenantId) {
        self.record_error();
        self.tenant(tenant).errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an admission-control rejection. Counters only: a
    /// rejection must never touch a latency reservoir (its latency is
    /// the quota check, not service time), so shed load cannot skew
    /// any tenant's percentiles.
    pub fn record_rejection(&self, tenant: &TenantId) {
        self.tenant(tenant).rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a client cancellation. Counters only — a cancelled
    /// request was never served, so it carries no service latency and
    /// must not perturb any reservoir.
    pub fn record_cancelled_for(&self, tenant: &TenantId) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
        self.tenant(tenant).cancelled.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a deadline timeout (the request was answered with a
    /// positioned timeout error instead of a result). Counters only,
    /// same reservoir-isolation contract as rejections.
    pub fn record_timed_out_for(&self, tenant: &TenantId) {
        self.timed_out.fetch_add(1, Ordering::Relaxed);
        self.tenant(tenant).timed_out.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot one tenant's counters and percentiles (`None` if the
    /// tenant was never recorded).
    pub fn tenant_snapshot(&self, tenant: &TenantId) -> Option<TenantSnapshot> {
        let t = self.tenants.read().unwrap().get(tenant)?.clone();
        Some(Self::snap_tenant(tenant, &t))
    }

    fn snap_tenant(id: &TenantId, t: &TenantMetrics) -> TenantSnapshot {
        let (p50_us, p95_us, p99_us, max_us) =
            t.latencies_us.lock().unwrap().stats();
        TenantSnapshot {
            tenant: id.as_str().to_string(),
            requests: t.requests.load(Ordering::Relaxed),
            rows: t.rows.load(Ordering::Relaxed),
            errors: t.errors.load(Ordering::Relaxed),
            rejected: t.rejected.load(Ordering::Relaxed),
            cancelled: t.cancelled.load(Ordering::Relaxed),
            timed_out: t.timed_out.load(Ordering::Relaxed),
            p50_us,
            p95_us,
            p99_us,
            max_us,
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let (p50_us, p95_us, p99_us, max_us) =
            self.latencies_us.lock().unwrap().stats();
        let mut tenants: Vec<TenantSnapshot> = self
            .tenants
            .read()
            .unwrap()
            .iter()
            .map(|(id, t)| Self::snap_tenant(id, t))
            .collect();
        tenants.sort_by(|a, b| a.tenant.cmp(&b.tenant));
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            rows: self.rows.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            pjrt_batches: self.pjrt_batches.load(Ordering::Relaxed),
            cpu_batches: self.cpu_batches.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            timed_out: self.timed_out.load(Ordering::Relaxed),
            p50_us,
            p95_us,
            p99_us,
            max_us,
            tenants,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_percentiles() {
        let m = Metrics::default();
        for i in 1..=100u64 {
            m.record_request(10, Duration::from_micros(i));
        }
        m.record_batch(true);
        m.record_batch(false);
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.rows, 1000);
        assert_eq!(s.pjrt_batches, 1);
        assert_eq!(s.cpu_batches, 1);
        assert!((s.p50_us - 50.5).abs() < 1.0);
        assert!(s.p99_us >= 99.0 && s.max_us == 100.0);
        assert!(s.tenants.is_empty(), "no tenant-attributed traffic");
    }

    #[test]
    fn reservoir_stays_bounded() {
        let m = Metrics::default();
        for i in 0..(RESERVOIR + 100) as u64 {
            m.record_request(1, Duration::from_micros(i % 500));
        }
        assert!(m.latencies_us.lock().unwrap().samples.len() <= RESERVOIR);
    }

    #[test]
    fn reservoir_keeps_both_modes_of_a_bimodal_stream() {
        // Regression: the value-keyed overwrite slot
        // (`as_nanos() % RESERVOIR`) mapped each distinct latency to
        // one fixed slot, so a long bimodal stream degenerated to two
        // live slots and 65k stale ones. Uniform sampling must retain
        // both modes in roughly their stream proportions.
        let m = Metrics::default();
        let total = 3 * RESERVOIR as u64;
        for i in 0..total {
            let us = if i % 2 == 0 { 100 } else { 10_000 };
            m.record_request(1, Duration::from_micros(us));
        }
        let (lows, highs) = {
            let r = m.latencies_us.lock().unwrap();
            (
                r.samples.iter().filter(|&&v| v == 100).count(),
                r.samples.iter().filter(|&&v| v == 10_000).count(),
            )
        };
        assert_eq!(lows + highs, RESERVOIR, "reservoir holds only stream values");
        let frac = lows as f64 / RESERVOIR as f64;
        assert!(
            (0.45..=0.55).contains(&frac),
            "sampled low-mode fraction {frac} should match the 50/50 stream"
        );
        let s = m.snapshot();
        assert!(
            s.p99_us > 9_999.0,
            "slow mode must be visible in tail percentiles, p99 {}",
            s.p99_us
        );
        assert!(
            (100.0..=10_000.0).contains(&s.p50_us),
            "p50 sits at the mode boundary, got {}",
            s.p50_us
        );
    }

    #[test]
    fn tenant_attribution_feeds_both_views() {
        let m = Metrics::default();
        let a = TenantId::new("a");
        let b = TenantId::new("b");
        for i in 1..=10u64 {
            m.record_request_for(&a, 4, Duration::from_micros(100 * i));
        }
        m.record_request_for(&b, 2, Duration::from_micros(5));
        m.record_error_for(&b);
        let s = m.snapshot();
        assert_eq!(s.requests, 11, "aggregate includes every tenant");
        assert_eq!(s.rows, 42);
        assert_eq!(s.errors, 1);
        assert_eq!(s.tenants.len(), 2);
        assert_eq!(s.tenants[0].tenant, "a", "sorted by name");
        assert_eq!(s.tenants[0].requests, 10);
        assert_eq!(s.tenants[0].rows, 40);
        assert_eq!(s.tenants[0].rejected, 0);
        assert!(s.tenants[0].p50_us >= 100.0);
        assert_eq!(s.tenants[1].tenant, "b");
        assert_eq!(s.tenants[1].errors, 1);
        assert_eq!(s.tenants[1].max_us, 5.0);
        let only_a = m.tenant_snapshot(&a).unwrap();
        assert_eq!(only_a.requests, 10);
        assert!(m.tenant_snapshot(&TenantId::new("nobody")).is_none());
    }

    #[test]
    fn rejections_count_without_touching_any_reservoir() {
        // The isolation contract: an over-quota tenant shedding load
        // must not move any percentile — its own or anyone else's.
        let m = Metrics::default();
        let victim = TenantId::new("victim");
        let noisy = TenantId::new("noisy");
        for i in 1..=100u64 {
            m.record_request_for(&victim, 1, Duration::from_micros(i));
        }
        let before = m.tenant_snapshot(&victim).unwrap();
        for _ in 0..10_000 {
            m.record_rejection(&noisy);
        }
        let after = m.tenant_snapshot(&victim).unwrap();
        assert_eq!(before.p50_us, after.p50_us);
        assert_eq!(before.p99_us, after.p99_us);
        assert_eq!(before.max_us, after.max_us);
        assert_eq!(before.requests, after.requests);
        let noisy_snap = m.tenant_snapshot(&noisy).unwrap();
        assert_eq!(noisy_snap.rejected, 10_000);
        assert_eq!(noisy_snap.requests, 0);
        assert_eq!(noisy_snap.p99_us, 0.0, "rejections carry no latency");
        // and the aggregate reservoir saw nothing from the rejections
        assert_eq!(m.snapshot().requests, 100);
    }

    #[test]
    fn cancelled_and_timed_out_are_counters_only() {
        let m = Metrics::default();
        let t = TenantId::new("flaky");
        m.record_request_for(&t, 2, Duration::from_micros(9));
        m.record_cancelled_for(&t);
        m.record_cancelled_for(&t);
        m.record_timed_out_for(&t);
        let s = m.snapshot();
        assert_eq!(s.cancelled, 2);
        assert_eq!(s.timed_out, 1);
        assert_eq!(s.requests, 1, "drops are not served requests");
        let ts = m.tenant_snapshot(&t).unwrap();
        assert_eq!(ts.cancelled, 2);
        assert_eq!(ts.timed_out, 1);
        assert_eq!(ts.requests, 1);
        assert_eq!(ts.max_us, 9.0, "reservoir holds only the served request");
    }

    #[test]
    fn tenant_metric_tables_fold_into_overflow_past_the_cap() {
        // client-chosen names must not grow the table forever: past the
        // cap, traffic is still accounted — under the shared overflow
        // entry
        let m = Metrics::default();
        for i in 0..MAX_TENANT_TABLES {
            m.record_rejection(&TenantId::new(&format!("t{i}")));
        }
        m.record_request_for(&TenantId::new("late"), 3, Duration::from_micros(7));
        m.record_rejection(&TenantId::new("later"));
        let s = m.snapshot();
        assert!(s.tenants.len() <= MAX_TENANT_TABLES + 1);
        let overflow = s
            .tenants
            .iter()
            .find(|t| t.tenant == OVERFLOW_TENANT)
            .expect("overflow entry exists");
        assert_eq!(overflow.requests, 1);
        assert_eq!(overflow.rows, 3);
        assert_eq!(overflow.rejected, 1);
        assert!(
            m.tenant_snapshot(&TenantId::new("late")).is_none(),
            "no per-name entry past the cap"
        );
    }

    #[test]
    fn tenant_reservoirs_stay_bounded() {
        let m = Metrics::default();
        let t = TenantId::new("firehose");
        for i in 0..(TENANT_RESERVOIR + 50) as u64 {
            m.record_request_for(&t, 1, Duration::from_micros(i));
        }
        let map = m.tenants.read().unwrap();
        let tm = map.get(&t).unwrap();
        assert!(tm.latencies_us.lock().unwrap().samples.len() <= TENANT_RESERVOIR);
    }
}
