//! Per-warp cycle accounting for the RTop-K kernel and the RadixSelect
//! baseline, following each algorithm's actual instruction stream.

use crate::simt::cost::{CostModel, StageCycles};

/// One warp's estimated execution of a kernel over one row.
#[derive(Clone, Copy, Debug)]
pub struct KernelEstimate {
    pub stages: StageCycles,
    /// shared-memory footprint in f32 elements per warp
    pub smem_f32: usize,
}

const W: f64 = 32.0; // lanes per warp

/// RTop-K kernel (Fig. 3): load M elements to shared memory, run
/// `iters` binary-search iterations (each: per-lane count over M/32
/// elements + log2(32) shuffle reduction + broadcast), then a two-pass
/// ballot/popc selection writing k results.
///
/// `iters` should come from the measured/expected iteration count
/// (Tables 1/5, or `stats::expected_iterations`) for exact mode, or be
/// the `max_iter` setting for early stopping.
pub fn simulate_rtopk_row(m: usize, k: usize, iters: f64,
                          c: &CostModel) -> KernelEstimate {
    let mf = m as f64;
    let per_lane = (mf / W).ceil();

    // Loading: M/32 coalesced gmem reads + same smem writes + barrier
    let load = per_lane * (c.gmem_txn + c.smem_txn) + c.sync;

    // min/max initial reduction: per-lane scan + 2 * log2(32) shuffles
    let minmax = per_lane * (c.smem_txn + 2.0 * c.alu)
        + 2.0 * 5.0 * c.shfl;

    // each search iteration: threshold ALU + per-lane smem scan with
    // compare+add + log2(32) shuffle reduction + bracket-update ALU
    let per_iter = 2.0 * c.alu
        + per_lane * (c.smem_txn + 2.0 * c.alu)
        + 5.0 * c.shfl
        + 3.0 * c.alu;
    let search = minmax + iters * per_iter;

    // selection: up to two passes; each pass scans per-lane elements,
    // one ballot+popc per 32-element group, prefix ALU, and the winners
    // write (k outputs -> k/32 coalesced transactions, x2 for val+idx)
    let groups = per_lane; // one 32-wide group per per-lane element
    let pass = per_lane * (c.smem_txn + c.alu) + groups * (c.ballot + 2.0 * c.alu);
    let writes = 2.0 * (k as f64 / W).ceil() * c.gmem_txn;
    // expected 1.3 passes: pass 2 only runs when supplements are needed
    let select = 1.3 * pass + writes;

    KernelEstimate {
        stages: StageCycles { load, search, select },
        smem_f32: m,
    }
}

/// Warps `torch.topk` dedicates to one row: its generic RadixSelect is a
/// block-per-row kernel (256 threads), sized for the ~2^20-element
/// vectors it was designed for (§2.3). At M=256 each warp touches only
/// 32 elements per pass but still occupies SM residency for the whole
/// block — the resource waste the paper's warp-per-row design removes.
pub const TORCH_BLOCK_WARPS: f64 = 8.0;

/// Fixed per-block wall-cycle overhead of the torch.topk path:
/// kernel-launch amortization, index-tensor setup, and histogram
/// zeroing ("initialization, histogram construction, and indexing
/// overhead" — Appendix B's explanation of why RadixSelect's relative
/// efficiency *improves* with M).
pub const TORCH_FIXED_OVERHEAD: f64 = 100.0;

/// Per-row RadixSelect as `torch.topk` performs it: a 256-thread block
/// per row runs 4 MSD digit passes, each streaming the row from global
/// memory (the generic kernel cannot assume the row fits shared memory)
/// into a shared 256-bin histogram merged across the block's warps, then
/// a collect pass and a k-element output sort (PyTorch returns sorted
/// values).
///
/// Returned cycles are **resource-cycles** (wall cycles x warps
/// occupied), the unit `occupancy::kernel_time_ms` divides by the
/// device's warp slots — this is what makes the block-per-row waste
/// visible in throughput, exactly as on real hardware.
pub fn simulate_radix_row(m: usize, k: usize, c: &CostModel) -> KernelEstimate {
    let mf = m as f64;
    let wb = TORCH_BLOCK_WARPS;
    // elements each of the block's lanes handles per pass
    let per_lane = (mf / (W * wb)).ceil();

    // no staging stage: passes stream gmem directly (wall cycles)
    let load_wall = TORCH_FIXED_OVERHEAD;

    // each pass: strided gmem scan + shift/mask ALU + smem histogram
    // update (atomic ~ 2x smem) + block-wide 256-bin scan + block sync
    let hist_scan = (256.0 / (W * wb)).ceil() * (c.smem_txn + c.alu)
        + 5.0 * c.shfl;
    let per_pass_wall = per_lane * (c.gmem_txn + 3.0 * c.alu + 2.0 * c.smem_txn)
        + hist_scan
        + 2.0 * c.sync; // block barrier costs more than a warp sync
    let search_wall = 4.0 * per_pass_wall;

    // collect pass + k-element sort + sorted writes (wall cycles)
    let collect_wall = per_lane * (c.gmem_txn + c.alu)
        + per_lane * (c.ballot + 2.0 * c.alu);
    let kf = k as f64;
    let log2k = kf.log2().ceil().max(1.0);
    let sort_wall = (kf / W).ceil() * log2k * (log2k + 1.0) / 2.0
        * (3.0 * c.alu + c.shfl);
    let writes_wall = 2.0 * (kf / W).ceil() * c.gmem_txn;
    let select_wall = collect_wall + sort_wall + writes_wall;

    // resource-cycles: the whole block is resident for the row
    KernelEstimate {
        stages: StageCycles {
            load: load_wall * wb,
            search: search_wall * wb,
            select: select_wall * wb,
        },
        smem_f32: 256, // histogram only; the row itself streams
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const C: CostModel = CostModel::A6000;

    #[test]
    fn rtopk_scales_linearly_in_m() {
        let a = simulate_rtopk_row(256, 32, 9.0, &C).stages.total();
        let b = simulate_rtopk_row(512, 32, 9.0, &C).stages.total();
        assert!(b > 1.7 * a && b < 2.3 * a, "a={a} b={b}");
    }

    #[test]
    fn rtopk_search_grows_with_iters()  {
        let a = simulate_rtopk_row(256, 32, 2.0, &C);
        let b = simulate_rtopk_row(256, 32, 8.0, &C);
        assert!(b.stages.search > a.stages.search * 2.0);
        assert_eq!(a.stages.load, b.stages.load);
    }

    #[test]
    fn rtopk_beats_radix_at_small_m() {
        // the paper's core claim, in cycle terms, at M=256, k=32
        let r = simulate_rtopk_row(256, 32, 9.6, &C).stages.total();
        let p = simulate_radix_row(256, 32, &C).stages.total();
        let speedup = p / r;
        assert!(speedup > 1.5, "cycle speedup {speedup}");
    }

    #[test]
    fn gap_narrows_as_m_grows() {
        // Appendix B / Fig. 6: relative advantage decreases with M
        let s = |m: usize| {
            simulate_radix_row(m, 64, &C).stages.total()
                / simulate_rtopk_row(m, 64, (m as f64).log2() + 3.0, &C)
                    .stages
                    .total()
        };
        let s256 = s(256);
        let s2048 = s(2048);
        let s8192 = s(8192);
        assert!(s256 > s2048 && s2048 > s8192,
                "speedups {s256:.2} {s2048:.2} {s8192:.2} not decreasing");
    }

    #[test]
    fn smem_footprint_tracks_m() {
        assert_eq!(simulate_rtopk_row(768, 16, 5.0, &C).smem_f32, 768);
    }
}
