//! End-to-end smoke: all three layers composed — graph generation (L3
//! substrate) -> AOT train step (L2 model + L1 kernel) -> coordinator
//! training loop -> the served top-k of trained activations. This is
//! the test-suite twin of `examples/gnn_training.rs`.

use rtopk::config::{BackendConfig, ServeConfig};
use rtopk::coordinator::{SubmitRequest, TopKService, Trainer};
use rtopk::runtime::executor::Executor;
use rtopk::topk::types::Mode;
use rtopk::topk::verify::is_exact;
use rtopk::util::matrix::RowMatrix;
use rtopk::util::rng::Rng;

fn artifacts_dir() -> String {
    std::env::var("RTOPK_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

fn have_artifacts() -> bool {
    std::path::Path::new(&artifacts_dir()).join("manifest.json").exists()
}

#[test]
fn train_then_serve_composes() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // phase 1: train a tiny MaxK-GNN through PJRT
    let exec = Executor::spawn(&artifacts_dir()).unwrap();
    let mut trainer =
        Trainer::new(exec.handle(), "gcn_tiny-sim_h256_k32_es4", 11).unwrap();
    let out = trainer.train(25, 0, |_, _, _| {}).unwrap();
    assert!(out.losses.last().unwrap() < out.losses.first().unwrap());
    drop(exec);

    // phase 2: serve top-k requests (PJRT tiles + CPU fallback mixed).
    // The backend is pinned so the accelerator path is exercised
    // deterministically; adaptive selection would use PJRT only where
    // it measures faster than the CPU engine on this host.
    let svc = TopKService::start(&ServeConfig {
        artifacts_dir: artifacts_dir(),
        workers: 2,
        backend: BackendConfig {
            force: Some("pjrt".into()),
            ..BackendConfig::default()
        },
        ..Default::default()
    })
    .unwrap();
    let mut rng = Rng::seed_from(3);
    let routed = RowMatrix::random_normal(600, 256, &mut rng);
    let fallback = RowMatrix::random_normal(60, 80, &mut rng);
    let r1 = svc
        .submit_ticket(SubmitRequest::new(routed.clone(), 32).mode(Mode::EXACT))
        .unwrap();
    let r2 = svc
        .submit_ticket(SubmitRequest::new(fallback.clone(), 8).mode(Mode::EXACT))
        .unwrap();
    assert!(is_exact(&routed, &r1.wait().unwrap()));
    assert!(is_exact(&fallback, &r2.wait().unwrap()));
    let s = svc.stats();
    assert_eq!(s.requests, 2);
    assert!(s.pjrt_batches >= 1 && s.cpu_batches >= 1);
}
