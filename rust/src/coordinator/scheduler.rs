//! Scheduler: worker threads that pull batches from the batcher,
//! execute them through the planner-chosen execution backend, and
//! scatter per-request results back to reply channels.
//!
//! There is no routing logic here: the planner owns the backend choice
//! (`crate::plan`), the registry resolves the chosen id to a handle
//! (`crate::backend`), and this module only dispatches and delivers.
//! An accelerator backend that fails at execution time degrades to the
//! CPU engine instead of failing the batch.

use crate::backend::{registry::QUARANTINE_AFTER, BackendRegistry, CPU_BACKEND_ID};
use crate::coordinator::batcher::{Batch, Batcher};
use crate::coordinator::metrics::Metrics;
use crate::plan::Planner;
use crate::topk::rowwise::rowwise_topk;
use crate::topk::types::TopKResult;
use crate::util::matrix::RowMatrix;
use anyhow::{anyhow, Result};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

/// Reply slot carried through the batcher.
pub type Reply = mpsc::Sender<Result<TopKResult>>;

/// Spawn `workers` scheduler threads; they exit when the batcher closes.
/// Batches execute through the shared adaptive `planner` (plans are
/// cached per shape, so workers agree after the first batch of a
/// shape) against the backends in `backends`.
pub fn spawn_workers(
    workers: usize,
    batcher: Arc<Batcher<Reply>>,
    backends: Arc<BackendRegistry>,
    metrics: Arc<Metrics>,
    planner: Arc<Planner>,
) -> Vec<JoinHandle<()>> {
    (0..workers.max(1))
        .map(|i| {
            let batcher = batcher.clone();
            let backends = backends.clone();
            let metrics = metrics.clone();
            let planner = planner.clone();
            std::thread::Builder::new()
                .name(format!("topk-worker-{i}"))
                .spawn(move || {
                    while let Some(batch) = batcher.next_batch() {
                        run_batch(batch, &backends, &metrics, &planner);
                    }
                })
                .expect("spawn worker")
        })
        .collect()
}

/// Execute one batch through the plan's backend and deliver per-request
/// results.
pub fn run_batch(
    batch: Batch<Reply>,
    backends: &BackendRegistry,
    metrics: &Metrics,
    planner: &Planner,
) {
    let plan = planner.plan(batch.cols, batch.k, batch.mode);
    // a plan can only name a registered backend, but resolve
    // defensively; a backend that kept failing at runtime is
    // quarantined — its batches run on the CPU engine directly instead
    // of paying a doomed attempt (and a log line) per batch
    let mut backend = backends
        .get(&plan.backend)
        .unwrap_or_else(|| backends.cpu());
    if backends.is_quarantined(backend.id()) {
        backend = backends.cpu();
    }
    let spec = plan.spec();
    let mats: Vec<&RowMatrix> =
        batch.items.iter().map(|item| &item.matrix).collect();
    let mut via_accel = backend.id() != CPU_BACKEND_ID;
    let mut outcome = backend.execute(&spec, &mats, batch.k, batch.mode);
    if via_accel && outcome.is_err() {
        // accelerator misbehaved at runtime: degrade to the CPU engine
        // rather than failing every request in the batch. The failure
        // log is bounded — at most QUARANTINE_AFTER lines per backend
        // between successes — and a backend that keeps failing stops
        // being attempted at all.
        let msg = outcome
            .as_ref()
            .err()
            .map(|e| format!("{e:#}"))
            .unwrap_or_default();
        let fails = backends.note_failure(backend.id());
        if fails <= QUARANTINE_AFTER {
            eprintln!(
                "scheduler: backend {:?} failed ({msg}); batch falls back \
                 to cpu{}",
                backend.id(),
                if fails == QUARANTINE_AFTER {
                    " (quarantining backend until restart)"
                } else {
                    ""
                }
            );
        }
        via_accel = false;
        outcome = backends.cpu().execute(&spec, &mats, batch.k, batch.mode);
    } else if via_accel {
        backends.note_success(backend.id());
    }
    drop(mats);
    metrics.record_batch(via_accel);
    match outcome {
        Ok(results) => {
            for (item, res) in batch.items.into_iter().zip(results) {
                let latency = item.enqueued.elapsed();
                metrics.record_request(item.matrix.rows, latency);
                let _ = item.reply.send(Ok(res));
            }
        }
        Err(e) => {
            metrics.record_error();
            let msg = format!("{e:#}");
            for item in batch.items {
                let _ = item.reply.send(Err(anyhow!("{msg}")));
            }
        }
    }
}

/// Pad-free helper used by tests and the service's synchronous path.
pub fn run_direct_cpu(matrix: &RowMatrix, k: usize,
                      mode: crate::topk::types::Mode) -> TopKResult {
    rowwise_topk(matrix, k, mode)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{ExecBackend, ExecSpec};
    use crate::coordinator::batcher::BatchPolicy;
    use crate::topk::types::Mode;
    use crate::topk::verify::is_exact;
    use crate::util::rng::Rng;
    use std::time::Duration;

    #[test]
    fn cpu_pipeline_end_to_end() {
        let batcher: Arc<Batcher<Reply>> = Arc::new(Batcher::new(BatchPolicy {
            max_rows: 64,
            max_wait: Duration::from_millis(2),
            queue_limit: 4096,
        }));
        let backends = Arc::new(BackendRegistry::cpu_only());
        let metrics = Arc::new(Metrics::default());
        let planner = Arc::new(Planner::default());
        let workers =
            spawn_workers(2, batcher.clone(), backends, metrics.clone(), planner);

        let mut rng = Rng::seed_from(21);
        let mut rxs = Vec::new();
        let mut mats = Vec::new();
        for _ in 0..6 {
            let x = RowMatrix::random_normal(20, 32, &mut rng);
            let (tx, rx) = mpsc::channel();
            assert!(batcher.submit(x.clone(), 4, Mode::EXACT, tx));
            rxs.push(rx);
            mats.push(x);
        }
        for (rx, x) in rxs.into_iter().zip(&mats) {
            let res = rx.recv().unwrap().unwrap();
            assert_eq!(res.rows, 20);
            assert!(is_exact(x, &res));
        }
        batcher.close();
        for w in workers {
            w.join().unwrap();
        }
        let s = metrics.snapshot();
        assert_eq!(s.requests, 6);
        assert_eq!(s.rows, 120);
        assert!(s.batches >= 1);
        assert_eq!(s.errors, 0);
    }

    #[test]
    fn failing_accelerator_degrades_to_cpu_not_to_errors() {
        use crate::plan::PlannerConfig;
        use std::sync::atomic::{AtomicUsize, Ordering};

        struct Flaky {
            attempts: AtomicUsize,
        }
        impl ExecBackend for Flaky {
            fn id(&self) -> &str {
                "flaky"
            }
            fn describe(&self) -> String {
                "errors at execute".into()
            }
            fn supports(&self, _c: usize, _k: usize, _m: Mode) -> bool {
                true
            }
            fn execute(
                &self,
                _spec: &ExecSpec,
                _mats: &[&RowMatrix],
                _k: usize,
                _mode: Mode,
            ) -> Result<Vec<TopKResult>> {
                self.attempts.fetch_add(1, Ordering::SeqCst);
                Err(anyhow!("device fell off the bus"))
            }
        }

        let flaky = Arc::new(Flaky { attempts: AtomicUsize::new(0) });
        let mut registry = BackendRegistry::cpu_only();
        registry.register(flaky.clone());
        let backends = Arc::new(registry);
        // pin the batch to the flaky backend so the fallback path runs
        let planner = Arc::new(crate::plan::Planner::with_backends(
            PlannerConfig {
                force_backend: Some("flaky".into()),
                calib_rows: 0,
                ..PlannerConfig::default()
            },
            backends.clone(),
        ));
        let metrics = Arc::new(Metrics::default());

        let mut rng = Rng::seed_from(99);
        let x = RowMatrix::random_normal(12, 32, &mut rng);
        // run several batches: the first QUARANTINE_AFTER attempt the
        // backend and fall back; after that the backend is quarantined
        // and never even tried again
        let total_batches = QUARANTINE_AFTER + 2;
        for _ in 0..total_batches {
            let (tx, rx) = mpsc::channel();
            let batch = Batch {
                cols: 32,
                k: 4,
                mode: Mode::EXACT,
                total_rows: 12,
                items: vec![crate::coordinator::batcher::Pending {
                    matrix: x.clone(),
                    k: 4,
                    mode: Mode::EXACT,
                    enqueued: std::time::Instant::now(),
                    reply: tx,
                }],
            };
            run_batch(batch, &backends, &metrics, &planner);
            let res = rx.recv().unwrap().unwrap();
            assert!(is_exact(&x, &res), "fallback result must stay exact");
        }
        assert_eq!(
            flaky.attempts.load(Ordering::SeqCst) as u32,
            QUARANTINE_AFTER,
            "quarantined backend stops being attempted"
        );
        let s = metrics.snapshot();
        assert_eq!(s.errors, 0, "fallback is not a client error");
        assert_eq!(
            s.cpu_batches,
            total_batches as u64,
            "every batch is accounted to the cpu engine"
        );
    }
}
