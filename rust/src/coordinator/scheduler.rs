//! Scheduler: worker threads that pull batches from the batcher,
//! execute them (PJRT tile artifact via the router, or the CPU engine),
//! and scatter per-request results back to reply channels.

use crate::coordinator::batcher::{Batch, Batcher};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::router::{Route, Router};
use crate::plan::Planner;
use crate::runtime::executor::ExecutorHandle;
use crate::runtime::tensor::HostTensor;
use crate::topk::rowwise::{rowwise_topk, rowwise_topk_grained};
use crate::topk::types::TopKResult;
use crate::util::matrix::RowMatrix;
use anyhow::{anyhow, Result};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

/// Reply slot carried through the batcher.
pub type Reply = mpsc::Sender<Result<TopKResult>>;

/// Spawn `workers` scheduler threads; they exit when the batcher closes.
/// CPU-route batches execute through the shared adaptive `planner`
/// (plans are cached per shape, so workers agree after the first batch
/// of a shape).
pub fn spawn_workers(
    workers: usize,
    batcher: Arc<Batcher<Reply>>,
    router: Arc<Router>,
    executor: Option<ExecutorHandle>,
    metrics: Arc<Metrics>,
    planner: Arc<Planner>,
) -> Vec<JoinHandle<()>> {
    (0..workers.max(1))
        .map(|i| {
            let batcher = batcher.clone();
            let router = router.clone();
            let executor = executor.clone();
            let metrics = metrics.clone();
            let planner = planner.clone();
            std::thread::Builder::new()
                .name(format!("topk-worker-{i}"))
                .spawn(move || {
                    while let Some(batch) = batcher.next_batch() {
                        run_batch(
                            batch,
                            &router,
                            executor.as_ref(),
                            &metrics,
                            &planner,
                        );
                    }
                })
                .expect("spawn worker")
        })
        .collect()
}

/// Execute one batch and deliver per-request results.
pub fn run_batch(
    batch: Batch<Reply>,
    router: &Router,
    executor: Option<&ExecutorHandle>,
    metrics: &Metrics,
    planner: &Planner,
) {
    let route = router.route(batch.cols, batch.k, batch.mode);
    let outcome: Result<Vec<TopKResult>> = match (&route, executor) {
        (Route::Pjrt { artifact, rows }, Some(exec)) => {
            metrics.record_batch(true);
            run_batch_pjrt(&batch, artifact, *rows, exec)
        }
        _ => {
            metrics.record_batch(false);
            Ok(run_batch_cpu(&batch, planner))
        }
    };
    match outcome {
        Ok(results) => {
            for (item, res) in batch.items.into_iter().zip(results) {
                let latency = item.enqueued.elapsed();
                metrics.record_request(item.matrix.rows, latency);
                let _ = item.reply.send(Ok(res));
            }
        }
        Err(e) => {
            metrics.record_error();
            let msg = format!("{e:#}");
            for item in batch.items {
                let _ = item.reply.send(Err(anyhow!("{msg}")));
            }
        }
    }
}

/// Concatenate the batch's rows, pad to the tile size, run the artifact
/// (multiple tiles if the batch exceeds one), then scatter rows back.
fn run_batch_pjrt(
    batch: &Batch<Reply>,
    artifact: &str,
    tile_rows: usize,
    exec: &ExecutorHandle,
) -> Result<Vec<TopKResult>> {
    let cols = batch.cols;
    let k = batch.k;
    let total = batch.total_rows;
    // gather all rows into one contiguous buffer
    let mut all = Vec::with_capacity(total * cols);
    for item in &batch.items {
        all.extend_from_slice(&item.matrix.data);
    }
    // run tile by tile
    let mut values = vec![0f32; total * k];
    let mut indices = vec![0u32; total * k];
    let mut done = 0usize;
    while done < total {
        let take = tile_rows.min(total - done);
        let mut tile = vec![0f32; tile_rows * cols];
        tile[..take * cols]
            .copy_from_slice(&all[done * cols..(done + take) * cols]);
        let outs = exec.execute(
            artifact,
            vec![HostTensor::f32(tile, &[tile_rows, cols])],
        )?;
        // outputs: values (R,k) f32, indices (R,k) s32, mask (R,M) f32
        let v = outs[0].as_f32()?;
        let i = outs[1].as_i32()?;
        values[done * k..(done + take) * k]
            .copy_from_slice(&v[..take * k]);
        for (dst, &src) in indices[done * k..(done + take) * k]
            .iter_mut()
            .zip(&i[..take * k])
        {
            *dst = src as u32;
        }
        done += take;
    }
    // scatter back per request
    let mut results = Vec::with_capacity(batch.items.len());
    let mut offset = 0usize;
    for item in &batch.items {
        let r = item.matrix.rows;
        results.push(TopKResult {
            rows: r,
            k,
            values: values[offset * k..(offset + r) * k].to_vec(),
            indices: indices[offset * k..(offset + r) * k].to_vec(),
        });
        offset += r;
    }
    Ok(results)
}

/// CPU route: run the batch through the planner-selected engine. All
/// items share (cols, k, mode) by construction, so the plan is
/// resolved once per batch, not per item (one cached plan per shape —
/// cost-model prior plus one-time microbenchmark calibration; see
/// `crate::plan`).
fn run_batch_cpu(batch: &Batch<Reply>, planner: &Planner) -> Vec<TopKResult> {
    let plan = planner.plan(batch.cols, batch.k, batch.mode);
    batch
        .items
        .iter()
        .map(|item| {
            rowwise_topk_grained(&item.matrix, batch.k, plan.algo, plan.grain)
        })
        .collect()
}

/// Pad-free helper used by tests and the service's synchronous path.
pub fn run_direct_cpu(matrix: &RowMatrix, k: usize,
                      mode: crate::topk::types::Mode) -> TopKResult {
    rowwise_topk(matrix, k, mode)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::topk::types::Mode;
    use crate::topk::verify::is_exact;
    use crate::util::rng::Rng;
    use std::time::Duration;

    #[test]
    fn cpu_pipeline_end_to_end() {
        let batcher: Arc<Batcher<Reply>> = Arc::new(Batcher::new(BatchPolicy {
            max_rows: 64,
            max_wait: Duration::from_millis(2),
            queue_limit: 4096,
        }));
        let router = Arc::new(Router::default()); // empty -> CPU route
        let metrics = Arc::new(Metrics::default());
        let planner = Arc::new(Planner::default());
        let workers =
            spawn_workers(2, batcher.clone(), router, None, metrics.clone(), planner);

        let mut rng = Rng::seed_from(21);
        let mut rxs = Vec::new();
        let mut mats = Vec::new();
        for _ in 0..6 {
            let x = RowMatrix::random_normal(20, 32, &mut rng);
            let (tx, rx) = mpsc::channel();
            assert!(batcher.submit(x.clone(), 4, Mode::EXACT, tx));
            rxs.push(rx);
            mats.push(x);
        }
        for (rx, x) in rxs.into_iter().zip(&mats) {
            let res = rx.recv().unwrap().unwrap();
            assert_eq!(res.rows, 20);
            assert!(is_exact(x, &res));
        }
        batcher.close();
        for w in workers {
            w.join().unwrap();
        }
        let s = metrics.snapshot();
        assert_eq!(s.requests, 6);
        assert_eq!(s.rows, 120);
        assert!(s.batches >= 1);
        assert_eq!(s.errors, 0);
    }
}
