//! Simulated dataset registry — the Rust mirror of
//! `python/compile/datasets.py` (keep the two tables in sync; the AOT
//! manifest carries the python side's shapes and `runtime::ArtifactStore`
//! cross-checks them against this table at load time).
//!
//! | name          | stands for    | nodes  | avg deg | feat | classes |
//! |---------------|---------------|--------|---------|------|---------|
//! | flickr-sim    | Flickr        |  2048  |   10    | 128  |  7      |
//! | yelp-sim      | Yelp          |  3072  |   16    | 128  | 16      |
//! | reddit-sim    | Reddit        |  4096  |   32    | 128  | 16      |
//! | products-sim  | Ogbn-products |  5120  |   16    | 100  | 24      |
//! | tiny-sim      | (unit tests)  |   256  |    8    |  32  |  4      |

use crate::graph::csr::CsrGraph;
use crate::graph::generate::{sbm_graph, SbmParams};

/// Static shape spec of one simulated dataset (the AOT contract).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub stands_for: &'static str,
    pub num_nodes: usize,
    pub avg_degree: usize,
    pub feat_dim: usize,
    pub num_classes: usize,
}

impl DatasetSpec {
    pub const fn num_edges(&self) -> usize {
        self.num_nodes * self.avg_degree
    }
}

/// All registered datasets.
pub const ALL_DATASETS: &[DatasetSpec] = &[
    DatasetSpec { name: "tiny-sim", stands_for: "(tests)", num_nodes: 256, avg_degree: 8, feat_dim: 32, num_classes: 4 },
    DatasetSpec { name: "flickr-sim", stands_for: "Flickr", num_nodes: 2048, avg_degree: 10, feat_dim: 128, num_classes: 7 },
    DatasetSpec { name: "yelp-sim", stands_for: "Yelp", num_nodes: 3072, avg_degree: 16, feat_dim: 128, num_classes: 16 },
    DatasetSpec { name: "reddit-sim", stands_for: "Reddit", num_nodes: 4096, avg_degree: 32, feat_dim: 128, num_classes: 16 },
    DatasetSpec { name: "products-sim", stands_for: "Ogbn-products", num_nodes: 5120, avg_degree: 16, feat_dim: 100, num_classes: 24 },
];

/// Look up a dataset spec by name.
pub fn spec(name: &str) -> Option<&'static DatasetSpec> {
    ALL_DATASETS.iter().find(|d| d.name == name)
}

/// A fully materialized graph dataset in the edge-list layout the AOT
/// train/eval artifacts consume (padded edges would carry w = 0; the
/// generator emits exactly `num_edges` real edges so no padding is
/// needed, but the runtime supports it).
#[derive(Clone, Debug)]
pub struct GraphData {
    pub num_nodes: usize,
    pub feat_dim: usize,
    pub num_classes: usize,
    pub src: Vec<u32>,
    pub dst: Vec<u32>,
    pub weights: Vec<f32>,
    /// row-major (num_nodes, feat_dim)
    pub feats: Vec<f32>,
    pub labels: Vec<u32>,
    pub train_mask: Vec<f32>,
    pub val_mask: Vec<f32>,
    pub test_mask: Vec<f32>,
}

impl GraphData {
    /// CSR view (destination-indexed) for the CPU GNN substrate.
    pub fn to_csr(&self) -> CsrGraph {
        CsrGraph::from_edges(self.num_nodes, &self.src, &self.dst,
                             &self.weights)
    }

    /// Labels as i32 (the PJRT artifact ABI uses s32).
    pub fn labels_i32(&self) -> Vec<i32> {
        self.labels.iter().map(|&l| l as i32).collect()
    }

    pub fn src_i32(&self) -> Vec<i32> {
        self.src.iter().map(|&v| v as i32).collect()
    }

    pub fn dst_i32(&self) -> Vec<i32> {
        self.dst.iter().map(|&v| v as i32).collect()
    }
}

/// Materialize a registered dataset deterministically.
///
/// Aggregation-weight semantics per model are applied later by the
/// trainer (GCN uses these symmetric-norm weights as-is; SAGE rescales
/// to mean weights; GIN to unit weights — see `gnn::reweight`).
pub fn build(name: &str, seed: u64) -> Option<GraphData> {
    let d = spec(name)?;
    let p = SbmParams {
        num_nodes: d.num_nodes,
        num_edges: d.num_edges(),
        feat_dim: d.feat_dim,
        num_classes: d.num_classes,
        homophily: 0.6,
        signal: 1.5,
        train_frac: 0.5,
        val_frac: 0.2,
    };
    Some(sbm_graph(&p, seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_lookup() {
        assert!(spec("flickr-sim").is_some());
        assert!(spec("nope").is_none());
        assert_eq!(spec("reddit-sim").unwrap().avg_degree, 32);
    }

    #[test]
    fn build_matches_spec_shapes() {
        for d in ALL_DATASETS {
            if d.num_nodes > 1024 && d.name != "flickr-sim" {
                continue; // keep unit tests fast; covered by integration
            }
            let g = build(d.name, 42).unwrap();
            assert_eq!(g.num_nodes, d.num_nodes);
            assert_eq!(g.src.len(), d.num_edges());
            assert_eq!(g.feats.len(), d.num_nodes * d.feat_dim);
            assert_eq!(g.num_classes, d.num_classes);
        }
    }

    #[test]
    fn csr_roundtrip_degree_sum() {
        let g = build("tiny-sim", 1).unwrap();
        let csr = g.to_csr();
        let total: usize = (0..csr.num_nodes).map(|d| csr.degree(d)).sum();
        assert_eq!(total, g.src.len());
    }
}
