//! Integration: PJRT runtime against real artifacts.
//!
//! Requires `make artifacts` (the repo's default set). The key test is
//! the cross-language numeric check: the AOT-compiled Pallas kernel,
//! executed from Rust through PJRT, must agree decision-for-decision
//! with the in-crate Rust implementation of the same algorithm — the
//! two sides share only the semantics spec (kernels/ref.py docstring).

use rtopk::runtime::executor::Executor;
use rtopk::runtime::manifest::Manifest;
use rtopk::runtime::tensor::HostTensor;
use rtopk::topk::binary_search::rtopk_row;
use rtopk::topk::types::Mode;
use rtopk::util::matrix::RowMatrix;
use rtopk::util::rng::Rng;

fn artifacts_dir() -> String {
    std::env::var("RTOPK_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

fn have_artifacts() -> bool {
    std::path::Path::new(&artifacts_dir()).join("manifest.json").exists()
}

#[test]
fn manifest_loads_and_validates() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let m = Manifest::load(std::path::Path::new(&artifacts_dir())).unwrap();
    assert!(!m.of_kind("rtopk_tile").is_empty());
    assert!(!m.of_kind("train_step").is_empty());
    m.validate_datasets().unwrap();
}

#[test]
fn executor_spawns_and_reports_platform() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let exec = Executor::spawn(&artifacts_dir()).unwrap();
    let h = exec.handle();
    assert!(h.platform().to_lowercase().contains("cpu"));
    assert!(h.manifest().artifacts.len() >= 5);
}

/// The paper-critical equivalence: AOT Pallas kernel == Rust engine.
#[test]
fn pjrt_rtopk_tile_matches_rust_engine() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let exec = Executor::spawn(&artifacts_dir()).unwrap();
    let h = exec.handle();

    for (name, mode) in [
        ("rtopk_1024x256_k32_exact", Mode::Exact { eps_rel: 1e-16 }),
        ("rtopk_1024x256_k32_es4", Mode::EarlyStop { max_iter: 4 }),
        ("rtopk_1024x256_k32_es8", Mode::EarlyStop { max_iter: 8 }),
    ] {
        let info = h.manifest().get(name).unwrap();
        let rows = info.meta_usize("rows").unwrap();
        let m = info.meta_usize("m").unwrap();
        let k = info.meta_usize("k").unwrap();

        let mut rng = Rng::seed_from(777);
        let x = RowMatrix::random_normal(rows, m, &mut rng);
        let outs = h
            .execute(name, vec![HostTensor::f32(x.data.clone(), &[rows, m])])
            .unwrap();
        let vals = outs[0].as_f32().unwrap();
        let idx = outs[1].as_i32().unwrap();
        let mask = outs[2].as_f32().unwrap();

        let mut rvals = vec![0f32; k];
        let mut ridx = vec![0u32; k];
        for r in 0..rows {
            rtopk_row(x.row(r), k, mode, &mut rvals, &mut ridx);
            assert_eq!(
                &vals[r * k..(r + 1) * k],
                &rvals[..],
                "{name}: values differ at row {r}"
            );
            let got: Vec<u32> =
                idx[r * k..(r + 1) * k].iter().map(|&v| v as u32).collect();
            assert_eq!(got, ridx, "{name}: indices differ at row {r}");
            // mask has exactly k nonzeros and marks the selected columns
            let mrow = &mask[r * m..(r + 1) * m];
            assert_eq!(
                mrow.iter().filter(|&&v| v != 0.0).count(),
                k,
                "{name}: mask nonzeros at row {r}"
            );
            for &i in &ridx {
                assert!(mrow[i as usize] != 0.0, "{name}: mask misses idx {i}");
            }
        }
    }
}

#[test]
fn execute_rejects_shape_mismatch() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let exec = Executor::spawn(&artifacts_dir()).unwrap();
    let h = exec.handle();
    let err = h
        .execute(
            "rtopk_1024x256_k32_exact",
            vec![HostTensor::f32(vec![0.0; 10 * 256], &[10, 256])],
        )
        .unwrap_err();
    assert!(format!("{err:#}").contains("shape"), "got: {err:#}");
}

#[test]
fn execute_rejects_unknown_artifact() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let exec = Executor::spawn(&artifacts_dir()).unwrap();
    assert!(exec.handle().execute("nope", vec![]).is_err());
}

#[test]
fn precompile_then_execute_is_consistent() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let exec = Executor::spawn(&artifacts_dir()).unwrap();
    let h = exec.handle();
    h.precompile(&["rtopk_1024x256_k32_es4"]).unwrap();
    let x = HostTensor::f32(vec![1.0; 1024 * 256], &[1024, 256]);
    let a = h.execute("rtopk_1024x256_k32_es4", vec![x.clone()]).unwrap();
    let b = h.execute("rtopk_1024x256_k32_es4", vec![x]).unwrap();
    assert_eq!(a[0], b[0]);
    assert_eq!(a[1], b[1]);
}
