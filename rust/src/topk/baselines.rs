//! Baseline top-k algorithms the paper compares against or surveys
//! (§2.1/§2.3): RadixSelect (PyTorch's `torch.topk` underlying method),
//! QuickSelect, heap select, bucket select, bitonic top-k, full sort.
//!
//! All implementations are faithful to the algorithms' structure (the
//! point of the comparison is per-row *work*, not micro-tuning):
//! RadixSelect does MSD 8-bit digit passes over order-preserving u32
//! keys and — like `torch.topk` — returns its k results **sorted**;
//! RTop-K's results are unsorted, which is part of the paper's argument.

/// Reusable per-thread scratch buffers (allocation-free hot loop).
/// Arenas are grow-only: [`Scratch::ensure`] reserves for the largest
/// (M, k) shape seen on this thread and never shrinks, so steady-state
/// batches of recurring shapes perform zero allocations.
pub struct Scratch {
    pub keys: Vec<u32>,
    pub tmp_idx: Vec<u32>,
    pub pairs: Vec<(f32, u32)>,
    pub hist: [usize; 256],
}

/// Scratch allocation events (creates and grows) across all threads —
/// the dispatch-overhead bench and the arena tests use deltas of this
/// to prove the steady state allocates nothing.
static SCRATCH_ALLOCS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Total scratch-arena allocation events so far (process-wide,
/// monotone). A delta of zero across a window of batches means every
/// row ran out of pre-grown arenas.
pub fn scratch_allocs() -> u64 {
    SCRATCH_ALLOCS.load(std::sync::atomic::Ordering::Relaxed)
}

impl Scratch {
    /// An empty arena; buffers grow on first [`Scratch::ensure`]. Does
    /// not count as an allocation event.
    pub fn empty() -> Self {
        Scratch { keys: Vec::new(), tmp_idx: Vec::new(), pairs: Vec::new(), hist: [0; 256] }
    }

    pub fn new(m: usize, k: usize) -> Self {
        let mut s = Scratch::empty();
        s.ensure(m, k);
        s
    }

    /// Grow-only reserve for an (M, k) row shape: after this call the
    /// buffers hold at least the capacities `Scratch::new(m, k)` would
    /// have provided. Counts one allocation event if anything grew.
    pub fn ensure(&mut self, m: usize, _k: usize) {
        let mut grew = false;
        if self.keys.capacity() < m {
            self.keys.reserve(m - self.keys.len());
            grew = true;
        }
        if self.tmp_idx.capacity() < m {
            self.tmp_idx.reserve(m - self.tmp_idx.len());
            grew = true;
        }
        let pcap = m.next_power_of_two();
        if self.pairs.capacity() < pcap {
            self.pairs.reserve(pcap - self.pairs.len());
            grew = true;
        }
        if grew {
            SCRATCH_ALLOCS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
    }
}

/// A single-row top-k algorithm. Implementations may order their output
/// arbitrarily (RadixSelect/Sort return sorted-descending like PyTorch).
pub trait RowSelector {
    fn select_row(&self, row: &[f32], k: usize, vals: &mut [f32],
                  idx: &mut [u32], scratch: &mut Scratch);
}

/// Order-preserving map f32 -> u32: flip all bits of negatives, flip the
/// sign bit of non-negatives. After the map, unsigned comparison agrees
/// with the float's total order (the standard radix-select trick; this
/// is exactly what PyTorch's CUDA radix select does).
#[inline]
pub fn f32_to_ordered_u32(x: f32) -> u32 {
    let b = x.to_bits();
    if b & 0x8000_0000 != 0 {
        !b
    } else {
        b ^ 0x8000_0000
    }
}

/// Inverse of `f32_to_ordered_u32`.
#[inline]
pub fn ordered_u32_to_f32(u: u32) -> f32 {
    let b = if u & 0x8000_0000 != 0 {
        u ^ 0x8000_0000
    } else {
        !u
    };
    f32::from_bits(b)
}

// ---------------------------------------------------------------------------
// RadixSelect — the PyTorch baseline
// ---------------------------------------------------------------------------

/// MSD radix select over 8-bit digits: 4 histogram passes narrow the
/// k-th largest key's digit prefix; a final pass collects everything
/// above the threshold plus enough ties; results are sorted descending
/// (PyTorch's contract).
pub struct RadixSelect;

impl RowSelector for RadixSelect {
    fn select_row(&self, row: &[f32], k: usize, vals: &mut [f32],
                  idx: &mut [u32], scratch: &mut Scratch) {
        let m = row.len();
        debug_assert!(k >= 1 && k <= m);
        // build ordered keys
        scratch.keys.clear();
        scratch.keys.extend(row.iter().map(|&v| f32_to_ordered_u32(v)));
        let keys = &scratch.keys;

        // find the k-th largest key digit by digit (MSD first)
        let mut prefix: u32 = 0;
        let mut prefix_mask: u32 = 0;
        let mut remaining = k;
        for pass in 0..4 {
            let shift = 24 - 8 * pass;
            let hist = &mut scratch.hist;
            hist.fill(0);
            for &key in keys {
                if key & prefix_mask == prefix {
                    hist[((key >> shift) & 0xFF) as usize] += 1;
                }
            }
            // walk digits from high to low until `remaining` is covered
            let mut digit = 255usize;
            loop {
                let c = hist[digit];
                if c >= remaining {
                    break;
                }
                remaining -= c;
                if digit == 0 {
                    break;
                }
                digit -= 1;
            }
            prefix |= (digit as u32) << shift;
            prefix_mask |= 0xFFu32 << shift;
        }
        let kth_key = prefix; // full 32-bit key of the k-th largest element

        // collect: everything strictly above kth_key, then ties == kth_key
        let mut w = 0usize;
        for (j, &key) in keys.iter().enumerate() {
            if key > kth_key {
                vals[w] = row[j];
                idx[w] = j as u32;
                w += 1;
            }
        }
        for (j, &key) in keys.iter().enumerate() {
            if w == k {
                break;
            }
            if key == kth_key {
                vals[w] = row[j];
                idx[w] = j as u32;
                w += 1;
            }
        }
        debug_assert_eq!(w, k);
        // PyTorch returns sorted results — include the sort in the
        // baseline's work, as the paper's comparison does.
        sort_outputs_desc(vals, idx, k);
    }
}

// ---------------------------------------------------------------------------
// QuickSelect
// ---------------------------------------------------------------------------

/// Hoare-partition quickselect on (value, index) pairs: partitions until
/// the k largest occupy the front, then collects (unsorted).
pub struct QuickSelect;

impl RowSelector for QuickSelect {
    fn select_row(&self, row: &[f32], k: usize, vals: &mut [f32],
                  idx: &mut [u32], scratch: &mut Scratch) {
        let m = row.len();
        scratch.pairs.clear();
        scratch
            .pairs
            .extend(row.iter().enumerate().map(|(j, &v)| (v, j as u32)));
        let pairs = &mut scratch.pairs[..m];
        // iterative quickselect for the k-th position in descending order
        let (mut lo, mut hi) = (0usize, m);
        let mut state = 0x9E3779B97F4A7C15u64 ^ (m as u64);
        while hi - lo > 1 {
            // median-of-3-ish pivot with a cheap xorshift to defeat
            // adversarial layouts
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            let p = lo + (state as usize) % (hi - lo);
            let pivot = pairs[p].0;
            // 3-way partition descending: [> pivot | == pivot | < pivot]
            let (mut i, mut j, mut n) = (lo, lo, hi);
            while j < n {
                if pairs[j].0 > pivot {
                    pairs.swap(i, j);
                    i += 1;
                    j += 1;
                } else if pairs[j].0 < pivot {
                    n -= 1;
                    pairs.swap(j, n);
                } else {
                    j += 1;
                }
            }
            if k <= i {
                hi = i;
            } else if k <= j {
                break; // k-th position falls inside the == pivot run
            } else {
                lo = j;
            }
        }
        for (w, p) in pairs[..k].iter().enumerate() {
            vals[w] = p.0;
            idx[w] = p.1;
        }
    }
}

// ---------------------------------------------------------------------------
// Heap select
// ---------------------------------------------------------------------------

/// Streaming size-k min-heap: the classic CPU method (§2.1 notes it
/// parallelizes poorly on GPUs; included for completeness).
pub struct HeapSelect;

impl RowSelector for HeapSelect {
    fn select_row(&self, row: &[f32], k: usize, vals: &mut [f32],
                  idx: &mut [u32], _scratch: &mut Scratch) {
        // (value, index) min-heap laid out in the output buffers
        let mut size = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if size < k {
                vals[size] = v;
                idx[size] = j as u32;
                size += 1;
                if size == k {
                    // heapify
                    for i in (0..k / 2).rev() {
                        sift_down(vals, idx, i, k);
                    }
                }
            } else if v > vals[0] {
                vals[0] = v;
                idx[0] = j as u32;
                sift_down(vals, idx, 0, k);
            }
        }
        debug_assert_eq!(size, k);
    }
}

#[inline]
fn sift_down(vals: &mut [f32], idx: &mut [u32], mut i: usize, n: usize) {
    loop {
        let l = 2 * i + 1;
        let r = l + 1;
        let mut smallest = i;
        if l < n && vals[l] < vals[smallest] {
            smallest = l;
        }
        if r < n && vals[r] < vals[smallest] {
            smallest = r;
        }
        if smallest == i {
            return;
        }
        vals.swap(i, smallest);
        idx.swap(i, smallest);
        i = smallest;
    }
}

// ---------------------------------------------------------------------------
// Bucket select
// ---------------------------------------------------------------------------

/// Single-level bucket select: 256 equal-width buckets over `[min, max]`,
/// histogram pass finds the threshold bucket, collect pass emits
/// everything above it and supplements from inside it (recursing once
/// into the threshold bucket when it is badly skewed).
pub struct BucketSelect;

impl RowSelector for BucketSelect {
    fn select_row(&self, row: &[f32], k: usize, vals: &mut [f32],
                  idx: &mut [u32], scratch: &mut Scratch) {
        let m = row.len();
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in row {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if lo == hi {
            for w in 0..k {
                vals[w] = row[w];
                idx[w] = w as u32;
            }
            return;
        }
        let nb = 256usize;
        let scale = nb as f32 / (hi - lo);
        let hist = &mut scratch.hist;
        hist.fill(0);
        let bucket_of = |v: f32| -> usize {
            (((v - lo) * scale) as usize).min(nb - 1)
        };
        for &v in row {
            hist[bucket_of(v)] += 1;
        }
        // highest buckets cover k
        let mut remaining = k;
        let mut b = nb - 1;
        loop {
            if hist[b] >= remaining {
                break;
            }
            remaining -= hist[b];
            if b == 0 {
                break;
            }
            b -= 1;
        }
        // collect everything above bucket b, then the first `remaining`
        // elements of bucket b (value-threshold semantics like RTop-K's
        // borderline pass)
        let mut w = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if bucket_of(v) > b {
                vals[w] = v;
                idx[w] = j as u32;
                w += 1;
            }
        }
        if w < k {
            // order the threshold bucket's members to take the true top
            // `remaining` (one small sort — bucket population ~ m/nb)
            scratch.pairs.clear();
            for (j, &v) in row.iter().enumerate() {
                if bucket_of(v) == b {
                    scratch.pairs.push((v, j as u32));
                }
            }
            scratch
                .pairs
                .sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
            for p in scratch.pairs.iter().take(k - w) {
                vals[w] = p.0;
                idx[w] = p.1;
                w += 1;
            }
        }
        debug_assert_eq!(w, k);
        let _ = m;
    }
}

// ---------------------------------------------------------------------------
// Bitonic top-k
// ---------------------------------------------------------------------------

/// Bitonic top-k: pad to a power of two with -inf, run the full bitonic
/// sorting network, take the first k (Shanbhag et al. run partial
/// networks; the full network is the canonical upper bound and keeps
/// the implementation honest).
pub struct BitonicSelect;

impl RowSelector for BitonicSelect {
    fn select_row(&self, row: &[f32], k: usize, vals: &mut [f32],
                  idx: &mut [u32], scratch: &mut Scratch) {
        let m = row.len();
        let n = m.next_power_of_two();
        scratch.pairs.clear();
        scratch
            .pairs
            .extend(row.iter().enumerate().map(|(j, &v)| (v, j as u32)));
        scratch
            .pairs
            .resize(n, (f32::NEG_INFINITY, u32::MAX));
        let a = &mut scratch.pairs[..n];
        // bitonic sort, descending
        let mut size = 2;
        while size <= n {
            let mut stride = size / 2;
            while stride > 0 {
                for i in 0..n {
                    let partner = i ^ stride;
                    if partner > i {
                        let up = (i & size) == 0; // descending overall
                        let swap = if up {
                            a[i].0 < a[partner].0
                        } else {
                            a[i].0 > a[partner].0
                        };
                        if swap {
                            a.swap(i, partner);
                        }
                    }
                }
                stride /= 2;
            }
            size *= 2;
        }
        for w in 0..k {
            vals[w] = a[w].0;
            idx[w] = a[w].1;
        }
    }
}

// ---------------------------------------------------------------------------
// Full sort
// ---------------------------------------------------------------------------

/// Sort the whole row descending, take k — the simplest correct method
/// and the upper bound every select algorithm must beat.
pub struct SortSelect;

impl RowSelector for SortSelect {
    fn select_row(&self, row: &[f32], k: usize, vals: &mut [f32],
                  idx: &mut [u32], scratch: &mut Scratch) {
        scratch.pairs.clear();
        scratch
            .pairs
            .extend(row.iter().enumerate().map(|(j, &v)| (v, j as u32)));
        let pairs = &mut scratch.pairs;
        pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        for w in 0..k {
            vals[w] = pairs[w].0;
            idx[w] = pairs[w].1;
        }
    }
}

/// Sort (value, index) output buffers descending by value (PyTorch's
/// output contract for RadixSelect/Sort baselines).
fn sort_outputs_desc(vals: &mut [f32], idx: &mut [u32], k: usize) {
    // small-k insertion sort: k <= 128 in every experiment
    for i in 1..k {
        let (v, ix) = (vals[i], idx[i]);
        let mut j = i;
        while j > 0 && vals[j - 1] < v {
            vals[j] = vals[j - 1];
            idx[j] = idx[j - 1];
            j -= 1;
        }
        vals[j] = v;
        idx[j] = ix;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gens};

    #[test]
    fn ordered_u32_preserves_order() {
        let xs = [-1e30f32, -2.5, -0.0, 0.0, 1e-20, 3.5, 1e30];
        for w in xs.windows(2) {
            assert!(
                f32_to_ordered_u32(w[0]) <= f32_to_ordered_u32(w[1]),
                "{} vs {}",
                w[0],
                w[1]
            );
        }
        for &x in &xs {
            assert_eq!(ordered_u32_to_f32(f32_to_ordered_u32(x)).to_bits(), x.to_bits());
        }
    }

    fn oracle(row: &[f32], k: usize) -> Vec<f32> {
        let mut v = row.to_vec();
        v.sort_by(|a, b| b.partial_cmp(a).unwrap());
        v.truncate(k);
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }

    fn check_selector<S: RowSelector>(s: &S, name: &str) {
        forall(
            name,
            0xABCD,
            200,
            |rng| {
                let (m, k) = gens::m_and_k(rng, 96);
                (gens::any_row(rng, m), k)
            },
            |(row, k)| {
                let mut vals = vec![0.0f32; *k];
                let mut idx = vec![0u32; *k];
                let mut scratch = Scratch::new(row.len(), *k);
                s.select_row(row, *k, &mut vals, &mut idx, &mut scratch);
                // gathered + unique
                for (v, &i) in vals.iter().zip(&idx) {
                    if (i as usize) >= row.len() || *v != row[i as usize] {
                        return Err(format!("bad gather v={v} i={i}"));
                    }
                }
                let mut u = idx.clone();
                u.sort_unstable();
                u.dedup();
                if u.len() != *k {
                    return Err("duplicate indices".into());
                }
                let mut got = vals.clone();
                got.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let want = oracle(row, *k);
                if got != want {
                    return Err(format!("multiset:\n got {got:?}\nwant {want:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn radix_property() {
        check_selector(&RadixSelect, "radix == oracle");
    }

    #[test]
    fn quickselect_property() {
        check_selector(&QuickSelect, "quickselect == oracle");
    }

    #[test]
    fn heap_property() {
        check_selector(&HeapSelect, "heap == oracle");
    }

    #[test]
    fn bucket_property() {
        check_selector(&BucketSelect, "bucket == oracle");
    }

    #[test]
    fn bitonic_property() {
        check_selector(&BitonicSelect, "bitonic == oracle");
    }

    #[test]
    fn sort_property() {
        check_selector(&SortSelect, "sort == oracle");
    }

    #[test]
    fn radix_output_is_sorted_descending() {
        let row = [5.0f32, 1.0, 9.0, 3.0, 7.0, 2.0];
        let mut vals = vec![0.0; 4];
        let mut idx = vec![0u32; 4];
        let mut s = Scratch::new(6, 4);
        RadixSelect.select_row(&row, 4, &mut vals, &mut idx, &mut s);
        assert_eq!(vals, vec![9.0, 7.0, 5.0, 3.0]);
        assert_eq!(idx, vec![2, 4, 0, 3]);
    }

    #[test]
    fn heap_handles_k_equals_m() {
        let row = [2.0f32, 1.0, 3.0];
        let mut vals = vec![0.0; 3];
        let mut idx = vec![0u32; 3];
        let mut s = Scratch::new(3, 3);
        HeapSelect.select_row(&row, 3, &mut vals, &mut idx, &mut s);
        let mut got = vals.clone();
        got.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(got, vec![1.0, 2.0, 3.0]);
    }
}
