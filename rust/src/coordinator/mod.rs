//! The L3 coordinator: a row-wise top-k *service* and the MaxK-GNN
//! training orchestrator, built on the PJRT runtime.
//!
//! Serving path (quickstart -> production):
//!
//! ```text
//!   client threads ──submit()──▶ Batcher (deadline + backpressure)
//!                                  │ tiles of R rows, same (M, k, mode)
//!                                  ▼
//!                              Scheduler workers
//!                                  │ route: PJRT tile artifact (Router)
//!                                  │        or CPU fallback engine
//!                                  ▼
//!                              Executor thread (owns PJRT)
//! ```
//!
//! The router picks the compiled tile variant for a request's
//! (M, k, mode); requests with no matching artifact run on the in-crate
//! CPU engine so the service always answers. CPU batches go through the
//! adaptive execution planner (`crate::plan`): the fastest row
//! algorithm and work-unit grain per shape, decided once (cost-model
//! prior + microbenchmark calibration) and cached. The trainer drives
//! the AOT train/eval step artifacts with device-resident parameter
//! round-trips.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod scheduler;
pub mod service;
pub mod trainer;

pub use metrics::Metrics;
pub use router::{Route, Router};
pub use service::{ServiceStats, TopKRequest, TopKService};
pub use trainer::{TrainOutcome, Trainer};
