//! Per-connection protocol state machine for the `rtopk listen`
//! server: incremental decode → service submission → FIFO reply
//! delivery, with both buffers bounded.
//!
//! The machine is transport-agnostic (`Read`/`Write` + `WouldBlock`),
//! so unit tests drive it with in-memory cursors and the server drives
//! it with nonblocking `TcpStream`s. It is single-threaded by
//! construction — owned and driven only by the socket loop — which is
//! why it needs no locks at all; the concurrency lives in the service
//! behind [`TopKService::submit_ticket`] and is already model-checked
//! there.
//!
//! One subtlety worth naming: admission can block. A submit whose
//! tenant chose the Block over-quota policy, or one that hits the
//! batcher's global queue limit, parks the socket loop until space
//! frees — every connection stalls, which is TCP backpressure doing
//! its job, but deployments that need strict isolation should size
//! `[serve] queue_limit` above worst-case backlog and give noisy
//! tenants row quotas (those shed with a fast reject before the global
//! queue fills).

use crate::coordinator::wire::{
    self, Frame, FrameDecoder, ERR_PROTOCOL, ERR_REQUEST,
};
use crate::coordinator::{TopKService, TopKTicket};
use crate::net::{error_frame_bytes, NetStats};
use crate::topk::types::TopKResult;
use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::sync::Arc;

/// Per-connection buffer and concurrency caps (from `[net]`).
#[derive(Clone, Copy, Debug)]
pub struct ConnLimits {
    pub read_buf_bytes: usize,
    pub write_buf_bytes: usize,
    pub max_inflight: usize,
}

/// One owed reply, in submission order.
enum Slot {
    /// inside the service; resolves via `try_wait`
    InFlight(TopKTicket),
    /// already encoded (admission error, or a ticket that resolved
    /// while an earlier request was still pending)
    Ready(Vec<u8>),
}

/// Protocol state for one accepted connection.
pub struct Connection {
    svc: Arc<TopKService>,
    stats: Arc<NetStats>,
    limits: ConnLimits,
    decoder: FrameDecoder,
    /// replies owed to the client, FIFO — the Nth entry answers the
    /// Nth submit frame
    pending: VecDeque<Slot>,
    outbuf: Vec<u8>,
    outpos: usize,
    /// graceful teardown: flush `outbuf`, then close (set on protocol
    /// violations after the terminal error frame is queued)
    closing: bool,
    /// transport gone (EOF, reset): nothing more can be delivered
    dead: bool,
}

impl Connection {
    pub fn new(
        svc: Arc<TopKService>,
        stats: Arc<NetStats>,
        limits: ConnLimits,
    ) -> Connection {
        Connection {
            svc,
            stats,
            limits,
            decoder: FrameDecoder::new(),
            pending: VecDeque::new(),
            outbuf: Vec::new(),
            outpos: 0,
            closing: false,
            dead: false,
        }
    }

    fn outbuf_len(&self) -> usize {
        self.outbuf.len() - self.outpos
    }

    /// Whether the socket loop should keep READ interest: not tearing
    /// down, and neither buffer is at its cap. Pausing reads at the
    /// caps is the memory bound — the client's unread bytes stay in
    /// kernel buffers and TCP flow control takes over.
    pub fn wants_read(&self) -> bool {
        !self.closing
            && !self.dead
            && self.decoder.buffered() < self.limits.read_buf_bytes
            && self.outbuf_len() < self.limits.write_buf_bytes
    }

    /// Whether the socket loop should keep WRITE interest.
    pub fn wants_write(&self) -> bool {
        !self.dead && self.outbuf_len() > 0
    }

    /// Done: everything deliverable is delivered (or nothing ever will
    /// be). The server drops the connection when this turns true.
    pub fn finished(&self) -> bool {
        self.dead
            || (self.closing && self.outbuf_len() == 0 && self.pending.is_empty())
    }

    /// Readiness hint: pull bytes until `WouldBlock` (or a cap),
    /// decode, submit. Returns `false` when the transport died.
    pub fn on_readable(&mut self, io: &mut impl Read) -> bool {
        let mut chunk = [0u8; 16 * 1024];
        while self.wants_read() {
            match io.read(&mut chunk) {
                Ok(0) => {
                    self.transport_died();
                    break;
                }
                Ok(n) => {
                    self.decoder.feed(&chunk[..n]);
                    self.drain_decoder();
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.transport_died();
                    break;
                }
            }
        }
        !self.dead
    }

    /// Decode buffered frames while there is room to hold their
    /// replies. Frames past the in-flight cap stay undecoded in the
    /// read buffer until `pump` frees slots.
    fn drain_decoder(&mut self) {
        while !self.closing
            && self.pending.len() < self.limits.max_inflight
            && self.outbuf_len() < self.limits.write_buf_bytes
        {
            match self.decoder.next() {
                Ok(Some(frame)) => {
                    self.stats.frame_in();
                    self.handle_frame(frame);
                }
                Ok(None) => break,
                Err(e) => {
                    // framing is lost: one terminal error frame, then
                    // teardown (cancelling anything still in flight)
                    self.stats.decode_error();
                    self.fail_connection(
                        ERR_PROTOCOL,
                        &format!("undecodable frame: {e}"),
                    );
                    break;
                }
            }
        }
    }

    fn handle_frame(&mut self, frame: Frame) {
        match frame {
            Frame::Submit(req) => match self.svc.submit_ticket(req) {
                Ok(ticket) => self.pending.push_back(Slot::InFlight(ticket)),
                // admission refusals (quota, feasibility, validation,
                // recall floor) become positioned error frames in the
                // same FIFO slot a result would have used
                Err(e) => self.pending.push_back(Slot::Ready(
                    error_frame_bytes(ERR_REQUEST, &format!("{e:#}")),
                )),
            },
            // pings bypass the FIFO: a health probe must not wait
            // behind a deep submit backlog
            Frame::Ping(nonce) => {
                let pong = wire::encode_pong(nonce);
                self.queue_bytes(&pong);
            }
            Frame::Result(_) | Frame::Pong(_) | Frame::Error(_) => {
                self.fail_connection(
                    ERR_PROTOCOL,
                    "clients send submit (1) or ping (4) frames only",
                );
            }
        }
    }

    /// Queue an encoded frame onto the write buffer.
    fn queue_bytes(&mut self, bytes: &[u8]) {
        self.outbuf.extend_from_slice(bytes);
        self.stats.frame_out();
    }

    /// Terminal protocol failure: queue one error frame, cancel all
    /// in-flight work, flush, close.
    fn fail_connection(&mut self, code: u32, msg: &str) {
        let bytes = error_frame_bytes(code, msg);
        self.queue_bytes(&bytes);
        self.cancel_inflight();
        self.closing = true;
    }

    /// The transport is gone: nothing can be delivered, so every
    /// pending request is cancelled — quota and queue space must not
    /// stay pinned to a vanished peer.
    fn transport_died(&mut self) {
        self.dead = true;
        self.cancel_inflight();
    }

    fn cancel_inflight(&mut self) {
        for slot in &self.pending {
            if let Slot::InFlight(ticket) = slot {
                ticket.cancel();
            }
        }
        self.pending.clear();
    }

    /// Move resolved replies into the write buffer, strictly FIFO.
    /// Called every loop tick (completions arrive from worker threads,
    /// not from socket readiness). Freed slots may unblock decoding of
    /// already-buffered frames, so the decoder drains afterwards.
    pub fn pump(&mut self) {
        loop {
            if self.outbuf_len() >= self.limits.write_buf_bytes {
                break;
            }
            let bytes = match self.pending.front() {
                None => break,
                Some(Slot::Ready(_)) => match self.pending.pop_front() {
                    Some(Slot::Ready(b)) => b,
                    _ => unreachable!("front() said Ready"),
                },
                Some(Slot::InFlight(ticket)) => match ticket.try_wait() {
                    // the head is still running; later completions wait
                    // their turn (FIFO is the protocol contract)
                    None => break,
                    Some(outcome) => {
                        self.pending.pop_front();
                        encode_outcome(outcome)
                    }
                },
            };
            self.queue_bytes(&bytes);
        }
        if !self.closing && !self.dead {
            self.drain_decoder();
        }
    }

    /// Readiness hint: flush the write buffer until `WouldBlock` or
    /// empty. Returns `false` when the transport died.
    pub fn on_writable(&mut self, io: &mut impl Write) -> bool {
        while self.outpos < self.outbuf.len() {
            match io.write(&self.outbuf[self.outpos..]) {
                Ok(0) => {
                    self.transport_died();
                    break;
                }
                Ok(n) => self.outpos += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.transport_died();
                    break;
                }
            }
        }
        if self.outpos == self.outbuf.len() {
            self.outbuf.clear();
            self.outpos = 0;
        } else if self.outpos > 64 * 1024 {
            // reclaim the flushed prefix without waiting for a full
            // drain (a slow reader may never fully drain)
            self.outbuf.drain(..self.outpos);
            self.outpos = 0;
        }
        !self.dead
    }
}

impl Drop for Connection {
    /// Safety net: however the server discards a connection, its
    /// in-flight tickets get cancelled.
    fn drop(&mut self) {
        self.cancel_inflight();
    }
}

fn encode_outcome(
    outcome: anyhow::Result<TopKResult>,
) -> Vec<u8> {
    match outcome {
        Ok(res) => wire::encode_result(&res).unwrap_or_else(|e| {
            error_frame_bytes(
                ERR_REQUEST,
                &format!("result not encodable: {e}"),
            )
        }),
        Err(e) => error_frame_bytes(ERR_REQUEST, &format!("{e:#}")),
    }
}

#[cfg(all(test, not(rtopk_model_check)))]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::coordinator::wire::{decode, encode_ping, encode_request};
    use crate::coordinator::SubmitRequest;
    use crate::topk::verify::is_exact;
    use crate::util::matrix::RowMatrix;
    use crate::util::rng::Rng;

    fn small_limits() -> ConnLimits {
        ConnLimits {
            read_buf_bytes: 1 << 20,
            write_buf_bytes: 1 << 20,
            max_inflight: 8,
        }
    }

    fn test_service() -> Arc<TopKService> {
        let mut cfg = ServeConfig::default();
        cfg.workers = 1;
        cfg.max_wait_us = 0; // flush immediately: deterministic tests
        Arc::new(TopKService::cpu_only(&cfg).unwrap())
    }

    /// Drive the machine with in-memory buffers until all owed replies
    /// flushed (bounded spin: completions come from worker threads).
    fn run_to_quiescence(conn: &mut Connection, input: &[u8]) -> Vec<u8> {
        let mut cursor = std::io::Cursor::new(input.to_vec());
        assert!(conn.on_readable(&mut cursor));
        let mut out = Vec::new();
        for _ in 0..5000 {
            conn.pump();
            conn.on_writable(&mut out);
            if conn.pending.is_empty()
                && conn.outbuf_len() == 0
                && conn.decoder.buffered() == 0
            {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        out
    }

    #[test]
    fn submits_round_trip_in_fifo_order() {
        let svc = test_service();
        let stats = Arc::new(NetStats::default());
        let mut conn =
            Connection::new(svc, stats.clone(), small_limits());

        let mut rng = Rng::seed_from(7);
        let mats: Vec<RowMatrix> = (0..3)
            .map(|_| RowMatrix::random_normal(8, 32, &mut rng))
            .collect();
        let mut input = Vec::new();
        for m in &mats {
            input.extend_from_slice(
                &encode_request(&SubmitRequest::new(m.clone(), 4)).unwrap(),
            );
        }
        let out = run_to_quiescence(&mut conn, &input);

        let mut dec = FrameDecoder::new();
        dec.feed(&out);
        for m in &mats {
            match dec.next().unwrap().expect("a reply per submit") {
                Frame::Result(res) => {
                    assert_eq!(res.rows, 8);
                    assert_eq!(res.k, 4);
                    assert!(is_exact(m, &res));
                }
                other => panic!("wrong frame: {other:?}"),
            }
        }
        assert!(dec.next().unwrap().is_none(), "extra frames");
        assert_eq!(stats.gauges().frames_in, 3);
        assert_eq!(stats.gauges().frames_out, 3);
    }

    #[test]
    fn ping_answers_out_of_band_and_garbage_fails_the_connection() {
        let svc = test_service();
        let stats = Arc::new(NetStats::default());
        let mut conn = Connection::new(svc, stats.clone(), small_limits());

        let mut input = encode_ping(99);
        input.extend_from_slice(b"this is not a frame header at all!!");
        let out = run_to_quiescence(&mut conn, &input);

        let mut dec = FrameDecoder::new();
        dec.feed(&out);
        match dec.next().unwrap().expect("pong") {
            Frame::Pong(n) => assert_eq!(n, 99),
            other => panic!("wrong frame: {other:?}"),
        }
        match dec.next().unwrap().expect("terminal error frame") {
            Frame::Error(e) => {
                assert_eq!(e.code, ERR_PROTOCOL);
                assert!(e.msg.contains("undecodable"), "got: {}", e.msg);
            }
            other => panic!("wrong frame: {other:?}"),
        }
        assert!(conn.closing);
        assert!(conn.finished());
        assert_eq!(stats.gauges().decode_errors, 1);
    }

    #[test]
    fn invalid_request_is_answered_with_a_request_error_frame() {
        let svc = test_service();
        let stats = Arc::new(NetStats::default());
        let mut conn = Connection::new(svc, stats, small_limits());

        // k larger than cols: the service's admission refuses it
        let bad =
            SubmitRequest::new(RowMatrix::zeros(4, 8), 64);
        let input = encode_request(&bad).unwrap();
        let out = run_to_quiescence(&mut conn, &input);

        let mut dec = FrameDecoder::new();
        dec.feed(&out);
        match dec.next().unwrap().expect("error frame") {
            Frame::Error(e) => assert_eq!(e.code, ERR_REQUEST),
            other => panic!("wrong frame: {other:?}"),
        }
        // the connection survives: per-request errors are not fatal
        assert!(!conn.closing && !conn.dead);
    }

    #[test]
    fn eof_cancels_in_flight_tickets() {
        let mut cfg = ServeConfig::default();
        cfg.workers = 1;
        // park requests in the batcher so they are still in flight
        // when the EOF lands
        cfg.max_wait_us = 5_000_000;
        cfg.max_batch_rows = 1 << 30;
        let svc = Arc::new(TopKService::cpu_only(&cfg).unwrap());
        let stats = Arc::new(NetStats::default());
        let mut conn =
            Connection::new(svc.clone(), stats, small_limits());

        let req = SubmitRequest::new(RowMatrix::zeros(4, 16), 2);
        let mut input = encode_request(&req).unwrap();
        // half of a second frame: the disconnect happens mid-frame
        let partial = encode_request(&req).unwrap();
        input.extend_from_slice(&partial[..partial.len() / 2]);

        let mut cursor = std::io::Cursor::new(input);
        // reads the bytes, submits the complete frame, then hits EOF
        assert!(!conn.on_readable(&mut cursor));
        assert!(conn.finished());
        assert_eq!(conn.pending.len(), 0, "tickets dropped after cancel");
        // the cancel-hook evicted the queued request and counted it
        let snap = svc.load_snapshot();
        assert_eq!(snap.cancelled_total, 1);
        assert_eq!(snap.in_flight_rows, 0, "quota released");
    }
}
