//! Table 5: cumulative exit-iteration distribution of Algorithm 1 at
//! eps = 0 across (M, k) pairs, with the Appendix-A analytic E(n)
//! (Eq. 4) on the bottom rows — measurement vs theory.

use rtopk::bench::{exit_iteration_histogram, Table};
use rtopk::stats::expected_iterations;

fn main() {
    let quick = std::env::var("RTOPK_QUICK").is_ok();
    let trials = if quick { 2_000 } else { 4_000 };
    let cases: &[(usize, usize)] = &[
        (256, 64), (256, 128),
        (1024, 64), (1024, 128), (1024, 256), (1024, 512),
        (4096, 64), (4096, 128), (4096, 256), (4096, 512),
        (8192, 64), (8192, 128), (8192, 256), (8192, 512),
    ];
    // paper's measured Avg / E(n) rows for comparison
    let paper_avg = [8.72, 9.0, 9.53, 10.31, 10.87, 11.24, 10.07, 10.95,
                     11.73, 12.46, 10.3, 11.14, 12.02, 12.8];
    let paper_en = [9.08, 9.41, 9.87, 10.62, 11.24, 11.57, 10.36, 11.2,
                    12.0, 12.75, 10.54, 11.41, 12.26, 13.06];

    let mut header = vec!["Iters".to_string()];
    for (m, k) in cases {
        header.push(format!("{m}/{k}"));
    }
    let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        &format!("Table 5: cumulative exit % (eps=0, {trials} trials per case)"),
        &hrefs,
    );
    let hists: Vec<_> = cases
        .iter()
        .map(|&(m, k)| exit_iteration_histogram(m, k, 0.0, trials, (m * 31 + k) as u64))
        .collect();
    for it in (4..=24).step_by(2) {
        let mut row = vec![it.to_string()];
        for h in &hists {
            row.push(format!("{:.1}", h.cdf_at(it) * 100.0));
        }
        t.row(row);
    }
    let mut avg = vec!["Avg".to_string()];
    for h in &hists {
        avg.push(format!("{:.2}", h.mean()));
    }
    t.row(avg);
    let mut en = vec!["E(n)".to_string()];
    for &(m, k) in cases {
        en.push(format!("{:.2}", expected_iterations(m, k)));
    }
    t.row(en);
    let mut pa = vec!["paperAvg".to_string()];
    pa.extend(paper_avg.iter().map(|v| format!("{v:.2}")));
    t.row(pa);
    let mut pe = vec!["paperE(n)".to_string()];
    pe.extend(paper_en.iter().map(|v| format!("{v:.2}")));
    t.row(pe);
    t.print();
    println!("\nE(n) slightly exceeds the measured average (the paper observes the same:\n\
              the D ~ 2 sigma sqrt(2 ln M) initial-bracket estimate overshoots at finite M).");
}
