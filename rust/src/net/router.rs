//! `rtopk shard`: a frame router fanning client submits across N
//! worker processes that each run `rtopk listen` on the same protocol.
//!
//! ## Allocation
//!
//! Weight-aware rendezvous hashing. A tenant with WDRR weight *w*
//! (from `[tenants.<name>] weight`) is spread across its top
//! `min(w, alive_shards)` shards by rendezvous rank — heavier tenants
//! get more parallel capacity, lighter tenants stay sticky (warm plan
//! caches, fewer cross-shard moves) — and successive requests
//! round-robin inside that allocated set. Rendezvous ranking keeps
//! allocations stable when a shard dies: only the dead shard's slice
//! of traffic moves.
//!
//! ## Correlation
//!
//! Workers answer each connection's submits in FIFO order (the
//! protocol contract), so the router keeps one FIFO of
//! `(client, seq)` per upstream connection and matches replies by
//! position. Client replies are re-sequenced per client — a reply that
//! overtakes an earlier request routed to a slower shard waits in a
//! reorder buffer so each client still sees strict FIFO.
//!
//! ## Failure
//!
//! A dead shard (I/O failure, EOF, protocol violation, or
//! health-probe quarantine — see [`crate::net::health`]) fails every
//! request in flight on it with a **positioned** error frame naming
//! the shard and the request's position, never silence. The shard is
//! quarantined and the prober keeps retrying; a successful ping
//! restores it to the allocation pool.

use crate::config::NetConfig;
use crate::coordinator::wire::{
    self, Frame, FrameDecoder, ERR_OVERLOAD, ERR_PROTOCOL, ERR_SHARD_DOWN,
};
use crate::net::health::{spawn_prober, ShardTable};
use crate::net::reactor::{new_reactor, os_handle, Event, Reactor, READ, WRITE};
use crate::net::{error_frame_bytes, NetStats};
use crate::util::sync::atomic::{AtomicBool, Ordering};
use std::collections::{HashMap, VecDeque};
use std::io::{self, ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::AtomicU64;
use std::sync::Arc;
use std::time::Duration;

const TICK: Duration = Duration::from_millis(1);
const LISTENER_TOKEN: usize = 0;
/// Upstream shard i owns token `UP_BASE + i`; clients count up from 1.
const UP_BASE: usize = usize::MAX - (1 << 20);

/// Per-shard forwarding counters (observability; the bench's
/// per-shard JSON section reads these).
#[derive(Debug, Default)]
pub struct ShardCounters {
    pub forwarded: AtomicU64,
    pub shard_down_errors: AtomicU64,
}

/// A running shard router.
pub struct RouterHandle {
    addr: SocketAddr,
    stats: Arc<NetStats>,
    table: Arc<ShardTable>,
    counters: Arc<Vec<ShardCounters>>,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl RouterHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> Arc<NetStats> {
        self.stats.clone()
    }

    /// Per-shard `(addr, forwarded, shard_down_errors)` counters.
    pub fn shard_counters(&self) -> Vec<(String, u64, u64)> {
        self.table
            .addrs
            .iter()
            .zip(self.counters.iter())
            .map(|(a, c)| {
                (
                    a.clone(),
                    c.forwarded.load(Ordering::Relaxed),
                    c.shard_down_errors.load(Ordering::Relaxed),
                )
            })
            .collect()
    }

    /// Stop the loop and the health prober; join both.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Release);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }

    /// Block the calling thread for the router's lifetime.
    pub fn join(mut self) {
        if let Some(t) = self.threads.drain(..).next() {
            let _ = t.join();
        }
    }
}

/// FNV-1a, the rendezvous hash base. Stable across runs and platforms
/// (allocation must not depend on process-random hasher seeds).
fn fnv1a(bytes: &[u8], seed: u64) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ seed;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Rendezvous score of (tenant, shard).
fn rendezvous(tenant: &str, shard: &str) -> u64 {
    fnv1a(shard.as_bytes(), fnv1a(tenant.as_bytes(), 0))
}

/// Pick a shard for one request: rank the alive shards by rendezvous
/// score for this tenant, keep the top `min(weight, alive)` of them,
/// round-robin inside that set via `counter`. Pure — unit-tested
/// without sockets.
pub fn allocate_shard(
    tenant: &str,
    weight: u64,
    addrs: &[String],
    alive: &[bool],
    counter: u64,
) -> Option<usize> {
    let mut ranked: Vec<(u64, usize)> = addrs
        .iter()
        .enumerate()
        .filter(|&(i, _)| alive.get(i).copied().unwrap_or(false))
        .map(|(i, a)| (rendezvous(tenant, a), i))
        .collect();
    if ranked.is_empty() {
        return None;
    }
    ranked.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    let fan = (weight.max(1) as usize).min(ranked.len());
    Some(ranked[(counter % fan as u64) as usize].1)
}

/// One client connection's routing state.
struct Client {
    stream: TcpStream,
    decoder: FrameDecoder,
    /// next sequence number to assign to a submit
    next_seq: u64,
    /// next sequence number owed to the socket (FIFO contract)
    next_deliver: u64,
    /// replies that overtook an earlier in-flight request
    reorder: HashMap<u64, Vec<u8>>,
    outbuf: Vec<u8>,
    outpos: usize,
    closing: bool,
    dead: bool,
    interest: u8,
}

impl Client {
    fn new(stream: TcpStream) -> Client {
        Client {
            stream,
            decoder: FrameDecoder::new(),
            next_seq: 0,
            next_deliver: 0,
            reorder: HashMap::new(),
            outbuf: Vec::new(),
            outpos: 0,
            closing: false,
            dead: false,
            interest: READ,
        }
    }

    fn outbuf_len(&self) -> usize {
        self.outbuf.len() - self.outpos
    }

    fn inflight(&self) -> u64 {
        self.next_seq - self.next_deliver - self.reorder.len() as u64
    }

    /// Sequenced delivery: park the reply until its turn, then drain
    /// every consecutive reply that was waiting behind it.
    fn deliver(&mut self, seq: u64, bytes: Vec<u8>, stats: &NetStats) {
        self.reorder.insert(seq, bytes);
        while let Some(b) = self.reorder.remove(&self.next_deliver) {
            self.outbuf.extend_from_slice(&b);
            self.next_deliver += 1;
            stats.frame_out();
        }
    }

    fn wants_read(&self, limits: &Limits) -> bool {
        !self.closing
            && !self.dead
            && self.decoder.buffered() < limits.read_buf
            && self.outbuf_len() < limits.write_buf
            && (self.inflight() as usize) < limits.max_inflight
    }

    fn wants_write(&self) -> bool {
        !self.dead && self.outbuf_len() > 0
    }

    fn finished(&self) -> bool {
        self.dead || (self.closing && self.outbuf_len() == 0)
    }
}

/// One worker process the router multiplexes onto.
struct Upstream {
    addr: String,
    stream: Option<TcpStream>,
    decoder: FrameDecoder,
    outbuf: Vec<u8>,
    outpos: usize,
    /// submits forwarded and not yet answered, FIFO — workers answer
    /// per-connection in order, so position is the correlation key
    pending: VecDeque<(usize, u64)>,
}

impl Upstream {
    fn outbuf_len(&self) -> usize {
        self.outbuf.len() - self.outpos
    }
}

#[derive(Clone, Copy)]
struct Limits {
    read_buf: usize,
    write_buf: usize,
    max_inflight: usize,
    max_connections: usize,
    connect_timeout: Duration,
}

/// Bind the router and spawn its loop + health prober.
///
/// `weights` maps tenant name → WDRR weight (from
/// `config::TenantsConfig`); unknown tenants get weight 1.
pub fn serve_router(
    cfg: &NetConfig,
    weights: HashMap<String, u64>,
) -> io::Result<RouterHandle> {
    if cfg.shards.is_empty() {
        return Err(io::Error::new(
            ErrorKind::InvalidInput,
            "[net] shards is empty: the router needs at least one worker \
             address",
        ));
    }
    let listener = TcpListener::bind(&cfg.bind)?;
    listener.set_nonblocking(true)?;
    let addr = listener.local_addr()?;
    let stats = Arc::new(NetStats::default());
    let table = Arc::new(ShardTable::new(cfg.shards.clone()));
    let counters: Arc<Vec<ShardCounters>> = Arc::new(
        cfg.shards.iter().map(|_| ShardCounters::default()).collect(),
    );
    let stop = Arc::new(AtomicBool::new(false));

    let prober = spawn_prober(
        table.clone(),
        stats.clone(),
        Duration::from_millis(cfg.health_cadence_ms.max(1)),
        Duration::from_millis(cfg.health_timeout_ms.max(1)),
        stop.clone(),
    );
    let limits = Limits {
        read_buf: cfg.read_buf_bytes.max(1),
        write_buf: cfg.write_buf_bytes.max(1),
        max_inflight: cfg.max_inflight_per_conn.max(1),
        max_connections: cfg.max_connections.max(1),
        connect_timeout: Duration::from_millis(cfg.health_timeout_ms.max(1)),
    };
    let loop_ctx = (
        table.clone(),
        counters.clone(),
        stats.clone(),
        stop.clone(),
        weights,
    );
    let thread = std::thread::Builder::new()
        .name("rtopk-shard".to_string())
        .spawn(move || {
            let (table, counters, stats, stop, weights) = loop_ctx;
            router_loop(
                listener, table, counters, stats, stop, weights, limits,
            )
        })?;
    Ok(RouterHandle {
        addr,
        stats,
        table,
        counters,
        stop,
        threads: vec![thread, prober],
    })
}

/// Nonblocking read into a frame decoder, bounded by `cap` buffered
/// bytes. Returns `false` when the transport died (EOF or hard error).
fn pull(stream: &mut TcpStream, dec: &mut FrameDecoder, cap: usize) -> bool {
    let mut chunk = [0u8; 16 * 1024];
    while dec.buffered() < cap {
        match stream.read(&mut chunk) {
            Ok(0) => return false,
            Ok(n) => dec.feed(&chunk[..n]),
            Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    true
}

/// Nonblocking flush of an out-buffer. Returns `false` on transport
/// death; compacts the flushed prefix.
fn flush(stream: &mut TcpStream, outbuf: &mut Vec<u8>, outpos: &mut usize) -> bool {
    while *outpos < outbuf.len() {
        match stream.write(&outbuf[*outpos..]) {
            Ok(0) => return false,
            Ok(n) => *outpos += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    if *outpos == outbuf.len() {
        outbuf.clear();
        *outpos = 0;
    } else if *outpos > 64 * 1024 {
        outbuf.drain(..*outpos);
        *outpos = 0;
    }
    true
}

#[allow(clippy::too_many_arguments)]
fn router_loop(
    listener: TcpListener,
    table: Arc<ShardTable>,
    counters: Arc<Vec<ShardCounters>>,
    stats: Arc<NetStats>,
    stop: Arc<AtomicBool>,
    weights: HashMap<String, u64>,
    limits: Limits,
) {
    let mut reactor = new_reactor();
    if reactor
        .register(os_handle(&listener), LISTENER_TOKEN, READ)
        .is_err()
    {
        return;
    }
    let mut clients: HashMap<usize, Client> = HashMap::new();
    let mut next_token = LISTENER_TOKEN + 1;
    let mut upstreams: Vec<Upstream> = table
        .addrs
        .iter()
        .map(|a| Upstream {
            addr: a.clone(),
            stream: None,
            decoder: FrameDecoder::new(),
            outbuf: Vec::new(),
            outpos: 0,
            pending: VecDeque::new(),
        })
        .collect();
    let mut rr: HashMap<String, u64> = HashMap::new();
    let mut events: Vec<Event> = Vec::new();
    // (client, seq, frame bytes) replies produced this tick
    let mut deliveries: Vec<(usize, u64, Vec<u8>)> = Vec::new();

    while !stop.load(Ordering::Acquire) {
        if reactor.wait(TICK, &mut events).is_err() {
            break;
        }
        for ev in &events {
            if ev.token == LISTENER_TOKEN {
                accept_clients(
                    &listener,
                    &mut clients,
                    &mut next_token,
                    reactor.as_mut(),
                    &stats,
                    limits,
                );
            } else if ev.token >= UP_BASE {
                let idx = ev.token - UP_BASE;
                if idx >= upstreams.len() {
                    continue;
                }
                let up = &mut upstreams[idx];
                let mut died = false;
                if let Some(stream) = up.stream.as_mut() {
                    if ev.readable && !pull(stream, &mut up.decoder, limits.read_buf)
                    {
                        died = true;
                    }
                    if ev.writable
                        && !flush(stream, &mut up.outbuf, &mut up.outpos)
                    {
                        died = true;
                    }
                }
                if died {
                    fail_shard(
                        idx,
                        &mut upstreams[idx],
                        &table,
                        &counters,
                        reactor.as_mut(),
                        &mut deliveries,
                    );
                }
            } else if let Some(c) = clients.get_mut(&ev.token) {
                if ev.readable && !pull(&mut c.stream, &mut c.decoder, limits.read_buf)
                {
                    c.dead = true;
                }
                if ev.writable
                    && !flush(&mut c.stream, &mut c.outbuf, &mut c.outpos)
                {
                    c.dead = true;
                }
            }
        }

        // health-probe quarantine with an open upstream connection:
        // treat exactly like an observed I/O death so the shard's
        // in-flight requests get their positioned errors now
        let alive = table.alive();
        for idx in 0..upstreams.len() {
            if !alive[idx]
                && (upstreams[idx].stream.is_some()
                    || !upstreams[idx].pending.is_empty())
            {
                fail_shard(
                    idx,
                    &mut upstreams[idx],
                    &table,
                    &counters,
                    reactor.as_mut(),
                    &mut deliveries,
                );
            }
        }

        // decode upstream replies and correlate by FIFO position
        for idx in 0..upstreams.len() {
            let up = &mut upstreams[idx];
            if up.stream.is_none() {
                continue;
            }
            let mut broken = false;
            loop {
                match up.decoder.next_with_bytes() {
                    Ok(Some((frame, bytes))) => match frame {
                        Frame::Result(_) | Frame::Error(_) => {
                            match up.pending.pop_front() {
                                Some((tok, seq)) => {
                                    deliveries.push((tok, seq, bytes))
                                }
                                // a reply with nothing outstanding:
                                // the worker broke the FIFO contract
                                None => {
                                    broken = true;
                                    break;
                                }
                            }
                        }
                        _ => {
                            broken = true;
                            break;
                        }
                    },
                    Ok(None) => break,
                    Err(_) => {
                        stats.decode_error();
                        broken = true;
                        break;
                    }
                }
            }
            if broken {
                fail_shard(
                    idx,
                    &mut upstreams[idx],
                    &table,
                    &counters,
                    reactor.as_mut(),
                    &mut deliveries,
                );
            }
        }

        // decode client frames and route them
        let mut routed: Vec<(usize, u64, String, Vec<u8>)> = Vec::new();
        for (&tok, c) in clients.iter_mut() {
            loop {
                if c.closing
                    || c.dead
                    || (c.inflight() as usize) >= limits.max_inflight
                    || c.outbuf_len() >= limits.write_buf
                {
                    break;
                }
                match c.decoder.next_with_bytes() {
                    Ok(Some((frame, bytes))) => {
                        stats.frame_in();
                        match frame {
                            Frame::Submit(req) => {
                                let seq = c.next_seq;
                                c.next_seq += 1;
                                routed.push((
                                    tok,
                                    seq,
                                    req.tenant.as_str().to_string(),
                                    bytes,
                                ));
                            }
                            Frame::Ping(nonce) => {
                                c.outbuf
                                    .extend_from_slice(&wire::encode_pong(nonce));
                                stats.frame_out();
                            }
                            _ => {
                                c.outbuf.extend_from_slice(&error_frame_bytes(
                                    ERR_PROTOCOL,
                                    "clients send submit (1) or ping (4) \
                                     frames only",
                                ));
                                stats.frame_out();
                                c.closing = true;
                            }
                        }
                    }
                    Ok(None) => break,
                    Err(e) => {
                        stats.decode_error();
                        c.outbuf.extend_from_slice(&error_frame_bytes(
                            ERR_PROTOCOL,
                            &format!("undecodable frame: {e}"),
                        ));
                        stats.frame_out();
                        c.closing = true;
                    }
                }
            }
        }
        for (tok, seq, tenant, bytes) in routed {
            let alive = table.alive();
            let weight = weights.get(&tenant).copied().unwrap_or(1);
            let counter = rr.entry(tenant.clone()).or_insert(0);
            let pick =
                allocate_shard(&tenant, weight, &table.addrs, &alive, *counter);
            *counter += 1;
            match pick {
                None => deliveries.push((
                    tok,
                    seq,
                    error_frame_bytes(
                        ERR_SHARD_DOWN,
                        &format!(
                            "request #{seq}: no alive shard (all {} \
                             quarantined)",
                            table.addrs.len()
                        ),
                    ),
                )),
                Some(idx) => {
                    if ensure_connected(
                        idx,
                        &mut upstreams[idx],
                        &table,
                        reactor.as_mut(),
                        limits,
                    ) {
                        let up = &mut upstreams[idx];
                        up.outbuf.extend_from_slice(&bytes);
                        up.pending.push_back((tok, seq));
                        counters[idx].forwarded.fetch_add(1, Ordering::Relaxed);
                    } else {
                        counters[idx]
                            .shard_down_errors
                            .fetch_add(1, Ordering::Relaxed);
                        deliveries.push((
                            tok,
                            seq,
                            error_frame_bytes(
                                ERR_SHARD_DOWN,
                                &format!(
                                    "request #{seq}: shard {} is unreachable",
                                    table.addrs[idx]
                                ),
                            ),
                        ));
                    }
                }
            }
        }

        // hand replies (and failure frames) to their clients in
        // sequence order
        for (tok, seq, bytes) in deliveries.drain(..) {
            if let Some(c) = clients.get_mut(&tok) {
                // a vanished client's replies are dropped on the floor
                c.deliver(seq, bytes, &stats);
            }
        }

        // opportunistic flushes + interest maintenance
        for idx in 0..upstreams.len() {
            let up = &mut upstreams[idx];
            let mut died = false;
            if let Some(stream) = up.stream.as_mut() {
                if up.outpos < up.outbuf.len()
                    && !flush(stream, &mut up.outbuf, &mut up.outpos)
                {
                    died = true;
                }
            }
            if died {
                fail_shard(
                    idx,
                    &mut upstreams[idx],
                    &table,
                    &counters,
                    reactor.as_mut(),
                    &mut deliveries,
                );
                continue;
            }
            let up = &mut upstreams[idx];
            if let Some(stream) = up.stream.as_ref() {
                let want = READ
                    | (if up.outbuf_len() > 0 { WRITE } else { 0 });
                let _ = reactor.reregister(
                    os_handle(stream),
                    UP_BASE + idx,
                    want,
                );
            }
        }
        // late failure frames from the flush pass above
        for (tok, seq, bytes) in deliveries.drain(..) {
            if let Some(c) = clients.get_mut(&tok) {
                c.deliver(seq, bytes, &stats);
            }
        }
        let mut finished: Vec<usize> = Vec::new();
        for (&tok, c) in clients.iter_mut() {
            if c.wants_write() && !flush(&mut c.stream, &mut c.outbuf, &mut c.outpos)
            {
                c.dead = true;
            }
            if c.finished() {
                finished.push(tok);
                continue;
            }
            let want = (if c.wants_read(&limits) { READ } else { 0 })
                | (if c.wants_write() { WRITE } else { 0 });
            if want != c.interest
                && reactor
                    .reregister(os_handle(&c.stream), tok, want)
                    .is_ok()
            {
                c.interest = want;
            }
        }
        for tok in finished {
            if let Some(c) = clients.remove(&tok) {
                let _ = reactor.deregister(os_handle(&c.stream));
                stats.conn_closed();
            }
        }
    }
    for (_, c) in clients.drain() {
        let _ = reactor.deregister(os_handle(&c.stream));
        stats.conn_closed();
    }
    for up in &mut upstreams {
        if let Some(s) = up.stream.take() {
            let _ = reactor.deregister(os_handle(&s));
        }
    }
    let _ = reactor.deregister(os_handle(&listener));
}

fn accept_clients(
    listener: &TcpListener,
    clients: &mut HashMap<usize, Client>,
    next_token: &mut usize,
    reactor: &mut dyn Reactor,
    stats: &Arc<NetStats>,
    limits: Limits,
) {
    loop {
        match listener.accept() {
            Ok((mut stream, _)) => {
                if clients.len() >= limits.max_connections {
                    let bytes = error_frame_bytes(
                        ERR_OVERLOAD,
                        &format!(
                            "router at max_connections ({})",
                            limits.max_connections
                        ),
                    );
                    let _ = stream.write_all(&bytes);
                    continue;
                }
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                let _ = stream.set_nodelay(true);
                let token = *next_token;
                *next_token += 1;
                if reactor.register(os_handle(&stream), token, READ).is_err() {
                    continue;
                }
                clients.insert(token, Client::new(stream));
                stats.conn_opened();
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => break,
        }
    }
}

/// Lazily (re)connect an upstream. Blocking connect with the health
/// timeout as the bound: a short, rare stall when a shard first sees
/// traffic — after that the prober's quarantine keeps dead shards out
/// of the allocation pool entirely.
fn ensure_connected(
    idx: usize,
    up: &mut Upstream,
    table: &ShardTable,
    reactor: &mut dyn Reactor,
    limits: Limits,
) -> bool {
    if up.stream.is_some() {
        return true;
    }
    let sockaddr = match up
        .addr
        .to_socket_addrs()
        .ok()
        .and_then(|mut a| a.next())
    {
        Some(a) => a,
        None => {
            table.mark_dead(idx);
            return false;
        }
    };
    match TcpStream::connect_timeout(&sockaddr, limits.connect_timeout) {
        Ok(stream) => {
            if stream.set_nonblocking(true).is_err() {
                table.mark_dead(idx);
                return false;
            }
            let _ = stream.set_nodelay(true);
            if reactor
                .register(os_handle(&stream), UP_BASE + idx, READ)
                .is_err()
            {
                table.mark_dead(idx);
                return false;
            }
            up.decoder = FrameDecoder::new();
            up.outbuf.clear();
            up.outpos = 0;
            up.stream = Some(stream);
            true
        }
        Err(_) => {
            table.mark_dead(idx);
            false
        }
    }
}

/// A shard died: positioned error frames for everything in flight on
/// it, quarantine, and teardown of the multiplexed connection. The
/// prober's next successful ping restores the shard.
fn fail_shard(
    idx: usize,
    up: &mut Upstream,
    table: &ShardTable,
    counters: &[ShardCounters],
    reactor: &mut dyn Reactor,
    deliveries: &mut Vec<(usize, u64, Vec<u8>)>,
) {
    if let Some(stream) = up.stream.take() {
        let _ = reactor.deregister(os_handle(&stream));
    }
    table.mark_dead(idx);
    let total = up.pending.len();
    for (pos, (tok, seq)) in up.pending.drain(..).enumerate() {
        counters[idx].shard_down_errors.fetch_add(1, Ordering::Relaxed);
        deliveries.push((
            tok,
            seq,
            error_frame_bytes(
                ERR_SHARD_DOWN,
                &format!(
                    "request #{seq}: shard {} failed with the request in \
                     flight (position {} of {total} on that shard); the \
                     shard is quarantined until a health probe succeeds",
                    up.addr,
                    pos + 1,
                ),
            ),
        ));
    }
    up.decoder = FrameDecoder::new();
    up.outbuf.clear();
    up.outpos = 0;
}

#[cfg(all(test, not(rtopk_model_check)))]
mod tests {
    use super::*;

    fn addrs(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("127.0.0.1:{}", 9000 + i)).collect()
    }

    #[test]
    fn weight_one_tenants_are_sticky() {
        let a = addrs(4);
        let alive = vec![true; 4];
        let first = allocate_shard("t", 1, &a, &alive, 0).unwrap();
        for ctr in 1..32 {
            assert_eq!(
                allocate_shard("t", 1, &a, &alive, ctr),
                Some(first),
                "weight-1 tenant must stay on its rendezvous winner"
            );
        }
    }

    #[test]
    fn weight_spreads_across_exactly_weight_shards() {
        let a = addrs(4);
        let alive = vec![true; 4];
        let mut seen = std::collections::HashSet::new();
        for ctr in 0..32 {
            seen.insert(allocate_shard("heavy", 3, &a, &alive, ctr).unwrap());
        }
        assert_eq!(seen.len(), 3, "weight 3 → exactly 3 shards: {seen:?}");
        // weight past the shard count uses everything
        let mut all = std::collections::HashSet::new();
        for ctr in 0..32 {
            all.insert(allocate_shard("huge", 100, &a, &alive, ctr).unwrap());
        }
        assert_eq!(all.len(), 4);
    }

    #[test]
    fn dead_shards_are_excluded_and_allocation_is_stable_otherwise() {
        let a = addrs(4);
        let alive = vec![true; 4];
        let sticky = allocate_shard("t", 1, &a, &alive, 0).unwrap();
        // kill a shard the tenant does not use: allocation unchanged
        let mut partial = vec![true; 4];
        let other = (sticky + 1) % 4;
        partial[other] = false;
        assert_eq!(allocate_shard("t", 1, &a, &partial, 0), Some(sticky));
        // kill the tenant's shard: it moves, deterministically
        let mut gone = vec![true; 4];
        gone[sticky] = false;
        let moved = allocate_shard("t", 1, &a, &gone, 0).unwrap();
        assert_ne!(moved, sticky);
        assert_eq!(allocate_shard("t", 1, &a, &gone, 5), Some(moved));
        // nothing alive: no allocation
        assert_eq!(allocate_shard("t", 1, &a, &[false; 4], 0), None);
    }

    #[test]
    fn different_tenants_land_on_different_rendezvous_winners() {
        // not guaranteed per pair, but across many tenants the
        // rendezvous ranking must actually spread load
        let a = addrs(4);
        let alive = vec![true; 4];
        let mut seen = std::collections::HashSet::new();
        for i in 0..64 {
            let t = format!("tenant-{i}");
            seen.insert(allocate_shard(&t, 1, &a, &alive, 0).unwrap());
        }
        assert_eq!(seen.len(), 4, "64 tenants must cover all 4 shards");
    }
}
