//! Dynamic batcher: accumulate same-shape requests into row tiles, flush
//! on tile-full or deadline, apply backpressure when the queue is deep,
//! and drain budget-full tiles across tenants with weighted-deficit
//! round-robin (WDRR).
//!
//! The paper's service scenario batches millions of small rows; here the
//! unit of admission is a whole request (a matrix), and requests sharing
//! (tenant, M, k, mode) are packed into one execution batch up to the
//! tile's row budget. Rows never split across batches mid-request
//! (simplifies result scatter; tiles are padded anyway). Groups are
//! keyed per tenant, so a batch is always single-tenant — per-tenant
//! accounting, pins, and fairness need no cross-tenant untangling
//! downstream.
//!
//! Requests may carry a per-request **deadline** (an end-to-end latency
//! budget): it *caps* that request's batching wait at half the budget
//! still remaining at enqueue (the wait is `min(max_wait, remaining/2)`
//! — a deadline can only shorten batching, never extend it past the
//! global `max_wait`; the unspent budget remains for execution and
//! delivery), and requests only share a group when their deadlines are
//! within the same power-of-two class — close enough that the group
//! flushing at its earliest member's time costs any co-member at most
//! half its own wait, while clients that compute a fresh
//! remaining-budget deadline per call still batch together instead of
//! fragmenting into singleton groups. Requests also carry a
//! **priority** class ([`Priority`]): another grouping dimension,
//! consumed by the WDRR drain as a quantum multiplier (see below).
//!
//! Flush policy, in priority order per wake:
//!
//! 1. **Deadline flushes bypass everything** and go earliest-first: a
//!    min-heap over every queued request's flush time (lazily pruned as
//!    requests leave in batches) names the next group that must flush,
//!    so a short per-request deadline behind a long-deadline head is
//!    honored. For uniform waits the heap order is submission order —
//!    exactly the old oldest-first rule. Deadline-expired groups are
//!    served before any budget-full tile — under quota pressure a
//!    heavy tenant's full tiles must not push a light tenant's
//!    deadline-expired trickle past its latency SLO. (The first WDRR
//!    cut recomputed oldest-first ordering but let ready tiles win
//!    ties, which starved exactly the tenants the weights were meant
//!    to protect.)
//! 2. **Budget-full groups flush under WDRR.** A group that reaches the
//!    row budget is flushable *immediately*, wherever it sits in the
//!    queue — no head-of-line blocking across keys. When budget-full
//!    groups from several tenants are pending, they drain
//!    proportionally to tenant weight (deficit round-robin with a
//!    one-tile quantum) instead of FIFO-by-key: each tenant accrues
//!    `weight x tile` rows of credit per rotation — scaled by the
//!    front group's [`Priority`] (normal 1x, high 4x, low 1/2x) — and
//!    serves tiles while its credit lasts, so a weight-4 tenant drains
//!    4 tiles for every 1 a weight-1 tenant drains, and no backlogged
//!    tenant is ever skipped for a full rotation. Within a tenant,
//!    ready groups drain in the order they filled, and within a key
//!    FIFO order is preserved (the budget closes at the first same-key
//!    request that does not fit).
//!
//! Bookkeeping is O(1)-amortized per wake: per-key running row counts
//! are maintained on submit/flush (`Inner::group_rows`), keys that
//! cross the budget are queued per tenant (`Inner::ready`), and the
//! tenant rotation (`Inner::rr`) tops up deficits lazily — `next_batch`
//! never rescans the queue to rediscover group sizes.
//!
//! Fairness accounting notes: a flushed batch is charged its *actual*
//! rows (so budget-closed partial tiles under-charge and oversized
//! single-request batches over-charge into debt), credit is capped at
//! one tile above the tenant's quantum so an uncontended tenant cannot
//! bank unbounded credit and later monopolize the workers, and a
//! tenant's deficit resets when its ready queue drains (standard DRR
//! reset-on-empty).

use crate::coordinator::metrics::{QueueGauges, QueueProbe};
use crate::coordinator::request::{CancelToken, Priority};
use crate::coordinator::tenant::TenantId;
use crate::topk::types::Mode;
use crate::util::matrix::RowMatrix;
use crate::util::sync::{Condvar, Mutex};
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::time::{Duration, Instant};

/// Everything the batcher needs to enqueue one request (the typed
/// submission minus the reply slot — built by the service from a
/// `SubmitRequest` after validation and admission).
pub struct Enqueue {
    pub tenant: TenantId,
    pub matrix: RowMatrix,
    pub k: usize,
    pub mode: Mode,
    /// when the caller submitted (before admission) — the clock served
    /// latency and deadlines are measured against
    pub submitted: Instant,
    /// per-request deadline (duration from submit); caps the batching
    /// wait at `min(max_wait, remaining/2)` and keys grouping by
    /// power-of-two deadline class
    pub deadline: Option<Duration>,
    /// absolute expiry instant — the scheduler answers an expired
    /// request with a timeout error instead of serving stale work
    pub expire_at: Option<Instant>,
    pub priority: Priority,
    /// shared with the caller's ticket; a cancelled request is dropped
    /// at dispatch
    pub cancel: CancelToken,
}

impl Enqueue {
    /// A submission with default policy (no deadline, normal priority,
    /// fresh cancel token) — what the pre-typed-API call sites mean.
    pub fn basic(
        tenant: TenantId,
        matrix: RowMatrix,
        k: usize,
        mode: Mode,
    ) -> Enqueue {
        Enqueue {
            tenant,
            matrix,
            k,
            mode,
            submitted: Instant::now(),
            deadline: None,
            expire_at: None,
            priority: Priority::Normal,
            cancel: CancelToken::new(),
        }
    }
}

/// One admitted request plus its reply slot.
pub struct Pending<T> {
    pub tenant: TenantId,
    pub matrix: RowMatrix,
    pub k: usize,
    pub mode: Mode,
    /// submit instant (before admission) — served latency is measured
    /// from here, so time parked in blocking admission or backpressure
    /// is visible in the reservoirs, not silently excluded
    pub submitted: Instant,
    pub enqueued: Instant,
    /// when this request's group must flush regardless of fill
    pub flush_at: Instant,
    /// the per-request deadline this request was submitted with, if any
    /// (kept for positioned timeout errors)
    pub deadline: Option<Duration>,
    /// absolute expiry; checked by the scheduler at dispatch + delivery
    pub expire_at: Option<Instant>,
    pub priority: Priority,
    pub cancel: CancelToken,
    pub reply: T,
}

/// A flushed batch: requests sharing (tenant, cols, k, mode).
pub struct Batch<T> {
    pub tenant: TenantId,
    pub cols: usize,
    pub k: usize,
    pub mode: Mode,
    pub items: Vec<Pending<T>>,
    pub total_rows: usize,
}

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// flush when a group reaches this many rows
    pub max_rows: usize,
    /// flush a group when its oldest member waited this long
    pub max_wait: Duration,
    /// admission blocks when this many rows are queued (backpressure)
    pub queue_limit: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_rows: 1024,
            max_wait: Duration::from_micros(200),
            queue_limit: 1 << 16,
        }
    }
}

/// Hashable form of a request's (tenant, cols, k, mode, deadline
/// class, priority) grouping key. `Mode` carries an `f32`, so the
/// float is keyed by its bit pattern — two requests group together iff
/// their modes are bit-identical, exactly the equality
/// `Mode: PartialEq` uses. Deadline class and priority are grouping
/// dimensions too: the WDRR scaling must be uniform across a group's
/// members, and its flush times must be close (the earliest member
/// flushes the group; same-class deadlines keep that early flush
/// within 2x of everyone's own wait).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct GroupKey {
    tenant: TenantId,
    cols: usize,
    k: usize,
    mode: ModeBits,
    /// power-of-two class of the per-request deadline (`None` = the
    /// policy wait). Keyed by class, not exact nanoseconds: clients
    /// that compute a fresh remaining-budget deadline per call would
    /// otherwise fragment every request into a singleton group and
    /// defeat batching entirely. Within a class deadlines differ by at
    /// most 2x, and the flush heap flushes the group at its *earliest*
    /// member's time, so sharing a group can only shorten a
    /// co-member's wait — never push it past its own deadline.
    deadline_class: Option<u32>,
    priority: Priority,
}

/// Floor-log2 bucket of a deadline — the grouping class.
fn deadline_class(d: Duration) -> u32 {
    63 - (d.as_nanos().clamp(1, u64::MAX as u128) as u64).leading_zeros()
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
enum ModeBits {
    Exact(u32),
    EarlyStop(u32),
    Approx(u16),
}

fn key_of<T>(p: &Pending<T>) -> GroupKey {
    GroupKey {
        tenant: p.tenant.clone(),
        cols: p.matrix.cols,
        k: p.k,
        mode: match p.mode {
            Mode::Exact { eps_rel } => ModeBits::Exact(eps_rel.to_bits()),
            Mode::EarlyStop { max_iter } => ModeBits::EarlyStop(max_iter),
            Mode::Approx { recall_milli } => ModeBits::Approx(recall_milli),
        },
        deadline_class: p.deadline.map(deadline_class),
        priority: p.priority,
    }
}

/// One queued request's flush time in the deadline min-heap. Entries
/// are lazily deleted: when the request leaves the queue in a batch its
/// token's `queued` flag clears and the entry is pruned at the next
/// peek, so the heap never needs random removal.
struct FlushEntry {
    at: Instant,
    /// submission sequence — the tiebreak that keeps equal flush times
    /// in FIFO order
    seq: u64,
    key: GroupKey,
    token: CancelToken,
}

impl PartialEq for FlushEntry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl Eq for FlushEntry {}

impl PartialOrd for FlushEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for FlushEntry {
    /// Inverted ordering so `BinaryHeap` (a max-heap) pops the earliest
    /// flush time first, FIFO within a tie.
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.at.cmp(&self.at).then(other.seq.cmp(&self.seq))
    }
}

/// One tenant's share of the WDRR state: its budget-full groups in
/// fill order, plus its rows of accumulated drain credit.
#[derive(Debug, Default)]
struct TenantQueue {
    /// rows of credit; negative = debt from an oversized batch
    deficit: i64,
    /// keys whose group crossed `max_rows`, in the order they did
    ready: VecDeque<GroupKey>,
}

struct Inner<T> {
    queue: VecDeque<Pending<T>>,
    queued_rows: usize,
    /// running rows per grouping key — updated on submit and flush,
    /// never recomputed by scanning the queue
    group_rows: HashMap<GroupKey, usize>,
    /// per-tenant budget-full group queues + deficit counters
    ready: HashMap<TenantId, TenantQueue>,
    /// round-robin rotation of tenants with queued ready groups
    /// (stale-tolerant: entries are validated and pruned on pick)
    rr: VecDeque<TenantId>,
    /// min-heap of every queued request's flush time (lazily pruned via
    /// each token's `queued` flag) — names the next deadline flush
    flush: BinaryHeap<FlushEntry>,
    /// submission counter feeding [`FlushEntry::seq`]
    seq: u64,
    closed: bool,
}

/// MPMC batching queue (mutex + condvars; request threads push, worker
/// threads pull ready batches).
pub struct Batcher<T> {
    policy: BatchPolicy,
    /// configured WDRR weights; tenants absent here weigh 1
    weights: HashMap<TenantId, u64>,
    inner: Mutex<Inner<T>>,
    /// signaled when work arrives or the queue closes
    work: Condvar,
    /// signaled when rows drain (unblocks backpressured producers)
    space: Condvar,
}

/// Why [`Batcher::submit_request`] refused a submission before it ever
/// reached the queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitRefusal {
    /// the batcher is closed (service shutting down)
    Closed,
    /// the request's deadline expired while blocked on backpressure —
    /// the caller owes the client a positioned timeout error
    Expired,
}

/// Largest honored WDRR weight. Clamping here keeps the deficit
/// arithmetic inside i64 (`quantum = weight x max_rows` must never
/// wrap negative — a negative quantum would make the pick loop spin
/// forever under the queue lock) and a ratio of a million-to-one is
/// already far past any meaningful fairness split.
pub const MAX_WEIGHT: u64 = 1 << 20;

impl<T> Batcher<T> {
    /// A batcher where every tenant weighs 1 (plain deficit
    /// round-robin; single-tenant workloads behave exactly as before
    /// tenancy existed).
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher::with_weights(policy, Vec::new())
    }

    /// A batcher with explicit per-tenant WDRR weights (clamped into
    /// `1..=`[`MAX_WEIGHT`]; tenants not listed weigh 1).
    pub fn with_weights(policy: BatchPolicy, weights: Vec<(TenantId, u64)>) -> Self {
        Batcher {
            policy,
            weights: weights
                .into_iter()
                .map(|(t, w)| (t, w.clamp(1, MAX_WEIGHT)))
                .collect(),
            inner: Mutex::new(Inner {
                queue: VecDeque::new(),
                queued_rows: 0,
                group_rows: HashMap::new(),
                ready: HashMap::new(),
                rr: VecDeque::new(),
                flush: BinaryHeap::new(),
                seq: 0,
                closed: false,
            }),
            work: Condvar::new(),
            space: Condvar::new(),
        }
    }

    /// Admit a default-policy request (blocks under backpressure).
    /// Returns false if the batcher is closed. Convenience over
    /// [`Batcher::submit_request`] for call sites without per-request
    /// policy.
    pub fn submit(
        &self,
        tenant: TenantId,
        matrix: RowMatrix,
        k: usize,
        mode: Mode,
        reply: T,
    ) -> bool {
        self.submit_request(Enqueue::basic(tenant, matrix, k, mode), reply)
            .is_ok()
    }

    /// The batching wait for a request: the policy's `max_wait`, capped
    /// at half the request's *remaining* budget — blocking admission,
    /// validation, or backpressure may have eaten part of the deadline
    /// before enqueue, and batching must leave execution headroom out
    /// of what is actually left, not out of the original budget (a
    /// request with time left to execute must never be parked until
    /// exactly its expiry and then answered with a guaranteed timeout).
    /// Never longer than `max_wait`.
    fn effective_wait(&self, budget: Option<Duration>) -> Duration {
        match budget {
            None => self.policy.max_wait,
            Some(b) => self.policy.max_wait.min(b / 2),
        }
    }

    /// Admit a request (blocks under backpressure; the wait is bounded
    /// by the request's own expiry — a deadline'd submission must not
    /// park past its budget waiting for queue space). On refusal the
    /// reply slot is dropped unanswered — the caller must release any
    /// admission reservation and surface the matching error itself.
    pub fn submit_request(
        &self,
        req: Enqueue,
        reply: T,
    ) -> Result<(), SubmitRefusal> {
        let rows = req.matrix.rows;
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(SubmitRefusal::Closed);
            }
            if g.queued_rows + rows <= self.policy.queue_limit
                || g.queued_rows == 0
            {
                break;
            }
            match req.expire_at {
                Some(at) => {
                    let now = Instant::now();
                    if now >= at {
                        return Err(SubmitRefusal::Expired);
                    }
                    g = self.space.wait_timeout(g, at - now).unwrap().0;
                }
                None => g = self.space.wait(g).unwrap(),
            }
        }
        let now = Instant::now();
        // budget still on the clock at enqueue (the whole deadline when
        // the caller supplied no expiry instant); an already-expired
        // request gets a zero wait so the timeout error is prompt
        let budget = req
            .expire_at
            .map(|at| at.saturating_duration_since(now))
            .or(req.deadline);
        let mut flush_at = now + self.effective_wait(budget);
        if let Some(at) = req.expire_at {
            flush_at = flush_at.min(at);
        }
        let pending = Pending {
            tenant: req.tenant,
            matrix: req.matrix,
            k: req.k,
            mode: req.mode,
            submitted: req.submitted,
            enqueued: now,
            flush_at,
            deadline: req.deadline,
            expire_at: req.expire_at,
            priority: req.priority,
            cancel: req.cancel,
            reply,
        };
        let key = key_of(&pending);
        pending.cancel.mark_queued(true);
        g.seq += 1;
        let seq = g.seq;
        g.flush.push(FlushEntry {
            at: pending.flush_at,
            seq,
            key: key.clone(),
            token: pending.cancel.clone(),
        });
        g.queue.push_back(pending);
        g.queued_rows += rows;
        let group = g.group_rows.entry(key.clone()).or_insert(0);
        let was_ready = *group >= self.policy.max_rows;
        *group += rows;
        let now_ready = *group >= self.policy.max_rows;
        if now_ready && !was_ready {
            Self::enqueue_ready(&mut g, key);
        }
        drop(g);
        self.work.notify_one();
        Ok(())
    }

    /// Queue a budget-full group key into its tenant's ready queue,
    /// entering the tenant into the rotation if absent. Deduplicates: a
    /// key can re-cross the budget while a stale entry for it is still
    /// queued.
    fn enqueue_ready(g: &mut Inner<T>, key: GroupKey) {
        let Inner { ready, rr, .. } = g;
        let tenant = key.tenant.clone();
        let tq = ready.entry(tenant.clone()).or_default();
        if !tq.ready.contains(&key) {
            tq.ready.push_back(key);
            if !rr.contains(&tenant) {
                rr.push_back(tenant);
            }
        }
    }

    /// Weighted-deficit-round-robin pick over budget-full groups.
    /// Visits the rotation front: serves it if its credit covers one
    /// tile, else tops the credit up by `weight x tile` (capped one
    /// tile above the quantum) and rotates. Stale keys — groups a
    /// deadline flush already drained below the budget — are pruned
    /// here, and a tenant whose queue empties leaves the rotation with
    /// its credit reset. Terminates: every iteration serves, prunes, or
    /// rotates-with-top-up, and after one full rotation every remaining
    /// tenant's credit covers a tile.
    fn pick_ready(
        policy: &BatchPolicy,
        weights: &HashMap<TenantId, u64>,
        g: &mut Inner<T>,
    ) -> Option<GroupKey> {
        let Inner { ready, rr, group_rows, .. } = g;
        // clamp keeps `quantum_base * MAX_WEIGHT` inside i64 (a
        // negative quantum could never satisfy the serve condition)
        let quantum_base = policy.max_rows.clamp(1, 1 << 32) as i64;
        loop {
            let tenant = match rr.front() {
                Some(t) => t.clone(),
                None => return None,
            };
            // prune stale keys: a deadline flush may have drained the
            // group below the budget since it was queued
            let drained = match ready.get_mut(&tenant) {
                Some(tq) => {
                    while let Some(key) = tq.ready.front() {
                        if group_rows.get(key).copied().unwrap_or(0)
                            >= policy.max_rows
                        {
                            break;
                        }
                        tq.ready.pop_front();
                    }
                    tq.ready.is_empty()
                }
                None => true,
            };
            if drained {
                // reset-on-empty: the tenant leaves the rotation and
                // forfeits any banked credit
                ready.remove(&tenant);
                rr.pop_front();
                continue;
            }
            let tq = ready.get_mut(&tenant).expect("tenant queue checked above");
            if tq.deficit >= quantum_base {
                return tq.ready.pop_front();
            }
            let weight = weights
                .get(&tenant)
                .copied()
                .unwrap_or(1)
                .clamp(1, MAX_WEIGHT) as i64;
            // the front group's priority scales the refill: while a
            // tenant's next tile is high-priority it accrues credit 4x
            // as fast (low: half) — Priority::Normal is exactly the
            // pre-priority quantum. Bounded: quantum_base <= 2^32,
            // weight <= 2^20, priority <= 4x, all inside i64.
            let priority = tq
                .ready
                .front()
                .map(|k| k.priority)
                .unwrap_or(Priority::Normal);
            let quantum =
                priority.scale_quantum(quantum_base.saturating_mul(weight));
            tq.deficit = tq
                .deficit
                .saturating_add(quantum)
                .min(quantum.saturating_add(quantum_base));
            rr.rotate_left(1);
        }
    }

    /// Pull the next batch. Flush order: the group whose flush time
    /// (per-request deadline override, else the policy wait) expires
    /// earliest — an expired flush time beats any budget-full tile —
    /// else a budget-full group picked by WDRR across tenants. Blocks
    /// otherwise. Returns None when closed and drained.
    pub fn next_batch(&self) -> Option<Batch<T>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            let now = Instant::now();
            // prune heap entries whose request already left in a batch
            while let Some(top) = g.flush.peek() {
                if top.token.is_queued() {
                    break;
                }
                g.flush.pop();
            }
            let next_flush = g.flush.peek().map(|e| (e.at, e.key.clone()));
            if let Some((at, key)) = &next_flush {
                if g.closed || now >= *at {
                    // deadline (or drain-on-close) flush: bypasses WDRR
                    // so quota pressure can never starve a light
                    // tenant past its latency budget
                    return Some(self.finish_flush(g, key.clone(), false));
                }
            } else if g.closed {
                // every queued request holds a live heap entry, so an
                // empty heap means an empty queue
                debug_assert!(g.queue.is_empty());
                return None;
            }
            if let Some(key) = Self::pick_ready(&self.policy, &self.weights, &mut g)
            {
                return Some(self.finish_flush(g, key, true));
            }
            // wait for more work (a group may fill) or the next flush
            g = match next_flush {
                Some((at, _)) => {
                    self.work
                        .wait_timeout(g, at.saturating_duration_since(now))
                        .unwrap()
                        .0
                }
                None => self.work.wait(g).unwrap(),
            };
        }
    }

    /// Flush `key` out of the locked queue, charge a WDRR pick its
    /// actual rows, then release the lock and wake the right parties:
    /// producers always (rows drained), and another worker when
    /// flushable groups remain — a worker that was already parked on
    /// the head's deadline would otherwise sleep through a budget-full
    /// tile this flush left behind (or a second key that crossed its
    /// budget while we held the lock).
    fn finish_flush(
        &self,
        mut g: crate::util::sync::MutexGuard<'_, Inner<T>>,
        key: GroupKey,
        wdrr_pick: bool,
    ) -> Batch<T> {
        let tenant = key.tenant.clone();
        let batch = self.flush_locked(&mut g, key);
        if wdrr_pick {
            // charge the tenant the rows actually drained (a tenant
            // whose queue emptied has left the table; its reset credit
            // would be meaningless to charge)
            if let Some(tq) = g.ready.get_mut(&tenant) {
                tq.deficit -= batch.total_rows as i64;
            }
        }
        let more_ready = !g.rr.is_empty();
        drop(g);
        self.space.notify_all();
        if more_ready {
            self.work.notify_one();
        }
        batch
    }

    /// Extract one batch for `key` from the queue (caller holds the
    /// lock and guarantees the group is non-empty). Takes matching
    /// requests while they fit the tile budget. The budget check
    /// includes the candidate's own rows — checking `total_rows <
    /// max_rows` *before* adding (the old behavior) let one large
    /// request blow the budget arbitrarily. The group's first request
    /// is always admitted even when it alone exceeds the budget
    /// (oversized requests get a dedicated batch; they must still be
    /// served), and the first same-key request that does not fit closes
    /// the budget — admitting later smaller ones would serve them ahead
    /// of it (FIFO per key).
    fn flush_locked(&self, g: &mut Inner<T>, key: GroupKey) -> Batch<T> {
        let mut items: Vec<Pending<T>> = Vec::new();
        let mut total_rows = 0usize;
        let mut rest = VecDeque::new();
        let mut budget_open = true;
        let mut meta: Option<(usize, usize, Mode)> = None;
        while let Some(p) = g.queue.pop_front() {
            if budget_open && key_of(&p) == key {
                let fits = total_rows + p.matrix.rows <= self.policy.max_rows;
                if items.is_empty() || fits {
                    if meta.is_none() {
                        meta = Some((p.matrix.cols, p.k, p.mode));
                    }
                    total_rows += p.matrix.rows;
                    // leaving the queue: the deadline heap's entry for
                    // this request becomes prunable
                    p.cancel.mark_queued(false);
                    items.push(p);
                    continue;
                }
                budget_open = false;
            }
            rest.push_back(p);
        }
        g.queue = rest;
        g.queued_rows -= total_rows;
        // tolerate a missing/zero entry: zero-row requests contribute
        // nothing to the count, so their group's entry can already be
        // gone while they still sit in the queue
        let remaining = match g.group_rows.get_mut(&key) {
            Some(e) => {
                *e = e.saturating_sub(total_rows);
                *e
            }
            None => 0,
        };
        if remaining == 0 {
            g.group_rows.remove(&key);
        } else if remaining >= self.policy.max_rows {
            // a budget-closing flush can leave another full tile behind
            Self::enqueue_ready(g, key.clone());
        }
        let (cols, k, mode) = meta.expect("flush_locked on an empty group");
        Batch { tenant: key.tenant, cols, k, mode, items, total_rows }
    }

    /// Remove every cancelled request still waiting in the queue and
    /// return them (row accounting fixed, heap entries left for lazy
    /// pruning, backpressured producers woken). Called from the
    /// ticket's cancel hook so a cancelled request releases its tenant
    /// quota and queue space immediately instead of pinning both until
    /// the group's scheduled flush; the caller releases reservations
    /// and delivers the `cancelled` error. Safe against a concurrent
    /// flush: under the queue lock a request is either evicted here or
    /// flushed there, never both.
    pub fn evict_cancelled(&self) -> Vec<Pending<T>> {
        let mut g = self.inner.lock().unwrap();
        if !g.queue.iter().any(|p| p.cancel.is_cancelled()) {
            return Vec::new();
        }
        let mut evicted = Vec::new();
        let mut rest = VecDeque::with_capacity(g.queue.len());
        while let Some(p) = g.queue.pop_front() {
            if !p.cancel.is_cancelled() {
                rest.push_back(p);
                continue;
            }
            g.queued_rows -= p.matrix.rows;
            let key = key_of(&p);
            if let Some(e) = g.group_rows.get_mut(&key) {
                *e = e.saturating_sub(p.matrix.rows);
                if *e == 0 {
                    g.group_rows.remove(&key);
                }
            }
            // a ready entry whose group just fell below the budget is
            // pruned by pick_ready; the flush-heap entry by next_batch
            p.cancel.mark_queued(false);
            evicted.push(p);
        }
        g.queue = rest;
        drop(g);
        if !evicted.is_empty() {
            self.space.notify_all();
        }
        evicted
    }

    /// Close the queue: producers are rejected, workers drain then stop.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.work.notify_all();
        self.space.notify_all();
    }

    pub fn queued_rows(&self) -> usize {
        self.inner.lock().unwrap().queued_rows
    }

    /// Point-in-time queue gauges for the telemetry hub: queued rows,
    /// queued requests, and the tightest remaining end-to-end deadline
    /// slack among queued requests (`None` when nothing queued carries
    /// a deadline). Slack is measured against request *expiry*, not
    /// flush times — flush waits are a few hundred microseconds by
    /// design, so they would read as "near deadline" whenever anything
    /// is queued at all. One lock, one queue scan.
    pub fn queue_gauges(&self) -> QueueGauges {
        let g = self.inner.lock().unwrap();
        let now = Instant::now();
        let min_slack_us = g
            .queue
            .iter()
            .filter_map(|p| p.expire_at)
            .min()
            .map(|at| at.saturating_duration_since(now).as_micros() as u64);
        QueueGauges {
            queued_rows: g.queued_rows as u64,
            queued_requests: g.queue.len() as u64,
            min_slack_us,
        }
    }

    /// Sum of the per-key running row counts — must always reconcile
    /// with [`Batcher::queued_rows`] (and drain to 0 with the queue).
    /// Exposed for invariant checks in tests and debugging.
    pub fn group_rows_outstanding(&self) -> usize {
        self.inner.lock().unwrap().group_rows.values().sum()
    }
}

/// The batcher is the service's live queue-gauges source: the hub
/// registers it at build and feedback consumers (cadence control,
/// feasibility admission) poll through the hub.
impl<T: Send> QueueProbe for Batcher<T> {
    fn queue_gauges(&self) -> QueueGauges {
        Batcher::queue_gauges(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn mat(rows: usize, cols: usize) -> RowMatrix {
        RowMatrix::zeros(rows, cols)
    }

    /// Default-tenant id (most tests predate tenancy).
    fn dt() -> TenantId {
        TenantId::default()
    }

    fn tid(name: &str) -> TenantId {
        TenantId::new(name)
    }

    #[test]
    fn groups_same_shape_requests() {
        let b: Batcher<usize> = Batcher::new(BatchPolicy {
            max_rows: 100,
            max_wait: Duration::from_millis(5),
            queue_limit: 1000,
        });
        assert!(b.submit(dt(), mat(40, 8), 2, Mode::EXACT, 0));
        assert!(b.submit(dt(), mat(40, 8), 2, Mode::EXACT, 1));
        assert!(b.submit(dt(), mat(40, 16), 2, Mode::EXACT, 2)); // different M
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.cols, 8);
        assert_eq!(batch.items.len(), 2);
        assert_eq!(batch.total_rows, 80);
        assert_eq!(batch.tenant, dt());
        let batch2 = b.next_batch().unwrap();
        assert_eq!(batch2.cols, 16);
        assert_eq!(batch2.items[0].reply, 2);
    }

    #[test]
    fn same_shape_different_tenants_do_not_share_a_batch() {
        // tenant is a grouping dimension: per-tenant accounting and
        // fairness require single-tenant batches
        let b: Batcher<usize> = Batcher::new(BatchPolicy {
            max_rows: 100,
            max_wait: Duration::from_millis(5),
            queue_limit: 1000,
        });
        assert!(b.submit(tid("a"), mat(40, 8), 2, Mode::EXACT, 0));
        assert!(b.submit(tid("b"), mat(40, 8), 2, Mode::EXACT, 1));
        let first = b.next_batch().unwrap();
        assert_eq!(first.items.len(), 1);
        assert_eq!(first.tenant, tid("a"));
        let second = b.next_batch().unwrap();
        assert_eq!(second.items.len(), 1);
        assert_eq!(second.tenant, tid("b"));
    }

    #[test]
    fn flushes_on_budget_without_waiting() {
        let b: Batcher<usize> = Batcher::new(BatchPolicy {
            max_rows: 64,
            max_wait: Duration::from_secs(60), // deadline must not matter
            queue_limit: 1000,
        });
        b.submit(dt(), mat(64, 8), 2, Mode::EXACT, 0);
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert!(t0.elapsed() < Duration::from_secs(1));
        assert_eq!(batch.total_rows, 64);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let b: Batcher<usize> = Batcher::new(BatchPolicy {
            max_rows: 1_000_000,
            max_wait: Duration::from_millis(10),
            queue_limit: 1000,
        });
        b.submit(dt(), mat(5, 8), 2, Mode::EXACT, 9);
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(8));
        assert_eq!(batch.total_rows, 5);
        assert_eq!(batch.items[0].reply, 9);
    }

    #[test]
    fn close_drains_then_stops() {
        let b: Arc<Batcher<usize>> = Arc::new(Batcher::new(BatchPolicy::default()));
        b.submit(dt(), mat(3, 4), 1, Mode::EXACT, 7);
        b.close();
        assert!(!b.submit(dt(), mat(1, 4), 1, Mode::EXACT, 8)); // rejected
        let batch = b.next_batch().unwrap(); // drains the queued one
        assert_eq!(batch.items.len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn budget_not_exceeded_by_second_request() {
        // Regression: the pre-add budget check admitted any request
        // while total_rows < max_rows, so 60 + 60 rows flushed as one
        // 120-row batch against a 100-row budget.
        let b: Batcher<usize> = Batcher::new(BatchPolicy {
            max_rows: 100,
            max_wait: Duration::from_millis(5),
            queue_limit: 1000,
        });
        assert!(b.submit(dt(), mat(60, 8), 2, Mode::EXACT, 0));
        assert!(b.submit(dt(), mat(60, 8), 2, Mode::EXACT, 1));
        let first = b.next_batch().unwrap();
        assert_eq!(first.total_rows, 60, "budget exceeded");
        assert_eq!(first.items[0].reply, 0);
        let second = b.next_batch().unwrap();
        assert_eq!(second.total_rows, 60);
        assert_eq!(second.items[0].reply, 1);
        assert_eq!(b.queued_rows(), 0);
        assert_eq!(b.group_rows_outstanding(), 0);
    }

    #[test]
    fn budget_overflow_preserves_fifo_within_key() {
        // [A(60), B(60), C(10)] same key, budget 100: C must not be
        // served ahead of B just because it fits next to A.
        let b: Batcher<usize> = Batcher::new(BatchPolicy {
            max_rows: 100,
            max_wait: Duration::from_millis(5),
            queue_limit: 1000,
        });
        assert!(b.submit(dt(), mat(60, 8), 2, Mode::EXACT, 0));
        assert!(b.submit(dt(), mat(60, 8), 2, Mode::EXACT, 1));
        assert!(b.submit(dt(), mat(10, 8), 2, Mode::EXACT, 2));
        let first = b.next_batch().unwrap();
        assert_eq!(
            first.items.iter().map(|p| p.reply).collect::<Vec<_>>(),
            vec![0],
            "budget closes at the first non-fitting same-key request"
        );
        let second = b.next_batch().unwrap();
        assert_eq!(
            second.items.iter().map(|p| p.reply).collect::<Vec<_>>(),
            vec![1, 2],
            "B and C flush together, in order"
        );
    }

    #[test]
    fn oversized_head_gets_dedicated_batch() {
        // A request larger than max_rows must still be served — alone —
        // and must not drag same-key followers over the budget with it.
        let b: Batcher<usize> = Batcher::new(BatchPolicy {
            max_rows: 64,
            max_wait: Duration::from_millis(5),
            queue_limit: 10_000,
        });
        assert!(b.submit(dt(), mat(500, 8), 2, Mode::EXACT, 0));
        assert!(b.submit(dt(), mat(10, 8), 2, Mode::EXACT, 1));
        let big = b.next_batch().unwrap();
        assert_eq!(big.total_rows, 500);
        assert_eq!(big.items.len(), 1, "oversized request must batch alone");
        let small = b.next_batch().unwrap();
        assert_eq!(small.total_rows, 10);
        assert_eq!(small.items[0].reply, 1);
        assert_eq!(b.queued_rows(), 0);
        assert_eq!(b.group_rows_outstanding(), 0);
    }

    #[test]
    fn budget_full_group_behind_head_flushes_without_head_deadline() {
        // Regression (head-of-line blocking): the head's group is far
        // from its budget with a long deadline; a *different* key
        // behind it reaches the budget. It must flush immediately —
        // not when the head's deadline finally expires — and the head
        // must keep waiting.
        let b: Batcher<usize> = Batcher::new(BatchPolicy {
            max_rows: 64,
            max_wait: Duration::from_secs(60),
            queue_limit: 10_000,
        });
        assert!(b.submit(dt(), mat(5, 8), 2, Mode::EXACT, 0)); // head, key A
        assert!(b.submit(dt(), mat(64, 16), 2, Mode::EXACT, 1)); // key B: full
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "budget-full group waited on the head's deadline"
        );
        assert_eq!(batch.cols, 16, "the full group flushes, not the head");
        assert_eq!(batch.total_rows, 64);
        assert_eq!(b.queued_rows(), 5, "head keeps waiting for its own flush");
        // the head still flushes on close/deadline
        b.close();
        let head = b.next_batch().unwrap();
        assert_eq!(head.cols, 8);
        assert_eq!(head.items[0].reply, 0);
        assert_eq!(b.group_rows_outstanding(), 0);
    }

    #[test]
    fn expired_deadline_beats_a_budget_full_tile() {
        // Regression (starved light tenant): a light tenant's trickle
        // whose deadline has already expired must flush before a heavy
        // tenant's budget-full tiles — WDRR governs ready tiles, never
        // the latency SLO.
        let b: Batcher<usize> = Batcher::with_weights(
            BatchPolicy {
                max_rows: 64,
                max_wait: Duration::from_millis(20),
                queue_limit: 100_000,
            },
            vec![(tid("heavy"), 8), (tid("light"), 1)],
        );
        // light submits first (head), then heavy piles up full tiles
        assert!(b.submit(tid("light"), mat(3, 8), 2, Mode::EXACT, 0));
        for i in 0..10 {
            assert!(b.submit(tid("heavy"), mat(64, 8), 2, Mode::EXACT, 1 + i));
        }
        std::thread::sleep(Duration::from_millis(30)); // deadline passes
        let first = b.next_batch().unwrap();
        assert_eq!(
            first.tenant,
            tid("light"),
            "deadline-expired trickle must bypass WDRR"
        );
        assert_eq!(first.total_rows, 3);
        // with the light tenant served, WDRR drains the heavy backlog
        let second = b.next_batch().unwrap();
        assert_eq!(second.tenant, tid("heavy"));
        assert_eq!(second.total_rows, 64);
        b.close();
    }

    #[test]
    fn wdrr_drains_tenants_proportionally_to_weight() {
        // Two tenants with weights 2:1, both with deep backlogs of full
        // tiles: over any window of 3 drains the weight-2 tenant gets 2
        // and the weight-1 tenant gets 1.
        let b: Batcher<usize> = Batcher::with_weights(
            BatchPolicy {
                max_rows: 64,
                max_wait: Duration::from_secs(60),
                queue_limit: 1 << 20,
            },
            vec![(tid("a"), 2), (tid("b"), 1)],
        );
        for i in 0..12 {
            assert!(b.submit(tid("a"), mat(64, 8), 2, Mode::EXACT, i));
            assert!(b.submit(tid("b"), mat(64, 8), 2, Mode::EXACT, 100 + i));
        }
        let mut a_rows = 0usize;
        let mut b_rows = 0usize;
        // drain 9 batches while both tenants stay backlogged
        for _ in 0..9 {
            let batch = b.next_batch().unwrap();
            if batch.tenant == tid("a") {
                a_rows += batch.total_rows;
            } else {
                b_rows += batch.total_rows;
            }
        }
        assert_eq!(a_rows, 6 * 64, "weight-2 tenant drains 2 of every 3");
        assert_eq!(b_rows, 3 * 64, "weight-1 tenant drains 1 of every 3");
        b.close();
    }

    #[test]
    fn blocked_worker_wakes_for_a_late_arriving_full_group() {
        // A worker already parked on the head's (long) deadline must
        // wake and serve a different-key group the moment it fills.
        let b: Arc<Batcher<usize>> = Arc::new(Batcher::new(BatchPolicy {
            max_rows: 32,
            max_wait: Duration::from_secs(60),
            queue_limit: 10_000,
        }));
        b.submit(dt(), mat(4, 8), 2, Mode::EXACT, 0); // head, key A
        let b2 = b.clone();
        let worker = std::thread::spawn(move || b2.next_batch().unwrap());
        std::thread::sleep(Duration::from_millis(30)); // worker parks
        b.submit(dt(), mat(32, 16), 2, Mode::EXACT, 1); // key B fills
        let batch = worker.join().unwrap();
        assert_eq!(batch.cols, 16);
        assert_eq!(b.queued_rows(), 4);
        b.close();
        assert_eq!(b.next_batch().unwrap().cols, 8);
    }

    #[test]
    fn multi_tile_group_wakes_a_second_parked_worker() {
        // Regression: a flush that leaves another full tile behind
        // re-queues the key as ready but used to notify only producers
        // — a second worker parked on the head's (long) deadline slept
        // through the leftover tile. Both tiles must flush promptly.
        let b: Arc<Batcher<usize>> = Arc::new(Batcher::new(BatchPolicy {
            max_rows: 64,
            max_wait: Duration::from_secs(60),
            queue_limit: 10_000,
        }));
        b.submit(dt(), mat(4, 8), 2, Mode::EXACT, 0); // head, key A, far deadline
        let workers: Vec<_> = (0..2)
            .map(|_| {
                let b = b.clone();
                std::thread::spawn(move || b.next_batch().unwrap())
            })
            .collect();
        std::thread::sleep(Duration::from_millis(30)); // both park
        // key B arrives as two full tiles in one burst: the crossing
        // submit wakes one worker; the flush must wake the other
        b.submit(dt(), mat(60, 16), 2, Mode::EXACT, 1);
        b.submit(dt(), mat(60, 16), 2, Mode::EXACT, 2);
        b.submit(dt(), mat(60, 16), 2, Mode::EXACT, 3);
        let t0 = Instant::now();
        let mut cols: Vec<usize> =
            workers.into_iter().map(|w| w.join().unwrap().cols).collect();
        cols.sort_unstable();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "second tile waited on the head's deadline"
        );
        assert_eq!(cols, vec![16, 16], "both flushed tiles are key B");
        assert_eq!(b.queued_rows(), 4 + 60, "head and the partial tile wait");
        b.close();
    }

    #[test]
    fn zero_row_requests_are_served_not_leaked() {
        // A zero-row request contributes nothing to the running counts,
        // so its group entry can vanish while it still queues (here:
        // behind an oversized same-key request that flushes alone). It
        // must still be served, and the counters must drain to zero.
        let b: Batcher<usize> = Batcher::new(BatchPolicy {
            max_rows: 64,
            max_wait: Duration::from_millis(2),
            queue_limit: 1000,
        });
        assert!(b.submit(dt(), mat(100, 8), 2, Mode::EXACT, 0));
        assert!(b.submit(dt(), mat(0, 8), 2, Mode::EXACT, 1));
        let big = b.next_batch().unwrap();
        assert_eq!(big.total_rows, 100);
        assert_eq!(big.items.len(), 1);
        let empty = b.next_batch().unwrap();
        assert_eq!(empty.items[0].reply, 1);
        assert_eq!(empty.total_rows, 0);
        assert_eq!(b.queued_rows(), 0);
        assert_eq!(b.group_rows_outstanding(), 0);
    }

    #[test]
    fn stress_multi_producer_no_loss_duplication_or_leak() {
        // 4 producers x 60 requests of mixed sizes/keys/tenants against
        // 2 consumers, with a queue limit small enough to exercise
        // backpressure. Every reply token must come back exactly once,
        // every batch must respect the key grouping (including the
        // tenant dimension) and the row budget (unless it is a
        // dedicated oversized batch), and both row counters —
        // queued_rows and the per-key running counts — must reconcile
        // to 0 at drain (no double-counting).
        const PRODUCERS: usize = 4;
        const PER_PRODUCER: usize = 60;
        let policy = BatchPolicy {
            max_rows: 64,
            max_wait: Duration::from_micros(200),
            queue_limit: 256,
        };
        let b: Arc<Batcher<usize>> = Arc::new(Batcher::with_weights(
            policy,
            vec![(tid("t0"), 3), (tid("t1"), 1)],
        ));
        let seen = Arc::new(std::sync::Mutex::new(Vec::<usize>::new()));

        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let b = b.clone();
                let seen = seen.clone();
                std::thread::spawn(move || {
                    while let Some(batch) = b.next_batch() {
                        let rows: usize =
                            batch.items.iter().map(|p| p.matrix.rows).sum();
                        assert_eq!(rows, batch.total_rows, "row accounting");
                        if batch.items.len() > 1 {
                            assert!(
                                batch.total_rows <= 64,
                                "multi-request batch over budget: {}",
                                batch.total_rows
                            );
                        }
                        for p in &batch.items {
                            assert_eq!(p.tenant, batch.tenant);
                            assert_eq!(p.matrix.cols, batch.cols);
                            assert_eq!(p.k, batch.k);
                            assert_eq!(p.mode, batch.mode);
                        }
                        let mut g = seen.lock().unwrap();
                        g.extend(batch.items.iter().map(|p| p.reply));
                    }
                })
            })
            .collect();

        let producers: Vec<_> = (0..PRODUCERS)
            .map(|t| {
                let b = b.clone();
                std::thread::spawn(move || {
                    for i in 0..PER_PRODUCER {
                        // sizes 1..=20 plus an occasional oversized 100;
                        // two cols keys and two tenants to exercise
                        // grouping
                        let rows = if i % 17 == 0 { 100 } else { 1 + (i * 7) % 20 };
                        let cols = if i % 3 == 0 { 16 } else { 8 };
                        let tenant = if i % 2 == 0 { tid("t0") } else { tid("t1") };
                        assert!(b.submit(
                            tenant,
                            mat(rows, cols),
                            2,
                            Mode::EXACT,
                            t * 1000 + i
                        ));
                    }
                })
            })
            .collect();

        for p in producers {
            p.join().unwrap();
        }
        b.close();
        for c in consumers {
            c.join().unwrap();
        }
        let mut got = seen.lock().unwrap().clone();
        got.sort_unstable();
        let mut want: Vec<usize> = (0..PRODUCERS)
            .flat_map(|t| (0..PER_PRODUCER).map(move |i| t * 1000 + i))
            .collect();
        want.sort_unstable();
        assert_eq!(got, want, "requests lost or duplicated");
        assert_eq!(b.queued_rows(), 0, "queued_rows leaked");
        assert_eq!(
            b.group_rows_outstanding(),
            0,
            "per-key running counts leaked"
        );
    }

    #[test]
    fn per_request_deadline_overrides_the_wait_and_splits_the_group() {
        // Same shape, one request with a 40ms deadline against a 60s
        // policy wait: the deadline'd request must not share a group
        // with (or wait behind) the default-wait one — it flushes alone
        // at half its budget while the default request keeps waiting.
        let b: Batcher<usize> = Batcher::new(BatchPolicy {
            max_rows: 1_000_000,
            max_wait: Duration::from_secs(60),
            queue_limit: 10_000,
        });
        assert!(b.submit(dt(), mat(5, 8), 2, Mode::EXACT, 0));
        let deadlined = Enqueue {
            deadline: Some(Duration::from_millis(40)),
            ..Enqueue::basic(dt(), mat(7, 8), 2, Mode::EXACT)
        };
        assert!(b.submit_request(deadlined, 1).is_ok());
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        let waited = t0.elapsed();
        assert!(
            waited < Duration::from_secs(1),
            "deadline'd request waited on the policy deadline: {waited:?}"
        );
        assert!(
            waited >= Duration::from_millis(15),
            "flush should wait ~half the budget (20ms), got {waited:?}"
        );
        assert_eq!(batch.items.len(), 1, "deadline splits the group");
        assert_eq!(batch.items[0].reply, 1);
        assert_eq!(batch.total_rows, 7);
        assert_eq!(b.queued_rows(), 5, "default request keeps waiting");
        b.close();
        assert_eq!(b.next_batch().unwrap().items[0].reply, 0);
        assert_eq!(b.group_rows_outstanding(), 0);
    }

    #[test]
    fn short_deadline_behind_a_long_head_still_flushes_first() {
        // The old head-deadline rule would sleep on the head's wait; a
        // later-submitted request with a short per-request deadline
        // must wake the worker and flush first.
        let b: Arc<Batcher<usize>> = Arc::new(Batcher::new(BatchPolicy {
            max_rows: 1_000_000,
            max_wait: Duration::from_secs(60),
            queue_limit: 10_000,
        }));
        b.submit(dt(), mat(4, 8), 2, Mode::EXACT, 0); // head, 60s wait
        let b2 = b.clone();
        let worker = std::thread::spawn(move || b2.next_batch().unwrap());
        std::thread::sleep(Duration::from_millis(20)); // worker parks on 60s
        let urgent = Enqueue {
            deadline: Some(Duration::from_millis(30)),
            ..Enqueue::basic(dt(), mat(9, 16), 2, Mode::EXACT)
        };
        assert!(b.submit_request(urgent, 1).is_ok());
        let batch = worker.join().unwrap();
        assert_eq!(batch.items[0].reply, 1, "urgent request flushes first");
        assert_eq!(b.queued_rows(), 4);
        b.close();
    }

    #[test]
    fn evict_cancelled_removes_requests_and_fixes_accounting() {
        // A cancelled request must leave the queue (and its row
        // accounting) immediately when evicted, while co-members of
        // the same group keep flushing normally.
        let b: Batcher<usize> = Batcher::new(BatchPolicy {
            max_rows: 64,
            max_wait: Duration::from_secs(60),
            queue_limit: 1000,
        });
        let doomed = Enqueue::basic(dt(), mat(10, 8), 2, Mode::EXACT);
        let token = doomed.cancel.clone();
        assert!(b.submit_request(doomed, 0).is_ok());
        assert!(b.submit(dt(), mat(5, 8), 2, Mode::EXACT, 1));
        assert!(b.evict_cancelled().is_empty(), "nothing cancelled yet");
        token.cancel();
        let evicted = b.evict_cancelled();
        assert_eq!(evicted.len(), 1);
        assert_eq!(evicted[0].reply, 0);
        assert_eq!(b.queued_rows(), 5, "cancelled rows freed");
        assert_eq!(b.group_rows_outstanding(), 5);
        // the surviving co-member still flushes
        b.close();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.items.len(), 1);
        assert_eq!(batch.items[0].reply, 1);
        assert!(b.next_batch().is_none(), "evicted entry pruned cleanly");
        assert_eq!(b.group_rows_outstanding(), 0);
    }

    #[test]
    fn priority_scales_the_wdrr_quantum() {
        // Equal weights, both tenants saturated with full tiles; the
        // high-priority tenant's refill is 4x, so it drains 4 tiles per
        // rotation to the normal tenant's 1.
        let b: Batcher<usize> = Batcher::new(BatchPolicy {
            max_rows: 64,
            max_wait: Duration::from_secs(600),
            queue_limit: 1 << 20,
        });
        for i in 0..12 {
            let hi = Enqueue {
                priority: Priority::High,
                ..Enqueue::basic(tid("hi"), mat(64, 8), 2, Mode::EXACT)
            };
            assert!(b.submit_request(hi, i).is_ok());
            assert!(b.submit(tid("lo"), mat(64, 8), 2, Mode::EXACT, 100 + i));
        }
        let mut hi_batches = 0usize;
        let mut lo_batches = 0usize;
        for _ in 0..10 {
            let batch = b.next_batch().unwrap();
            assert_eq!(batch.total_rows, 64);
            if batch.tenant == tid("hi") {
                hi_batches += 1;
            } else {
                lo_batches += 1;
            }
        }
        assert_eq!(
            (hi_batches, lo_batches),
            (8, 2),
            "high priority drains 4 of every 5 tiles at equal weight"
        );
        b.close();
    }

    #[test]
    fn queue_gauges_report_rows_requests_and_deadline_slack() {
        let b: Batcher<usize> = Batcher::new(BatchPolicy {
            max_rows: 1_000_000,
            max_wait: Duration::from_secs(60),
            queue_limit: 10_000,
        });
        assert_eq!(b.queue_gauges(), QueueGauges::default());
        assert!(b.submit(dt(), mat(40, 8), 2, Mode::EXACT, 0));
        let g = b.queue_gauges();
        assert_eq!(g.queued_rows, 40);
        assert_eq!(g.queued_requests, 1);
        assert_eq!(g.min_slack_us, None, "no deadline'd request queued");
        let urgent = Enqueue {
            deadline: Some(Duration::from_secs(2)),
            expire_at: Some(Instant::now() + Duration::from_secs(2)),
            ..Enqueue::basic(dt(), mat(7, 8), 2, Mode::EXACT)
        };
        assert!(b.submit_request(urgent, 1).is_ok());
        let g = b.queue_gauges();
        assert_eq!(g.queued_rows, 47);
        assert_eq!(g.queued_requests, 2);
        let slack = g.min_slack_us.expect("deadline'd request sets slack");
        assert!(
            slack > 1_000_000 && slack <= 2_000_000,
            "slack should be ~2s, got {slack} us"
        );
        b.close();
    }

    #[test]
    fn backpressure_blocks_until_drain() {
        let b: Arc<Batcher<usize>> = Arc::new(Batcher::new(BatchPolicy {
            max_rows: 8,
            max_wait: Duration::from_millis(1),
            queue_limit: 10,
        }));
        b.submit(dt(), mat(10, 4), 1, Mode::EXACT, 0); // fills the queue
        let b2 = b.clone();
        let producer = std::thread::spawn(move || {
            // blocks until the worker drains, then succeeds
            b2.submit(dt(), mat(10, 4), 1, Mode::EXACT, 1)
        });
        std::thread::sleep(Duration::from_millis(20));
        assert!(!producer.is_finished(), "submit should be backpressured");
        let _ = b.next_batch().unwrap(); // drain
        assert!(producer.join().unwrap());
        b.close();
    }
}
