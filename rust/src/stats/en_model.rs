//! Appendix A: closed-form expected iteration count of Algorithm 1.
//!
//! For a length-M vector of i.i.d. N(mu, sigma^2) elements, the paper
//! derives (Eq. 4):
//!
//! ```text
//! E(n) ~= log2( 2 M sqrt(ln M / pi) )
//!         - (1 / (2 ln 2)) * ( Phi^{-1}(1 - k/M) )^2
//! ```
//!
//! independent of (mu, sigma). Table 5's bottom row compares this to
//! measurement; `benches/table5_exit_full.rs` regenerates both sides.

use crate::stats::normal::norm_ppf;

/// Eq. 4: expected binary-search iterations for (M, k).
pub fn expected_iterations(m: usize, k: usize) -> f64 {
    assert!(k >= 1 && k < m, "model needs 1 <= k < M, got k={k} M={m}");
    let mf = m as f64;
    let kf = k as f64;
    let lead = (2.0 * mf * (mf.ln() / std::f64::consts::PI).sqrt()).log2();
    let z = norm_ppf(1.0 - kf / mf);
    lead - z * z / (2.0 * std::f64::consts::LN_2)
}

/// Eq. 3: expected initial bracket width D ~ 2 sigma sqrt(2 ln M).
pub fn expected_initial_bracket(m: usize, sigma: f64) -> f64 {
    2.0 * sigma * (2.0 * (m as f64).ln()).sqrt()
}

/// Eq. 1: expected selection threshold for (M, k) under N(mu, sigma^2).
pub fn expected_threshold(m: usize, k: usize, mu: f64, sigma: f64) -> f64 {
    mu + sigma * norm_ppf(1.0 - k as f64 / m as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table 5 bottom row: E(n) for selected (M, k).
    #[test]
    fn matches_paper_table5_values() {
        // (M, k, E(n) from the paper)
        let cases = [
            (256, 64, 9.08),
            (256, 128, 9.41),
            (1024, 64, 9.87),
            (1024, 128, 10.62),
            (1024, 256, 11.24),
            (1024, 512, 11.57),
            (4096, 64, 10.36),
            (4096, 512, 12.75),
            (8192, 64, 10.54),
            (8192, 512, 13.06),
        ];
        for (m, k, want) in cases {
            let got = expected_iterations(m, k);
            assert!(
                (got - want).abs() < 0.02,
                "E(n) for M={m} k={k}: got {got:.3}, paper {want}"
            );
        }
    }

    #[test]
    fn monotone_in_m_for_fixed_ratio() {
        // larger M at the same k/M ratio needs more iterations
        let a = expected_iterations(256, 64);
        let b = expected_iterations(1024, 256);
        let c = expected_iterations(8192, 2048);
        assert!(a < b && b < c);
    }

    #[test]
    fn symmetric_k_term() {
        // the Phi^{-1} correction vanishes at k = M/2 -> maximal E(n)
        let mid = expected_iterations(1024, 512);
        for &k in &[64usize, 128, 256, 960] {
            assert!(expected_iterations(1024, k) <= mid + 1e-12);
        }
    }

    #[test]
    fn bracket_grows_slowly() {
        let d1 = expected_initial_bracket(256, 1.0);
        let d2 = expected_initial_bracket(8192, 1.0);
        assert!(d1 < d2 && d2 < d1 * 1.5);
    }

    #[test]
    fn threshold_location() {
        // k = M/2 -> threshold at the mean (erfc-limited accuracy ~1e-7)
        let t = expected_threshold(1000, 500, 3.0, 2.0);
        assert!((t - 3.0).abs() < 1e-6);
        // small k -> threshold in the upper tail
        assert!(expected_threshold(1000, 10, 0.0, 1.0) > 2.0);
    }
}
