//! CPU GNN compute substrate.
//!
//! Two jobs:
//!
//! 1. **Baseline compute path** — real SpMM / dense matmul / MaxK-SpMM
//!    implementations that execute the MaxK-GNN forward pass without
//!    XLA, validated against the PJRT path in integration tests.
//! 2. **Table 4's timing decomposition** — measure what fraction of a
//!    training step row-wise top-k accounts for, with the *sort-based*
//!    top-k standing in for the pre-RTop-K operator (what MaxK-GNN
//!    would use without the paper's kernel), exactly as the paper's
//!    "Top-k Prop(%)" column is defined.

pub mod compressed;
pub mod ops;
pub mod profile;

pub use compressed::{maxk_compress, spmm_compressed, CompressedRows};
pub use ops::{matmul, relu_inplace, spmm_csr};
pub use profile::{profile_train_step, StepProfile};
