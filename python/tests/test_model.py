"""L2 correctness: MaxK-GNN models — shapes, gradients, convergence.

Uses tiny-sim shapes throughout (256 nodes) so the suite stays fast on
one core. The convergence test generates a proper SBM-style task (the
same construction the Rust `graph` module uses) and checks the loss
actually drops and accuracy beats chance in a handful of steps.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import datasets, model

jax.config.update("jax_platform_name", "cpu")


def make_sbm(spec: model.ModelSpec, seed: int = 0):
    """SBM-style labeled graph matching the dataset spec's shapes.

    Mirrors rust/src/graph/generate.rs: labels uniform over classes,
    ~60% of edges intra-class, features = class centroid + noise,
    symmetric-norm edge weights.
    """
    g = spec.graph
    rng = np.random.default_rng(seed)
    n, e, f, c = g.num_nodes, g.num_edges, g.feat_dim, g.num_classes
    labels = rng.integers(0, c, n).astype(np.int32)
    by_class = [np.flatnonzero(labels == i) for i in range(c)]
    src = np.empty(e, np.int32)
    dst = np.empty(e, np.int32)
    for i in range(e):
        d = rng.integers(0, n)
        dst[i] = d
        if rng.random() < 0.6 and len(by_class[labels[d]]) > 0:
            src[i] = rng.choice(by_class[labels[d]])
        else:
            src[i] = rng.integers(0, n)
    deg = np.bincount(dst, minlength=n) + 1
    w = (1.0 / np.sqrt(deg[src] * deg[dst])).astype(np.float32)
    centroids = rng.standard_normal((c, f)).astype(np.float32)
    feats = (centroids[labels] * 1.5
             + rng.standard_normal((n, f))).astype(np.float32)
    r = rng.random(n)
    train = (r < 0.5).astype(np.float32)
    val = ((r >= 0.5) & (r < 0.7)).astype(np.float32)
    test = (r >= 0.7).astype(np.float32)
    return src, dst, w, feats, labels, train, val, test


@pytest.fixture(scope="module")
def tiny_graph():
    spec = model.ModelSpec(model="gcn", dataset="tiny-sim")
    return make_sbm(spec)


@pytest.mark.parametrize("m", model.MODELS)
def test_forward_shapes(m, tiny_graph):
    spec = model.ModelSpec(model=m, dataset="tiny-sim")
    src, dst, w, feats, labels, train, val, test = tiny_graph
    params = model.init_params(spec)
    logits = model.forward(spec, params, src, dst, w, feats)
    g = spec.graph
    assert logits.shape == (g.num_nodes, g.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("m", model.MODELS)
def test_param_shapes_consistent(m):
    spec = model.ModelSpec(model=m, dataset="tiny-sim")
    shapes = model.param_shapes(spec)
    params = model.init_params(spec)
    assert len(params) == len(shapes)
    for p, (_, s) in zip(params, shapes):
        assert p.shape == s and p.dtype == jnp.float32


@pytest.mark.parametrize("m", model.MODELS)
def test_gradients_finite_and_nonzero(m, tiny_graph):
    spec = model.ModelSpec(model=m, dataset="tiny-sim", topk_mode="exact")
    src, dst, w, feats, labels, train, val, test = tiny_graph
    params = model.init_params(spec)

    def loss_fn(ps):
        loss, _ = model.loss_and_acc(spec, ps, src, dst, w, feats, labels,
                                     train)
        return loss

    grads = jax.grad(loss_fn)(params)
    for g, (name, _) in zip(grads, model.param_shapes(spec)):
        assert bool(jnp.all(jnp.isfinite(g))), f"{name} grad not finite"
    total = sum(float(jnp.sum(jnp.abs(g))) for g in grads)
    assert total > 0, "all-zero gradient"


def test_train_step_decreases_loss(tiny_graph):
    spec = model.ModelSpec(model="gcn", dataset="tiny-sim",
                           topk_mode="early_stop", max_iter=4, lr=0.05)
    src, dst, w, feats, labels, train, val, test = tiny_graph
    fn, _ = model.make_train_fn(spec)
    jfn = jax.jit(fn)
    params = model.init_params(spec)
    mom = model.init_momentum(spec)
    n = len(params)
    out = jfn(*params, *mom, src, dst, w, feats, labels, train)
    first_loss = float(out[-2])
    for _ in range(30):
        out = jfn(*out[:2 * n], src, dst, w, feats, labels, train)
    last_loss, last_acc = float(out[-2]), float(out[-1])
    assert last_loss < first_loss * 0.9, (first_loss, last_loss)
    g = spec.graph
    assert last_acc > 2.0 / g.num_classes  # well above chance


def test_eval_step_outputs(tiny_graph):
    spec = model.ModelSpec(model="gcn", dataset="tiny-sim")
    src, dst, w, feats, labels, train, val, test = tiny_graph
    fn, _ = model.make_eval_fn(spec)
    params = model.init_params(spec)
    vl, va, tl, ta = jax.jit(fn)(*params, src, dst, w, feats, labels, val,
                                 test)
    for v in (vl, va, tl, ta):
        assert v.shape == () and bool(jnp.isfinite(v))
    assert 0.0 <= float(va) <= 1.0 and 0.0 <= float(ta) <= 1.0


def test_early_stop_mode_close_to_exact(tiny_graph):
    """Fig 5's claim in miniature: early-stop training tracks exact."""
    src, dst, w, feats, labels, train, val, test = tiny_graph
    accs = {}
    for mode, it in (("exact", 0), ("early_stop", 3)):
        spec = model.ModelSpec(model="gcn", dataset="tiny-sim",
                               topk_mode=mode, max_iter=it or 4, lr=0.05)
        fn, _ = model.make_train_fn(spec)
        jfn = jax.jit(fn)
        params = model.init_params(spec, seed=1)
        mom = model.init_momentum(spec)
        n = len(params)
        out = jfn(*params, *mom, src, dst, w, feats, labels, train)
        for _ in range(40):
            out = jfn(*out[:2 * n], src, dst, w, feats, labels, train)
        accs[mode] = float(out[-1])
    assert abs(accs["exact"] - accs["early_stop"]) < 0.25, accs


def test_relu_ablation_runs(tiny_graph):
    spec = model.ModelSpec(model="gcn", dataset="tiny-sim", use_maxk=False)
    src, dst, w, feats, labels, train, val, test = tiny_graph
    params = model.init_params(spec)
    logits = model.forward(spec, params, src, dst, w, feats)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_model_spec_validation():
    with pytest.raises(ValueError):
        model.ModelSpec(model="mlp", dataset="tiny-sim")
    with pytest.raises(KeyError):
        model.ModelSpec(model="gcn", dataset="nope")


def test_spec_tags_unique():
    tags = set()
    for m in model.MODELS:
        for mode, it in (("exact", 4), ("early_stop", 2),
                         ("early_stop", 8)):
            t = model.ModelSpec(model=m, dataset="tiny-sim",
                                topk_mode=mode, max_iter=it).tag()
            assert t not in tags
            tags.add(t)
