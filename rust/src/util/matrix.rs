//! Dense row-major f32 matrix storage — the unit of work for row-wise
//! top-k (N rows of length M) and the host-side mirror of PJRT buffers.

use crate::util::rng::Rng;

/// Row-major dense matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct RowMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl RowMatrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        RowMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize,
                   mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        RowMatrix { rows, cols, data }
    }

    /// Wrap an existing buffer (len must equal rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        RowMatrix { rows, cols, data }
    }

    /// i.i.d. standard-normal entries — the paper's evaluation
    /// distribution for every kernel table/figure.
    pub fn random_normal(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let mut m = RowMatrix::zeros(rows, cols);
        rng.fill_normal(&mut m.data);
        m
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// Split rows into contiguous chunks of at most `chunk` rows
    /// (the batcher's tiling primitive).
    pub fn row_chunks(&self, chunk: usize) -> impl Iterator<Item = (usize, &[f32])> {
        let cols = self.cols;
        self.data
            .chunks(chunk * cols)
            .enumerate()
            .map(move |(i, d)| (i * chunk, d))
    }

    /// Copy rows [start, start+len) into a new matrix, zero-padding to
    /// `len` rows if the source ends early (service tile padding).
    pub fn slice_rows_padded(&self, start: usize, len: usize) -> RowMatrix {
        let mut out = RowMatrix::zeros(len, self.cols);
        let avail = self.rows.saturating_sub(start).min(len);
        let src = &self.data[start * self.cols..(start + avail) * self.cols];
        out.data[..src.len()].copy_from_slice(src);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_accessors() {
        let m = RowMatrix::from_fn(3, 4, |r, c| (r * 10 + c) as f32);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
        assert_eq!(m.get(2, 3), 23.0);
    }

    #[test]
    fn chunks_cover_all_rows() {
        let m = RowMatrix::from_fn(10, 2, |r, _| r as f32);
        let total: usize = m.row_chunks(3).map(|(_, d)| d.len() / 2).sum();
        assert_eq!(total, 10);
        let starts: Vec<usize> = m.row_chunks(3).map(|(s, _)| s).collect();
        assert_eq!(starts, vec![0, 3, 6, 9]);
    }

    #[test]
    fn slice_rows_padded_pads_with_zeros() {
        let m = RowMatrix::from_fn(3, 2, |r, c| (r + c) as f32 + 1.0);
        let s = m.slice_rows_padded(2, 4);
        assert_eq!(s.rows, 4);
        assert_eq!(s.row(0), m.row(2));
        assert!(s.row(1).iter().all(|&v| v == 0.0));
        assert!(s.row(3).iter().all(|&v| v == 0.0));
    }

    #[test]
    #[should_panic(expected = "buffer/shape mismatch")]
    fn from_vec_checks_len() {
        RowMatrix::from_vec(2, 2, vec![0.0; 3]);
    }
}
