//! The typed request API: [`SubmitRequest`] (what a caller asks for)
//! and [`TopKTicket`] (the handle they hold while the service works).
//!
//! The service's submission surface used to be four positional-argument
//! `submit*` variants; every new per-request knob would have required a
//! fifth. `SubmitRequest` is the single self-describing form instead: a
//! builder over matrix + k plus the per-request *policy* — mode, tenant,
//! an end-to-end deadline, a WDRR [priority](Priority) class, a
//! [validation](ValidationPolicy) override, and the
//! [over-quota](OverQuotaPolicy) behavior. Being plain data (no
//! channels, no handles), it is also exactly what the wire codec
//! (`crate::coordinator::wire`) serializes for the future
//! network-ingestion and sharding layers.
//!
//! A submission returns a [`TopKTicket`]: `wait` / `wait_timeout` /
//! `try_wait` to collect the result, and [`TopKTicket::cancel()`] to
//! abandon it — a cancelled request still queued is dropped by the
//! scheduler (its admission reservation released, a `cancelled` error
//! delivered); one already mid-flight completes but the reply is
//! discarded.

use crate::coordinator::tenant::TenantId;
use crate::topk::types::{Mode, TopKResult};
use crate::util::matrix::RowMatrix;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Per-request scheduling class. Priority feeds the batcher's
/// weighted-deficit round-robin as a *quantum multiplier*: while a
/// tenant's front-of-queue tile carries this priority, the tenant's
/// credit refill per rotation is scaled by it. [`Priority::Normal`] is
/// exactly the pre-priority behavior (multiplier 1); `High` refills 4x
/// (the tenant drains up to 4 tiles per rotation where it drained 1);
/// `Low` refills at half rate. Priority never reorders requests within
/// a tenant (FIFO per group holds) and never outranks a deadline flush.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Priority {
    /// half the normal WDRR refill — bulk work that should yield
    Low,
    /// the default: exactly the weight-proportional WDRR share
    #[default]
    Normal,
    /// 4x the normal WDRR refill — latency-sensitive interactive work
    High,
}

impl Priority {
    /// Scale a tenant's WDRR refill quantum by this priority. The
    /// result is always >= 1 so a low-priority tenant still accrues
    /// credit every rotation (a zero quantum could never reach the
    /// serve threshold and would spin the pick loop).
    pub(crate) fn scale_quantum(self, quantum: i64) -> i64 {
        match self {
            Priority::Low => (quantum / 2).max(1),
            Priority::Normal => quantum,
            Priority::High => quantum.saturating_mul(4),
        }
    }

    /// Stable name (CLI flags, wire tooling output).
    pub fn name(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }

    /// Inverse of [`Priority::name`].
    pub fn parse(s: &str) -> Result<Priority, String> {
        match s {
            "low" => Ok(Priority::Low),
            "normal" => Ok(Priority::Normal),
            "high" => Ok(Priority::High),
            other => Err(format!(
                "unknown priority {other:?} (expected low | normal | high)"
            )),
        }
    }
}

/// Per-request input-validation override. The service-wide default is
/// `[serve] validate_inputs`; a request can force the scan on or off
/// for itself alone.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum ValidationPolicy {
    /// follow the service's `[serve] validate_inputs` setting
    #[default]
    Inherit,
    /// always scan this request's matrix for non-finite values
    Strict,
    /// skip the scan for this request (caller guarantees finiteness)
    Skip,
}

/// What to do when the tenant is over its admission quota.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum OverQuotaPolicy {
    /// shed: reject with a positioned error before queueing (the
    /// pre-existing behavior, and the service default)
    #[default]
    Reject,
    /// cooperate: block the submitting thread (FIFO per tenant, bounded
    /// by `[serve] max_blocked_waiters`) until quota frees, the
    /// request's deadline expires, or the service shuts down
    Block,
}

impl OverQuotaPolicy {
    /// Stable name (`[serve] over_quota_policy` values).
    pub fn name(self) -> &'static str {
        match self {
            OverQuotaPolicy::Reject => "reject",
            OverQuotaPolicy::Block => "block",
        }
    }

    /// Inverse of [`OverQuotaPolicy::name`].
    pub fn parse(s: &str) -> Result<OverQuotaPolicy, String> {
        match s {
            "reject" => Ok(OverQuotaPolicy::Reject),
            "block" => Ok(OverQuotaPolicy::Block),
            other => Err(format!(
                "unknown over-quota policy {other:?} (expected reject | block)"
            )),
        }
    }
}

/// Shared cancellation + queue-residency flags for one request. Cloned
/// between the caller's [`TopKTicket`] and the copy travelling through
/// the batcher, so a `cancel()` is visible to the scheduler wherever
/// the request currently sits.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<TicketFlags>);

#[derive(Debug, Default)]
struct TicketFlags {
    cancelled: AtomicBool,
    /// true while the request sits in the batcher queue — the lazy-
    /// deletion marker for the batcher's deadline heap
    queued: AtomicBool,
}

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Request cancellation. Idempotent; takes effect at the next point
    /// the scheduler inspects the request.
    pub fn cancel(&self) {
        self.0.cancelled.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.cancelled.load(Ordering::Acquire)
    }

    /// Queue-residency marker maintained by the batcher (set on
    /// enqueue, cleared when the request leaves in a batch) so stale
    /// deadline-heap entries can be pruned without scanning the queue.
    pub(crate) fn mark_queued(&self, queued: bool) {
        self.0.queued.store(queued, Ordering::Release);
    }

    pub(crate) fn is_queued(&self) -> bool {
        self.0.queued.load(Ordering::Acquire)
    }
}

/// One typed top-k submission: the matrix and `k`, plus every
/// per-request policy knob. Build with [`SubmitRequest::new`] and the
/// chainable setters, then hand to `TopKService::submit` (sync) or
/// `TopKService::submit_ticket` (async).
///
/// ```no_run
/// use rtopk::coordinator::{Priority, SubmitRequest, TopKService};
/// use rtopk::topk::types::Mode;
/// use rtopk::util::matrix::RowMatrix;
/// use std::time::Duration;
///
/// let svc = TopKService::cpu_only(&Default::default()).unwrap();
/// let req = SubmitRequest::new(RowMatrix::zeros(64, 256), 32)
///     .mode(Mode::EarlyStop { max_iter: 4 })
///     .tenant("interactive")
///     .priority(Priority::High)
///     .deadline(Duration::from_millis(20));
/// let result = svc.submit(req).unwrap();
/// assert_eq!(result.k, 32);
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct SubmitRequest {
    /// the input rows (one top-k selection per row)
    pub matrix: RowMatrix,
    /// elements to select per row
    pub k: usize,
    /// search mode; `None` uses the tenant's configured default mode,
    /// else [`Mode::EXACT`]
    pub mode: Option<Mode>,
    /// the tenant this request runs as (admission quotas, WDRR weight,
    /// per-tenant overrides); defaults to the anonymous default tenant
    pub tenant: TenantId,
    /// end-to-end latency budget measured from submission. Caps the
    /// batcher's wait for this request at `min(max_wait, remaining/2)`
    /// — a deadline can only shorten batching, and half of whatever
    /// budget is left at enqueue stays reserved for execution and
    /// delivery — and is enforced at dispatch and delivery: an
    /// expired request is answered with a positioned timeout error,
    /// never served stale work. `None` = no per-request deadline.
    pub deadline: Option<Duration>,
    /// WDRR drain-priority class (see [`Priority`])
    pub priority: Priority,
    /// per-request input-validation override (see [`ValidationPolicy`])
    pub validation: ValidationPolicy,
    /// over-quota behavior; `None` uses the service's configured
    /// default (`[serve] over_quota_policy`, itself defaulting to
    /// [`OverQuotaPolicy::Reject`])
    pub over_quota: Option<OverQuotaPolicy>,
}

impl SubmitRequest {
    /// A request with every policy at its default: tenant-default (or
    /// exact) mode, anonymous tenant, no deadline, normal priority,
    /// service-default validation and over-quota behavior.
    pub fn new(matrix: RowMatrix, k: usize) -> SubmitRequest {
        SubmitRequest {
            matrix,
            k,
            mode: None,
            tenant: TenantId::default(),
            deadline: None,
            priority: Priority::Normal,
            validation: ValidationPolicy::Inherit,
            over_quota: None,
        }
    }

    /// Set an explicit search mode (overrides the tenant default).
    pub fn mode(mut self, mode: Mode) -> Self {
        self.mode = Some(mode);
        self
    }

    /// Run as a named tenant.
    pub fn tenant(mut self, name: &str) -> Self {
        self.tenant = TenantId::new(name);
        self
    }

    /// Set the end-to-end deadline (see the field docs for semantics).
    pub fn deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Set the WDRR priority class.
    pub fn priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    /// Override the service's input-validation setting for this
    /// request.
    pub fn validation(mut self, policy: ValidationPolicy) -> Self {
        self.validation = policy;
        self
    }

    /// Choose the over-quota behavior for this request.
    pub fn on_over_quota(mut self, policy: OverQuotaPolicy) -> Self {
        self.over_quota = Some(policy);
        self
    }
}

/// The caller's handle to a pending submission.
pub struct TopKTicket {
    rx: mpsc::Receiver<Result<TopKResult>>,
    cancel: CancelToken,
    /// run after the cancel flag is set — the service hooks the
    /// batcher's cancelled-request eviction here so a cancelled
    /// request frees its quota and queue space immediately instead of
    /// pinning both until its group's scheduled flush
    on_cancel: Option<Arc<dyn Fn() + Send + Sync>>,
}

impl TopKTicket {
    pub(crate) fn new(
        rx: mpsc::Receiver<Result<TopKResult>>,
        cancel: CancelToken,
    ) -> TopKTicket {
        TopKTicket { rx, cancel, on_cancel: None }
    }

    /// Attach the eviction hook invoked by [`TopKTicket::cancel()`].
    pub(crate) fn with_cancel_hook(
        mut self,
        hook: Arc<dyn Fn() + Send + Sync>,
    ) -> TopKTicket {
        self.on_cancel = Some(hook);
        self
    }

    /// Block for the result (or the request's error: validation,
    /// execution, cancellation, deadline timeout).
    pub fn wait(self) -> Result<TopKResult> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("service dropped the request"))?
    }

    /// Block for at most `timeout`. `None` means the request is still
    /// in flight — the ticket stays usable; `Some` is the final
    /// outcome, including the "service dropped the request" error when
    /// the reply channel disconnected without an answer.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<Result<TopKResult>> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => Some(r),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Some(Err(anyhow!("service dropped the request")))
            }
        }
    }

    /// Non-blocking poll. `None` means still in flight. A disconnected
    /// reply channel surfaces the "service dropped the request" error —
    /// it must never read as "still pending" forever (regression:
    /// `try_recv().ok()` swallowed the disconnect).
    pub fn try_wait(&self) -> Option<Result<TopKResult>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(anyhow!("service dropped the request")))
            }
        }
    }

    /// Cancel the request. A request still queued is evicted promptly
    /// (admission reservation released, queue space freed, a
    /// `cancelled` error delivered to this ticket); one already
    /// executing completes but its reply is discarded (a `cancelled`
    /// error is delivered instead of the result). Idempotent.
    pub fn cancel(&self) {
        self.cancel.cancel();
        if let Some(hook) = &self.on_cancel {
            hook();
        }
    }

    /// Whether [`TopKTicket::cancel()`] has been called.
    pub fn is_cancelled(&self) -> bool {
        self.cancel.is_cancelled()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_and_setters() {
        let req = SubmitRequest::new(RowMatrix::zeros(2, 4), 2);
        assert_eq!(req.k, 2);
        assert_eq!(req.mode, None);
        assert_eq!(req.tenant, TenantId::default());
        assert_eq!(req.deadline, None);
        assert_eq!(req.priority, Priority::Normal);
        assert_eq!(req.validation, ValidationPolicy::Inherit);
        assert_eq!(req.over_quota, None);
        let req = req
            .mode(Mode::EarlyStop { max_iter: 4 })
            .tenant("team-a")
            .deadline(Duration::from_millis(5))
            .priority(Priority::High)
            .validation(ValidationPolicy::Skip)
            .on_over_quota(OverQuotaPolicy::Block);
        assert_eq!(req.mode, Some(Mode::EarlyStop { max_iter: 4 }));
        assert_eq!(req.tenant.as_str(), "team-a");
        assert_eq!(req.deadline, Some(Duration::from_millis(5)));
        assert_eq!(req.priority, Priority::High);
        assert_eq!(req.validation, ValidationPolicy::Skip);
        assert_eq!(req.over_quota, Some(OverQuotaPolicy::Block));
    }

    #[test]
    fn priority_quantum_scaling() {
        assert_eq!(Priority::Normal.scale_quantum(100), 100);
        assert_eq!(Priority::High.scale_quantum(100), 400);
        assert_eq!(Priority::Low.scale_quantum(100), 50);
        // the low-priority refill never reaches zero (a zero quantum
        // would spin the WDRR pick loop forever)
        assert_eq!(Priority::Low.scale_quantum(1), 1);
        // and high-priority scaling saturates instead of wrapping
        assert!(Priority::High.scale_quantum(i64::MAX) > 0);
    }

    #[test]
    fn names_roundtrip() {
        for p in [Priority::Low, Priority::Normal, Priority::High] {
            assert_eq!(Priority::parse(p.name()).unwrap(), p);
        }
        assert!(Priority::parse("urgent").is_err());
        for q in [OverQuotaPolicy::Reject, OverQuotaPolicy::Block] {
            assert_eq!(OverQuotaPolicy::parse(q.name()).unwrap(), q);
        }
        assert!(OverQuotaPolicy::parse("queue").is_err());
    }

    #[test]
    fn cancel_token_flags() {
        let t = CancelToken::new();
        assert!(!t.is_cancelled());
        let clone = t.clone();
        clone.cancel();
        assert!(t.is_cancelled(), "cancellation is shared across clones");
        t.mark_queued(true);
        assert!(clone.is_queued());
        t.mark_queued(false);
        assert!(!clone.is_queued());
    }

    #[test]
    fn try_wait_surfaces_a_dropped_reply_channel() {
        // Regression: `try_recv().ok()` returned None forever when the
        // service dropped the reply sender — a poller could never learn
        // its request died. The disconnect must surface the same error
        // `wait` reports.
        let (tx, rx) = mpsc::channel();
        let ticket = TopKTicket::new(rx, CancelToken::new());
        assert!(ticket.try_wait().is_none(), "still pending while tx lives");
        drop(tx);
        match ticket.try_wait() {
            Some(Err(e)) => {
                assert!(format!("{e:#}").contains("dropped"), "got: {e:#}")
            }
            other => panic!(
                "disconnect must surface an error, got {:?}",
                other.map(|r| r.map(|_| ()))
            ),
        }
    }

    #[test]
    fn wait_timeout_times_out_then_delivers() {
        let (tx, rx) = mpsc::channel();
        let ticket = TopKTicket::new(rx, CancelToken::new());
        assert!(
            ticket.wait_timeout(Duration::from_millis(1)).is_none(),
            "nothing sent yet"
        );
        tx.send(Ok(TopKResult::zeros(1, 1))).unwrap();
        match ticket.wait_timeout(Duration::from_secs(5)) {
            Some(Ok(res)) => assert_eq!(res.rows, 1),
            other => panic!("expected the result, got {:?}", other.map(|r| r.map(|_| ()))),
        }
        // sender gone, nothing buffered: the disconnect is an error,
        // not an eternal timeout
        drop(tx);
        match ticket.wait_timeout(Duration::from_millis(1)) {
            Some(Err(e)) => {
                assert!(format!("{e:#}").contains("dropped"), "got: {e:#}")
            }
            other => panic!(
                "disconnect must surface an error, got {:?}",
                other.map(|r| r.map(|_| ()))
            ),
        }
    }
}
