"""Layer-2 JAX models: MaxK-GNN (GCN / GraphSAGE / GIN).

The paper's Fig. 1 workflow per hidden layer:

    linear  ->  row-wise top-k (MaxK nonlinearity, the L1 kernel)
            ->  sparse aggregation (SpMM with the top-k-compressed rhs)

Here the aggregation is an edge-list ``segment_sum`` over a padded edge
list (static shapes for AOT; padded edges carry weight 0), and the MaxK
nonlinearity is :func:`compile.kernels.maxk` — the Pallas kernel with a
straight-through gradient — so ``jax.grad`` differentiates the whole
step and one fused HLO module contains forward + backward + optimizer.

Everything is shaped by ``ModelSpec`` and flattened into a fixed-order
list of f32 arrays; the Rust runtime round-trips that list through PJRT
buffer-by-buffer (see artifacts/manifest.json and rust/src/runtime/).

Optimizer: SGD with momentum (lr, mu baked into the artifact). This
keeps the round-tripped state at one extra array per parameter and is
sufficient for the synthetic tasks to converge in a few hundred steps.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp

from . import datasets
from .kernels import maxk

MODELS = ("gcn", "sage", "gin")


@dataclass(frozen=True)
class ModelSpec:
    """Static configuration of one MaxK-GNN variant (AOT contract)."""

    model: str  # "gcn" | "sage" | "gin"
    dataset: str  # key into datasets.SPECS
    hidden: int = datasets.HIDDEN_DIM
    k: int = datasets.TOPK_K
    layers: int = datasets.NUM_LAYERS
    # top-k mode baked into the artifact: "exact" or "early_stop"
    topk_mode: str = "early_stop"
    max_iter: int = 4
    eps_rel: float = 1e-16
    lr: float = 0.01
    momentum: float = 0.9
    # set False to replace MaxK by plain ReLU (ablation baseline)
    use_maxk: bool = True
    # "rtopk" = the paper's Pallas kernel; "sort" = lax.top_k (XLA's
    # sort-based selection — the torch.topk stand-in Fig 5 compares
    # against)
    topk_impl: str = "rtopk"

    def __post_init__(self):
        if self.model not in MODELS:
            raise ValueError(f"unknown model {self.model!r}")
        if self.topk_impl not in ("rtopk", "sort"):
            raise ValueError(f"unknown topk_impl {self.topk_impl!r}")
        datasets.get(self.dataset)  # validate

    @property
    def graph(self) -> datasets.GraphSpec:
        return datasets.get(self.dataset)

    def tag(self) -> str:
        """Stable artifact name component."""
        mode = (
            f"es{self.max_iter}" if self.topk_mode == "early_stop" else "exact"
        )
        if self.topk_impl == "sort":
            mode = "sortk"
        if not self.use_maxk:
            mode = "relu"
        return f"{self.model}_{self.dataset}_h{self.hidden}_k{self.k}_{mode}"


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def _glorot(key, fan_in, fan_out):
    scale = jnp.sqrt(2.0 / (fan_in + fan_out)).astype(jnp.float32)
    return jax.random.normal(key, (fan_in, fan_out), jnp.float32) * scale


def param_shapes(spec: ModelSpec) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) list — the flat-params ABI shared with Rust.

    GCN   layer: W (in, hidden)
    SAGE  layer: W_self (in, hidden), W_neigh (in, hidden)
    GIN   layer: W (in, hidden), W_mlp (hidden, hidden)
    head: W_out (hidden, classes), b_out (classes,)
    """
    g = spec.graph
    shapes: list[tuple[str, tuple[int, ...]]] = []
    dim_in = g.feat_dim
    for layer in range(spec.layers):
        if spec.model == "gcn":
            shapes.append((f"l{layer}.w", (dim_in, spec.hidden)))
        elif spec.model == "sage":
            shapes.append((f"l{layer}.w_self", (dim_in, spec.hidden)))
            shapes.append((f"l{layer}.w_neigh", (dim_in, spec.hidden)))
        else:  # gin
            shapes.append((f"l{layer}.w", (dim_in, spec.hidden)))
            shapes.append((f"l{layer}.w_mlp", (spec.hidden, spec.hidden)))
        shapes.append((f"l{layer}.b", (spec.hidden,)))
        dim_in = spec.hidden
    shapes.append(("head.w", (spec.hidden, g.num_classes)))
    shapes.append(("head.b", (g.num_classes,)))
    return shapes


def init_params(spec: ModelSpec, seed: int = 0) -> list[jax.Array]:
    """Glorot-initialized flat parameter list in `param_shapes` order."""
    shapes = param_shapes(spec)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(shapes))
    out = []
    for key, (name, shape) in zip(keys, shapes):
        if len(shape) == 2:
            out.append(_glorot(key, *shape))
        else:
            out.append(jnp.zeros(shape, jnp.float32))
    return out


def init_momentum(spec: ModelSpec) -> list[jax.Array]:
    return [jnp.zeros(s, jnp.float32) for _, s in param_shapes(spec)]


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _aggregate(src, dst, w, x, num_nodes):
    """Weighted edge-list SpMM (see kernels.ref.spmm_ref)."""
    return jax.ops.segment_sum(x[src] * w[:, None], dst,
                               num_segments=num_nodes)


def _sort_topk_mask(z: jax.Array, k: int) -> jax.Array:
    """Top-k mask via a full row sort — the generic sort-based selection
    baseline. Deliberately built from the classic HLO `sort` op (not
    `lax.top_k`, whose TopK custom-op text the runtime's xla_extension
    0.5.1 parser cannot read). Ties break by index, matching lax.top_k.
    """
    n, m = z.shape
    idx = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32)[None, :], (n, m))
    # sort by descending value (ascending -z), carrying the column index
    _, si = jax.lax.sort((-z, idx), num_keys=1)
    top = si[:, :k]  # (n, k) winning columns
    rows = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, k))
    mask = jnp.zeros((n, m), z.dtype)
    return mask.at[rows, top].set(1.0)


def _maxk_sort(z: jax.Array, k: int) -> jax.Array:
    """Sort-based MaxK — the torch.topk stand-in Fig 5's training
    speed-up is measured against. Same straight-through gradient as the
    Pallas path."""

    @jax.custom_vjp
    def _m(z_):
        return z_ * _sort_topk_mask(z_, k)

    def fwd(z_):
        mask = _sort_topk_mask(z_, k)
        return z_ * mask, mask

    def bwd(mask, g):
        return (g * mask,)

    _m.defvjp(fwd, bwd)
    return _m(z)


def _nonlin(spec: ModelSpec, z: jax.Array) -> jax.Array:
    """MaxK (the paper's nonlinearity) or ReLU for the ablation baseline."""
    if not spec.use_maxk:
        return jax.nn.relu(z)
    if spec.topk_impl == "sort":
        return _maxk_sort(z, spec.k)
    return maxk(
        z,
        spec.k,
        mode=spec.topk_mode,  # type: ignore[arg-type]
        max_iter=spec.max_iter,
        eps_rel=spec.eps_rel,
    )


def forward(spec: ModelSpec, params: list[jax.Array], src, dst, w,
            feats) -> jax.Array:
    """Logits (N, C) for one MaxK-GNN variant.

    Edge weights ``w`` carry the aggregation semantics the Rust side
    generated: GCN uses symmetric-norm weights, SAGE mean weights
    (1/deg_dst), GIN unit weights — so one forward body serves all three
    with their canonical aggregators.
    """
    g = spec.graph
    h = feats
    i = 0
    for layer in range(spec.layers):
        if spec.model == "gcn":
            wl = params[i]; i += 1
            b = params[i]; i += 1
            z = h @ wl + b
            z = _nonlin(spec, z)
            h = _aggregate(src, dst, w, z, g.num_nodes)
        elif spec.model == "sage":
            w_self = params[i]; i += 1
            w_neigh = params[i]; i += 1
            b = params[i]; i += 1
            z = _nonlin(spec, h @ w_self + b)
            agg = _aggregate(src, dst, w, h @ w_neigh, g.num_nodes)
            h = z + agg
        else:  # gin: (1 + eps) * z + sum-agg(z), then 1-layer MLP
            wl = params[i]; i += 1
            w_mlp = params[i]; i += 1
            b = params[i]; i += 1
            z = _nonlin(spec, h @ wl + b)
            agg = _aggregate(src, dst, w, z, g.num_nodes)
            h = jax.nn.relu((1.0 + 0.1) * z + agg) @ w_mlp
    w_out, b_out = params[i], params[i + 1]
    return h @ w_out + b_out


def loss_and_acc(spec: ModelSpec, params, src, dst, w, feats, labels,
                 mask):
    """Masked softmax cross-entropy + accuracy over ``mask`` nodes."""
    logits = forward(spec, params, src, dst, w, feats)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    correct = (jnp.argmax(logits, axis=1) == labels).astype(jnp.float32)
    acc = jnp.sum(correct * mask) / denom
    return loss, acc


# ---------------------------------------------------------------------------
# Train / eval steps (the functions that get AOT-lowered)
# ---------------------------------------------------------------------------


def train_step(spec: ModelSpec, params: list[jax.Array],
               momentum: list[jax.Array], src, dst, w, feats, labels,
               train_mask):
    """One SGD-with-momentum step; returns (params', momentum', loss, acc).

    This is the request-path unit: Rust feeds the previous step's output
    buffers straight back in (device-resident round-trip, no host copies
    besides the loss/acc scalars it logs).
    """

    def loss_fn(ps):
        return loss_and_acc(spec, ps, src, dst, w, feats, labels,
                            train_mask)

    (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    mu = jnp.float32(spec.momentum)
    lr = jnp.float32(spec.lr)
    new_m = [mu * m + g for m, g in zip(momentum, grads)]
    new_p = [p - lr * m for p, m in zip(params, new_m)]
    return new_p, new_m, loss, acc


def eval_step(spec: ModelSpec, params, src, dst, w, feats, labels,
              val_mask, test_mask):
    """Returns (val_loss, val_acc, test_loss, test_acc)."""
    logits = forward(spec, params, src, dst, w, feats)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    correct = (jnp.argmax(logits, axis=1) == labels).astype(jnp.float32)

    def masked(msk):
        d = jnp.maximum(jnp.sum(msk), 1.0)
        return jnp.sum(nll * msk) / d, jnp.sum(correct * msk) / d

    vl, va = masked(val_mask)
    tl, ta = masked(test_mask)
    return vl, va, tl, ta


def graph_input_specs(spec: ModelSpec):
    """ShapeDtypeStructs of the graph inputs, in ABI order."""
    g = spec.graph
    e = g.num_edges
    return dict(
        src=jax.ShapeDtypeStruct((e,), jnp.int32),
        dst=jax.ShapeDtypeStruct((e,), jnp.int32),
        w=jax.ShapeDtypeStruct((e,), jnp.float32),
        feats=jax.ShapeDtypeStruct((g.num_nodes, g.feat_dim), jnp.float32),
        labels=jax.ShapeDtypeStruct((g.num_nodes,), jnp.int32),
        mask=jax.ShapeDtypeStruct((g.num_nodes,), jnp.float32),
    )


def make_train_fn(spec: ModelSpec) -> tuple[Callable, list]:
    """(flat_fn, example_args) for AOT lowering of the train step.

    Flat signature: (p_0..p_P-1, m_0..m_P-1, src, dst, w, feats, labels,
    train_mask) -> (p'_0..p'_P-1, m'_0..m'_P-1, loss, acc).
    """
    shapes = param_shapes(spec)
    n = len(shapes)
    gi = graph_input_specs(spec)

    def flat(*args):
        params = list(args[:n])
        mom = list(args[n:2 * n])
        src, dst, w, feats, labels, train_mask = args[2 * n:]
        new_p, new_m, loss, acc = train_step(spec, params, mom, src, dst,
                                             w, feats, labels, train_mask)
        return tuple(new_p) + tuple(new_m) + (loss, acc)

    p_specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in shapes]
    example = (p_specs + p_specs
               + [gi["src"], gi["dst"], gi["w"], gi["feats"], gi["labels"],
                  gi["mask"]])
    return flat, example


def make_eval_fn(spec: ModelSpec) -> tuple[Callable, list]:
    """(flat_fn, example_args) for AOT lowering of the eval step."""
    shapes = param_shapes(spec)
    n = len(shapes)
    gi = graph_input_specs(spec)

    def flat(*args):
        params = list(args[:n])
        src, dst, w, feats, labels, val_mask, test_mask = args[n:]
        return eval_step(spec, params, src, dst, w, feats, labels,
                         val_mask, test_mask)

    p_specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in shapes]
    example = (p_specs + [gi["src"], gi["dst"], gi["w"], gi["feats"],
                          gi["labels"], gi["mask"], gi["mask"]])
    return flat, example


__all__ = [
    "MODELS",
    "ModelSpec",
    "param_shapes",
    "init_params",
    "init_momentum",
    "forward",
    "loss_and_acc",
    "train_step",
    "eval_step",
    "make_train_fn",
    "make_eval_fn",
    "graph_input_specs",
]
