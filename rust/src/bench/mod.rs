//! Bench harness helpers shared by `rust/benches/*` (criterion is not in
//! the vendored crate set; each bench is a `harness = false` binary that
//! prints the corresponding paper table/figure).

use crate::stats::summary::IntHistogram;
use crate::topk::binary_search::{search_early_stop, search_exact};
use crate::topk::rowwise::{rowwise_topk_with, RowAlgo};
use crate::topk::types::Mode;
use crate::util::matrix::RowMatrix;
use crate::util::rng::Rng;
use crate::util::timer::{time_adaptive, Timing};
use std::time::Duration;

/// Standard workload: N x M i.i.d. standard-normal rows (the paper's
/// evaluation distribution throughout).
pub fn workload(n: usize, m: usize, seed: u64) -> RowMatrix {
    let mut rng = Rng::seed_from(seed);
    RowMatrix::random_normal(n, m, &mut rng)
}

/// Time one row-wise top-k configuration on a workload.
pub fn time_algo(x: &RowMatrix, k: usize, algo: RowAlgo) -> Timing {
    time_adaptive(3, Duration::from_millis(300), || {
        std::hint::black_box(rowwise_topk_with(x, k, algo));
    })
}

/// Exit-iteration histogram for Algorithm 1 over `trials` fresh rows
/// (Tables 1 and 5). Returns the histogram of `iters` at exit.
pub fn exit_iteration_histogram(m: usize, k: usize, eps_rel: f32,
                                trials: usize, seed: u64) -> IntHistogram {
    let mut rng = Rng::seed_from(seed);
    let mut h = IntHistogram::new();
    let mut row = vec![0f32; m];
    for _ in 0..trials {
        rng.fill_normal(&mut row);
        let s = search_exact(&row, k, eps_rel, 64);
        h.record(s.iters as usize);
    }
    h
}

/// Markdown-ish table printer: header + aligned rows.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n## {}\n\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {c:>w$} |", w = w));
            }
            s.push('\n');
            s
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Early-stop trailer used by several benches: average exit iterations
/// under Algorithm 2 is exactly max_iter (hard bound) — helper asserts
/// the invariant in debug harnesses.
pub fn early_stop_iters(m: usize, k: usize, max_iter: u32, seed: u64) -> u32 {
    let mut rng = Rng::seed_from(seed);
    let mut row = vec![0f32; m];
    rng.fill_normal(&mut row);
    search_early_stop(&row, k, max_iter).iters
}

/// Parse a mode string ("exact", "eps1e-4", "es4", "apx950") for bench
/// CLIs and the `[tenants.<name>] mode` knob.
pub fn parse_mode(s: &str) -> Result<Mode, String> {
    if s == "exact" {
        return Ok(Mode::EXACT);
    }
    if let Some(it) = s.strip_prefix("es") {
        let max_iter: u32 = it.parse().map_err(|_| format!("bad mode {s:?}"))?;
        return Ok(Mode::EarlyStop { max_iter });
    }
    if let Some(eps) = s.strip_prefix("eps") {
        let eps_rel: f32 = eps.parse().map_err(|_| format!("bad mode {s:?}"))?;
        return Ok(Mode::Exact { eps_rel });
    }
    if let Some(rm) = s.strip_prefix("apx") {
        let recall_milli: u16 =
            rm.parse().map_err(|_| format!("bad mode {s:?}"))?;
        if recall_milli == 0 || recall_milli > 1000 {
            return Err(format!(
                "mode {s:?}: recall target must be in 1..=1000 thousandths"
            ));
        }
        return Ok(Mode::Approx { recall_milli });
    }
    Err(format!(
        "unknown mode {s:?} (expected exact | es<N> | eps<X> | apx<N>)"
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_is_deterministic() {
        assert_eq!(workload(4, 8, 1).data, workload(4, 8, 1).data);
        assert_ne!(workload(4, 8, 1).data, workload(4, 8, 2).data);
    }

    #[test]
    fn histogram_mean_matches_en_model_ballpark() {
        // Table 5: M=256, k=64, eps=0 -> avg 8.72 (paper), E(n)=9.08.
        // Derandomized (fixed seed 7); bounds widened to +-1.5 around
        // the paper's value because the mean is RNG-stream dependent
        // (see iteration_count_matches_paper_ballpark in
        // topk::binary_search for the full justification) — the
        // assertion still catches a broken exit condition, which moves
        // the mean to ~1 or toward the 64 cap.
        let h = exit_iteration_histogram(256, 64, 0.0, 2000, 7);
        let avg = h.mean();
        assert!((7.2..10.2).contains(&avg), "avg {avg}");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("## T"));
        assert!(r.contains("| 1 |"));
    }

    #[test]
    fn parse_modes() {
        assert_eq!(parse_mode("exact").unwrap(), Mode::EXACT);
        assert_eq!(parse_mode("es4").unwrap(), Mode::EarlyStop { max_iter: 4 });
        assert!(matches!(parse_mode("eps1e-4").unwrap(), Mode::Exact { .. }));
        assert_eq!(
            parse_mode("apx950").unwrap(),
            Mode::Approx { recall_milli: 950 }
        );
        assert_eq!(
            parse_mode("apx1000").unwrap(),
            Mode::Approx { recall_milli: 1000 }
        );
        assert!(parse_mode("apx0").is_err(), "zero recall is no contract");
        assert!(parse_mode("apx1001").is_err(), "recall cannot exceed 1");
        assert!(parse_mode("apx").is_err());
        assert!(parse_mode("wat").is_err());
    }

    #[test]
    fn early_stop_iteration_bound() {
        assert_eq!(early_stop_iters(64, 8, 5, 3), 5);
    }
}
