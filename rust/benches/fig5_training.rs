//! Figure 5: overall training speed-up of RTop-K over the sort-based
//! top-k baseline, and test accuracy across early-stopping settings,
//! per model/dataset (N = #nodes, M = 256, k = 32).
//!
//! Speed-up = per-step wall time of the `sortk` artifact (lax.top_k,
//! XLA's generic selection — the torch.topk stand-in) over the RTop-K
//! artifact at each max_iter. Accuracy from the same runs. Needs
//! `make artifacts` (default set: gcn on all datasets + all models on
//! flickr-sim; ARTIFACT_SET=full adds the es2..es8 sweep).

use rtopk::bench::Table;
use rtopk::coordinator::Trainer;
use rtopk::runtime::executor::Executor;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("fig5_training: run `make artifacts` first");
        return;
    }
    let quick = std::env::var("RTOPK_QUICK").is_ok();
    let steps = if quick { 10 } else { 20 };
    let exec = Executor::spawn("artifacts").unwrap();
    let manifest = exec.handle().manifest().clone();

    // find every (model, dataset) with a sortk baseline artifact
    let mut combos: Vec<(String, String)> = Vec::new();
    for a in manifest.of_kind("train_step") {
        if a.name.ends_with("_sortk") {
            let model = a.meta_str("model").unwrap_or("?").to_string();
            let dataset = a.meta_str("dataset").unwrap_or("?").to_string();
            if dataset != "tiny-sim" {
                combos.push((model, dataset));
            }
        }
    }
    combos.sort();

    let mut t = Table::new(
        &format!("Fig 5: training speed-up vs sort-topk + test accuracy ({steps} steps, M=256, k=32)"),
        &["model", "dataset", "variant", "ms/step", "speed-up %", "test acc %"],
    );
    for (model, dataset) in combos {
        // baseline
        let base_tag = format!("{model}_{dataset}_h256_k32_sortk");
        let Ok(mut base) = Trainer::new(exec.handle(), &base_tag, 42) else {
            continue;
        };
        let base_out = base.train(steps, 0, |_, _, _| {}).unwrap();
        let base_ms = base_out.per_step.as_secs_f64() * 1e3;
        t.row(vec![
            model.clone(),
            dataset.clone(),
            "sortk (baseline)".into(),
            format!("{base_ms:.1}"),
            "-".into(),
            format!("{:.2}", base_out.final_test_acc * 100.0),
        ]);
        // rtopk variants present in the manifest
        for variant in ["exact", "es2", "es3", "es4", "es5", "es6", "es7", "es8"] {
            let tag = format!("{model}_{dataset}_h256_k32_{variant}");
            if manifest.get(&format!("train_{tag}")).is_err() {
                continue;
            }
            let mut tr = Trainer::new(exec.handle(), &tag, 42).unwrap();
            let out = tr.train(steps, 0, |_, _, _| {}).unwrap();
            let ms = out.per_step.as_secs_f64() * 1e3;
            t.row(vec![
                model.clone(),
                dataset.clone(),
                variant.into(),
                format!("{ms:.1}"),
                format!("{:+.2}", (base_ms / ms - 1.0) * 100.0),
                format!("{:.2}", out.final_test_acc * 100.0),
            ]);
        }
    }
    t.print();
    println!("\npaper (Fig 5): training speed-up 11.97% (Reddit) .. 33.29% (Flickr);\n\
              test accuracy under early stopping fluctuates around the exact-top-k accuracy.");
}
