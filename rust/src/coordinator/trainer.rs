//! Trainer: drives the AOT train/eval step artifacts end-to-end —
//! generates the dataset, initializes parameters, loops steps with
//! parameter round-trips, evaluates, and reports timing + accuracy
//! (the engine behind `rtopk train`, `examples/gnn_training.rs` and the
//! Fig. 5 bench).

use crate::coordinator::metrics::Metrics;
use crate::graph::datasets::{self, GraphData};
use crate::runtime::executor::ExecutorHandle;
use crate::runtime::manifest::ArtifactInfo;
use crate::runtime::tensor::HostTensor;
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Result};
use std::time::{Duration, Instant};

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub steps: usize,
    pub losses: Vec<f32>,
    pub train_accs: Vec<f32>,
    pub final_val_acc: f32,
    pub final_test_acc: f32,
    pub wall: Duration,
    pub per_step: Duration,
}

/// Training orchestrator over one (model, dataset, topk-mode) artifact
/// pair. Parameter and optimizer state stay in host tensors between
/// steps (the CPU PJRT client keeps buffers host-side anyway; on an
/// accelerator these would be donated device buffers).
pub struct Trainer {
    exec: ExecutorHandle,
    train_name: String,
    eval_name: String,
    n_params: usize,
    graph: GraphData,
    params: Vec<HostTensor>,
    momentum: Vec<HostTensor>,
    pub metrics: Metrics,
}

impl Trainer {
    /// Build a trainer for artifact tag `tag` (e.g.
    /// "gcn_flickr-sim_h256_k32_es4"); expects `train_<tag>` and
    /// `eval_<tag>` in the manifest.
    pub fn new(exec: ExecutorHandle, tag: &str, seed: u64) -> Result<Trainer> {
        let train_name = format!("train_{tag}");
        let eval_name = format!("eval_{tag}");
        let info = exec.manifest().get(&train_name)?.clone();
        exec.manifest().get(&eval_name)?;
        let dataset = info
            .meta_str("dataset")
            .ok_or_else(|| anyhow!("{train_name}: meta missing dataset"))?
            .to_string();
        let param_shapes = param_shapes_from_meta(&info)?;
        let n_params = param_shapes.len();
        // ABI: 2P + 6 inputs
        if info.inputs.len() != 2 * n_params + 6 {
            bail!(
                "{train_name}: manifest ABI mismatch ({} inputs, {} params)",
                info.inputs.len(),
                n_params
            );
        }
        let graph = datasets::build(&dataset, seed)
            .ok_or_else(|| anyhow!("unknown dataset {dataset:?}"))?;
        let mut rng = Rng::seed_from(seed ^ 0x5EED);
        let params = init_params(&param_shapes, &mut rng);
        let momentum = param_shapes
            .iter()
            .map(|s| HostTensor::f32(vec![0.0; s.iter().product::<usize>().max(1)], s))
            .collect();
        Ok(Trainer {
            exec,
            train_name,
            eval_name,
            n_params,
            graph,
            params,
            momentum,
            metrics: Metrics::default(),
        })
    }

    pub fn graph(&self) -> &GraphData {
        &self.graph
    }

    fn graph_inputs(&self, mask: &[f32]) -> Vec<HostTensor> {
        let g = &self.graph;
        vec![
            HostTensor::i32(g.src_i32(), &[g.src.len()]),
            HostTensor::i32(g.dst_i32(), &[g.dst.len()]),
            HostTensor::f32(g.weights.clone(), &[g.weights.len()]),
            HostTensor::f32(g.feats.clone(), &[g.num_nodes, g.feat_dim]),
            HostTensor::i32(g.labels_i32(), &[g.num_nodes]),
            HostTensor::f32(mask.to_vec(), &[g.num_nodes]),
        ]
    }

    /// One optimizer step; returns (loss, train-batch accuracy).
    pub fn step(&mut self) -> Result<(f32, f32)> {
        let mut inputs = Vec::with_capacity(2 * self.n_params + 6);
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.momentum.iter().cloned());
        inputs.extend(self.graph_inputs(&self.graph.train_mask.clone()));
        let t0 = Instant::now();
        let mut out = self.exec.execute(&self.train_name, inputs)?;
        self.metrics.record_request(self.graph.num_nodes, t0.elapsed());
        if out.len() != 2 * self.n_params + 2 {
            bail!("{}: unexpected output arity {}", self.train_name, out.len());
        }
        let acc = out.pop().unwrap().into_f32()?[0];
        let loss = out.pop().unwrap().into_f32()?[0];
        let momentum = out.split_off(self.n_params);
        self.params = out;
        self.momentum = momentum;
        Ok((loss, acc))
    }

    /// Evaluate: (val_loss, val_acc, test_loss, test_acc).
    pub fn evaluate(&self) -> Result<(f32, f32, f32, f32)> {
        let g = &self.graph;
        let mut inputs = Vec::with_capacity(self.n_params + 7);
        inputs.extend(self.params.iter().cloned());
        inputs.push(HostTensor::i32(g.src_i32(), &[g.src.len()]));
        inputs.push(HostTensor::i32(g.dst_i32(), &[g.dst.len()]));
        inputs.push(HostTensor::f32(g.weights.clone(), &[g.weights.len()]));
        inputs.push(HostTensor::f32(g.feats.clone(), &[g.num_nodes, g.feat_dim]));
        inputs.push(HostTensor::i32(g.labels_i32(), &[g.num_nodes]));
        inputs.push(HostTensor::f32(g.val_mask.clone(), &[g.num_nodes]));
        inputs.push(HostTensor::f32(g.test_mask.clone(), &[g.num_nodes]));
        let out = self.exec.execute(&self.eval_name, inputs)?;
        Ok((
            out[0].as_f32()?[0],
            out[1].as_f32()?[0],
            out[2].as_f32()?[0],
            out[3].as_f32()?[0],
        ))
    }

    /// Run a full training loop with periodic logging via `log`.
    pub fn train(&mut self, steps: usize, eval_every: usize,
                 mut log: impl FnMut(usize, f32, f32)) -> Result<TrainOutcome> {
        let t0 = Instant::now();
        let mut losses = Vec::with_capacity(steps);
        let mut accs = Vec::with_capacity(steps);
        for s in 0..steps {
            let (loss, acc) = self.step()?;
            if !loss.is_finite() {
                bail!("loss diverged at step {s}");
            }
            losses.push(loss);
            accs.push(acc);
            if eval_every > 0 && (s + 1) % eval_every == 0 {
                log(s + 1, loss, acc);
            }
        }
        let (_, val_acc, _, test_acc) = self.evaluate()?;
        let wall = t0.elapsed();
        Ok(TrainOutcome {
            steps,
            per_step: wall / steps.max(1) as u32,
            losses,
            train_accs: accs,
            final_val_acc: val_acc,
            final_test_acc: test_acc,
            wall,
        })
    }
}

/// Glorot-normal initialization matching the L2 model's scheme
/// (matrices ~ N(0, 2/(fan_in+fan_out)); vectors zero).
fn init_params(shapes: &[Vec<usize>], rng: &mut Rng) -> Vec<HostTensor> {
    shapes
        .iter()
        .map(|s| {
            let n: usize = s.iter().product::<usize>().max(1);
            let data = if s.len() == 2 {
                let scale = (2.0 / (s[0] + s[1]) as f64).sqrt() as f32;
                (0..n).map(|_| rng.normal_f32() * scale).collect()
            } else {
                vec![0.0; n]
            };
            HostTensor::f32(data, s)
        })
        .collect()
}

fn param_shapes_from_meta(info: &ArtifactInfo) -> Result<Vec<Vec<usize>>> {
    use crate::util::json::Value;
    let shapes = info
        .meta
        .get("param_shapes")
        .and_then(Value::as_array)
        .ok_or_else(|| anyhow!("{}: meta missing param_shapes", info.name))?;
    shapes
        .iter()
        .map(|s| {
            s.as_array()
                .ok_or_else(|| anyhow!("bad param shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect()
        })
        .collect()
}

// Integration-tested in rust/tests/trainer.rs against real artifacts.
