//! The paper's contribution: binary-search top-k over one row.
//!
//! Semantics are pinned by `python/compile/kernels/ref.py` — this file,
//! the Pallas kernel and the jnp oracle must agree decision-for-decision
//! in f32 arithmetic:
//!
//! * bracket midpoint: `thres = 0.5 * (lo + hi)` in f32,
//! * count predicate: `v >= thres`,
//! * exact mode (Algorithm 1): loop while `hi - lo > eps` and
//!   `cnt != k`, with `eps = eps_rel * max(v)` when `max(v) > 0`
//!   (the paper's line 3, verbatim on its assumed positive-activation
//!   domain) and `eps = eps_rel * max(|max(v)|, |min(v)|)` otherwise —
//!   the paper's formula goes negative/zero for non-positive maxima,
//!   which silently disabled the bracket-width exit (see the
//!   regression tests below). Selection thresholds are
//!   `(thres, thres)` on a `cnt == k` exit and `(hi, lo)` on a bracket
//!   exit (tie-safe — the last midpoint can land exactly on a tie
//!   value),
//! * early-stop mode (Algorithm 2): exactly `max_iter` iterations,
//!   `cnt < k -> hi = thres` else `lo = thres`; selection at the final
//!   `lo` ("min" in the paper), one pass.
//!
//! Selection is the unified two-mask ranking: first-k-by-index elements
//! `>= t1`, supplemented by first elements in `[t2, t1)`. The invariant
//! `|{v >= t2}| >= k` holds in both modes (t2 only ever moves to a
//! threshold whose count was >= k), so exactly k elements always emerge.
//!
//! ## Input contract: no NaNs
//!
//! [`min_max`] and [`count_ge`] use branchless float compares for SIMD
//! autovectorization; IEEE comparisons with NaN are always false, so a
//! NaN element would silently corrupt the bracket and the counts rather
//! than fail loudly. Rows must be NaN-free: this is a *caller
//! contract* for direct library users — in-crate producers (workload
//! generators, GNN activations) are finite by construction, and the
//! service boundary enforces it for external clients:
//! `TopKService::submit` rejects non-finite matrices with a clear
//! error unless the operator opts out via `[serve] validate_inputs =
//! false`. Callers bypassing the service should scan their inputs
//! first if they can carry NaNs. Infinities are likewise unsupported
//! (the midpoint `0.5 * (lo + hi)` would be NaN for opposite-sign
//! infinities).

use crate::topk::types::Mode;

/// Final state of the search phase for one row.
#[derive(Clone, Copy, Debug)]
pub struct SearchOut {
    /// primary selection threshold (t1)
    pub t1: f32,
    /// secondary/supplement threshold (t2 <= t1)
    pub t2: f32,
    /// loop iterations executed (Tables 1 and 5 histogram this)
    pub iters: u32,
}

/// Algorithm 1's search loop. `iter_cap` bounds convergence (64 halvings
/// exhaust f32 resolution from any initial bracket).
pub fn search_exact(row: &[f32], k: usize, eps_rel: f32, iter_cap: u32) -> SearchOut {
    debug_assert!(k >= 1 && k <= row.len());
    let (mut lo, mut hi) = min_max(row);
    // Paper line 3 is `eps = eps' * max(v)`, which goes *negative* (or
    // zero) when the row max is non-positive — the bracket-width exit
    // then never fires and such rows burn the full iteration cap
    // (worst case: a constant negative row spins `iter_cap` times on a
    // zero-width bracket). Keep the paper's formula verbatim on its
    // assumed domain (positive activations) so the configured relative
    // tolerance is unchanged there, and fall back to the bracket
    // magnitude only when the max cannot scale it.
    let eps = eps_rel * if hi > 0.0 { hi } else { hi.abs().max(lo.abs()) };
    let mut thres = lo;
    let mut cnt = row.len();
    let mut iters = 0u32;
    while iters < iter_cap && hi - lo > eps && cnt != k {
        thres = 0.5 * (lo + hi);
        cnt = count_ge(row, thres);
        if cnt < k {
            hi = thres;
        } else if cnt > k {
            lo = thres;
        }
        iters += 1;
    }
    if cnt == k {
        SearchOut { t1: thres, t2: thres, iters }
    } else {
        SearchOut { t1: hi, t2: lo, iters }
    }
}

/// Algorithm 2's search loop: exactly `max_iter` iterations, one-pass
/// selection threshold = final lo.
pub fn search_early_stop(row: &[f32], k: usize, max_iter: u32) -> SearchOut {
    debug_assert!(k >= 1 && k <= row.len());
    let (mut lo, mut hi) = min_max(row);
    for _ in 0..max_iter {
        let thres = 0.5 * (lo + hi);
        let cnt = count_ge(row, thres);
        if cnt < k {
            hi = thres;
        } else {
            lo = thres;
        }
    }
    SearchOut { t1: lo, t2: lo, iters: max_iter }
}

/// Count of elements >= t — the hot inner loop. Eight independent i32
/// accumulators over fixed-width chunks give the autovectorizer a
/// straight-line SIMD reduction (a single sequential `cnt +=` chain
/// defeats it); see EXPERIMENTS.md §Perf L3-1.
///
/// NaN elements are unsupported (module-level input contract): a NaN
/// compares false against any threshold and would be silently dropped
/// from every count.
#[inline]
pub fn count_ge(row: &[f32], t: f32) -> usize {
    let mut acc = [0i32; 8];
    let chunks = row.chunks_exact(8);
    let rem = chunks.remainder();
    for c in chunks {
        for i in 0..8 {
            acc[i] += (c[i] >= t) as i32;
        }
    }
    let mut cnt: i32 = acc.iter().sum();
    for &v in rem {
        cnt += (v >= t) as i32;
    }
    cnt as usize
}

/// Row min/max in one pass, SIMD-friendly (branchless f32 select; rows
/// are finite by construction — NaN inputs are documented unsupported
/// at module level: a NaN loses every `<`/`>` compare and would leave
/// the bracket at whatever the NaN-free prefix produced).
#[inline]
pub fn min_max(row: &[f32]) -> (f32, f32) {
    let mut lo = [f32::INFINITY; 8];
    let mut hi = [f32::NEG_INFINITY; 8];
    let chunks = row.chunks_exact(8);
    let rem = chunks.remainder();
    for c in chunks {
        for i in 0..8 {
            lo[i] = if c[i] < lo[i] { c[i] } else { lo[i] };
            hi[i] = if c[i] > hi[i] { c[i] } else { hi[i] };
        }
    }
    let (mut l, mut h) = (lo[0], hi[0]);
    for i in 1..8 {
        l = if lo[i] < l { lo[i] } else { l };
        h = if hi[i] > h { hi[i] } else { h };
    }
    for &v in rem {
        l = if v < l { v } else { l };
        h = if v > h { v } else { h };
    }
    (l, h)
}

/// The paper's selecting stage: write the first k elements `>= t1` (by
/// index), then supplement with the first elements in `[t2, t1)`.
/// Two passes over the row, no writes besides the k outputs.
pub fn select_row(
    row: &[f32],
    k: usize,
    s: SearchOut,
    vals: &mut [f32],
    idx: &mut [u32],
) {
    debug_assert_eq!(vals.len(), k);
    debug_assert_eq!(idx.len(), k);
    let mut w = 0usize;
    // pass 1: threshold survivors
    for (j, &v) in row.iter().enumerate() {
        if v >= s.t1 {
            vals[w] = v;
            idx[w] = j as u32;
            w += 1;
            if w == k {
                return;
            }
        }
    }
    // pass 2: borderline supplements in [t2, t1)
    for (j, &v) in row.iter().enumerate() {
        if v >= s.t2 && v < s.t1 {
            vals[w] = v;
            idx[w] = j as u32;
            w += 1;
            if w == k {
                return;
            }
        }
    }
    debug_assert_eq!(w, k, "selection invariant violated");
}

/// One row end-to-end: search (per `mode`) + selection.
/// Returns the search output (for iteration statistics).
pub fn rtopk_row(
    row: &[f32],
    k: usize,
    mode: Mode,
    vals: &mut [f32],
    idx: &mut [u32],
) -> SearchOut {
    let s = match mode {
        Mode::Exact { eps_rel } => search_exact(row, k, eps_rel, 64),
        Mode::EarlyStop { max_iter } => search_early_stop(row, k, max_iter),
        // Two-stage bucketed selection is not a single-threshold search,
        // so it cannot flow into select_row below; it runs the full
        // bucket/merge pipeline and synthesizes its SearchOut.
        Mode::Approx { recall_milli } => {
            return crate::topk::approx::approx_row(row, k, recall_milli, vals, idx);
        }
    };
    select_row(row, k, s, vals, idx);
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gens};
    use crate::util::rng::Rng;

    fn exact_topk_sorted(row: &[f32], k: usize) -> Vec<f32> {
        let mut v = row.to_vec();
        v.sort_by(|a, b| b.partial_cmp(a).unwrap());
        v.truncate(k);
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }

    fn run(row: &[f32], k: usize, mode: Mode) -> (Vec<f32>, Vec<u32>) {
        let mut vals = vec![0.0; k];
        let mut idx = vec![0u32; k];
        rtopk_row(row, k, mode, &mut vals, &mut idx);
        (vals, idx)
    }

    #[test]
    fn exact_small_known() {
        let row = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let (mut vals, idx) = run(&row, 3, Mode::EXACT);
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(vals, vec![5.0, 6.0, 9.0]);
        let mut srt = idx.clone();
        srt.sort_unstable();
        assert_eq!(srt, vec![4, 5, 7]);
    }

    #[test]
    fn exact_with_ties_at_borderline() {
        // 8 ones then 8 twos, k=12 -> all twos + four ones (the tie case
        // that broke the naive final-thres selection; see ref.py)
        let row: Vec<f32> = std::iter::repeat(1.0f32)
            .take(8)
            .chain(std::iter::repeat(2.0).take(8))
            .collect();
        let (mut vals, _) = run(&row, 12, Mode::EXACT);
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(vals, exact_topk_sorted(&row, 12));
    }

    #[test]
    fn all_equal_row() {
        let row = vec![2.5f32; 16];
        let (vals, idx) = run(&row, 5, Mode::EXACT);
        assert_eq!(vals, vec![2.5; 5]);
        assert_eq!(idx, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn k_equals_m() {
        let row = [1.0f32, -2.0, 3.0];
        let (vals, idx) = run(&row, 3, Mode::EXACT);
        assert_eq!(vals, vec![1.0, -2.0, 3.0]);
        assert_eq!(idx, vec![0, 1, 2]);
    }

    #[test]
    fn k_equals_one() {
        let row = [0.5f32, 7.25, -1.0, 7.0];
        let (vals, idx) = run(&row, 1, Mode::EXACT);
        assert_eq!(vals, vec![7.25]);
        assert_eq!(idx, vec![1]);
    }

    #[test]
    fn negative_values_only() {
        let row = [-5.0f32, -1.0, -3.0, -2.0];
        let (mut vals, _) = run(&row, 2, Mode::EXACT);
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(vals, vec![-2.0, -1.0]);
    }

    #[test]
    fn constant_negative_row_exits_without_iterating() {
        // Regression: eps = eps_rel * max(v) was negative here, so the
        // zero-width bracket (lo == hi) still satisfied `hi - lo > eps`
        // and the search spun the full 64-iteration cap making no
        // progress. The magnitude-scaled eps exits immediately.
        let row = vec![-3.25f32; 64];
        let s = search_exact(&row, 5, 1e-16, 64);
        assert_eq!(s.iters, 0, "zero-width bracket must not iterate");
        let (vals, idx) = run(&row, 5, Mode::EXACT);
        assert_eq!(vals, vec![-3.25; 5]);
        assert_eq!(idx, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn all_negative_ties_hit_bracket_exit() {
        // Two tied negative levels and a k between their counts:
        // cnt == k is unreachable, so only the bracket-width exit can
        // stop the loop. With the sign-buggy eps this burned all 64
        // iterations; the magnitude-scaled eps exits after about
        // log2(width / (eps_rel * 2)) ~ 13 iterations.
        let row: Vec<f32> = std::iter::repeat(-1.0f32)
            .take(8)
            .chain(std::iter::repeat(-2.0).take(8))
            .collect();
        let s = search_exact(&row, 4, 1e-4, 64);
        assert!(s.iters <= 20, "bracket exit too late: {} iters", s.iters);
        let (mut vals, _) = run(&row, 4, Mode::Exact { eps_rel: 1e-4 });
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(vals, vec![-1.0; 4]);
    }

    #[test]
    fn mixed_sign_positive_max_keeps_paper_eps() {
        // Row max is +0.001 with a -100 outlier: the paper's formula is
        // well-defined here (eps = 1e-4 * 0.001 = 1e-7) and must be
        // preserved verbatim — scaling by the bracket magnitude instead
        // would loosen the configured tolerance by |min|/max = 1e5.
        // cnt == k is unreachable (counts jump 8 -> 16 across the tie),
        // so the width exit fires after ~log2(100 / 1e-7) ~ 30
        // halvings: more than the negative-row cases, far below the
        // 64-iteration cap.
        let row: Vec<f32> = std::iter::repeat(0.001f32)
            .take(8)
            .chain(std::iter::repeat(-100.0).take(8))
            .collect();
        let s = search_exact(&row, 4, 1e-4, 64);
        assert!(
            (25..=40).contains(&s.iters),
            "expected the paper's tight-eps exit (~30), got {} iters",
            s.iters
        );
        let (mut vals, _) = run(&row, 4, Mode::Exact { eps_rel: 1e-4 });
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(vals, vec![0.001; 4]);
    }

    #[test]
    fn all_negative_random_rows_stay_bounded_and_exact() {
        // Shifted-negative normal rows: exactness at tight eps, and the
        // loose-eps iteration count must match the positive-row budget
        // (E(n) ~ 9 plus bracket-exit slack), never the 64 cap.
        let mut rng = Rng::seed_from(0x9E6);
        for _ in 0..30 {
            let row: Vec<f32> =
                (0..256).map(|_| -rng.normal_f32().abs() - 1.0).collect();
            let s = search_exact(&row, 32, 1e-4, 64);
            assert!(s.iters <= 24, "iters {} at loose eps", s.iters);
            let (mut vals, _) = run(&row, 32, Mode::EXACT);
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            assert_eq!(vals, exact_topk_sorted(&row, 32));
        }
    }

    #[test]
    fn early_stop_selects_k_and_is_reasonable() {
        let mut rng = Rng::seed_from(1);
        let row: Vec<f32> = (0..256).map(|_| rng.normal_f32()).collect();
        for it in [1u32, 2, 4, 8, 16] {
            let (vals, idx) = run(&row, 32, Mode::EarlyStop { max_iter: it });
            assert_eq!(vals.len(), 32);
            // indices unique
            let mut u = idx.clone();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), 32, "duplicate indices at max_iter={it}");
            // values gathered correctly
            for (v, &i) in vals.iter().zip(&idx) {
                assert_eq!(*v, row[i as usize]);
            }
        }
    }

    #[test]
    fn early_stop_converges_to_exact() {
        let mut rng = Rng::seed_from(2);
        let row: Vec<f32> = (0..256).map(|_| rng.normal_f32()).collect();
        let exact = exact_topk_sorted(&row, 32);
        let (mut vals, _) = run(&row, 32, Mode::EarlyStop { max_iter: 30 });
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(vals, exact);
    }

    #[test]
    fn iteration_count_matches_paper_ballpark() {
        // Table 1: average exit iteration for M=256, k=64 is ~8.95 at
        // eps=1e-4 (paper). The run is derandomized (fixed seed 3) so
        // it cannot flake between runs, but the bounds stay wide on
        // purpose: the mean depends on the RNG stream (ours is
        // xoshiro256++, the paper's is unstated) and on Box-Muller vs
        // ziggurat tails. Per-seed spread is a few tenths of an
        // iteration; +-1.5 around the paper's 8.95 keeps the assertion
        // meaningful (it still catches a broken exit condition, which
        // shifts the mean to ~1 or to the 64 cap) without pinning
        // implementation details. Normal rows have positive maxima, so
        // the non-positive-max eps fallback never fires here and the
        // eps formula is the paper's verbatim.
        let mut rng = Rng::seed_from(3);
        let mut total = 0u64;
        let n = 2000;
        for _ in 0..n {
            let row: Vec<f32> = (0..256).map(|_| rng.normal_f32()).collect();
            let s = search_exact(&row, 64, 1e-4, 64);
            total += s.iters as u64;
        }
        let avg = total as f64 / n as f64;
        assert!(
            (7.0..10.9).contains(&avg),
            "avg exit iteration {avg}, paper ~8.95"
        );
    }

    #[test]
    fn property_exact_matches_sort_oracle() {
        forall(
            "rtopk_exact == sort_topk",
            0xC0FFEE,
            300,
            |rng| {
                let (m, k) = gens::m_and_k(rng, 128);
                (gens::any_row(rng, m), k)
            },
            |(row, k)| {
                let (mut vals, idx) = run(row, *k, Mode::EXACT);
                // gathered
                for (v, &i) in vals.iter().zip(&idx) {
                    if *v != row[i as usize] {
                        return Err(format!("vals[{i}] not gathered"));
                    }
                }
                // unique indices
                let mut u = idx.clone();
                u.sort_unstable();
                u.dedup();
                if u.len() != *k {
                    return Err("duplicate indices".into());
                }
                vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let want = exact_topk_sorted(row, *k);
                if vals != want {
                    return Err(format!("multiset mismatch:\n got {vals:?}\nwant {want:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_early_stop_invariants() {
        forall(
            "early_stop invariants",
            0xBEEF,
            200,
            |rng| {
                let (m, k) = gens::m_and_k(rng, 128);
                let it = 1 + rng.index(12) as u32;
                (gens::any_row(rng, m), k, it)
            },
            |(row, k, it)| {
                let (vals, idx) = run(row, *k, Mode::EarlyStop { max_iter: *it });
                let mut u = idx.clone();
                u.sort_unstable();
                u.dedup();
                if u.len() != *k {
                    return Err("duplicate indices".into());
                }
                for (v, i) in vals.iter().zip(idx) {
                    if *v != row[i as usize] {
                        return Err("not gathered".into());
                    }
                }
                Ok(())
            },
        );
    }
}
