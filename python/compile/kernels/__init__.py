"""L1 Pallas kernels for RTop-K + pure-jnp reference oracles."""

from . import ref
from .rtopk import maxk, pick_block_rows, rtopk, rtopk_mask

__all__ = ["ref", "rtopk", "rtopk_mask", "maxk", "pick_block_rows"]
