//! Integration: multi-tenant serving — weighted-fair batch draining
//! (WDRR), admission-control quotas, deadline protection against
//! starvation, and per-tenant metrics isolation.
//!
//! The fairness tests drive the batcher single-threaded over pre-filled
//! backlogs so the WDRR schedule is deterministic: with every tenant
//! saturated, a full rotation serves exactly `weight` tiles' worth of
//! rows per tenant (deficit round-robin with a one-tile quantum), so
//! drained-row proportions can be asserted tightly instead of
//! statistically.

use rtopk::config::{ServeConfig, TenantConfig, TenantsConfig};
use rtopk::coordinator::batcher::{BatchPolicy, Batcher};
use rtopk::coordinator::{SubmitRequest, TenantId, TopKService};
use rtopk::topk::types::Mode;
use rtopk::topk::verify::is_exact;
use rtopk::util::matrix::RowMatrix;
use rtopk::util::rng::Rng;
use std::collections::HashMap;
use std::time::Duration;

const TILE: usize = 64;

fn tid(name: &str) -> TenantId {
    TenantId::new(name)
}

/// Weighted 4/2/1 draw over ("a", "b", "c").
fn draw_tenant(rng: &mut Rng) -> &'static str {
    match rng.below(7) {
        0..=3 => "a",
        4..=5 => "b",
        _ => "c",
    }
}

fn weights_421() -> Vec<(TenantId, u64)> {
    vec![(tid("a"), 4), (tid("b"), 2), (tid("c"), 1)]
}

fn saturated_batcher(policy: BatchPolicy) -> Batcher<usize> {
    Batcher::with_weights(policy, weights_421())
}

#[test]
fn three_tenant_stress_weights_4_2_1_drain_ratios() {
    // Acceptance: tenants weighted 4/2/1, all saturated with full
    // tiles; drained-row ratios over any whole number of rotations must
    // match the weights within 10% (the deterministic schedule makes
    // them exact; the 10% bound is the contract, not the observation).
    let b = saturated_batcher(BatchPolicy {
        max_rows: TILE,
        max_wait: Duration::from_secs(600),
        queue_limit: usize::MAX,
    });
    let mut rng = Rng::seed_from(0x421);
    let mut submitted: HashMap<&'static str, usize> = HashMap::new();
    for i in 0..10_500 {
        let t = draw_tenant(&mut rng);
        *submitted.entry(t).or_insert(0) += TILE;
        assert!(b.submit(tid(t), RowMatrix::zeros(TILE, 8), 2, Mode::EXACT, i));
    }
    for t in ["a", "b", "c"] {
        assert!(
            submitted[t] >= 60 * TILE,
            "premise: every tenant has deep backlog, {t} has {}",
            submitted[t]
        );
    }

    // drain 50 full rotations (7 tiles each) while everyone stays
    // saturated
    let mut served: HashMap<String, usize> = HashMap::new();
    let rotations = 50usize;
    for _ in 0..rotations * 7 {
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.total_rows, TILE, "uniform tiles flush whole");
        *served.entry(batch.tenant.as_str().to_string()).or_insert(0) +=
            batch.total_rows;
    }
    let total: usize = served.values().sum();
    assert_eq!(total, rotations * 7 * TILE);
    for (t, w) in [("a", 4usize), ("b", 2), ("c", 1)] {
        let got = served[t] as f64;
        let want = (total * w) as f64 / 7.0;
        let ratio = got / want;
        assert!(
            (0.9..=1.1).contains(&ratio),
            "tenant {t}: served {got} rows, want ~{want} (ratio {ratio:.3})"
        );
        // the deterministic schedule is in fact exact to one batch
        assert!(
            (got - want).abs() <= TILE as f64,
            "tenant {t}: drained rows {got} off exact share {want} by more \
             than one batch"
        );
    }
    b.close();
}

#[test]
fn wdrr_property_10k_mixed_submissions_stay_weight_proportional() {
    // Property: over 10k uniform-tile submissions from a weighted-
    // random tenant mix, cumulative drained rows at every rotation
    // boundary sit within one batch of the exact weight shares.
    let b = saturated_batcher(BatchPolicy {
        max_rows: TILE,
        max_wait: Duration::from_secs(600),
        queue_limit: usize::MAX,
    });
    let mut rng = Rng::seed_from(0x10_000);
    for i in 0..10_000 {
        let t = draw_tenant(&mut rng);
        assert!(b.submit(tid(t), RowMatrix::zeros(TILE, 8), 2, Mode::EXACT, i));
    }
    let mut served: HashMap<String, usize> = HashMap::new();
    let mut drained = 0usize;
    for round in 1..=40usize {
        for _ in 0..7 {
            let batch = b.next_batch().unwrap();
            drained += batch.total_rows;
            *served.entry(batch.tenant.as_str().to_string()).or_insert(0) +=
                batch.total_rows;
        }
        // rotation boundary: shares must be within +-1 batch of exact
        for (t, w) in [("a", 4usize), ("b", 2), ("c", 1)] {
            let got = *served.get(t).unwrap_or(&0) as f64;
            let want = (drained * w) as f64 / 7.0;
            assert!(
                (got - want).abs() <= TILE as f64,
                "round {round}: tenant {t} served {got} rows, exact share \
                 {want} (deviation past one batch)"
            );
        }
    }
    b.close();
}

#[test]
fn wdrr_mixed_sizes_stay_inside_the_batch_granularity_envelope() {
    // With variable request sizes (budget-closed partial tiles,
    // variable charges) the drain stays inside a provable envelope at
    // every batch: a tenant can run at most one burst (~weight tiles)
    // ahead of its share, plus one tile of boundary slack.
    let b = saturated_batcher(BatchPolicy {
        max_rows: TILE,
        max_wait: Duration::from_secs(600),
        queue_limit: usize::MAX,
    });
    let mut rng = Rng::seed_from(0x5151);
    let mut submitted: HashMap<&'static str, usize> = HashMap::new();
    for i in 0..2_000 {
        let t = draw_tenant(&mut rng);
        let rows = 1 + rng.below(48) as usize;
        *submitted.entry(t).or_insert(0) += rows;
        assert!(b.submit(tid(t), RowMatrix::zeros(rows, 8), 2, Mode::EXACT, i));
    }
    let mut served: HashMap<String, usize> = HashMap::new();
    let mut drained = 0usize;
    for _ in 0..150 {
        let batch = b.next_batch().unwrap();
        assert!(
            batch.total_rows <= TILE,
            "no request exceeds the tile, so no batch may"
        );
        drained += batch.total_rows;
        *served.entry(batch.tenant.as_str().to_string()).or_insert(0) +=
            batch.total_rows;
        for (t, w) in [("a", 4usize), ("b", 2), ("c", 1)] {
            let got = *served.get(t).unwrap_or(&0) as f64;
            let want = (drained * w) as f64 / 7.0;
            let envelope = ((w + 2) * TILE) as f64;
            assert!(
                (got - want).abs() <= envelope,
                "tenant {t} served {got} rows vs share {want}, outside the \
                 {envelope}-row envelope"
            );
        }
    }
    b.close();
}

#[test]
fn deadline_expired_light_tenant_preempts_heavy_backlog() {
    // Satellite bugfix regression (starved light tenant): the light
    // tenant's lone small request ages past the deadline while the
    // heavy tenant keeps a wall of budget-full tiles ready. The
    // deadline flush must bypass WDRR and serve the light tenant
    // first; the heavy backlog resumes right after.
    let b: Batcher<usize> = Batcher::with_weights(
        BatchPolicy {
            max_rows: TILE,
            max_wait: Duration::from_millis(25),
            queue_limit: usize::MAX,
        },
        vec![(tid("heavy"), 8), (tid("light"), 1)],
    );
    assert!(b.submit(tid("light"), RowMatrix::zeros(2, 8), 2, Mode::EXACT, 0));
    for i in 0..20 {
        assert!(b.submit(
            tid("heavy"),
            RowMatrix::zeros(TILE, 8),
            2,
            Mode::EXACT,
            1 + i
        ));
    }
    std::thread::sleep(Duration::from_millis(40)); // light's deadline expires
    let first = b.next_batch().unwrap();
    assert_eq!(
        first.tenant,
        tid("light"),
        "expired deadline must beat the heavy tenant's ready tiles"
    );
    assert_eq!(first.total_rows, 2);
    let second = b.next_batch().unwrap();
    assert_eq!(second.tenant, tid("heavy"));
    b.close();
}

#[test]
fn service_stress_over_quota_tenant_cannot_perturb_others() {
    // Acceptance (service level): three tenants, weights 4/2/1, the
    // light tenant capped hard enough that its burst sheds load. Every
    // admitted request must complete exactly (zero starvation), the
    // capped tenant must see rejections, the others must see none, and
    // per-tenant latency percentiles must be populated independently.
    //
    // Determinism: the batching deadline (500ms) is orders of magnitude
    // longer than the sub-millisecond submission bursts, so no drain
    // can release tenant c's quota mid-burst — exactly
    // `max_in_flight_rows / request_rows` of c's submissions admit and
    // the rest reject, every run.
    let svc = TopKService::cpu_only(&ServeConfig {
        workers: 2,
        max_batch_rows: 100_000,
        max_wait_us: 500_000,
        tenants: TenantsConfig {
            tenants: vec![
                TenantConfig { weight: 4, ..TenantConfig::named("a") },
                TenantConfig { weight: 2, ..TenantConfig::named("b") },
                TenantConfig {
                    weight: 1,
                    max_in_flight_rows: 4 * 32,
                    ..TenantConfig::named("c")
                },
            ],
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();

    std::thread::scope(|scope| {
        for (t, seed) in [("a", 1u64), ("b", 2), ("c", 3)] {
            let svc = &svc;
            scope.spawn(move || {
                let mut rng = Rng::seed_from(seed);
                let mut handles = Vec::new();
                for _ in 0..40 {
                    let x = RowMatrix::random_normal(32, 32, &mut rng);
                    // fire the burst without waiting: tenant c's
                    // in-flight quota (4 requests' worth of rows) must
                    // reject the rest of its burst
                    let req = SubmitRequest::new(x.clone(), 4).tenant(t);
                    match svc.submit_ticket(req) {
                        Ok(h) => handles.push((x, h)),
                        Err(e) => {
                            let msg = format!("{e:#}");
                            assert!(
                                msg.contains(t),
                                "rejection must name the tenant: {msg}"
                            );
                        }
                    }
                }
                for (x, h) in handles {
                    let res = h.wait().expect("admitted request starved");
                    assert!(is_exact(&x, &res));
                }
            });
        }
    });

    let s = svc.stats();
    let by_name: HashMap<&str, _> =
        s.tenants.iter().map(|t| (t.tenant.as_str(), t)).collect();
    let a = by_name["a"];
    let b = by_name["b"];
    let c = by_name["c"];
    assert_eq!(a.rejected, 0, "uncapped tenant must never shed");
    assert_eq!(b.rejected, 0, "uncapped tenant must never shed");
    assert_eq!(a.requests, 40);
    assert_eq!(b.requests, 40);
    // the first 4 submissions always fit the quota; a mid-burst drain
    // can only happen if the thread stalls past the 500ms deadline, so
    // in practice exactly 4 admit — but the isolation contract is what
    // the test pins, not the scheduler's timing
    assert!(
        c.requests >= 4,
        "the quota-fitting prefix of c's burst must be admitted, got {}",
        c.requests
    );
    assert!(
        c.rejected > 0,
        "c's 40-deep burst against a 4-request quota must shed load"
    );
    assert_eq!(
        c.requests + c.rejected,
        40,
        "every submission is either served or rejected, never lost"
    );
    for t in [a, b, c] {
        assert_eq!(t.errors, 0);
        assert!(t.p99_us >= t.p50_us);
        assert!(t.max_us > 0.0, "served tenants have populated reservoirs");
    }
    assert_eq!(s.errors, 0);
    assert_eq!(s.requests, a.requests + b.requests + c.requests);
    // all reservations returned
    for t in ["a", "b", "c"] {
        assert_eq!(svc.tenants().in_flight(&tid(t)), (0, 0));
    }
    svc.shutdown();
}

#[test]
fn rejections_never_move_another_tenants_reservoir() {
    // Metrics-isolation acceptance: hammer an over-quota tenant with
    // rejected submissions while a victim tenant's latency stream is
    // already recorded; the victim's percentiles must be bit-identical
    // before and after.
    let svc = TopKService::cpu_only(&ServeConfig {
        workers: 1,
        max_wait_us: 100,
        tenants: TenantsConfig {
            tenants: vec![TenantConfig {
                // smaller than any request "noisy" sends: every one of
                // its submissions rejects, deterministically
                max_in_flight_rows: 2,
                ..TenantConfig::named("noisy")
            }],
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    let mut rng = Rng::seed_from(0x99);
    for _ in 0..20 {
        let x = RowMatrix::random_normal(16, 32, &mut rng);
        let req = SubmitRequest::new(x.clone(), 4).tenant("victim");
        assert!(is_exact(&x, &svc.submit(req).unwrap()));
    }
    let before = svc
        .stats()
        .tenants
        .into_iter()
        .find(|t| t.tenant == "victim")
        .unwrap();
    for _ in 0..500 {
        // every submission exceeds the 2-row quota: dies at admission
        let err = svc
            .submit_ticket(SubmitRequest::new(RowMatrix::zeros(4, 16), 2).tenant("noisy"));
        assert!(err.is_err(), "4-row request must exceed the 2-row quota");
    }
    let after_stats = svc.stats();
    let after = after_stats
        .tenants
        .iter()
        .find(|t| t.tenant == "victim")
        .unwrap();
    assert_eq!(before.requests, after.requests);
    assert_eq!(before.p50_us, after.p50_us);
    assert_eq!(before.p95_us, after.p95_us);
    assert_eq!(before.p99_us, after.p99_us);
    assert_eq!(before.max_us, after.max_us);
    let noisy = after_stats
        .tenants
        .iter()
        .find(|t| t.tenant == "noisy")
        .unwrap();
    assert_eq!(noisy.rejected, 500);
    svc.shutdown();
}
