//! Integration: planner-owned backend selection.
//!
//! These tests run without real artifacts: a synthetic manifest (plus
//! stub `.hlo.txt` files) is enough to build the PJRT backend's variant
//! table and exercise registration, routing priors, probe skipping
//! (the stub PJRT build always fails to execute — exactly the
//! "no artifacts here" situation the calibrator must survive), and the
//! forced-pin persistence rules.

use rtopk::backend::{
    BackendRegistry, ExecBackend, ExecSpec, TileTable, CPU_BACKEND_ID,
    PJRT_BACKEND_ID,
};
use rtopk::config::BackendConfig;
use rtopk::plan::{
    mode_key, tile_mode_key, PlanCache, PlanSource, Planner, PlannerConfig,
    RowBucket,
};
use rtopk::runtime::executor::Executor;
use rtopk::runtime::manifest::Manifest;
use rtopk::topk::rowwise::rowwise_topk_grained;
use rtopk::topk::types::{Mode, TopKResult};
use rtopk::topk::verify::is_exact;
use rtopk::util::matrix::RowMatrix;
use rtopk::util::rng::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

const MANIFEST: &str = r#"{
  "version": 1, "artifact_set": "synthetic",
  "artifacts": {
    "rtopk_1024x256_k32_exact": {
      "path": "a.hlo.txt",
      "inputs": [{"shape": [1024, 256], "dtype": "float32"}],
      "outputs": [{"shape": [1024, 32], "dtype": "float32"},
                   {"shape": [1024, 32], "dtype": "int32"},
                   {"shape": [1024, 256], "dtype": "float32"}],
      "meta": {"kind": "rtopk_tile", "rows": 1024, "m": 256, "k": 32,
                "mode": "exact", "max_iter": 0}
    },
    "rtopk_1024x256_k32_es4": {
      "path": "b.hlo.txt",
      "inputs": [{"shape": [1024, 256], "dtype": "float32"}],
      "outputs": [{"shape": [1024, 32], "dtype": "float32"},
                   {"shape": [1024, 32], "dtype": "int32"},
                   {"shape": [1024, 256], "dtype": "float32"}],
      "meta": {"kind": "rtopk_tile", "rows": 1024, "m": 256, "k": 32,
                "mode": "early_stop", "max_iter": 4}
    },
    "train_x": {
      "path": "c.hlo.txt", "inputs": [], "outputs": [],
      "meta": {"kind": "train_step"}
    }
  }
}"#;

/// Write a synthetic artifacts dir (manifest + stub HLO files) under a
/// unique temp path; each test gets its own so parallel tests never
/// collide.
fn synth_artifacts(label: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("rtopk_backend_it_{label}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), MANIFEST).unwrap();
    for f in ["a.hlo.txt", "b.hlo.txt", "c.hlo.txt"] {
        std::fs::write(dir.join(f), "HloModule synthetic_stub").unwrap();
    }
    dir
}

fn synth_registry(label: &str) -> (Arc<BackendRegistry>, Executor) {
    let dir = synth_artifacts(label);
    let exec = Executor::spawn(dir.to_str().unwrap()).unwrap();
    let registry = Arc::new(BackendRegistry::with_manifest(
        &BackendConfig::default(),
        exec.handle(),
    ));
    (registry, exec)
}

#[test]
fn registry_routes_compiled_shapes_to_pjrt_and_falls_back_to_cpu() {
    let (registry, _exec) = synth_registry("routing");
    assert!(registry.contains(PJRT_BACKEND_ID));
    assert_eq!(registry.variants().len(), 2, "train_x is not a tile");

    // model-only planning (calib_rows = 0) uses the manifest prior —
    // the old router's rule: a compiled tile wins its shape
    let planner = Planner::with_backends(
        PlannerConfig { calib_rows: 0, ..PlannerConfig::default() },
        registry.clone(),
    );
    assert_eq!(
        planner.plan(64, 256, 32, Mode::EXACT).backend,
        PJRT_BACKEND_ID
    );
    assert_eq!(
        planner
            .plan(64, 256, 32, Mode::EarlyStop { max_iter: 4 })
            .backend,
        PJRT_BACKEND_ID
    );
    // no tile -> CPU engine
    assert_eq!(planner.plan(64, 512, 32, Mode::EXACT).backend, CPU_BACKEND_ID);
    assert_eq!(planner.plan(64, 256, 16, Mode::EXACT).backend, CPU_BACKEND_ID);
    assert_eq!(
        planner
            .plan(64, 256, 32, Mode::EarlyStop { max_iter: 7 })
            .backend,
        CPU_BACKEND_ID
    );
    // a loose-eps exact request is approximate: it must not match the
    // exact tile
    assert_eq!(
        planner
            .plan(64, 256, 32, Mode::Exact { eps_rel: 1e-4 })
            .backend,
        CPU_BACKEND_ID
    );
}

#[test]
fn deny_and_disable_keep_pjrt_out_of_the_registry() {
    let dir = synth_artifacts("deny");
    let exec = Executor::spawn(dir.to_str().unwrap()).unwrap();
    let denied = BackendRegistry::with_manifest(
        &BackendConfig { deny: vec!["pjrt".into()], ..BackendConfig::default() },
        exec.handle(),
    );
    assert!(!denied.contains(PJRT_BACKEND_ID));
    assert_eq!(denied.ids(), vec![CPU_BACKEND_ID.to_string()]);
    let disabled = BackendRegistry::with_manifest(
        &BackendConfig { enable: false, ..BackendConfig::default() },
        exec.handle(),
    );
    assert!(!disabled.contains(PJRT_BACKEND_ID));
}

#[test]
fn calibration_probes_skip_the_stub_pjrt_cleanly() {
    let (registry, _exec) = synth_registry("probe");
    // with calibration on, the pjrt probe *runs* — and fails, because
    // this build carries the xla stub — so the measured winner is cpu
    let planner = Planner::with_backends(
        PlannerConfig { calib_rows: 32, calib_reps: 1, ..PlannerConfig::default() },
        registry,
    );
    let plan = planner.plan(64, 256, 32, Mode::EXACT);
    assert_eq!(plan.source, PlanSource::Calibrated);
    assert_eq!(plan.backend, CPU_BACKEND_ID, "failed probe must not win");

    let log = planner.probe_log();
    let pjrt: Vec<_> =
        log.iter().filter(|p| p.backend == PJRT_BACKEND_ID).collect();
    assert_eq!(pjrt.len(), 1, "pjrt was probed exactly once for the shape");
    assert!(pjrt[0].secs.is_none(), "stub probe records as skipped");
    assert!(!pjrt[0].chosen);
    assert_eq!(pjrt[0].bucket, RowBucket::Le64, "probes record their bucket");
    let cpu: Vec<_> =
        log.iter().filter(|p| p.backend == CPU_BACKEND_ID).collect();
    assert_eq!(cpu.len(), 1);
    assert!(cpu[0].secs.is_some(), "cpu is measured with the same harness");
    assert!(cpu[0].chosen);

    // a skipped accelerator never becomes the shadow comparator — the
    // runner-up comes from candidates that actually measured
    if let Some(ru) = &plan.runner_up {
        assert_eq!(ru.backend, CPU_BACKEND_ID);
    }

    // shapes pjrt does not support at all are not probed
    planner.plan(64, 512, 32, Mode::EXACT);
    let log = planner.probe_log();
    assert!(log
        .iter()
        .filter(|p| p.cols == 512)
        .all(|p| p.backend == CPU_BACKEND_ID));
}

#[test]
fn mode_key_keeps_exact_and_early_stop_variants_distinct() {
    assert_eq!(mode_key(Mode::EXACT), "exact");
    assert_eq!(mode_key(Mode::EarlyStop { max_iter: 4 }), "es4");
    assert_ne!(
        mode_key(Mode::EarlyStop { max_iter: 4 }),
        mode_key(Mode::EarlyStop { max_iter: 8 })
    );
    assert_ne!(mode_key(Mode::Exact { eps_rel: 1e-4 }), "exact");
    // tiles are indexed through the same key function requests look up
    // with — manifest metadata round-trips through mode_key
    assert_eq!(tile_mode_key("exact", 0).as_deref(), Some("exact"));
    assert_eq!(tile_mode_key("early_stop", 4).as_deref(), Some("es4"));
    assert_eq!(tile_mode_key("warp9", 0), None);

    // the tile table inherits the distinction
    let tiles = TileTable::from_manifest(&Manifest::parse(MANIFEST).unwrap());
    assert_eq!(
        tiles.lookup(256, 32, Mode::EXACT).map(|(n, _)| n),
        Some("rtopk_1024x256_k32_exact")
    );
    assert_eq!(
        tiles
            .lookup(256, 32, Mode::EarlyStop { max_iter: 4 })
            .map(|(n, _)| n),
        Some("rtopk_1024x256_k32_es4")
    );
    assert!(tiles.lookup(256, 32, Mode::EarlyStop { max_iter: 8 }).is_none());
    assert!(tiles.lookup(256, 32, Mode::Exact { eps_rel: 1e-4 }).is_none());
}

#[test]
fn stale_cached_plan_for_a_vanished_tile_is_rederived_not_dispatched() {
    // artifacts regenerated without a tile: the backend id is still
    // registered, but the shape it was cached for no longer exists —
    // trusting the plan would error (and eventually quarantine pjrt)
    // on every batch of the shape
    let (registry, _exec) = synth_registry("stale");
    let planner = Planner::with_backends(
        PlannerConfig { calib_rows: 0, ..PlannerConfig::default() },
        registry,
    );
    let pjrt_plan = || rtopk::plan::Plan {
        backend: PJRT_BACKEND_ID.into(),
        algo: rtopk::topk::rowwise::RowAlgo::RTopK(Mode::EXACT),
        grain: 64,
        source: PlanSource::Cached,
        probes: Vec::new(),
        runner_up: None,
        shadow: None,
        recall: None,
    };
    planner.cache().insert(RowBucket::Le64, 512, 32, "exact", pjrt_plan());
    let plan = planner.plan(64, 512, 32, Mode::EXACT);
    assert_eq!(plan.backend, CPU_BACKEND_ID, "unsupported shape re-decided");
    // a cached plan whose tile still exists is trusted as-is
    planner.cache().insert(RowBucket::Le64, 256, 32, "exact", pjrt_plan());
    assert_eq!(
        planner.plan(64, 256, 32, Mode::EXACT).backend,
        PJRT_BACKEND_ID
    );
}

#[test]
fn forced_backend_pins_never_reach_the_persisted_cache() {
    let (registry, _exec) = synth_registry("pin");
    let path = std::env::temp_dir().join(format!(
        "rtopk_backend_pin_cache_{}.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    let planner = Planner::with_backends(
        PlannerConfig {
            force_backend: Some(PJRT_BACKEND_ID.to_string()),
            calib_rows: 0,
            cache_path: Some(path.clone()),
            ..PlannerConfig::default()
        },
        registry,
    );
    let pinned = planner.plan(64, 256, 32, Mode::EXACT);
    assert_eq!(pinned.backend, PJRT_BACKEND_ID);
    assert_eq!(pinned.source, PlanSource::Forced);
    // the pin resolves to cpu where pjrt has no tile — still forced,
    // still session-only
    assert_eq!(planner.plan(64, 512, 32, Mode::EXACT).backend, CPU_BACKEND_ID);
    assert_eq!(planner.cache().len(), 0, "pins bypass the adaptive cache");
    planner.save().unwrap();
    let reloaded = PlanCache::new();
    assert_eq!(
        reloaded.load(&path).unwrap(),
        0,
        "a pinned session persists zero plans"
    );
    let _ = std::fs::remove_file(&path);
}

/// A correct, countable backend: results come from the CPU engine, but
/// every group execution is tallied so tests can prove dispatch went
/// through the backend handle.
struct CountingBackend {
    cols: usize,
    calls: AtomicUsize,
}

impl ExecBackend for CountingBackend {
    fn id(&self) -> &str {
        "mock"
    }
    fn describe(&self) -> String {
        "counting test backend".into()
    }
    fn supports(&self, cols: usize, _k: usize, _mode: Mode) -> bool {
        cols == self.cols
    }
    fn execute(
        &self,
        spec: &ExecSpec,
        mats: &[&RowMatrix],
        k: usize,
        _mode: Mode,
    ) -> anyhow::Result<Vec<TopKResult>> {
        self.calls.fetch_add(1, Ordering::SeqCst);
        Ok(mats
            .iter()
            .map(|x| rowwise_topk_grained(x, k, spec.algo, spec.grain))
            .collect())
    }
}

#[test]
fn custom_backends_are_measured_and_dispatched_like_any_other() {
    let mock = Arc::new(CountingBackend { cols: 48, calls: AtomicUsize::new(0) });
    let mut registry = BackendRegistry::cpu_only();
    registry.register(mock.clone());
    assert_eq!(registry.ids(), vec!["cpu".to_string(), "mock".to_string()]);
    let registry = Arc::new(registry);

    // adaptive: the mock is probed with the same harness as the CPU
    // algorithms (whoever wins, the probe itself must be recorded)
    let adaptive = Planner::with_backends(
        PlannerConfig { calib_rows: 32, calib_reps: 1, ..PlannerConfig::default() },
        registry.clone(),
    );
    adaptive.plan(25, 48, 6, Mode::EXACT);
    let probes = adaptive.probe_log();
    let mock_probe = probes
        .iter()
        .find(|p| p.backend == "mock")
        .expect("mock backend was probed");
    assert!(mock_probe.secs.is_some(), "working backend measures cleanly");
    assert!(adaptive.probe_log().iter().any(|p| p.chosen));

    // pinned: execution demonstrably flows through the backend handle
    let pinned = Planner::with_backends(
        PlannerConfig {
            force_backend: Some("mock".into()),
            calib_rows: 0,
            ..PlannerConfig::default()
        },
        registry,
    );
    let before = mock.calls.load(Ordering::SeqCst);
    let mut rng = Rng::seed_from(7);
    let x = RowMatrix::random_normal(25, 48, &mut rng);
    let res = pinned.run(&x, 6, Mode::EXACT);
    assert!(is_exact(&x, &res));
    assert!(
        mock.calls.load(Ordering::SeqCst) > before,
        "run() dispatched through the pinned backend"
    );
    // shapes outside the mock's support run the CPU engine
    assert_eq!(pinned.plan(25, 64, 6, Mode::EXACT).backend, CPU_BACKEND_ID);
}

#[test]
fn cached_plans_are_keyed_by_backend_and_survive_roundtrip() {
    let path = std::env::temp_dir().join(format!(
        "rtopk_backend_roundtrip_{}.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    let cfg = PlannerConfig {
        calib_rows: 32,
        calib_reps: 1,
        cache_path: Some(path.clone()),
        ..PlannerConfig::default()
    };
    let p = Planner::new(cfg.clone());
    let decided = p.plan(30, 96, 12, Mode::EXACT);
    assert_eq!(decided.backend, CPU_BACKEND_ID);
    p.save().unwrap();
    // the persisted document records the backend id, the row bucket,
    // and the raw probe timings per entry (schema v3)
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains("\"backend\":\"cpu\""), "doc: {text}");
    assert!(text.contains("\"rows_bucket\":\"le64\""), "doc: {text}");
    assert!(text.contains("\"probes\":"), "doc: {text}");
    assert!(text.contains("\"created_unix\":"), "doc: {text}");
    let q = Planner::new(cfg);
    let recalled = q.plan(30, 96, 12, Mode::EXACT);
    assert_eq!(recalled.backend, decided.backend);
    assert_eq!(recalled.algo, decided.algo);
    assert_eq!(recalled.source, PlanSource::Cached);
    let _ = std::fs::remove_file(&path);
}
