//! # RTop-K: row-wise top-k selection for neural-network acceleration
//!
//! Reproduction of *RTop-K: Ultra-Fast Row-Wise Top-K Selection for Neural
//! Network Acceleration on GPUs* (ICLR 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the request-path coordinator: a row-wise
//!   top-k service ([`coordinator`]), the PJRT runtime that executes the
//!   AOT-compiled JAX artifacts ([`runtime`]), the execution-backend
//!   seam the planner selects through ([`backend`]), and every substrate
//!   the paper's evaluation needs — the top-k algorithm zoo incl. the
//!   RadixSelect baseline ([`topk`]), a warp-level GPU cost simulator
//!   ([`simt`]), graph datasets ([`graph`]), and a CPU GNN compute
//!   substrate ([`gnn`]).
//! * **Layer 2** — JAX MaxK-GNN models, lowered once by
//!   `python/compile/aot.py` into `artifacts/*.hlo.txt`.
//! * **Layer 1** — the Pallas binary-search top-k kernel embedded in
//!   those artifacts.
//!
//! Python never runs on the request path: `make artifacts` is build-time
//! only, and the binary in `rust/src/main.rs` is self-contained after it.
//!
//! ## Quick start
//!
//! ```no_run
//! use rtopk::topk::{rowwise_topk, Mode};
//! use rtopk::util::matrix::RowMatrix;
//! use rtopk::util::rng::Rng;
//!
//! let mut rng = Rng::seed_from(42);
//! let x = RowMatrix::random_normal(1024, 256, &mut rng);
//! let res = rowwise_topk(&x, 32, Mode::EarlyStop { max_iter: 4 });
//! assert_eq!(res.indices.len(), 1024 * 32);
//! ```
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for the
//! measured paper-vs-reproduction numbers.

pub mod backend;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod gnn;
pub mod graph;
pub mod lint;
pub mod net;
pub mod plan;
pub mod runtime;
pub mod simt;
pub mod stats;
pub mod topk;
pub mod util;
