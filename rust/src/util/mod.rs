//! General-purpose substrates (offline build: no crates.io, so these are
//! implemented in-tree — see DESIGN.md §2 "Offline-build note").

pub mod json;
pub mod matrix;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod timer;
