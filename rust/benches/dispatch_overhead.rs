//! Per-batch dispatch overhead: persistent worker pool vs the old
//! spawn-per-call threading, across the planner's batch-size buckets.
//!
//! The serving hot path pays a fixed cost per batch before any row is
//! selected: getting work onto threads and getting scratch/output
//! buffers. This bench isolates that cost. For each (rows, cols, k)
//! bucket it measures three per-batch times with the same algorithm,
//! grain, and workload:
//!
//! * `serial` — one participant, warm arenas (the pure-compute floor);
//! * `pool` — the library path: persistent pool + thread-local
//!   grow-only `Scratch` arenas + recycled output buffers;
//! * `spawn` — a faithful in-bench replica of the pre-pool path:
//!   `std::thread::scope` per call, a fresh `Scratch` per dynamic
//!   chunk, freshly allocated output vectors per batch.
//!
//! Per-batch *overhead* is `measured - serial / participants` (what the
//! batch cost beyond its ideal compute share). Acceptance (non-smoke,
//! >= 4 threads): the <= 64-row buckets show >= 2x lower overhead with
//! the pool, and a steady-state window of fixed-shape batches performs
//! zero scratch-arena allocations. The pool's gauges are exported under
//! `"pool"` in the JSON document (last stdout line) so CI can pin the
//! telemetry schema:
//!
//!   cargo bench --bench dispatch_overhead                (full gate)
//!   RTOPK_SMOKE=1 cargo bench --bench dispatch_overhead  (CI: schema
//!       check only — shared runners are too noisy for timing gates)

use rtopk::bench::{workload, Table};
use rtopk::topk::baselines::{scratch_allocs, Scratch};
use rtopk::topk::rowwise::{rowwise_topk_grained, run_row, RowAlgo};
use rtopk::topk::types::TopKResult;
use rtopk::util::json::{self, Value};
use rtopk::util::matrix::RowMatrix;
use rtopk::util::pool;
use rtopk::util::timer::time_adaptive;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

fn median_secs(f: impl FnMut()) -> f64 {
    time_adaptive(3, Duration::from_millis(120), f).median().as_secs_f64()
}

/// Disjoint-row raw-pointer handle (same contract as the library's
/// internal one: the dynamic counter hands out non-overlapping ranges).
struct SendPtr<T>(*mut T);
impl<T> SendPtr<T> {
    fn get(&self) -> *mut T {
        self.0
    }
}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// The pre-pool hot path, reproduced faithfully: fresh output vectors,
/// `std::thread::scope` spawning `threads` OS threads per call, the
/// same atomic-counter dynamic chunking, and a fresh `Scratch`
/// allocation per claimed chunk (exactly what `rowwise_topk_grained`
/// did before the persistent pool landed).
fn spawn_rowwise(
    x: &RowMatrix,
    k: usize,
    algo: RowAlgo,
    grain: usize,
    threads: usize,
) -> TopKResult {
    let n = x.rows;
    let mut out = TopKResult {
        rows: n,
        k,
        values: vec![0.0; n * k],
        indices: vec![0; n * k],
    };
    if threads <= 1 {
        let mut scratch = Scratch::new(x.cols, k);
        for r in 0..n {
            let (v, i) = out.row_mut(r);
            run_row(x.row(r), k, algo, v, i, &mut scratch);
        }
        return out;
    }
    let vals_ptr = SendPtr(out.values.as_mut_ptr());
    let idx_ptr = SendPtr(out.indices.as_mut_ptr());
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            let next = &next;
            let vals_ptr = &vals_ptr;
            let idx_ptr = &idx_ptr;
            s.spawn(move || loop {
                let start = next.fetch_add(grain, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + grain).min(n);
                let mut scratch = Scratch::new(x.cols, k);
                for r in start..end {
                    // SAFETY: chunk ranges are disjoint, row windows
                    // [r*k, (r+1)*k) are disjoint per row, and `out`
                    // outlives the scope.
                    let (v, i) = unsafe {
                        (
                            std::slice::from_raw_parts_mut(vals_ptr.get().add(r * k), k),
                            std::slice::from_raw_parts_mut(idx_ptr.get().add(r * k), k),
                        )
                    };
                    run_row(x.row(r), k, algo, v, i, &mut scratch);
                }
            });
        }
    });
    out
}

/// Zero-allocation steady-state check: run fixed-shape batches until a
/// full measurement window shows no scratch-arena allocation events.
/// Dynamic scheduling means a slow worker can sit out early batches and
/// fault its arena in late, so earlier windows double as warmup;
/// returns the last window's allocation count (0 = converged).
fn steady_state_allocs(x: &RowMatrix, k: usize, algo: RowAlgo, grain: usize) -> u64 {
    let mut last = u64::MAX;
    for _ in 0..10 {
        let before = scratch_allocs();
        for _ in 0..20 {
            rowwise_topk_grained(x, k, algo, grain).recycle();
        }
        last = scratch_allocs() - before;
        if last == 0 {
            break;
        }
    }
    last
}

fn main() {
    let smoke = std::env::var("RTOPK_SMOKE").is_ok();
    let threads = pool::num_threads();
    let cols: usize = if smoke { 64 } else { 256 };
    let k: usize = if smoke { 8 } else { 32 };
    let rows_list: Vec<usize> = if smoke { vec![16, 64] } else { vec![16, 64, 256] };
    // Heap select: deterministic per-row cost, no mode parameter, so
    // the two dispatch paths run byte-identical row work.
    let algo = RowAlgo::Heap;

    pool::warm();

    let mut t = Table::new(
        "per-batch dispatch overhead: persistent pool vs spawn-per-call",
        &["rows", "cols", "k", "grain", "threads", "serial us", "pool us",
          "spawn us", "pool ovh us", "spawn ovh us", "ovh ratio"],
    );
    let mut buckets = Vec::new();
    let mut min_ratio_le64 = f64::INFINITY;

    for &rows in &rows_list {
        // size chunks so every participant engages (~1 chunk each) —
        // the regime where dispatch cost, not imbalance, is measured
        let grain = rows.div_ceil(threads).max(1);
        let eff_threads = threads.min(rows.div_ceil(grain)).max(1);
        let x = workload(rows, cols, 0x0D15_7A7C ^ ((rows as u64) << 8));

        // warm arenas + freelist for this shape before timing anything
        for _ in 0..8 {
            rowwise_topk_grained(&x, k, algo, grain).recycle();
        }
        let serial_s = median_secs(|| {
            // grain >= rows forces the single-participant inline path
            rowwise_topk_grained(&x, k, algo, rows.max(1)).recycle();
        });
        let pool_s = median_secs(|| {
            rowwise_topk_grained(&x, k, algo, grain).recycle();
        });
        let spawn_s = median_secs(|| {
            std::hint::black_box(spawn_rowwise(&x, k, algo, grain, eff_threads));
        });

        let compute_share = serial_s / eff_threads as f64;
        let pool_ovh = (pool_s - compute_share).max(0.0);
        let spawn_ovh = (spawn_s - compute_share).max(0.0);
        // clamp the denominator: a pool overhead too small to measure
        // is a win, not a divide-by-zero
        let ratio = spawn_ovh / pool_ovh.max(1e-9);
        if rows <= 64 {
            min_ratio_le64 = min_ratio_le64.min(ratio);
        }

        let us = |s: f64| s * 1e6;
        t.row(vec![
            rows.to_string(),
            cols.to_string(),
            k.to_string(),
            grain.to_string(),
            eff_threads.to_string(),
            format!("{:.1}", us(serial_s)),
            format!("{:.1}", us(pool_s)),
            format!("{:.1}", us(spawn_s)),
            format!("{:.1}", us(pool_ovh)),
            format!("{:.1}", us(spawn_ovh)),
            format!("{ratio:.2}"),
        ]);
        buckets.push(json::obj(vec![
            ("rows", json::num(rows as f64)),
            ("cols", json::num(cols as f64)),
            ("k", json::num(k as f64)),
            ("grain", json::num(grain as f64)),
            ("threads", json::num(eff_threads as f64)),
            ("serial_us", json::num(us(serial_s))),
            ("pool_us_per_batch", json::num(us(pool_s))),
            ("spawn_us_per_batch", json::num(us(spawn_s))),
            ("pool_overhead_us", json::num(us(pool_ovh))),
            ("spawn_overhead_us", json::num(us(spawn_ovh))),
            ("overhead_ratio", json::num(ratio)),
        ]));
    }
    t.print();

    // steady-state zero-alloc check at the smallest bucket's shape
    let x = workload(rows_list[0], cols, 0xA11_0C);
    let grain = rows_list[0].div_ceil(threads).max(1);
    let steady_allocs = steady_state_allocs(&x, k, algo, grain);

    let g = pool::gauges();
    let pool_json = json::obj(vec![
        ("workers", json::num(g.workers as f64)),
        ("jobs", json::num(g.jobs as f64)),
        ("inline_jobs", json::num(g.inline_jobs as f64)),
        ("tasks", json::num(g.tasks as f64)),
        ("steals", json::num(g.steals as f64)),
        ("parks", json::num(g.parks as f64)),
        ("unparks", json::num(g.unparks as f64)),
        ("busy_ns", json::num(g.busy_ns as f64)),
        ("utilization", json::num(g.utilization)),
    ]);

    // The overhead gate is only meaningful where parallel dispatch
    // actually engages: >= 4 threads, non-smoke (shared CI runners are
    // too noisy for timing ratios).
    let gate_applies = !smoke && threads >= 4;
    let ratio_ok = !gate_applies || min_ratio_le64 >= 2.0;
    let alloc_ok = smoke || steady_allocs == 0;
    let pass = ratio_ok && alloc_ok;
    println!(
        "\nmin overhead ratio (rows <= 64) = {min_ratio_le64:.2} \
         (want >= 2.0 at >= 4 threads; have {threads}), \
         steady-state scratch allocs = {steady_allocs} (want 0) -> {}",
        if pass {
            "PASS"
        } else if smoke {
            "FAIL (ignored: smoke mode checks schema, not speed)"
        } else {
            "FAIL"
        }
    );
    let doc: Value = json::obj(vec![
        ("bench", json::s("dispatch_overhead")),
        ("smoke", Value::Bool(smoke)),
        ("threads", json::num(threads as f64)),
        ("buckets", json::arr(buckets)),
        ("pool", pool_json),
        ("scratch_allocs_steady", json::num(steady_allocs as f64)),
        (
            "summary",
            json::obj(vec![
                ("min_overhead_ratio_le64", json::num(min_ratio_le64)),
                ("gate_applies", Value::Bool(gate_applies)),
                ("zero_alloc_steady", Value::Bool(steady_allocs == 0)),
                ("pass", Value::Bool(pass)),
            ]),
        ),
    ]);
    println!("{}", doc.to_string());
    if !pass && !smoke {
        std::process::exit(1);
    }
}
