//! ArtifactStore: owns the PJRT client and the compiled executables.
//! Single-threaded by construction (`PjRtClient` is Rc-based); wrap in
//! [`crate::runtime::executor::Executor`] for cross-thread access.

use crate::runtime::manifest::{ArtifactInfo, Manifest};
use crate::runtime::tensor::HostTensor;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

/// Loads HLO-text artifacts, compiles them on the PJRT CPU client
/// (lazily, cached), and executes them with host tensors.
pub struct ArtifactStore {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl ArtifactStore {
    /// Open an artifacts directory (must contain manifest.json).
    pub fn open(dir: impl Into<PathBuf>) -> Result<ArtifactStore> {
        let dir = dir.into();
        let manifest = Manifest::load(&dir)?;
        manifest.validate_datasets()?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(ArtifactStore { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an executable by artifact name.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let info = self.manifest.get(name)?;
        let path = self.dir.join(&info.path);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Validate inputs against the manifest spec, execute, unpack the
    /// output tuple into host tensors.
    pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let info = self.manifest.get(name)?.clone();
        self.check_inputs(&info, inputs)?;
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("readback {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))?;
        let out: Vec<HostTensor> = parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<_>>()?;
        if out.len() != info.outputs.len() {
            bail!(
                "{name}: manifest declares {} outputs, got {}",
                info.outputs.len(),
                out.len()
            );
        }
        Ok(out)
    }

    fn check_inputs(&self, info: &ArtifactInfo, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != info.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                info.name,
                info.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&info.inputs).enumerate() {
            let dims: Vec<usize> = t.dims().iter().map(|&d| d as usize).collect();
            if dims != spec.shape {
                bail!(
                    "{} input {i}: shape {:?} != manifest {:?}",
                    info.name, dims, spec.shape
                );
            }
            if t.dtype_str() != spec.dtype {
                bail!(
                    "{} input {i}: dtype {} != manifest {}",
                    info.name,
                    t.dtype_str(),
                    spec.dtype
                );
            }
        }
        Ok(())
    }

    /// Warm the compile cache for a set of artifacts (startup hook).
    pub fn precompile(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n).with_context(|| format!("precompile {n}"))?;
        }
        Ok(())
    }
}

// Unit tests live in rust/tests/runtime.rs (integration) because they
// need real artifacts built by `make artifacts`.
