//! Standard-normal special functions: pdf, cdf (via erfc), inverse cdf
//! (Acklam's rational approximation, |rel err| < 1.15e-9).
//!
//! Needed by the Appendix-A iteration model (Eq. 1-4) and by the
//! statistical tests on generated workloads.

use std::f64::consts::PI;

/// Standard normal density.
#[inline]
pub fn norm_pdf(x: f64) -> f64 {
    (-0.5 * x * x).exp() / (2.0 * PI).sqrt()
}

/// Complementary error function (W. J. Cody-style rational approximation
/// via the Numerical Recipes erfc; |rel err| < 1.2e-7 which is ample for
/// the iteration model).
pub fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t
        * (-z * z - 1.26551223
            + t * (1.00002368
                + t * (0.37409196
                    + t * (0.09678418
                        + t * (-0.18628806
                            + t * (0.27886807
                                + t * (-1.13520398
                                    + t * (1.48851587
                                        + t * (-0.82215223
                                            + t * 0.17087277)))))))))
        .exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// Standard normal CDF.
#[inline]
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// Inverse standard normal CDF (Acklam 2003).
pub fn norm_ppf(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "norm_ppf domain: got {p}");
    // coefficients
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;

    let x = if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5])
            * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r
                + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };
    // one Halley refinement step for ~1e-15 accuracy
    let e = norm_cdf(x) - p;
    let u = e * (2.0 * PI).sqrt() * (x * x / 2.0).exp();
    x - u / (1.0 + x * u / 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdf_known_values() {
        // the erfc approximation is good to ~1.2e-7 relative
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((norm_cdf(1.959963985) - 0.975).abs() < 1e-6);
        assert!((norm_cdf(-1.0) - 0.15865525).abs() < 1e-6);
        assert!(norm_cdf(8.0) > 0.9999999);
        assert!(norm_cdf(-8.0) < 1e-7);
    }

    #[test]
    fn ppf_inverts_cdf() {
        for &p in &[0.001, 0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 0.999] {
            let x = norm_ppf(p);
            assert!((norm_cdf(x) - p).abs() < 1e-9, "p={p} x={x}");
        }
    }

    #[test]
    fn ppf_symmetry() {
        for &p in &[0.01, 0.2, 0.4] {
            assert!((norm_ppf(p) + norm_ppf(1.0 - p)).abs() < 1e-9);
        }
    }

    #[test]
    fn pdf_peak_and_symmetry() {
        assert!((norm_pdf(0.0) - 0.3989422804014327).abs() < 1e-12);
        assert!((norm_pdf(1.3) - norm_pdf(-1.3)).abs() < 1e-15);
    }

    #[test]
    #[should_panic(expected = "norm_ppf domain")]
    fn ppf_rejects_out_of_domain() {
        norm_ppf(0.0);
    }
}
