"""Shape specifications for the simulated graph datasets.

The paper evaluates MaxK-GNN on Flickr, Yelp, Reddit and Ogbn-products
(89k - 2.4M nodes). Those datasets and the A6000 testbed are not
available here, so each is replaced by a *-sim dataset: a synthetic
SBM-style labeled graph whose (nodes, avg-degree, feature-dim, classes)
are scaled to this single-core testbed while keeping the ratios that
drive the experiments (top-k time share, accuracy stability under
approximate top-k). See DESIGN.md §6.

Only the *shapes* defined here are baked into the AOT artifacts; the
actual graphs are generated at runtime by the Rust `graph` module
(`rust/src/graph/datasets.rs` mirrors these specs exactly — keep the two
files in sync, both cite this table).

| name          | stands for    | nodes  | avg deg | feat | classes |
|---------------|---------------|--------|---------|------|---------|
| flickr-sim    | Flickr        |  2048  |   10    | 128  |  7      |
| yelp-sim      | Yelp          |  3072  |   16    | 128  | 16      |
| reddit-sim    | Reddit        |  4096  |   32    | 128  | 16      |
| products-sim  | Ogbn-products |  5120  |   16    | 100  | 24      |
| tiny-sim      | (unit tests)  |   256  |    8    |  32  |  4      |
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class GraphSpec:
    """Static shapes of one simulated dataset (AOT contract with Rust)."""

    name: str
    num_nodes: int
    avg_degree: int
    feat_dim: int
    num_classes: int

    @property
    def num_edges(self) -> int:
        """Padded edge count (exact multiple of nodes; pad edges carry w=0)."""
        return self.num_nodes * self.avg_degree


SPECS: dict[str, GraphSpec] = {
    s.name: s
    for s in [
        GraphSpec("tiny-sim", 256, 8, 32, 4),
        GraphSpec("flickr-sim", 2048, 10, 128, 7),
        GraphSpec("yelp-sim", 3072, 16, 128, 16),
        GraphSpec("reddit-sim", 4096, 32, 128, 16),
        GraphSpec("products-sim", 5120, 16, 100, 24),
    ]
}

# Fig. 5 setting: hidden dim M = 256, k = 32, 3 hidden layers.
HIDDEN_DIM = 256
TOPK_K = 32
NUM_LAYERS = 3


def get(name: str) -> GraphSpec:
    try:
        return SPECS[name]
    except KeyError:
        raise KeyError(
            f"unknown dataset {name!r}; known: {sorted(SPECS)}"
        ) from None


__all__ = ["GraphSpec", "SPECS", "get", "HIDDEN_DIM", "TOPK_K", "NUM_LAYERS"]
