"""AOT driver: lower every kernel/model variant to HLO text + manifest.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Outputs (all under ``artifacts/``):

  * ``<name>.hlo.txt``       one module per variant, lowered with
                             ``return_tuple=True`` (Rust unwraps tuples)
  * ``manifest.json``        every artifact's inputs/outputs (shape,
                             dtype) plus domain metadata (k, mode,
                             max_iter, dataset spec, param names...) —
                             the Rust runtime is entirely manifest-driven.

Variant sets:

  * service top-k tiles: ``rtopk_<R>x<M>_k<K>_<mode>`` used by the Rust
    TopKService (router picks the variant, batcher pads rows to R).
  * train/eval steps: ``train_<tag>`` / ``eval_<tag>`` per ModelSpec.

``ARTIFACT_SET=quick|default|full`` (env) controls how many variants are
built; the Makefile re-runs this only when compile/ sources change.

Usage: cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import datasets, model
from .kernels import rtopk


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_json(s: jax.ShapeDtypeStruct | jax.Array) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def lower_entry(name: str, fn, example_args, meta: dict, out_dir: str,
                manifest: dict) -> None:
    """Lower ``fn(*example_args)`` and append a manifest entry."""
    t0 = time.time()
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    path = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(text)
    out_avals = jax.eval_shape(fn, *example_args)
    if not isinstance(out_avals, (tuple, list)):
        out_avals = (out_avals,)
    manifest["artifacts"][name] = {
        "path": path,
        "inputs": [_spec_json(a) for a in example_args],
        "outputs": [_spec_json(a) for a in out_avals],
        "meta": meta,
    }
    print(f"  lowered {name:48s} ({len(text)/1e3:8.1f} kB, "
          f"{time.time()-t0:5.1f}s)")


# ---------------------------------------------------------------------------
# Service top-k tiles
# ---------------------------------------------------------------------------

# (rows-per-tile, M, k) tiles the TopKService routes to. R=1024 amortizes
# PJRT dispatch; the batcher pads the tail tile.
QUICK_TILES = [(1024, 256, 32)]
DEFAULT_TILES = [
    (1024, 256, 16),
    (1024, 256, 32),
    (1024, 256, 64),
    (1024, 512, 32),
    (1024, 768, 32),
]
# modes per tile: exact (paper's eps=1e-16 "no early stopping") + es4/es8
SERVICE_MODES = [("exact", 0), ("es", 4), ("es", 8)]


def service_variants(tiles):
    for (r, m, k) in tiles:
        for kind, it in SERVICE_MODES:
            mode = "exact" if kind == "exact" else "early_stop"
            tag = "exact" if kind == "exact" else f"es{it}"
            name = f"rtopk_{r}x{m}_k{k}_{tag}"

            def fn(x, *, _m=mode, _it=it, _k=k):
                return rtopk(x, _k, mode=_m, max_iter=_it,
                             eps_rel=1e-16, interpret=True)

            example = [jax.ShapeDtypeStruct((r, m), jnp.float32)]
            meta = {
                "kind": "rtopk_tile",
                "rows": r,
                "m": m,
                "k": k,
                "mode": mode,
                "max_iter": it,
                "eps_rel": 1e-16,
            }
            yield name, fn, example, meta


# ---------------------------------------------------------------------------
# Train / eval steps
# ---------------------------------------------------------------------------


def model_specs(artifact_set: str) -> list[model.ModelSpec]:
    """Which ModelSpecs to bake, per artifact set.

    quick:   gcn on tiny-sim (tests / CI)
    default: quick + all three models on flickr-sim (exact + es4) + gcn on
             every dataset (es4) — covers the e2e example and Fig 5 subset.
    full:    default + es2..es8 sweep for Fig 5's x-axis on flickr-sim
             and products-sim, all models.
    """
    specs: list[model.ModelSpec] = []

    def add(m, d, mode, it=4, impl="rtopk"):
        specs.append(model.ModelSpec(model=m, dataset=d, topk_mode=mode,
                                     max_iter=it, topk_impl=impl))

    add("gcn", "tiny-sim", "exact")
    add("gcn", "tiny-sim", "early_stop", 4)
    add("gcn", "tiny-sim", "exact", impl="sort")
    if artifact_set == "quick":
        return specs
    for m in model.MODELS:
        add(m, "flickr-sim", "exact")
        add(m, "flickr-sim", "early_stop", 4)
        add(m, "flickr-sim", "exact", impl="sort")  # Fig 5 baseline
    for d in ("yelp-sim", "reddit-sim", "products-sim"):
        add("gcn", d, "exact")
        add("gcn", d, "early_stop", 4)
        add("gcn", d, "exact", impl="sort")
    if artifact_set == "default":
        return specs
    for m in model.MODELS:
        for d in ("flickr-sim", "products-sim"):
            for it in (2, 3, 5, 6, 7, 8):
                add(m, d, "early_stop", it)
    for m in ("sage", "gin"):
        for d in ("yelp-sim", "reddit-sim"):
            add(m, d, "exact")
            add(m, d, "early_stop", 4)
    return specs


def model_variants(artifact_set: str):
    seen = set()
    for spec in model_specs(artifact_set):
        tag = spec.tag()
        if tag in seen:
            continue
        seen.add(tag)
        g = spec.graph
        meta_common = {
            "model": spec.model,
            "dataset": spec.dataset,
            "hidden": spec.hidden,
            "k": spec.k,
            "layers": spec.layers,
            "topk_mode": spec.topk_mode,
            "max_iter": spec.max_iter,
            "lr": spec.lr,
            "momentum": spec.momentum,
            "num_nodes": g.num_nodes,
            "num_edges": g.num_edges,
            "feat_dim": g.feat_dim,
            "num_classes": g.num_classes,
            "param_names": [n for n, _ in model.param_shapes(spec)],
            "param_shapes": [list(s) for _, s in model.param_shapes(spec)],
        }
        fn, example = model.make_train_fn(spec)
        yield (f"train_{tag}", fn, example,
               {"kind": "train_step", **meta_common})
        fn, example = model.make_eval_fn(spec)
        yield (f"eval_{tag}", fn, example,
               {"kind": "eval_step", **meta_common})


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--set",
        default=os.environ.get("ARTIFACT_SET", "default"),
        choices=("quick", "default", "full"),
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest: dict = {
        "version": 1,
        "artifact_set": args.set,
        "datasets": {
            s.name: {
                "num_nodes": s.num_nodes,
                "num_edges": s.num_edges,
                "avg_degree": s.avg_degree,
                "feat_dim": s.feat_dim,
                "num_classes": s.num_classes,
            }
            for s in datasets.SPECS.values()
        },
        "artifacts": {},
    }
    tiles = QUICK_TILES if args.set == "quick" else DEFAULT_TILES
    t0 = time.time()
    for name, fn, example, meta in service_variants(tiles):
        lower_entry(name, fn, example, meta, args.out, manifest)
    for name, fn, example, meta in model_variants(args.set):
        lower_entry(name, fn, example, meta, args.out, manifest)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(manifest['artifacts'])} artifacts "
          f"in {time.time()-t0:.1f}s -> {args.out}/manifest.json")


if __name__ == "__main__":
    main()
