//! Adaptive execution planner: pick the fastest execution backend,
//! row-wise top-k algorithm, and work-unit grain per batch shape.
//!
//! RadiK-style size dispatch and the regime analysis in "Approximate
//! Top-k for Increased Parallelism" both observe that the best top-k
//! algorithm depends on the shape; this crate already carries six
//! baselines, the paper's kernel, a SIMT cost model, and a PJRT tile
//! executor — the planner is the seam that turns those parts into one
//! self-tuning engine. Execution backends (`crate::backend`) are just
//! more candidates: the planner races every registered backend that
//! supports a shape with the same microbenchmark harness it uses for
//! CPU algorithms, so a compiled accelerator tile wins a shape only by
//! *measuring* faster than the CPU engine — not by merely existing in
//! the manifest.
//!
//! Shapes are keyed by **batch row count** as well as `(cols, k, mode)`:
//! row count dominates the setup-vs-throughput tradeoff at service
//! batch sizes (a 16-row batch favors low-setup algorithms that a
//! 4096-row batch would not), so plans carry a [`RowBucket`] dimension
//! and each bucket is calibrated at a representative row count of its
//! own instead of one fixed probe size.
//!
//! Decision pipeline for a `(rows-bucket, cols, k, mode)` key:
//!
//! 1. **Force overrides** (`PlannerConfig::force`,
//!    `PlannerConfig::force_backend`): operator pins, honored only when
//!    they cannot change result semantics (see [`ForceAlgo`]; a pinned
//!    backend that does not support a shape falls back to the CPU
//!    engine). Pinned decisions live in a session-local cache and are
//!    never persisted.
//! 2. **Plan cache** ([`cache::PlanCache`]): one decision per keyed
//!    shape for the process lifetime; optionally persisted to JSON
//!    (schema-versioned, host-fingerprinted, and TTL-stamped — a cache
//!    from another host, another schema, or past its TTL is
//!    re-calibrated instead of trusted) and reloaded at startup. A
//!    cached plan naming a backend this process does not have is
//!    re-decided, not trusted.
//! 3. **Cost-model prior** ([`model`]): the `simt` instruction-stream
//!    estimates rank the CPU candidates; with calibration disabled the
//!    backend prior is "a compiled tile exists" (the old manifest-only
//!    router's rule).
//! 4. **Microbenchmark calibration** ([`calibrate`]): when the budget
//!    allows (`calib_rows > 0`), every CPU candidate is timed on a
//!    small deterministic workload sized for the request's row bucket
//!    and the winner's grain is calibrated; then every registered
//!    accelerator backend supporting the shape is timed with the same
//!    harness ([`calibrate::time_backend`]), each at its own natural
//!    batch size (e.g. one full PJRT tile), and the fastest *per-row*
//!    rate wins the shape. Backends that cannot execute here (stub
//!    PJRT build, missing artifacts) fail their probe and are skipped
//!    cleanly. The raw probe timings and the runner-up candidate are
//!    recorded on the plan (and persisted), so the decision stays
//!    auditable and online re-probing has a comparator.
//! 5. **Shadow re-probing** (`shadow_every > 0`): calibration is a
//!    one-time measurement, but the host drifts (thermal limits,
//!    co-tenant contention, driver updates). Every Nth dispatched batch
//!    the scheduler re-times the live batch against the plan's
//!    runner-up and feeds the measured edge into an EWMA
//!    ([`Planner::record_shadow`]); a winner whose edge inverts past a
//!    hysteresis margin is demoted in place, with quarantine-style
//!    bounded logging mirroring the backend degradation path.
//!
//! ## Telemetry feedback (the closed loop)
//!
//! Two planner inputs come back from the serving layer's telemetry hub
//! (`coordinator::metrics::TelemetryHub`) instead of being fixed at
//! startup:
//!
//! * **Load-adaptive shadow cadence** ([`Planner::note_load`]): shadow
//!   re-probes double-execute a batch, which is exactly wrong under
//!   pressure. The scheduler reports queue depth and deadline slack
//!   after each batch; sustained busy readings stretch the effective
//!   `shadow_every` (×2 per step, up to `shadow_every_max`), sustained
//!   idle readings restore it (÷2 per step, back to the configured
//!   base). Both directions require a streak
//!   ([`CADENCE_STRETCH_AFTER`] / [`CADENCE_RESTORE_AFTER`]) so an
//!   alternating load signal never flaps the cadence.
//! * **Learned row buckets** ([`Planner::relearn_buckets`]): the
//!   `<=64 / <=1024 / >1024` split is a guess about batch geometry;
//!   the observed rows histogram is not. Once enough rows samples
//!   accumulate the boundaries are re-derived from the P33/P66
//!   quantiles and the plan cache re-keys its entries under them
//!   ([`cache::PlanCache::set_bounds`]) — calibration is re-bucketed,
//!   never discarded. The three [`RowBucket`] names stay fixed ordinal
//!   labels (small/medium/large) so cache schema, CLI output, and
//!   bench JSON never change shape.
//!
//! ## Correctness contract
//!
//! Candidate substitution never changes result *semantics*:
//!
//! * Exact requests (`Mode::Exact` with `eps_rel <= 1e-15`, the paper's
//!   no-early-stop setting) may run any algorithm in the zoo — they all
//!   return the exact top-k multiset (order differs; order is
//!   unspecified by the API, as the paper's consumers never sort).
//! * Approximate requests (early-stop, or a loose exact eps) are
//!   defined *by the paper's algorithm*, so the planner only tunes the
//!   grain and always executes `RowAlgo::RTopK(mode)`.
//! * Recall-contracted requests (`Mode::Approx { recall_milli }`) are
//!   defined by their *contract*, not by one kernel: the race admits
//!   the two-stage kernel, the paper's early-stop kernel at several
//!   budgets, and exact selection, measures each candidate's recall on
//!   the calibration probes with the shared oracle
//!   (`topk::verify::recall_of`), and **disqualifies any candidate
//!   below the target (plus `recall_margin_milli`) regardless of
//!   speed**. Exact selection always qualifies, so the family is never
//!   empty; unmeasured decision paths (model-only, forced-backend
//!   pins) rank only the provable members (the two-stage kernel —
//!   whose own calibration table enforces the target at execution
//!   time — and exact). The winner's achieved recall is recorded on
//!   the plan and persisted.
//! * Backends carry the same contract (`tests/runtime.rs` pins the
//!   PJRT tile bit-for-bit against the Rust engine), so switching
//!   backends can change speed, never results. Shadow demotion only
//!   swaps between candidates of the same race, so it inherits the
//!   guarantee.
//!
//! ## Knobs (config `[plan]` / `[backend]` sections, `rtopk plan` flags)
//!
//! * `force_algo` — pin one algorithm (`rtopk`, `radix`, `quickselect`,
//!   `heap`, `bucket`, `bitonic`, `sort`); empty = adaptive.
//! * `backend.force` — pin one backend id (`cpu`, `pjrt`, ...); empty =
//!   adaptive (measured) selection.
//! * `calib_rows` — baseline probe-matrix rows per candidate (each row
//!   bucket scales its own representative probe from this); `0`
//!   disables microbenchmarks (cost-model + manifest-prior decisions).
//! * `calib_reps` — timed repetitions per probe (best-of).
//! * `cache_path` — JSON file for plan persistence across restarts.
//! * `cache_ttl_secs` — persisted-cache expiry; an older document is
//!   re-calibrated wholesale (0 = never expires).
//! * `shadow_every` — shadow re-probe every Nth dispatched batch
//!   (0 = off; dispatch is then exactly the pre-shadow path).
//! * `shadow_every_max` — ceiling the load-adaptive cadence may
//!   stretch to (0 = 8x the base).
//! * `shadow_busy_rows` — queued-rows threshold above which a load
//!   report counts as busy.
//! * `bucket_learn_window` — rows samples the serving loop collects
//!   between bucket-boundary relearn attempts.
//! * `recall_probe_rows` — rows in the seeded workload the recall
//!   qualification gate measures `Mode::Approx` candidates on.
//! * `recall_margin_milli` — safety margin (thousandths) added to a
//!   request's recall target during qualification, so probe noise
//!   cannot admit a candidate sitting exactly at the contract.

pub mod cache;
pub mod calibrate;
pub mod model;

use crate::backend::{BackendRegistry, ExecSpec, CPU_BACKEND_ID};
use crate::topk::rowwise::{default_grain, rowwise_topk_grained, RowAlgo};
use crate::topk::types::{Mode, TopKResult};
use crate::util::matrix::RowMatrix;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

pub use cache::{parse_algo, parse_mode_tag, HostFingerprint, PlanCache};

/// Batch row-count buckets — the rows dimension of a plan key. Three
/// service-shaped regimes: interactive trickles, the batcher's steady
/// state, and oversized/bulk requests. Coarse on purpose: each bucket
/// is one calibration, and winners move with orders of magnitude, not
/// with ±10 rows.
///
/// The variant names record the *seed* boundaries
/// ([`RowBucket::DEFAULT_BOUNDS`], `<=64 / <=1024 / >1024`). Once the
/// serving loop has observed enough real batch geometry it re-derives
/// the boundaries from the rows histogram
/// ([`Planner::relearn_buckets`]); the names then read as ordinal
/// labels — small / medium / large — while staying byte-stable in the
/// cache schema, CLI output, and bench JSON.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum RowBucket {
    /// the small regime (`rows <= b0`; seed `b0 = 64`)
    Le64,
    /// the medium regime (`b0 < rows <= b1`; seed `b1 = 1024`)
    Le1024,
    /// the bulk regime (`rows > b1`)
    Gt1024,
}

impl RowBucket {
    pub const ALL: [RowBucket; 3] =
        [RowBucket::Le64, RowBucket::Le1024, RowBucket::Gt1024];

    /// Seed partition boundaries `(b0, b1)`: `rows <= b0` is small,
    /// `rows <= b1` medium, the rest bulk.
    pub const DEFAULT_BOUNDS: (usize, usize) = (64, 1024);

    /// The bucket a batch of `rows` rows plans under (seed boundaries).
    pub fn of(rows: usize) -> RowBucket {
        RowBucket::of_with(rows, RowBucket::DEFAULT_BOUNDS)
    }

    /// The bucket `rows` falls in under explicit boundaries.
    pub fn of_with(rows: usize, (b0, b1): (usize, usize)) -> RowBucket {
        if rows <= b0 {
            RowBucket::Le64
        } else if rows <= b1 {
            RowBucket::Le1024
        } else {
            RowBucket::Gt1024
        }
    }

    /// Stable serialized name (plan-cache schema v3/v4, CLI output).
    pub fn name(self) -> &'static str {
        match self {
            RowBucket::Le64 => "le64",
            RowBucket::Le1024 => "le1024",
            RowBucket::Gt1024 => "gt1024",
        }
    }

    /// Inverse of [`RowBucket::name`].
    pub fn parse(s: &str) -> Result<RowBucket, String> {
        match s {
            "le64" => Ok(RowBucket::Le64),
            "le1024" => Ok(RowBucket::Le1024),
            "gt1024" => Ok(RowBucket::Gt1024),
            other => Err(format!(
                "unknown rows bucket {other:?} (expected le64 | le1024 | gt1024)"
            )),
        }
    }

    /// Probe-matrix rows used to calibrate this bucket, scaled from the
    /// `calib_rows` budget but clamped *into* the bucket so the probe
    /// actually has the bucket's geometry (a 192-row probe says nothing
    /// about per-batch setup costs at 16 rows, and vice versa). Seed
    /// boundaries; the planner passes the learned ones.
    pub fn representative_rows(self, calib_rows: usize) -> usize {
        self.representative_rows_with(RowBucket::DEFAULT_BOUNDS, calib_rows)
    }

    /// [`RowBucket::representative_rows`] under explicit boundaries;
    /// the clamp targets keep their seed proportions (1.5x `b0` for the
    /// medium floor, 1.25x–4x `b1` for the bulk range) so learned
    /// bounds probe at the same relative geometry the seeds did.
    pub fn representative_rows_with(
        self,
        (b0, b1): (usize, usize),
        calib_rows: usize,
    ) -> usize {
        match self {
            RowBucket::Le64 => calib_rows.clamp(1, b0.max(1)),
            RowBucket::Le1024 => {
                let lo = (b0 + b0 / 2).max(b0 + 1).min(b1);
                calib_rows.clamp(lo, b1.max(lo))
            }
            RowBucket::Gt1024 => {
                let lo = (b1 + b1 / 4).max(b1 + 1);
                calib_rows
                    .saturating_mul(8)
                    .clamp(lo, b1.saturating_mul(4).max(lo))
            }
        }
    }
}

/// Where a plan came from (reporting / cache hygiene).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanSource {
    /// operator pin via `force_algo` / `backend.force`
    Forced,
    /// loaded from the cache (this process or a persisted file)
    Cached,
    /// cost-model prior only (calibration disabled)
    Model,
    /// microbenchmark-calibrated
    Calibrated,
    /// winner demoted by an online shadow re-probe
    Shadow,
}

impl PlanSource {
    pub fn name(&self) -> &'static str {
        match self {
            PlanSource::Forced => "forced",
            PlanSource::Cached => "cached",
            PlanSource::Model => "model",
            PlanSource::Calibrated => "calibrated",
            PlanSource::Shadow => "shadow",
        }
    }
}

/// What kind of candidate a raw probe timing measured.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProbeKind {
    /// a CPU-engine algorithm
    Algo,
    /// a registered accelerator backend
    Backend,
}

impl ProbeKind {
    pub fn name(self) -> &'static str {
        match self {
            ProbeKind::Algo => "algo",
            ProbeKind::Backend => "backend",
        }
    }

    pub fn parse(s: &str) -> Result<ProbeKind, String> {
        match s {
            "algo" => Ok(ProbeKind::Algo),
            "backend" => Ok(ProbeKind::Backend),
            other => Err(format!("unknown probe kind {other:?}")),
        }
    }
}

/// One raw calibration measurement, kept on the plan (and persisted in
/// cache schema v3) so a cached decision stays auditable after the
/// fact: `secs` over `rows` probe rows for the named candidate.
#[derive(Clone, Debug, PartialEq)]
pub struct RawProbe {
    pub kind: ProbeKind,
    /// algorithm name ([`RowAlgo::name`]) or backend id
    pub name: String,
    /// best-of-reps wall seconds for the candidate's probe matrix
    pub secs: f64,
    /// rows that probe executed (backends probe at their natural size)
    pub rows: usize,
}

/// The second-fastest candidate of a shape's calibration race — the
/// comparator shadow re-probing re-times live batches against. For a
/// CPU candidate this is `(cpu, algo, grain)`; for an accelerator it is
/// the backend id with the CPU fallback algorithm.
#[derive(Clone, Debug, PartialEq)]
pub struct RunnerUp {
    pub backend: String,
    pub algo: RowAlgo,
    pub grain: usize,
}

/// The shadow re-probe evidence behind a demoted plan, carried on the
/// plan (and persisted in the v3 cache's entry payload) so a restart
/// neither resurrects the demoted winner nor forgets why it fell: the
/// EWMA edge and sample count at demotion time, plus a demotion
/// counter that keeps accumulating across restarts (a shape demoted on
/// every boot is a calibration-stability signal worth seeing).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShadowHistory {
    /// EWMA of `(runner_secs - winner_secs) / winner_secs` at the
    /// moment the demotion fired (negative: the runner-up was faster)
    pub ewma: f64,
    /// shadow samples behind that EWMA
    pub samples: u64,
    /// demotions this shape has suffered, across restarts
    pub demotions: u32,
}

/// One execution decision for a shape.
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    /// execution backend id ([`CPU_BACKEND_ID`] = in-crate engine)
    pub backend: String,
    /// CPU-engine algorithm — what runs when `backend` is the CPU
    /// engine, and the fallback if an accelerator backend fails
    pub algo: RowAlgo,
    /// rows per dynamic work unit (CPU engine)
    pub grain: usize,
    pub source: PlanSource,
    /// raw calibration timings behind this decision (empty for forced
    /// and model-only plans)
    pub probes: Vec<RawProbe>,
    /// the race's runner-up, if the shape had more than one candidate —
    /// `None` disables shadow re-probing for the shape
    pub runner_up: Option<RunnerUp>,
    /// shadow-demotion evidence (`Some` iff this plan's winner was
    /// installed by an online demotion); persisted with the plan
    pub shadow: Option<ShadowHistory>,
    /// achieved recall of the winner on the qualification probe —
    /// `Some` only for calibrated decisions of recall-contracted
    /// (`Mode::Approx`) requests; persisted with the plan so a recalled
    /// decision stays auditable against its contract
    pub recall: Option<f64>,
}

impl Plan {
    /// The CPU-engine portion handed to [`crate::backend::ExecBackend::execute`].
    pub fn spec(&self) -> ExecSpec {
        ExecSpec { algo: self.algo, grain: self.grain }
    }
}

/// One backend measurement from a shape's calibration race (the
/// `rtopk plan` CLI prints these). Backends race on *per-row* time
/// (`secs / rows`): each is probed at its own natural batch size
/// ([`crate::backend::ExecBackend::preferred_probe_rows`], e.g. one
/// full PJRT tile), so absolute probe times are not directly
/// comparable across backends but rates are.
#[derive(Clone, Debug)]
pub struct BackendProbe {
    /// the row bucket this race calibrated
    pub bucket: RowBucket,
    pub cols: usize,
    pub k: usize,
    /// the shape's mode key (see [`mode_key`])
    pub mode: String,
    pub backend: String,
    /// best-of-reps probe seconds; `None` = the backend skipped this
    /// shape (unavailable here — stub build, missing artifacts)
    pub secs: Option<f64>,
    /// rows the probe actually executed (0 when skipped)
    pub rows: usize,
    /// whether this backend won the shape
    pub chosen: bool,
}

/// A forced algorithm choice. `RTopK` means "the paper's kernel at the
/// request's own mode"; `Fixed` pins a baseline, which is only honored
/// for exact-semantics requests (an approximate request silently keeps
/// `RTopK(mode)` — substituting an exact baseline would *change* the
/// output contract, not just the speed).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ForceAlgo {
    RTopK,
    Fixed(RowAlgo),
}

/// Parse a `force_algo` knob value.
pub fn parse_force(s: &str) -> Result<ForceAlgo, String> {
    match s {
        "rtopk" => Ok(ForceAlgo::RTopK),
        "radix" => Ok(ForceAlgo::Fixed(RowAlgo::Radix)),
        "quickselect" => Ok(ForceAlgo::Fixed(RowAlgo::QuickSelect)),
        "heap" => Ok(ForceAlgo::Fixed(RowAlgo::Heap)),
        "bucket" => Ok(ForceAlgo::Fixed(RowAlgo::Bucket)),
        "bitonic" => Ok(ForceAlgo::Fixed(RowAlgo::Bitonic)),
        "sort" => Ok(ForceAlgo::Fixed(RowAlgo::Sort)),
        other => Err(format!(
            "unknown force_algo {other:?} (expected rtopk | radix | \
             quickselect | heap | bucket | bitonic | sort)"
        )),
    }
}

/// EWMA weight of each new shadow edge sample.
pub const SHADOW_EWMA_ALPHA: f64 = 0.3;
/// Hysteresis margin: the runner-up must measure at least this much
/// faster (relative) on the EWMA before the winner is demoted.
/// Symmetric by construction — after a demotion the roles swap, so
/// flapping requires the *true* edge to oscillate across ±margin.
pub const SHADOW_MARGIN: f64 = 0.15;
/// Minimum shadow samples before a demotion can fire (one noisy batch
/// must never flip a calibrated winner).
pub const SHADOW_MIN_SAMPLES: u64 = 3;
/// Bounded logging: at most this many demotion lines per shape
/// (mirrors the backend-quarantine log bound).
const SHADOW_LOG_MAX: u32 = 3;

/// Consecutive busy load reports before the shadow cadence stretches
/// one step (x2, capped at `shadow_every_max`).
pub const CADENCE_STRETCH_AFTER: u32 = 2;
/// Consecutive idle load reports before the cadence restores one step
/// (/2, floored at the configured `shadow_every`). Larger than the
/// stretch streak on purpose: backing off under pressure should be
/// quick, resuming double-execution should want sustained calm.
pub const CADENCE_RESTORE_AFTER: u32 = 4;
/// A load report whose minimum deadline slack is below this counts as
/// busy (near-deadline traffic) regardless of queue depth.
pub const CADENCE_NEAR_DEADLINE_US: u64 = 2_000;

/// Minimum rows samples before a bucket-boundary relearn is considered.
pub const BUCKET_LEARN_MIN_SAMPLES: usize = 64;
/// Relative move a learned boundary must make before the cache
/// re-buckets (hysteresis: re-bucketing re-keys every cached plan, so
/// quantile jitter must not thrash the cache).
pub const BUCKET_MOVE_MIN_REL: f64 = 0.5;

/// Planner knobs (typed form of the config `[plan]` section plus the
/// `[backend]` pin).
#[derive(Clone, Debug)]
pub struct PlannerConfig {
    pub force: Option<ForceAlgo>,
    /// pin every supporting shape to one backend id; `None` = measured
    /// selection
    pub force_backend: Option<String>,
    /// baseline probe rows per candidate; 0 = cost-model only
    pub calib_rows: usize,
    /// best-of repetitions per probe
    pub calib_reps: usize,
    /// JSON persistence path for the plan cache
    pub cache_path: Option<PathBuf>,
    /// persisted-cache TTL in seconds (0 = never expires)
    pub cache_ttl_secs: u64,
    /// shadow re-probe every Nth dispatched batch (0 = off)
    pub shadow_every: usize,
    /// ceiling the load-adaptive cadence may stretch `shadow_every` to
    /// (0 = 8x the base)
    pub shadow_every_max: usize,
    /// queued rows at or above which a load report counts as busy
    pub shadow_busy_rows: u64,
    /// rows samples collected between bucket-relearn attempts
    pub bucket_learn_window: usize,
    /// rows in the seeded recall-qualification probe for `Mode::Approx`
    /// requests
    pub recall_probe_rows: usize,
    /// safety margin (thousandths) added to the recall target when
    /// qualifying candidates
    pub recall_margin_milli: u16,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            force: None,
            force_backend: None,
            calib_rows: 192,
            calib_reps: 3,
            cache_path: None,
            cache_ttl_secs: cache::DEFAULT_TTL_SECS,
            shadow_every: 0,
            shadow_every_max: 0,
            shadow_busy_rows: 4096,
            bucket_learn_window: 1024,
            recall_probe_rows: 256,
            recall_margin_milli: 5,
        }
    }
}

impl PlannerConfig {
    /// Build from the untyped config section; rejects bad knob values.
    pub fn from_plan_config(c: &crate::config::PlanConfig) -> Result<PlannerConfig, String> {
        let force = match c.force_algo.as_deref() {
            None | Some("") => None,
            Some(s) => Some(parse_force(s)?),
        };
        Ok(PlannerConfig {
            force,
            force_backend: None,
            calib_rows: c.calib_rows,
            calib_reps: c.calib_reps.max(1),
            cache_path: c.cache_path.as_ref().map(PathBuf::from),
            cache_ttl_secs: c.cache_ttl_secs,
            shadow_every: c.shadow_every,
            shadow_every_max: c.shadow_every_max,
            shadow_busy_rows: c.shadow_busy_rows,
            bucket_learn_window: c.bucket_learn_window,
            recall_probe_rows: c.recall_probe_rows,
            recall_margin_milli: c.recall_margin_milli,
        })
    }
}

/// True when this mode's results are the exact top-k multiset (so any
/// exact algorithm may substitute).
pub fn is_exact_semantics(mode: Mode) -> bool {
    matches!(mode, Mode::Exact { eps_rel } if eps_rel <= 1e-15)
}

/// Cache key for a mode — also the key backends match tiles against.
/// `Mode::tag()` is a display label that rounds eps to one significant
/// digit; here loose-eps exact modes keep nine significant digits (a
/// lossless f32 round-trip) so two requests with different eps settings
/// never collide on one cached plan, and every `es{N}` stays distinct
/// from `exact` and from every other `es{M}`.
pub fn mode_key(mode: Mode) -> String {
    match mode {
        Mode::Exact { eps_rel } if eps_rel <= 1e-15 => "exact".into(),
        Mode::Exact { eps_rel } => format!("exact_eps{eps_rel:.9e}"),
        Mode::EarlyStop { max_iter } => format!("es{max_iter}"),
        // the recall target is an integer in thousandths: lossless
        Mode::Approx { recall_milli } => format!("apx{recall_milli}"),
    }
}

/// The [`mode_key`] a compiled tile is indexed under, derived from its
/// manifest metadata (`mode` / `max_iter` fields). Kept next to
/// [`mode_key`] so the key a tile table is *built* with and the key a
/// request *looks up* with can never drift apart — both sides go
/// through `mode_key`. Returns `None` for metadata naming no known
/// mode (the tile is skipped, matching the manifest-driven contract).
pub fn tile_mode_key(meta_mode: &str, max_iter: usize) -> Option<String> {
    match meta_mode {
        "exact" => Some(mode_key(Mode::EXACT)),
        "early_stop" => {
            Some(mode_key(Mode::EarlyStop { max_iter: max_iter as u32 }))
        }
        _ => None,
    }
}

/// The algorithms the planner may choose for a shape.
pub fn candidates(m: usize, k: usize, mode: Mode) -> Vec<RowAlgo> {
    let _ = (m, k);
    if is_exact_semantics(mode) {
        let mut v = vec![RowAlgo::RTopK(mode)];
        v.extend(RowAlgo::all_baselines());
        v
    } else if let Mode::Approx { .. } = mode {
        // the recall race: the two-stage kernel, the paper's early-stop
        // kernel at increasing budgets, and exact selection as the
        // always-qualifying floor. Calibration measures each one's
        // recall and disqualifies the ones below the target before the
        // timing race picks a winner; all members are RTop-K-family, so
        // the cache's kernel-pairing rule for non-exact keys holds.
        vec![
            RowAlgo::RTopK(mode),
            RowAlgo::RTopK(Mode::EarlyStop { max_iter: 4 }),
            RowAlgo::RTopK(Mode::EarlyStop { max_iter: 6 }),
            RowAlgo::RTopK(Mode::EarlyStop { max_iter: 8 }),
            RowAlgo::RTopK(Mode::EXACT),
        ]
    } else {
        // early-stop / loose-eps semantics are defined by the paper's
        // kernel
        vec![RowAlgo::RTopK(mode)]
    }
}

/// The subset of [`candidates`] whose recall contract holds *without a
/// measured probe* — what the unmeasured decision paths (model-only,
/// forced-backend fallbacks) may rank for a `Mode::Approx` request:
/// the two-stage kernel (its own calibration table enforces the target
/// empirically at execution time) and exact-semantics members (recall
/// 1 by definition). Early-stop members need a measured qualification
/// probe and are dropped here. Every other mode passes through
/// unchanged.
pub fn provable_candidates(m: usize, k: usize, mode: Mode) -> Vec<RowAlgo> {
    let all = candidates(m, k, mode);
    if !matches!(mode, Mode::Approx { .. }) {
        return all;
    }
    all.into_iter()
        .filter(|a| match a {
            RowAlgo::RTopK(m) => {
                matches!(m, Mode::Approx { .. }) || is_exact_semantics(*m)
            }
            _ => true,
        })
        .collect()
}

/// Per-shape shadow re-probe state: the EWMA of the winner-vs-runner-up
/// relative edge, plus the bounded-log and demotion counters.
#[derive(Clone, Copy, Debug, Default)]
struct ShadowState {
    /// EWMA of `(runner_secs - winner_secs) / winner_secs`; negative
    /// means the runner-up is measuring faster than the cached winner
    ewma: f64,
    samples: u64,
    logged: u32,
    /// demotions fired for this shape — seeded from a persisted plan's
    /// [`ShadowHistory`] so the count survives restarts
    demotions: u32,
}

type ShapeKey = (RowBucket, usize, usize, String);

/// Load-adaptive shadow-cadence state: the effective `shadow_every`
/// plus the busy/idle streak counters behind the hysteresis.
#[derive(Clone, Copy, Debug)]
struct CadenceState {
    /// effective cadence `shadow_due` gates on
    current: usize,
    busy_streak: u32,
    idle_streak: u32,
}

/// The adaptive planner: decision pipeline + shared plan cache +
/// backend registry.
pub struct Planner {
    cfg: PlannerConfig,
    backends: Arc<BackendRegistry>,
    cache: PlanCache,
    /// Plans decided under a `force_algo` / `backend.force` pin. Kept
    /// apart from the adaptive cache so a pinned run neither trusts nor
    /// overwrites (and at save() time never erases) persisted
    /// calibration — the pin is session state, the adaptive cache is
    /// measurement.
    forced_cache: PlanCache,
    /// Single-flight guard for cache misses: without it, concurrent
    /// workers first touching a shape would calibrate simultaneously,
    /// timing each other's CPU contention and caching whichever noisy
    /// result landed last.
    decide_lock: Mutex<()>,
    /// Per-shape backend measurements (reporting; `rtopk plan`).
    probe_log: Mutex<Vec<BackendProbe>>,
    /// Dispatch counter behind [`Planner::shadow_due`].
    shadow_ctr: AtomicU64,
    /// Per-shape shadow EWMA state.
    shadow: Mutex<BTreeMap<ShapeKey, ShadowState>>,
    /// Total shadow measurements recorded (reporting / tests).
    shadow_seen: AtomicU64,
    /// Load-adaptive cadence streaks ([`Planner::note_load`]).
    cadence: Mutex<CadenceState>,
    /// Lock-free mirror of `cadence.current` — `shadow_due` runs on
    /// every dispatched batch and must not take the streak lock.
    cadence_current: AtomicUsize,
}

impl Default for Planner {
    fn default() -> Self {
        Planner::new(PlannerConfig::default())
    }
}

impl Planner {
    /// Build a CPU-only planner; loads the persisted cache if the
    /// configured path exists (a missing file is not an error — first
    /// run).
    pub fn new(cfg: PlannerConfig) -> Planner {
        Planner::with_backends(cfg, Arc::new(BackendRegistry::cpu_only()))
    }

    /// Build a planner over a backend registry — every registered
    /// backend becomes a calibratable candidate.
    pub fn with_backends(cfg: PlannerConfig, backends: Arc<BackendRegistry>) -> Planner {
        let cache = PlanCache::new();
        if let Some(path) = &cfg.cache_path {
            if path.exists() {
                if let Err(e) = cache.load_with_ttl(path, cfg.cache_ttl_secs) {
                    eprintln!("planner: ignoring plan cache (re-calibrating): {e}");
                }
            }
        }
        // re-seed shadow state from persisted demotion history: the
        // EWMA restarts (post-demotion it watches the other direction
        // from zero, exactly the in-process reset) but the demotion
        // counter carries across restarts
        let mut shadow = BTreeMap::new();
        for (bucket, cols, k, mode, plan) in cache.snapshot() {
            if let Some(h) = plan.shadow {
                shadow.insert(
                    (bucket, cols, k, mode),
                    ShadowState { demotions: h.demotions, ..ShadowState::default() },
                );
            }
        }
        let base_cadence = cfg.shadow_every;
        Planner {
            cfg,
            backends,
            cache,
            forced_cache: PlanCache::new(),
            decide_lock: Mutex::new(()),
            probe_log: Mutex::new(Vec::new()),
            shadow_ctr: AtomicU64::new(0),
            shadow: Mutex::new(shadow),
            shadow_seen: AtomicU64::new(0),
            cadence: Mutex::new(CadenceState {
                current: base_cadence,
                busy_streak: 0,
                idle_streak: 0,
            }),
            cadence_current: AtomicUsize::new(base_cadence),
        }
    }

    pub fn config(&self) -> &PlannerConfig {
        &self.cfg
    }

    pub fn cache(&self) -> &PlanCache {
        &self.cache
    }

    pub fn backends(&self) -> &BackendRegistry {
        &self.backends
    }

    /// Snapshot of every backend probe recorded so far.
    pub fn probe_log(&self) -> Vec<BackendProbe> {
        self.probe_log.lock().unwrap().clone()
    }

    /// The forced algorithm for a request mode, if a pin is configured.
    fn forced_algo(&self, mode: Mode) -> Option<RowAlgo> {
        self.cfg.force.map(|force| match force {
            ForceAlgo::RTopK => RowAlgo::RTopK(mode),
            ForceAlgo::Fixed(a) if is_exact_semantics(mode) => a,
            // approximate request: the pin cannot change semantics,
            // keep the paper's kernel at the requested mode
            ForceAlgo::Fixed(_) => RowAlgo::RTopK(mode),
        })
    }

    /// Normalize a cached adaptive plan for this request: stamp the
    /// source (a recall is a recall, wherever the entry came from) and,
    /// for exact-family requests, re-stamp the RTopK eps — the cached
    /// algo may carry a lossily-serialized eps (JSON stores the display
    /// tag); the request's own mode is authoritative there. The
    /// runner-up gets the same re-stamp so a shadow demotion can never
    /// swap in a stale eps. Early-stop and apx tags round-trip
    /// losslessly, and a `Mode::Approx` request's cached winner may
    /// legitimately be a *different* RTopK mode (the recall race admits
    /// exact and early-stop candidates), so those are never rewritten.
    fn recall(mut p: Plan, mode: Mode) -> Plan {
        if matches!(mode, Mode::Exact { .. }) {
            if let RowAlgo::RTopK(Mode::Exact { .. }) = p.algo {
                p.algo = RowAlgo::RTopK(mode);
            }
            if let Some(ru) = &mut p.runner_up {
                if let RowAlgo::RTopK(Mode::Exact { .. }) = ru.algo {
                    ru.algo = RowAlgo::RTopK(mode);
                }
            }
        }
        p.source = PlanSource::Cached;
        p
    }

    /// A cached plan is only trusted if this process actually has its
    /// backend *and* that backend still supports the shape (tiles can
    /// disappear when artifacts are regenerated); otherwise the shape
    /// is re-decided with what exists.
    fn usable(&self, p: &Plan, cols: usize, k: usize, mode: Mode) -> bool {
        self.backends
            .get(&p.backend)
            .is_some_and(|b| b.supports(cols, k, mode))
    }

    /// The row bucket `rows` plans under, using the cache's current
    /// (possibly learned) boundaries.
    pub fn bucket_of(&self, rows: usize) -> RowBucket {
        RowBucket::of_with(rows, self.cache.bounds())
    }

    /// Decide (or recall) the plan for a batch shape. `rows` is the
    /// batch's row count; it selects the [`RowBucket`] key dimension
    /// under the current (possibly learned) boundaries.
    pub fn plan(&self, rows: usize, cols: usize, k: usize, mode: Mode) -> Plan {
        let base_grain = default_grain(cols);
        let bucket = self.bucket_of(rows);
        let key = mode_key(mode);
        if self.cfg.force.is_some() || self.cfg.force_backend.is_some() {
            // Pinned: the pin fixes the algorithm and/or backend, not
            // the tuning — decided once into the session-local forced
            // cache; the persisted adaptive cache is left alone.
            if let Some(p) = self.forced_cache.get(bucket, cols, k, &key) {
                return p;
            }
            let _guard = self.decide_lock.lock().unwrap();
            if let Some(p) = self.forced_cache.get(bucket, cols, k, &key) {
                return p;
            }
            let plan = self.decide_forced(bucket, cols, k, mode, base_grain);
            self.forced_cache.insert(bucket, cols, k, &key, plan.clone());
            return plan;
        }
        if let Some(p) = self.cache.get(bucket, cols, k, &key) {
            if self.usable(&p, cols, k, mode) {
                return Self::recall(p, mode);
            }
        }
        // Single-flight: serialize first-touch calibration so probe
        // timings are not contended, then re-check the cache (another
        // worker may have decided while we waited for the lock).
        let _guard = self.decide_lock.lock().unwrap();
        if let Some(p) = self.cache.get(bucket, cols, k, &key) {
            if self.usable(&p, cols, k, mode) {
                return Self::recall(p, mode);
            }
        }
        let plan = self.decide(bucket, cols, k, mode, base_grain);
        self.cache.insert(bucket, cols, k, &key, plan.clone());
        plan
    }

    /// Backend prior when nothing is measured (calibration disabled):
    /// the first registered accelerator carrying a compiled variant for
    /// the shape — the old manifest-only router's rule — else the CPU
    /// engine.
    fn prior_backend(&self, cols: usize, k: usize, mode: Mode) -> String {
        self.backends
            .accelerators()
            .into_iter()
            .find(|b| b.supports(cols, k, mode))
            .map(|b| b.id().to_string())
            .unwrap_or_else(|| CPU_BACKEND_ID.to_string())
    }

    /// Resolve a `backend.force` pin for a shape: the pinned backend if
    /// it exists and supports the shape, else the CPU engine. `None`
    /// when no pin is configured.
    fn forced_backend_for(&self, cols: usize, k: usize, mode: Mode) -> Option<String> {
        let id = self.cfg.force_backend.as_deref()?;
        if id == CPU_BACKEND_ID {
            return Some(CPU_BACKEND_ID.to_string());
        }
        match self.backends.get(id) {
            Some(b) if b.supports(cols, k, mode) => Some(id.to_string()),
            // unknown or unsupporting pin: the shape still gets served
            _ => Some(CPU_BACKEND_ID.to_string()),
        }
    }

    /// Recall qualification for `Mode::Approx` requests: measure every
    /// non-exact candidate's recall on a seeded probe workload
    /// (`recall_probe_rows` rows, the shared `topk::verify` oracle) and
    /// drop the ones below the target plus `recall_margin_milli` —
    /// **regardless of how fast they would race**. Exact-semantics
    /// candidates qualify at recall 1.0 without measurement, so the
    /// surviving family is never empty. Returns the qualified
    /// candidates plus each candidate's measured recall (disqualified
    /// ones included, for the audit trail). Every other mode passes
    /// through unmeasured.
    fn qualify_recall(
        &self,
        cols: usize,
        k: usize,
        mode: Mode,
        all: Vec<RowAlgo>,
    ) -> (Vec<RowAlgo>, Option<Vec<(RowAlgo, f64)>>) {
        let Mode::Approx { recall_milli } = mode else {
            return (all, None);
        };
        let need = (recall_milli as u32 + self.cfg.recall_margin_milli as u32)
            .min(1000) as f64
            / 1000.0;
        let rx = calibrate::probe_workload(self.cfg.recall_probe_rows.max(8), cols);
        let mut measured = Vec::with_capacity(all.len());
        let mut keep = Vec::new();
        for a in all {
            let r = match a {
                RowAlgo::RTopK(m) if !is_exact_semantics(m) => {
                    calibrate::measure_recall(&rx, k, a)
                }
                // exact algorithms return the exact multiset: recall 1
                _ => 1.0,
            };
            measured.push((a, r));
            if r >= need {
                keep.push(a);
            }
        }
        (keep, Some(measured))
    }

    /// Race the CPU candidates on a probe workload; returns the winning
    /// `(algo, grain, secs)` with the grain neighborhood calibrated,
    /// plus every candidate's raw probe (fastest first, the winner's
    /// entry carrying its grain-calibrated time) and — for recall-
    /// contracted requests — the winner's measured recall from the
    /// qualification gate.
    fn race_cpu_on(
        &self,
        x: &RowMatrix,
        cols: usize,
        k: usize,
        mode: Mode,
        base_grain: usize,
    ) -> (RowAlgo, usize, f64, Vec<calibrate::Probe>, Option<f64>) {
        let (cands, recalls) =
            self.qualify_recall(cols, k, mode, candidates(cols, k, mode));
        let (mut probes, algo, base_secs) = if cands.len() == 1 {
            // nothing to race, but the grain is still worth measuring
            let secs = calibrate::time_candidate(
                x,
                k,
                cands[0],
                base_grain,
                self.cfg.calib_reps,
            );
            (vec![calibrate::Probe { algo: cands[0], secs }], cands[0], secs)
        } else {
            let probes = calibrate::microbench_on(
                x,
                k,
                &cands,
                self.cfg.calib_reps,
                base_grain,
            );
            let (algo, secs) = (probes[0].algo, probes[0].secs);
            (probes, algo, secs)
        };
        let (grain, secs) = calibrate::pick_grain_timed(
            x,
            k,
            algo,
            self.cfg.calib_reps,
            base_grain,
            base_secs,
        );
        probes[0].secs = secs;
        let won = recalls
            .as_ref()
            .and_then(|rs| rs.iter().find(|(a, _)| *a == algo).map(|&(_, r)| r));
        (algo, grain, secs, probes, won)
    }

    /// Race every registered accelerator backend that supports the
    /// shape against the CPU engine's measured time. Each backend is
    /// probed at its own natural batch size and the comparison is on
    /// *per-row* time, so a tiled backend is not charged for padding
    /// rows the CPU probe never computes. Probes that fail (backend
    /// unavailable here) are skipped cleanly and logged as such.
    /// Returns the winning backend id plus each successful accelerator
    /// probe as `(id, secs, rows)`.
    fn race_backends_on(
        &self,
        bucket: RowBucket,
        x: &RowMatrix,
        cols: usize,
        k: usize,
        mode: Mode,
        cpu_secs: f64,
    ) -> (String, Vec<(String, f64, usize)>) {
        let key = mode_key(mode);
        let cpu_rows = x.rows.max(1);
        let mut entries = vec![BackendProbe {
            bucket,
            cols,
            k,
            mode: key.clone(),
            backend: CPU_BACKEND_ID.to_string(),
            secs: Some(cpu_secs),
            rows: cpu_rows,
            chosen: false,
        }];
        let mut accel = Vec::new();
        let mut best_id = CPU_BACKEND_ID.to_string();
        let mut best_per_row = cpu_secs / cpu_rows as f64;
        for b in self.backends.accelerators() {
            if !b.supports(cols, k, mode) {
                continue;
            }
            let probe =
                calibrate::time_backend(b.as_ref(), x, k, mode, self.cfg.calib_reps);
            if let Some((secs, rows)) = probe {
                let per_row = secs / rows.max(1) as f64;
                if per_row < best_per_row {
                    best_id = b.id().to_string();
                    best_per_row = per_row;
                }
                accel.push((b.id().to_string(), secs, rows));
            }
            entries.push(BackendProbe {
                bucket,
                cols,
                k,
                mode: key.clone(),
                backend: b.id().to_string(),
                secs: probe.map(|(s, _)| s),
                rows: probe.map(|(_, r)| r).unwrap_or(0),
                chosen: false,
            });
        }
        for e in &mut entries {
            e.chosen = e.backend == best_id;
        }
        self.probe_log.lock().unwrap().extend(entries);
        (best_id, accel)
    }

    fn decide(
        &self,
        bucket: RowBucket,
        cols: usize,
        k: usize,
        mode: Mode,
        base_grain: usize,
    ) -> Plan {
        if self.cfg.calib_rows == 0 {
            // model-only: the prior's pick at the default grain, the
            // manifest prior for the backend, and the prior's second
            // pick as the shadow comparator (with no calibration,
            // online measurement is the only correction signal)
            // recall-contracted shapes rank only provable members here:
            // with no calibration there is no measurement to qualify an
            // early-stop candidate against the contract
            let ranked = model::rank(&provable_candidates(cols, k, mode), cols, k);
            let backend = self.prior_backend(cols, k, mode);
            let runner_up = if backend != CPU_BACKEND_ID {
                Some(RunnerUp {
                    backend: CPU_BACKEND_ID.to_string(),
                    algo: ranked[0].0,
                    grain: base_grain,
                })
            } else {
                ranked.get(1).map(|&(a, _)| RunnerUp {
                    backend: CPU_BACKEND_ID.to_string(),
                    algo: a,
                    grain: base_grain,
                })
            };
            return Plan {
                backend,
                algo: ranked[0].0,
                grain: base_grain,
                source: PlanSource::Model,
                probes: Vec::new(),
                runner_up,
                shadow: None,
                recall: None,
            };
        }
        // one probe workload — sized for this row bucket under the
        // current boundaries — serves the algorithm race, the grain
        // neighborhood, and the backend race
        let rep_rows =
            bucket.representative_rows_with(self.cache.bounds(), self.cfg.calib_rows);
        let x = calibrate::probe_workload(rep_rows, cols);
        let (algo, grain, secs, cpu_probes, recall) =
            self.race_cpu_on(&x, cols, k, mode, base_grain);
        let (backend, accel) =
            self.race_backends_on(bucket, &x, cols, k, mode, secs);
        let probe_rows = x.rows.max(1);
        let mut probes: Vec<RawProbe> = cpu_probes
            .iter()
            .map(|p| RawProbe {
                kind: ProbeKind::Algo,
                name: p.algo.name(),
                secs: p.secs,
                rows: probe_rows,
            })
            .collect();
        probes.extend(accel.iter().map(|(id, s, r)| RawProbe {
            kind: ProbeKind::Backend,
            name: id.clone(),
            secs: *s,
            rows: (*r).max(1),
        }));
        // unified per-row ranking across CPU algorithms and backends,
        // to find the runner-up the shadow re-probe compares against
        let mut ranked: Vec<(String, RowAlgo, usize, f64)> = vec![(
            CPU_BACKEND_ID.to_string(),
            algo,
            grain,
            secs / probe_rows as f64,
        )];
        for p in cpu_probes.iter().skip(1) {
            ranked.push((
                CPU_BACKEND_ID.to_string(),
                p.algo,
                base_grain,
                p.secs / probe_rows as f64,
            ));
        }
        for (id, s, r) in &accel {
            // accelerators carry the CPU winner as their fallback algo
            ranked.push((id.clone(), algo, grain, s / (*r).max(1) as f64));
        }
        ranked.sort_by(|a, b| a.3.partial_cmp(&b.3).unwrap());
        let runner_up = ranked
            .iter()
            .find(|(b, a, _, _)| {
                if backend != CPU_BACKEND_ID {
                    b != &backend
                } else {
                    !(b == CPU_BACKEND_ID && *a == algo)
                }
            })
            .map(|(b, a, g, _)| RunnerUp {
                backend: b.clone(),
                algo: *a,
                grain: *g,
            });
        Plan {
            backend,
            algo,
            grain,
            source: PlanSource::Calibrated,
            probes,
            runner_up,
            shadow: None,
            recall,
        }
    }

    /// Decide under an operator pin: the algorithm pin fixes the CPU
    /// algorithm (grain still calibrated), the backend pin fixes the
    /// backend for shapes it supports; whichever dimension is unpinned
    /// is decided the normal way. Pinned plans never carry a runner-up:
    /// a pin is an instruction, not a measurement, so shadow re-probing
    /// must not second-guess it.
    fn decide_forced(
        &self,
        bucket: RowBucket,
        cols: usize,
        k: usize,
        mode: Mode,
        base_grain: usize,
    ) -> Plan {
        if self.cfg.calib_rows == 0 {
            let algo = self.forced_algo(mode).unwrap_or_else(|| {
                model::rank(&provable_candidates(cols, k, mode), cols, k)[0].0
            });
            let backend = self
                .forced_backend_for(cols, k, mode)
                .unwrap_or_else(|| self.prior_backend(cols, k, mode));
            return Plan {
                backend,
                algo,
                grain: base_grain,
                source: PlanSource::Forced,
                probes: Vec::new(),
                runner_up: None,
                shadow: None,
                recall: None,
            };
        }
        let rep_rows =
            bucket.representative_rows_with(self.cache.bounds(), self.cfg.calib_rows);
        let x = calibrate::probe_workload(rep_rows, cols);
        let (algo, grain, secs) = match self.forced_algo(mode) {
            Some(algo) => {
                let base_secs = calibrate::time_candidate(
                    &x,
                    k,
                    algo,
                    base_grain,
                    self.cfg.calib_reps,
                );
                let (grain, secs) = calibrate::pick_grain_timed(
                    &x,
                    k,
                    algo,
                    self.cfg.calib_reps,
                    base_grain,
                    base_secs,
                );
                (algo, grain, secs)
            }
            None => {
                let (algo, grain, secs, _, _) =
                    self.race_cpu_on(&x, cols, k, mode, base_grain);
                (algo, grain, secs)
            }
        };
        let backend = match self.forced_backend_for(cols, k, mode) {
            Some(id) => id,
            None => self.race_backends_on(bucket, &x, cols, k, mode, secs).0,
        };
        Plan {
            backend,
            algo,
            grain,
            source: PlanSource::Forced,
            probes: Vec::new(),
            runner_up: None,
            shadow: None,
            recall: None,
        }
    }

    /// Counter-driven shadow gate: true on every Nth call, where N is
    /// the *effective* cadence — the configured `shadow_every` when the
    /// load-adaptive loop is quiet, a stretched multiple of it under
    /// sustained pressure (see [`Planner::note_load`]). With
    /// `shadow_every = 0` this returns false without touching any
    /// state, so dispatch behaves exactly as it did before shadow
    /// re-probing existed.
    pub fn shadow_due(&self) -> bool {
        let every = self.cadence_current.load(Ordering::Relaxed);
        if every == 0 {
            return false;
        }
        let n = self.shadow_ctr.fetch_add(1, Ordering::Relaxed) + 1;
        n % every as u64 == 0
    }

    /// The effective shadow cadence right now (the configured base when
    /// idle, stretched under load; 0 = shadow re-probing off).
    pub fn shadow_cadence(&self) -> usize {
        self.cadence_current.load(Ordering::Relaxed)
    }

    /// The cadence ceiling: the configured `shadow_every_max`, or 8x
    /// the base when unset.
    fn cadence_max(&self) -> usize {
        let base = self.cfg.shadow_every;
        if base == 0 {
            return 0;
        }
        if self.cfg.shadow_every_max == 0 {
            base.saturating_mul(8)
        } else {
            self.cfg.shadow_every_max.max(base)
        }
    }

    /// Feed one load observation from the serving layer's telemetry
    /// (queued rows across the batcher, and the tightest deadline slack
    /// of anything queued). Shadow re-probes double-execute a batch —
    /// exactly wrong under pressure — so sustained busy readings
    /// (queue at or past `shadow_busy_rows`, or slack under
    /// [`CADENCE_NEAR_DEADLINE_US`]) stretch the effective cadence x2
    /// per [`CADENCE_STRETCH_AFTER`]-long streak up to the ceiling, and
    /// sustained idle readings restore it /2 per
    /// [`CADENCE_RESTORE_AFTER`]-long streak down to the base. A streak
    /// resets whenever the opposite reading arrives, so an alternating
    /// signal changes nothing (no flapping).
    pub fn note_load(&self, queued_rows: u64, min_slack_us: Option<u64>) {
        if self.cfg.shadow_every == 0 {
            return;
        }
        let busy = queued_rows >= self.cfg.shadow_busy_rows
            || min_slack_us.is_some_and(|s| s < CADENCE_NEAR_DEADLINE_US);
        let base = self.cfg.shadow_every;
        let max = self.cadence_max();
        let mut st = self.cadence.lock().unwrap();
        if busy {
            st.idle_streak = 0;
            st.busy_streak += 1;
            if st.busy_streak >= CADENCE_STRETCH_AFTER {
                st.busy_streak = 0;
                st.current = st.current.saturating_mul(2).min(max);
            }
        } else {
            st.busy_streak = 0;
            st.idle_streak += 1;
            if st.idle_streak >= CADENCE_RESTORE_AFTER {
                st.idle_streak = 0;
                st.current = (st.current / 2).max(base);
            }
        }
        self.cadence_current.store(st.current, Ordering::Relaxed);
    }

    /// Re-derive the row-bucket boundaries from an observed rows
    /// window (the telemetry hub's recent batch sizes): the P33/P66
    /// quantiles become the new `(b0, b1)` split, so each bucket
    /// covers roughly a third of real traffic instead of a guessed
    /// range. Guarded three ways: a minimum sample count
    /// ([`BUCKET_LEARN_MIN_SAMPLES`]), a minimum relative move per
    /// boundary ([`BUCKET_MOVE_MIN_REL`] — re-keying the cache must
    /// not thrash on quantile jitter), and `b1 >= 2*b0` (degenerate
    /// splits collapse a bucket). Operator pins freeze tuning, this
    /// included. Returns whether the boundaries changed; cached plans
    /// are re-bucketed, never discarded
    /// ([`cache::PlanCache::set_bounds`]).
    pub fn relearn_buckets(&self, rows_window: &[u32]) -> bool {
        if self.cfg.force.is_some() || self.cfg.force_backend.is_some() {
            return false;
        }
        if rows_window.len() < BUCKET_LEARN_MIN_SAMPLES {
            return false;
        }
        let mut sorted: Vec<u32> = rows_window.to_vec();
        sorted.sort_unstable();
        let q = |p: f64| -> usize {
            let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
            sorted[idx.min(sorted.len() - 1)] as usize
        };
        let b0 = q(1.0 / 3.0).max(8);
        let b1 = q(2.0 / 3.0).max(b0.saturating_mul(2));
        let (c0, c1) = self.cache.bounds();
        let moved = |new: usize, old: usize| {
            (new as f64 - old as f64).abs() / (old as f64).max(1.0)
                >= BUCKET_MOVE_MIN_REL
        };
        if !moved(b0, c0) && !moved(b1, c1) {
            return false;
        }
        self.cache.set_bounds(b0, b1);
        // re-keying breaks the shape attribution of in-flight shadow
        // EWMAs; restart them (persisted demotion counters stay with
        // their plans, exactly as across a process restart)
        let mut g = self.shadow.lock().unwrap();
        for st in g.values_mut() {
            st.ewma = 0.0;
            st.samples = 0;
        }
        true
    }

    /// Total shadow measurements recorded so far.
    pub fn shadow_observations(&self) -> u64 {
        self.shadow_seen.load(Ordering::Relaxed)
    }

    /// Feed one shadow measurement for a shape: the dispatched winner
    /// took `winner_secs`, the plan's runner-up took `runner_secs` on
    /// the *same* live batch. Updates the shape's EWMA edge; when the
    /// edge inverts past [`SHADOW_MARGIN`] (with at least
    /// [`SHADOW_MIN_SAMPLES`] samples) the cached winner is demoted —
    /// the runner-up takes the plan, the old winner becomes the new
    /// comparator, and the EWMA restarts so re-promotion needs fresh
    /// evidence (hysteresis, not flapping). Returns whether a demotion
    /// fired. No-op under operator pins and for shapes without a cached
    /// adaptive plan or runner-up.
    pub fn record_shadow(
        &self,
        rows: usize,
        cols: usize,
        k: usize,
        mode: Mode,
        winner_secs: f64,
        runner_secs: f64,
    ) -> bool {
        if self.cfg.force.is_some() || self.cfg.force_backend.is_some() {
            return false;
        }
        let bucket = self.bucket_of(rows);
        let key = mode_key(mode);
        let Some(plan) = self.cache.get(bucket, cols, k, &key) else {
            return false;
        };
        let Some(ru) = plan.runner_up.clone() else {
            return false;
        };
        if !(winner_secs.is_finite() && runner_secs.is_finite()) {
            return false;
        }
        self.shadow_seen.fetch_add(1, Ordering::Relaxed);
        let edge = (runner_secs - winner_secs) / winner_secs.max(1e-12);
        let mut g = self.shadow.lock().unwrap();
        let st = g.entry((bucket, cols, k, key.clone())).or_default();
        st.ewma = if st.samples == 0 {
            edge
        } else {
            SHADOW_EWMA_ALPHA * edge + (1.0 - SHADOW_EWMA_ALPHA) * st.ewma
        };
        st.samples += 1;
        if st.samples < SHADOW_MIN_SAMPLES || st.ewma >= -SHADOW_MARGIN {
            return false;
        }
        // Demote: the runner-up takes the plan; the displaced winner
        // stays recorded as the comparator so the edge keeps being
        // watched in the other direction. (A concurrent demotion by
        // another worker between our cache read and this insert would
        // be overwritten with the same content — both saw the same
        // cached plan — so the race is benign.)
        let old = RunnerUp {
            backend: plan.backend.clone(),
            algo: plan.algo,
            grain: plan.grain,
        };
        st.demotions += 1;
        let demoted = Plan {
            backend: ru.backend.clone(),
            algo: ru.algo,
            grain: ru.grain,
            source: PlanSource::Shadow,
            probes: plan.probes.clone(),
            runner_up: Some(old),
            // the evidence travels with the plan (and into the
            // persisted cache): a restart must neither resurrect the
            // demoted winner nor forget how often this shape flips
            shadow: Some(ShadowHistory {
                ewma: st.ewma,
                samples: st.samples,
                demotions: st.demotions,
            }),
            // the runner-up passed the same recall qualification gate at
            // decision time (unqualified candidates never become
            // runner-ups), so the contract survives the demotion; the
            // decision-time measurement travels along unchanged
            recall: plan.recall,
        };
        self.cache.insert(bucket, cols, k, &key, demoted);
        let ewma = st.ewma;
        st.ewma = 0.0;
        st.samples = 0;
        if st.logged < SHADOW_LOG_MAX {
            st.logged += 1;
            eprintln!(
                "planner: shadow re-probe demoted {}/{} for (M={cols}, k={k}, \
                 {key}, rows {}): runner-up {}/{} measured {:.0}% faster \
                 (EWMA){}",
                plan.backend,
                plan.algo.name(),
                bucket.name(),
                ru.backend,
                ru.algo.name(),
                -ewma * 100.0,
                if st.logged == SHADOW_LOG_MAX {
                    " (further demotions for this shape unlogged)"
                } else {
                    ""
                }
            );
        }
        true
    }

    /// Plan + execute one matrix: through the plan's backend when it is
    /// an accelerator (falling back to the CPU engine on error), else
    /// directly on the CPU engine.
    pub fn run(&self, x: &RowMatrix, k: usize, mode: Mode) -> TopKResult {
        let plan = self.plan(x.rows, x.cols, k, mode);
        if plan.backend != CPU_BACKEND_ID {
            if let Some(b) = self.backends.get(&plan.backend) {
                if let Ok(mut v) = b.execute(&plan.spec(), &[x], k, mode) {
                    if v.len() == 1 {
                        return v.remove(0);
                    }
                }
            }
        }
        rowwise_topk_grained(x, k, plan.algo, plan.grain)
    }

    /// Persist the cache if a path is configured (no-op otherwise).
    /// Only the adaptive cache is written: pinned (forced) decisions
    /// never reach disk.
    pub fn save(&self) -> Result<(), String> {
        match &self.cfg.cache_path {
            Some(path) => self.cache.save(path),
            None => Ok(()),
        }
    }
}

static GLOBAL: OnceLock<Planner> = OnceLock::new();

/// The process-wide planner behind
/// [`crate::topk::rowwise::rowwise_topk_auto`] (default knobs, CPU-only
/// registry, no persistence). Services build their own [`Planner`] from
/// `ServeConfig` instead.
pub fn global() -> &'static Planner {
    GLOBAL.get_or_init(|| Planner::new(PlannerConfig::default()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topk::rowwise::rowwise_topk_with;
    use crate::util::rng::Rng;

    fn quick_planner() -> Planner {
        Planner::new(PlannerConfig {
            calib_rows: 32,
            calib_reps: 1,
            ..PlannerConfig::default()
        })
    }

    fn bare_plan(algo: RowAlgo, grain: usize) -> Plan {
        Plan {
            backend: CPU_BACKEND_ID.into(),
            algo,
            grain,
            source: PlanSource::Cached,
            probes: Vec::new(),
            runner_up: None,
            shadow: None,
            recall: None,
        }
    }

    #[test]
    fn row_buckets_partition_and_roundtrip() {
        assert_eq!(RowBucket::of(1), RowBucket::Le64);
        assert_eq!(RowBucket::of(64), RowBucket::Le64);
        assert_eq!(RowBucket::of(65), RowBucket::Le1024);
        assert_eq!(RowBucket::of(1024), RowBucket::Le1024);
        assert_eq!(RowBucket::of(1025), RowBucket::Gt1024);
        for b in RowBucket::ALL {
            assert_eq!(RowBucket::parse(b.name()).unwrap(), b);
        }
        assert!(RowBucket::parse("le9000").is_err());
        // representative probes live inside their bucket
        for calib in [0usize, 32, 192, 4096] {
            assert_eq!(
                RowBucket::of(RowBucket::Le64.representative_rows(calib)),
                RowBucket::Le64
            );
            assert_eq!(
                RowBucket::of(RowBucket::Le1024.representative_rows(calib)),
                RowBucket::Le1024
            );
            assert_eq!(
                RowBucket::of(RowBucket::Gt1024.representative_rows(calib)),
                RowBucket::Gt1024
            );
        }
    }

    #[test]
    fn exact_candidates_cover_zoo_approximate_pin_kernel() {
        assert_eq!(candidates(256, 32, Mode::EXACT).len(), 7);
        let es = candidates(256, 32, Mode::EarlyStop { max_iter: 4 });
        assert_eq!(es, vec![RowAlgo::RTopK(Mode::EarlyStop { max_iter: 4 })]);
        // a loose exact eps is approximate too
        let loose = candidates(256, 32, Mode::Exact { eps_rel: 1e-4 });
        assert_eq!(loose.len(), 1);
        // a recall contract races the whole RTop-K family: the
        // requested two-stage mode, the early-stop ladder, and the
        // exact kernel as the always-qualified fallback
        let apx = candidates(256, 32, Mode::Approx { recall_milli: 950 });
        assert_eq!(apx.len(), 5);
        assert!(apx.iter().all(|a| matches!(a, RowAlgo::RTopK(_))));
        assert_eq!(apx[0], RowAlgo::RTopK(Mode::Approx { recall_milli: 950 }));
        assert_eq!(*apx.last().unwrap(), RowAlgo::RTopK(Mode::EXACT));
    }

    #[test]
    fn provable_candidates_drop_unmeasurable_family_members() {
        // under a recall contract, paths with no calibration probe may
        // only rank members whose recall is provable without
        // measurement: the contracted mode itself (analytic binomial
        // bound) and exact kernels (recall 1 by construction)
        let prov = provable_candidates(256, 32, Mode::Approx { recall_milli: 950 });
        assert!(!prov.is_empty());
        for a in &prov {
            match a {
                RowAlgo::RTopK(m) => assert!(
                    matches!(m, Mode::Approx { .. }) || is_exact_semantics(*m),
                    "unprovable member {} leaked into model-only ranking",
                    a.name()
                ),
                other => panic!("non-RTopK member {} under a recall key", other.name()),
            }
        }
        // every other mode passes through unchanged
        assert_eq!(
            provable_candidates(256, 32, Mode::EXACT),
            candidates(256, 32, Mode::EXACT)
        );
        let es = Mode::EarlyStop { max_iter: 4 };
        assert_eq!(provable_candidates(256, 32, es), candidates(256, 32, es));
    }

    #[test]
    fn plan_is_cached_per_shape_and_bucket() {
        let p = quick_planner();
        let a = p.plan(40, 128, 16, Mode::EXACT);
        let b = p.plan(40, 128, 16, Mode::EXACT);
        assert_eq!(a.algo, b.algo);
        assert_eq!(b.source, PlanSource::Cached);
        assert_eq!(p.cache().len(), 1);
        // same bucket, different row count: still one entry
        p.plan(10, 128, 16, Mode::EXACT);
        assert_eq!(p.cache().len(), 1);
        p.plan(40, 128, 16, Mode::EarlyStop { max_iter: 4 });
        assert_eq!(p.cache().len(), 2);
        // a different bucket of the same (cols, k, mode) is its own plan
        p.plan(500, 128, 16, Mode::EXACT);
        assert_eq!(p.cache().len(), 3);
    }

    #[test]
    fn buckets_calibrate_at_their_representative_rows() {
        let p = quick_planner();
        p.plan(8, 96, 8, Mode::EXACT); // Le64
        p.plan(500, 96, 8, Mode::EXACT); // Le1024
        let log = p.probe_log();
        let rows_for = |bucket: RowBucket| {
            log.iter()
                .find(|e| e.bucket == bucket)
                .expect("bucket probed")
                .rows
        };
        assert_eq!(
            rows_for(RowBucket::Le64),
            RowBucket::Le64.representative_rows(32)
        );
        assert_eq!(
            rows_for(RowBucket::Le1024),
            RowBucket::Le1024.representative_rows(32)
        );
    }

    #[test]
    fn row_buckets_hold_independent_winners() {
        // When probes disagree across batch geometries, each bucket
        // keeps its own winner for the same (cols, k, mode).
        let p = quick_planner();
        p.cache()
            .insert(RowBucket::Le64, 300, 10, "exact", bare_plan(RowAlgo::Heap, 8));
        p.cache().insert(
            RowBucket::Gt1024,
            300,
            10,
            "exact",
            bare_plan(RowAlgo::Radix, 64),
        );
        assert_eq!(p.plan(8, 300, 10, Mode::EXACT).algo, RowAlgo::Heap);
        assert_eq!(p.plan(5000, 300, 10, Mode::EXACT).algo, RowAlgo::Radix);
        assert_eq!(p.cache().len(), 2, "recalls must not add entries");
        // the unseeded middle bucket calibrates its own entry
        let mid = p.plan(200, 300, 10, Mode::EXACT);
        assert_eq!(mid.source, PlanSource::Calibrated);
        assert_eq!(p.cache().len(), 3);
    }

    #[test]
    fn cpu_only_planner_always_plans_the_cpu_backend() {
        let p = quick_planner();
        assert_eq!(p.plan(40, 128, 16, Mode::EXACT).backend, CPU_BACKEND_ID);
        assert_eq!(
            p.plan(40, 128, 16, Mode::EarlyStop { max_iter: 4 }).backend,
            CPU_BACKEND_ID
        );
        // the race logged the cpu probe as chosen
        let log = p.probe_log();
        assert!(!log.is_empty());
        assert!(log.iter().all(|e| e.backend == CPU_BACKEND_ID && e.chosen));
        assert!(log.iter().all(|e| e.secs.is_some()));
    }

    #[test]
    fn early_stop_plans_keep_the_papers_kernel() {
        let p = quick_planner();
        let mode = Mode::EarlyStop { max_iter: 4 };
        let plan = p.plan(40, 256, 32, mode);
        assert_eq!(plan.algo, RowAlgo::RTopK(mode));
        // single-candidate shapes still get their grain measured
        assert_eq!(plan.source, PlanSource::Calibrated);
        // and a single-candidate CPU-only race has no runner-up
        assert!(plan.runner_up.is_none());
    }

    #[test]
    fn recall_contract_plans_qualify_and_record_achieved_recall() {
        let p = quick_planner();
        let mode = Mode::Approx { recall_milli: 950 };
        let plan = p.plan(40, 512, 32, mode);
        assert_eq!(plan.source, PlanSource::Calibrated);
        assert!(
            matches!(plan.algo, RowAlgo::RTopK(_)),
            "recall keys pair with the RTop-K kernel family, got {}",
            plan.algo.name()
        );
        let r = plan
            .recall
            .expect("calibrated recall-contract plans record achieved recall");
        assert!(
            (0.95..=1.0).contains(&r),
            "winner's achieved recall {r} violates the 0.95 contract"
        );
        // cache hits keep the measured winner and its recorded recall —
        // the requested-mode re-stamp is for lossy exact-eps tags only
        let hit = p.plan(40, 512, 32, mode);
        assert_eq!(hit.source, PlanSource::Cached);
        assert_eq!(hit.algo, plan.algo);
        assert_eq!(hit.recall, plan.recall);
        // exact requests never carry a recall figure
        assert_eq!(p.plan(40, 64, 8, Mode::EXACT).recall, None);
    }

    #[test]
    fn recall_qualification_never_admits_a_below_target_candidate() {
        let p = quick_planner();
        // target 1.0: nothing below a perfect measured recall may stay
        let mode = Mode::Approx { recall_milli: 1000 };
        let all = candidates(1024, 32, mode);
        let (keep, measured) = p.qualify_recall(1024, 32, mode, all.clone());
        let measured = measured.expect("recall contracts measure the family");
        assert_eq!(measured.len(), all.len(), "every candidate gets a verdict");
        for (a, r) in &measured {
            assert!((0.0..=1.0).contains(r), "recall out of range for {}", a.name());
            assert_eq!(
                keep.contains(a),
                *r >= 1.0,
                "{} kept/dropped against its own measurement (r={r})",
                a.name()
            );
        }
        // exact members free-pass at 1.0, so the family is never empty
        assert!(keep.contains(&RowAlgo::RTopK(Mode::EXACT)));
        assert!(measured
            .iter()
            .any(|(a, r)| *a == RowAlgo::RTopK(Mode::EXACT) && *r == 1.0));
        // no contract -> no measurement, family passes through
        let (through, none) =
            p.qualify_recall(1024, 32, Mode::EXACT, candidates(1024, 32, Mode::EXACT));
        assert!(none.is_none());
        assert_eq!(through.len(), 7);
    }

    #[test]
    fn calibrated_plans_record_probes_and_a_runner_up() {
        let p = quick_planner();
        let plan = p.plan(40, 128, 16, Mode::EXACT);
        assert_eq!(plan.source, PlanSource::Calibrated);
        assert_eq!(
            plan.probes.len(),
            7,
            "every exact candidate's raw timing is recorded"
        );
        assert!(plan
            .probes
            .iter()
            .all(|pr| pr.kind == ProbeKind::Algo && pr.secs.is_finite() && pr.rows > 0));
        let ru = plan.runner_up.expect("multi-candidate race has a runner-up");
        assert_eq!(ru.backend, CPU_BACKEND_ID);
        assert_ne!(
            ru.algo, plan.algo,
            "runner-up must differ from the winner"
        );
        // the winner's probe entry carries its calibrated time
        assert_eq!(plan.probes[0].name, plan.algo.name());
    }

    #[test]
    fn distinct_loose_eps_modes_do_not_collide() {
        // Mode::tag() rounds eps to one digit; the cache key must not,
        // or two different eps settings share one plan and execute at
        // the wrong bracket precision.
        let p = quick_planner();
        let a = Mode::Exact { eps_rel: 1.04e-4 };
        let b = Mode::Exact { eps_rel: 1.4e-4 };
        assert_eq!(a.tag(), b.tag(), "premise: display tags collide");
        assert_ne!(mode_key(a), mode_key(b), "cache keys must not");
        let pa = p.plan(20, 64, 8, a);
        let pb = p.plan(20, 64, 8, b);
        assert_eq!(p.cache().len(), 2);
        assert_eq!(pa.algo, RowAlgo::RTopK(a));
        assert_eq!(pb.algo, RowAlgo::RTopK(b));
        // cache hits re-stamp the *requested* mode onto RTopK plans
        assert_eq!(p.plan(20, 64, 8, a).algo, RowAlgo::RTopK(a));
    }

    #[test]
    fn forced_algo_is_honored_only_when_semantics_allow() {
        let p = Planner::new(PlannerConfig {
            force: Some(ForceAlgo::Fixed(RowAlgo::Heap)),
            calib_rows: 32,
            calib_reps: 1,
            ..PlannerConfig::default()
        });
        let first = p.plan(20, 64, 8, Mode::EXACT);
        assert_eq!(first.algo, RowAlgo::Heap);
        assert_eq!(first.source, PlanSource::Forced);
        assert!(first.grain >= 1, "forced plans still calibrate a grain");
        assert!(first.runner_up.is_none(), "pins are not shadow-probed");
        let es = Mode::EarlyStop { max_iter: 2 };
        assert_eq!(p.plan(20, 64, 8, es).algo, RowAlgo::RTopK(es));
        // recalls (now cached) keep the pin
        assert_eq!(p.plan(20, 64, 8, Mode::EXACT).algo, RowAlgo::Heap);
        // a stale adaptive decision (e.g. loaded from a pre-pin cache
        // file) is neither trusted nor overwritten by the pinned run —
        // it survives for the day the pin is removed
        p.cache().insert(
            RowBucket::Le64,
            96,
            8,
            "exact",
            bare_plan(RowAlgo::Radix, 4),
        );
        assert_eq!(p.plan(20, 96, 8, Mode::EXACT).algo, RowAlgo::Heap);
        assert_eq!(
            p.cache().get(RowBucket::Le64, 96, 8, "exact").unwrap().algo,
            RowAlgo::Radix,
            "pinned run must not erase persisted calibration"
        );
    }

    #[test]
    fn model_only_mode_skips_calibration() {
        let p = Planner::new(PlannerConfig {
            calib_rows: 0,
            ..PlannerConfig::default()
        });
        let plan = p.plan(40, 256, 32, Mode::EXACT);
        assert_eq!(plan.source, PlanSource::Model);
        assert_eq!(plan.backend, CPU_BACKEND_ID, "no accelerators registered");
        // the prior must not pick the provably-expensive tail (the
        // exact winner between rtopk and the cheap two-pass baselines
        // is the calibrator's call, not the prior's)
        assert_ne!(plan.algo, RowAlgo::Sort);
        assert_ne!(plan.algo, RowAlgo::Bitonic);
        // model-only decisions do not probe backends...
        assert!(p.probe_log().is_empty());
        // ...but still name the prior's second pick as the shadow
        // comparator — online measurement is their only correction
        let ru = plan.runner_up.expect("model plans carry a runner-up");
        assert_eq!(ru.backend, CPU_BACKEND_ID);
        assert_ne!(ru.algo, plan.algo);
    }

    #[test]
    fn run_matches_fixed_algo_oracle() {
        let p = quick_planner();
        let mut rng = Rng::seed_from(0x9A7);
        for &(m, k) in &[(64usize, 8usize), (100, 13), (256, 32)] {
            for mode in [
                Mode::EXACT,
                Mode::EarlyStop { max_iter: 4 },
                Mode::Approx { recall_milli: 900 },
            ] {
                let x = RowMatrix::random_normal(50, m, &mut rng);
                let auto = p.run(&x, k, mode);
                let plan = p.plan(x.rows, m, k, mode);
                let oracle = rowwise_topk_with(&x, k, plan.algo);
                assert_eq!(auto.values, oracle.values, "M={m} k={k}");
                assert_eq!(auto.indices, oracle.indices, "M={m} k={k}");
            }
        }
    }

    #[test]
    fn parse_force_names() {
        assert_eq!(parse_force("rtopk").unwrap(), ForceAlgo::RTopK);
        assert_eq!(
            parse_force("bucket").unwrap(),
            ForceAlgo::Fixed(RowAlgo::Bucket)
        );
        assert!(parse_force("gpu").is_err());
    }

    #[test]
    fn persistence_roundtrip_through_planner() {
        let path = std::env::temp_dir().join("rtopk_planner_persist_test.json");
        let _ = std::fs::remove_file(&path);
        let cfg = PlannerConfig {
            calib_rows: 32,
            calib_reps: 1,
            cache_path: Some(path.clone()),
            ..PlannerConfig::default()
        };
        let p = Planner::new(cfg.clone());
        let decided = p.plan(30, 96, 12, Mode::EXACT);
        p.save().unwrap();
        let q = Planner::new(cfg);
        let recalled = q.plan(30, 96, 12, Mode::EXACT);
        assert_eq!(recalled.algo, decided.algo);
        assert_eq!(recalled.grain, decided.grain);
        assert_eq!(recalled.backend, decided.backend);
        assert_eq!(recalled.source, PlanSource::Cached);
        // raw probes and the runner-up survive the roundtrip
        assert_eq!(recalled.probes, decided.probes);
        assert_eq!(recalled.runner_up, decided.runner_up);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cached_plan_for_a_missing_backend_is_rederived() {
        let p = quick_planner();
        // simulate a persisted plan naming a backend this process does
        // not carry (e.g. a pjrt-calibrated cache reused in a CPU-only
        // build)
        p.cache().insert(
            RowBucket::Le64,
            80,
            8,
            "exact",
            Plan {
                backend: "pjrt".into(),
                algo: RowAlgo::RTopK(Mode::EXACT),
                grain: 64,
                source: PlanSource::Cached,
                probes: Vec::new(),
                runner_up: None,
                shadow: None,
                recall: None,
            },
        );
        let plan = p.plan(20, 80, 8, Mode::EXACT);
        assert_eq!(plan.backend, CPU_BACKEND_ID);
        assert_eq!(plan.source, PlanSource::Calibrated, "re-decided, not trusted");
        // and the re-decision replaced the stale entry
        assert_eq!(
            p.cache().get(RowBucket::Le64, 80, 8, "exact").unwrap().backend,
            CPU_BACKEND_ID
        );
    }

    #[test]
    fn forced_backend_pin_stays_in_the_session_cache() {
        let p = Planner::new(PlannerConfig {
            force_backend: Some(CPU_BACKEND_ID.to_string()),
            calib_rows: 32,
            calib_reps: 1,
            ..PlannerConfig::default()
        });
        let plan = p.plan(20, 64, 8, Mode::EXACT);
        assert_eq!(plan.backend, CPU_BACKEND_ID);
        assert_eq!(plan.source, PlanSource::Forced);
        assert_eq!(p.cache().len(), 0, "pins must not touch the adaptive cache");
        // an unknown pinned backend still serves (cpu fallback)
        let q = Planner::new(PlannerConfig {
            force_backend: Some("warp9".to_string()),
            calib_rows: 0,
            ..PlannerConfig::default()
        });
        assert_eq!(q.plan(20, 64, 8, Mode::EXACT).backend, CPU_BACKEND_ID);
    }

    #[test]
    fn shadow_off_never_ticks() {
        let p = quick_planner(); // shadow_every = 0
        for _ in 0..16 {
            assert!(!p.shadow_due());
        }
        assert_eq!(p.shadow_observations(), 0);
    }

    #[test]
    fn shadow_due_fires_every_nth_call() {
        let p = Planner::new(PlannerConfig {
            shadow_every: 4,
            calib_rows: 0,
            ..PlannerConfig::default()
        });
        let fired: Vec<bool> = (0..8).map(|_| p.shadow_due()).collect();
        assert_eq!(
            fired,
            vec![false, false, false, true, false, false, false, true]
        );
    }

    #[test]
    fn shadow_reprobe_demotes_a_stale_winner_with_hysteresis() {
        let p = Planner::new(PlannerConfig {
            shadow_every: 1,
            calib_rows: 32,
            calib_reps: 1,
            ..PlannerConfig::default()
        });
        // seed a cached decision whose winner has gone stale
        let mut seeded = bare_plan(RowAlgo::Sort, 16);
        seeded.runner_up = Some(RunnerUp {
            backend: CPU_BACKEND_ID.into(),
            algo: RowAlgo::Heap,
            grain: 8,
        });
        p.cache().insert(RowBucket::Le64, 128, 8, "exact", seeded);
        // the runner-up measures 2x faster on every shadowed batch:
        // after the minimum sample count the winner is demoted
        let mut demoted = false;
        for _ in 0..SHADOW_MIN_SAMPLES {
            assert!(!demoted, "must not demote before the sample floor");
            demoted = p.record_shadow(16, 128, 8, Mode::EXACT, 2.0e-3, 1.0e-3);
        }
        assert!(demoted, "a persistent 2x inversion must demote");
        let now = p.plan(16, 128, 8, Mode::EXACT);
        assert_eq!(now.algo, RowAlgo::Heap);
        assert_eq!(now.grain, 8);
        assert_eq!(
            now.runner_up.as_ref().unwrap().algo,
            RowAlgo::Sort,
            "old winner becomes the comparator"
        );
        assert!(p.shadow_observations() >= SHADOW_MIN_SAMPLES);
        // hysteresis: edges inside the margin (runner-up 5% faster)
        // never flip the plan back, however many samples arrive
        for _ in 0..20 {
            assert!(!p.record_shadow(16, 128, 8, Mode::EXACT, 1.00e-3, 0.95e-3));
        }
        assert_eq!(
            p.plan(16, 128, 8, Mode::EXACT).algo,
            RowAlgo::Heap,
            "no flapping inside the hysteresis margin"
        );
    }

    #[test]
    fn shadow_demotions_persist_with_their_edge_history() {
        // ROADMAP follow-on: a restart must not resurrect a demoted
        // winner, and the demotion evidence (edge EWMA, sample count,
        // demotion counter) must survive the save/load cycle so the
        // counter keeps accumulating across restarts.
        let path = std::env::temp_dir().join("rtopk_shadow_persist_test.json");
        let _ = std::fs::remove_file(&path);
        let cfg = PlannerConfig {
            shadow_every: 1,
            calib_rows: 32,
            calib_reps: 1,
            cache_path: Some(path.clone()),
            ..PlannerConfig::default()
        };
        let p = Planner::new(cfg.clone());
        let mut seeded = bare_plan(RowAlgo::Sort, 16);
        seeded.runner_up = Some(RunnerUp {
            backend: CPU_BACKEND_ID.into(),
            algo: RowAlgo::Heap,
            grain: 8,
        });
        p.cache().insert(RowBucket::Le64, 128, 8, "exact", seeded);
        for _ in 0..SHADOW_MIN_SAMPLES {
            p.record_shadow(16, 128, 8, Mode::EXACT, 2.0e-3, 1.0e-3);
        }
        let demoted = p.cache().get(RowBucket::Le64, 128, 8, "exact").unwrap();
        assert_eq!(demoted.algo, RowAlgo::Heap, "premise: demotion fired");
        let h = demoted.shadow.expect("demoted plan carries its history");
        assert!(h.ewma < -SHADOW_MARGIN, "edge at demotion: {}", h.ewma);
        assert_eq!(h.samples, SHADOW_MIN_SAMPLES);
        assert_eq!(h.demotions, 1);
        p.save().unwrap();

        // restart: the demoted plan (and its history) load back
        let q = Planner::new(cfg);
        let recalled = q.plan(16, 128, 8, Mode::EXACT);
        assert_eq!(recalled.algo, RowAlgo::Heap, "demoted winner not resurrected");
        assert_eq!(recalled.shadow, Some(h), "edge history survived the restart");
        // a second demotion (the edge inverts back) continues the
        // persisted counter instead of restarting at 1
        for _ in 0..SHADOW_MIN_SAMPLES {
            q.record_shadow(16, 128, 8, Mode::EXACT, 2.0e-3, 1.0e-3);
        }
        let flipped = q.cache().get(RowBucket::Le64, 128, 8, "exact").unwrap();
        assert_eq!(flipped.algo, RowAlgo::Sort, "roles swapped again");
        assert_eq!(flipped.shadow.unwrap().demotions, 2, "counter accumulated");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cadence_stretches_under_sustained_load_and_restores_with_hysteresis() {
        let p = Planner::new(PlannerConfig {
            shadow_every: 4,
            shadow_every_max: 16,
            shadow_busy_rows: 1000,
            calib_rows: 0,
            ..PlannerConfig::default()
        });
        assert_eq!(p.shadow_cadence(), 4);
        // one busy report is noise, not pressure
        p.note_load(5000, None);
        assert_eq!(p.shadow_cadence(), 4);
        // the second consecutive one stretches x2
        p.note_load(5000, None);
        assert_eq!(p.shadow_cadence(), 8);
        // near-deadline slack counts as busy even with an empty queue
        p.note_load(0, Some(100));
        p.note_load(0, Some(100));
        assert_eq!(p.shadow_cadence(), 16);
        // the configured ceiling holds
        p.note_load(5000, None);
        p.note_load(5000, None);
        assert_eq!(p.shadow_cadence(), 16);
        // restoring wants a longer streak: three idle reports change
        // nothing...
        for _ in 0..3 {
            p.note_load(0, None);
        }
        assert_eq!(p.shadow_cadence(), 16);
        // ...the fourth steps back down, towards (never past) the base
        p.note_load(0, None);
        assert_eq!(p.shadow_cadence(), 8);
        // an alternating load signal resets both streaks: no flapping
        for _ in 0..8 {
            p.note_load(5000, None);
            p.note_load(0, None);
        }
        assert_eq!(p.shadow_cadence(), 8);
        // sustained calm walks all the way back to the base and stops
        for _ in 0..20 {
            p.note_load(0, None);
        }
        assert_eq!(p.shadow_cadence(), 4);
        // shadow_due gates on the effective cadence
        let fired = (0..8).filter(|_| p.shadow_due()).count();
        assert_eq!(fired, 2, "cadence 4 over 8 calls fires twice");
    }

    #[test]
    fn cadence_is_inert_when_shadowing_is_off() {
        let p = quick_planner(); // shadow_every = 0
        p.note_load(1_000_000, Some(0));
        p.note_load(1_000_000, Some(0));
        assert_eq!(p.shadow_cadence(), 0);
        assert!(!p.shadow_due());
    }

    #[test]
    fn relearned_buckets_rekey_plans_and_redirect_lookups() {
        let p = quick_planner();
        let first = p.plan(500, 96, 8, Mode::EXACT); // medium under seeds
        assert_eq!(first.source, PlanSource::Calibrated);
        assert_eq!(p.cache().len(), 1);
        // below the sample floor nothing moves
        assert!(!p.relearn_buckets(&[4u32; 8]));
        // a skewed window: two thirds of traffic is tiny, one third bulk
        let mut window = Vec::new();
        window.extend(std::iter::repeat(8u32).take(100));
        window.extend(std::iter::repeat(16u32).take(100));
        window.extend(std::iter::repeat(2000u32).take(100));
        assert!(p.relearn_buckets(&window));
        let learned = p.cache().bounds();
        assert_ne!(learned, RowBucket::DEFAULT_BOUNDS);
        assert!(learned.1 >= learned.0 * 2, "degenerate split: {learned:?}");
        // the cached plan was re-keyed by its probe geometry, so the
        // same request recalls it instead of re-calibrating
        let recalled = p.plan(500, 96, 8, Mode::EXACT);
        assert_eq!(recalled.source, PlanSource::Cached, "calibration survived");
        assert_eq!(recalled.algo, first.algo);
        assert_eq!(p.cache().len(), 1);
        // a tiny request now calibrates in its own (learned) bucket at
        // the learned geometry
        let small = p.plan(10, 96, 8, Mode::EXACT);
        assert_eq!(small.source, PlanSource::Calibrated);
        assert_eq!(p.cache().len(), 2);
        // quantile jitter below the move threshold must not re-key
        let mut jitter = Vec::new();
        jitter.extend(std::iter::repeat(8u32).take(100));
        jitter.extend(std::iter::repeat(20u32).take(100));
        jitter.extend(std::iter::repeat(2000u32).take(100));
        assert!(!p.relearn_buckets(&jitter));
        assert_eq!(p.cache().bounds(), learned);
    }

    #[test]
    fn pinned_planners_do_not_relearn_buckets() {
        let p = Planner::new(PlannerConfig {
            force: Some(ForceAlgo::RTopK),
            calib_rows: 0,
            ..PlannerConfig::default()
        });
        assert!(!p.relearn_buckets(&vec![8u32; 300]));
        assert_eq!(p.cache().bounds(), RowBucket::DEFAULT_BOUNDS);
    }

    #[test]
    fn shadow_ignores_shapes_without_plans_or_runner_ups() {
        let p = Planner::new(PlannerConfig {
            shadow_every: 1,
            calib_rows: 32,
            calib_reps: 1,
            ..PlannerConfig::default()
        });
        // no cached plan at all
        assert!(!p.record_shadow(16, 64, 4, Mode::EXACT, 2.0, 1.0));
        // cached plan without a runner-up
        p.cache().insert(RowBucket::Le64, 64, 4, "exact", bare_plan(RowAlgo::Heap, 8));
        assert!(!p.record_shadow(16, 64, 4, Mode::EXACT, 2.0, 1.0));
        assert!(!p.record_shadow(16, 64, 4, Mode::EXACT, 2.0, 1.0));
        assert!(!p.record_shadow(16, 64, 4, Mode::EXACT, 2.0, 1.0));
        assert_eq!(p.plan(16, 64, 4, Mode::EXACT).algo, RowAlgo::Heap);
    }
}
