//! Readiness seam for the socket loops: a minimal epoll-style
//! interface the server and router block on, with two implementations
//! and no async runtime or external crate behind either.
//!
//! * [`PollReactor`] (Linux) — raw FFI to `poll(2)`. The C library is
//!   already linked by std, so the one symbol is declared by hand
//!   instead of depending on the (un-vendored) `libc` crate. `pollfd`
//!   is plain `repr(C)` on every architecture — unlike `epoll_event`,
//!   which is packed only on x86-64 — so there is no layout hazard to
//!   get wrong without a compiler in the loop. Level-triggered, like
//!   epoll without `EPOLLET`; swapping an epoll/io_uring reactor in
//!   later is a change behind this trait only.
//! * [`SleepReactor`] (everywhere else) — reports every registered
//!   descriptor as maybe-ready after a short sleep.
//!
//! Both are *hints*: the connection state machine does nonblocking
//! try-read/try-write on every wake and treats `WouldBlock` as "not
//! yet", so a spurious readiness report costs one syscall, never
//! correctness. That is what makes the fallback (and any future
//! reactor) trivially safe to substitute.

use std::io;
use std::time::Duration;

/// OS-level descriptor identity, as the reactor needs it.
#[cfg(unix)]
pub type OsHandle = std::os::unix::io::RawFd;
#[cfg(not(unix))]
pub type OsHandle = u64;

/// The handle of a socket (listener or stream), portably.
#[cfg(unix)]
pub fn os_handle<T: std::os::unix::io::AsRawFd>(t: &T) -> OsHandle {
    t.as_raw_fd()
}
#[cfg(not(unix))]
pub fn os_handle<T: std::os::windows::io::AsRawSocket>(t: &T) -> OsHandle {
    t.as_raw_socket()
}

/// Interest bit: wake when the descriptor may be readable.
pub const READ: u8 = 0b01;
/// Interest bit: wake when the descriptor may be writable.
pub const WRITE: u8 = 0b10;

/// One readiness report. `readable`/`writable` are set from the OS
/// flags; error/hangup conditions report as both, so the state
/// machine discovers them on its next I/O attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    pub token: usize,
    pub readable: bool,
    pub writable: bool,
}

/// The readiness loop's blocking point. One instance per socket loop,
/// owned by that thread.
pub trait Reactor: Send {
    /// Start watching a descriptor under a caller-chosen token.
    fn register(
        &mut self,
        handle: OsHandle,
        token: usize,
        interest: u8,
    ) -> io::Result<()>;

    /// Change a registered descriptor's interest set (no-op interest
    /// is fine; unknown handles are an error).
    fn reregister(
        &mut self,
        handle: OsHandle,
        token: usize,
        interest: u8,
    ) -> io::Result<()>;

    /// Stop watching a descriptor. Must be called before the
    /// descriptor is closed.
    fn deregister(&mut self, handle: OsHandle) -> io::Result<()>;

    /// Block until something is ready or `timeout` passes. Clears and
    /// refills `out`; returning with `out` empty means timeout (or a
    /// harmless interruption).
    fn wait(&mut self, timeout: Duration, out: &mut Vec<Event>) -> io::Result<()>;
}

/// The platform-default reactor.
pub fn new_reactor() -> Box<dyn Reactor> {
    #[cfg(target_os = "linux")]
    {
        Box::new(PollReactor::new())
    }
    #[cfg(not(target_os = "linux"))]
    {
        Box::new(SleepReactor::default())
    }
}

/// Registration table shared by both implementations.
#[derive(Default)]
struct Slots(Vec<(OsHandle, usize, u8)>);

impl Slots {
    fn register(&mut self, h: OsHandle, token: usize, interest: u8) -> io::Result<()> {
        if self.0.iter().any(|&(f, _, _)| f == h) {
            return Err(io::Error::new(
                io::ErrorKind::AlreadyExists,
                "descriptor already registered",
            ));
        }
        self.0.push((h, token, interest));
        Ok(())
    }

    fn reregister(&mut self, h: OsHandle, token: usize, interest: u8) -> io::Result<()> {
        match self.0.iter_mut().find(|(f, _, _)| *f == h) {
            Some(slot) => {
                slot.1 = token;
                slot.2 = interest;
                Ok(())
            }
            None => Err(io::Error::new(
                io::ErrorKind::NotFound,
                "descriptor not registered",
            )),
        }
    }

    fn deregister(&mut self, h: OsHandle) -> io::Result<()> {
        let before = self.0.len();
        self.0.retain(|&(f, _, _)| f != h);
        if self.0.len() == before {
            return Err(io::Error::new(
                io::ErrorKind::NotFound,
                "descriptor not registered",
            ));
        }
        Ok(())
    }
}

#[cfg(target_os = "linux")]
mod sys {
    use std::os::raw::{c_int, c_ulong};

    /// `struct pollfd` from `<poll.h>`: plain `repr(C)` on every
    /// Linux architecture (no packing games, unlike `epoll_event`).
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    extern "C" {
        /// `poll(2)`. Declared by hand: the C library is linked by std
        /// on Linux, and the `libc` crate is not vendored in this
        /// build.
        pub fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
    }
}

/// `poll(2)`-backed reactor (Linux). Rebuilds the `pollfd` array from
/// the registration table on each wait — O(n) per tick, which is the
/// right trade at this fan-in (hundreds of connections, 1 ms ticks)
/// and keeps registration bookkeeping trivially correct.
#[cfg(target_os = "linux")]
pub struct PollReactor {
    slots: Slots,
    fds: Vec<sys::PollFd>,
}

#[cfg(target_os = "linux")]
impl PollReactor {
    pub fn new() -> PollReactor {
        PollReactor { slots: Slots::default(), fds: Vec::new() }
    }
}

#[cfg(target_os = "linux")]
impl Default for PollReactor {
    fn default() -> Self {
        PollReactor::new()
    }
}

#[cfg(target_os = "linux")]
impl Reactor for PollReactor {
    fn register(&mut self, h: OsHandle, token: usize, interest: u8) -> io::Result<()> {
        self.slots.register(h, token, interest)
    }

    fn reregister(&mut self, h: OsHandle, token: usize, interest: u8) -> io::Result<()> {
        self.slots.reregister(h, token, interest)
    }

    fn deregister(&mut self, h: OsHandle) -> io::Result<()> {
        self.slots.deregister(h)
    }

    fn wait(&mut self, timeout: Duration, out: &mut Vec<Event>) -> io::Result<()> {
        out.clear();
        if self.slots.0.is_empty() {
            std::thread::sleep(timeout);
            return Ok(());
        }
        self.fds.clear();
        for &(fd, _, interest) in &self.slots.0 {
            let mut events = 0i16;
            if interest & READ != 0 {
                events |= sys::POLLIN;
            }
            if interest & WRITE != 0 {
                events |= sys::POLLOUT;
            }
            self.fds.push(sys::PollFd { fd, events, revents: 0 });
        }
        // sub-millisecond timeouts round up to 1 ms: poll's 0 means
        // "don't block", which would busy-spin the loop
        let ms = timeout.as_millis().clamp(1, i32::MAX as u128) as i32;
        // SAFETY: `fds` points at `self.fds.len()` initialized PollFd
        // records owned by self and alive across the call; poll(2)
        // only writes `revents` within that range; nfds matches the
        // allocation length exactly.
        let n = unsafe {
            sys::poll(self.fds.as_mut_ptr(), self.fds.len() as _, ms)
        };
        if n < 0 {
            let e = io::Error::last_os_error();
            // a signal landed: report "nothing ready", the loop re-polls
            if e.kind() == io::ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(e);
        }
        for (pf, &(_, token, _)) in self.fds.iter().zip(&self.slots.0) {
            if pf.revents == 0 {
                continue;
            }
            let broken = pf.revents
                & (sys::POLLERR | sys::POLLHUP | sys::POLLNVAL)
                != 0;
            out.push(Event {
                token,
                // errors/hangups surface as "try your I/O": the read
                // or write will fail and the state machine handles it
                readable: pf.revents & sys::POLLIN != 0 || broken,
                writable: pf.revents & sys::POLLOUT != 0 || broken,
            });
        }
        Ok(())
    }
}

/// Portable fallback: every registered descriptor is reported with its
/// full interest set after a short sleep. Spurious wakes only — safe
/// because readiness is a hint (see the module doc).
#[derive(Default)]
pub struct SleepReactor {
    slots: Slots,
}

impl Reactor for SleepReactor {
    fn register(&mut self, h: OsHandle, token: usize, interest: u8) -> io::Result<()> {
        self.slots.register(h, token, interest)
    }

    fn reregister(&mut self, h: OsHandle, token: usize, interest: u8) -> io::Result<()> {
        self.slots.reregister(h, token, interest)
    }

    fn deregister(&mut self, h: OsHandle) -> io::Result<()> {
        self.slots.deregister(h)
    }

    fn wait(&mut self, timeout: Duration, out: &mut Vec<Event>) -> io::Result<()> {
        out.clear();
        // cap the sleep so a quiet loop still notices shutdown flags
        std::thread::sleep(timeout.min(Duration::from_millis(1)));
        for &(_, token, interest) in &self.slots.0 {
            if interest == 0 {
                continue;
            }
            out.push(Event {
                token,
                readable: interest & READ != 0,
                writable: interest & WRITE != 0,
            });
        }
        Ok(())
    }
}

#[cfg(all(test, not(rtopk_model_check)))]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn slots_reject_double_register_and_unknown_deregister() {
        let mut s = Slots::default();
        s.register(7, 1, READ).unwrap();
        assert!(s.register(7, 2, READ).is_err());
        s.reregister(7, 3, READ | WRITE).unwrap();
        assert_eq!(s.0[0], (7, 3, READ | WRITE));
        assert!(s.reregister(8, 0, READ).is_err());
        assert!(s.deregister(8).is_err());
        s.deregister(7).unwrap();
        assert!(s.0.is_empty());
    }

    #[test]
    fn sleep_reactor_reports_interest_as_readiness() {
        let mut r = SleepReactor::default();
        r.register(3, 10, READ).unwrap();
        r.register(4, 11, WRITE).unwrap();
        let mut out = Vec::new();
        r.wait(Duration::from_millis(1), &mut out).unwrap();
        assert_eq!(out.len(), 2);
        assert!(out.iter().any(|e| e.token == 10 && e.readable && !e.writable));
        assert!(out.iter().any(|e| e.token == 11 && e.writable && !e.readable));
    }

    #[test]
    fn default_reactor_sees_loopback_readability() {
        // end-to-end sanity for the platform reactor: a byte in a
        // loopback socket's receive buffer must produce a READ event
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        server_side.set_nonblocking(true).unwrap();

        let mut r = new_reactor();
        r.register(os_handle(&server_side), 42, READ).unwrap();
        let mut out = Vec::new();

        // nothing sent yet: a PollReactor reports nothing (the
        // fallback may spuriously wake; both are allowed by the trait)
        r.wait(Duration::from_millis(1), &mut out).unwrap();

        client.write_all(b"x").unwrap();
        client.flush().unwrap();
        // give loopback delivery a few ticks
        let mut seen = false;
        for _ in 0..500 {
            r.wait(Duration::from_millis(2), &mut out).unwrap();
            if out.iter().any(|e| e.token == 42 && e.readable) {
                seen = true;
                break;
            }
        }
        assert!(seen, "readable event never arrived");
        let mut buf = [0u8; 8];
        let mut s = &server_side;
        assert_eq!(s.read(&mut buf).unwrap(), 1);
        r.deregister(os_handle(&server_side)).unwrap();
    }
}
