//! End-to-end driver (DESIGN.md §validation): train a ~0.8M-parameter
//! MaxK-GCN on a synthetic Flickr-scale graph for a few hundred steps
//! through the full three-layer stack — Rust coordinator -> PJRT ->
//! AOT-lowered JAX model -> Pallas RTop-K kernel — logging the loss
//! curve, then compare the early-stopped run against the exact-top-k
//! and sort-topk baselines (Fig 5 in miniature).
//!
//!   make artifacts && cargo run --release --example gnn_training
//!   RTOPK_STEPS=50 cargo run ... (shorter run)

use rtopk::coordinator::Trainer;
use rtopk::runtime::executor::Executor;

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("run `make artifacts` first");
        std::process::exit(1);
    }
    let steps: usize = std::env::var("RTOPK_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let exec = Executor::spawn("artifacts")?;

    println!("=== phase 1: train MaxK-GCN (flickr-sim, early-stop top-k, {steps} steps) ===");
    let mut trainer =
        Trainer::new(exec.handle(), "gcn_flickr-sim_h256_k32_es4", 42)?;
    let g = trainer.graph();
    println!(
        "graph: {} nodes, {} edges, {} feats, {} classes",
        g.num_nodes,
        g.src.len(),
        g.feat_dim,
        g.num_classes
    );
    let out = trainer.train(steps, (steps / 12).max(1), |s, loss, acc| {
        println!("  step {s:4}  loss {loss:.4}  train-acc {acc:.3}");
    })?;
    println!(
        "loss curve: {:.4} -> {:.4}; {:.1} ms/step; val acc {:.3}; test acc {:.3}",
        out.losses.first().unwrap(),
        out.losses.last().unwrap(),
        out.per_step.as_secs_f64() * 1e3,
        out.final_val_acc,
        out.final_test_acc
    );

    println!("\n=== phase 2: exact top-k and sort-topk baselines ({} steps each) ===",
             steps.min(100));
    let short = steps.min(100);
    for tag in ["gcn_flickr-sim_h256_k32_exact", "gcn_flickr-sim_h256_k32_sortk"] {
        let mut t = Trainer::new(exec.handle(), tag, 42)?;
        let o = t.train(short, 0, |_, _, _| {})?;
        println!(
            "  {tag}: {:.1} ms/step, test acc {:.3}",
            o.per_step.as_secs_f64() * 1e3,
            o.final_test_acc
        );
    }
    println!("\n(expect: es4 fastest per step; accuracies within noise of each other — Fig 5's claim)");
    Ok(())
}
