//! Shape-keyed plan cache with optional JSON persistence.
//!
//! Keys are `(cols, k, mode-tag)` — the same shape key the batcher
//! groups on — so one calibration serves every batch of that shape for
//! the process lifetime, and (when a `cache_path` is configured) across
//! restarts. The on-disk format is a plain JSON document written with
//! the in-tree writer (`util::json`):
//!
//! ```json
//! {"version": 1, "plans": [
//!   {"cols": 256, "k": 32, "mode": "exact",
//!    "algo": "rtopk_exact", "grain": 64}
//! ]}
//! ```

use crate::plan::{Plan, PlanSource};
use crate::topk::rowwise::RowAlgo;
use crate::topk::types::Mode;
use crate::util::json::{self, Value};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::RwLock;

type Key = (usize, usize, String);

/// Concurrent plan cache (read-mostly; one write per new shape).
#[derive(Debug, Default)]
pub struct PlanCache {
    inner: RwLock<BTreeMap<Key, Plan>>,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    pub fn get(&self, cols: usize, k: usize, mode_tag: &str) -> Option<Plan> {
        self.inner
            .read()
            .unwrap()
            .get(&(cols, k, mode_tag.to_string()))
            .copied()
    }

    pub fn insert(&self, cols: usize, k: usize, mode_tag: &str, plan: Plan) {
        self.inner
            .write()
            .unwrap()
            .insert((cols, k, mode_tag.to_string()), plan);
    }

    pub fn len(&self) -> usize {
        self.inner.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of every cached entry (for reporting / persistence).
    pub fn snapshot(&self) -> Vec<(usize, usize, String, Plan)> {
        self.inner
            .read()
            .unwrap()
            .iter()
            .map(|((c, k, m), p)| (*c, *k, m.clone(), *p))
            .collect()
    }

    /// Serialize to the JSON document format. Forced plans are
    /// deliberately dropped: they record an operator pin, not a
    /// measurement, and persisting them would keep the pinned
    /// algorithm alive after the pin is removed from the config.
    pub fn to_json(&self) -> String {
        let plans: Vec<Value> = self
            .snapshot()
            .into_iter()
            .filter(|(_, _, _, plan)| plan.source != PlanSource::Forced)
            .map(|(cols, k, mode, plan)| {
                json::obj(vec![
                    ("cols", json::num(cols as f64)),
                    ("k", json::num(k as f64)),
                    ("mode", json::s(&mode)),
                    ("algo", json::s(&plan.algo.name())),
                    ("grain", json::num(plan.grain as f64)),
                ])
            })
            .collect();
        json::obj(vec![
            ("version", json::num(1.0)),
            ("plans", json::arr(plans)),
        ])
        .to_string()
    }

    /// Persist to a file (best-effort caller decides how to surface).
    pub fn save(&self, path: &Path) -> Result<(), String> {
        std::fs::write(path, self.to_json())
            .map_err(|e| format!("write plan cache {path:?}: {e}"))
    }

    /// Merge entries from a JSON document into this cache. All-or-
    /// nothing: a document that fails to parse anywhere leaves the
    /// cache untouched (a caller that logs "ignoring bad cache" must
    /// actually have ignored all of it).
    pub fn load_json(&self, text: &str) -> Result<usize, String> {
        let v = json::parse(text)?;
        let version = v.get("version").and_then(Value::as_usize).unwrap_or(0);
        if version != 1 {
            return Err(format!("unsupported plan-cache version {version}"));
        }
        let plans = v
            .get("plans")
            .and_then(Value::as_array)
            .ok_or("plan cache missing plans array")?;
        let mut parsed: Vec<(usize, usize, String, Plan)> = Vec::new();
        for p in plans {
            let cols = p.get("cols").and_then(Value::as_usize).ok_or("bad cols")?;
            let k = p.get("k").and_then(Value::as_usize).ok_or("bad k")?;
            let mode = p.get("mode").and_then(Value::as_str).ok_or("bad mode")?;
            let algo_name =
                p.get("algo").and_then(Value::as_str).ok_or("bad algo")?;
            let grain =
                p.get("grain").and_then(Value::as_usize).unwrap_or(0).max(1);
            let algo = parse_algo(algo_name)?;
            // an approximate mode key (early-stop / loose eps) must map
            // to the paper's kernel — any other algorithm would change
            // the output contract, not just the speed
            let key_mode = parse_mode_tag(mode)?;
            if !crate::plan::is_exact_semantics(key_mode)
                && !matches!(algo, RowAlgo::RTopK(_))
            {
                return Err(format!(
                    "plan for approximate mode {mode:?} must use the rtopk \
                     kernel, got {algo_name:?}"
                ));
            }
            parsed.push((
                cols,
                k,
                mode.to_string(),
                Plan { algo, grain, source: PlanSource::Cached },
            ));
        }
        let n = parsed.len();
        for (cols, k, mode, plan) in parsed {
            self.insert(cols, k, &mode, plan);
        }
        Ok(n)
    }

    /// Load from a file path.
    pub fn load(&self, path: &Path) -> Result<usize, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read plan cache {path:?}: {e}"))?;
        self.load_json(&text)
    }
}

/// Parse a serialized [`RowAlgo`] name (the inverse of
/// `RowAlgo::name()`): `rtopk_<mode-tag>` or a fixed-algorithm name.
pub fn parse_algo(name: &str) -> Result<RowAlgo, String> {
    match name {
        "radix" => Ok(RowAlgo::Radix),
        "quickselect" => Ok(RowAlgo::QuickSelect),
        "heap" => Ok(RowAlgo::Heap),
        "bucket" => Ok(RowAlgo::Bucket),
        "bitonic" => Ok(RowAlgo::Bitonic),
        "sort" => Ok(RowAlgo::Sort),
        _ => {
            let tag = name
                .strip_prefix("rtopk_")
                .ok_or_else(|| format!("unknown algorithm {name:?}"))?;
            Ok(RowAlgo::RTopK(parse_mode_tag(tag)?))
        }
    }
}

/// Parse a `Mode::tag()` string back into a [`Mode`].
pub fn parse_mode_tag(tag: &str) -> Result<Mode, String> {
    if tag == "exact" {
        return Ok(Mode::EXACT);
    }
    if let Some(eps) = tag.strip_prefix("exact_eps") {
        let eps_rel: f32 =
            eps.parse().map_err(|_| format!("bad mode tag {tag:?}"))?;
        return Ok(Mode::Exact { eps_rel });
    }
    if let Some(it) = tag.strip_prefix("es") {
        let max_iter: u32 =
            it.parse().map_err(|_| format!("bad mode tag {tag:?}"))?;
        return Ok(Mode::EarlyStop { max_iter });
    }
    Err(format!("unknown mode tag {tag:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(algo: RowAlgo, grain: usize) -> Plan {
        Plan { algo, grain, source: PlanSource::Calibrated }
    }

    #[test]
    fn insert_get_snapshot() {
        let c = PlanCache::new();
        assert!(c.is_empty());
        c.insert(256, 32, "exact", plan(RowAlgo::Radix, 64));
        assert_eq!(c.len(), 1);
        let p = c.get(256, 32, "exact").unwrap();
        assert_eq!(p.algo, RowAlgo::Radix);
        assert_eq!(p.grain, 64);
        assert!(c.get(256, 32, "es4").is_none());
        assert_eq!(c.snapshot().len(), 1);
    }

    #[test]
    fn json_roundtrip() {
        let c = PlanCache::new();
        c.insert(256, 32, "exact", plan(RowAlgo::RTopK(Mode::EXACT), 64));
        c.insert(512, 16, "es4", plan(RowAlgo::RTopK(Mode::EarlyStop { max_iter: 4 }), 32));
        c.insert(768, 128, "exact", plan(RowAlgo::Bucket, 21));
        let text = c.to_json();
        let d = PlanCache::new();
        assert_eq!(d.load_json(&text).unwrap(), 3);
        for (cols, k, mode, p) in c.snapshot() {
            let q = d.get(cols, k, &mode).unwrap();
            assert_eq!(q.algo, p.algo);
            assert_eq!(q.grain, p.grain);
            assert_eq!(q.source, PlanSource::Cached);
        }
    }

    #[test]
    fn file_roundtrip() {
        let c = PlanCache::new();
        c.insert(100, 10, "exact", plan(RowAlgo::QuickSelect, 8));
        let path = std::env::temp_dir().join("rtopk_plan_cache_test.json");
        c.save(&path).unwrap();
        let d = PlanCache::new();
        assert_eq!(d.load(&path).unwrap(), 1);
        assert_eq!(d.get(100, 10, "exact").unwrap().algo, RowAlgo::QuickSelect);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn parse_algo_names() {
        assert_eq!(parse_algo("radix").unwrap(), RowAlgo::Radix);
        assert_eq!(
            parse_algo("rtopk_exact").unwrap(),
            RowAlgo::RTopK(Mode::EXACT)
        );
        assert_eq!(
            parse_algo("rtopk_es4").unwrap(),
            RowAlgo::RTopK(Mode::EarlyStop { max_iter: 4 })
        );
        assert!(matches!(
            parse_algo("rtopk_exact_eps1e-4").unwrap(),
            RowAlgo::RTopK(Mode::Exact { .. })
        ));
        assert!(parse_algo("nope").is_err());
        assert!(parse_algo("rtopk_wat").is_err());
    }

    #[test]
    fn rejects_bad_documents() {
        let c = PlanCache::new();
        assert!(c.load_json("{}").is_err());
        assert!(c.load_json(r#"{"version": 2, "plans": []}"#).is_err());
        assert!(c
            .load_json(r#"{"version": 1, "plans": [{"cols": 1}]}"#)
            .is_err());
    }

    #[test]
    fn forced_plans_are_not_persisted() {
        let c = PlanCache::new();
        c.insert(256, 32, "exact", plan(RowAlgo::RTopK(Mode::EXACT), 64));
        c.insert(
            512,
            32,
            "exact",
            Plan { algo: RowAlgo::Sort, grain: 64, source: PlanSource::Forced },
        );
        let d = PlanCache::new();
        assert_eq!(d.load_json(&c.to_json()).unwrap(), 1);
        assert!(d.get(512, 32, "exact").is_none(), "pin leaked to disk");
    }

    #[test]
    fn approximate_mode_keys_require_the_rtopk_kernel() {
        let c = PlanCache::new();
        let doc = r#"{"version": 1, "plans": [
          {"cols": 256, "k": 32, "mode": "es4", "algo": "heap", "grain": 8}
        ]}"#;
        let err = c.load_json(doc).unwrap_err();
        assert!(err.contains("rtopk"), "got: {err}");
        assert!(c.is_empty());
        // the same algo under an exact key is fine
        let ok = r#"{"version": 1, "plans": [
          {"cols": 256, "k": 32, "mode": "exact", "algo": "heap", "grain": 8}
        ]}"#;
        assert_eq!(c.load_json(ok).unwrap(), 1);
    }

    #[test]
    fn bad_document_is_all_or_nothing() {
        // a valid entry followed by a broken one must not leave the
        // valid prefix merged in
        let c = PlanCache::new();
        let doc = r#"{"version": 1, "plans": [
          {"cols": 256, "k": 32, "mode": "exact", "algo": "radix", "grain": 8},
          {"cols": 512, "k": 16, "mode": "exact", "algo": "not_an_algo"}
        ]}"#;
        assert!(c.load_json(doc).is_err());
        assert!(c.is_empty(), "partial merge from a rejected document");
    }
}
