//! Persistent fork-join worker pool over std threads.
//!
//! Substrate note: rayon/tokio are not in the vendored crate set, so the
//! pool is implemented in-tree. Earlier revisions spawned fresh OS
//! threads per `parallel_*` call (`std::thread::scope`); that puts a
//! thread-create/join round trip on every batch, which dominates the
//! small-batch buckets the planner cares most about. This module instead
//! keeps `num_threads() - 1` resident workers parked on a condvar and
//! submits each `parallel_*` call as a fork-join job:
//!
//! * The submitting thread pushes one task stub per participating worker,
//!   wakes the workers, runs its own share of the work, then blocks on a
//!   completion latch until every stub has finished.
//! * Work distribution inside a job keeps the original atomic-counter
//!   dynamic scheduling: participants pull `grain`-sized index ranges
//!   from a shared counter, so uneven per-row cost still balances.
//! * A panic inside any participant is caught, stashed on the job, and
//!   re-thrown on the submitting thread after the join (first worker
//!   panic wins; the submitter's own panic is re-thrown otherwise). The
//!   pool itself survives panicking jobs.
//! * Workers that submit nested parallel work run it inline — a worker
//!   blocked on a latch cannot also drain the queue, so nesting through
//!   the queue could deadlock.
//!
//! The public entry points `parallel_ranges` / `parallel_fill` /
//! `parallel_dynamic` keep their historical signatures and chunking
//! semantics; call sites did not change. The global pool is created
//! lazily on first use and sized by [`num_threads`] at that moment
//! (`RTOPK_THREADS` env, else [`configure`]'s `[pool] threads` value,
//! else `available_parallelism`); raising the thread count after the
//! pool exists caps at the resident worker count. [`gauges`] exposes
//! job/steal/park counters and worker utilization for the telemetry hub.

// Protocol state (queue, latch, shutdown flag, worker handles) goes
// through the sync façade so the model checker can explore it; gauges,
// config, and the process-global pool stay on std (observability only —
// see util/sync.rs for the rules).
use crate::util::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::util::sync::{race_read, race_write, thread, Arc, Condvar, Mutex};
use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize as StdAtomicUsize};
use std::sync::OnceLock;
use std::time::Instant;

// ---------------------------------------------------------------------------
// Sizing
// ---------------------------------------------------------------------------

/// `[pool] threads` from config; 0 means "not configured". Process
/// global, so it stays on std (façade rule: no globals in the model).
static CONFIG_THREADS: StdAtomicUsize = StdAtomicUsize::new(0);

/// Record the `[pool] threads` config value. Takes effect for sizing the
/// global pool only if called before the pool's first job (the service
/// builder does this); the per-call participant cap always sees it.
pub fn configure(threads: usize) {
    CONFIG_THREADS.store(threads, Ordering::Relaxed);
}

/// Parse an `RTOPK_THREADS` value; `None` when it is not a positive
/// integer (the caller then warns once and falls back).
fn parse_threads(v: &str) -> Option<usize> {
    match v.trim().parse::<usize>() {
        Ok(n) if n >= 1 => Some(n),
        _ => None,
    }
}

/// Number of threads to use: `RTOPK_THREADS` env override, else the
/// `[pool] threads` config value (see [`configure`]), else
/// `std::thread::available_parallelism()`. An invalid or zero env value
/// is rejected with a single warning naming the value.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("RTOPK_THREADS") {
        match parse_threads(&v) {
            Some(n) => return n,
            None => {
                static WARN_ONCE: std::sync::Once = std::sync::Once::new();
                WARN_ONCE.call_once(|| {
                    eprintln!(
                        "rtopk: ignoring invalid RTOPK_THREADS={v:?} \
                         (expected an integer >= 1); falling back to \
                         [pool] threads / available_parallelism"
                    );
                });
            }
        }
    }
    let cfg = CONFIG_THREADS.load(Ordering::Relaxed);
    if cfg >= 1 {
        return cfg;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

// ---------------------------------------------------------------------------
// Gauges
// ---------------------------------------------------------------------------

static JOBS: AtomicU64 = AtomicU64::new(0);
static INLINE_JOBS: AtomicU64 = AtomicU64::new(0);
static TASKS: AtomicU64 = AtomicU64::new(0);
static STEALS: AtomicU64 = AtomicU64::new(0);
static PARKS: AtomicU64 = AtomicU64::new(0);
static UNPARKS: AtomicU64 = AtomicU64::new(0);
static BUSY_NS: AtomicU64 = AtomicU64::new(0);

/// Point-in-time pool counters, fed into the telemetry hub's
/// `LoadSnapshot` so operators can see substrate saturation next to
/// queue depth. All zeros until the global pool has run a job.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolGauges {
    /// Resident worker threads in the global pool (excludes submitters).
    pub workers: u64,
    /// Fork-join jobs dispatched to the workers.
    pub jobs: u64,
    /// Jobs run inline on the submitting thread (single-thread sizing,
    /// nested submission from a worker, or work too small to split).
    pub inline_jobs: u64,
    /// Worker-side task stubs executed (≈ jobs × participating workers).
    pub tasks: u64,
    /// Index ranges claimed beyond a participant's first — how much the
    /// dynamic scheduler rebalanced inside jobs.
    pub steals: u64,
    /// Times a worker parked on the condvar waiting for work.
    pub parks: u64,
    /// Times a parked worker was woken.
    pub unparks: u64,
    /// Total nanoseconds workers spent running task stubs.
    pub busy_ns: u64,
    /// `busy_ns / (workers × wall time since the pool started)`,
    /// clamped to `[0, 1]`. 0.0 when the pool has not started.
    pub utilization: f64,
}

/// Snapshot the global pool's counters. Cheap (a handful of relaxed
/// loads); safe to call from the telemetry hub on every snapshot.
pub fn gauges() -> PoolGauges {
    let (workers, elapsed_ns) = match GLOBAL.get() {
        Some(p) => (
            p.threads.saturating_sub(1) as u64,
            p.started.elapsed().as_nanos() as u64,
        ),
        None => (0, 0),
    };
    let busy_ns = BUSY_NS.load(Ordering::Relaxed);
    let utilization = if workers > 0 && elapsed_ns > 0 {
        (busy_ns as f64 / (workers as f64 * elapsed_ns as f64)).clamp(0.0, 1.0)
    } else {
        0.0
    };
    PoolGauges {
        workers,
        jobs: JOBS.load(Ordering::Relaxed),
        inline_jobs: INLINE_JOBS.load(Ordering::Relaxed),
        tasks: TASKS.load(Ordering::Relaxed),
        steals: STEALS.load(Ordering::Relaxed),
        parks: PARKS.load(Ordering::Relaxed),
        unparks: UNPARKS.load(Ordering::Relaxed),
        busy_ns,
        utilization,
    }
}

// ---------------------------------------------------------------------------
// Job + pool internals
// ---------------------------------------------------------------------------

/// One fork-join job. `func` is a raw pointer to the submitter's
/// stack-borrowed closure; it is only dereferenced between submission
/// and the completion latch flipping, and the submitter blocks on that
/// latch before returning, so the pointee outlives every use.
struct JobCore {
    func: *const (dyn Fn() + Sync),
    /// Task stubs still queued or running; the last one to finish flips
    /// `done` and wakes the submitter.
    pending: AtomicUsize,
    /// First panic payload captured from a worker-side stub.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
    done: Mutex<bool>,
    done_cv: Condvar,
}

// SAFETY: `func` crosses threads by design. The submitter keeps the
// pointee alive until `join()` observes `done == true`, which happens
// only after every dereference has completed (workers finish running
// the closure before calling `finish`).
unsafe impl Send for JobCore {}
unsafe impl Sync for JobCore {}

impl JobCore {
    fn finish(&self) {
        if self.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            let mut done = self.done.lock().unwrap();
            *done = true;
            self.done_cv.notify_all();
        }
    }

    fn join(&self) {
        let mut done = self.done.lock().unwrap();
        while !*done {
            done = self.done_cv.wait(done).unwrap();
        }
    }
}

struct PoolShared {
    queue: Mutex<VecDeque<Arc<JobCore>>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

thread_local! {
    /// True on resident pool workers; nested submissions from them run
    /// inline instead of going through the queue (deadlock avoidance).
    static IS_POOL_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

fn worker_loop(shared: Arc<PoolShared>) {
    IS_POOL_WORKER.with(|w| w.set(true));
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                PARKS.fetch_add(1, Ordering::Relaxed);
                q = shared.cv.wait(q).unwrap();
                UNPARKS.fetch_add(1, Ordering::Relaxed);
            }
        };
        let Some(job) = job else { return };
        let t0 = Instant::now();
        // Model hook: this deref must happen-after the submitter's
        // publish and happen-before its reclaim (no-op in real builds).
        race_read(job.func as *const () as usize);
        // SAFETY: see `JobCore::func` — the submitter is blocked on the
        // completion latch, so the closure is alive for this call.
        let func = unsafe { &*job.func };
        let result = catch_unwind(AssertUnwindSafe(func));
        BUSY_NS.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        TASKS.fetch_add(1, Ordering::Relaxed);
        if let Err(payload) = result {
            let mut slot = job.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
        job.finish();
    }
}

/// A persistent fork-join pool. Production code uses the lazily started
/// process-global instance via `parallel_*`; tests construct private
/// instances to exercise shutdown and panic paths deterministically.
pub struct Pool {
    shared: Arc<PoolShared>,
    workers: Mutex<Vec<thread::JoinHandle<()>>>,
    /// Total participants per job (resident workers + the submitter).
    threads: usize,
    started: Instant,
}

impl Pool {
    /// Start a pool with `threads` total participants (`threads - 1`
    /// resident workers; the submitter is always the last participant).
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let mut handles = Vec::with_capacity(threads - 1);
        for i in 0..threads - 1 {
            let sh = Arc::clone(&shared);
            handles.push(
                thread::Builder::new()
                    .name(format!("rtopk-pool-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn rtopk pool worker"),
            );
        }
        Pool { shared, workers: Mutex::new(handles), threads, started: Instant::now() }
    }

    /// Total participants per job (resident workers + submitter).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Stop the workers and join them. Queued jobs drain first (workers
    /// re-check the queue before honoring the flag). Idempotent.
    pub fn shutdown(&self) {
        #[cfg(not(rtopk_model_check_mutants))]
        {
            // Flip the flag under the queue lock so a worker between its
            // shutdown check and `cv.wait` cannot miss the wakeup.
            let _q = self.shared.queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::Release);
        }
        // Seeded missed-wakeup mutant (the historical bug class this
        // checker exists for): flipping the flag *outside* the queue
        // lock lets the store+notify land between a worker's shutdown
        // check and its park — that worker sleeps forever. The
        // `mutant_` suite asserts the model checker reports it.
        #[cfg(rtopk_model_check_mutants)]
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.cv.notify_all();
        let mut workers = self.workers.lock().unwrap();
        for handle in workers.drain(..) {
            let _ = handle.join();
        }
    }

    /// Fork-join `f` over `0..n` in `grain`-sized ranges pulled from a
    /// shared counter, with at most `threads` participants. Runs inline
    /// when one participant suffices or when called from a pool worker.
    /// Panics in any participant propagate to the caller after all
    /// participants have finished.
    pub fn run_dynamic<F>(&self, n: usize, grain: usize, threads: usize, f: &F)
    where
        F: Fn(usize, usize) + Sync,
    {
        if n == 0 {
            return;
        }
        let grain = grain.max(1);
        let extra = threads
            .saturating_sub(1)
            .min(self.threads.saturating_sub(1));
        if extra == 0 || IS_POOL_WORKER.with(|w| w.get()) {
            INLINE_JOBS.fetch_add(1, Ordering::Relaxed);
            f(0, n);
            return;
        }
        JOBS.fetch_add(1, Ordering::Relaxed);
        let next = AtomicUsize::new(0);
        let body = || {
            let mut claimed: u64 = 0;
            loop {
                let start = next.fetch_add(grain, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                claimed += 1;
                f(start, (start + grain).min(n));
            }
            if claimed > 1 {
                STEALS.fetch_add(claimed - 1, Ordering::Relaxed);
            }
        };
        self.run(extra, &body);
    }

    /// Submit `extra` worker-side stubs of `f`, run the submitter's own
    /// share, join, and re-throw any captured panic. `extra >= 1`.
    fn run<F>(&self, extra: usize, f: &F)
    where
        F: Fn() + Sync,
    {
        debug_assert!(extra >= 1);
        let wide: &(dyn Fn() + Sync) = f;
        let job = Arc::new(JobCore {
            func: wide as *const (dyn Fn() + Sync),
            pending: AtomicUsize::new(extra),
            panic: Mutex::new(None),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
        });
        // Model hook: publish the stack-borrowed closure before any
        // worker can dereference it (no-op in real builds).
        race_write(job.func as *const () as usize);
        {
            let mut q = self.shared.queue.lock().unwrap();
            for _ in 0..extra {
                q.push_back(Arc::clone(&job));
            }
        }
        if extra == 1 {
            self.shared.cv.notify_one();
        } else {
            self.shared.cv.notify_all();
        }
        // The submitter is a full participant: it drains the same atomic
        // counter as the workers, then blocks until every stub finished.
        let own = catch_unwind(AssertUnwindSafe(f));
        job.join();
        // Model hook: reclaim the borrow — the latch must order every
        // worker's dereference before this point, or it is a race.
        race_write(job.func as *const () as usize);
        let worker_panic = job.panic.lock().unwrap().take();
        if let Some(payload) = worker_panic {
            resume_unwind(payload);
        }
        if let Err(payload) = own {
            resume_unwind(payload);
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Global pool + public entry points
// ---------------------------------------------------------------------------

static GLOBAL: OnceLock<Pool> = OnceLock::new();

fn global() -> &'static Pool {
    GLOBAL.get_or_init(|| Pool::new(num_threads()))
}

/// Start the global pool (if not already started) and run one no-op job
/// so every worker has been scheduled at least once. The calibrator
/// calls this before timing so grain picking measures pool-resident
/// dispatch rates, not first-touch thread creation. Idempotent, cheap
/// once warm.
pub fn warm() {
    let pool = global();
    if pool.threads() > 1 && !IS_POOL_WORKER.with(|w| w.get()) {
        pool.run_dynamic(pool.threads(), 1, pool.threads(), &|_, _| {});
    }
}

/// Run `f(start, end)` over disjoint chunks of `0..n` with up to
/// `num_threads()` participants. Chunk boundaries match the historical
/// static split (`n.div_ceil(threads)`-sized contiguous ranges); `f`
/// runs inline when a single thread suffices.
pub fn parallel_ranges<F>(n: usize, min_chunk: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = num_threads().min(n.div_ceil(min_chunk.max(1))).max(1);
    if threads == 1 {
        INLINE_JOBS.fetch_add(1, Ordering::Relaxed);
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    global().run_dynamic(n, chunk, threads, &f);
}

/// Raw-pointer handle for disjoint-slot parallel writes; `Sync` because
/// the dynamic scheduler hands each participant non-overlapping index
/// ranges.
struct SendPtr<T>(*mut T);
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
// SAFETY: the pointer is only ever dereferenced at indices inside the
// disjoint ranges the dynamic scheduler hands out, and the pointee
// slice outlives the job (the submitter joins before returning), so
// sharing the handle across participant threads is sound.
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Map `0..n` through `f` into a pre-allocated output slice, in
/// parallel chunks. `f(i, &mut out[i])` touches only its own slot —
/// participants receive disjoint index ranges, so the writes are
/// per-slot exclusive.
pub fn parallel_fill<T, F>(out: &mut [T], min_chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = out.len();
    if n == 0 {
        return;
    }
    let threads = num_threads().min(n.div_ceil(min_chunk.max(1))).max(1);
    if threads == 1 {
        INLINE_JOBS.fetch_add(1, Ordering::Relaxed);
        for (i, v) in out.iter_mut().enumerate() {
            f(i, v);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    let base = SendPtr(out.as_mut_ptr());
    let body = move |a: usize, b: usize| {
        for i in a..b {
            // SAFETY: ranges from the dynamic counter are disjoint, so
            // each slot is written by exactly one participant, and `out`
            // outlives the job (the submitter joins before returning).
            f(i, unsafe { &mut *base.0.add(i) });
        }
    };
    global().run_dynamic(n, chunk, threads, &body);
}

/// Dynamic scheduler: participants pull `grain`-sized index ranges from
/// a shared atomic counter. Better than static chunking when per-item
/// cost varies (e.g. exact-mode rows converge at different iterations).
pub fn parallel_dynamic<F>(n: usize, grain: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let grain = grain.max(1);
    let threads = num_threads().min(n.div_ceil(grain)).max(1);
    if threads == 1 {
        INLINE_JOBS.fetch_add(1, Ordering::Relaxed);
        f(0, n);
        return;
    }
    global().run_dynamic(n, grain, threads, &f);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn ranges_cover_exactly_once() {
        let hits: Vec<AtomicU64> = (0..101).map(|_| AtomicU64::new(0)).collect();
        parallel_ranges(101, 1, |a, b| {
            for i in a..b {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn dynamic_covers_exactly_once() {
        let hits: Vec<AtomicU64> = (0..97).map(|_| AtomicU64::new(0)).collect();
        parallel_dynamic(97, 8, |a, b| {
            for i in a..b {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn fill_writes_every_slot() {
        let mut out = vec![0usize; 57];
        parallel_fill(&mut out, 4, |i, v| *v = i * 2);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * 2);
        }
    }

    #[test]
    fn empty_is_noop() {
        parallel_ranges(0, 1, |_, _| panic!("should not run"));
        parallel_dynamic(0, 1, |_, _| panic!("should not run"));
    }

    #[test]
    fn parse_threads_rejects_garbage() {
        assert_eq!(parse_threads("4"), Some(4));
        assert_eq!(parse_threads(" 2 "), Some(2));
        assert_eq!(parse_threads("0"), None);
        assert_eq!(parse_threads("-3"), None);
        assert_eq!(parse_threads("many"), None);
        assert_eq!(parse_threads(""), None);
    }

    #[test]
    fn private_pool_covers_exactly_once() {
        let pool = Pool::new(4);
        let hits: Vec<AtomicU64> = (0..513).map(|_| AtomicU64::new(0)).collect();
        pool.run_dynamic(513, 7, 4, &|a: usize, b: usize| {
            for i in a..b {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        pool.shutdown();
    }

    #[test]
    fn private_pool_propagates_panic_and_survives() {
        let pool = Pool::new(4);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.run_dynamic(64, 4, 4, &|a: usize, _b: usize| {
                if a == 32 {
                    panic!("boom at {a}");
                }
            });
        }));
        assert!(caught.is_err(), "panic in a participant must reach the submitter");
        // The pool is still usable after a panicking job.
        let hits: Vec<AtomicU64> = (0..64).map(|_| AtomicU64::new(0)).collect();
        pool.run_dynamic(64, 4, 4, &|a: usize, b: usize| {
            for i in a..b {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        pool.shutdown();
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = Pool::new(1);
        let submitter = std::thread::current().id();
        let ran_on = Mutex::new(None);
        pool.run_dynamic(8, 1, 8, &|_a: usize, _b: usize| {
            *ran_on.lock().unwrap() = Some(std::thread::current().id());
        });
        assert_eq!(*ran_on.lock().unwrap(), Some(submitter));
    }

    #[test]
    fn shutdown_is_idempotent_and_joins_workers() {
        let pool = Pool::new(3);
        pool.run_dynamic(32, 1, 3, &|_, _| {});
        pool.shutdown();
        pool.shutdown();
        assert!(pool.workers.lock().unwrap().is_empty());
    }

    #[test]
    fn gauges_are_populated_after_work() {
        // Force at least one global-pool interaction, then check the
        // snapshot is internally consistent. (Counters are process-wide,
        // so only monotone/derived properties are asserted.)
        parallel_dynamic(64, 1, |_, _| {});
        let g = gauges();
        assert!(g.jobs + g.inline_jobs >= 1);
        assert!((0.0..=1.0).contains(&g.utilization));
    }
}

/// Model-check suites: compiled only under `RUSTFLAGS="--cfg
/// rtopk_model_check"` (CI's bounded model-check job). Each test body
/// is explored across thread interleavings by the in-tree checker; see
/// rust/modelcheck/src/lib.rs for the model. Private pools only — the
/// process-global pool outlives executions and is invisible to the
/// explorer.
#[cfg(all(test, rtopk_model_check))]
mod model_tests {
    #[allow(unused_imports)]
    use super::*;

    /// Trunk protocols: every explored schedule must be free of
    /// deadlocks, data races on the erased closure, and panics.
    #[cfg(not(rtopk_model_check_mutants))]
    mod trunk {
        use super::super::*;
        use modelcheck::{model, Checker};

        /// The shutdown-vs-notify window at two threads: a worker
        /// between its shutdown check and its park must still see the
        /// wakeup (the flag flips under the queue lock). Exhaustive.
        #[test]
        fn model_shutdown_quiesces_two_threads() {
            model(|| {
                let pool = Pool::new(2);
                pool.shutdown();
            });
        }

        /// Same window with two workers racing for the same park/wake.
        #[test]
        fn model_shutdown_quiesces_three_threads() {
            let report = Checker::dfs()
                .max_executions(8_000)
                .env_caps()
                .check(|| {
                    let pool = Pool::new(3);
                    pool.shutdown();
                });
            assert!(report.failure.is_none(), "{:#?}", report.failure);
        }

        /// Full fork-join latch at three participants (2 workers + the
        /// submitter): dynamic counter covers every index exactly once,
        /// the erased-closure accesses are ordered by publish/latch,
        /// and shutdown drains cleanly afterwards.
        #[test]
        fn model_latch_three_participants() {
            let report = Checker::dfs()
                .max_executions(8_000)
                .env_caps()
                .check(|| {
                    let pool = Pool::new(3);
                    let hits: Vec<AtomicU64> =
                        (0..2).map(|_| AtomicU64::new(0)).collect();
                    pool.run_dynamic(2, 1, 3, &|a: usize, b: usize| {
                        for i in a..b {
                            hits[i].fetch_add(1, Ordering::Relaxed);
                        }
                    });
                    for h in &hits {
                        assert_eq!(h.load(Ordering::Relaxed), 1);
                    }
                    pool.shutdown();
                });
            assert!(report.failure.is_none(), "{:#?}", report.failure);
        }

        /// Four threads via seeded random walks (the DFS tree is too
        /// wide to exhaust; walks still cross the interesting windows).
        #[test]
        fn model_latch_four_threads_random() {
            let report = Checker::random(200, 0x7069).check(|| {
                let pool = Pool::new(4);
                let hits: Vec<AtomicU64> =
                    (0..3).map(|_| AtomicU64::new(0)).collect();
                pool.run_dynamic(3, 1, 4, &|a: usize, b: usize| {
                    for i in a..b {
                        hits[i].fetch_add(1, Ordering::Relaxed);
                    }
                });
                for h in &hits {
                    assert_eq!(h.load(Ordering::Relaxed), 1);
                }
                pool.shutdown();
            });
            assert!(report.failure.is_none(), "{:#?}", report.failure);
        }

        /// Panic during a job, in every interleaving: the payload
        /// reaches the submitter, the latch still completes, and the
        /// pool survives to run a second job and shut down.
        #[test]
        fn model_panic_during_job() {
            let report = Checker::dfs()
                .max_executions(8_000)
                .env_caps()
                .check(|| {
                    let pool = Pool::new(2);
                    let caught = catch_unwind(AssertUnwindSafe(|| {
                        pool.run_dynamic(2, 1, 2, &|a: usize, _b: usize| {
                            if a == 0 {
                                panic!("model boom");
                            }
                        });
                    }));
                    assert!(
                        caught.is_err(),
                        "participant panic must reach the submitter"
                    );
                    let ran = AtomicU64::new(0);
                    pool.run_dynamic(2, 1, 2, &|a: usize, b: usize| {
                        ran.fetch_add((b - a) as u64, Ordering::Relaxed);
                    });
                    assert_eq!(ran.load(Ordering::Relaxed), 2);
                    pool.shutdown();
                });
            assert!(report.failure.is_none(), "{:#?}", report.failure);
        }
    }

    /// Detector pins: with the seeded mutants compiled in
    /// (`--cfg rtopk_model_check_mutants`), the checker MUST flag the
    /// protocol — these assert the *failure*, regression-pinning the
    /// bug class the checker exists for.
    #[cfg(rtopk_model_check_mutants)]
    mod mutants {
        use super::super::*;
        use modelcheck::Checker;

        #[test]
        fn mutant_missed_wakeup_shutdown_is_caught() {
            // deliberately no env_caps(): capping exploration could
            // starve the buggy schedule and fail this test spuriously
            let report = Checker::dfs().max_executions(8_000).check(|| {
                let pool = Pool::new(2);
                pool.shutdown();
            });
            let failure = report.failure.expect(
                "flag-outside-lock shutdown must deadlock some schedule",
            );
            assert!(
                failure.message.contains("deadlock"),
                "expected a deadlock report, got: {}",
                failure.message
            );
        }
    }
}
