//! Wire-codec integration: golden-file byte pinning for schema v1
//! (committed fixture frames must encode/decode byte-exact, so an
//! accidental encoding change breaks the build), roundtrip property
//! tests over randomized frames, and adversarial truncation/corruption
//! sweeps — decode must reject with a positioned error, never panic.

use rtopk::coordinator::wire::{self, Frame, HEADER_LEN};
use rtopk::coordinator::{
    OverQuotaPolicy, Priority, SubmitRequest, ValidationPolicy,
};
use rtopk::topk::types::{Mode, TopKResult};
use rtopk::util::matrix::RowMatrix;
use rtopk::util::rng::Rng;
use std::time::Duration;

/// The request behind `fixtures/wire_submit_v1.bin` — regenerate the
/// fixture only for a deliberate, versioned format change.
fn golden_request() -> SubmitRequest {
    SubmitRequest::new(
        RowMatrix::from_vec(
            2,
            4,
            vec![1.0, -2.0, 0.5, 3.25, -0.125, 8.0, -64.0, 0.0],
        ),
        3,
    )
    .mode(Mode::EarlyStop { max_iter: 4 })
    .tenant("golden")
    .deadline(Duration::from_micros(1500))
    .priority(Priority::High)
    .validation(ValidationPolicy::Strict)
    .on_over_quota(OverQuotaPolicy::Block)
}

/// The result behind `fixtures/wire_result_v1.bin`.
fn golden_result() -> TopKResult {
    TopKResult {
        rows: 2,
        k: 2,
        values: vec![3.25, 1.0, 8.0, 0.5],
        indices: vec![3, 0, 1, 2],
    }
}

#[test]
fn golden_submit_frame_is_byte_exact() {
    let fixture: &[u8] = include_bytes!("fixtures/wire_submit_v1.bin");
    let encoded = wire::encode(&Frame::Submit(golden_request())).unwrap();
    assert_eq!(
        encoded, fixture,
        "schema-v1 submit encoding changed; peers speaking v1 would \
         mis-decode every frame — bump the wire VERSION instead"
    );
    match wire::decode(fixture).unwrap() {
        Frame::Submit(req) => assert_eq!(req, golden_request()),
        other => panic!("wrong frame kind: {other:?}"),
    }
}

#[test]
fn golden_result_frame_is_byte_exact() {
    let fixture: &[u8] = include_bytes!("fixtures/wire_result_v1.bin");
    let encoded = wire::encode(&Frame::Result(golden_result())).unwrap();
    assert_eq!(
        encoded, fixture,
        "schema-v1 result encoding changed; bump the wire VERSION instead"
    );
    match wire::decode(fixture).unwrap() {
        Frame::Result(res) => assert_eq!(res, golden_result()),
        other => panic!("wrong frame kind: {other:?}"),
    }
}

/// A randomized-but-valid request: every enum arm, optional field, and
/// shape dimension gets exercised across the sweep.
fn random_request(rng: &mut Rng) -> SubmitRequest {
    let rows = rng.index(6); // 0-row requests are legal on the wire
    let cols = 1 + rng.index(8);
    let mut data = vec![0f32; rows * cols];
    rng.fill_normal(&mut data);
    let mut req = SubmitRequest::new(
        RowMatrix::from_vec(rows, cols, data),
        1 + rng.index(cols),
    );
    match rng.index(3) {
        0 => {}
        1 => {
            req = req.mode(Mode::Exact {
                eps_rel: rng.uniform_range(1e-8, 1e-2),
            })
        }
        _ => {
            req = req.mode(Mode::EarlyStop { max_iter: rng.below(9) as u32 })
        }
    }
    let names = ["", "a", "tenant-b", "Ωmega", "x y z"];
    req = req.tenant(names[rng.index(names.len())]);
    if rng.chance(0.5) {
        req = req.deadline(Duration::from_nanos(1 + rng.below(1 << 40)));
    }
    req = req.priority(
        [Priority::Low, Priority::Normal, Priority::High][rng.index(3)],
    );
    req = req.validation(
        [
            ValidationPolicy::Inherit,
            ValidationPolicy::Strict,
            ValidationPolicy::Skip,
        ][rng.index(3)],
    );
    if rng.chance(0.5) {
        req = req.on_over_quota(
            [OverQuotaPolicy::Reject, OverQuotaPolicy::Block][rng.index(2)],
        );
    }
    req
}

#[test]
fn random_submit_frames_roundtrip() {
    let mut rng = Rng::seed_from(0xA11CE);
    for i in 0..200 {
        let req = random_request(&mut rng);
        let bytes = wire::encode(&Frame::Submit(req.clone())).unwrap();
        match wire::decode(&bytes).unwrap() {
            Frame::Submit(back) => {
                assert_eq!(back, req, "roundtrip diverged at case {i}")
            }
            other => panic!("wrong frame kind: {other:?}"),
        }
    }
}

#[test]
fn random_result_frames_roundtrip() {
    let mut rng = Rng::seed_from(0xB0B);
    for i in 0..200 {
        let rows = rng.index(8);
        let k = rng.index(5);
        let mut values = vec![0f32; rows * k];
        rng.fill_normal(&mut values);
        let indices: Vec<u32> =
            (0..rows * k).map(|_| rng.below(1 << 20) as u32).collect();
        let res = TopKResult { rows, k, values, indices };
        let bytes = wire::encode(&Frame::Result(res.clone())).unwrap();
        match wire::decode(&bytes).unwrap() {
            Frame::Result(back) => {
                assert_eq!(back, res, "roundtrip diverged at case {i}")
            }
            other => panic!("wrong frame kind: {other:?}"),
        }
    }
}

#[test]
fn every_truncation_rejects_with_a_position_and_never_panics() {
    let frames = [
        wire::encode(&Frame::Submit(golden_request())).unwrap(),
        wire::encode(&Frame::Result(golden_result())).unwrap(),
    ];
    for bytes in &frames {
        for len in 0..bytes.len() {
            let err = wire::decode(&bytes[..len])
                .expect_err("a truncated frame must never decode");
            assert!(
                err.offset <= bytes.len(),
                "error offset {} points past the frame",
                err.offset
            );
        }
    }
}

#[test]
fn every_single_bit_flip_rejects() {
    // the checksummed header + payload CRC make any single-bit
    // corruption detectable; decode must reject every one, not
    // reinterpret
    let frames = [
        wire::encode(&Frame::Submit(golden_request())).unwrap(),
        wire::encode(&Frame::Result(golden_result())).unwrap(),
    ];
    for bytes in &frames {
        for i in 0..bytes.len() {
            for bit in 0..8 {
                let mut flipped = bytes.clone();
                flipped[i] ^= 1 << bit;
                assert!(
                    wire::decode(&flipped).is_err(),
                    "flip of byte {i} bit {bit} decoded anyway"
                );
            }
        }
    }
}

#[test]
fn trailing_bytes_reject() {
    let mut bytes = wire::encode(&Frame::Submit(golden_request())).unwrap();
    bytes.push(0);
    let err = wire::decode(&bytes).unwrap_err();
    assert!(
        err.msg.contains("mismatch") || err.msg.contains("trailing"),
        "got: {err}"
    );
}

#[test]
fn foreign_schema_versions_are_strictly_rejected() {
    // flip the version and re-stamp the header CRC so the version gate
    // itself (not the checksum) is what rejects
    for version in [0u16, 2, 7, u16::MAX] {
        let mut bytes = wire::encode(&Frame::Submit(golden_request())).unwrap();
        bytes[4..6].copy_from_slice(&version.to_le_bytes());
        let crc = wire::crc32(&bytes[..20]);
        bytes[20..24].copy_from_slice(&crc.to_le_bytes());
        let err = wire::decode(&bytes).unwrap_err();
        assert_eq!(err.offset, 4, "version errors are positioned");
        assert!(
            err.msg.contains(&format!("version {version}")),
            "names the foreign version: {err}"
        );
    }
}

#[test]
fn header_len_is_part_of_the_contract() {
    // the committed fixtures pin this too, but make the constant's
    // value explicit: changing it is a wire-format break
    assert_eq!(HEADER_LEN, 24);
    let bytes = wire::encode(&Frame::Submit(golden_request())).unwrap();
    assert_eq!(&bytes[0..4], b"RTKF");
}
