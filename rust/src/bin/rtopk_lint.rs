//! Repo-invariant lint gate: `cargo run --bin rtopk-lint [repo-root]`.
//!
//! Thin driver over [`rtopk::lint`]: walks `rust/src`, checks the
//! cross-file contracts (config knobs <-> docs/CONFIG.md, `unsafe` <->
//! `// SAFETY:`, wall-clock-free cost model and wire codec, Counter
//! <-> LoadSnapshot JSON keys, no deprecated-shim callers), prints one
//! line per violation, and exits non-zero when any survive the
//! `rust/lint-allow.txt` allowlist. The same rules run inside
//! `cargo test` (`lint::tests::real_tree_is_clean`); this binary is
//! the named CI step and the local pre-push hook.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    // default root: the checkout this binary was built from (the
    // parent of the rust/ package), so plain `cargo run --bin
    // rtopk-lint` works from anywhere inside the repo
    let root = std::env::args().nth(1).map_or_else(
        || {
            PathBuf::from(env!("CARGO_MANIFEST_DIR"))
                .parent()
                .expect("rust/ package sits inside the repo")
                .to_path_buf()
        },
        PathBuf::from,
    );
    match rtopk::lint::run_all(&root) {
        Ok(findings) if findings.is_empty() => {
            println!("rtopk-lint: clean ({})", root.display());
            ExitCode::SUCCESS
        }
        Ok(findings) => {
            for f in &findings {
                eprintln!("{f}");
            }
            eprintln!(
                "rtopk-lint: {} violation(s); fix them or add a justified \
                 line to rust/lint-allow.txt",
                findings.len()
            );
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("rtopk-lint: cannot walk {}: {e}", root.display());
            ExitCode::FAILURE
        }
    }
}
