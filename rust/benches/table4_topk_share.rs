//! Table 4: share of MaxK-GNN training time spent on row-wise top-k,
//! per model/dataset, plus baseline test accuracy.
//!
//! Timing side: the CPU GNN substrate executes one training step's
//! operator stream (linear -> top-k -> compressed SpMM per layer, head,
//! 2x-forward backward convention) with the *sort-based* top-k — the
//! operator MaxK-GNN ships without RTop-K — and reports top-k's share.
//! Accuracy side: the PJRT-trained exact-top-k model's test accuracy
//! (requires `make artifacts`; skipped otherwise).

use rtopk::bench::Table;
use rtopk::coordinator::Trainer;
use rtopk::gnn::profile::profile_train_step;
use rtopk::graph::datasets;
use rtopk::runtime::executor::Executor;
use rtopk::topk::rowwise::RowAlgo;

fn main() {
    let quick = std::env::var("RTOPK_QUICK").is_ok();
    let datasets_list = ["flickr-sim", "yelp-sim", "reddit-sim", "products-sim"];
    let hidden = 256;
    let k = 32;
    let layers = 3;

    let mut t = Table::new(
        "Table 4: top-k share of training-step time (sort-based top-k, CPU substrate)",
        &["Graph", "#Nodes", "linear ms", "topk ms", "spmm ms", "Top-k Prop %"],
    );
    for name in datasets_list {
        let g = datasets::build(name, 42).unwrap();
        let p = profile_train_step(&g, hidden, k, layers, RowAlgo::Sort);
        t.row(vec![
            name.to_string(),
            g.num_nodes.to_string(),
            format!("{:.1}", p.linear_s * 1e3),
            format!("{:.1}", p.topk_s * 1e3),
            format!("{:.1}", p.spmm_s * 1e3),
            format!("{:.2}", p.topk_fraction() * 100.0),
        ]);
    }
    t.print();
    println!("\npaper (Table 4): Top-k Prop 11.6% (Reddit) .. 26.9% (Flickr)");

    // accuracy column (PJRT training, exact top-k artifacts)
    let have = std::path::Path::new("artifacts/manifest.json").exists();
    if !have {
        println!("\n(accuracy column skipped: run `make artifacts`)");
        return;
    }
    let steps = if quick { 20 } else { 40 };
    let exec = Executor::spawn("artifacts").unwrap();
    let mut t = Table::new(
        &format!("Table 4 (cont.): baseline GCN test accuracy after {steps} steps"),
        &["Graph", "test acc %"],
    );
    for name in datasets_list {
        let tag = format!("gcn_{name}_h256_k32_exact");
        match Trainer::new(exec.handle(), &tag, 42) {
            Ok(mut tr) => {
                let out = tr.train(steps, 0, |_, _, _| {}).unwrap();
                t.row(vec![
                    name.to_string(),
                    format!("{:.2}", out.final_test_acc * 100.0),
                ]);
            }
            Err(_) => t.row(vec![name.to_string(), "n/a (artifact set)".into()]),
        }
    }
    t.print();
}
