//! The deterministic scheduler: one logical thread runs at a time, every
//! synchronization operation is a *schedule point*, and the controller
//! explores the tree of schedule decisions (exhaustive DFS or seeded
//! random walks). See the crate docs for the model and its limits.
//!
//! Mechanics: model threads are real OS threads, but each one parks on
//! the execution's condvar whenever it reaches a schedule point and only
//! proceeds when the controller grants it the "running" token. Because
//! at most one model thread is ever running, the region between two
//! schedule points executes atomically with respect to the model — which
//! is exactly why every cross-thread operation (lock, atomic, condvar
//! park/notify, join, tracked raw access) must pass through a schedule
//! point, and why plain data shared between those points is invisible to
//! the explorer unless flagged via [`race_read`]/[`race_write`].

use crate::clock::VClock;
use std::cell::RefCell;
use std::collections::HashMap;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex, OnceLock};
use std::time::Duration;

/// Panic payload used to unwind model threads when an execution aborts
/// (failure found, or wind-down after a sibling failed). Never reported
/// as an application panic.
pub(crate) struct AbortToken;

// ---------------------------------------------------------------------------
// Per-thread context
// ---------------------------------------------------------------------------

#[derive(Clone)]
pub(crate) struct Ctx {
    pub exec: Arc<Exec>,
    pub tid: usize,
}

thread_local! {
    static CTX: RefCell<Option<Ctx>> = const { RefCell::new(None) };
}

/// The current thread's model context, if it is a model thread.
pub(crate) fn cur() -> Option<Ctx> {
    CTX.with(|c| c.borrow().clone())
}

/// The context to schedule under: `None` for passthrough threads *and*
/// for model threads that are already unwinding (their teardown —
/// destructors, pool shutdown from `Drop` — degrades to real std
/// operations so a panic during abort can never double-panic the
/// process).
pub(crate) fn scheduled() -> Option<Ctx> {
    if std::thread::panicking() {
        return None;
    }
    cur()
}

// ---------------------------------------------------------------------------
// Execution state
// ---------------------------------------------------------------------------

/// What a quiescent thread is waiting to do next.
#[derive(Clone, Debug)]
pub(crate) enum Op {
    /// Plain schedule point (atomic access, notify, tracked access).
    Point(&'static str),
    /// Acquire the mutex at this address; enabled iff unheld.
    Lock(usize),
    /// About to atomically release `mutex` and park on `cv`. Always
    /// enabled — granting it models the preemption window between a
    /// waiter's last check and its park (where lost wakeups live).
    CvPark { cv: usize, mutex: usize, timeout: bool },
    /// Join thread `tid`; enabled iff that thread finished.
    Join(usize),
}

#[derive(Clone, Debug)]
enum Status {
    /// OS thread exists but has not reached its first schedule point.
    Spawning,
    /// Parked at a schedule point, op published, waiting for a grant.
    Ready(Op),
    /// Holds the running token (at most one thread at a time).
    Running,
    /// Parked on a condvar: released the mutex, waiting for a notify
    /// (or, when `timeout`, for the controller to fire its timeout —
    /// model time only advances when nothing else can run).
    CvWaiting { cv: usize, timeout: bool, notified: bool, fired: bool },
    Finished,
}

struct Th {
    name: String,
    status: Status,
    clock: VClock,
    /// Set by the controller when granting a wake out of `CvWaiting`:
    /// true iff the wake was a fired timeout, not a notify.
    wake_was_timeout: bool,
}

#[derive(Default)]
struct MutexSt {
    held_by: Option<usize>,
    clock: VClock,
}

/// Race-detector record for one tracked raw-memory location.
#[derive(Default)]
struct Loc {
    last_write: Option<(usize, VClock)>,
    /// Most recent read per thread since the last write.
    reads: Vec<(usize, VClock)>,
}

pub(crate) struct ExecState {
    threads: Vec<Th>,
    mutexes: HashMap<usize, MutexSt>,
    atomics: HashMap<usize, VClock>,
    /// Park order per condvar address (front = longest-parked waiter).
    cv_waiters: HashMap<usize, VecDeque<usize>>,
    locs: HashMap<usize, Loc>,
    /// Schedule decisions made so far: (number of choices, chosen index).
    decisions: Vec<(usize, usize)>,
    step: usize,
    /// Replay prefix for DFS (beyond it, the picker decides).
    prefix: Vec<usize>,
    picker: Picker,
    max_steps: usize,
    trace: Vec<String>,
    failure: Option<String>,
    aborting: bool,
}

pub(crate) struct Exec {
    state: StdMutex<ExecState>,
    cv: StdCondvar,
}

enum Picker {
    /// DFS: first enabled choice once past the replay prefix.
    First,
    /// Seeded random walk (no replay).
    Random(u64),
}

fn splitmix(s: &mut u64) -> u64 {
    *s = s.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *s;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl ExecState {
    fn fail(&mut self, msg: String) {
        if self.failure.is_none() {
            self.failure = Some(msg);
        }
        self.aborting = true;
    }

    fn trace_push(&mut self, line: String) {
        // Bound memory on long random walks; the tail is what matters.
        if self.trace.len() >= 512 {
            self.trace.drain(..256);
            self.trace.insert(0, "… (earlier steps trimmed)".to_string());
        }
        self.trace.push(line);
    }

    fn all_quiescent(&self) -> bool {
        !self
            .threads
            .iter()
            .any(|t| matches!(t.status, Status::Spawning | Status::Running))
    }

    fn all_finished(&self) -> bool {
        self.threads.iter().all(|t| matches!(t.status, Status::Finished))
    }

    /// Grantable choices this round, ordered by thread id (determinism).
    fn choices(&self) -> Vec<Choice> {
        let mut out = Vec::new();
        for (tid, th) in self.threads.iter().enumerate() {
            match &th.status {
                Status::Ready(Op::Point(_)) | Status::Ready(Op::CvPark { .. }) => {
                    out.push(Choice::Grant(tid))
                }
                Status::Ready(Op::Lock(m)) => {
                    if self.mutexes.get(m).and_then(|s| s.held_by).is_none() {
                        out.push(Choice::Grant(tid));
                    }
                }
                Status::Ready(Op::Join(t)) => {
                    if matches!(self.threads[*t].status, Status::Finished) {
                        out.push(Choice::Grant(tid));
                    }
                }
                Status::CvWaiting { notified, fired, .. } => {
                    if *notified || *fired {
                        out.push(Choice::Grant(tid));
                    }
                }
                _ => {}
            }
        }
        out
    }

    /// Wait-for edges for blocked threads: `Lock` points at the holder,
    /// `Join` at the joinee. Used for cycle detection and the deadlock
    /// report.
    fn wait_edges(&self) -> Vec<(usize, usize, String)> {
        let mut edges = Vec::new();
        for (tid, th) in self.threads.iter().enumerate() {
            match &th.status {
                Status::Ready(Op::Lock(m)) => {
                    if let Some(holder) =
                        self.mutexes.get(m).and_then(|s| s.held_by)
                    {
                        edges.push((
                            tid,
                            holder,
                            format!("lock {:#x} held by t{holder}", m),
                        ));
                    }
                }
                Status::Ready(Op::Join(t)) => {
                    if !matches!(self.threads[*t].status, Status::Finished) {
                        edges.push((tid, *t, format!("join of t{t}")));
                    }
                }
                _ => {}
            }
        }
        edges
    }

    fn wait_cycle(&self) -> Option<Vec<usize>> {
        let edges = self.wait_edges();
        let next: HashMap<usize, usize> =
            edges.iter().map(|(a, b, _)| (*a, *b)).collect();
        for &start in next.keys() {
            let (mut slow, mut path) = (start, vec![start]);
            while let Some(&n) = next.get(&slow) {
                if let Some(pos) = path.iter().position(|&p| p == n) {
                    return Some(path[pos..].to_vec());
                }
                path.push(n);
                slow = n;
                if path.len() > self.threads.len() + 1 {
                    break;
                }
            }
        }
        None
    }

    fn blocked_report(&self, header: &str) -> String {
        let mut lines = vec![header.to_string()];
        for (tid, th) in self.threads.iter().enumerate() {
            let what = match &th.status {
                Status::Ready(Op::Lock(m)) => {
                    let holder = self
                        .mutexes
                        .get(m)
                        .and_then(|s| s.held_by)
                        .map(|h| format!(" held by t{h}"))
                        .unwrap_or_default();
                    format!("blocked locking mutex {:#x}{holder}", m)
                }
                Status::Ready(Op::Join(t)) => format!("waiting to join t{t}"),
                Status::Ready(Op::CvPark { cv, .. }) => {
                    format!("about to park on condvar {:#x}", cv)
                }
                Status::Ready(Op::Point(l)) => format!("at point `{l}`"),
                Status::CvWaiting { cv, timeout, .. } => format!(
                    "parked on condvar {:#x}{}",
                    cv,
                    if *timeout { " (with timeout)" } else { "" }
                ),
                Status::Running => "running".to_string(),
                Status::Spawning => "spawning".to_string(),
                Status::Finished => continue,
            };
            lines.push(format!("  t{tid} [{}]: {what}", th.name));
        }
        lines.join("\n")
    }
}

#[derive(Clone, Copy, Debug)]
enum Choice {
    /// Hand the running token to this thread (granting its pending op
    /// or waking it out of a condvar park).
    Grant(usize),
}

// ---------------------------------------------------------------------------
// Thread-side schedule points (called from sync.rs)
// ---------------------------------------------------------------------------

fn abort_unwind() -> ! {
    std::panic::panic_any(AbortToken)
}

/// Park at a schedule point until the controller grants the running
/// token. The op must already describe what this thread does next.
fn yield_op(ctx: &Ctx, op: Op) {
    let mut st = ctx.exec.state.lock().unwrap();
    if st.aborting {
        drop(st);
        abort_unwind();
    }
    st.threads[ctx.tid].status = Status::Ready(op);
    ctx.exec.cv.notify_all();
    loop {
        if st.aborting {
            drop(st);
            abort_unwind();
        }
        if matches!(st.threads[ctx.tid].status, Status::Running) {
            return;
        }
        st = ctx.exec.cv.wait(st).unwrap();
    }
}

/// Plain schedule point.
pub(crate) fn point(ctx: &Ctx, label: &'static str) {
    yield_op(ctx, Op::Point(label));
}

/// Schedule point acquiring `mutex_addr`; on return the model holds it.
pub(crate) fn acquire_mutex(ctx: &Ctx, mutex_addr: usize) {
    yield_op(ctx, Op::Lock(mutex_addr));
}

/// Release `mutex_addr`. Not itself a schedule point: the release only
/// *enables* other threads, and the next decision round sees it.
pub(crate) fn release_mutex(ctx: &Ctx, mutex_addr: usize) {
    let mut st = ctx.exec.state.lock().unwrap();
    let tid = ctx.tid;
    st.threads[tid].clock.tick(tid);
    let thread_clock = st.threads[tid].clock.clone();
    let m = st.mutexes.entry(mutex_addr).or_default();
    if m.held_by == Some(tid) {
        m.held_by = None;
    }
    m.clock.join(&thread_clock);
    ctx.exec.cv.notify_all();
}

/// Schedule point for "about to release the mutex and park" — granting
/// another thread here models the lost-wakeup window (a notify fired
/// now is not seen by this not-yet-parked waiter).
pub(crate) fn cv_park_point(
    ctx: &Ctx,
    cv_addr: usize,
    mutex_addr: usize,
    timeout: bool,
) {
    yield_op(ctx, Op::CvPark { cv: cv_addr, mutex: mutex_addr, timeout });
}

/// Park on `cv_addr` until notified or (when `timeout`) the controller
/// fires this waiter's timeout. The caller must already have released
/// the mutex (guard drop) *after* its `cv_park_point` — no schedule
/// point separates release from park, so the pair is atomic, matching
/// std's guarantee. Returns true iff the wake was a fired timeout.
pub(crate) fn cv_park(ctx: &Ctx, cv_addr: usize, timeout: bool) -> bool {
    let tid = ctx.tid;
    let mut st = ctx.exec.state.lock().unwrap();
    if st.aborting {
        drop(st);
        abort_unwind();
    }
    st.cv_waiters.entry(cv_addr).or_default().push_back(tid);
    st.threads[tid].status = Status::CvWaiting {
        cv: cv_addr,
        timeout,
        notified: false,
        fired: false,
    };
    ctx.exec.cv.notify_all();
    loop {
        if st.aborting {
            // Deregister so an aborted waiter is not "woken" later.
            if let Some(q) = st.cv_waiters.get_mut(&cv_addr) {
                q.retain(|&t| t != tid);
            }
            drop(st);
            abort_unwind();
        }
        if matches!(st.threads[tid].status, Status::Running) {
            return st.threads[tid].wake_was_timeout;
        }
        st = ctx.exec.cv.wait(st).unwrap();
    }
}

/// Notify effect (the caller passed a `Point` first): mark one / all
/// parked waiters notified. `notify_one` wakes in park (FIFO) order —
/// a deliberate simplification over std's unspecified order.
pub(crate) fn cv_notify(ctx: &Ctx, cv_addr: usize, all: bool) {
    let mut st = ctx.exec.state.lock().unwrap();
    let waiters: Vec<usize> = st
        .cv_waiters
        .get(&cv_addr)
        .map(|q| q.iter().copied().collect())
        .unwrap_or_default();
    for tid in waiters {
        if let Status::CvWaiting { notified, .. } =
            &mut st.threads[tid].status
        {
            if !*notified {
                *notified = true;
                if !all {
                    break;
                }
            }
        }
    }
    ctx.exec.cv.notify_all();
}

/// Happens-before bookkeeping for an atomic access (the caller passed a
/// `Point` first and performs the real operation around this call).
pub(crate) fn atomic_hb(ctx: &Ctx, addr: usize, ord: Ordering, is_load: bool, is_store: bool) {
    let mut st = ctx.exec.state.lock().unwrap();
    let tid = ctx.tid;
    let acquire = matches!(
        ord,
        Ordering::Acquire | Ordering::AcqRel | Ordering::SeqCst
    ) && is_load;
    let release = matches!(
        ord,
        Ordering::Release | Ordering::AcqRel | Ordering::SeqCst
    ) && is_store;
    if acquire {
        let obj = st.atomics.entry(addr).or_default().clone();
        st.threads[tid].clock.join(&obj);
    }
    if release {
        let thread_clock = st.threads[tid].clock.clone();
        st.atomics.entry(addr).or_default().join(&thread_clock);
    }
}

/// Tracked raw-memory read: fails the execution if it is not ordered
/// after the location's last write.
pub(crate) fn race_read(ctx: &Ctx, addr: usize) {
    point(ctx, "race.read");
    let mut st = ctx.exec.state.lock().unwrap();
    let tid = ctx.tid;
    let my = st.threads[tid].clock.clone();
    let loc = st.locs.entry(addr).or_default();
    if let Some((wtid, wclock)) = &loc.last_write {
        if !wclock.leq(&my) {
            let msg = format!(
                "data race: t{tid} reads {:#x} unordered with the write \
                 by t{wtid} (no happens-before edge)",
                addr
            );
            st.fail(msg);
            ctx.exec.cv.notify_all();
            drop(st);
            abort_unwind();
        }
    }
    let loc = st.locs.entry(addr).or_default();
    loc.reads.retain(|(t, _)| *t != tid);
    loc.reads.push((tid, my));
}

/// Tracked raw-memory write: fails the execution if any prior access to
/// the location is not ordered before it.
pub(crate) fn race_write(ctx: &Ctx, addr: usize) {
    point(ctx, "race.write");
    let mut st = ctx.exec.state.lock().unwrap();
    let tid = ctx.tid;
    let my = st.threads[tid].clock.clone();
    let loc = st.locs.entry(addr).or_default();
    let mut conflict: Option<String> = None;
    if let Some((wtid, wclock)) = &loc.last_write {
        if !wclock.leq(&my) {
            conflict = Some(format!("the write by t{wtid}"));
        }
    }
    if conflict.is_none() {
        for (rtid, rclock) in &loc.reads {
            if *rtid != tid && !rclock.leq(&my) {
                conflict = Some(format!("the read by t{rtid}"));
                break;
            }
        }
    }
    if let Some(what) = conflict {
        let msg = format!(
            "data race: t{tid} writes {:#x} unordered with {what} \
             (no happens-before edge)",
            addr
        );
        st.fail(msg);
        ctx.exec.cv.notify_all();
        drop(st);
        abort_unwind();
    }
    let loc = st.locs.entry(addr).or_default();
    loc.last_write = Some((tid, my));
    loc.reads.clear();
}

/// Block until `target` finishes (schedule point), joining its clock.
pub(crate) fn join_thread(ctx: &Ctx, target: usize) {
    yield_op(ctx, Op::Join(target));
    let mut st = ctx.exec.state.lock().unwrap();
    let final_clock = st.threads[target].clock.clone();
    st.threads[ctx.tid].clock.join(&final_clock);
}

/// Register a child thread (spawn is not itself a schedule point: the
/// child's first schedule point is the synchronization event).
pub(crate) fn register_child(ctx: &Ctx, name: String) -> usize {
    let mut st = ctx.exec.state.lock().unwrap();
    let parent = ctx.tid;
    st.threads[parent].clock.tick(parent);
    let mut clock = st.threads[parent].clock.clone();
    let tid = st.threads.len();
    clock.tick(tid);
    st.threads.push(Th {
        name,
        status: Status::Spawning,
        clock,
        wake_was_timeout: false,
    });
    tid
}

/// Model-thread body wrapper: first schedule point, run, then mark
/// finished (recording a non-abort panic as the execution's failure).
pub(crate) fn run_thread_body<T>(
    exec: Arc<Exec>,
    tid: usize,
    f: impl FnOnce() -> T,
) -> T {
    CTX.with(|c| {
        *c.borrow_mut() = Some(Ctx { exec: Arc::clone(&exec), tid })
    });
    let ctx = Ctx { exec: Arc::clone(&exec), tid };
    point(&ctx, "start");
    let result = catch_unwind(AssertUnwindSafe(f));
    let mut st = exec.state.lock().unwrap();
    if let Err(payload) = &result {
        if !payload.is::<AbortToken>() {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string());
            let name = st.threads[tid].name.clone();
            st.fail(format!("thread t{tid} [{name}] panicked: {msg}"));
        }
    }
    st.threads[tid].clock.tick(tid);
    st.threads[tid].status = Status::Finished;
    exec.cv.notify_all();
    drop(st);
    CTX.with(|c| *c.borrow_mut() = None);
    match result {
        Ok(v) => v,
        Err(payload) => resume_unwind(payload),
    }
}

// ---------------------------------------------------------------------------
// Controller
// ---------------------------------------------------------------------------

/// One finished execution's outcome.
struct ExecOutcome {
    decisions: Vec<(usize, usize)>,
    failure: Option<String>,
    trace: Vec<String>,
}

/// How long the controller waits for a model thread to reach a schedule
/// point before declaring the harness stalled (a real block outside the
/// model, e.g. contending a non-façade lock with a parked thread).
const STALL: Duration = Duration::from_secs(10);

/// Silence the default panic hook for [`AbortToken`] unwinds: aborting
/// an execution panics every parked model thread, and printing a
/// backtrace per thread per aborted schedule would drown real output.
/// Application panics still print normally.
fn install_quiet_abort_hook() {
    static HOOK: OnceLock<()> = OnceLock::new();
    HOOK.get_or_init(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if !info.payload().is::<AbortToken>() {
                prev(info);
            }
        }));
    });
}

fn run_one<F>(
    body: &Arc<F>,
    prefix: Vec<usize>,
    picker: Picker,
    max_steps: usize,
) -> ExecOutcome
where
    F: Fn() + Send + Sync + 'static,
{
    install_quiet_abort_hook();
    let exec = Arc::new(Exec {
        state: StdMutex::new(ExecState {
            threads: Vec::new(),
            mutexes: HashMap::new(),
            atomics: HashMap::new(),
            cv_waiters: HashMap::new(),
            locs: HashMap::new(),
            decisions: Vec::new(),
            step: 0,
            prefix,
            picker,
            max_steps,
            trace: Vec::new(),
            failure: None,
            aborting: false,
        }),
        cv: StdCondvar::new(),
    });
    // Register and spawn the root thread (t0).
    {
        let mut st = exec.state.lock().unwrap();
        let mut clock = VClock::new();
        clock.tick(0);
        st.threads.push(Th {
            name: "root".to_string(),
            status: Status::Spawning,
            clock,
            wake_was_timeout: false,
        });
    }
    let root = {
        let exec = Arc::clone(&exec);
        let body = Arc::clone(body);
        std::thread::Builder::new()
            .name("mc-root".to_string())
            .spawn(move || {
                run_thread_body(exec, 0, move || body());
            })
            .expect("spawn model root thread")
    };

    let mut stalled = false;
    loop {
        let mut st = exec.state.lock().unwrap();
        // Wait for quiescence (no thread spawning or running).
        let mut waited = Duration::ZERO;
        while !st.all_quiescent() {
            let (s, timeout) =
                exec.cv.wait_timeout(st, Duration::from_millis(100)).unwrap();
            st = s;
            if timeout.timed_out() {
                waited += Duration::from_millis(100);
                if waited >= STALL {
                    let report = st.blocked_report(
                        "harness stall: a model thread blocked outside \
                         the model (real lock or unported primitive?)",
                    );
                    st.fail(report);
                    stalled = true;
                    break;
                }
            }
        }
        if stalled {
            exec.cv.notify_all();
            break;
        }
        if st.aborting || st.all_finished() {
            exec.cv.notify_all();
            break;
        }
        // Immediate wait-for-graph cycle check (partial deadlocks).
        if let Some(cycle) = st.wait_cycle() {
            let header = format!(
                "deadlock: wait-for cycle {}",
                cycle
                    .iter()
                    .map(|t| format!("t{t}"))
                    .collect::<Vec<_>>()
                    .join(" -> ")
            );
            let report = st.blocked_report(&header);
            st.fail(report);
            exec.cv.notify_all();
            continue;
        }
        let choices = st.choices();
        if choices.is_empty() {
            // Nothing runnable: advance model time by firing EVERY
            // pending timeout at once. This is a forced transition, not
            // a schedule decision — firing timeouts selectively would
            // hand DFS an infinite branch on poll-loop protocols (fire
            // one waiter, it rechecks, reparks, fire it again, ...).
            // Waking order among the fired waiters is still explored:
            // each is a separate grant at the next decision round.
            let mut fired_count = 0usize;
            for th in st.threads.iter_mut() {
                if let Status::CvWaiting {
                    timeout: true,
                    notified: false,
                    fired,
                    ..
                } = &mut th.status
                {
                    if !*fired {
                        *fired = true;
                        fired_count += 1;
                    }
                }
            }
            if fired_count == 0 {
                let report = st.blocked_report(
                    "deadlock: no runnable thread and no pending timeout \
                     (lost wakeup or wait-for cycle)",
                );
                st.fail(report);
                exec.cv.notify_all();
                continue;
            }
            st.trace_push(format!(
                "advance model time: fired {fired_count} pending timeout(s)"
            ));
            continue;
        }
        let n = choices.len();
        let step = st.step;
        let idx = if step < st.prefix.len() {
            let want = st.prefix[step];
            if want >= n {
                st.fail(format!(
                    "internal: DFS replay diverged at step {step} \
                     (wanted choice {want} of {n}) — the model body is \
                     nondeterministic (wall-clock reads?); use the \
                     random strategy for this suite"
                ));
                exec.cv.notify_all();
                continue;
            }
            want
        } else {
            match &mut st.picker {
                Picker::First => 0,
                Picker::Random(seed) => (splitmix(seed) % n as u64) as usize,
            }
        };
        st.decisions.push((n, idx));
        st.step += 1;
        if st.step > st.max_steps {
            let report = st.blocked_report(&format!(
                "step bound exceeded ({} schedule points): livelock, or \
                 raise max_steps",
                st.max_steps
            ));
            st.fail(report);
            exec.cv.notify_all();
            continue;
        }
        match choices[idx] {
            Choice::Grant(tid) => {
                let desc = match &st.threads[tid].status {
                    Status::Ready(op) => format!("{op:?}"),
                    Status::CvWaiting { fired, notified, .. } => format!(
                        "Wake({})",
                        if *notified { "notified" } else if *fired { "timeout" } else { "?" }
                    ),
                    other => format!("{other:?}"),
                };
                st.trace_push(format!("step {step}: grant t{tid} {desc}"));
                st.threads[tid].clock.tick(tid);
                match st.threads[tid].status.clone() {
                    Status::Ready(Op::Lock(m)) => {
                        let obj_clock = {
                            let mu = st.mutexes.entry(m).or_default();
                            mu.held_by = Some(tid);
                            mu.clock.clone()
                        };
                        st.threads[tid].clock.join(&obj_clock);
                    }
                    Status::Ready(Op::Join(_)) => {
                        // Clock join happens thread-side (join_thread).
                    }
                    Status::CvWaiting { cv, notified, fired, .. } => {
                        if let Some(q) = st.cv_waiters.get_mut(&cv) {
                            q.retain(|&t| t != tid);
                        }
                        st.threads[tid].wake_was_timeout =
                            fired && !notified;
                    }
                    _ => {}
                }
                st.threads[tid].status = Status::Running;
            }
        }
        exec.cv.notify_all();
    }

    // Wind down: wait (bounded) for every model thread to finish, then
    // join the root OS thread.
    {
        let mut st = exec.state.lock().unwrap();
        let mut waited = Duration::ZERO;
        while !st.all_finished() && waited < STALL {
            let (s, t) =
                exec.cv.wait_timeout(st, Duration::from_millis(100)).unwrap();
            st = s;
            if t.timed_out() {
                waited += Duration::from_millis(100);
            }
            exec.cv.notify_all();
        }
        if !st.all_finished() {
            stalled = true;
            st.fail(
                "harness stall during wind-down: leaking execution threads"
                    .to_string(),
            );
        }
    }
    if !stalled {
        let _ = root.join();
    }
    let st = exec.state.lock().unwrap();
    ExecOutcome {
        decisions: st.decisions.clone(),
        failure: st.failure.clone(),
        trace: st.trace.clone(),
    }
}

// ---------------------------------------------------------------------------
// Public API
// ---------------------------------------------------------------------------

/// A failing schedule: what went wrong and the decision trace that got
/// there (replay it by reading the granted ops in order).
#[derive(Clone, Debug)]
pub struct Failure {
    pub message: String,
    pub schedule: Vec<String>,
}

/// Exploration outcome.
#[derive(Clone, Debug)]
pub struct Report {
    /// Executions (interleavings) actually run.
    pub executions: u64,
    /// True iff DFS exhausted the schedule tree (always false for the
    /// random strategy unless the tree had a single schedule).
    pub complete: bool,
    pub failure: Option<Failure>,
}

/// Exploration strategy.
#[derive(Clone, Copy, Debug)]
pub enum Strategy {
    /// Depth-first over every schedule decision, up to the execution
    /// cap. Requires a deterministic body (no wall-clock branching).
    Dfs,
    /// `iterations` seeded random walks. Tolerates nondeterministic
    /// bodies (each walk is independent; no replay).
    Random { iterations: u64, seed: u64 },
}

/// Configured model checker. `Checker::dfs()` / `Checker::random(..)`
/// then `.check(body)`; [`model`] is the assert-on-failure shorthand.
#[derive(Clone, Debug)]
pub struct Checker {
    strategy: Strategy,
    max_executions: u64,
    max_steps: usize,
}

impl Checker {
    pub fn dfs() -> Checker {
        Checker {
            strategy: Strategy::Dfs,
            max_executions: 20_000,
            max_steps: 20_000,
        }
    }

    pub fn random(iterations: u64, seed: u64) -> Checker {
        Checker {
            strategy: Strategy::Random { iterations, seed },
            max_executions: iterations,
            max_steps: 20_000,
        }
    }

    /// Cap the number of executions (DFS stops incomplete at the cap).
    pub fn max_executions(mut self, n: u64) -> Checker {
        self.max_executions = n;
        self
    }

    /// Cap schedule points per execution (livelock backstop).
    pub fn max_steps(mut self, n: usize) -> Checker {
        self.max_steps = n;
        self
    }

    /// Apply `RTOPK_MC_MAX_EXECS` (CI bounds exploration time with it).
    pub fn env_caps(mut self) -> Checker {
        if let Ok(v) = std::env::var("RTOPK_MC_MAX_EXECS") {
            if let Ok(n) = v.trim().parse::<u64>() {
                if n >= 1 {
                    self.max_executions = self.max_executions.min(n);
                }
            }
        }
        self
    }

    /// Explore `body` under the configured strategy. The body runs once
    /// per execution on a fresh model; it must create its threads and
    /// synchronization objects inside the call (no reuse of model state
    /// across executions — process-global sync objects stay invisible).
    pub fn check<F>(&self, body: F) -> Report
    where
        F: Fn() + Send + Sync + 'static,
    {
        let body = Arc::new(body);
        match self.strategy {
            Strategy::Dfs => {
                let mut executions = 0u64;
                let mut prefix: Vec<usize> = Vec::new();
                loop {
                    let out = run_one(
                        &body,
                        prefix.clone(),
                        Picker::First,
                        self.max_steps,
                    );
                    executions += 1;
                    if let Some(message) = out.failure {
                        return Report {
                            executions,
                            complete: false,
                            failure: Some(Failure {
                                message,
                                schedule: out.trace,
                            }),
                        };
                    }
                    // Backtrack: deepest decision with an unexplored
                    // sibling becomes the next prefix.
                    let mut next: Option<Vec<usize>> = None;
                    for (i, &(n, chosen)) in
                        out.decisions.iter().enumerate().rev()
                    {
                        if chosen + 1 < n {
                            let mut p: Vec<usize> = out.decisions[..i]
                                .iter()
                                .map(|(_, c)| *c)
                                .collect();
                            p.push(chosen + 1);
                            next = Some(p);
                            break;
                        }
                    }
                    match next {
                        None => {
                            return Report {
                                executions,
                                complete: true,
                                failure: None,
                            }
                        }
                        Some(p) => prefix = p,
                    }
                    if executions >= self.max_executions {
                        return Report {
                            executions,
                            complete: false,
                            failure: None,
                        };
                    }
                }
            }
            Strategy::Random { iterations, seed } => {
                let iterations = iterations.min(self.max_executions);
                for i in 0..iterations {
                    let out = run_one(
                        &body,
                        Vec::new(),
                        Picker::Random(seed.wrapping_add(
                            i.wrapping_mul(0x9e37_79b9_7f4a_7c15),
                        )),
                        self.max_steps,
                    );
                    if let Some(message) = out.failure {
                        return Report {
                            executions: i + 1,
                            complete: false,
                            failure: Some(Failure {
                                message,
                                schedule: out.trace,
                            }),
                        };
                    }
                }
                Report {
                    executions: iterations,
                    complete: false,
                    failure: None,
                }
            }
        }
    }
}

/// Exhaustive (bounded) DFS over `body`; panics with the failing
/// schedule if any interleaving races, deadlocks, or panics.
pub fn model<F>(body: F)
where
    F: Fn() + Send + Sync + 'static,
{
    let report = Checker::dfs().env_caps().check(body);
    if let Some(f) = report.failure {
        panic!(
            "model check failed after {} execution(s): {}\nschedule:\n{}",
            report.executions,
            f.message,
            f.schedule.join("\n")
        );
    }
}
