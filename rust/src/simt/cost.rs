//! Cost model for warp-synchronous execution on an A6000-class SM.
//!
//! Numbers are per-warp issue costs in cycles, taken from public
//! microbenchmark literature for Ampere (GA102): they matter only
//! *relative to each other*, since every figure reports speed-up ratios.

/// Per-operation cycle costs for one warp.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// one coalesced 128B global-memory transaction (32 lanes x f32),
    /// amortized steady-state (latency hidden by occupancy)
    pub gmem_txn: f64,
    /// one shared-memory 32-lane access (bank-conflict-free)
    pub smem_txn: f64,
    /// one warp shuffle
    pub shfl: f64,
    /// one ballot + popc pair
    pub ballot: f64,
    /// one simple ALU/FP op (warp-wide)
    pub alu: f64,
    /// block-level barrier
    pub sync: f64,
}

impl CostModel {
    /// A6000 (Ampere GA102)-like steady-state issue costs.
    pub const A6000: CostModel = CostModel {
        gmem_txn: 8.0, // ~DRAM bandwidth-limited issue per warp txn
        smem_txn: 2.0,
        shfl: 2.0,
        ballot: 3.0,
        alu: 1.0,
        sync: 20.0,
    };

    /// SM clock in GHz (A6000 boost ~1.8 GHz).
    pub const A6000_CLOCK_GHZ: f64 = 1.8;
    /// SM count on the A6000.
    pub const A6000_SMS: usize = 84;
    /// shared memory per block the paper assumes (8192 f32 elements).
    pub const SMEM_F32_PER_BLOCK: usize = 8192;
}

/// Cycle totals per kernel stage (Fig. 3's decomposition).
#[derive(Clone, Copy, Debug, Default)]
pub struct StageCycles {
    pub load: f64,
    pub search: f64,
    pub select: f64,
}

impl StageCycles {
    pub fn total(&self) -> f64 {
        self.load + self.search + self.select
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a6000_costs_ordered_sanely() {
        let c = CostModel::A6000;
        assert!(c.alu < c.smem_txn);
        assert!(c.smem_txn < c.gmem_txn);
        assert!(c.sync > c.gmem_txn);
    }

    #[test]
    fn stage_total() {
        let s = StageCycles { load: 1.0, search: 2.0, select: 3.0 };
        assert_eq!(s.total(), 6.0);
    }
}
