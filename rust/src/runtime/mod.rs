//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json` produced by `python/compile/aot.py`) and executes
//! them on the request path.
//!
//! Threading model: the `xla` crate's `PjRtClient` is `Rc`-based
//! (!Send), so all PJRT state lives on one **executor thread**
//! ([`executor::Executor`]); the rest of the coordinator talks to it
//! through an mpsc channel handle. This matches the deployment shape of
//! a single-accelerator serving process (one device stream, many
//! request threads).
//!
//! Interchange: HLO **text** (xla_extension 0.5.1 rejects jax>=0.5's
//! 64-bit-id serialized protos; the text parser reassigns ids).

pub mod executor;
pub mod manifest;
pub mod store;
pub mod tensor;

pub use executor::{Executor, ExecutorHandle};
pub use manifest::{ArtifactInfo, Manifest, TensorSpec};
pub use store::ArtifactStore;
pub use tensor::HostTensor;
