//! PJRT tile-artifact backend: execute a batch through an AOT-compiled
//! `rtopk_tile` artifact, padding row groups to the tile size.
//!
//! The variant table ([`TileTable`]) is built once from the manifest;
//! `supports`/lookup on the hot path is a `BTreeMap` probe (the table
//! is tiny). Row padding and multi-tile chunking — previously buried in
//! the scheduler — live here, behind the [`ExecBackend`] seam.

use crate::backend::{ExecBackend, ExecSpec, PJRT_BACKEND_ID};
use crate::plan::{mode_key, tile_mode_key};
use crate::runtime::executor::ExecutorHandle;
use crate::runtime::manifest::Manifest;
use crate::runtime::tensor::HostTensor;
use crate::topk::types::{Mode, TopKResult};
use crate::util::matrix::RowMatrix;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// Compiled tile variants: `(m, k, mode_key) -> (artifact name, rows)`.
///
/// Keys use the planner's [`mode_key`], so `exact` and every `es{N}`
/// variant stay distinct, and a loose-eps exact request (an
/// *approximate* contract, key `exact_eps…`) never silently matches an
/// `exact` tile.
#[derive(Clone, Debug, Default)]
pub struct TileTable {
    table: BTreeMap<(usize, usize, String), (String, usize)>,
}

impl TileTable {
    /// Build from the manifest's `rtopk_tile` artifacts.
    pub fn from_manifest(m: &Manifest) -> TileTable {
        let mut table = BTreeMap::new();
        for a in m.of_kind("rtopk_tile") {
            let (Some(rows), Some(mm), Some(k)) = (
                a.meta_usize("rows"),
                a.meta_usize("m"),
                a.meta_usize("k"),
            ) else {
                continue;
            };
            // index under the same mode_key requests look up with
            // (tile_mode_key routes through plan::mode_key, so the two
            // sides cannot drift apart)
            let Some(mode) = a.meta_str("mode").and_then(|m| {
                tile_mode_key(m, a.meta_usize("max_iter").unwrap_or(0))
            }) else {
                continue;
            };
            table.insert((mm, k, mode), (a.name.clone(), rows));
        }
        TileTable { table }
    }

    /// The tile artifact serving one request shape, if compiled.
    pub fn lookup(&self, m: usize, k: usize, mode: Mode) -> Option<(&str, usize)> {
        self.table
            .get(&(m, k, mode_key(mode)))
            .map(|(name, rows)| (name.as_str(), *rows))
    }

    /// All (m, k, mode_key) combinations with compiled tiles.
    pub fn variants(&self) -> Vec<(usize, usize, String)> {
        self.table.keys().cloned().collect()
    }

    pub fn artifact_names(&self) -> Vec<String> {
        self.table.values().map(|(n, _)| n.clone()).collect()
    }

    pub fn len(&self) -> usize {
        self.table.len()
    }

    pub fn is_empty(&self) -> bool {
        self.table.is_empty()
    }
}

/// The PJRT executor as an [`ExecBackend`].
pub struct PjrtBackend {
    handle: ExecutorHandle,
    tiles: TileTable,
}

impl PjrtBackend {
    /// Wrap an executor handle; the variant table comes from its
    /// manifest.
    pub fn from_handle(handle: ExecutorHandle) -> PjrtBackend {
        let tiles = TileTable::from_manifest(handle.manifest());
        PjrtBackend { handle, tiles }
    }

    pub fn tiles(&self) -> &TileTable {
        &self.tiles
    }
}

impl ExecBackend for PjrtBackend {
    fn id(&self) -> &str {
        PJRT_BACKEND_ID
    }

    fn describe(&self) -> String {
        format!(
            "PJRT executor ({}, {} compiled tile variants)",
            self.handle.platform(),
            self.tiles.len()
        )
    }

    fn supports(&self, cols: usize, k: usize, mode: Mode) -> bool {
        self.tiles.lookup(cols, k, mode).is_some()
    }

    /// Probe at one full tile: execution always pads to `rows`, so a
    /// smaller probe would charge this backend for padding rows the CPU
    /// probe never computes (per-row rates would be incomparable).
    fn preferred_probe_rows(&self, cols: usize, k: usize, mode: Mode) -> Option<usize> {
        self.tiles.lookup(cols, k, mode).map(|(_, rows)| rows)
    }

    /// Concatenate the group's rows, pad to the tile size, run the
    /// artifact (multiple tiles if the group exceeds one), then scatter
    /// rows back per matrix. The `spec` is ignored — the tile carries
    /// its own compiled kernel.
    fn execute(
        &self,
        _spec: &ExecSpec,
        mats: &[&RowMatrix],
        k: usize,
        mode: Mode,
    ) -> Result<Vec<TopKResult>> {
        let cols = mats.first().map(|m| m.cols).unwrap_or(0);
        let (artifact, tile_rows) = self
            .tiles
            .lookup(cols, k, mode)
            .map(|(name, rows)| (name.to_string(), rows))
            .ok_or_else(|| {
                anyhow!(
                    "no compiled tile for (M={cols}, k={k}, mode={})",
                    mode_key(mode)
                )
            })?;
        let total: usize = mats.iter().map(|m| m.rows).sum();
        // gather all rows into one contiguous buffer
        let mut all = Vec::with_capacity(total * cols);
        for m in mats {
            all.extend_from_slice(&m.data);
        }
        // run tile by tile
        let mut values = vec![0f32; total * k];
        let mut indices = vec![0u32; total * k];
        let mut done = 0usize;
        while done < total {
            let take = tile_rows.min(total - done);
            let mut tile = vec![0f32; tile_rows * cols];
            tile[..take * cols]
                .copy_from_slice(&all[done * cols..(done + take) * cols]);
            let outs = self.handle.execute(
                &artifact,
                vec![HostTensor::f32(tile, &[tile_rows, cols])],
            )?;
            // outputs: values (R,k) f32, indices (R,k) s32, mask (R,M) f32
            let v = outs[0].as_f32()?;
            let i = outs[1].as_i32()?;
            values[done * k..(done + take) * k]
                .copy_from_slice(&v[..take * k]);
            for (dst, &src) in indices[done * k..(done + take) * k]
                .iter_mut()
                .zip(&i[..take * k])
            {
                *dst = src as u32;
            }
            done += take;
        }
        // scatter back per matrix
        let mut results = Vec::with_capacity(mats.len());
        let mut offset = 0usize;
        for m in mats {
            let r = m.rows;
            results.push(TopKResult {
                rows: r,
                k,
                values: values[offset * k..(offset + r) * k].to_vec(),
                indices: indices[offset * k..(offset + r) * k].to_vec(),
            });
            offset += r;
        }
        Ok(results)
    }

    fn variants(&self) -> Vec<(usize, usize, String)> {
        self.tiles.variants()
    }

    /// Warm the compile cache so first requests do not pay compilation.
    fn warmup(&self) -> Result<()> {
        let names = self.tiles.artifact_names();
        let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        self.handle.precompile(&refs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{
          "version": 1, "artifact_set": "t",
          "artifacts": {
            "rtopk_1024x256_k32_exact": {
              "path": "a.hlo.txt",
              "inputs": [{"shape": [1024, 256], "dtype": "float32"}],
              "outputs": [{"shape": [1024, 32], "dtype": "float32"}],
              "meta": {"kind": "rtopk_tile", "rows": 1024, "m": 256,
                        "k": 32, "mode": "exact", "max_iter": 0}
            },
            "rtopk_1024x256_k32_es4": {
              "path": "b.hlo.txt",
              "inputs": [{"shape": [1024, 256], "dtype": "float32"}],
              "outputs": [{"shape": [1024, 32], "dtype": "float32"}],
              "meta": {"kind": "rtopk_tile", "rows": 1024, "m": 256,
                        "k": 32, "mode": "early_stop", "max_iter": 4}
            },
            "train_x": {
              "path": "c.hlo.txt", "inputs": [], "outputs": [],
              "meta": {"kind": "train_step"}
            }
          }
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn tile_table_matches_compiled_shapes() {
        let t = TileTable::from_manifest(&manifest());
        assert_eq!(
            t.lookup(256, 32, Mode::EXACT),
            Some(("rtopk_1024x256_k32_exact", 1024))
        );
        assert_eq!(
            t.lookup(256, 32, Mode::EarlyStop { max_iter: 4 }),
            Some(("rtopk_1024x256_k32_es4", 1024))
        );
    }

    #[test]
    fn tile_table_misses_fall_through() {
        let t = TileTable::from_manifest(&manifest());
        assert!(t.lookup(512, 32, Mode::EXACT).is_none());
        assert!(t.lookup(256, 16, Mode::EXACT).is_none());
        assert!(t.lookup(256, 32, Mode::EarlyStop { max_iter: 7 }).is_none());
        // a loose-eps exact request is an approximate contract — it must
        // not silently match the exact tile
        assert!(t.lookup(256, 32, Mode::Exact { eps_rel: 1e-4 }).is_none());
    }

    #[test]
    fn ignores_non_tile_artifacts() {
        let t = TileTable::from_manifest(&manifest());
        assert_eq!(t.variants().len(), 2);
        assert_eq!(t.len(), 2);
        assert!(!t.is_empty());
        assert_eq!(t.artifact_names().len(), 2);
    }
}
