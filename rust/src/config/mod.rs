//! Run configuration: typed configs for the service and trainer plus a
//! TOML-subset parser so deployments can keep settings in files
//! (`rtopk serve --config serve.toml`). Supports tables, strings,
//! integers, floats, booleans, and comments — the subset the configs
//! need (serde/toml are not in the vendored crate set).

use std::collections::BTreeMap;

/// Parsed config: flat `section.key -> raw string` map.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    entries: BTreeMap<String, String>,
}

impl Config {
    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: bad section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let mut val = v.trim().to_string();
            if (val.starts_with('"') && val.ends_with('"') && val.len() >= 2)
                || (val.starts_with('\'') && val.ends_with('\'') && val.len() >= 2)
            {
                val = val[1..val.len() - 1].to_string();
            }
            entries.insert(key, val);
        }
        Ok(Config { entries })
    }

    pub fn load(path: &std::path::Path) -> Result<Config, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {path:?}: {e}"))?;
        Config::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(|s| s.as_str())
    }

    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.entries.get(key) {
            Some(v) => v.parse().unwrap_or(default),
            None => default,
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // a '#' outside quotes starts a comment
    let mut in_str = false;
    let mut quote = ' ';
    for (i, c) in line.char_indices() {
        match c {
            '"' | '\'' if !in_str => {
                in_str = true;
                quote = c;
            }
            c if in_str && c == quote => in_str = false,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Adaptive-planner knobs (the `[plan]` section). Untyped here —
/// `plan::PlannerConfig::from_plan_config` validates and parses (this
/// module stays plain data with no dependency on the topk layer).
///
/// * `force_algo` — pin one algorithm (`rtopk`, `radix`, `quickselect`,
///   `heap`, `bucket`, `bitonic`, `sort`); empty/absent = adaptive.
///   Pins are honored only when they cannot change result semantics.
/// * `calib_rows` — baseline microbenchmark probe rows per candidate;
///   each row bucket scales its own representative probe from this.
///   0 runs on the cost-model prior alone.
/// * `calib_reps` — best-of repetitions per probe.
/// * `cache_path` — JSON file persisting plans across restarts. Plans
///   are keyed per row bucket and persisted as schema v4: each entry
///   carries its `rows_bucket`, the raw probe timings behind the
///   decision, and the race's runner-up; the document carries a host
///   fingerprint, a `created_unix` stamp, and the learned row-bucket
///   boundaries. Schema-v3 documents migrate in place (entries
///   re-bucketed under the default boundaries); foreign-host, older
///   (v1/v2), or expired documents are rejected wholesale and
///   re-calibrated.
/// * `cache_ttl_secs` — persisted-cache expiry in seconds (default one
///   week; 0 = never expires). Calibration is a measurement of a
///   moment — hosts drift — so stale caches are re-measured.
/// * `shadow_every` — online shadow re-probing cadence: every Nth
///   dispatched batch is re-timed against the plan's recorded
///   runner-up, and a winner whose measured edge inverts past the
///   hysteresis margin is demoted in place. 0 (default) turns the
///   mechanism off entirely — dispatch is then exactly the
///   pre-shadow path.
/// * `shadow_every_max` — ceiling the load-adaptive cadence may
///   stretch `shadow_every` to when telemetry shows deep queues or
///   near-deadline traffic (0 = 8x the base).
/// * `shadow_busy_rows` — queued rows at or above which a telemetry
///   report counts as busy for the cadence loop.
/// * `bucket_learn_window` — rows samples the service accumulates
///   between row-bucket boundary relearn attempts (the telemetry
///   window the quantile split is computed over).
/// * `recall_probe_rows` — rows in the seeded probe workload the
///   planner measures `Mode::Approx` candidates' recall on before the
///   timing race (candidates below the target are disqualified
///   regardless of speed; clamped to at least 8).
/// * `recall_margin_milli` — qualification safety margin in
///   thousandths added to the requested recall target: a candidate
///   must measure at least `target + margin` to stay in the race, so
///   sampling noise on the probe cannot admit a borderline mode.
#[derive(Clone, Debug)]
pub struct PlanConfig {
    pub force_algo: Option<String>,
    pub calib_rows: usize,
    pub calib_reps: usize,
    pub cache_path: Option<String>,
    pub cache_ttl_secs: u64,
    pub shadow_every: usize,
    pub shadow_every_max: usize,
    pub shadow_busy_rows: u64,
    pub bucket_learn_window: usize,
    pub recall_probe_rows: usize,
    pub recall_margin_milli: u16,
}

/// Hand-written (not derived): a derived Default would zero
/// `calib_rows` and silently switch the planner to cost-model-only
/// mode for anyone using `..Default::default()`.
impl Default for PlanConfig {
    fn default() -> Self {
        PlanConfig {
            force_algo: None,
            calib_rows: 192,
            calib_reps: 3,
            cache_path: None,
            // one week — keep in sync with plan::cache::DEFAULT_TTL_SECS
            // (this module must stay free of plan-layer dependencies)
            cache_ttl_secs: 7 * 24 * 3600,
            shadow_every: 0,
            shadow_every_max: 0,
            shadow_busy_rows: 4096,
            bucket_learn_window: 1024,
            recall_probe_rows: 256,
            recall_margin_milli: 5,
        }
    }
}

impl PlanConfig {
    pub fn from_config(c: &Config) -> PlanConfig {
        let d = PlanConfig::default();
        PlanConfig {
            force_algo: c
                .get("plan.force_algo")
                .filter(|s| !s.is_empty())
                .map(|s| s.to_string()),
            calib_rows: c.get_or("plan.calib_rows", d.calib_rows),
            calib_reps: c.get_or("plan.calib_reps", d.calib_reps),
            cache_path: c
                .get("plan.cache_path")
                .filter(|s| !s.is_empty())
                .map(|s| s.to_string()),
            cache_ttl_secs: c.get_or("plan.cache_ttl_secs", d.cache_ttl_secs),
            shadow_every: c.get_or("plan.shadow_every", d.shadow_every),
            shadow_every_max: c.get_or("plan.shadow_every_max", d.shadow_every_max),
            shadow_busy_rows: c.get_or("plan.shadow_busy_rows", d.shadow_busy_rows),
            bucket_learn_window: c
                .get_or("plan.bucket_learn_window", d.bucket_learn_window),
            recall_probe_rows: c
                .get_or("plan.recall_probe_rows", d.recall_probe_rows),
            recall_margin_milli: c
                .get_or("plan.recall_margin_milli", d.recall_margin_milli),
        }
    }
}

/// Execution-backend knobs (the `[backend]` section). Untyped here —
/// the service validates ids against the built registry at startup.
///
/// * `enable` — register accelerator backends from the manifest
///   (default true); `false` runs everything on the CPU engine.
/// * `force` — pin every shape the named backend supports to it
///   (`cpu`, `pjrt`, ...); shapes it cannot serve still fall back to
///   the CPU engine. Pins are session state: they bypass and never
///   overwrite the persisted plan cache.
/// * `deny` — comma-separated backend ids that must never register
///   (e.g. `deny = "pjrt"` to quarantine a misbehaving accelerator).
///   The CPU backend cannot be denied; it is the guaranteed fallback.
#[derive(Clone, Debug)]
pub struct BackendConfig {
    pub enable: bool,
    pub force: Option<String>,
    pub deny: Vec<String>,
}

impl Default for BackendConfig {
    fn default() -> Self {
        BackendConfig { enable: true, force: None, deny: Vec::new() }
    }
}

impl BackendConfig {
    pub fn from_config(c: &Config) -> BackendConfig {
        BackendConfig {
            enable: c.get_or("backend.enable", true),
            force: c
                .get("backend.force")
                .filter(|s| !s.is_empty())
                .map(|s| s.to_string()),
            deny: c
                .get("backend.deny")
                .map(|s| {
                    s.split(',')
                        .map(|t| t.trim().to_string())
                        .filter(|t| !t.is_empty())
                        .collect()
                })
                .unwrap_or_default(),
        }
    }

    /// Whether an id is deny-listed (the CPU fallback never is).
    pub fn denies(&self, id: &str) -> bool {
        id != "cpu" && self.deny.iter().any(|d| d == id)
    }
}

/// One tenant's serving policy (a `[tenants.<name>]` table). Untyped
/// here — `coordinator::tenant::TenantDirectory::from_config` validates
/// `force_algo` / `mode` strings at service startup (this module stays
/// plain data with no dependency on the topk layer). Tenant names must
/// not contain dots (the table key separator).
///
/// * `weight` — weighted-deficit-round-robin drain weight (default 1;
///   0 is clamped to 1). A weight-4 tenant's budget-full batches drain
///   4x as often as a weight-1 tenant's when both have backlog.
/// * `max_in_flight_rows` — rows admitted and not yet replied to;
///   submissions past the limit are rejected, not queued (0 = no
///   limit, the default).
/// * `max_queue_depth` — requests admitted and not yet replied to
///   (0 = no limit, the default).
/// * `force_algo` — per-tenant algorithm pin, same vocabulary and
///   semantics rules as `[plan] force_algo`.
/// * `mode` — default search mode (`exact` | `es<N>` | `eps<X>` |
///   `apx<N>`) used when the tenant submits without an explicit mode.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantConfig {
    pub name: String,
    pub weight: u64,
    pub max_in_flight_rows: usize,
    pub max_queue_depth: usize,
    pub force_algo: Option<String>,
    pub mode: Option<String>,
}

impl TenantConfig {
    /// A tenant entry with the defaults (weight 1, no quotas, no
    /// overrides).
    pub fn named(name: &str) -> TenantConfig {
        TenantConfig {
            name: name.to_string(),
            weight: 1,
            max_in_flight_rows: 0,
            max_queue_depth: 0,
            force_algo: None,
            mode: None,
        }
    }
}

/// The `[tenants]` section: one [`TenantConfig`] per `[tenants.<name>]`
/// table. Tenants absent from config are still served — under weight 1
/// with no quotas — so this table *constrains* tenants rather than
/// registering them.
///
/// Key names are checked: a misspelled quota key (say
/// `max_inflight_rows`) would otherwise silently leave the tenant
/// unquotaed, defeating the one feature the table exists for. Unknown
/// keys are collected into `unknown_keys` here (this module never
/// fails) and rejected at service startup by
/// `coordinator::tenant::TenantDirectory::from_config`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TenantsConfig {
    pub tenants: Vec<TenantConfig>,
    /// `tenants.*` keys whose field name is not a known knob
    pub unknown_keys: Vec<String>,
}

/// The field names a `[tenants.<name>]` table may set.
pub const TENANT_KEYS: [&str; 5] =
    ["weight", "max_in_flight_rows", "max_queue_depth", "force_algo", "mode"];

impl TenantsConfig {
    pub fn from_config(c: &Config) -> TenantsConfig {
        let mut names: Vec<String> = Vec::new();
        let mut unknown_keys: Vec<String> = Vec::new();
        for key in c.keys() {
            if let Some(rest) = key.strip_prefix("tenants.") {
                if let Some((name, field)) = rest.rsplit_once('.') {
                    if name.is_empty() {
                        continue;
                    }
                    // a dotted name ([tenants.team.alpha]) would
                    // register tenant "team.alpha" while the operator
                    // meant to quota "alpha" — same silent-misaddress
                    // class as a typoed field, so same treatment
                    if name.contains('.') || !TENANT_KEYS.contains(&field) {
                        unknown_keys.push(key.to_string());
                        continue;
                    }
                    if !names.iter().any(|n| n == name) {
                        names.push(name.to_string());
                    }
                }
            }
        }
        let tenants = names
            .iter()
            .map(|name| {
                let d = TenantConfig::named(name);
                TenantConfig {
                    name: name.clone(),
                    weight: c
                        .get_or(&format!("tenants.{name}.weight"), d.weight)
                        .max(1),
                    max_in_flight_rows: c.get_or(
                        &format!("tenants.{name}.max_in_flight_rows"),
                        d.max_in_flight_rows,
                    ),
                    max_queue_depth: c.get_or(
                        &format!("tenants.{name}.max_queue_depth"),
                        d.max_queue_depth,
                    ),
                    force_algo: c
                        .get(&format!("tenants.{name}.force_algo"))
                        .filter(|s| !s.is_empty())
                        .map(|s| s.to_string()),
                    mode: c
                        .get(&format!("tenants.{name}.mode"))
                        .filter(|s| !s.is_empty())
                        .map(|s| s.to_string()),
                }
            })
            .collect();
        TenantsConfig { tenants, unknown_keys }
    }

    /// The entry for a tenant name, if one is configured.
    pub fn get(&self, name: &str) -> Option<&TenantConfig> {
        self.tenants.iter().find(|t| t.name == name)
    }
}

/// Persistent-worker-pool knobs (the `[pool]` section). The service
/// applies these before the pool's first job (`util::pool::configure`);
/// the `RTOPK_THREADS` env var overrides `threads` when set to a valid
/// positive integer.
///
/// * `threads` — total participants per fork-join job (resident
///   workers + the submitting thread). 0 (default) sizes from
///   `available_parallelism`.
/// * `warm_on_start` — start the pool and run one no-op job at service
///   build (default true), so the first client batch does not pay
///   worker start-up. `false` defers to the first parallel call.
#[derive(Clone, Debug, PartialEq)]
pub struct PoolConfig {
    pub threads: usize,
    pub warm_on_start: bool,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig { threads: 0, warm_on_start: true }
    }
}

impl PoolConfig {
    pub fn from_config(c: &Config) -> PoolConfig {
        let d = PoolConfig::default();
        PoolConfig {
            threads: c.get_or("pool.threads", d.threads),
            warm_on_start: c.get_or("pool.warm_on_start", d.warm_on_start),
        }
    }
}

/// Network-layer knobs (the `[net]` section), read by `rtopk listen`
/// and `rtopk shard`. Untyped here — `net::server` / `net::router`
/// validate the bind address and shard list when they open sockets.
///
/// * `bind` — listen address for both subcommands.
/// * `max_connections` — accepted-connection cap; a connection past the
///   cap is answered with one overload error frame and closed.
/// * `read_buf_bytes` — per-connection cap on buffered undecoded
///   bytes. Reads pause at the cap, so a client streaming a frame
///   larger than this deadlocks itself — size it above the largest
///   legitimate request frame.
/// * `write_buf_bytes` — per-connection cap on buffered encoded reply
///   bytes; result encoding (and then reads) pause while a slow reader
///   keeps the buffer at the cap.
/// * `max_inflight_per_conn` — requests one connection may have inside
///   the service at once; further frames wait in the read buffer.
/// * `shards` — comma-separated worker addresses the shard router fans
///   requests across (ignored by `rtopk listen`).
/// * `health_cadence_ms` — interval between ping probes to each shard.
/// * `health_timeout_ms` — per-probe connect/read timeout; a probe
///   past it counts as a failure toward quarantine.
#[derive(Clone, Debug, PartialEq)]
pub struct NetConfig {
    pub bind: String,
    pub max_connections: usize,
    pub read_buf_bytes: usize,
    pub write_buf_bytes: usize,
    pub max_inflight_per_conn: usize,
    pub shards: Vec<String>,
    pub health_cadence_ms: u64,
    pub health_timeout_ms: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            bind: "127.0.0.1:7070".to_string(),
            max_connections: 1024,
            read_buf_bytes: 64 << 20,
            write_buf_bytes: 64 << 20,
            max_inflight_per_conn: 64,
            shards: Vec::new(),
            health_cadence_ms: 500,
            health_timeout_ms: 250,
        }
    }
}

impl NetConfig {
    pub fn from_config(c: &Config) -> NetConfig {
        let d = NetConfig::default();
        NetConfig {
            bind: c
                .get("net.bind")
                .filter(|s| !s.is_empty())
                .map(|s| s.to_string())
                .unwrap_or(d.bind),
            max_connections: c.get_or("net.max_connections", d.max_connections),
            read_buf_bytes: c.get_or("net.read_buf_bytes", d.read_buf_bytes),
            write_buf_bytes: c.get_or("net.write_buf_bytes", d.write_buf_bytes),
            max_inflight_per_conn: c
                .get_or("net.max_inflight_per_conn", d.max_inflight_per_conn),
            shards: c
                .get("net.shards")
                .map(|s| {
                    s.split(',')
                        .map(|t| t.trim().to_string())
                        .filter(|t| !t.is_empty())
                        .collect()
                })
                .unwrap_or_default(),
            health_cadence_ms: c
                .get_or("net.health_cadence_ms", d.health_cadence_ms),
            health_timeout_ms: c
                .get_or("net.health_timeout_ms", d.health_timeout_ms),
        }
    }
}

/// Default per-tenant cap on blocked cooperative submitters (the
/// `[serve] max_blocked_waiters` knob). Single source of truth — the
/// tenant directory's default references this constant.
pub const MAX_BLOCKED_WAITERS: usize = 64;

/// Service deployment settings (defaults match the benched setup).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// artifacts directory holding manifest.json
    pub artifacts_dir: String,
    /// max rows buffered before a batch is forced out
    pub max_batch_rows: usize,
    /// max microseconds a request may wait for batching
    pub max_wait_us: u64,
    /// worker threads executing batches
    pub workers: usize,
    /// queued-row limit before submissions block (backpressure)
    pub queue_limit: usize,
    /// reject non-finite (NaN/Inf) client matrices at submit with a
    /// clear error instead of letting the kernels' branchless IEEE
    /// compares silently corrupt the selection (default on; disable
    /// only for callers that guarantee finite inputs themselves —
    /// per-request `ValidationPolicy` overrides win either way)
    pub validate_inputs: bool,
    /// default behavior for over-quota submissions that do not choose
    /// a policy themselves: `"reject"` (shed with a positioned error,
    /// the default) or `"block"` (park the submitting thread until
    /// quota frees). Validated at service startup.
    pub over_quota_policy: String,
    /// per-tenant cap on blocked cooperative submitters
    /// (`OverQuotaPolicy::Block`); 0 turns blocking admission into
    /// rejection
    pub max_blocked_waiters: usize,
    /// reject deadline'd submissions whose deadline is provably
    /// unmeetable at enqueue (current backlog at the measured service
    /// rate plus the request's own cost-model floor already exceeds
    /// the budget) with an immediate positioned error instead of
    /// queueing work guaranteed to time out (default on)
    pub feasibility_admission: bool,
    /// slack factor for feasibility admission: reject only when the
    /// predicted completion exceeds `deadline * (1 + margin)` — the
    /// margin absorbs estimate noise so admission stays a *provably
    /// unmeetable* test, not a load-shedding heuristic
    pub feasibility_margin: f64,
    /// floor (in thousandths) on the recall target a `Mode::Approx`
    /// submission may request: requests below it are rejected at submit
    /// with a positioned error, so one misconfigured caller cannot
    /// quietly degrade its own results past what the deployment deems
    /// usable (default 500 = recall 0.5; 1 admits any valid target)
    pub min_recall_milli: u16,
    /// adaptive-planner knobs for the CPU engine route
    pub plan: PlanConfig,
    /// execution-backend registration / pinning knobs
    pub backend: BackendConfig,
    /// per-tenant weights, quotas, and execution overrides
    pub tenants: TenantsConfig,
    /// persistent worker-pool sizing / warmup knobs
    pub pool: PoolConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifacts_dir: "artifacts".into(),
            max_batch_rows: 1024,
            max_wait_us: 200,
            workers: 2,
            queue_limit: 1 << 16,
            validate_inputs: true,
            over_quota_policy: "reject".into(),
            max_blocked_waiters: MAX_BLOCKED_WAITERS,
            feasibility_admission: true,
            feasibility_margin: 0.25,
            min_recall_milli: 500,
            plan: PlanConfig::default(),
            backend: BackendConfig::default(),
            tenants: TenantsConfig::default(),
            pool: PoolConfig::default(),
        }
    }
}

impl ServeConfig {
    pub fn from_config(c: &Config) -> ServeConfig {
        let d = ServeConfig::default();
        ServeConfig {
            artifacts_dir: c
                .get("serve.artifacts_dir")
                .unwrap_or(&d.artifacts_dir)
                .to_string(),
            max_batch_rows: c.get_or("serve.max_batch_rows", d.max_batch_rows),
            max_wait_us: c.get_or("serve.max_wait_us", d.max_wait_us),
            workers: c.get_or("serve.workers", d.workers),
            queue_limit: c.get_or("serve.queue_limit", d.queue_limit),
            validate_inputs: c.get_or("serve.validate_inputs", d.validate_inputs),
            over_quota_policy: c
                .get("serve.over_quota_policy")
                .filter(|s| !s.is_empty())
                .unwrap_or(&d.over_quota_policy)
                .to_string(),
            max_blocked_waiters: c
                .get_or("serve.max_blocked_waiters", d.max_blocked_waiters),
            feasibility_admission: c
                .get_or("serve.feasibility_admission", d.feasibility_admission),
            feasibility_margin: c
                .get_or("serve.feasibility_margin", d.feasibility_margin),
            min_recall_milli: c
                .get_or("serve.min_recall_milli", d.min_recall_milli),
            plan: PlanConfig::from_config(c),
            backend: BackendConfig::from_config(c),
            tenants: TenantsConfig::from_config(c),
            pool: PoolConfig::from_config(c),
        }
    }
}

/// Trainer settings.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub artifacts_dir: String,
    pub model: String,
    pub dataset: String,
    /// `exact` or `es<N>`
    pub topk_mode: String,
    pub steps: usize,
    pub eval_every: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            artifacts_dir: "artifacts".into(),
            model: "gcn".into(),
            dataset: "flickr-sim".into(),
            topk_mode: "es4".into(),
            steps: 200,
            eval_every: 20,
            seed: 42,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_types_comments() {
        let c = Config::parse(
            r#"
            # top comment
            root_key = 7
            [serve]
            artifacts_dir = "art/x"  # trailing comment
            max_batch_rows = 512
            [train]
            model = 'sage'
            lr = 0.05
            flag = true
            "#,
        )
        .unwrap();
        assert_eq!(c.get("root_key"), Some("7"));
        assert_eq!(c.get("serve.artifacts_dir"), Some("art/x"));
        assert_eq!(c.get_or("serve.max_batch_rows", 0usize), 512);
        assert_eq!(c.get("train.model"), Some("sage"));
        assert_eq!(c.get_or("train.lr", 0.0f64), 0.05);
        assert_eq!(c.get_or("train.flag", false), true);
    }

    #[test]
    fn hash_inside_quotes_is_kept() {
        let c = Config::parse(r##"name = "a#b""##).unwrap();
        assert_eq!(c.get("name"), Some("a#b"));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("[unclosed").is_err());
        assert!(Config::parse("novalue").is_err());
    }

    #[test]
    fn serve_config_from_file_text() {
        let c = Config::parse("[serve]\nmax_batch_rows = 2048\nworkers = 4").unwrap();
        let s = ServeConfig::from_config(&c);
        assert_eq!(s.max_batch_rows, 2048);
        assert_eq!(s.workers, 4);
        assert_eq!(s.max_wait_us, ServeConfig::default().max_wait_us);
        assert_eq!(s.plan.calib_rows, PlanConfig::default().calib_rows);
    }

    #[test]
    fn plan_config_section_parses() {
        let c = Config::parse(
            "[plan]\nforce_algo = \"radix\"\ncalib_rows = 64\n\
             cache_path = \"plans.json\"\ncache_ttl_secs = 3600\n\
             shadow_every = 32\nshadow_every_max = 128\n\
             shadow_busy_rows = 2048\nbucket_learn_window = 256",
        )
        .unwrap();
        let p = PlanConfig::from_config(&c);
        assert_eq!(p.force_algo.as_deref(), Some("radix"));
        assert_eq!(p.calib_rows, 64);
        assert_eq!(p.calib_reps, PlanConfig::default().calib_reps);
        assert_eq!(p.cache_path.as_deref(), Some("plans.json"));
        assert_eq!(p.cache_ttl_secs, 3600);
        assert_eq!(p.shadow_every, 32);
        assert_eq!(p.shadow_every_max, 128);
        assert_eq!(p.shadow_busy_rows, 2048);
        assert_eq!(p.bucket_learn_window, 256);
        // empty string means unset
        let c2 = Config::parse("[plan]\nforce_algo = \"\"").unwrap();
        assert!(PlanConfig::from_config(&c2).force_algo.is_none());
        // defaults: weekly cache ttl, shadow re-probing off
        let d = PlanConfig::default();
        assert_eq!(d.cache_ttl_secs, 7 * 24 * 3600);
        assert_eq!(d.shadow_every, 0);
    }

    #[test]
    fn recall_knobs_parse_with_defaults() {
        let d = PlanConfig::default();
        assert_eq!(d.recall_probe_rows, 256);
        assert_eq!(d.recall_margin_milli, 5);
        let c = Config::parse(
            "[plan]\nrecall_probe_rows = 64\nrecall_margin_milli = 10\n\
             [serve]\nmin_recall_milli = 800",
        )
        .unwrap();
        let p = PlanConfig::from_config(&c);
        assert_eq!(p.recall_probe_rows, 64);
        assert_eq!(p.recall_margin_milli, 10);
        let s = ServeConfig::from_config(&c);
        assert_eq!(s.min_recall_milli, 800);
        assert_eq!(s.plan.recall_probe_rows, 64);
        assert_eq!(ServeConfig::default().min_recall_milli, 500);
    }

    #[test]
    fn serve_validate_inputs_knob_parses_and_defaults_on() {
        assert!(ServeConfig::default().validate_inputs);
        let c = Config::parse("[serve]\nvalidate_inputs = false").unwrap();
        assert!(!ServeConfig::from_config(&c).validate_inputs);
        let c2 = Config::parse("[serve]\nworkers = 2").unwrap();
        assert!(ServeConfig::from_config(&c2).validate_inputs);
    }

    #[test]
    fn serve_over_quota_knobs_parse_with_defaults() {
        let d = ServeConfig::default();
        assert_eq!(d.over_quota_policy, "reject");
        assert_eq!(d.max_blocked_waiters, 64);
        let c = Config::parse(
            "[serve]\nover_quota_policy = \"block\"\nmax_blocked_waiters = 8",
        )
        .unwrap();
        let s = ServeConfig::from_config(&c);
        assert_eq!(s.over_quota_policy, "block");
        assert_eq!(s.max_blocked_waiters, 8);
        // empty string means "use the default", like the other knobs
        let c2 = Config::parse("[serve]\nover_quota_policy = \"\"").unwrap();
        assert_eq!(ServeConfig::from_config(&c2).over_quota_policy, "reject");
        // the value itself is validated at service startup, not here
        let c3 = Config::parse("[serve]\nover_quota_policy = \"typo\"").unwrap();
        assert_eq!(ServeConfig::from_config(&c3).over_quota_policy, "typo");
    }

    #[test]
    fn serve_feasibility_knobs_parse_with_defaults() {
        let d = ServeConfig::default();
        assert!(d.feasibility_admission, "feasibility admission defaults on");
        assert_eq!(d.feasibility_margin, 0.25);
        let c = Config::parse(
            "[serve]\nfeasibility_admission = false\nfeasibility_margin = 0.5",
        )
        .unwrap();
        let s = ServeConfig::from_config(&c);
        assert!(!s.feasibility_admission);
        assert_eq!(s.feasibility_margin, 0.5);
    }

    #[test]
    fn tenants_section_parses_per_tenant_tables() {
        let c = Config::parse(
            "[tenants.alpha]\nweight = 4\nmax_in_flight_rows = 4096\n\
             max_queue_depth = 64\nforce_algo = \"heap\"\n\
             [tenants.beta]\nweight = 2\nmode = \"es4\"\n\
             [tenants.gamma]\nweight = 0",
        )
        .unwrap();
        let t = TenantsConfig::from_config(&c);
        assert_eq!(t.tenants.len(), 3);
        let alpha = t.get("alpha").unwrap();
        assert_eq!(alpha.weight, 4);
        assert_eq!(alpha.max_in_flight_rows, 4096);
        assert_eq!(alpha.max_queue_depth, 64);
        assert_eq!(alpha.force_algo.as_deref(), Some("heap"));
        assert_eq!(alpha.mode, None);
        let beta = t.get("beta").unwrap();
        assert_eq!(beta.weight, 2);
        assert_eq!(beta.max_in_flight_rows, 0, "quotas default to unlimited");
        assert_eq!(beta.mode.as_deref(), Some("es4"));
        // weight 0 would make a tenant never drain; clamped to 1
        assert_eq!(t.get("gamma").unwrap().weight, 1);
        assert!(t.get("unknown").is_none());
        // empty-string overrides mean unset
        let c2 = Config::parse("[tenants.x]\nforce_algo = \"\"").unwrap();
        let t2 = TenantsConfig::from_config(&c2);
        assert!(t2.get("x").unwrap().force_algo.is_none());
        // no [tenants] section at all: empty table
        assert!(TenantsConfig::from_config(&Config::default())
            .tenants
            .is_empty());
    }

    #[test]
    fn misspelled_tenant_keys_are_collected_not_silently_dropped() {
        // a typoed quota key must not leave the tenant unquotaed with
        // no trace — from_config records it for startup validation
        let c = Config::parse(
            "[tenants.abuser]\nmax_inflight_rows = 4096\n\
             [tenants.ok]\nweight = 2",
        )
        .unwrap();
        let t = TenantsConfig::from_config(&c);
        assert_eq!(
            t.unknown_keys,
            vec!["tenants.abuser.max_inflight_rows".to_string()]
        );
        assert!(t.get("abuser").is_none(), "no valid keys, no entry");
        assert_eq!(t.get("ok").unwrap().weight, 2);
        // clean configs carry no unknown keys
        let clean = Config::parse("[tenants.ok]\nweight = 2").unwrap();
        assert!(TenantsConfig::from_config(&clean).unknown_keys.is_empty());
    }

    #[test]
    fn serve_config_carries_the_tenants_table() {
        let c = Config::parse(
            "[serve]\nworkers = 3\n[tenants.heavy]\nweight = 8",
        )
        .unwrap();
        let s = ServeConfig::from_config(&c);
        assert_eq!(s.workers, 3);
        assert_eq!(s.tenants.get("heavy").unwrap().weight, 8);
        assert!(ServeConfig::default().tenants.tenants.is_empty());
    }

    #[test]
    fn pool_config_section_parses_with_defaults() {
        let d = PoolConfig::default();
        assert_eq!(d.threads, 0, "0 = size from available_parallelism");
        assert!(d.warm_on_start);
        let c = Config::parse("[pool]\nthreads = 6\nwarm_on_start = false").unwrap();
        let p = PoolConfig::from_config(&c);
        assert_eq!(p.threads, 6);
        assert!(!p.warm_on_start);
        // ServeConfig carries the section
        let s = ServeConfig::from_config(&c);
        assert_eq!(s.pool.threads, 6);
        assert!(!s.pool.warm_on_start);
        assert_eq!(ServeConfig::default().pool, PoolConfig::default());
    }

    #[test]
    fn backend_config_section_parses() {
        let c = Config::parse(
            "[backend]\nenable = false\nforce = \"pjrt\"\ndeny = \"pjrt, mock\"",
        )
        .unwrap();
        let b = BackendConfig::from_config(&c);
        assert!(!b.enable);
        assert_eq!(b.force.as_deref(), Some("pjrt"));
        assert_eq!(b.deny, vec!["pjrt".to_string(), "mock".to_string()]);
        assert!(b.denies("pjrt"));
        assert!(b.denies("mock"));
        assert!(!b.denies("other"));
        // the cpu fallback can never be denied
        let c2 = Config::parse("[backend]\ndeny = \"cpu\"").unwrap();
        assert!(!BackendConfig::from_config(&c2).denies("cpu"));
        // defaults: enabled, no pin, empty deny list
        let d = BackendConfig::from_config(&Config::default());
        assert!(d.enable);
        assert!(d.force.is_none());
        assert!(d.deny.is_empty());
    }
}
