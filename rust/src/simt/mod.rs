//! Warp-level GPU execution simulator.
//!
//! The paper's testbed is an NVIDIA A6000; this substrate models the
//! kernel's three stages (Fig. 3: load -> search -> select) at the
//! warp-instruction level with an A6000-like cost model, so Fig. 4/6/7's
//! *kernel-time* comparisons can be reproduced as cycle estimates in
//! addition to the CPU wall-clock benches. It also provides the
//! structural VMEM/roofline estimates DESIGN.md §5 commits to for the
//! TPU mapping.
//!
//! Fidelity statement: this is a cost model, not a cycle-accurate GPU.
//! It charges each stage the *memory transactions and warp-synchronous
//! instructions the algorithm provably performs* (coalesced 128B global
//! loads, shared-memory reads, shuffle/ballot/popc ops, ALU ops) and
//! derives kernel time from occupancy-limited wave counts — the same
//! accounting the paper uses to argue its complexity (Appendix B).

pub mod cost;
pub mod kernels;
pub mod occupancy;

pub use cost::{CostModel, StageCycles};
pub use kernels::{simulate_radix_row, simulate_rtopk_row, KernelEstimate};
pub use occupancy::kernel_time_ms;
