//! MaxK-compressed feature rows + the compressed SpMM — the MaxK-GNN
//! trick (paper Fig. 1): after row-wise top-k, each feature row has
//! exactly k nonzeros, so the aggregation SpMM touches k instead of M
//! columns per gathered row. This is where the paper's end-to-end
//! training speed-up comes from; RTop-K makes the *producer* of this
//! format fast.

use crate::graph::csr::CsrGraph;
use crate::topk::types::TopKResult;
use crate::util::matrix::RowMatrix;
use crate::util::pool;

/// Row-compressed matrix: row r holds exactly k (value, column) pairs.
#[derive(Clone, Debug)]
pub struct CompressedRows {
    pub rows: usize,
    pub cols: usize,
    pub k: usize,
    /// len rows*k
    pub values: Vec<f32>,
    /// len rows*k
    pub indices: Vec<u32>,
}

impl CompressedRows {
    #[inline]
    pub fn row(&self, r: usize) -> (&[f32], &[u32]) {
        let k = self.k;
        (&self.values[r * k..(r + 1) * k], &self.indices[r * k..(r + 1) * k])
    }

    /// Expand back to dense (testing / the ablation path).
    pub fn to_dense(&self) -> RowMatrix {
        let mut out = RowMatrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let (vals, idx) = self.row(r);
            for (v, &i) in vals.iter().zip(idx) {
                out.set(r, i as usize, *v);
            }
        }
        out
    }
}

/// Wrap a top-k result as the compressed operand of the next SpMM.
pub fn maxk_compress(res: &TopKResult, cols: usize) -> CompressedRows {
    CompressedRows {
        rows: res.rows,
        cols,
        k: res.k,
        values: res.values.clone(),
        indices: res.indices.clone(),
    }
}

/// SpMM with a row-compressed right-hand side:
/// `out[d] += w * compressed_row(s)` for each in-edge `(s, w)` of `d`.
/// Inner loop is k-long instead of M-long — the MaxK-GNN speedup.
pub fn spmm_compressed(g: &CsrGraph, x: &CompressedRows) -> RowMatrix {
    assert_eq!(g.num_nodes, x.rows);
    let m = x.cols;
    let mut out = RowMatrix::zeros(g.num_nodes, m);
    let optr = SendPtr(out.data.as_mut_ptr());
    pool::parallel_ranges(g.num_nodes, 16, |start, end| {
        for d in start..end {
            // SAFETY: destination rows are partitioned disjointly
            // across threads; `out` outlives the parallel call.
            let orow = unsafe {
                std::slice::from_raw_parts_mut(optr.get().add(d * m), m)
            };
            let (srcs, ws) = g.in_edges(d);
            for (&s, &w) in srcs.iter().zip(ws) {
                let (vals, idx) = x.row(s as usize);
                for (v, &i) in vals.iter().zip(idx) {
                    orow[i as usize] += w * v;
                }
            }
        }
    });
    out
}

struct SendPtr<T>(*mut T);
impl<T> SendPtr<T> {
    #[inline]
    fn get(&self) -> *mut T {
        self.0
    }
}
// SAFETY: participants write only their own disjoint row ranges (the
// scheduler partitions 0..num_nodes), and the pointee outlives the job.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::ops::spmm_csr;
    use crate::graph::generate::{sbm_graph, SbmParams};
    use crate::topk::{rowwise_topk, Mode};
    use crate::util::rng::Rng;

    #[test]
    fn compressed_spmm_equals_dense_spmm_on_masked_input() {
        let mut rng = Rng::seed_from(12);
        let g = sbm_graph(&SbmParams::default(), 5).to_csr();
        let x = RowMatrix::random_normal(g.num_nodes, 32, &mut rng);
        let res = rowwise_topk(&x, 8, Mode::EXACT);
        let comp = maxk_compress(&res, 32);
        // dense reference: zero out everything not selected
        let dense = comp.to_dense();
        let want = spmm_csr(&g, &dense);
        let got = spmm_compressed(&g, &comp);
        for (a, b) in got.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn to_dense_has_k_nonzeros_per_row() {
        let mut rng = Rng::seed_from(13);
        let x = RowMatrix::random_normal(10, 16, &mut rng);
        let res = rowwise_topk(&x, 4, Mode::EXACT);
        let dense = maxk_compress(&res, 16).to_dense();
        for r in 0..10 {
            let nz = dense.row(r).iter().filter(|&&v| v != 0.0).count();
            // top-k of a continuous distribution never selects exact zeros
            assert_eq!(nz, 4);
        }
    }
}
