//! Integration: adaptive planner parity and persistence.
//!
//! The core guarantee under test: for any shape, `Planner::run` (and
//! therefore `rowwise_topk_auto`) returns *bit-identical* output to the
//! fixed-algorithm oracle of whatever plan the grid chose — dispatch
//! may change speed, never results — and exact-mode plans additionally
//! match the sort oracle's multiset. Plans are keyed per row bucket;
//! the oracle lookup must use the matrix's own row count so both sides
//! resolve the same bucketed plan.

use rtopk::plan::{
    candidates, Plan, PlanSource, Planner, PlannerConfig, RowBucket,
};
use rtopk::topk::rowwise::{rowwise_topk_with, RowAlgo};
use rtopk::topk::types::Mode;
use rtopk::topk::verify::is_exact;
use rtopk::util::matrix::RowMatrix;
use rtopk::util::prop::{forall, gens};
use rtopk::util::rng::Rng;

fn quick_planner() -> Planner {
    Planner::new(PlannerConfig {
        calib_rows: 32,
        calib_reps: 1,
        ..PlannerConfig::default()
    })
}

#[test]
fn auto_equals_fixed_algo_oracle_for_every_chosen_plan() {
    let planner = quick_planner();
    forall(
        "auto == fixed-algo oracle",
        0x9_1A_7,
        120,
        |rng| {
            let (m, k) = gens::m_and_k(rng, 96);
            let rows = 1 + rng.index(40);
            let mode = if rng.chance(0.5) {
                Mode::EXACT
            } else {
                Mode::EarlyStop { max_iter: 1 + rng.index(8) as u32 }
            };
            let x = RowMatrix::from_vec(
                rows,
                m,
                (0..rows * m).map(|_| rng.normal_f32()).collect(),
            );
            (x, k, mode)
        },
        |(x, k, mode)| {
            let planner = &planner;
            let auto = planner.run(x, *k, *mode);
            let plan = planner.plan(x.rows, x.cols, *k, *mode);
            let oracle = rowwise_topk_with(x, *k, plan.algo);
            if auto.values != oracle.values || auto.indices != oracle.indices {
                return Err(format!(
                    "auto diverged from its own plan {:?}",
                    plan.algo.name()
                ));
            }
            if rtopk::plan::is_exact_semantics(*mode) && !is_exact(x, &auto) {
                return Err("exact-mode plan returned non-exact top-k".into());
            }
            Ok(())
        },
    );
}

#[test]
fn auto_parity_holds_across_row_buckets() {
    // The same (cols, k, mode) planned at every bucket must stay
    // bit-identical to each bucket's own plan oracle — bucketed
    // dispatch changes speed, never results.
    let planner = quick_planner();
    let mut rng = Rng::seed_from(0xB0C);
    for rows in [16usize, 200, 1500] {
        let x = RowMatrix::random_normal(rows, 96, &mut rng);
        let auto = planner.run(&x, 12, Mode::EXACT);
        let plan = planner.plan(rows, 96, 12, Mode::EXACT);
        let oracle = rowwise_topk_with(&x, 12, plan.algo);
        assert_eq!(auto.values, oracle.values, "rows={rows}");
        assert_eq!(auto.indices, oracle.indices, "rows={rows}");
        assert!(is_exact(&x, &auto), "rows={rows}");
    }
    // one plan per touched bucket
    assert_eq!(planner.cache().len(), 3);
}

#[test]
fn buckets_of_one_shape_can_hold_different_winners() {
    // Acceptance: two buckets of the same (cols, k, mode) holding
    // different winners when their probes disagree. Probes are seeded
    // directly (real timings are host-dependent); the planner must key
    // recalls by bucket and never cross-contaminate.
    let planner = quick_planner();
    let seed = |algo: RowAlgo, grain: usize| Plan {
        backend: "cpu".into(),
        algo,
        grain,
        source: PlanSource::Cached,
        probes: Vec::new(),
        runner_up: None,
        shadow: None,
        recall: None,
    };
    planner
        .cache()
        .insert(RowBucket::Le64, 300, 10, "exact", seed(RowAlgo::Heap, 8));
    planner
        .cache()
        .insert(RowBucket::Gt1024, 300, 10, "exact", seed(RowAlgo::Radix, 64));
    assert_eq!(planner.plan(8, 300, 10, Mode::EXACT).algo, RowAlgo::Heap);
    assert_eq!(planner.plan(5000, 300, 10, Mode::EXACT).algo, RowAlgo::Radix);
    // both run paths still produce exact results through their bucket's
    // algorithm
    let mut rng = Rng::seed_from(0xB0D);
    for rows in [8usize, 1500] {
        let x = RowMatrix::random_normal(rows, 300, &mut rng);
        assert!(is_exact(&x, &planner.run(&x, 10, Mode::EXACT)));
    }
}

#[test]
fn every_candidate_the_grid_can_choose_is_exact() {
    // The planner may pick any of these for an exact request; each one
    // must satisfy the exact-multiset contract independently, so no
    // calibration outcome can produce a wrong answer.
    let mut rng = Rng::seed_from(0xA11);
    for &(m, k) in &[(64usize, 8usize), (100, 25), (256, 32)] {
        let x = RowMatrix::random_normal(40, m, &mut rng);
        for algo in candidates(m, k, Mode::EXACT) {
            let res = rowwise_topk_with(&x, k, algo);
            assert!(is_exact(&x, &res), "algo {} at M={m} k={k}", algo.name());
        }
    }
}

#[test]
fn approximate_requests_never_switch_algorithm() {
    let planner = quick_planner();
    for it in [1u32, 4, 8] {
        let mode = Mode::EarlyStop { max_iter: it };
        let plan = planner.plan(40, 200, 20, mode);
        assert_eq!(plan.algo, RowAlgo::RTopK(mode));
    }
    let loose = Mode::Exact { eps_rel: 1e-3 };
    assert_eq!(planner.plan(40, 200, 20, loose).algo, RowAlgo::RTopK(loose));
}

#[test]
fn cache_roundtrips_through_disk() {
    let path = std::env::temp_dir().join("rtopk_planner_integration_cache.json");
    let _ = std::fs::remove_file(&path);
    let cfg = PlannerConfig {
        calib_rows: 32,
        calib_reps: 1,
        cache_path: Some(path.clone()),
        ..PlannerConfig::default()
    };
    let first = Planner::new(cfg.clone());
    let mut decided: Vec<(usize, usize, usize, Plan)> = Vec::new();
    // span two row buckets to prove the bucket dimension persists
    for &(rows, m, k) in
        &[(30usize, 64usize, 8usize), (30, 128, 32), (500, 128, 32)]
    {
        decided.push((rows, m, k, first.plan(rows, m, k, Mode::EXACT)));
    }
    first.save().unwrap();

    let second = Planner::new(cfg);
    for (rows, m, k, plan) in decided {
        let recalled = second.plan(rows, m, k, Mode::EXACT);
        assert_eq!(recalled.algo, plan.algo, "rows={rows} M={m} k={k}");
        assert_eq!(recalled.grain, plan.grain, "rows={rows} M={m} k={k}");
        assert_eq!(recalled.source, PlanSource::Cached);
        assert_eq!(recalled.probes, plan.probes, "raw timings persist");
        assert_eq!(recalled.runner_up, plan.runner_up, "runner-up persists");
    }
    // recalled plans still execute correctly
    let mut rng = Rng::seed_from(0xD15C);
    let x = RowMatrix::random_normal(30, 128, &mut rng);
    assert!(is_exact(&x, &second.run(&x, 32, Mode::EXACT)));
    let _ = std::fs::remove_file(&path);
}
