//! Shard health: ping/pong probes with quarantine-and-retry, the same
//! policy shape as the backend registry's accelerator quarantine
//! (`backend::registry::QUARANTINE_AFTER` consecutive failures bench a
//! shard; a later successful probe restores it).
//!
//! The prober runs on its own thread with its own short-lived
//! connections — probes must not queue behind a shard's submit FIFO,
//! and a wedged shard must time out without stalling the router loop.
//! The shard table is the cross-thread protocol state (prober writes,
//! router loop reads routing decisions off it), so it lives behind the
//! `util::sync` façade.

use crate::backend::registry::QUARANTINE_AFTER;
use crate::coordinator::wire::{encode_ping, Frame, FrameDecoder};
use crate::net::NetStats;
use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::Mutex;
use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::time::Duration;

/// One shard's live health, prober-maintained.
#[derive(Clone, Debug)]
pub struct ShardState {
    pub alive: bool,
    pub consecutive_failures: u32,
}

/// Health table for a fixed shard list. Shards start alive (optimistic:
/// traffic flows before the first probe lands; a dead shard's first
/// requests get positioned errors via the router's I/O failure path,
/// which quarantines immediately).
pub struct ShardTable {
    pub addrs: Vec<String>,
    states: Mutex<Vec<ShardState>>,
}

impl ShardTable {
    pub fn new(addrs: Vec<String>) -> ShardTable {
        let states = addrs
            .iter()
            .map(|_| ShardState { alive: true, consecutive_failures: 0 })
            .collect();
        ShardTable { addrs, states: Mutex::new(states) }
    }

    pub fn len(&self) -> usize {
        self.addrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.addrs.is_empty()
    }

    /// Per-shard liveness snapshot, index-aligned with `addrs`.
    pub fn alive(&self) -> Vec<bool> {
        self.states.lock().unwrap().iter().map(|s| s.alive).collect()
    }

    /// (alive, quarantined) counts for the gauges.
    pub fn counts(&self) -> (u64, u64) {
        let states = self.states.lock().unwrap();
        let alive = states.iter().filter(|s| s.alive).count() as u64;
        (alive, states.len() as u64 - alive)
    }

    /// Record a probe outcome. A success restores the shard on the
    /// spot; [`QUARANTINE_AFTER`] consecutive failures quarantine it.
    /// Returns the shard's post-update liveness.
    pub fn note_probe(&self, idx: usize, ok: bool) -> bool {
        let mut states = self.states.lock().unwrap();
        let s = &mut states[idx];
        if ok {
            s.consecutive_failures = 0;
            s.alive = true;
        } else {
            s.consecutive_failures = s.consecutive_failures.saturating_add(1);
            if s.consecutive_failures >= QUARANTINE_AFTER {
                s.alive = false;
            }
        }
        s.alive
    }

    /// The router observed a hard I/O failure (connect refused, reset,
    /// protocol violation) — quarantine immediately rather than waiting
    /// for [`QUARANTINE_AFTER`] probes to notice. The prober's next
    /// successful ping restores the shard.
    pub fn mark_dead(&self, idx: usize) {
        let mut states = self.states.lock().unwrap();
        let s = &mut states[idx];
        s.alive = false;
        s.consecutive_failures = s.consecutive_failures.max(QUARANTINE_AFTER);
    }
}

/// One synchronous ping probe: connect, send, await the matching pong.
/// Every step is bounded by `timeout`.
pub fn probe(addr: &str, timeout: Duration, nonce: u64) -> bool {
    let sockaddr = match addr.to_socket_addrs().ok().and_then(|mut a| a.next())
    {
        Some(a) => a,
        None => return false,
    };
    let mut stream = match TcpStream::connect_timeout(&sockaddr, timeout) {
        Ok(s) => s,
        Err(_) => return false,
    };
    if stream.set_read_timeout(Some(timeout)).is_err()
        || stream.set_write_timeout(Some(timeout)).is_err()
    {
        return false;
    }
    if stream.write_all(&encode_ping(nonce)).is_err() {
        return false;
    }
    let mut dec = FrameDecoder::new();
    let mut chunk = [0u8; 1024];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) | Err(_) => return false,
            Ok(n) => {
                dec.feed(&chunk[..n]);
                match dec.next() {
                    Ok(Some(Frame::Pong(n))) => return n == nonce,
                    Ok(Some(_)) => return false,
                    Ok(None) => continue,
                    Err(_) => return false,
                }
            }
        }
    }
}

/// Spawn the prober thread: every `cadence`, ping every shard, update
/// the table and the shard-health gauges. Stops when `stop` flips.
pub fn spawn_prober(
    table: Arc<ShardTable>,
    stats: Arc<NetStats>,
    cadence: Duration,
    timeout: Duration,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("rtopk-health".to_string())
        .spawn(move || {
            let mut nonce: u64 = 0;
            // publish the optimistic initial state before first sleep
            let (alive, quarantined) = table.counts();
            stats.set_shard_health(alive, quarantined);
            while !stop.load(Ordering::Acquire) {
                for idx in 0..table.len() {
                    nonce = nonce.wrapping_add(1);
                    let ok = probe(&table.addrs[idx], timeout, nonce);
                    table.note_probe(idx, ok);
                }
                let (alive, quarantined) = table.counts();
                stats.set_shard_health(alive, quarantined);
                std::thread::sleep(cadence);
            }
        })
        .expect("spawn health prober")
}

#[cfg(all(test, not(rtopk_model_check)))]
mod tests {
    use super::*;

    #[test]
    fn quarantine_after_consecutive_failures_and_restore_on_success() {
        let t = ShardTable::new(vec!["a:1".into(), "b:2".into()]);
        assert_eq!(t.alive(), vec![true, true]);
        for i in 1..=QUARANTINE_AFTER {
            let alive = t.note_probe(0, false);
            assert_eq!(alive, i < QUARANTINE_AFTER, "failure #{i}");
        }
        assert_eq!(t.alive(), vec![false, true]);
        assert_eq!(t.counts(), (1, 1));
        // one intervening success resets the streak
        assert!(t.note_probe(0, true));
        assert_eq!(t.counts(), (2, 0));
        // a single failure after restore does not re-quarantine
        assert!(t.note_probe(0, false));
    }

    #[test]
    fn mark_dead_quarantines_immediately() {
        let t = ShardTable::new(vec!["a:1".into()]);
        t.mark_dead(0);
        assert_eq!(t.alive(), vec![false]);
        // restore still works via a successful probe
        assert!(t.note_probe(0, true));
    }

    #[test]
    fn probe_fails_cleanly_on_unresolvable_and_refused_addresses() {
        assert!(!probe("not an address", Duration::from_millis(50), 1));
        // a port nothing listens on: refused (or timed out), not hung
        assert!(!probe("127.0.0.1:1", Duration::from_millis(200), 2));
    }
}
