//! Compressed-sparse-row graph storage (destination-indexed: `indptr[d]`
//! ranges over the in-edges of node d, matching the aggregation
//! direction of the GNN models).

/// CSR adjacency with per-edge f32 weights.
#[derive(Clone, Debug)]
pub struct CsrGraph {
    pub num_nodes: usize,
    /// len = num_nodes + 1
    pub indptr: Vec<u32>,
    /// len = num_edges; source node of each in-edge
    pub indices: Vec<u32>,
    /// len = num_edges; aggregation weight of each in-edge
    pub weights: Vec<f32>,
}

impl CsrGraph {
    /// Build from an edge list (src, dst, w), bucketing by destination.
    pub fn from_edges(num_nodes: usize, src: &[u32], dst: &[u32],
                      w: &[f32]) -> Self {
        assert_eq!(src.len(), dst.len());
        assert_eq!(src.len(), w.len());
        let mut indptr = vec![0u32; num_nodes + 1];
        for &d in dst {
            indptr[d as usize + 1] += 1;
        }
        for i in 0..num_nodes {
            indptr[i + 1] += indptr[i];
        }
        let ne = src.len();
        let mut indices = vec![0u32; ne];
        let mut weights = vec![0f32; ne];
        let mut cursor = indptr.clone();
        for e in 0..ne {
            let d = dst[e] as usize;
            let slot = cursor[d] as usize;
            indices[slot] = src[e];
            weights[slot] = w[e];
            cursor[d] += 1;
        }
        CsrGraph { num_nodes, indptr, indices, weights }
    }

    pub fn num_edges(&self) -> usize {
        self.indices.len()
    }

    /// In-degree of node d.
    pub fn degree(&self, d: usize) -> usize {
        (self.indptr[d + 1] - self.indptr[d]) as usize
    }

    /// (sources, weights) of node d's in-edges.
    pub fn in_edges(&self, d: usize) -> (&[u32], &[f32]) {
        let a = self.indptr[d] as usize;
        let b = self.indptr[d + 1] as usize;
        (&self.indices[a..b], &self.weights[a..b])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_from_edge_list() {
        // edges: 0->1, 2->1, 1->0
        let g = CsrGraph::from_edges(3, &[0, 2, 1], &[1, 1, 0],
                                     &[0.5, 0.25, 1.0]);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.degree(2), 0);
        let (src, w) = g.in_edges(1);
        let mut pairs: Vec<_> = src.iter().zip(w.iter()).collect();
        pairs.sort_by_key(|(s, _)| **s);
        assert_eq!(*pairs[0].0, 0);
        assert_eq!(*pairs[0].1, 0.5);
        assert_eq!(*pairs[1].0, 2);
        assert_eq!(*pairs[1].1, 0.25);
    }

    #[test]
    fn empty_graph() {
        let g = CsrGraph::from_edges(2, &[], &[], &[]);
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.degree(0), 0);
    }
}
