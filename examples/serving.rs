//! Serving demo: concurrent clients push row-wise top-k requests of
//! mixed shapes through the TopKService; reports throughput and
//! latency percentiles — the paper's "row-wise top-k as a service for
//! GNN training" scenario under load.
//!
//!   cargo run --release --example serving
//!   RTOPK_CLIENTS=8 RTOPK_REQS=40 cargo run --release --example serving

use rtopk::config::ServeConfig;
use rtopk::coordinator::{Priority, SubmitRequest, TopKService};
use rtopk::topk::types::Mode;
use rtopk::util::matrix::RowMatrix;
use rtopk::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let clients: usize = std::env::var("RTOPK_CLIENTS")
        .ok().and_then(|s| s.parse().ok()).unwrap_or(4);
    let reqs: usize = std::env::var("RTOPK_REQS")
        .ok().and_then(|s| s.parse().ok()).unwrap_or(25);

    let cfg = ServeConfig { workers: 2, ..Default::default() };
    let svc = if std::path::Path::new("artifacts/manifest.json").exists() {
        TopKService::start(&cfg)?
    } else {
        println!("(artifacts missing; CPU-only service)");
        TopKService::cpu_only(&cfg)?
    };
    let svc = Arc::new(svc);
    println!("service up; {clients} clients x {reqs} requests each");

    let t0 = Instant::now();
    let mut total_rows = 0usize;
    let threads: Vec<_> = (0..clients)
        .map(|c| {
            let svc = svc.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::seed_from(1000 + c as u64);
                let mut rows = 0usize;
                for i in 0..reqs {
                    // mixed workload: mostly the routed (256, 32) shape,
                    // some odd shapes that exercise the CPU fallback
                    let (n, m, k, mode) = if i % 5 == 4 {
                        (200 + rng.index(200), 100, 10, Mode::EXACT)
                    } else {
                        (512 + rng.index(1024), 256, 32,
                         Mode::EarlyStop { max_iter: 4 })
                    };
                    let x = RowMatrix::random_normal(n, m, &mut rng);
                    rows += n;
                    // odd-one-out clients showcase the typed knobs: a
                    // high drain priority plus a generous end-to-end
                    // deadline (never binding at this load)
                    let mut req = SubmitRequest::new(x, k).mode(mode);
                    if c == 0 {
                        req = req
                            .priority(Priority::High)
                            .deadline(std::time::Duration::from_secs(30));
                    }
                    svc.submit(req).expect("request failed");
                }
                rows
            })
        })
        .collect();
    for t in threads {
        total_rows += t.join().unwrap();
    }
    let dt = t0.elapsed();
    let s = svc.stats();
    println!(
        "\n{} requests / {total_rows} rows in {:.2}s -> {:.2} Mrows/s",
        s.requests,
        dt.as_secs_f64(),
        total_rows as f64 / dt.as_secs_f64() / 1e6
    );
    println!(
        "latency us: p50={:.0} p95={:.0} p99={:.0} max={:.0}",
        s.p50_us, s.p95_us, s.p99_us, s.max_us
    );
    println!(
        "batches: {} total ({} pjrt, {} cpu), errors {}",
        s.batches, s.pjrt_batches, s.cpu_batches, s.errors
    );
    Ok(())
}
