"""Build-time Python: L1 Pallas kernels + L2 JAX models + AOT lowering.

Never imported on the request path — `make artifacts` runs this once and
the Rust binary is self-contained afterwards.
"""
