//! Core library: row-wise top-k selection.
//!
//! * [`binary_search`] — the paper's contribution (Algorithm 1 exact /
//!   Algorithm 2 early-stopping), single-row primitives that mirror the
//!   Pallas kernel and the pure-jnp oracle decision-for-decision.
//! * [`rowwise`] — the batched driver that applies any row selector to
//!   an (N, M) matrix in parallel (the "kernel launch" equivalent);
//!   [`rowwise::rowwise_topk_auto`] routes through the adaptive
//!   execution planner ([`crate::plan`]) instead of hardwiring one
//!   algorithm.
//! * [`baselines`] — the algorithms the paper compares against or
//!   discusses: RadixSelect (PyTorch's `torch.topk` underlying method),
//!   QuickSelect, heap, bucket select, bitonic top-k, and full sort.
//! * [`approx`] — recall-contracted two-stage bucketed selection behind
//!   `Mode::Approx` (binomial (B, k') derivation + empirical
//!   calibration table).
//! * [`verify`] — oracle comparisons: exact-set equality, hit rate and
//!   relative-error metrics (Table 2's E1/E2/Hit), and the shared
//!   recall harness (oracle, seeded distributions, statistical gate).

pub mod approx;
pub mod baselines;
pub mod binary_search;
pub mod rowwise;
pub mod types;
pub mod verify;

pub use binary_search::{rtopk_row, search_early_stop, search_exact, select_row, SearchOut};
pub use rowwise::{rowwise_topk, rowwise_topk_auto, rowwise_topk_with, RowAlgo};
pub use types::{Mode, TopKResult};
