//! Declarative CLI argument parser (clap is not in the vendored crate
//! set). Supports subcommands, `--flag value`, `--flag=value`, boolean
//! switches, defaults, and generated help text.

use std::collections::BTreeMap;

/// One declared option.
#[derive(Clone, Debug)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_switch: bool,
}

/// A parsed argument set.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String> {
        match self.values.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| format!("invalid value {v:?} for --{name}")),
        }
    }

    /// Parsed value or the declared default (panics if neither exists —
    /// a spec bug, not a user error).
    pub fn req<T: std::str::FromStr>(&self, name: &str) -> Result<T, String> {
        let v = self
            .values
            .get(name)
            .ok_or_else(|| format!("missing required --{name}"))?;
        v.parse::<T>()
            .map_err(|_| format!("invalid value {v:?} for --{name}"))
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

/// A subcommand spec.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<Opt>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &str,
               help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            default: Some(default.to_string()),
            is_switch: false,
        });
        self
    }

    pub fn opt_req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_switch: false });
        self
    }

    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_switch: true });
        self
    }

    /// Parse this command's arguments (after the subcommand token).
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        // seed defaults
        for o in &self.opts {
            if let Some(d) = &o.default {
                args.values.insert(o.name.to_string(), d.clone());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name} for '{}'", self.name))?;
                if spec.is_switch {
                    if inline.is_some() {
                        return Err(format!("--{name} takes no value"));
                    }
                    args.switches.push(name.to_string());
                } else {
                    let val = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} needs a value"))?
                        }
                    };
                    args.values.insert(name.to_string(), val);
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    pub fn help(&self) -> String {
        let mut s = format!("  {:12} {}\n", self.name, self.about);
        for o in &self.opts {
            let d = match (&o.default, o.is_switch) {
                (_, true) => " (switch)".to_string(),
                (Some(d), _) => format!(" [default: {d}]"),
                (None, _) => " (required)".to_string(),
            };
            s.push_str(&format!("      --{:18} {}{}\n", o.name, o.help, d));
        }
        s
    }
}

/// Top-level dispatcher.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

impl App {
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE: {} <command> [options]\n\nCOMMANDS:\n",
                            self.name, self.about, self.name);
        for c in &self.commands {
            s.push_str(&c.help());
        }
        s
    }

    /// Split argv into (command, its args). Returns Err(help) on
    /// missing/unknown commands and for -h/--help.
    pub fn dispatch<'a>(&'a self, argv: &[String])
        -> Result<(&'a Command, Args), String> {
        let Some(cmd_name) = argv.first() else {
            return Err(self.help());
        };
        if cmd_name == "-h" || cmd_name == "--help" || cmd_name == "help" {
            return Err(self.help());
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| format!("unknown command {cmd_name:?}\n\n{}", self.help()))?;
        let rest = &argv[1..];
        if rest.iter().any(|a| a == "-h" || a == "--help") {
            return Err(cmd.help());
        }
        let args = cmd.parse(rest)?;
        Ok((cmd, args))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("topk", "run top-k")
            .opt("rows", "1024", "row count")
            .opt("mode", "exact", "search mode")
            .opt_req("k", "k value")
            .switch("verbose", "print more")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cmd().parse(&sv(&["--k", "32", "--rows=2048"])).unwrap();
        assert_eq!(a.req::<usize>("rows").unwrap(), 2048);
        assert_eq!(a.req::<usize>("k").unwrap(), 32);
        assert_eq!(a.get("mode"), Some("exact"));
        assert!(!a.switch("verbose"));
    }

    #[test]
    fn switches_and_positional() {
        let a = cmd().parse(&sv(&["--verbose", "--k=1", "file.txt"])).unwrap();
        assert!(a.switch("verbose"));
        assert_eq!(a.positional, vec!["file.txt"]);
    }

    #[test]
    fn errors() {
        assert!(cmd().parse(&sv(&["--nope", "1"])).is_err());
        assert!(cmd().parse(&sv(&["--rows"])).is_err());
        assert!(cmd().parse(&sv(&["--verbose=1"])).is_err());
        let a = cmd().parse(&sv(&[])).unwrap();
        assert!(a.req::<usize>("k").is_err()); // required missing
        let b = cmd().parse(&sv(&["--k", "abc"])).unwrap();
        assert!(b.req::<usize>("k").is_err()); // unparseable
    }

    #[test]
    fn app_dispatch() {
        let app = App {
            name: "rtopk",
            about: "test",
            commands: vec![cmd()],
        };
        let (c, a) = app.dispatch(&sv(&["topk", "--k", "4"])).unwrap();
        assert_eq!(c.name, "topk");
        assert_eq!(a.req::<usize>("k").unwrap(), 4);
        assert!(app.dispatch(&sv(&["bogus"])).is_err());
        assert!(app.dispatch(&sv(&[])).is_err());
        assert!(app.dispatch(&sv(&["topk", "--help"])).is_err());
    }
}
