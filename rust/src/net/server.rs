//! `rtopk listen`: the readiness-loop TCP server feeding the in-process
//! [`TopKService`].
//!
//! One thread owns everything: the listener, every accepted
//! connection's state machine, and the reactor. Request execution is
//! the service's worker pool; the loop only shuttles bytes, so a 1 ms
//! reactor tick bounds the added reply latency. Per-connection
//! interest follows the state machine: READ drops while a buffer is at
//! its cap (backpressure), WRITE is registered only while the write
//! buffer holds bytes (level-triggered POLLOUT would otherwise spin
//! the loop hot).

use crate::config::NetConfig;
use crate::coordinator::wire::ERR_OVERLOAD;
use crate::coordinator::TopKService;
use crate::net::conn::{ConnLimits, Connection};
use crate::net::reactor::{new_reactor, os_handle, Event, READ, WRITE};
use crate::net::{error_frame_bytes, NetStats};
use crate::util::sync::atomic::{AtomicBool, Ordering};
use std::collections::HashMap;
use std::io::{self, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

/// Reactor tick: the loop wakes at least this often to pump completed
/// tickets toward their sockets and to observe the shutdown flag.
const TICK: Duration = Duration::from_millis(1);

const LISTENER_TOKEN: usize = 0;

/// A running server. Dropping the handle leaks the loop thread;
/// call [`ServerHandle::shutdown`] for an orderly stop (tests and the
/// bench also use it as an abrupt "kill this worker": connections are
/// dropped, not drained).
pub struct ServerHandle {
    addr: SocketAddr,
    stats: Arc<NetStats>,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound address (resolves the port when `[net] bind` used 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> Arc<NetStats> {
        self.stats.clone()
    }

    /// Stop the loop and join its thread. In-flight requests are
    /// cancelled via the connection drop path — from a client's view
    /// this is indistinguishable from a killed worker process.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }

    /// Block the calling thread for the server's lifetime (the CLI
    /// foreground path).
    pub fn join(mut self) {
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Bind, register the net probe on the service's telemetry hub, and
/// spawn the socket loop.
pub fn serve(
    svc: Arc<TopKService>,
    cfg: &NetConfig,
) -> io::Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.bind)?;
    listener.set_nonblocking(true)?;
    let addr = listener.local_addr()?;
    let stats = Arc::new(NetStats::default());
    svc.metrics().set_net_probe(stats.clone());
    let stop = Arc::new(AtomicBool::new(false));
    let loop_stats = stats.clone();
    let loop_stop = stop.clone();
    let cfg = cfg.clone();
    let thread = std::thread::Builder::new()
        .name("rtopk-net".to_string())
        .spawn(move || socket_loop(listener, svc, cfg, loop_stats, loop_stop))?;
    Ok(ServerHandle { addr, stats, stop, thread: Some(thread) })
}

/// One accepted connection as the loop tracks it.
struct Tracked {
    stream: TcpStream,
    conn: Connection,
    /// interest currently registered with the reactor
    interest: u8,
}

fn socket_loop(
    listener: TcpListener,
    svc: Arc<TopKService>,
    cfg: NetConfig,
    stats: Arc<NetStats>,
    stop: Arc<AtomicBool>,
) {
    let limits = ConnLimits {
        read_buf_bytes: cfg.read_buf_bytes.max(1),
        write_buf_bytes: cfg.write_buf_bytes.max(1),
        max_inflight: cfg.max_inflight_per_conn.max(1),
    };
    let mut reactor = new_reactor();
    if reactor
        .register(os_handle(&listener), LISTENER_TOKEN, READ)
        .is_err()
    {
        return;
    }
    let mut conns: HashMap<usize, Tracked> = HashMap::new();
    let mut next_token = LISTENER_TOKEN + 1;
    let mut events: Vec<Event> = Vec::new();

    while !stop.load(Ordering::Acquire) {
        if reactor.wait(TICK, &mut events).is_err() {
            break;
        }
        for ev in &events {
            if ev.token == LISTENER_TOKEN {
                accept_ready(
                    &listener, &svc, &stats, limits, &cfg, &mut conns,
                    &mut next_token, reactor.as_mut(),
                );
            } else if let Some(t) = conns.get_mut(&ev.token) {
                if ev.readable {
                    t.conn.on_readable(&mut t.stream);
                }
                if ev.writable {
                    t.conn.on_writable(&mut t.stream);
                }
            }
        }
        // every tick, every connection: tickets resolve on worker
        // threads, not on socket readiness, so pumping cannot wait for
        // an event
        let mut finished: Vec<usize> = Vec::new();
        for (&token, t) in conns.iter_mut() {
            t.conn.pump();
            if t.conn.wants_write() {
                // opportunistic flush: most replies fit the socket
                // buffer, no need to wait a tick for POLLOUT
                t.conn.on_writable(&mut t.stream);
            }
            if t.conn.finished() {
                finished.push(token);
                continue;
            }
            let want = (if t.conn.wants_read() { READ } else { 0 })
                | (if t.conn.wants_write() { WRITE } else { 0 });
            if want != t.interest {
                if reactor
                    .reregister(os_handle(&t.stream), token, want)
                    .is_ok()
                {
                    t.interest = want;
                }
            }
        }
        for token in finished {
            if let Some(t) = conns.remove(&token) {
                let _ = reactor.deregister(os_handle(&t.stream));
                stats.conn_closed();
                // dropping Tracked closes the socket and (via the
                // Connection drop) cancels anything still in flight
            }
        }
    }
    // loop exit: deregister and drop everything; Connection::drop
    // cancels remaining tickets so the service never waits on us
    for (_, t) in conns.drain() {
        let _ = reactor.deregister(os_handle(&t.stream));
        stats.conn_closed();
    }
    let _ = reactor.deregister(os_handle(&listener));
}

#[allow(clippy::too_many_arguments)]
fn accept_ready(
    listener: &TcpListener,
    svc: &Arc<TopKService>,
    stats: &Arc<NetStats>,
    limits: ConnLimits,
    cfg: &NetConfig,
    conns: &mut HashMap<usize, Tracked>,
    next_token: &mut usize,
    reactor: &mut dyn crate::net::reactor::Reactor,
) {
    loop {
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                if conns.len() >= cfg.max_connections.max(1) {
                    // one best-effort overload frame, then close: an
                    // answered refusal beats a silent RST
                    let bytes = error_frame_bytes(
                        ERR_OVERLOAD,
                        &format!(
                            "server at max_connections ({})",
                            cfg.max_connections
                        ),
                    );
                    let _ = stream.write_all(&bytes);
                    continue;
                }
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                let _ = stream.set_nodelay(true);
                let token = *next_token;
                *next_token += 1;
                if reactor.register(os_handle(&stream), token, READ).is_err() {
                    continue;
                }
                conns.insert(token, Tracked {
                    stream,
                    conn: Connection::new(svc.clone(), stats.clone(), limits),
                    interest: READ,
                });
                stats.conn_opened();
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => break,
        }
    }
}
