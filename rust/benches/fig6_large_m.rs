//! Figure 6 (Appendix B): RTop-K speed-up vs RadixSelect as the vector
//! size M grows to 8192 — the crossover analysis. Averaged over
//! k in {64, 128, 256, 512} with k < M, N = 65536 (paper's setting;
//! reduced when RTOPK_QUICK=1).
//!
//! Both views printed: measured CPU wall time and the A6000 simulator
//! (the simulator exhibits the paper's crossover where torch.topk's
//! block-per-row amortization catches up).

use rtopk::bench::{time_algo, workload, Table};
use rtopk::simt::{kernel_time_ms, simulate_radix_row, simulate_rtopk_row, CostModel};
use rtopk::stats::expected_iterations;
use rtopk::topk::rowwise::RowAlgo;
use rtopk::topk::types::Mode;

fn main() {
    let quick = std::env::var("RTOPK_QUICK").is_ok();
    let n = if quick { 1 << 12 } else { 1 << 14 };
    let ms = [256usize, 512, 1024, 2048, 3072, 4096, 6144, 8192];
    let ks = [64usize, 128, 256, 512];

    let mut t = Table::new(
        &format!("Fig 6: no-ES speed-up vs RadixSelect by M (N={n}, k avg over {ks:?}, k<M)"),
        &["M", "measured CPU", "A6000 simulator"],
    );
    let c = CostModel::A6000;
    for &m in &ms {
        let valid: Vec<usize> = ks.iter().cloned().filter(|&k| k < m).collect();
        let mut cpu_acc = 0.0;
        let mut sim_acc = 0.0;
        for &k in &valid {
            let x = workload(n, m, 0xF160 + (m + k) as u64);
            let base = time_algo(&x, k, RowAlgo::Radix).median_us();
            let ours = time_algo(&x, k, RowAlgo::RTopK(Mode::EXACT)).median_us();
            cpu_acc += base / ours;

            let e_it = expected_iterations(m, k);
            let sim_r = kernel_time_ms(n, &simulate_rtopk_row(m, k, e_it, &c),
                                       CostModel::A6000_SMS, CostModel::A6000_CLOCK_GHZ);
            let sim_b = kernel_time_ms(n, &simulate_radix_row(m, k, &c),
                                       CostModel::A6000_SMS, CostModel::A6000_CLOCK_GHZ);
            sim_acc += sim_b / sim_r;
        }
        t.row(vec![
            m.to_string(),
            format!("{:.2}x", cpu_acc / valid.len() as f64),
            format!("{:.2}x", sim_acc / valid.len() as f64),
        ]);
    }
    t.print();
    println!("\npaper (Fig 6): 4.9-12.5x below M=1280; 2.3-4.9x to 3072; 1.1-2.3x to 6144;\n\
              slower than PyTorch beyond ~6144.");
}
