//! Table 1: cumulative percentage of exit iterations for Algorithm 1
//! (eps = 1e-4, M = 256, k in {16, 32, 64, 96, 128}, 1e5 trials each).
//!
//!   cargo bench --bench table1_exit_iters          (paper-scale trials)
//!   RTOPK_QUICK=1 cargo bench --bench table1_exit_iters   (1e4 trials)

use rtopk::bench::{exit_iteration_histogram, Table};

fn main() {
    let quick = std::env::var("RTOPK_QUICK").is_ok();
    let trials = if quick { 10_000 } else { 30_000 };
    let m = 256;
    let ks = [16usize, 32, 64, 96, 128];
    let eps = 1e-4f32;

    let hists: Vec<_> = ks
        .iter()
        .map(|&k| exit_iteration_histogram(m, k, eps, trials, 0x7AB1E1 + k as u64))
        .collect();

    let mut t = Table::new(
        &format!("Table 1: cumulative % of exit iterations (eps=1e-4, M={m}, {trials} trials)"),
        &["Iteration", "k=16", "k=32", "k=64", "k=96", "k=128"],
    );
    for it in 3..=16 {
        let mut row = vec![it.to_string()];
        for h in &hists {
            row.push(format!("{:.2}%", h.cdf_at(it) * 100.0));
        }
        t.row(row);
    }
    let mut avg = vec!["Average Exit".to_string()];
    for h in &hists {
        avg.push(format!("{:.2}", h.mean()));
    }
    t.row(avg);
    t.print();
    println!("\npaper (Table 1) average exit: k=16: 7.60  k=32: 8.29  k=64: 8.95  k=96: 9.52  k=128: 9.60");
}
