"""Pure-jnp reference oracles for the RTop-K kernels.

This module is the single source of truth for the *semantics* of the
binary-search row-wise top-k (Algorithm 1 / Algorithm 2 of the paper).
The Pallas kernel (`rtopk.py`) and the Rust implementation
(`rust/src/topk/binary_search.rs`) must match these functions decision-
for-decision in f32 arithmetic:

  * the bracket update uses ``thres = 0.5 * (lo + hi)`` in float32,
  * the count predicate is ``v >= thres``,
  * exact mode (Algorithm 1): while ``hi - lo > eps`` with
    ``eps = eps_rel * max(v)`` when ``max(v) > 0`` (the paper's line 3,
    verbatim on its assumed positive-activation domain) and
    ``eps = eps_rel * max(|max(v)|, |min(v)|)`` otherwise — the paper's
    formula goes negative/zero for non-positive maxima and would
    disable the bracket-width exit; break when ``cnt == k``; selection
    takes
    the first-k-by-index elements ``>= T1`` and, if fewer than k,
    supplements with the first elements in ``[T2, T1)``, where
    ``(T1, T2) = (thres, thres)`` on a ``cnt == k`` exit and
    ``(hi, lo)`` on a bracket exit (see exact_selection_thresholds),
  * early-stop mode (Algorithm 2): exactly ``max_iter`` iterations with
    ``cnt < k -> hi = thres`` else ``lo = thres``; selection takes the
    first k elements ``>= lo`` (the final min), one pass.

Both selections are expressed here through one unified two-mask ranking,
which is exactly what the kernel implements (see `rtopk.py`):

  rank(j) = cumsum(v >= thres)[j]                  if v[j] >= thres
          = cnt1 + cumsum(lo <= v < thres)[j]      otherwise
  selected(j) = rank(j) <= k

For early stop we pass ``thres = lo`` so the second mask is empty.

Everything here is plain jax.numpy on full arrays (no pallas), so it
runs anywhere and is independently testable against ``jax.lax.top_k``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# Iteration cap for exact mode. The paper's Table 5 shows exits beyond 24
# iterations are vanishingly rare even for M=8192, eps=0; 64 is a safe cap
# for float32 brackets (the bracket is halved each step, so 64 halvings
# exhaust f32 resolution from any initial range).
EXACT_ITER_CAP = 64


class SearchState(NamedTuple):
    """Final state of the binary-search phase for a batch of rows."""

    lo: jax.Array  # (N,) final lower bracket ("min" in the paper)
    hi: jax.Array  # (N,) final upper bracket ("max" in the paper)
    thres: jax.Array  # (N,) last threshold evaluated
    cnt: jax.Array  # (N,) count of v >= thres at the last evaluation
    iters: jax.Array  # (N,) number of loop iterations executed (int32)


def search_exact(x: jax.Array, k: int, eps_rel: float,
                 iter_cap: int = EXACT_ITER_CAP) -> SearchState:
    """Algorithm 1's search loop, vectorized over rows.

    Per row: ``eps = eps_rel * max(v)`` when the max is positive, else
    ``eps_rel * max(|max(v)|, |min(v)|)`` (non-negative for any row;
    see the module docstring); loop while ``hi - lo > eps``, computing
    ``thres = (lo+hi)/2`` and ``cnt = |{v >= thres}|``; narrow the
    bracket toward cnt == k and stop early when it hits.

    Rows converge independently (a converged row's state is frozen), which
    mirrors the per-warp divergent exits of the CUDA kernel.
    """
    xf = x.astype(jnp.float32)
    n, m = xf.shape
    lo0 = jnp.min(xf, axis=1)
    hi0 = jnp.max(xf, axis=1)
    # paper line 3 (eps' * max) verbatim where it is well-defined; for
    # non-positive maxima it would be negative/zero and the width exit
    # could never fire, so fall back to the bracket magnitude there.
    eps = jnp.float32(eps_rel) * jnp.where(
        hi0 > 0, hi0, jnp.maximum(jnp.abs(hi0), jnp.abs(lo0))
    )
    kf = jnp.int32(k)

    def body(_, st):
        lo, hi, thres, cnt, iters = st
        active = jnp.logical_and(hi - lo > eps, cnt != kf)
        t_new = jnp.where(active, jnp.float32(0.5) * (lo + hi), thres)
        c_new = jnp.where(
            active,
            jnp.sum((xf >= t_new[:, None]).astype(jnp.int32), axis=1),
            cnt,
        )
        hi_new = jnp.where(jnp.logical_and(active, c_new < kf), t_new, hi)
        lo_new = jnp.where(jnp.logical_and(active, c_new > kf), t_new, lo)
        it_new = iters + active.astype(jnp.int32)
        return lo_new, hi_new, t_new, c_new, it_new

    # thres starts at lo (count at lo is M by definition); if the loop never
    # runs (degenerate all-equal row) selection sees thres = lo and picks the
    # first k elements, which is the only sensible answer for an all-tie row.
    st0 = (
        lo0,
        hi0,
        lo0,
        jnp.full((n,), m, jnp.int32),
        jnp.zeros((n,), jnp.int32),
    )
    lo, hi, thres, cnt, iters = jax.lax.fori_loop(0, iter_cap, body, st0)
    return SearchState(lo, hi, thres, cnt, iters)


def search_early_stop(x: jax.Array, k: int, max_iter: int) -> SearchState:
    """Algorithm 2's search loop: exactly ``max_iter`` iterations.

    Update rule (paper lines 6-10): ``cnt < k -> hi = thres``, else
    ``lo = thres`` (the >= k branch folds the == case into moving lo).
    """
    xf = x.astype(jnp.float32)
    n, m = xf.shape
    lo0 = jnp.min(xf, axis=1)
    hi0 = jnp.max(xf, axis=1)
    kf = jnp.int32(k)

    def body(_, st):
        lo, hi, _, _ = st
        thres = jnp.float32(0.5) * (lo + hi)
        cnt = jnp.sum((xf >= thres[:, None]).astype(jnp.int32), axis=1)
        hi_new = jnp.where(cnt < kf, thres, hi)
        lo_new = jnp.where(cnt >= kf, thres, lo)
        return lo_new, hi_new, thres, cnt

    st0 = (lo0, hi0, lo0, jnp.full((n,), m, jnp.int32))
    lo, hi, thres, cnt = jax.lax.fori_loop(0, max_iter, body, st0)
    return SearchState(lo, hi, thres, cnt,
                       jnp.full((n,), max_iter, jnp.int32))


def select(x: jax.Array, k: int, thres: jax.Array,
           lo: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Unified two-mask selection (paper's Selecting Stage).

    Primary mask: ``v >= thres`` (first-k by index).  Secondary mask:
    ``lo <= v < thres`` supplements when the primary yields fewer than k.
    Pass ``thres = lo`` for early-stop mode (secondary mask empty, one
    pass over ``v >= min`` exactly as Algorithm 2 line 12).

    Returns ``(values (N,k), indices (N,k) int32, mask (N,M) bool)``.
    The invariant ``|{v >= lo}| >= k`` holds for both search modes (lo only
    ever moves to a threshold whose count was >= k), so exactly k elements
    are always selected.
    """
    xf = x.astype(jnp.float32)
    n, m = xf.shape
    t = thres[:, None]
    l = lo[:, None]
    m1 = xf >= t
    m2 = jnp.logical_and(xf >= l, xf < t)
    c1 = jnp.sum(m1.astype(jnp.int32), axis=1, keepdims=True)
    r1 = jnp.cumsum(m1.astype(jnp.int32), axis=1)
    r2 = c1 + jnp.cumsum(m2.astype(jnp.int32), axis=1)
    big = jnp.int32(2 * m + 2)
    rank = jnp.where(m1, r1, jnp.where(m2, r2, big))
    sel = rank <= k

    # Compact the <=k selected entries into dense (N, k) outputs with a
    # one-hot contraction (sort-free, matches the kernel's MXU-friendly
    # compaction; see DESIGN.md §5).
    slot = jnp.where(sel, rank - 1, big)  # in [0, k) for selected
    onehot = (slot[:, :, None] == jnp.arange(k, dtype=jnp.int32)).astype(
        jnp.float32
    )
    vals = jnp.einsum("nm,nmk->nk", xf, onehot)
    cols = jnp.arange(m, dtype=jnp.float32)[None, :]
    idx = jnp.einsum("nm,nmk->nk", jnp.broadcast_to(cols, (n, m)), onehot)
    return vals.astype(x.dtype), idx.astype(jnp.int32), sel


def exact_selection_thresholds(st: SearchState, k: int):
    """Selection thresholds for Algorithm 1's two exit paths.

    * ``cnt == k`` exit: ``thres`` separates exactly the top-k — use it
      for both masks.
    * bracket exit (``hi - lo <= eps``): the last midpoint can land
      exactly *on* a tie value, in which case ``{v >= thres}`` truncated
      by index would return the wrong multiset. The borderline elements
      are precisely those in ``[lo, hi)`` (the paper's "located between
      min and thres"), so select the certain winners with ``hi`` and
      supplement from ``[lo, hi)``. With a tiny eps the bracket is 1 ulp
      wide, making this exact; with a loose eps it is the paper's
      intended controlled approximation.
    """
    exact_exit = st.cnt == jnp.int32(k)
    t1 = jnp.where(exact_exit, st.thres, st.hi)
    t2 = jnp.where(exact_exit, st.thres, st.lo)
    return t1, t2


def rtopk_exact(x: jax.Array, k: int, eps_rel: float = 1e-16,
                iter_cap: int = EXACT_ITER_CAP):
    """Algorithm 1 end-to-end: search + two-mask selection."""
    st = search_exact(x, k, eps_rel, iter_cap)
    t1, t2 = exact_selection_thresholds(st, k)
    return select(x, k, t1, t2)


def rtopk_early_stop(x: jax.Array, k: int, max_iter: int):
    """Algorithm 2 end-to-end: fixed-iteration search + one-pass selection."""
    st = search_early_stop(x, k, max_iter)
    return select(x, k, st.lo, st.lo)


def rtopk_ref(x: jax.Array, k: int, *, mode: str = "exact",
              eps_rel: float = 1e-16, max_iter: int = 8):
    """Dispatch helper mirroring the Pallas kernel's signature."""
    if mode == "exact":
        return rtopk_exact(x, k, eps_rel)
    if mode == "early_stop":
        return rtopk_early_stop(x, k, max_iter)
    raise ValueError(f"unknown mode {mode!r}")


# ---------------------------------------------------------------------------
# Independent ground truth + metrics (used by tests and Table 2 analysis)
# ---------------------------------------------------------------------------


def lax_topk(x: jax.Array, k: int):
    """The independent oracle: ``jax.lax.top_k`` (sorted descending)."""
    return jax.lax.top_k(x.astype(jnp.float32), k)


def maxk_mask(x: jax.Array, k: int) -> jax.Array:
    """Exact MaxK mask via top_k: True at the k largest entries per row.

    Ties are broken by index (lowest index wins), matching lax.top_k.
    Used by the L2 model as the straight-through reference nonlinearity.
    """
    _, idx = lax_topk(x, k)
    n, m = x.shape
    onehot = jax.nn.one_hot(idx, m, dtype=jnp.float32)
    return jnp.sum(onehot, axis=1) > 0


def earlystop_metrics(x: jax.Array, k: int, max_iter: int):
    """Table 2 statistics for one batch of rows.

    Returns per-row arrays: E1 = |max_sel - max_opt| / |max_opt|,
    E2 = |min_sel - min_opt| / |min_opt|, hit = |sel ∩ opt| / k, where
    "opt" is the exact top-k set and "sel" the early-stopped selection.
    """
    vals, idx, _ = rtopk_early_stop(x, k, max_iter)
    opt_vals, opt_idx = lax_topk(x, k)
    sel_max = jnp.max(vals, axis=1)
    sel_min = jnp.min(vals, axis=1)
    opt_max = opt_vals[:, 0]
    opt_min = opt_vals[:, -1]
    e1 = jnp.abs(sel_max - opt_max) / jnp.abs(opt_max)
    e2 = jnp.abs(sel_min - opt_min) / jnp.abs(opt_min)
    n, m = x.shape
    sel_mask = jnp.zeros((n, m), jnp.bool_)
    sel_mask = sel_mask.at[jnp.arange(n)[:, None], idx].set(True)
    opt_mask = jnp.zeros((n, m), jnp.bool_)
    opt_mask = opt_mask.at[jnp.arange(n)[:, None], opt_idx].set(True)
    hit = jnp.sum(jnp.logical_and(sel_mask, opt_mask), axis=1) / k
    return e1, e2, hit


# ---------------------------------------------------------------------------
# SpMM reference (substrate for the L2 MaxK-GNN aggregation)
# ---------------------------------------------------------------------------


def spmm_ref(src: jax.Array, dst: jax.Array, w: jax.Array, x: jax.Array,
             num_nodes: int) -> jax.Array:
    """Edge-list SpMM: out[d] += w_e * x[s] for every edge e=(s,d).

    Padded edges must carry w == 0 (and any valid src/dst), making them
    no-ops. This is the jnp oracle for the aggregation op inside the L2
    models and for the Rust `gnn::spmm` substrate.
    """
    gathered = x[src] * w[:, None]
    return jax.ops.segment_sum(gathered, dst, num_segments=num_nodes)


__all__ = [
    "SearchState",
    "search_exact",
    "search_early_stop",
    "select",
    "rtopk_exact",
    "rtopk_early_stop",
    "rtopk_ref",
    "lax_topk",
    "maxk_mask",
    "earlystop_metrics",
    "spmm_ref",
    "EXACT_ITER_CAP",
]
