//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The offline build cannot fetch crates.io, so this vendor crate
//! implements exactly the surface `rtopk` uses: [`Error`], [`Result`],
//! the [`anyhow!`] and [`bail!`] macros, [`Context`], and
//! [`Error::msg`]. Semantics mirror upstream where it matters:
//!
//! * `Error` does **not** implement `std::error::Error` — that is what
//!   makes the blanket `From<E: std::error::Error>` conversion (used by
//!   `?`) coherent, the same trick upstream anyhow uses.
//! * `Display` prints the outermost message; the alternate form (`{:#}`)
//!   prints the whole context chain joined with `": "`.

use std::fmt;

/// A string-backed error with a context chain. `msgs[0]` is the
/// outermost (most recently attached) message.
pub struct Error {
    msgs: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msgs: vec![m.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        let mut msgs = Vec::with_capacity(self.msgs.len() + 1);
        msgs.push(c.to_string());
        msgs.extend(self.msgs);
        Error { msgs }
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.msgs.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.msgs.join(": "))
        } else {
            f.write_str(&self.msgs[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // anyhow's Debug prints the message plus its causes; the joined
        // chain is the closest single-line equivalent.
        f.write_str(&self.msgs.join(": "))
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        Error { msgs }
    }
}

/// `anyhow::Result<T>` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Conversion into [`Error`] for the [`Context`] blanket impl. Covers
/// both `Error` itself and any standard error type; coherent because
/// `Error` does not implement `std::error::Error`.
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

impl<E> IntoError for E
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

/// Attach context to a `Result`'s error.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert_eq!(format!("{e:?}"), "outer: inner");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(format!("{e}").contains("missing"));
    }

    #[test]
    fn context_on_std_and_shim_results() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(format!("{e}"), "reading file");
        assert!(format!("{e:#}").contains("missing"));

        let r2: Result<()> = Err(anyhow!("deep"));
        let e2 = r2.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e2:#}"), "step 3: deep");
    }

    #[test]
    fn bail_returns_formatted() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative: -1");
    }

    #[test]
    fn msg_accepts_string_and_str() {
        let a = Error::msg("s");
        let b = Error::msg(String::from("s"));
        assert_eq!(format!("{a}"), format!("{b}"));
    }
}
