//! Property-testing harness (proptest is not in the vendored crate set).
//!
//! `forall` runs a seeded generator + checker for `cases` iterations; on
//! failure it reports the failing seed so the case can be replayed with
//! `replay`. Generators derive their stream from a base seed and the
//! case index, so failures are stable across runs.

use crate::util::rng::Rng;

/// Run `check(gen(rng))` for `cases` deterministic cases. Panics with
/// the failing case's seed and message on the first failure.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    base_seed: u64,
    cases: usize,
    gen: impl Fn(&mut Rng) -> T,
    check: impl Fn(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::seed_from(seed);
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}):\n  \
                 {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<T: std::fmt::Debug>(
    seed: u64,
    gen: impl Fn(&mut Rng) -> T,
    check: impl Fn(&T) -> Result<(), String>,
) -> Result<(), String> {
    let mut rng = Rng::seed_from(seed);
    check(&gen(&mut rng))
}

/// Generator helpers for common experiment inputs.
pub mod gens {
    use crate::util::rng::Rng;

    /// A row of length m from one of several distributions, chosen by
    /// the generator stream (normal / uniform / lognormal / quantized
    /// ties / constant).
    pub fn any_row(rng: &mut Rng, m: usize) -> Vec<f32> {
        let dist = rng.index(5);
        (0..m)
            .map(|_| match dist {
                0 => rng.normal_f32(),
                1 => rng.uniform_range(-5.0, 5.0),
                2 => rng.normal().exp() as f32,
                3 => (rng.normal_f32() * 2.0).round() / 2.0, // heavy ties
                _ => 1.25,                                   // constant row
            })
            .collect()
    }

    /// (m, k) with 1 <= k <= m <= max_m.
    pub fn m_and_k(rng: &mut Rng, max_m: usize) -> (usize, usize) {
        let m = 1 + rng.index(max_m);
        let k = 1 + rng.index(m);
        (m, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_a_true_property() {
        forall("sum_nonneg", 1, 50,
            |rng| (0..10).map(|_| rng.uniform() as f32).collect::<Vec<_>>(),
            |xs| {
                if xs.iter().sum::<f32>() >= 0.0 {
                    Ok(())
                } else {
                    Err("negative".into())
                }
            });
    }

    #[test]
    #[should_panic(expected = "always_fails")]
    fn reports_failures() {
        forall("always_fails", 2, 10, |rng| rng.next_u64(),
               |_| Err("nope".into()));
    }

    #[test]
    fn replay_reproduces() {
        // find a failing seed for "value is even", then replay it
        let mut failing = None;
        for case in 0..20u64 {
            let seed = 99 ^ case.wrapping_mul(0x9E3779B97F4A7C15);
            let mut rng = Rng::seed_from(seed);
            if rng.next_u64() % 2 == 1 {
                failing = Some(seed);
                break;
            }
        }
        let seed = failing.expect("some odd value in 20 tries");
        let res = replay(seed, |rng| rng.next_u64(), |v| {
            if v % 2 == 0 { Ok(()) } else { Err("odd".into()) }
        });
        assert!(res.is_err());
    }

    #[test]
    fn gens_cover_shapes() {
        let mut rng = Rng::seed_from(3);
        for _ in 0..100 {
            let (m, k) = gens::m_and_k(&mut rng, 64);
            assert!(1 <= k && k <= m && m <= 64);
            let row = gens::any_row(&mut rng, m);
            assert_eq!(row.len(), m);
        }
    }
}
