//! Batched row-wise driver: apply a per-row selector to all N rows of a
//! matrix in parallel — the CPU analogue of the paper's one-warp-per-row
//! kernel launch.

use crate::topk::baselines::{self, RowSelector};
use crate::topk::binary_search::rtopk_row;
use crate::topk::types::{Mode, TopKResult};
use crate::util::matrix::RowMatrix;
use crate::util::pool;

/// Which row algorithm to run — RTop-K or one of the baselines.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RowAlgo {
    /// the paper's binary-search selection (exact or early-stop)
    RTopK(Mode),
    /// per-row RadixSelect with sorted output — faithful stand-in for
    /// PyTorch's `torch.topk` (the paper's baseline)
    Radix,
    /// Hoare-partition quickselect
    QuickSelect,
    /// size-k min-heap streaming
    Heap,
    /// bucket select (Yang et al. 2024 style, single refinement level)
    Bucket,
    /// bitonic top-k (Shanbhag et al. 2018 style, power-of-two network)
    Bitonic,
    /// full sort then take k — the naive upper baseline
    Sort,
}

impl RowAlgo {
    pub fn name(&self) -> String {
        match self {
            RowAlgo::RTopK(m) => format!("rtopk_{}", m.tag()),
            RowAlgo::Radix => "radix".into(),
            RowAlgo::QuickSelect => "quickselect".into(),
            RowAlgo::Heap => "heap".into(),
            RowAlgo::Bucket => "bucket".into(),
            RowAlgo::Bitonic => "bitonic".into(),
            RowAlgo::Sort => "sort".into(),
        }
    }

    /// All comparison algorithms (for the bench sweeps).
    pub fn all_baselines() -> Vec<RowAlgo> {
        vec![
            RowAlgo::Radix,
            RowAlgo::QuickSelect,
            RowAlgo::Heap,
            RowAlgo::Bucket,
            RowAlgo::Bitonic,
            RowAlgo::Sort,
        ]
    }
}

/// Row-wise RTop-K over a matrix (the library's main entry point).
pub fn rowwise_topk(x: &RowMatrix, k: usize, mode: Mode) -> TopKResult {
    rowwise_topk_with(x, k, RowAlgo::RTopK(mode))
}

/// Planner-driven entry point: consults the adaptive execution planner
/// ([`crate::plan`]) to pick the fastest algorithm and work-unit grain
/// for this (M, k, mode) — cost-model prior plus one-time on-host
/// microbenchmark calibration, cached per shape. Semantics match
/// [`rowwise_topk`]: exact requests get an exact algorithm (any of the
/// zoo); early-stop and loose-eps requests always run the paper's
/// kernel at their requested mode; recall-contracted requests
/// (`Mode::Approx`) may run any RTop-K-family candidate whose measured
/// recall clears the contract (see `plan`'s correctness contract).
pub fn rowwise_topk_auto(x: &RowMatrix, k: usize, mode: Mode) -> TopKResult {
    crate::plan::global().run(x, k, mode)
}

/// Row-wise top-k with any algorithm at the default grain.
pub fn rowwise_topk_with(x: &RowMatrix, k: usize, algo: RowAlgo) -> TopKResult {
    rowwise_topk_grained(x, k, algo, default_grain(x.cols))
}

/// Row-wise top-k with any algorithm and an explicit rows-per-work-unit
/// grain (the planner calibrates this). Rows are distributed over
/// worker threads in dynamic chunks (exact-mode rows converge at
/// different iteration counts, so dynamic scheduling avoids stragglers
/// — the CPU analogue of the paper's observation that divergent warp
/// exits do not hurt overall kernel time).
pub fn rowwise_topk_grained(
    x: &RowMatrix,
    k: usize,
    algo: RowAlgo,
    grain: usize,
) -> TopKResult {
    assert!(k >= 1 && k <= x.cols, "k={} out of range for M={}", k, x.cols);
    let mut out = TopKResult::zeros(x.rows, k);
    // Split the output into disjoint per-row slices up front so worker
    // threads can write without locks.
    let kcap = k;
    let vals_ptr = SendPtr(out.values.as_mut_ptr());
    let idx_ptr = SendPtr(out.indices.as_mut_ptr());
    pool::parallel_dynamic(x.rows, grain.max(1), |start, end| {
        // Grow-only arena owned by the executing thread (a resident pool
        // worker or the submitter): after warmup on a shape, chunks of
        // recurring shapes allocate nothing.
        SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            scratch.ensure(x.cols, kcap);
            for r in start..end {
                let row = x.row(r);
                // SAFETY: each row index r is visited exactly once across all
                // chunks (parallel_dynamic partitions 0..rows), and the k-slot
                // windows [r*k, (r+1)*k) are disjoint per row.
                let (vals, idx) = unsafe {
                    (
                        std::slice::from_raw_parts_mut(vals_ptr.get().add(r * kcap), kcap),
                        std::slice::from_raw_parts_mut(idx_ptr.get().add(r * kcap), kcap),
                    )
                };
                run_row(row, kcap, algo, vals, idx, &mut scratch);
            }
        });
    });
    out
}

thread_local! {
    /// Per-thread grow-only scratch arena for the row loop. Lives as
    /// long as the thread — for pool workers that is the process
    /// lifetime, which is the point: the arena amortizes to zero
    /// allocations per batch. `baselines::scratch_allocs()` counts the
    /// create/grow events for the zero-alloc acceptance checks.
    static SCRATCH: std::cell::RefCell<baselines::Scratch> =
        std::cell::RefCell::new(baselines::Scratch::empty());
}

/// Dispatch one row through the chosen algorithm.
pub fn run_row(
    row: &[f32],
    k: usize,
    algo: RowAlgo,
    vals: &mut [f32],
    idx: &mut [u32],
    scratch: &mut baselines::Scratch,
) {
    match algo {
        RowAlgo::RTopK(mode) => {
            rtopk_row(row, k, mode, vals, idx);
        }
        RowAlgo::Radix => baselines::RadixSelect.select_row(row, k, vals, idx, scratch),
        RowAlgo::QuickSelect => baselines::QuickSelect.select_row(row, k, vals, idx, scratch),
        RowAlgo::Heap => baselines::HeapSelect.select_row(row, k, vals, idx, scratch),
        RowAlgo::Bucket => baselines::BucketSelect.select_row(row, k, vals, idx, scratch),
        RowAlgo::Bitonic => baselines::BitonicSelect.select_row(row, k, vals, idx, scratch),
        RowAlgo::Sort => baselines::SortSelect.select_row(row, k, vals, idx, scratch),
    }
}

/// Rows per dynamic work unit: keep units ~64kB of input so scheduling
/// overhead stays negligible at any M. This is the planner's starting
/// point; calibration may scale it.
pub fn default_grain(m: usize) -> usize {
    (16_384 / m.max(1)).clamp(1, 256)
}

/// Raw pointer wrapper that asserts Send/Sync (disjoint writes per row
/// are guaranteed by the scheduler's partitioning). Accessed through a
/// method so edition-2021 closures capture the wrapper, not the field.
struct SendPtr<T>(*mut T);
impl<T> SendPtr<T> {
    #[inline]
    fn get(&self) -> *mut T {
        self.0
    }
}
// SAFETY: every participant dereferences only inside the disjoint
// per-row windows handed out by `parallel_dynamic`, and the output
// buffers outlive the job (the submitter joins before returning).
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sorted_topk(row: &[f32], k: usize) -> Vec<f32> {
        let mut v = row.to_vec();
        v.sort_by(|a, b| b.partial_cmp(a).unwrap());
        v.truncate(k);
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }

    #[test]
    fn all_algorithms_agree_with_oracle() {
        let mut rng = Rng::seed_from(4);
        let x = RowMatrix::random_normal(37, 100, &mut rng);
        let k = 13;
        let mut algos = vec![RowAlgo::RTopK(Mode::EXACT)];
        algos.extend(RowAlgo::all_baselines());
        for algo in algos {
            let res = rowwise_topk_with(&x, k, algo);
            for r in 0..x.rows {
                let mut got = res.row_values(r).to_vec();
                got.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let want = sorted_topk(x.row(r), k);
                assert_eq!(got, want, "algo {} row {r}", algo.name());
                // indices gather the values
                for (v, &i) in res.row_values(r).iter().zip(res.row_indices(r)) {
                    assert_eq!(*v, x.get(r, i as usize), "algo {}", algo.name());
                }
            }
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Rng::seed_from(5);
        let x = RowMatrix::random_normal(300, 64, &mut rng);
        let par = rowwise_topk(&x, 8, Mode::EXACT);
        // serial reference
        let mut ser = TopKResult::zeros(x.rows, 8);
        for r in 0..x.rows {
            let (v, i) = ser.row_mut(r);
            rtopk_row(x.row(r), 8, Mode::EXACT, v, i);
        }
        assert_eq!(par.values, ser.values);
        assert_eq!(par.indices, ser.indices);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_bad_k() {
        let x = RowMatrix::zeros(2, 4);
        rowwise_topk(&x, 5, Mode::EXACT);
    }

    #[test]
    fn row_grain_bounds() {
        assert_eq!(default_grain(1), 256);
        assert!(default_grain(256) >= 1);
        assert_eq!(default_grain(100_000), 1);
    }

    #[test]
    fn grained_matches_default_grain() {
        let mut rng = Rng::seed_from(6);
        let x = RowMatrix::random_normal(100, 48, &mut rng);
        let a = rowwise_topk_with(&x, 7, RowAlgo::Heap);
        for grain in [1usize, 3, 64, 1000] {
            let b = rowwise_topk_grained(&x, 7, RowAlgo::Heap, grain);
            assert_eq!(a.values, b.values, "grain {grain}");
            assert_eq!(a.indices, b.indices, "grain {grain}");
        }
    }
}
