//! Service metrics: lock-free counters + a mutex-guarded latency
//! reservoir with percentile snapshots.

use crate::stats::summary::percentile;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Shared metrics hub (cheap to clone via Arc by the owner).
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub rows: AtomicU64,
    pub batches: AtomicU64,
    pub pjrt_batches: AtomicU64,
    pub cpu_batches: AtomicU64,
    pub errors: AtomicU64,
    /// request latencies in microseconds (bounded reservoir)
    latencies_us: Mutex<Vec<u64>>,
}

/// Point-in-time view.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub rows: u64,
    pub batches: u64,
    pub pjrt_batches: u64,
    pub cpu_batches: u64,
    pub errors: u64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

const RESERVOIR: usize = 1 << 16;

impl Metrics {
    pub fn record_request(&self, rows: usize, latency: Duration) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(rows as u64, Ordering::Relaxed);
        let mut l = self.latencies_us.lock().unwrap();
        if l.len() >= RESERVOIR {
            // overwrite pseudo-randomly to stay bounded
            let slot = (latency.as_nanos() as usize) % RESERVOIR;
            l[slot] = latency.as_micros() as u64;
        } else {
            l.push(latency.as_micros() as u64);
        }
    }

    pub fn record_batch(&self, via_pjrt: bool) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        if via_pjrt {
            self.pjrt_batches.fetch_add(1, Ordering::Relaxed);
        } else {
            self.cpu_batches.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut lat: Vec<f64> = self
            .latencies_us
            .lock()
            .unwrap()
            .iter()
            .map(|&v| v as f64)
            .collect();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pick = |p: f64| if lat.is_empty() { 0.0 } else { percentile(&lat, p) };
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            rows: self.rows.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            pjrt_batches: self.pjrt_batches.load(Ordering::Relaxed),
            cpu_batches: self.cpu_batches.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            p50_us: pick(50.0),
            p95_us: pick(95.0),
            p99_us: pick(99.0),
            max_us: lat.last().copied().unwrap_or(0.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_percentiles() {
        let m = Metrics::default();
        for i in 1..=100u64 {
            m.record_request(10, Duration::from_micros(i));
        }
        m.record_batch(true);
        m.record_batch(false);
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.rows, 1000);
        assert_eq!(s.pjrt_batches, 1);
        assert_eq!(s.cpu_batches, 1);
        assert!((s.p50_us - 50.5).abs() < 1.0);
        assert!(s.p99_us >= 99.0 && s.max_us == 100.0);
    }

    #[test]
    fn reservoir_stays_bounded() {
        let m = Metrics::default();
        for i in 0..(RESERVOIR + 100) as u64 {
            m.record_request(1, Duration::from_micros(i % 500));
        }
        assert!(m.latencies_us.lock().unwrap().len() <= RESERVOIR);
    }
}
