//! Integration: the typed request API end to end — per-request
//! deadlines answered with positioned timeouts (never stale work),
//! cancellation releasing admission reservations, blocking over-quota
//! admission, and per-request validation overrides through a live
//! service.

use rtopk::config::{ServeConfig, TenantConfig, TenantsConfig};
use rtopk::coordinator::{
    OverQuotaPolicy, SubmitRequest, TenantId, TopKService,
};
use rtopk::topk::types::Mode;
use rtopk::topk::verify::is_exact;
use rtopk::util::matrix::RowMatrix;
use rtopk::util::rng::Rng;
use std::time::{Duration, Instant};

fn tid(name: &str) -> TenantId {
    TenantId::new(name)
}

#[test]
fn expired_deadline_times_out_before_work_is_dispatched() {
    // A 1ns deadline is always expired by the time a worker picks the
    // batch up: the reply must be a positioned timeout error, the
    // request must never count as served, and the admission
    // reservation must come back.
    let svc = TopKService::cpu_only(&ServeConfig {
        workers: 1,
        max_wait_us: 100,
        ..Default::default()
    })
    .unwrap();
    let mut rng = Rng::seed_from(0xDead);
    let x = RowMatrix::random_normal(8, 32, &mut rng);
    let ticket = svc
        .submit_ticket(
            SubmitRequest::new(x, 4)
                .mode(Mode::EXACT)
                .deadline(Duration::from_nanos(1)),
        )
        .unwrap();
    let err = ticket.wait().unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("deadline exceeded"), "got: {msg}");
    assert!(msg.contains("default"), "names the tenant: {msg}");
    let s = svc.stats();
    assert_eq!(s.timed_out, 1);
    assert_eq!(s.requests, 0, "stale work must not be served or counted");
    assert_eq!(s.batches, 0, "nothing was dispatched");
    assert_eq!(
        svc.tenants().in_flight(&TenantId::default()),
        (0, 0),
        "timeout released the admission reservation"
    );
    // a generous deadline on the same service serves normally
    let y = RowMatrix::random_normal(8, 32, &mut rng);
    let res = svc
        .submit(
            SubmitRequest::new(y.clone(), 4)
                .mode(Mode::EXACT)
                .deadline(Duration::from_secs(30)),
        )
        .unwrap();
    assert!(is_exact(&y, &res));
    assert_eq!(svc.stats().requests, 1);
    svc.shutdown();
}

#[test]
fn cancel_while_queued_releases_the_admission_reservation() {
    // Long batching wait so the request is reliably still queued when
    // cancel() lands; the scheduler must then drop it — cancelled
    // error, reservation back to zero, nothing served.
    let svc = TopKService::cpu_only(&ServeConfig {
        workers: 1,
        max_wait_us: 50_000, // 50ms
        tenants: TenantsConfig {
            tenants: vec![TenantConfig {
                max_in_flight_rows: 64,
                ..TenantConfig::named("coop")
            }],
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    let mut rng = Rng::seed_from(0xCA);
    let x = RowMatrix::random_normal(8, 32, &mut rng);
    let ticket = svc
        .submit_ticket(
            SubmitRequest::new(x, 4).mode(Mode::EXACT).tenant("coop"),
        )
        .unwrap();
    assert_eq!(svc.tenants().in_flight(&tid("coop")), (8, 1), "reserved");
    ticket.cancel();
    assert!(ticket.is_cancelled());
    let err = ticket.wait().unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("cancelled"), "got: {msg}");
    assert!(msg.contains("coop"), "names the tenant: {msg}");
    let s = svc.stats();
    assert_eq!(s.cancelled, 1);
    assert_eq!(s.requests, 0, "a cancelled request is not a served request");
    assert_eq!(
        svc.tenants().in_flight(&tid("coop")),
        (0, 0),
        "cancellation released the reservation"
    );
    let coop = s.tenants.iter().find(|t| t.tenant == "coop").unwrap();
    assert_eq!(coop.cancelled, 1);
    assert_eq!(coop.max_us, 0.0, "no reservoir entry for a drop");
    svc.shutdown();
}

#[test]
fn block_policy_waits_for_quota_instead_of_rejecting() {
    // Tenant quota: one request in flight. The first (async) ticket
    // holds the quota until its ~20ms batch completes; the second
    // submission uses Block and must park, then serve — zero
    // rejections.
    let svc = TopKService::cpu_only(&ServeConfig {
        workers: 1,
        max_wait_us: 20_000,
        tenants: TenantsConfig {
            tenants: vec![TenantConfig {
                max_queue_depth: 1,
                ..TenantConfig::named("coop")
            }],
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    let mut rng = Rng::seed_from(0xB1);
    let first = RowMatrix::random_normal(8, 32, &mut rng);
    let second = RowMatrix::random_normal(8, 32, &mut rng);
    let ticket = svc
        .submit_ticket(
            SubmitRequest::new(first.clone(), 4)
                .mode(Mode::EXACT)
                .tenant("coop"),
        )
        .unwrap();
    // over quota now — Reject policy proves it...
    let rejected = svc.submit_ticket(
        SubmitRequest::new(second.clone(), 4)
            .mode(Mode::EXACT)
            .tenant("coop")
            .on_over_quota(OverQuotaPolicy::Reject),
    );
    assert!(rejected.is_err(), "premise: the quota is actually held");
    // ...while Block parks until the first request's reply frees it
    let res = svc
        .submit(
            SubmitRequest::new(second.clone(), 4)
                .mode(Mode::EXACT)
                .tenant("coop")
                .on_over_quota(OverQuotaPolicy::Block),
        )
        .unwrap();
    assert!(is_exact(&second, &res));
    assert!(is_exact(&first, &ticket.wait().unwrap()));
    let s = svc.stats();
    let coop = s.tenants.iter().find(|t| t.tenant == "coop").unwrap();
    assert_eq!(coop.requests, 2, "both served");
    assert_eq!(coop.rejected, 1, "only the explicit Reject probe shed");
    assert_eq!(svc.tenants().in_flight(&tid("coop")), (0, 0));
    svc.shutdown();
}

#[test]
fn blocked_submission_times_out_at_its_deadline() {
    // The quota holder never completes (long batching wait), so a
    // Block submission with a short deadline must give up with a
    // timeout error — and count as timed out, not rejected.
    let svc = TopKService::cpu_only(&ServeConfig {
        workers: 1,
        max_wait_us: 5_000_000, // the holder stays queued for ~5s
        tenants: TenantsConfig {
            tenants: vec![TenantConfig {
                max_queue_depth: 1,
                ..TenantConfig::named("coop")
            }],
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    let mut rng = Rng::seed_from(0xB2);
    let holder = RowMatrix::random_normal(4, 32, &mut rng);
    let _holder_ticket = svc
        .submit_ticket(
            SubmitRequest::new(holder, 2).mode(Mode::EXACT).tenant("coop"),
        )
        .unwrap();
    let t0 = Instant::now();
    let err = svc
        .submit(
            SubmitRequest::new(RowMatrix::zeros(4, 32), 2)
                .mode(Mode::EXACT)
                .tenant("coop")
                .deadline(Duration::from_millis(80))
                .on_over_quota(OverQuotaPolicy::Block),
        )
        .unwrap_err();
    assert!(
        t0.elapsed() >= Duration::from_millis(70),
        "gave up before the deadline: {:?}",
        t0.elapsed()
    );
    assert!(
        t0.elapsed() < Duration::from_secs(4),
        "blocked past the deadline: {:?}",
        t0.elapsed()
    );
    let msg = format!("{err:#}");
    assert!(msg.contains("deadline"), "got: {msg}");
    let s = svc.stats();
    let coop = s.tenants.iter().find(|t| t.tenant == "coop").unwrap();
    assert_eq!(coop.timed_out, 1, "an admission timeout is a timeout");
    assert_eq!(coop.rejected, 0, "…not a rejection");
    assert_eq!(svc.tenants().blocked_waiters(&tid("coop")), 0, "FIFO drained");
    // shutdown still drains the queued holder cleanly
    svc.shutdown();
}

#[test]
fn service_default_over_quota_policy_comes_from_config() {
    // over_quota_policy = "block": a request that says nothing about
    // over-quota behavior parks instead of rejecting.
    let svc = TopKService::cpu_only(&ServeConfig {
        workers: 1,
        max_wait_us: 20_000,
        over_quota_policy: "block".into(),
        tenants: TenantsConfig {
            tenants: vec![TenantConfig {
                max_queue_depth: 1,
                ..TenantConfig::named("coop")
            }],
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    let mut rng = Rng::seed_from(0xB3);
    let a = RowMatrix::random_normal(8, 32, &mut rng);
    let b = RowMatrix::random_normal(8, 32, &mut rng);
    let ticket = svc
        .submit_ticket(
            SubmitRequest::new(a.clone(), 4).mode(Mode::EXACT).tenant("coop"),
        )
        .unwrap();
    let res = svc
        .submit(
            SubmitRequest::new(b.clone(), 4).mode(Mode::EXACT).tenant("coop"),
        )
        .unwrap();
    assert!(is_exact(&b, &res));
    assert!(is_exact(&a, &ticket.wait().unwrap()));
    let s = svc.stats();
    let coop = s.tenants.iter().find(|t| t.tenant == "coop").unwrap();
    assert_eq!(coop.rejected, 0, "config default turned shedding into parking");
    assert_eq!(coop.requests, 2);
    svc.shutdown();
}
