//! # modelcheck — deterministic thread-interleaving explorer
//!
//! A loom-style concurrency model checker for the rtopk serving stack,
//! in-tree and dependency-free (the offline build cannot fetch loom or
//! shuttle, and the checker only needs std).
//!
//! ## Model
//!
//! A test body runs many times, once per explored *schedule*. Threads
//! are real OS threads, but at most one runs at a time: every operation
//! on the façade primitives in [`sync`] (lock/unlock, condvar
//! park/notify, atomic access, spawn/join, tracked raw access) is a
//! *schedule point* where the thread parks and a controller decides who
//! runs next. The controller either enumerates every decision
//! depth-first ([`Strategy::Dfs`], with replay-prefix backtracking) or
//! takes seeded random walks ([`Strategy::Random`]).
//!
//! What it detects:
//!
//! * **Deadlocks** — a wait-for graph (lock waiters → holder, joiners →
//!   joinee) is checked for cycles every round, and a round with no
//!   runnable thread and no pending timeout is reported with a
//!   per-thread blocked report. Lost wakeups surface here: the condvar
//!   park takes a schedule point *before* releasing the mutex, so the
//!   window between a waiter's last check and its park is explorable.
//! * **Data races on tracked raw memory** — every thread carries a
//!   vector clock; mutexes and atomics carry the clock released into
//!   them (acquire joins object→thread, release joins thread→object).
//!   [`sync::race_read`]/[`sync::race_write`] declare accesses to raw
//!   memory the type system cannot see (the pool's lifetime-erased
//!   `*const (dyn Fn + Sync)` job body) and fail the execution when two
//!   accesses are unordered by happens-before.
//! * **Panics and assertion failures** in any interleaving, reported
//!   with the failing schedule.
//!
//! ## Deliberate simplifications
//!
//! * **Sequentially consistent memory.** Vector clocks track the
//!   *presence* of acquire/release edges per `Ordering`, but values read
//!   are always the latest written — weak-memory reorderings are not
//!   simulated. A missing-edge bug is caught as a race; a
//!   wrong-ordering bug whose only symptom is a stale read is not.
//! * **`notify_one` wakes the longest-parked waiter** (FIFO). std makes
//!   no such promise; protocols relying on wake *order* should assert it
//!   explicitly (as the tenant FIFO suite does) rather than lean on the
//!   model's choice.
//! * **No spurious wakeups.** Waiters wake only by notify or timeout.
//!   Code must still loop on its predicate (std requires it), but the
//!   model does not exercise the spurious path.
//! * **Model time advances when idle**: timeouts fire only when no
//!   thread can run, and then *all* pending `wait_timeout`s fire at
//!   once (wake order among them is still explored as separate
//!   grants). This keeps poll loops from turning into livelock or an
//!   unbounded schedule tree during exploration, at the cost of never
//!   exploring "timeout although work was pending".
//! * **`RwLock` is not modelled** (re-exported as std): read guards are
//!   harmless; write guards must not be held across schedule points or
//!   the harness stalls (a 10s watchdog reports the blocked thread).
//!
//! ## Writing a suite
//!
//! The body must be self-contained: create every thread and sync object
//! inside the closure (process globals keep state across executions and
//! are invisible to the explorer), avoid wall-clock branching (DFS
//! replays decision traces; nondeterminism is detected and reported —
//! use [`Strategy::Random`] if unavoidable), and avoid spin-waits (park
//! on a condvar instead; a spinning thread never blocks, so DFS keeps
//! granting it).
//!
//! ```
//! use modelcheck::{model, sync::{Arc, Mutex, Condvar}};
//!
//! model(|| {
//!     let pair = Arc::new((Mutex::new(false), Condvar::new()));
//!     let p2 = Arc::clone(&pair);
//!     let t = modelcheck::sync::thread::spawn(move || {
//!         let (m, cv) = &*p2;
//!         *m.lock().unwrap() = true;
//!         cv.notify_one();
//!     });
//!     let (m, cv) = &*pair;
//!     let mut done = m.lock().unwrap();
//!     while !*done {
//!         done = cv.wait(done).unwrap();
//!     }
//!     drop(done);
//!     t.join().unwrap();
//! });
//! ```

mod clock;
mod sched;
pub mod sync;

pub use sched::{model, Checker, Failure, Report, Strategy};

#[cfg(test)]
mod tests {
    use super::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use super::sync::{thread, Arc, Condvar, Mutex};
    use super::{model, Checker};

    #[test]
    fn dfs_explores_mutex_counter_exhaustively() {
        let report = Checker::dfs().check(|| {
            let n = Arc::new(Mutex::new(0usize));
            let hs: Vec<_> = (0..2)
                .map(|_| {
                    let n = Arc::clone(&n);
                    thread::spawn(move || {
                        *n.lock().unwrap() += 1;
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
            assert_eq!(*n.lock().unwrap(), 2);
        });
        assert!(report.failure.is_none(), "{:?}", report.failure);
        assert!(report.complete, "DFS should exhaust this tiny tree");
        assert!(
            report.executions > 1,
            "two racing lockers must yield multiple schedules"
        );
    }

    /// The lost-wakeup shape the checker exists for: the flag is set
    /// outside the mutex, so the notify can land in the window between
    /// the waiter's check and its park — some schedule deadlocks.
    #[test]
    fn lost_wakeup_is_caught() {
        let report = Checker::dfs().check(|| {
            let flag = Arc::new(AtomicBool::new(false));
            let m = Arc::new(Mutex::new(()));
            let cv = Arc::new(Condvar::new());
            let (f2, cv2) = (Arc::clone(&flag), Arc::clone(&cv));
            let setter = thread::spawn(move || {
                f2.store(true, Ordering::Release);
                cv2.notify_one();
            });
            let mut g = m.lock().unwrap();
            while !flag.load(Ordering::Acquire) {
                g = cv.wait(g).unwrap();
            }
            drop(g);
            setter.join().unwrap();
        });
        let failure = report.failure.expect("DFS must find the lost wakeup");
        assert!(
            failure.message.contains("deadlock"),
            "unexpected failure: {}",
            failure.message
        );
    }

    /// Same protocol with the store under the mutex: the waiter either
    /// sees the flag before parking or is parked when the notify fires.
    #[test]
    fn flag_under_lock_is_clean() {
        model(|| {
            let flag = Arc::new(AtomicBool::new(false));
            let m = Arc::new(Mutex::new(()));
            let cv = Arc::new(Condvar::new());
            let (f2, m2, cv2) =
                (Arc::clone(&flag), Arc::clone(&m), Arc::clone(&cv));
            let setter = thread::spawn(move || {
                let g = m2.lock().unwrap();
                f2.store(true, Ordering::Release);
                drop(g);
                cv2.notify_one();
            });
            let mut g = m.lock().unwrap();
            while !flag.load(Ordering::Acquire) {
                g = cv.wait(g).unwrap();
            }
            drop(g);
            setter.join().unwrap();
        });
    }

    #[test]
    fn unsynchronized_raw_access_is_a_race() {
        const LOC: usize = 0xbeef;
        let report = Checker::dfs().check(|| {
            let a = thread::spawn(|| super::sync::race_write(LOC));
            let b = thread::spawn(|| super::sync::race_write(LOC));
            let _ = a.join();
            let _ = b.join();
        });
        let failure = report.failure.expect("unordered writes must race");
        assert!(
            failure.message.contains("data race"),
            "unexpected failure: {}",
            failure.message
        );
    }

    #[test]
    fn mutex_ordered_raw_access_is_clean() {
        const LOC: usize = 0xfeed;
        let report = Checker::dfs().check(|| {
            let m = Arc::new(Mutex::new(()));
            let hs: Vec<_> = (0..2)
                .map(|_| {
                    let m = Arc::clone(&m);
                    thread::spawn(move || {
                        let _g = m.lock().unwrap();
                        super::sync::race_write(LOC);
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
            // Join edges order this read after both writes.
            super::sync::race_read(LOC);
        });
        assert!(report.failure.is_none(), "{:?}", report.failure);
        assert!(report.complete);
    }

    #[test]
    fn lock_order_inversion_is_a_deadlock() {
        let report = Checker::dfs().check(|| {
            let a = Arc::new(Mutex::new(()));
            let b = Arc::new(Mutex::new(()));
            let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
            let t = thread::spawn(move || {
                let _gb = b2.lock().unwrap();
                let _ga = a2.lock().unwrap();
            });
            let _ga = a.lock().unwrap();
            let _gb = b.lock().unwrap();
            drop(_gb);
            drop(_ga);
            let _ = t.join();
        });
        let failure = report.failure.expect("AB-BA must deadlock somewhere");
        assert!(
            failure.message.contains("deadlock"),
            "unexpected failure: {}",
            failure.message
        );
    }

    #[test]
    fn panicking_thread_is_reported_with_schedule() {
        let report = Checker::dfs().check(|| {
            let t = thread::spawn(|| panic!("boom in model"));
            let _ = t.join();
        });
        let failure = report.failure.expect("panic must be reported");
        assert!(
            failure.message.contains("boom in model"),
            "unexpected failure: {}",
            failure.message
        );
        assert!(!failure.schedule.is_empty());
    }

    #[test]
    fn wait_timeout_fires_only_when_idle() {
        // A waiter nobody ever notifies: the logical timeout fires and
        // the body completes — no deadlock report, no real 1h sleep.
        let report = Checker::dfs().check(|| {
            let m = Mutex::new(());
            let cv = Condvar::new();
            let g = m.lock().unwrap();
            let (g, res) = cv
                .wait_timeout(g, std::time::Duration::from_secs(3600))
                .unwrap();
            assert!(res.timed_out());
            drop(g);
        });
        assert!(report.failure.is_none(), "{:?}", report.failure);
    }

    #[test]
    fn random_strategy_smoke() {
        let report = Checker::random(40, 0x5eed).check(|| {
            let n = Arc::new(AtomicUsize::new(0));
            let hs: Vec<_> = (0..3)
                .map(|_| {
                    let n = Arc::clone(&n);
                    thread::spawn(move || {
                        n.fetch_add(1, Ordering::AcqRel);
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
            assert_eq!(n.load(Ordering::Acquire), 3);
        });
        assert!(report.failure.is_none(), "{:?}", report.failure);
        assert_eq!(report.executions, 40);
    }

    /// Outside a checker the façade is plain std: real threads, real
    /// blocking — this is what `cargo test` without the model cfg runs.
    #[test]
    fn passthrough_behaves_like_std() {
        let n = Arc::new(Mutex::new(0usize));
        let cv = Arc::new(Condvar::new());
        let (n2, cv2) = (Arc::clone(&n), Arc::clone(&cv));
        let t = thread::Builder::new()
            .name("pt".to_string())
            .spawn(move || {
                *n2.lock().unwrap() += 1;
                cv2.notify_all();
            })
            .unwrap();
        let mut g = n.lock().unwrap();
        while *g == 0 {
            g = cv.wait(g).unwrap();
        }
        assert_eq!(*g, 1);
        drop(g);
        t.join().unwrap();
        super::sync::race_write(0x1); // no-op outside the model
        let b = AtomicBool::new(false);
        assert!(!b.swap(true, Ordering::AcqRel));
        assert!(b.load(Ordering::Acquire));
    }
}
