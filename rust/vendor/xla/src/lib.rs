//! In-tree stub of the `xla` (PJRT) bindings.
//!
//! The container image carries no native XLA/PJRT libraries, so this
//! crate provides the exact API surface `rtopk::runtime` uses with two
//! fidelity levels:
//!
//! * **Functional**: [`Literal`] construction, reshape, dtype/shape
//!   introspection and readback are fully implemented — the host-tensor
//!   plumbing (`runtime::tensor`) behaves identically to the real
//!   bindings and its unit tests exercise real behavior.
//! * **Stubbed**: [`PjRtClient::compile`] and
//!   [`PjRtLoadedExecutable::execute`] return a descriptive error.
//!   `TopKService` integration tests skip when `artifacts/` is absent,
//!   and the coordinator's CPU engine serves every request; a build
//!   against the real bindings swaps this crate out via the workspace
//!   manifest with no source changes.

use std::fmt;
use std::path::Path;

/// Error type; implements `std::error::Error` so `?` converts it into
/// the caller's `anyhow`-style error.
#[derive(Clone, Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const STUB_MSG: &str = "PJRT is unavailable: rtopk was built with the in-tree \
     xla stub (no native XLA libraries in this environment); the CPU engine \
     serves all requests";

/// Element types of the artifact ABI (plus common neighbors so dtype
/// matches stay non-exhaustive-friendly).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U32,
    F32,
    F64,
}

/// Internal typed buffer. Public only because the [`NativeType`] trait
/// mentions it; treat as an implementation detail.
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    fn ty(&self) -> ElementType {
        match self {
            Data::F32(_) => ElementType::F32,
            Data::I32(_) => ElementType::S32,
        }
    }
}

/// Host-side literal: typed buffer + dims, or a tuple of literals.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
    tuple: Option<Vec<Literal>>,
}

/// Element types the stub can carry natively.
pub trait NativeType: Copy + Sized {
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap_slice(d: &Data) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<f32>) -> Data {
        Data::F32(v)
    }
    fn unwrap_slice(d: &Data) -> Option<&[f32]> {
        match d {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<i32>) -> Data {
        Data::I32(v)
    }
    fn unwrap_slice(d: &Data) -> Option<&[i32]> {
        match d {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            data: T::wrap(v.to_vec()),
            dims: vec![v.len() as i64],
            tuple: None,
        }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { data: T::wrap(vec![v]), dims: vec![], tuple: None }
    }

    /// Tuple literal (what artifact executions return).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { data: Data::F32(Vec::new()), dims: vec![], tuple: Some(parts) }
    }

    /// Same buffer under new dims; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements do not fit {:?}",
                self.data.len(),
                dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec(), tuple: None })
    }

    /// Shape of a non-tuple literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        if self.tuple.is_some() {
            return Err(Error("tuple literal has no array shape".into()));
        }
        Ok(ArrayShape { dims: self.dims.clone(), ty: self.data.ty() })
    }

    /// Typed readback.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap_slice(&self.data)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error(format!("literal is {:?}, not the requested dtype", self.data.ty())))
    }

    /// Unpack a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        self.tuple.ok_or_else(|| Error("literal is not a tuple".into()))
    }
}

/// Shape + dtype view of an array literal.
#[derive(Clone, Debug, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Parsed HLO module (the stub only retains the text).
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    /// Read an HLO-text artifact. Fails if the file is unreadable, so
    /// missing-artifact errors still surface at the right layer.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let p = path.as_ref();
        let text = std::fs::read_to_string(p)
            .map_err(|e| Error(format!("read {p:?}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// Computation handle.
#[derive(Clone, Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle. `cpu()` succeeds so manifest-only operations
/// (routing tables, `rtopk info`) work; compilation is where the stub
/// reports itself.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(STUB_MSG.to_string()))
    }
}

/// Compiled executable handle (never constructed by the stub client).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(STUB_MSG.to_string()))
    }
}

/// Device buffer handle returned by executions.
pub struct PjRtBuffer(#[allow(dead_code)] Literal);

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.0.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        let s = r.array_shape().unwrap();
        assert_eq!(s.dims(), &[2, 2]);
        assert_eq!(s.ty(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_and_bad_reshape() {
        let s = Literal::scalar(7i32);
        assert!(s.array_shape().unwrap().dims().is_empty());
        assert_eq!(s.array_shape().unwrap().ty(), ElementType::S32);
        assert!(Literal::vec1(&[1.0f32; 3]).reshape(&[2, 2]).is_err());
    }

    #[test]
    fn tuple_unpacks() {
        let t = Literal::tuple(vec![Literal::scalar(1.0f32), Literal::scalar(2i32)]);
        assert!(t.clone().array_shape().is_err());
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::scalar(0i32).to_tuple().is_err());
    }

    #[test]
    fn client_compiles_to_stub_error() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("cpu"));
        let err = c.compile(&XlaComputation).unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}
