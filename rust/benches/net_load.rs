//! Open-loop multi-tenant load generator for the network serving layer
//! (`net::serve` + `net::serve_router`), over real loopback sockets.
//!
//! Phase 1 — tenant isolation on one server: a *clean* tenant (weight
//! 4, no quotas) runs its workload twice, first alone (baseline), then
//! while a *noisy* tenant floods the same server through its own
//! connection. The noisy tenant carries a small `max_in_flight_rows`
//! quota, so its flood is shed at admission with fast positioned
//! errors — it never occupies queue space, which is the mechanism that
//! keeps the clean tenant's p99 uncontaminated. Both phases report
//! client-measured per-tenant p50/p99 and the server's `NetGauges`.
//!
//! Phase 2 — shard-death accountability: two in-process workers behind
//! a router; mid-stream one worker is killed abruptly
//! (`ServerHandle::shutdown` drops its connections with requests
//! parked). Every affected request must be answered with a positioned
//! `shard_down` error frame naming the dead shard — the gate is
//! `all_answered`: results + positioned errors == frames sent, no
//! silence.
//!
//! Results are emitted as a JSON document (last line of output):
//!
//!   cargo bench --bench net_load                (full counts)
//!   RTOPK_SMOKE=1 cargo bench --bench net_load  (CI: tiny counts,
//!       correctness gates only — latency ratios are reported, never
//!       gated, because shared runners are too noisy)

use rtopk::bench::Table;
use rtopk::config::{NetConfig, ServeConfig, TenantConfig, TenantsConfig};
use rtopk::coordinator::wire::{
    self, Frame, FrameDecoder, ERR_SHARD_DOWN,
};
use rtopk::coordinator::{SubmitRequest, TopKService};
use rtopk::net;
use rtopk::topk::Mode;
use rtopk::util::json::{self, Value};
use rtopk::util::matrix::RowMatrix;
use rtopk::util::rng::Rng;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Instant;

/// One tenant's client-side outcome over a connection.
struct ClientStats {
    sent: usize,
    ok: usize,
    shed: usize,
    /// reply latencies in microseconds, FIFO-matched to sends
    latencies_us: Vec<f64>,
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

/// Pipelined open-loop client: write all `n` frames (stamping each
/// send), then read the `n` FIFO replies, matching latency by
/// position. Offered load never adapts to completions.
fn run_client(
    addr: SocketAddr,
    tenant: &str,
    n: usize,
    rows: usize,
    cols: usize,
    k: usize,
    seed: u64,
) -> ClientStats {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_nodelay(true).expect("nodelay");
    let mut rng = Rng::seed_from(seed);
    let mut sends = Vec::with_capacity(n);
    for _ in 0..n {
        let x = RowMatrix::random_normal(rows, cols, &mut rng);
        let req = SubmitRequest::new(x, k).mode(Mode::EXACT).tenant(tenant);
        let bytes =
            wire::encode(&Frame::Submit(req)).expect("encode submit");
        sends.push(Instant::now());
        stream.write_all(&bytes).expect("send frame");
    }
    let mut stats = ClientStats {
        sent: n,
        ok: 0,
        shed: 0,
        latencies_us: Vec::with_capacity(n),
    };
    let mut dec = FrameDecoder::new();
    let mut chunk = [0u8; 16 * 1024];
    let mut got = 0usize;
    while got < n {
        match dec.next().expect("clean reply stream") {
            Some(frame) => {
                stats
                    .latencies_us
                    .push(sends[got].elapsed().as_secs_f64() * 1e6);
                got += 1;
                match frame {
                    Frame::Result(_) => stats.ok += 1,
                    Frame::Error(_) => stats.shed += 1,
                    other => panic!("unexpected reply frame: {other:?}"),
                }
            }
            None => {
                let read = stream.read(&mut chunk).expect("read replies");
                assert!(read > 0, "server closed with replies owed");
                dec.feed(&chunk[..read]);
            }
        }
    }
    stats.latencies_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    stats
}

fn tenant_json(name: &str, weight: u64, c: &ClientStats) -> Value {
    json::obj(vec![
        ("tenant", json::s(name)),
        ("weight", json::num(weight as f64)),
        ("sent", json::num(c.sent as f64)),
        ("ok", json::num(c.ok as f64)),
        ("shed", json::num(c.shed as f64)),
        ("p50_us", json::num(percentile(&c.latencies_us, 0.50))),
        ("p99_us", json::num(percentile(&c.latencies_us, 0.99))),
    ])
}

/// A loopback `[net]` config binding an ephemeral port.
fn loopback_net() -> NetConfig {
    NetConfig { bind: "127.0.0.1:0".to_string(), ..NetConfig::default() }
}

fn main() {
    let smoke = std::env::var("RTOPK_SMOKE").is_ok();
    let (clean_n, noisy_n, rows, cols, k) = if smoke {
        (48usize, 160usize, 16usize, 64usize, 8usize)
    } else {
        (256, 1024, 64, 256, 32)
    };

    // ---- phase 1: one server, clean tenant vs noisy flood ----------
    let cfg = ServeConfig {
        workers: 2,
        tenants: TenantsConfig {
            tenants: vec![
                TenantConfig { weight: 4, ..TenantConfig::named("clean") },
                TenantConfig {
                    weight: 1,
                    // the noisy flood sheds at admission: at most two
                    // requests' worth of rows in flight, the rest is
                    // answered with fast positioned rejections
                    max_in_flight_rows: 2 * rows,
                    ..TenantConfig::named("noisy")
                },
            ],
            ..Default::default()
        },
        ..ServeConfig::default()
    };
    let svc = Arc::new(TopKService::cpu_only(&cfg).expect("service"));
    let server = net::serve(svc.clone(), &loopback_net()).expect("serve");
    let addr = server.addr();

    // baseline: the clean tenant alone
    let baseline =
        run_client(addr, "clean", clean_n, rows, cols, k, 0x0C1EA);
    assert_eq!(baseline.shed, 0, "unquotaed tenant must never shed");

    // contended: clean + noisy concurrently, own connections
    let t0 = Instant::now();
    let (clean, noisy) = std::thread::scope(|scope| {
        let c = scope.spawn(move || {
            run_client(addr, "clean", clean_n, rows, cols, k, 0x0C1EB)
        });
        let n = scope.spawn(move || {
            run_client(addr, "noisy", noisy_n, rows, cols, k, 0x4015E)
        });
        (c.join().expect("clean client"), n.join().expect("noisy client"))
    });
    let wall = t0.elapsed().as_secs_f64();
    let frames_per_sec = (clean.sent + noisy.sent) as f64 / wall.max(1e-9);
    assert_eq!(clean.shed, 0, "clean tenant must never shed");
    assert!(noisy.shed > 0, "the noisy flood must exceed its quota");
    assert_eq!(noisy.ok + noisy.shed, noisy.sent, "every frame answered");

    let gauges = server.stats().gauges();
    assert_eq!(gauges.decode_errors, 0, "well-formed load never misdecodes");
    let expected_in = (baseline.sent + clean.sent + noisy.sent) as u64;
    assert_eq!(gauges.frames_in, expected_in, "server saw every frame");
    server.shutdown();
    match Arc::try_unwrap(svc) {
        Ok(s) => s.shutdown(),
        Err(_) => panic!("server loop retained the service"),
    }

    let clean_p99 = percentile(&clean.latencies_us, 0.99);
    let baseline_p99 = percentile(&baseline.latencies_us, 0.99);
    let contamination = clean_p99 / baseline_p99.max(1e-9);

    let mut t = Table::new(
        "net_load phase 1 (open loop, own connections)",
        &["tenant", "weight", "sent", "ok", "shed", "p50 us", "p99 us"],
    );
    for (name, w, c) in [
        ("clean-baseline", 4u64, &baseline),
        ("clean", 4, &clean),
        ("noisy", 1, &noisy),
    ] {
        t.row(vec![
            name.to_string(),
            w.to_string(),
            c.sent.to_string(),
            c.ok.to_string(),
            c.shed.to_string(),
            format!("{:.0}", percentile(&c.latencies_us, 0.50)),
            format!("{:.0}", percentile(&c.latencies_us, 0.99)),
        ]);
    }
    t.print();
    println!(
        "clean p99 contamination ratio (contended/baseline): {contamination:.2}x \
         (reported, not gated: shed load never queues, so the ratio \
         measures runner noise)"
    );

    // ---- phase 2: router with a killed worker ----------------------
    let worker_cfg = ServeConfig {
        workers: 1,
        // park requests in the batcher long enough for the kill to
        // land while they are provably in flight on the doomed shard
        max_batch_rows: 1 << 20,
        max_wait_us: if smoke { 300_000 } else { 500_000 },
        ..ServeConfig::default()
    };
    let mut workers = Vec::new();
    let mut shard_addrs = Vec::new();
    for _ in 0..2 {
        let svc =
            Arc::new(TopKService::cpu_only(&worker_cfg).expect("worker"));
        let h = net::serve(svc.clone(), &loopback_net()).expect("worker net");
        shard_addrs.push(h.addr().to_string());
        workers.push((svc, h));
    }
    let router_cfg = NetConfig {
        bind: "127.0.0.1:0".to_string(),
        shards: shard_addrs.clone(),
        health_cadence_ms: 50,
        health_timeout_ms: 100,
        ..NetConfig::default()
    };
    // weight 2 spreads the bench tenant across both shards
    let weights: HashMap<String, u64> =
        [("spread".to_string(), 2u64)].into_iter().collect();
    let router = net::serve_router(&router_cfg, weights).expect("router");

    let batch = if smoke { 8usize } else { 32 };
    let mut stream = TcpStream::connect(router.addr()).expect("router conn");
    stream.set_nodelay(true).expect("nodelay");
    let mut rng = Rng::seed_from(0xD1E);
    let mut sent = 0usize;
    let mut send = |stream: &mut TcpStream, rng: &mut Rng, n: usize| {
        for _ in 0..n {
            let x = RowMatrix::random_normal(8, 32, rng);
            let req =
                SubmitRequest::new(x, 4).mode(Mode::EXACT).tenant("spread");
            let bytes =
                wire::encode(&Frame::Submit(req)).expect("encode submit");
            stream.write_all(&bytes).expect("send via router");
        }
    };
    // wave 1 lands on both shards and is parked by the long batch
    // window; the kill catches its dead-shard half in flight
    send(&mut stream, &mut rng, batch);
    sent += batch;
    let (_, doomed_handle) = workers.pop().expect("two workers");
    let killed_addr = shard_addrs[1].clone();
    doomed_handle.shutdown();
    // wave 2 arrives after the death: the router must reroute or
    // refuse with positioned errors — never stay silent
    send(&mut stream, &mut rng, batch);
    sent += batch;

    let mut results = 0usize;
    let mut positioned = 0usize;
    let mut dec = FrameDecoder::new();
    let mut chunk = [0u8; 16 * 1024];
    let mut got = 0usize;
    while got < sent {
        match dec.next().expect("clean router reply stream") {
            Some(frame) => {
                got += 1;
                match frame {
                    Frame::Result(_) => results += 1,
                    Frame::Error(e) => {
                        assert_eq!(
                            e.code, ERR_SHARD_DOWN,
                            "only shard-death errors expected: {e:?}"
                        );
                        assert!(
                            e.msg.contains("request #"),
                            "shard errors must be positioned: {}",
                            e.msg
                        );
                        positioned += 1;
                    }
                    other => panic!("unexpected router reply: {other:?}"),
                }
            }
            None => {
                let read = stream.read(&mut chunk).expect("router replies");
                assert!(read > 0, "router closed with replies owed");
                dec.feed(&chunk[..read]);
            }
        }
    }
    let all_answered = results + positioned == sent;
    assert!(all_answered, "router left requests unanswered");
    assert!(
        positioned > 0,
        "killing a shard mid-wave must produce positioned errors"
    );
    let shard_counters = router.shard_counters();
    router.shutdown();
    for (svc, h) in workers {
        h.shutdown();
        match Arc::try_unwrap(svc) {
            Ok(s) => s.shutdown(),
            Err(_) => panic!("worker loop retained its service"),
        }
    }
    println!(
        "router: {sent} sent -> {results} results + {positioned} positioned \
         shard-down errors (killed {killed_addr})"
    );

    let shards_json: Vec<Value> = shard_counters
        .iter()
        .map(|(addr, forwarded, errors)| {
            json::obj(vec![
                ("addr", json::s(addr)),
                ("forwarded", json::num(*forwarded as f64)),
                ("errors", json::num(*errors as f64)),
            ])
        })
        .collect();
    let doc = json::obj(vec![
        ("bench", json::s("net_load")),
        ("smoke", Value::Bool(smoke)),
        ("frames_per_sec", json::num(frames_per_sec)),
        (
            "tenants",
            json::arr(vec![
                tenant_json("clean_baseline", 4, &baseline),
                tenant_json("clean", 4, &clean),
                tenant_json("noisy", 1, &noisy),
            ]),
        ),
        ("contamination_ratio", json::num(contamination)),
        (
            "net",
            json::obj(vec![
                ("frames_in", json::num(gauges.frames_in as f64)),
                ("frames_out", json::num(gauges.frames_out as f64)),
                ("decode_errors", json::num(gauges.decode_errors as f64)),
                (
                    "open_connections",
                    json::num(gauges.open_connections as f64),
                ),
            ]),
        ),
        (
            "router",
            json::obj(vec![
                ("shards", json::arr(shards_json)),
                ("killed", json::s(&killed_addr)),
                ("sent", json::num(sent as f64)),
                ("results", json::num(results as f64)),
                ("positioned_errors", json::num(positioned as f64)),
                (
                    "all_answered",
                    Value::Bool(all_answered)),
            ]),
        ),
        (
            "summary",
            json::obj(vec![
                ("clean_p99_us", json::num(clean_p99)),
                ("baseline_p99_us", json::num(baseline_p99)),
                ("noisy_shed", json::num(noisy.shed as f64)),
                ("pass", Value::Bool(true)),
            ]),
        ),
    ]);
    println!("{}", doc.to_string());
}
