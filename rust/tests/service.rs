//! Integration: TopKService over the PJRT route (real artifacts) and
//! the CPU route, checking they agree and the coordinator behaves under
//! concurrent load.

use rtopk::config::{BackendConfig, ServeConfig};
use rtopk::coordinator::{SubmitRequest, TopKService};
use rtopk::topk::types::Mode;
use rtopk::topk::verify::{approx_metrics, is_exact};
use rtopk::util::matrix::RowMatrix;
use rtopk::util::rng::Rng;
use std::sync::Arc;

fn artifacts_dir() -> String {
    std::env::var("RTOPK_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

fn have_artifacts() -> bool {
    std::path::Path::new(&artifacts_dir()).join("manifest.json").exists()
}

/// A service pinned to the PJRT backend so these tests exercise the
/// accelerator path deterministically (adaptive selection would run
/// PJRT only where it *measures* faster than the CPU engine on the
/// test host). Shapes without a compiled tile still fall back to CPU.
fn pjrt_service() -> TopKService {
    TopKService::start(&ServeConfig {
        artifacts_dir: artifacts_dir(),
        workers: 2,
        max_wait_us: 100,
        backend: BackendConfig {
            force: Some("pjrt".into()),
            ..BackendConfig::default()
        },
        ..Default::default()
    })
    .unwrap()
}

#[test]
fn pjrt_route_serves_exact_topk() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let svc = pjrt_service();
    // (256, 32, exact) has a compiled tile in the default set
    assert!(svc
        .variants()
        .contains(&(256usize, 32usize, "exact".to_string())));
    let mut rng = Rng::seed_from(41);
    let x = RowMatrix::random_normal(1500, 256, &mut rng); // > 1 tile
    let res = svc
        .submit(SubmitRequest::new(x.clone(), 32).mode(Mode::EXACT))
        .unwrap();
    assert_eq!(res.rows, 1500);
    assert!(is_exact(&x, &res), "PJRT route returned non-exact top-k");
    let s = svc.stats();
    assert!(s.pjrt_batches >= 1, "expected the PJRT route, stats: {s:?}");
}

#[test]
fn pjrt_and_cpu_routes_agree_exactly() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let svc = pjrt_service();
    let mut rng = Rng::seed_from(43);
    let x = RowMatrix::random_normal(700, 256, &mut rng);
    // es4 goes through PJRT (compiled tile), the same shape through the
    // CPU engine must produce identical approximate selections — the
    // cross-language bit-equality guarantee, end to end through the
    // whole coordinator.
    let pjrt = svc
        .submit(
            SubmitRequest::new(x.clone(), 32)
                .mode(Mode::EarlyStop { max_iter: 4 }),
        )
        .unwrap();
    let cpu =
        rtopk::topk::rowwise_topk(&x, 32, Mode::EarlyStop { max_iter: 4 });
    assert_eq!(pjrt.values, cpu.values);
    assert_eq!(pjrt.indices, cpu.indices);
}

#[test]
fn unrouted_shapes_fall_back_to_cpu() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let svc = pjrt_service();
    let mut rng = Rng::seed_from(44);
    let x = RowMatrix::random_normal(64, 100, &mut rng); // M=100: no tile
    let res = svc
        .submit(SubmitRequest::new(x.clone(), 10).mode(Mode::EXACT))
        .unwrap();
    assert!(is_exact(&x, &res));
    assert!(svc.stats().cpu_batches >= 1);
}

#[test]
fn concurrent_clients_under_load() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let svc = Arc::new(pjrt_service());
    let threads: Vec<_> = (0..4)
        .map(|t| {
            let svc = svc.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::seed_from(100 + t);
                for _ in 0..5 {
                    let x = RowMatrix::random_normal(300, 256, &mut rng);
                    let res = svc
                        .submit(
                            SubmitRequest::new(x.clone(), 32)
                                .mode(Mode::EarlyStop { max_iter: 8 }),
                        )
                        .unwrap();
                    let m = approx_metrics(&x, &res);
                    assert!(m.hit > 0.9, "hit {}", m.hit);
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    let s = svc.stats();
    assert_eq!(s.requests, 20);
    assert_eq!(s.rows, 20 * 300);
    assert_eq!(s.errors, 0);
}
