//! Repo-invariant lint: machine-checks the cross-file contracts this
//! codebase relies on but `rustc` cannot see. Dependency-free and
//! token-level (see [`source`]); run via `cargo run --bin rtopk-lint`
//! (CI runs it as a named step) or exercised in-process by the
//! `real_tree_is_clean` test, so `cargo test` fails when an invariant
//! drifts.
//!
//! The rules:
//!
//! * **knob-doc** — every config knob referenced in code
//!   (`"serve.x"`, `"plan.y"`, `"backend.z"`, `"pool.w"`, `"net.v"`,
//!   `"tenants.{name}.k"`, plus the `TENANT_KEYS` table) has a row in
//!   `docs/CONFIG.md` under its section heading, and every documented
//!   row is backed by a knob the code actually reads — both directions,
//!   all six sections.
//! * **safety-comment** — every `unsafe` token in non-test code has a
//!   `// SAFETY:` comment on the same or one of the six preceding
//!   lines.
//! * **wall-clock** — `Instant::now` / `SystemTime` never appear in
//!   `plan/model.rs` (the cost model must be a pure function) or
//!   `coordinator/wire.rs` (encoding must be deterministic) outside
//!   the allowlist.
//! * **counter-key** — every [`crate::coordinator::metrics::Counter`]
//!   variant has its `<snake_case>_total` key in the `LoadSnapshot`
//!   JSON, and every `*_total` key in `metrics.rs` maps back to a
//!   variant; likewise the `NET_KEYS` table and the `NetGauges` struct
//!   fields must agree one-to-one (the snapshot's `net` section is
//!   pinned the same way the counters are).
//! * **deprecated-call** — no non-test code calls or names an item the
//!   repo marks `#[deprecated]` (the submit shims), outside
//!   `#[allow(deprecated)]` items and `use` re-exports.
//!
//! False positives are suppressed via `rust/lint-allow.txt`
//! (`rule path-suffix token # why` per line), kept deliberately empty
//! until a rule earns an exception.

pub mod source;

use source::{blank_attr_items, idents, line_of, scan, Scanned};
use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::path::{Path, PathBuf};

/// Attribute prefixes whose items are invisible to test-skipping rules
/// (whitespace-insensitive match against the attribute text).
const TEST_ATTRS: &[&str] = &["#[cfg(test)", "#[cfg(all(test", "#[test]"];

/// One lint violation.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    /// rule id, e.g. `knob-doc`
    pub rule: &'static str,
    /// repo-relative path
    pub path: String,
    /// 1-based line (0 when the finding is about a whole file/section)
    pub line: usize,
    /// the offending token, for allowlist matching
    pub token: String,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.path, self.line, self.rule, self.message
        )
    }
}

/// Parsed `lint-allow.txt`: `rule path-suffix token` triples.
#[derive(Debug, Default)]
pub struct Allowlist {
    entries: Vec<(String, String, String)>,
}

impl Allowlist {
    pub fn parse(text: &str) -> Allowlist {
        let mut entries = Vec::new();
        for raw in text.lines() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let mut it = line.split_whitespace();
            if let (Some(r), Some(p), Some(t)) = (it.next(), it.next(), it.next()) {
                entries.push((r.to_string(), p.to_string(), t.to_string()));
            }
        }
        Allowlist { entries }
    }

    pub fn permits(&self, f: &Finding) -> bool {
        self.entries.iter().any(|(r, p, t)| {
            r == f.rule && f.path.ends_with(p.as_str()) && *t == f.token
        })
    }
}

/// A source file handed to the rules: repo-relative path + content.
pub struct SourceFile {
    pub path: String,
    pub text: String,
}

fn non_test_code(s: &Scanned) -> String {
    blank_attr_items(&s.code, TEST_ATTRS)
}

// ---------------------------------------------------------------------------
// Rule: knob-doc
// ---------------------------------------------------------------------------

/// The `[section]` names CONFIG.md must document and code may reference.
const KNOB_SECTIONS: [&str; 6] =
    ["serve", "plan", "backend", "pool", "net", "tenants"];

/// Parse `docs/CONFIG.md` into section -> documented keys. Sections are
/// `## `[serve]`` headings (the tenants heading is `## `[tenants.<name>]``);
/// keys are the leading `` `key` `` cell of each table row.
pub fn documented_knobs(config_md: &str) -> BTreeMap<String, BTreeSet<String>> {
    let mut out: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    let mut current: Option<String> = None;
    for line in config_md.lines() {
        if let Some(h) = line.strip_prefix("## `[") {
            let name = h.split(&[']', '.'][..]).next().unwrap_or("");
            current = if KNOB_SECTIONS.contains(&name) {
                out.entry(name.to_string()).or_default();
                Some(name.to_string())
            } else {
                None
            };
            continue;
        }
        if line.starts_with("## ") {
            current = None;
            continue;
        }
        if let (Some(section), Some(rest)) = (&current, line.strip_prefix("| `")) {
            if let Some(key) = rest.split('`').next() {
                if !key.is_empty()
                    && key.chars().all(|c| c.is_ascii_lowercase() || c == '_')
                {
                    out.get_mut(section).unwrap().insert(key.to_string());
                }
            }
        }
    }
    out
}

fn knob_of(lit: &str) -> Option<(String, String)> {
    let (section, rest) = lit.split_once('.')?;
    if !KNOB_SECTIONS.contains(&section) {
        return None;
    }
    let key = if section == "tenants" {
        // "tenants.{name}.weight" (format!) or "tenants.acme.weight"
        let (_name, key) = rest.rsplit_once('.')?;
        key
    } else {
        rest
    };
    if key.is_empty()
        || !key.chars().all(|c| c.is_ascii_lowercase() || c == '_')
    {
        return None;
    }
    Some((section.to_string(), key.to_string()))
}

/// Extract the string-literal elements of the `TENANT_KEYS` table (the
/// per-tenant knob names are bare, not dotted, so [`knob_of`] cannot
/// see them).
fn tenant_table_keys(s: &Scanned) -> Vec<StrLitRef<'_>> {
    let Some(pos) = s.code.find("TENANT_KEYS") else {
        return Vec::new();
    };
    // the literals sit between the `=` of the declaration and the `;`
    // ending it (the `;` inside the `[&str; N]` type sits before `=`)
    let eq = s.code[pos..].find('=').map_or(pos, |o| pos + o);
    let end = s.code[eq..].find(';').map_or(s.code.len(), |o| eq + o);
    let start_line = line_of(&s.code, eq);
    let end_line = line_of(&s.code, end);
    s.strings
        .iter()
        .filter(|l| l.line >= start_line && l.line <= end_line)
        .map(|l| StrLitRef { line: l.line, text: &l.text })
        .collect()
}

struct StrLitRef<'a> {
    line: usize,
    text: &'a str,
}

/// Both directions of the knob <-> CONFIG.md contract.
pub fn check_knobs(files: &[SourceFile], config_md: &str) -> Vec<Finding> {
    let documented = documented_knobs(config_md);
    let mut findings = Vec::new();
    for section in KNOB_SECTIONS {
        if !documented.contains_key(section) {
            findings.push(Finding {
                rule: "knob-doc",
                path: "docs/CONFIG.md".into(),
                line: 0,
                token: section.to_string(),
                message: format!(
                    "CONFIG.md has no `## `[{section}]`` section (all six \
                     knob sections must be documented)"
                ),
            });
        }
    }
    // code -> docs, remembering which documented keys code actually uses
    let mut used: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    for f in files {
        let s = scan(&f.text);
        let masked = non_test_code(&s);
        // string literals inside test items were blanked in `masked`;
        // a literal counts only if its line still has code
        let live_line = |line: usize| {
            masked
                .lines()
                .nth(line - 1)
                .is_some_and(|l| !l.trim().is_empty())
        };
        let mut seen: Vec<(usize, String, String)> = s
            .strings
            .iter()
            .filter(|l| live_line(l.line))
            .filter_map(|l| {
                knob_of(&l.text).map(|(sec, key)| (l.line, sec, key))
            })
            .collect();
        if f.path.ends_with("config/mod.rs") {
            for l in tenant_table_keys(&s) {
                seen.push((l.line, "tenants".into(), l.text.to_string()));
            }
        }
        for (line, section, key) in seen {
            used.entry(section.clone()).or_default().insert(key.clone());
            let ok = documented
                .get(&section)
                .is_some_and(|keys| keys.contains(&key));
            if !ok {
                findings.push(Finding {
                    rule: "knob-doc",
                    path: f.path.clone(),
                    line,
                    token: format!("{section}.{key}"),
                    message: format!(
                        "config knob `[{section}] {key}` is read here but has \
                         no row in docs/CONFIG.md"
                    ),
                });
            }
        }
    }
    // docs -> code
    for (section, keys) in &documented {
        for key in keys {
            let is_used = used
                .get(section)
                .is_some_and(|u| u.contains(key));
            if !is_used {
                findings.push(Finding {
                    rule: "knob-doc",
                    path: "docs/CONFIG.md".into(),
                    line: 0,
                    token: format!("{section}.{key}"),
                    message: format!(
                        "documented knob `[{section}] {key}` is never read by \
                         the code (stale row or missing wiring)"
                    ),
                });
            }
        }
    }
    findings
}

// ---------------------------------------------------------------------------
// Rule: safety-comment
// ---------------------------------------------------------------------------

/// How many lines above an `unsafe` token a `SAFETY:` comment may sit.
const SAFETY_WINDOW: usize = 6;

pub fn check_safety_comments(f: &SourceFile) -> Vec<Finding> {
    let s = scan(&f.text);
    let masked = non_test_code(&s);
    let mut findings = Vec::new();
    for (pos, word) in idents(&masked) {
        if word != "unsafe" {
            continue;
        }
        let line = line_of(&masked, pos);
        let covered = (line.saturating_sub(SAFETY_WINDOW)..=line).any(|l| {
            s.comments
                .get(&l)
                .is_some_and(|c| c.contains("SAFETY:"))
        });
        if !covered {
            findings.push(Finding {
                rule: "safety-comment",
                path: f.path.clone(),
                line,
                token: "unsafe".into(),
                message: format!(
                    "`unsafe` without a `// SAFETY:` comment on the same or \
                     the {SAFETY_WINDOW} preceding lines"
                ),
            });
        }
    }
    findings
}

// ---------------------------------------------------------------------------
// Rule: wall-clock
// ---------------------------------------------------------------------------

/// Files that must stay wall-clock free: the planner cost model (a pure
/// function — nondeterminism would poison plan comparisons and the
/// model-check DFS) and the wire codec (byte-exact golden files).
const CLOCK_FREE_FILES: [&str; 2] =
    ["plan/model.rs", "coordinator/wire.rs"];

pub fn check_wall_clock(f: &SourceFile) -> Vec<Finding> {
    if !CLOCK_FREE_FILES.iter().any(|p| f.path.ends_with(p)) {
        return Vec::new();
    }
    let s = scan(&f.text);
    let masked = non_test_code(&s);
    let mut findings = Vec::new();
    for (pos, word) in idents(&masked) {
        let bad = match word.as_str() {
            "SystemTime" => true,
            "Instant" => {
                // only the clock read is banned; passing `Instant`
                // values through (deadlines) is fine
                masked[pos..]
                    .chars()
                    .skip(word.chars().count())
                    .collect::<String>()
                    .trim_start()
                    .starts_with("::now")
            }
            _ => false,
        };
        if bad {
            findings.push(Finding {
                rule: "wall-clock",
                path: f.path.clone(),
                line: line_of(&masked, pos),
                token: word.clone(),
                message: format!(
                    "`{word}` in a deterministic file (cost model / wire \
                     codec must not read wall clocks)"
                ),
            });
        }
    }
    findings
}

// ---------------------------------------------------------------------------
// Rule: counter-key
// ---------------------------------------------------------------------------

fn camel_to_snake(name: &str) -> String {
    let mut out = String::new();
    for (i, c) in name.chars().enumerate() {
        if c.is_ascii_uppercase() {
            if i > 0 {
                out.push('_');
            }
            out.push(c.to_ascii_lowercase());
        } else {
            out.push(c);
        }
    }
    out
}

/// String-literal elements of the `NET_KEYS` table in the metrics
/// source (same extraction shape as `TENANT_KEYS`: the literals sit
/// between the declaration's `=` and its terminating `;`).
fn net_key_literals(s: &Scanned) -> Vec<String> {
    let Some(pos) = s.code.find("NET_KEYS") else {
        return Vec::new();
    };
    let eq = s.code[pos..].find('=').map_or(pos, |o| pos + o);
    let end = s.code[eq..].find(';').map_or(s.code.len(), |o| eq + o);
    let start_line = line_of(&s.code, eq);
    let end_line = line_of(&s.code, end);
    s.strings
        .iter()
        .filter(|l| l.line >= start_line && l.line <= end_line)
        .map(|l| l.text.clone())
        .collect()
}

/// Field names of `pub struct NetGauges` in the metrics source.
fn net_gauge_fields(s: &Scanned) -> Vec<String> {
    let Some(pos) = s.code.find("struct NetGauges") else {
        return Vec::new();
    };
    let body_start = match s.code[pos..].find('{') {
        Some(off) => pos + off + 1,
        None => return Vec::new(),
    };
    let body_end = match s.code[body_start..].find('}') {
        Some(off) => body_start + off,
        None => return Vec::new(),
    };
    idents(&s.code[body_start..body_end])
        .into_iter()
        .map(|(_, w)| w)
        .filter(|w| w != "pub" && w != "u64")
        .collect()
}

/// Variant names of `pub enum Counter` in the metrics source.
fn counter_variants(s: &Scanned) -> Vec<String> {
    let Some(pos) = s.code.find("enum Counter") else {
        return Vec::new();
    };
    let body_start = match s.code[pos..].find('{') {
        Some(off) => pos + off + 1,
        None => return Vec::new(),
    };
    let body_end = match s.code[body_start..].find('}') {
        Some(off) => body_start + off,
        None => return Vec::new(),
    };
    idents(&s.code[body_start..body_end])
        .into_iter()
        .map(|(_, w)| w)
        .filter(|w| w.chars().next().is_some_and(|c| c.is_ascii_uppercase()))
        .collect()
}

/// Counter enum <-> `LoadSnapshot` JSON keys, both directions.
pub fn check_counter_keys(metrics: &SourceFile) -> Vec<Finding> {
    let s = scan(&metrics.text);
    let masked = non_test_code(&s);
    let variants = counter_variants(&s);
    let mut findings = Vec::new();
    if variants.is_empty() {
        findings.push(Finding {
            rule: "counter-key",
            path: metrics.path.clone(),
            line: 0,
            token: "Counter".into(),
            message: "could not locate `enum Counter` (rule needs updating?)"
                .into(),
        });
        return findings;
    }
    let live_line = |line: usize| {
        masked
            .lines()
            .nth(line - 1)
            .is_some_and(|l| !l.trim().is_empty())
    };
    let total_keys: BTreeSet<&str> = s
        .strings
        .iter()
        .filter(|l| live_line(l.line))
        .map(|l| l.text.as_str())
        .filter(|t| {
            t.ends_with("_total")
                && t.chars().all(|c| c.is_ascii_lowercase() || c == '_')
        })
        .collect();
    let expected: BTreeMap<String, &String> = variants
        .iter()
        .map(|v| (format!("{}_total", camel_to_snake(v)), v))
        .collect();
    for (key, variant) in &expected {
        if !total_keys.contains(key.as_str()) {
            findings.push(Finding {
                rule: "counter-key",
                path: metrics.path.clone(),
                line: 0,
                token: key.clone(),
                message: format!(
                    "Counter::{variant} has no `{key}` key in the \
                     LoadSnapshot JSON (snapshot consumers cannot see it)"
                ),
            });
        }
    }
    for key in total_keys {
        if !expected.contains_key(key) {
            findings.push(Finding {
                rule: "counter-key",
                path: metrics.path.clone(),
                line: 0,
                token: key.to_string(),
                message: format!(
                    "JSON key `{key}` does not correspond to any Counter \
                     variant (stale key or missing variant)"
                ),
            });
        }
    }
    // NET_KEYS <-> NetGauges fields, both directions. Fixtures without
    // a net section (neither table nor struct present) are exempt.
    let keys = net_key_literals(&s);
    let fields = net_gauge_fields(&s);
    if keys.is_empty() && fields.is_empty() {
        return findings;
    }
    let key_set: BTreeSet<&String> = keys.iter().collect();
    let field_set: BTreeSet<&String> = fields.iter().collect();
    for field in &fields {
        if !key_set.contains(field) {
            findings.push(Finding {
                rule: "counter-key",
                path: metrics.path.clone(),
                line: 0,
                token: field.clone(),
                message: format!(
                    "NetGauges field `{field}` has no entry in NET_KEYS \
                     (snapshot consumers pin the `net` section by these keys)"
                ),
            });
        }
    }
    for key in &keys {
        if !field_set.contains(key) {
            findings.push(Finding {
                rule: "counter-key",
                path: metrics.path.clone(),
                line: 0,
                token: key.clone(),
                message: format!(
                    "NET_KEYS entry `{key}` does not name a NetGauges field \
                     (stale key or missing gauge)"
                ),
            });
        }
    }
    findings
}

// ---------------------------------------------------------------------------
// Rule: deprecated-call
// ---------------------------------------------------------------------------

/// Names of items the repo marks `#[deprecated]`.
pub fn deprecated_items(files: &[SourceFile]) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for f in files {
        let s = scan(&f.text);
        let code = &s.code;
        let mut search_from = 0;
        while let Some(off) = code[search_from..].find("#[deprecated") {
            let attr_at = search_from + off;
            search_from = attr_at + 1;
            // scan forward past attributes to the item header
            let words = idents(&code[attr_at..]);
            let mut take_next = false;
            for (_, w) in words {
                match w.as_str() {
                    "fn" | "type" | "struct" | "enum" | "trait" | "const" => {
                        take_next = true;
                    }
                    _ if take_next => {
                        names.insert(w);
                        break;
                    }
                    _ => {}
                }
            }
        }
    }
    names
}

pub fn check_deprecated_calls(
    files: &[SourceFile],
    deprecated: &BTreeSet<String>,
) -> Vec<Finding> {
    let mut findings = Vec::new();
    for f in files {
        let s = scan(&f.text);
        // invisible regions: test items, #[allow(deprecated)] items
        // (shim bodies, the re-export), and the deprecated definitions
        // themselves
        let masked = blank_attr_items(
            &s.code,
            &[
                "#[cfg(test)",
                "#[cfg(all(test",
                "#[test]",
                "#[allow(deprecated)",
                "#[deprecated",
            ],
        );
        for (pos, word) in idents(&masked) {
            if !deprecated.contains(&word) {
                continue;
            }
            let line = line_of(&masked, pos);
            // `use` statements only move names around
            let line_text = masked.lines().nth(line - 1).unwrap_or("");
            let trimmed = line_text.trim_start();
            if trimmed.starts_with("use ") || trimmed.starts_with("pub use ") {
                continue;
            }
            findings.push(Finding {
                rule: "deprecated-call",
                path: f.path.clone(),
                line,
                token: word.clone(),
                message: format!(
                    "`{word}` is #[deprecated]; non-test code must use the \
                     typed SubmitRequest API instead"
                ),
            });
        }
    }
    findings
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Load every `rust/src/**/*.rs` under `repo_root` with repo-relative
/// paths.
pub fn load_sources(repo_root: &Path) -> std::io::Result<Vec<SourceFile>> {
    let src = repo_root.join("rust").join("src");
    let mut paths = Vec::new();
    walk_rs(&src, &mut paths)?;
    paths.sort();
    let mut files = Vec::new();
    for p in paths {
        let rel = p
            .strip_prefix(repo_root)
            .unwrap_or(&p)
            .to_string_lossy()
            .replace('\\', "/");
        files.push(SourceFile { path: rel, text: std::fs::read_to_string(&p)? });
    }
    Ok(files)
}

/// Run every rule against a repo checkout; returns the findings that
/// survive the allowlist.
pub fn run_all(repo_root: &Path) -> std::io::Result<Vec<Finding>> {
    let files = load_sources(repo_root)?;
    let config_md =
        std::fs::read_to_string(repo_root.join("docs").join("CONFIG.md"))?;
    let allow = match std::fs::read_to_string(
        repo_root.join("rust").join("lint-allow.txt"),
    ) {
        Ok(t) => Allowlist::parse(&t),
        Err(_) => Allowlist::default(),
    };
    let mut findings = check_knobs(&files, &config_md);
    for f in &files {
        findings.extend(check_safety_comments(f));
        findings.extend(check_wall_clock(f));
        if f.path.ends_with("coordinator/metrics.rs") {
            findings.extend(check_counter_keys(f));
        }
    }
    let deprecated = deprecated_items(&files);
    findings.extend(check_deprecated_calls(&files, &deprecated));
    findings.retain(|f| !allow.permits(f));
    Ok(findings)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sf(path: &str, text: &str) -> SourceFile {
        SourceFile { path: path.into(), text: text.into() }
    }

    const CONFIG_MD: &str = "\
## `[serve]`
| Key | Type | Default | Meaning |
| --- | --- | --- | --- |
| `workers` | int | `2` | Threads. |
## `[plan]`
| `calib_rows` | int | `192` | Rows. |
## `[backend]`
| `enable` | bool | `true` | On. |
## `[pool]`
| `threads` | int | `0` | Auto. |
## `[net]`
| `bind` | string | `127.0.0.1:7070` | Listen address. |
## `[tenants.<name>]`
| `weight` | int | `1` | WDRR. |
";

    #[test]
    fn knob_rule_passes_when_code_and_docs_agree() {
        let files = [sf(
            "rust/src/config/mod.rs",
            r#"
            fn load(c: &Config) {
                c.get_or("serve.workers", 2);
                c.get_or("plan.calib_rows", 192);
                c.get_or("backend.enable", true);
                c.get_or("pool.threads", 0);
                c.get("net.bind");
                let _ = format!("tenants.{name}.weight");
            }
            "#,
        )];
        let found = check_knobs(&files, CONFIG_MD);
        assert!(found.is_empty(), "{found:?}");
    }

    #[test]
    fn undocumented_knob_is_flagged() {
        let files = [sf(
            "rust/src/config/mod.rs",
            r#"
            fn load(c: &Config) {
                c.get_or("serve.workers", 2);
                c.get_or("serve.brand_new_knob", 1);
                c.get_or("plan.calib_rows", 192);
                c.get_or("backend.enable", true);
                c.get_or("pool.threads", 0);
                c.get("net.bind");
                let _ = format!("tenants.{name}.weight");
            }
            "#,
        )];
        let found = check_knobs(&files, CONFIG_MD);
        assert_eq!(found.len(), 1, "{found:?}");
        assert_eq!(found[0].token, "serve.brand_new_knob");
    }

    #[test]
    fn stale_doc_row_is_flagged_and_test_code_does_not_count() {
        // the only reference to serve.workers sits in a test module, so
        // the documented row must be reported as stale
        let files = [sf(
            "rust/src/config/mod.rs",
            r#"
            fn load(c: &Config) {
                c.get_or("plan.calib_rows", 192);
                c.get_or("backend.enable", true);
                c.get_or("pool.threads", 0);
                c.get("net.bind");
                let _ = format!("tenants.{name}.weight");
            }
            #[cfg(test)]
            mod tests {
                fn t(c: &Config) { c.get_or("serve.workers", 2); }
            }
            "#,
        )];
        let found = check_knobs(&files, CONFIG_MD);
        assert_eq!(found.len(), 1, "{found:?}");
        assert_eq!(found[0].token, "serve.workers");
        assert!(found[0].message.contains("never read"));
    }

    #[test]
    fn missing_section_is_flagged() {
        let md = "## `[serve]`\n| `workers` | int | `2` | T. |\n";
        let files = [sf(
            "rust/src/config/mod.rs",
            r#"fn f(c: &Config) { c.get_or("serve.workers", 2); }"#,
        )];
        let found = check_knobs(&files, md);
        let missing: Vec<_> =
            found.iter().filter(|f| f.line == 0 && f.path.ends_with("CONFIG.md")
                && f.message.contains("no `##")).collect();
        assert_eq!(missing.len(), 5, "{found:?}"); // plan/backend/pool/net/tenants
    }

    #[test]
    fn safety_rule_accepts_commented_and_rejects_bare_unsafe() {
        let ok = sf(
            "rust/src/x.rs",
            "// SAFETY: disjoint rows per thread.\n\
             let v = unsafe { &*p };\n",
        );
        assert!(check_safety_comments(&ok).is_empty());
        let bad = sf("rust/src/x.rs", "let v = unsafe { &*p };\n");
        let found = check_safety_comments(&bad);
        assert_eq!(found.len(), 1);
        assert_eq!(found[0].rule, "safety-comment");
        // mentions in strings and comments are not tokens
        let quoted = sf(
            "rust/src/x.rs",
            "let s = \"unsafe\"; // unsafe is discussed here only\n",
        );
        assert!(check_safety_comments(&quoted).is_empty());
    }

    #[test]
    fn safety_window_is_bounded() {
        let far = sf(
            "rust/src/x.rs",
            "// SAFETY: too far away.\n\n\n\n\n\n\n\nlet v = unsafe { &*p };\n",
        );
        assert_eq!(check_safety_comments(&far).len(), 1);
    }

    #[test]
    fn wall_clock_rule_only_bites_deterministic_files() {
        let model = sf(
            "rust/src/plan/model.rs",
            "fn t() { let t0 = Instant::now(); }\n",
        );
        let found = check_wall_clock(&model);
        assert_eq!(found.len(), 1, "{found:?}");
        assert_eq!(found[0].token, "Instant");
        // passing an Instant through is fine; reading the clock is not
        let pass_through = sf(
            "rust/src/plan/model.rs",
            "fn t(deadline: Instant) -> Instant { deadline }\n",
        );
        assert!(check_wall_clock(&pass_through).is_empty());
        let elsewhere = sf(
            "rust/src/coordinator/scheduler.rs",
            "fn t() { let t0 = Instant::now(); }\n",
        );
        assert!(check_wall_clock(&elsewhere).is_empty());
        let wire = sf(
            "rust/src/coordinator/wire.rs",
            "fn t() { let s = SystemTime::now(); }\n",
        );
        assert_eq!(check_wall_clock(&wire).len(), 1);
    }

    const METRICS_OK: &str = r#"
        pub enum Counter { Requests, TimedOut }
        fn json(s: &Snap) {
            obj(vec![
                ("requests_total", num(s.requests_total)),
                ("timed_out_total", num(s.timed_out_total)),
            ]);
        }
    "#;

    #[test]
    fn counter_rule_passes_on_matched_keys() {
        let found = check_counter_keys(&sf(
            "rust/src/coordinator/metrics.rs",
            METRICS_OK,
        ));
        assert!(found.is_empty(), "{found:?}");
    }

    #[test]
    fn counter_rule_flags_missing_and_stale_keys() {
        let missing = sf(
            "rust/src/coordinator/metrics.rs",
            r#"
            pub enum Counter { Requests, TimedOut }
            fn json(s: &Snap) { obj(vec![("requests_total", num(1.0))]); }
            "#,
        );
        let found = check_counter_keys(&missing);
        assert_eq!(found.len(), 1, "{found:?}");
        assert_eq!(found[0].token, "timed_out_total");
        let stale = sf(
            "rust/src/coordinator/metrics.rs",
            r#"
            pub enum Counter { Requests }
            fn json(s: &Snap) {
                obj(vec![
                    ("requests_total", num(1.0)),
                    ("ghosts_total", num(0.0)),
                ]);
            }
            "#,
        );
        let found = check_counter_keys(&stale);
        assert_eq!(found.len(), 1, "{found:?}");
        assert_eq!(found[0].token, "ghosts_total");
    }

    #[test]
    fn counter_rule_pins_net_keys_to_net_gauges_fields() {
        let matched = sf(
            "rust/src/coordinator/metrics.rs",
            r#"
            pub enum Counter { Requests }
            pub struct NetGauges { pub frames_in: u64, pub frames_out: u64 }
            pub const NET_KEYS: [&str; 2] = ["frames_in", "frames_out"];
            fn json(s: &Snap) { obj(vec![("requests_total", num(1.0))]); }
            "#,
        );
        assert!(check_counter_keys(&matched).is_empty());
        let drifted = sf(
            "rust/src/coordinator/metrics.rs",
            r#"
            pub enum Counter { Requests }
            pub struct NetGauges { pub frames_in: u64, pub decode_errors: u64 }
            pub const NET_KEYS: [&str; 2] = ["frames_in", "frames_out"];
            fn json(s: &Snap) { obj(vec![("requests_total", num(1.0))]); }
            "#,
        );
        let found = check_counter_keys(&drifted);
        assert_eq!(found.len(), 2, "{found:?}");
        let tokens: Vec<&str> =
            found.iter().map(|f| f.token.as_str()).collect();
        assert!(tokens.contains(&"decode_errors"), "{tokens:?}");
        assert!(tokens.contains(&"frames_out"), "{tokens:?}");
    }

    #[test]
    fn deprecated_rule_finds_shim_calls_outside_shields() {
        let service = sf(
            "rust/src/coordinator/service.rs",
            r#"
            #[deprecated(note = "use submit_ticket")]
            #[allow(deprecated)]
            pub fn submit_as(&self) { self.inner() }
            "#,
        );
        let caller = sf(
            "rust/src/cli/serve.rs",
            "fn go(svc: &S) { svc.submit_as(); }\n",
        );
        let test_caller = sf(
            "rust/src/cli/other.rs",
            "#[cfg(test)]\nmod tests { fn t(s: &S) { s.submit_as(); } }\n",
        );
        let files = [service, caller, test_caller];
        let deprecated = deprecated_items(&files);
        assert!(deprecated.contains("submit_as"), "{deprecated:?}");
        let found = check_deprecated_calls(&files, &deprecated);
        assert_eq!(found.len(), 1, "{found:?}");
        assert_eq!(found[0].path, "rust/src/cli/serve.rs");
        assert_eq!(found[0].token, "submit_as");
    }

    #[test]
    fn allowlist_suppresses_exact_matches_only() {
        let f = Finding {
            rule: "wall-clock",
            path: "rust/src/plan/model.rs".into(),
            line: 3,
            token: "Instant".into(),
            message: String::new(),
        };
        let allow = Allowlist::parse(
            "# comment line\nwall-clock plan/model.rs Instant # why: probes\n",
        );
        assert!(allow.permits(&f));
        let other = Finding { token: "SystemTime".into(), ..f.clone() };
        assert!(!allow.permits(&other));
    }

    /// The real tree must be lint-clean: this is the tier-1 enforcement
    /// of the invariants (CI also runs the binary as a named step).
    #[test]
    fn real_tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("rust/ has a parent")
            .to_path_buf();
        let findings = run_all(&root).expect("lint walk");
        assert!(
            findings.is_empty(),
            "repo lint violations:\n{}",
            findings
                .iter()
                .map(|f| f.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
    }
}
