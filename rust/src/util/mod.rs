//! General-purpose substrates (offline build: no crates.io, so these are
//! implemented in-tree — see DESIGN.md §2 "Offline-build note").
//!
//! * [`json`] — minimal JSON value model, parser, and writer (serde is
//!   not vendored); backs the plan cache, bench documents, and CLI
//!   `--json` output.
//! * [`matrix`] — dense row-major `RowMatrix` with seeded random
//!   fills; the unit of every request and probe workload.
//! * [`pool`] — persistent fork-join worker pool over std threads:
//!   resident workers parked on a condvar, atomic-counter dynamic
//!   scheduling, disjoint-slot parallel fills, panic propagation, and
//!   queryable gauges; sized from `RTOPK_THREADS` > `[pool] threads` >
//!   `available_parallelism`.
//! * [`prop`] — tiny property-test harness: seeded case generation
//!   with replayable failing seeds.
//! * [`rng`] — deterministic xoshiro256++ with SplitMix64 seeding;
//!   every experiment seeds explicitly so tables reproduce bit-for-bit.
//! * [`sync`] — synchronization façade: std re-exports normally, the
//!   in-tree model checker's instrumented primitives under
//!   `cfg(rtopk_model_check)`. All new cross-thread protocol code
//!   imports from here (see the module docs for the rules).
//! * [`timer`] — adaptive best-of timing loops shared by the
//!   calibrator and the bench harnesses.

pub mod json;
pub mod matrix;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod sync;
pub mod timer;
