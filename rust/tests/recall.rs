//! Recall contracts, end to end: `Mode::Approx { recall_milli }` is a
//! *contract* — "return at least this fraction of the true top-k, in
//! expectation" — and every layer that carries it is on the hook.
//!
//! The statistical methodology lives in `topk::verify`: one shared
//! recall oracle (`recall_of` — value-multiset overlap, fair under
//! ties), seeded distribution generators (`Dist::ALL`), and a
//! derandomized gate (`recall_gate` — target minus three sigma of the
//! row-mean under the Bhatia–Davis variance bound, so a true-at-the-
//! bound mode false-fails with probability under ~0.2%, and every
//! suite is seed-fixed on top). These tests exercise the contract
//! through the public surfaces: the kernel, the wire codec, the
//! planner's qualification race, and the serving path.

use rtopk::coordinator::wire::{self, Frame};
use rtopk::coordinator::{SubmitRequest, TopKService};
use rtopk::config::ServeConfig;
use rtopk::plan::{is_exact_semantics, PlanSource, Planner, PlannerConfig};
use rtopk::topk::rowwise::{rowwise_topk, RowAlgo};
use rtopk::topk::types::Mode;
use rtopk::topk::verify::{recall_gate, recall_of, Dist};

fn quick_planner() -> Planner {
    Planner::new(PlannerConfig {
        calib_rows: 32,
        calib_reps: 1,
        ..PlannerConfig::default()
    })
}

/// The kernel honors the contract across every generator distribution
/// and a grid of shapes and targets. Seeded and gated: a regression
/// that drops achieved recall below target at any grid point fails
/// deterministically.
#[test]
fn approx_recall_meets_target_across_distributions_and_shapes() {
    const ROWS: usize = 200;
    for dist in Dist::ALL {
        for &(m, k) in &[(256usize, 16usize), (512, 64), (1024, 32)] {
            for &target in &[800u16, 900, 950, 990] {
                let seed = 0xC0_47AC7 ^ ((m as u64) << 24) ^ ((k as u64) << 12)
                    ^ target as u64;
                let x = dist.matrix(ROWS, m, seed);
                let res =
                    rowwise_topk(&x, k, Mode::Approx { recall_milli: target });
                let r = recall_of(&x, &res);
                let gate = recall_gate(target as f64 / 1000.0, ROWS);
                assert!(
                    r >= gate,
                    "{} M={m} k={k}: achieved recall {r:.4} under the \
                     {target}‰ contract gate {gate:.4}",
                    dist.name()
                );
            }
        }
    }
}

/// `apx1000` is the degenerate contract — full recall — and must be
/// *met*, not approximated: the two-stage kernel's calibrated params
/// collapse to an exact configuration.
#[test]
fn full_recall_contract_degenerates_to_exact() {
    for dist in Dist::ALL {
        let x = dist.matrix(60, 300, 0xF0_11);
        let res = rowwise_topk(&x, 24, Mode::Approx { recall_milli: 1000 });
        let r = recall_of(&x, &res);
        assert!(r >= 1.0 - 1e-12, "{}: recall {r} < 1", dist.name());
    }
}

/// The tentpole acceptance path in one test: a typed request carrying
/// `Approx { 950 }` survives the wire byte-exactly, is raced against
/// the exact and early-stop candidates by the planner, and the plan it
/// gets records an achieved recall that clears the contract.
#[test]
fn approx_request_roundtrips_wire_and_plans_with_recall_recorded() {
    let mode = Mode::Approx { recall_milli: 950 };
    let req = SubmitRequest::new(Dist::Gaussian.matrix(40, 512, 0xE2E), 32)
        .mode(mode)
        .tenant("contract");
    let bytes = wire::encode(&Frame::Submit(req.clone())).unwrap();
    let back = match wire::decode(&bytes).unwrap() {
        Frame::Submit(r) => r,
        other => panic!("wrong frame kind: {other:?}"),
    };
    assert_eq!(back, req, "wire roundtrip must be lossless");
    assert_eq!(back.mode, Some(mode));

    let planner = quick_planner();
    let plan = planner.plan(back.matrix.rows, back.matrix.cols, back.k, mode);
    assert_eq!(plan.source, PlanSource::Calibrated);
    // the race really did consider alternatives: the probe list spans
    // the approx family (two-stage, early-stop truncations, exact)
    assert!(
        plan.probes.len() >= 2,
        "expected a real race, got probes {:?}",
        plan.probes
    );
    assert!(
        matches!(plan.algo, RowAlgo::RTopK(_)),
        "approx requests stay on the paper's kernel family"
    );
    let achieved = plan.recall.expect("calibrated approx plans record recall");
    assert!(
        achieved >= 0.95,
        "planned winner's measured recall {achieved} breaks the contract"
    );
    // and the planned execution honors it on the request's own matrix
    let res = planner.run(&back.matrix, back.k, mode);
    let r = recall_of(&back.matrix, &res);
    assert!(
        r >= recall_gate(0.95, back.matrix.rows),
        "served recall {r} under the contract gate"
    );
}

/// Regression: a candidate whose measured recall misses the target must
/// never be planned — the winner's recorded recall always clears the
/// contract (with the configured margin), for every target. At the
/// degenerate `apx1000` the constraint is recall = 1.0 exactly, which
/// disqualifies every lossy truncation regardless of how fast it
/// probed.
#[test]
fn disqualified_candidates_are_never_planned() {
    let planner = quick_planner();
    for &target in &[700u16, 900, 950, 1000] {
        let mode = Mode::Approx { recall_milli: target };
        let plan = planner.plan(48, 768, 24, mode);
        let achieved =
            plan.recall.expect("calibrated approx plans record recall");
        let need = (target as f64 / 1000.0).min(1.0);
        assert!(
            achieved >= need,
            "apx{target}: planned recall {achieved} < contracted {need}"
        );
        if target == 1000 {
            assert!(
                achieved >= 1.0,
                "full-recall contract admitted a lossy winner at {achieved}"
            );
        }
    }
}

/// The point of the whole subsystem: somewhere on the shape grid the
/// planner must *choose* an approximate mode because it is faster —
/// the recall constraint prunes, the stopwatch picks. Early-stop
/// truncations and the two-stage kernel skip most of the exact binary
/// search's iterations at large M, so at a loose target at least one
/// large-M regime picks a non-exact winner.
#[test]
fn some_regime_plans_an_approximate_mode_on_speed() {
    let planner = quick_planner();
    let mode = Mode::Approx { recall_milli: 600 };
    let mut non_exact_wins = 0;
    for &(m, k) in &[(2048usize, 32usize), (4096, 64), (4096, 32)] {
        let plan = planner.plan(40, m, k, mode);
        if let RowAlgo::RTopK(won) = plan.algo {
            if !is_exact_semantics(won) {
                non_exact_wins += 1;
                // speed, not recall, made the call — and it is recorded
                let r = plan.recall.unwrap();
                assert!(r >= 0.6, "winner at M={m} k={k} recall {r}");
            }
        }
    }
    assert!(
        non_exact_wins > 0,
        "no large-M regime planned an approximate mode — either the \
         qualification gate disqualified everything (recall bug) or the \
         exact kernel out-raced its own truncations (timing bug)"
    );
}

/// Serving path: a `Mode::Approx` submission decoded straight off the
/// wire is admitted, batched, planned, and answered — and the answer
/// honors the contract under the statistical gate.
#[test]
fn served_approx_requests_honor_the_contract() {
    let svc = TopKService::cpu_only(&ServeConfig {
        workers: 2,
        max_wait_us: 100,
        ..Default::default()
    })
    .unwrap();
    let mode = Mode::Approx { recall_milli: 950 };
    let mut total = 0.0;
    let mut rows = 0;
    for (i, dist) in Dist::ALL.iter().enumerate() {
        let x = dist.matrix(50, 256, 0x5E_0100 + i as u64);
        let req = SubmitRequest::new(x.clone(), 16).mode(mode);
        // route through the wire codec so the serving path under test
        // is the one a remote client actually reaches
        let bytes = wire::encode(&Frame::Submit(req)).unwrap();
        let decoded = match wire::decode(&bytes).unwrap() {
            Frame::Submit(r) => r,
            other => panic!("wrong frame kind: {other:?}"),
        };
        let res = svc.submit(decoded).unwrap();
        total += recall_of(&x, &res) * x.rows as f64;
        rows += x.rows;
    }
    let mean = total / rows as f64;
    assert!(
        mean >= recall_gate(0.95, rows),
        "served mean recall {mean} under the 0.95 contract gate"
    );
    assert_eq!(svc.stats().requests as usize, Dist::ALL.len());
}
