//! Vector clocks for happens-before tracking.
//!
//! Every model thread carries a [`VClock`]; every synchronization object
//! (mutex, atomic) carries the clock released into it. Acquire-style
//! operations join the object's clock into the thread's, release-style
//! operations join the thread's into the object's, and the data-race
//! detector compares the clocks of tracked raw-memory accesses: a read
//! and a write to the same location race unless one's clock is wholly
//! `<=` the other's.

/// A per-thread logical clock: component `i` is how far thread `i`'s
/// history this clock has observed. Indexing past the end reads 0, so
/// clocks grow lazily as threads spawn.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct VClock(Vec<u32>);

impl VClock {
    pub fn new() -> VClock {
        VClock(Vec::new())
    }

    pub fn get(&self, tid: usize) -> u32 {
        self.0.get(tid).copied().unwrap_or(0)
    }

    fn grow_to(&mut self, tid: usize) {
        if self.0.len() <= tid {
            self.0.resize(tid + 1, 0);
        }
    }

    /// Advance this clock's own component (one new event on `tid`).
    pub fn tick(&mut self, tid: usize) {
        self.grow_to(tid);
        self.0[tid] += 1;
    }

    /// Pointwise max: after `a.join(b)`, `a` has observed everything
    /// either clock had.
    pub fn join(&mut self, other: &VClock) {
        self.grow_to(other.0.len().saturating_sub(1));
        for (i, v) in other.0.iter().enumerate() {
            if *v > self.0[i] {
                self.0[i] = *v;
            }
        }
    }

    /// `self <= other` pointwise: every event this clock has seen,
    /// `other` has also seen — i.e. self happens-before-or-equals other.
    pub fn leq(&self, other: &VClock) -> bool {
        self.0
            .iter()
            .enumerate()
            .all(|(i, v)| *v <= other.get(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn join_and_leq() {
        let mut a = VClock::new();
        let mut b = VClock::new();
        a.tick(0); // a = [1]
        b.tick(1); // b = [0,1]
        assert!(!a.leq(&b));
        assert!(!b.leq(&a));
        let mut c = a.clone();
        c.join(&b); // c = [1,1]
        assert!(a.leq(&c));
        assert!(b.leq(&c));
        assert!(!c.leq(&a));
    }

    #[test]
    fn empty_clock_precedes_everything() {
        let empty = VClock::new();
        let mut t = VClock::new();
        t.tick(3);
        assert!(empty.leq(&t));
        assert!(empty.leq(&empty));
    }
}
