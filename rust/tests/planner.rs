//! Integration: adaptive planner parity and persistence.
//!
//! The core guarantee under test: for any shape, `Planner::run` (and
//! therefore `rowwise_topk_auto`) returns *bit-identical* output to the
//! fixed-algorithm oracle of whatever plan the grid chose — dispatch
//! may change speed, never results — and exact-mode plans additionally
//! match the sort oracle's multiset.

use rtopk::plan::{candidates, Plan, Planner, PlannerConfig, PlanSource};
use rtopk::topk::rowwise::{rowwise_topk_with, RowAlgo};
use rtopk::topk::types::Mode;
use rtopk::topk::verify::is_exact;
use rtopk::util::matrix::RowMatrix;
use rtopk::util::prop::{forall, gens};
use rtopk::util::rng::Rng;

fn quick_planner() -> Planner {
    Planner::new(PlannerConfig {
        calib_rows: 32,
        calib_reps: 1,
        ..PlannerConfig::default()
    })
}

#[test]
fn auto_equals_fixed_algo_oracle_for_every_chosen_plan() {
    let planner = quick_planner();
    forall(
        "auto == fixed-algo oracle",
        0x9_1A_7,
        120,
        |rng| {
            let (m, k) = gens::m_and_k(rng, 96);
            let rows = 1 + rng.index(40);
            let mode = if rng.chance(0.5) {
                Mode::EXACT
            } else {
                Mode::EarlyStop { max_iter: 1 + rng.index(8) as u32 }
            };
            let x = RowMatrix::from_vec(
                rows,
                m,
                (0..rows * m).map(|_| rng.normal_f32()).collect(),
            );
            (x, k, mode)
        },
        |(x, k, mode)| {
            let planner = &planner;
            let auto = planner.run(x, *k, *mode);
            let plan = planner.plan(x.cols, *k, *mode);
            let oracle = rowwise_topk_with(x, *k, plan.algo);
            if auto.values != oracle.values || auto.indices != oracle.indices {
                return Err(format!(
                    "auto diverged from its own plan {:?}",
                    plan.algo.name()
                ));
            }
            if rtopk::plan::is_exact_semantics(*mode) && !is_exact(x, &auto) {
                return Err("exact-mode plan returned non-exact top-k".into());
            }
            Ok(())
        },
    );
}

#[test]
fn every_candidate_the_grid_can_choose_is_exact() {
    // The planner may pick any of these for an exact request; each one
    // must satisfy the exact-multiset contract independently, so no
    // calibration outcome can produce a wrong answer.
    let mut rng = Rng::seed_from(0xA11);
    for &(m, k) in &[(64usize, 8usize), (100, 25), (256, 32)] {
        let x = RowMatrix::random_normal(40, m, &mut rng);
        for algo in candidates(m, k, Mode::EXACT) {
            let res = rowwise_topk_with(&x, k, algo);
            assert!(is_exact(&x, &res), "algo {} at M={m} k={k}", algo.name());
        }
    }
}

#[test]
fn approximate_requests_never_switch_algorithm() {
    let planner = quick_planner();
    for it in [1u32, 4, 8] {
        let mode = Mode::EarlyStop { max_iter: it };
        let plan = planner.plan(200, 20, mode);
        assert_eq!(plan.algo, RowAlgo::RTopK(mode));
    }
    let loose = Mode::Exact { eps_rel: 1e-3 };
    assert_eq!(planner.plan(200, 20, loose).algo, RowAlgo::RTopK(loose));
}

#[test]
fn cache_roundtrips_through_disk() {
    let path = std::env::temp_dir().join("rtopk_planner_integration_cache.json");
    let _ = std::fs::remove_file(&path);
    let cfg = PlannerConfig {
        calib_rows: 32,
        calib_reps: 1,
        cache_path: Some(path.clone()),
        ..PlannerConfig::default()
    };
    let first = Planner::new(cfg.clone());
    let mut decided: Vec<(usize, usize, Plan)> = Vec::new();
    for &(m, k) in &[(64usize, 8usize), (128, 32), (256, 64)] {
        decided.push((m, k, first.plan(m, k, Mode::EXACT)));
    }
    first.save().unwrap();

    let second = Planner::new(cfg);
    for (m, k, plan) in decided {
        let recalled = second.plan(m, k, Mode::EXACT);
        assert_eq!(recalled.algo, plan.algo, "M={m} k={k}");
        assert_eq!(recalled.grain, plan.grain, "M={m} k={k}");
        assert_eq!(recalled.source, PlanSource::Cached);
    }
    // recalled plans still execute correctly
    let mut rng = Rng::seed_from(0xD15C);
    let x = RowMatrix::random_normal(30, 128, &mut rng);
    assert!(is_exact(&x, &second.run(&x, 32, Mode::EXACT)));
    let _ = std::fs::remove_file(&path);
}
