//! Network serving layer: schema-v1 frames over TCP.
//!
//! Two deployables share this module and the wire codec:
//!
//! * [`server`] — `rtopk listen`: a single-threaded readiness loop
//!   (see [`reactor`]) accepting client connections, incrementally
//!   decoding submit frames ([`crate::coordinator::wire::FrameDecoder`])
//!   into [`crate::coordinator::SubmitRequest`]s, and submitting them
//!   through the in-process [`crate::coordinator::TopKService`] —
//!   tenants, quotas, deadlines, feasibility admission, and recall
//!   floors all apply unchanged. Results stream back as result frames;
//!   per-request failures as error frames.
//! * [`router`] — `rtopk shard`: the same readiness loop fanning
//!   client frames across N worker processes speaking this protocol,
//!   with weight-aware shard allocation, [`health`]-probe quarantine,
//!   and positioned error frames for requests stranded on a dead
//!   shard.
//!
//! ## Protocol contract
//!
//! A client sends submit (kind 1) and ping (kind 4) frames. The server
//! answers every submit frame with exactly one result (kind 2) or
//! error (kind 3) frame, **in submission order per connection** — the
//! Nth reply answers the Nth submit, even though the service completes
//! requests out of order. Pings are answered with pongs out-of-band
//! (they never wait behind submits). Closing the connection cancels
//! every in-flight request via the ticket cancel-hook: quota and queue
//! space are released promptly, never leaked to a vanished peer.
//!
//! ## Backpressure
//!
//! Per-connection memory is bounded by `[net] read_buf_bytes` +
//! `[net] write_buf_bytes` + one in-flight result. A slow reader fills
//! the write buffer, which pauses result encoding, which (with
//! `max_inflight_per_conn`) pauses frame decoding, which lets the read
//! buffer fill, which pauses socket reads — at which point TCP flow
//! control pushes the backpressure to the client. No unbounded queue
//! exists anywhere on the path.
//!
//! ## Locks
//!
//! Cross-thread state (the health prober's shard table, shutdown
//! flags) goes through the `util::sync` model-check façade like every
//! other concurrency-bearing module. The per-connection state machines
//! are single-threaded by construction — owned by the socket loop —
//! and the observability counters in [`NetStats`] are plain
//! `std::sync::atomic` per the façade's observability carve-out.

pub mod conn;
pub mod health;
pub mod reactor;
pub mod router;
pub mod server;

pub use router::{serve_router, RouterHandle};
pub use server::{serve, ServerHandle};

use crate::coordinator::metrics::{NetGauges, NetProbe};
use crate::coordinator::wire::{self, ErrorFrame};
use std::sync::atomic::{AtomicU64, Ordering};

/// Shared observability counters for one server or router instance.
/// Registered with the service's [`crate::coordinator::TelemetryHub`]
/// as the [`NetProbe`] behind the snapshot's `net` section.
/// Observability-only: no control flow reads these, so they stay on
/// std atomics (the façade's carve-out) and cost the model checker
/// nothing.
#[derive(Debug, Default)]
pub struct NetStats {
    open_connections: AtomicU64,
    frames_in: AtomicU64,
    frames_out: AtomicU64,
    decode_errors: AtomicU64,
    shards_alive: AtomicU64,
    shards_quarantined: AtomicU64,
}

impl NetStats {
    pub fn conn_opened(&self) {
        self.open_connections.fetch_add(1, Ordering::Relaxed);
    }

    pub fn conn_closed(&self) {
        self.open_connections.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn frame_in(&self) {
        self.frames_in.fetch_add(1, Ordering::Relaxed);
    }

    pub fn frame_out(&self) {
        self.frames_out.fetch_add(1, Ordering::Relaxed);
    }

    pub fn decode_error(&self) {
        self.decode_errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn set_shard_health(&self, alive: u64, quarantined: u64) {
        self.shards_alive.store(alive, Ordering::Relaxed);
        self.shards_quarantined.store(quarantined, Ordering::Relaxed);
    }

    pub fn gauges(&self) -> NetGauges {
        NetGauges {
            open_connections: self.open_connections.load(Ordering::Relaxed),
            frames_in: self.frames_in.load(Ordering::Relaxed),
            frames_out: self.frames_out.load(Ordering::Relaxed),
            decode_errors: self.decode_errors.load(Ordering::Relaxed),
            shards_alive: self.shards_alive.load(Ordering::Relaxed),
            shards_quarantined: self.shards_quarantined.load(Ordering::Relaxed),
        }
    }
}

impl NetProbe for NetStats {
    fn net_gauges(&self) -> NetGauges {
        self.gauges()
    }
}

/// Cap on error-frame message bytes: errors must stay deliverable
/// through a nearly-full write buffer and must never dwarf the request
/// they answer.
const MAX_ERROR_MSG_BYTES: usize = 16 * 1024;

/// Encode an error frame, truncating the message (on a char boundary)
/// to [`MAX_ERROR_MSG_BYTES`]. Total infallibility matters more than
/// the message tail: this runs on the failure path, where a second
/// failure would turn a positioned error into silence.
pub(crate) fn error_frame_bytes(code: u32, msg: &str) -> Vec<u8> {
    let mut end = msg.len().min(MAX_ERROR_MSG_BYTES);
    while end > 0 && !msg.is_char_boundary(end) {
        end -= 1;
    }
    let frame = ErrorFrame { code, msg: msg[..end].to_string() };
    wire::encode_error(&frame)
        .expect("bounded error messages always encode")
}

#[cfg(all(test, not(rtopk_model_check)))]
mod tests {
    use super::*;
    use crate::coordinator::wire::{decode, Frame, ERR_REQUEST};

    #[test]
    fn error_frame_bytes_truncates_on_char_boundaries() {
        // a message of multi-byte chars longer than the cap must not
        // split a char (that would be invalid UTF-8 on the wire)
        let long = "é".repeat(MAX_ERROR_MSG_BYTES);
        let bytes = error_frame_bytes(ERR_REQUEST, &long);
        match decode(&bytes).unwrap() {
            Frame::Error(e) => {
                assert_eq!(e.code, ERR_REQUEST);
                assert!(e.msg.len() <= MAX_ERROR_MSG_BYTES);
                assert!(e.msg.chars().all(|c| c == 'é'));
            }
            other => panic!("wrong kind: {other:?}"),
        }
    }

    #[test]
    fn stats_roundtrip_through_gauges() {
        let s = NetStats::default();
        s.conn_opened();
        s.conn_opened();
        s.conn_closed();
        s.frame_in();
        s.frame_out();
        s.decode_error();
        s.set_shard_health(2, 1);
        let g = s.gauges();
        assert_eq!(g.open_connections, 1);
        assert_eq!(g.frames_in, 1);
        assert_eq!(g.frames_out, 1);
        assert_eq!(g.decode_errors, 1);
        assert_eq!(g.shards_alive, 2);
        assert_eq!(g.shards_quarantined, 1);
    }
}
