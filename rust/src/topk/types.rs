//! Shared types for row-wise top-k.

/// Search mode — the paper's two algorithms.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Mode {
    /// Algorithm 1: iterate until the bracket closes below
    /// `eps_rel * max(row)` (the paper's line 3) or the count hits k
    /// exactly. For rows whose max is non-positive — where the paper's
    /// formula would be negative/zero and the width exit could never
    /// fire — the scale falls back to `max(|max(row)|, |min(row)|)`;
    /// see `topk::binary_search`.
    /// `eps_rel = 1e-16` is the paper's "no early stopping" setting
    /// (below f32 resolution, so effectively exact).
    Exact { eps_rel: f32 },
    /// Algorithm 2: hard iteration budget, one-pass selection at the
    /// final lower bracket. Approximate; paper sweeps max_iter in 2..8.
    EarlyStop { max_iter: u32 },
    /// Recall-contracted two-stage bucketed selection (Samaga et al. /
    /// Key et al. family): split the row into B buckets, take the top
    /// k' of each with the paper's kernel, merge exactly. `recall_milli`
    /// is the contracted recall target in thousandths (950 = recall >=
    /// 0.95), exact-representable on the wire; (B, k') are derived from
    /// it in `topk::approx`. 1000 degenerates to exact selection.
    Approx { recall_milli: u16 },
}

impl Mode {
    /// The paper's default exact setting (eps = 1e-16).
    pub const EXACT: Mode = Mode::Exact { eps_rel: 1e-16 };

    pub fn tag(&self) -> String {
        match self {
            Mode::Exact { eps_rel } if *eps_rel <= 1e-15 => "exact".into(),
            Mode::Exact { eps_rel } => format!("exact_eps{eps_rel:.0e}"),
            Mode::EarlyStop { max_iter } => format!("es{max_iter}"),
            Mode::Approx { recall_milli } => format!("apx{recall_milli}"),
        }
    }
}

/// Dense row-major result of a batched top-k: row r's selection lives in
/// `values[r*k..(r+1)*k]` / `indices[r*k..(r+1)*k]`.
///
/// Values are **unsorted** (selection order: threshold survivors by
/// index, then borderline supplements by index) exactly as the paper
/// specifies — neural-network consumers never need sorted output.
#[derive(Clone, Debug, PartialEq)]
pub struct TopKResult {
    pub rows: usize,
    pub k: usize,
    pub values: Vec<f32>,
    pub indices: Vec<u32>,
}

/// Bounded freelist of retired result buffers, so internal callers that
/// produce-and-discard results in a loop (calibration probes, shadow
/// re-probes, benches) do not allocate a fresh pair of vectors per
/// batch. Capacity-keyed: `zeros` reuses the first entry large enough
/// for the requested (rows, k). Results delivered to clients are owned
/// by the client and never enter the freelist.
static RESULT_POOL: std::sync::Mutex<Vec<(Vec<f32>, Vec<u32>)>> =
    std::sync::Mutex::new(Vec::new());

/// Retired buffers kept at most; beyond this, `recycle` just drops.
const RESULT_POOL_CAP: usize = 16;

impl TopKResult {
    /// A zero-filled (rows, k) result. Reuses a retired buffer pair from
    /// the freelist when one with sufficient capacity exists; semantics
    /// are identical to fresh allocation (fully zeroed, exact length).
    pub fn zeros(rows: usize, k: usize) -> Self {
        let need = rows * k;
        let reused = {
            let mut pool = RESULT_POOL.lock().unwrap();
            pool.iter()
                .position(|(v, i)| v.capacity() >= need && i.capacity() >= need)
                .map(|at| pool.swap_remove(at))
        };
        let (mut values, mut indices) = reused.unwrap_or_default();
        values.clear();
        values.resize(need, 0.0);
        indices.clear();
        indices.resize(need, 0);
        TopKResult { rows, k, values, indices }
    }

    /// Return this result's buffers to the freelist for a future
    /// [`TopKResult::zeros`] call. Use only for results that never leave
    /// the library (probe/bench outputs); client-facing results are
    /// simply dropped by the client.
    pub fn recycle(self) {
        let mut pool = RESULT_POOL.lock().unwrap();
        if pool.len() < RESULT_POOL_CAP {
            pool.push((self.values, self.indices));
        }
    }

    #[inline]
    pub fn row_values(&self, r: usize) -> &[f32] {
        &self.values[r * self.k..(r + 1) * self.k]
    }

    #[inline]
    pub fn row_indices(&self, r: usize) -> &[u32] {
        &self.indices[r * self.k..(r + 1) * self.k]
    }

    /// Mutable (values, indices) slices for one row — handed to row
    /// selectors by the batched driver.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> (&mut [f32], &mut [u32]) {
        let k = self.k;
        (
            &mut self.values[r * k..(r + 1) * k],
            &mut self.indices[r * k..(r + 1) * k],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_tags() {
        assert_eq!(Mode::EXACT.tag(), "exact");
        assert_eq!(Mode::EarlyStop { max_iter: 4 }.tag(), "es4");
        assert_eq!(Mode::Exact { eps_rel: 1e-4 }.tag(), "exact_eps1e-4");
        assert_eq!(Mode::Approx { recall_milli: 950 }.tag(), "apx950");
    }

    #[test]
    fn recycled_buffers_come_back_zeroed() {
        let mut r = TopKResult::zeros(4, 3);
        r.values.fill(9.0);
        r.indices.fill(9);
        r.recycle();
        // Any subsequent zeros() call — whether or not it wins the
        // recycled pair under concurrent tests — must be fully zeroed
        // and exactly sized.
        let fresh = TopKResult::zeros(2, 3);
        assert_eq!(fresh.values, vec![0.0; 6]);
        assert_eq!(fresh.indices, vec![0; 6]);
        let bigger = TopKResult::zeros(8, 3);
        assert_eq!(bigger.values.len(), 24);
        assert!(bigger.values.iter().all(|&v| v == 0.0));
        assert!(bigger.indices.iter().all(|&i| i == 0));
    }

    #[test]
    fn result_row_access() {
        let mut r = TopKResult::zeros(3, 2);
        {
            let (v, i) = r.row_mut(1);
            v.copy_from_slice(&[5.0, 6.0]);
            i.copy_from_slice(&[7, 8]);
        }
        assert_eq!(r.row_values(1), &[5.0, 6.0]);
        assert_eq!(r.row_indices(1), &[7, 8]);
        assert_eq!(r.row_values(0), &[0.0, 0.0]);
    }
}
